"""Pallas kernel: masked checkpoint-interval statistics.

Batches the autonomy loop's per-job estimation step: for every running
checkpointing job (row), reduce its observed checkpoint-timestamp history
to (last, count, mean interval, std interval).

TPU-first structure (see DESIGN.md section "Hardware-Adaptation"):

- the R x H history matrix is tiled into (BLOCK_R, H) VMEM blocks; the
  history window H is small (16/32) and is kept whole per block so each
  row's reduction is a single VPU pass — no cross-block accumulation;
- all reductions are masked sums/maxes over lanes, i.e. pure VPU work,
  there is no MXU involvement;
- VMEM per block is BLOCK_R x H x 4 B x 2 operands (< 64 KiB at the
  largest variant), far below the ~16 MiB VMEM budget, leaving room for
  double-buffering the HBM->VMEM pipeline.

Lowered with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, so interpret mode is the correctness (and the only
runnable) path on this testbed.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NO_ESTIMATE

# Rows per grid step. 8 keeps the block VMEM-tiny while amortizing the
# per-step overhead; the R dimension of every shipped variant is a
# multiple of 8.
BLOCK_R = 8


def _ckpt_stats_kernel(ts_ref, mask_ref, last_ref, count_ref, mean_ref, std_ref):
    """One (BLOCK_R, H) tile: masked interval statistics per row."""
    ts = ts_ref[...]
    mask = mask_ref[...]

    count = jnp.sum(mask, axis=1)
    # Timestamps are >= 0 and padding is masked to 0, so a masked max
    # yields the most recent checkpoint (0 when the row is empty).
    last = jnp.max(ts * mask, axis=1)

    # Successive deltas are valid where both endpoints are valid. The
    # history buffer is contiguous (no holes), so this equals the true
    # inter-checkpoint interval sequence.
    dmask = mask[:, 1:] * mask[:, :-1]
    deltas = ts[:, 1:] - ts[:, :-1]
    nd = jnp.sum(dmask, axis=1)
    nd_safe = jnp.maximum(nd, 1.0)
    mean = jnp.sum(deltas * dmask, axis=1) / nd_safe
    var = jnp.sum(dmask * (deltas - mean[:, None]) ** 2, axis=1) / nd_safe
    std = jnp.sqrt(var)

    have = count >= 2.0
    last_ref[...] = last
    count_ref[...] = count
    mean_ref[...] = jnp.where(have, mean, NO_ESTIMATE)
    std_ref[...] = jnp.where(have, std, 0.0)


@functools.partial(jax.jit, static_argnames=("block_r",))
def ckpt_stats(ts, mask, *, block_r=BLOCK_R):
    """Masked checkpoint-interval statistics (Pallas).

    Args:
      ts:   f32[R, H] absolute checkpoint timestamps (0-padded).
      mask: f32[R, H] validity mask (1.0 / 0.0).
      block_r: rows per grid step; must divide R.

    Returns:
      (last, count, mean_int, std_int), each f32[R]. Semantics match
      :func:`..ref.ckpt_stats_ref`.
    """
    r, h = ts.shape
    if r % block_r != 0:
        raise ValueError(f"R={r} must be a multiple of block_r={block_r}")
    grid = (r // block_r,)
    row_spec = pl.BlockSpec((block_r, h), lambda i: (i, 0))
    out_spec = pl.BlockSpec((block_r,), lambda i: (i,))
    out_shape = jax.ShapeDtypeStruct((r,), jnp.float32)
    return pl.pallas_call(
        _ckpt_stats_kernel,
        grid=grid,
        in_specs=[row_spec, row_spec],
        out_specs=[out_spec, out_spec, out_spec, out_spec],
        out_shape=[out_shape, out_shape, out_shape, out_shape],
        interpret=True,
    )(ts, mask)
