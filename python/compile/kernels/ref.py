"""Pure-jnp reference oracles for the Layer-1 Pallas kernels.

These are the CORE correctness signal: every Pallas kernel must match its
oracle to float32 tolerance across the hypothesis sweep in
``python/tests/``. The Rust ``analytics::NativeEngine`` mirrors the same
formulas (same operation order, f32 arithmetic) so that
native == pjrt == ref end to end.
"""

import jax.numpy as jnp

# Sentinel interval used when a job has fewer than two observed
# checkpoints (no estimate possible). Keep in sync with
# rust/src/analytics/mod.rs::NO_ESTIMATE.
NO_ESTIMATE = -1.0


def ckpt_stats_ref(ts, mask):
    """Masked checkpoint-interval statistics.

    Args:
      ts:   f32[R, H] absolute checkpoint timestamps, ascending where
            masked, arbitrary (>= 0) padding elsewhere.
      mask: f32[R, H] 1.0 for valid entries, 0.0 for padding.

    Returns:
      (last, count, mean_int, std_int) — each f32[R]:
        last:     timestamp of the most recent checkpoint (0 if none).
        count:    number of valid checkpoints.
        mean_int: mean of successive deltas (NO_ESTIMATE if count < 2).
        std_int:  population std of successive deltas (0 if count < 2).
    """
    ts = ts.astype(jnp.float32)
    mask = mask.astype(jnp.float32)
    count = jnp.sum(mask, axis=1)
    last = jnp.max(ts * mask, axis=1)

    dmask = mask[:, 1:] * mask[:, :-1]
    deltas = ts[:, 1:] - ts[:, :-1]
    nd = jnp.sum(dmask, axis=1)
    nd_safe = jnp.maximum(nd, 1.0)
    mean = jnp.sum(deltas * dmask, axis=1) / nd_safe
    var = jnp.sum(dmask * (deltas - mean[:, None]) ** 2, axis=1) / nd_safe
    std = jnp.sqrt(var)

    have = count >= 2.0
    mean = jnp.where(have, mean, NO_ESTIMATE)
    std = jnp.where(have, std, 0.0)
    return last, count, mean, std


def conflict_ref(cur_end, ext_end, nodes_r, rmask, pred_start, nodes_q, free_at, qmask):
    """Extension-delay conflict check (Hybrid policy).

    Extending running job r from ``cur_end[r]`` to ``ext_end[r]`` delays
    queued job q iff q was planned to start inside the extension window
    and needs nodes that only r's release would free:

        conflict(r, q) = pred_start[q] >= cur_end[r]
                       & pred_start[q] <  ext_end[r]
                       & nodes_q[q]    >  free_at[q] - nodes_r[r]

    ``free_at[q]`` is the scheduler's free-node count at q's predicted
    start under the *current* limits (i.e. assuming r has ended by then
    when pred_start >= cur_end), computed by the Rust coordinator from
    the availability timeline.

    Returns f32[R]: 1.0 where any queued job would be delayed.
    """
    cur_end = cur_end.astype(jnp.float32)
    ext_end = ext_end.astype(jnp.float32)
    in_window = (pred_start[None, :] >= cur_end[:, None]) & (
        pred_start[None, :] < ext_end[:, None]
    )
    needs_r = nodes_q[None, :] > (free_at[None, :] - nodes_r[:, None])
    c = in_window & needs_r & (qmask[None, :] > 0.0) & (rmask[:, None] > 0.0)
    return jnp.max(c.astype(jnp.float32), axis=1)


def delay_cost_ref(cur_end, ext_end, nodes_r, rmask, pred_start, nodes_q, free_at, qmask):
    """Worst-case extension delay cost (node-seconds): each conflicting
    queued job is pushed from its predicted start to the extended end.
    See kernels/delay_cost.py."""
    cur_end = cur_end.astype(jnp.float32)
    ext_end = ext_end.astype(jnp.float32)
    in_window = (pred_start[None, :] >= cur_end[:, None]) & (
        pred_start[None, :] < ext_end[:, None]
    )
    needs_r = nodes_q[None, :] > (free_at[None, :] - nodes_r[:, None])
    c = in_window & needs_r & (qmask[None, :] > 0.0) & (rmask[:, None] > 0.0)
    push = jnp.maximum(ext_end[:, None] - pred_start[None, :], 0.0)
    return jnp.sum(jnp.where(c, push * nodes_q[None, :], 0.0), axis=1)


def decision_ref(ts, mask, cur_end, nodes_r, rmask, pred_start, nodes_q, free_at, qmask, params):
    """Reference for the full Layer-2 decision model (see model.py)."""
    margin = params[0]
    safety = params[1]
    last, count, mean, std = ckpt_stats_ref(ts, mask)
    have = count >= 2.0
    pred_next = jnp.where(have, last + mean + safety * std, -1.0)
    ext_end = jnp.where(have, pred_next + margin, -1.0)
    fits = jnp.where(have & (pred_next + margin <= cur_end), 1.0, 0.0)
    rmask_eff = rmask * have.astype(jnp.float32)
    conf = conflict_ref(
        cur_end, ext_end, nodes_r, rmask_eff, pred_start, nodes_q, free_at, qmask
    )
    cost = delay_cost_ref(
        cur_end, ext_end, nodes_r, rmask_eff, pred_start, nodes_q, free_at, qmask
    )
    return pred_next, ext_end, fits, conf, count, mean, cost
