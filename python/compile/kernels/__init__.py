"""Layer-1 Pallas kernels for the tailtamer decision model.

Two kernels implement the autonomy loop's per-poll-tick analytics:

- :mod:`ckpt_stats` — masked checkpoint-interval statistics over a batch
  of running jobs (last checkpoint, count, mean / std of the successive
  intervals).
- :mod:`conflict` — the Hybrid policy's extension-delay check: an R x Q
  comparison between running jobs' candidate extended end times and
  queued jobs' predicted start times / node demands.

Both are lowered with ``interpret=True`` (the CPU PJRT plugin cannot run
Mosaic custom-calls); the BlockSpec structure is written TPU-first, see
DESIGN.md section "Hardware-Adaptation". :mod:`ref` holds the pure-jnp
oracles the pytest suite checks the kernels against.
"""

from .ckpt_stats import ckpt_stats
from .conflict import conflict
from .delay_cost import delay_cost

__all__ = ["ckpt_stats", "conflict", "delay_cost"]
