"""Pallas kernel: extension delay *cost* (threshold-Hybrid extension).

The paper's Hybrid policy is binary: extend only if **no** queued job is
delayed. Operators may accept small delays in exchange for checkpoints
(Discussion §6, "policies for extending jobs must be carefully
calibrated"). This kernel quantifies the trade: for running job r, the
worst-case scheduling cost of extending it is

    cost[r] = sum_q conflict(r, q) * (ext_end[r] - pred_start[q]) * nodes_q[q]

in node-seconds — each conflicting queued job q is pushed from its
predicted start to (at worst) r's extended end while needing nodes_q
nodes. The Rust daemon's `max_delay_cost` knob extends iff
cost <= threshold; threshold 0 reproduces the paper's strict Hybrid.

Same tiled (BLOCK_R x BLOCK_Q) grid as :mod:`conflict`, but the fold
across Q blocks is a **sum** (add-accumulate on output revisits) rather
than an OR. Pure VPU multiply-add work; VMEM per step is identical to
the conflict kernel's.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 8
BLOCK_Q = 64


def _delay_cost_kernel(
    cur_end_ref, ext_end_ref, nodes_r_ref, rmask_ref,
    pred_start_ref, nodes_q_ref, free_at_ref, qmask_ref,
    out_ref,
):
    """One (BLOCK_R, BLOCK_Q) tile of delay costs, sum-folded over Q."""
    qi = pl.program_id(1)

    cur_end = cur_end_ref[...]
    ext_end = ext_end_ref[...]
    nodes_r = nodes_r_ref[...]
    rmask = rmask_ref[...]
    pred_start = pred_start_ref[...]
    nodes_q = nodes_q_ref[...]
    free_at = free_at_ref[...]
    qmask = qmask_ref[...]

    in_window = (pred_start[None, :] >= cur_end[:, None]) & (
        pred_start[None, :] < ext_end[:, None]
    )
    needs_r = nodes_q[None, :] > (free_at[None, :] - nodes_r[:, None])
    c = in_window & needs_r & (qmask[None, :] > 0.0) & (rmask[:, None] > 0.0)
    push = jnp.maximum(ext_end[:, None] - pred_start[None, :], 0.0)
    tile_cost = jnp.sum(jnp.where(c, push * nodes_q[None, :], 0.0), axis=1)

    @pl.when(qi == 0)
    def _init():
        out_ref[...] = tile_cost

    @pl.when(qi != 0)
    def _fold():
        out_ref[...] = out_ref[...] + tile_cost


@functools.partial(jax.jit, static_argnames=("block_r", "block_q"))
def delay_cost(
    cur_end, ext_end, nodes_r, rmask,
    pred_start, nodes_q, free_at, qmask,
    *, block_r=BLOCK_R, block_q=BLOCK_Q,
):
    """Worst-case extension delay cost per running job (Pallas).

    Args/semantics: see module docstring; operand layout matches
    :func:`..conflict.conflict`. Returns f32[R] node-seconds.
    """
    (r,) = cur_end.shape
    (q,) = pred_start.shape
    if r % block_r != 0 or q % block_q != 0:
        raise ValueError(f"R={r}, Q={q} must be multiples of ({block_r}, {block_q})")
    grid = (r // block_r, q // block_q)
    r_spec = pl.BlockSpec((block_r,), lambda i, j: (i,))
    q_spec = pl.BlockSpec((block_q,), lambda i, j: (j,))
    return pl.pallas_call(
        _delay_cost_kernel,
        grid=grid,
        in_specs=[r_spec, r_spec, r_spec, r_spec, q_spec, q_spec, q_spec, q_spec],
        out_specs=r_spec,
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(cur_end, ext_end, nodes_r, rmask, pred_start, nodes_q, free_at, qmask)
