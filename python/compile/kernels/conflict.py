"""Pallas kernel: extension-delay conflict matrix (Hybrid policy).

For every running checkpointing job r with a candidate extended end
``ext_end[r]`` and every queued job q with backfill-predicted start
``pred_start[q]``, decide whether extending r would delay q:

    conflict(r, q) = pred_start[q] in [cur_end[r], ext_end[r])
                   & nodes_q[q] > free_at[q] - nodes_r[r]

and reduce with OR over q. This is the O(R x Q) hot spot of the paper's
Hybrid decision ("extend only if it does not delay other jobs").

TPU-first structure (DESIGN.md section "Hardware-Adaptation"):

- 2-D grid over (R-blocks, Q-blocks); each step loads four (BLOCK_R,)
  operand slices and four (BLOCK_Q,) slices into VMEM and materializes
  only a (BLOCK_R, BLOCK_Q) tile of the comparison matrix — the full
  R x Q matrix never exists in memory;
- the OR-reduction over Q revisits the same (BLOCK_R,) output block
  across the Q grid dimension, the standard Pallas accumulation
  pattern (initialize on q-index 0, max-accumulate afterwards);
- pure VPU compare/select work, bandwidth-bound; VMEM per step is
  O(BLOCK_R x BLOCK_Q x 4 B), 64 x 128 tiles use 32 KiB.

Lowered with ``interpret=True`` (CPU PJRT cannot run Mosaic calls).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 8
BLOCK_Q = 64


def _conflict_kernel(
    cur_end_ref, ext_end_ref, nodes_r_ref, rmask_ref,
    pred_start_ref, nodes_q_ref, free_at_ref, qmask_ref,
    out_ref,
):
    """One (BLOCK_R, BLOCK_Q) tile of the conflict matrix, OR-folded."""
    qi = pl.program_id(1)

    cur_end = cur_end_ref[...]
    ext_end = ext_end_ref[...]
    nodes_r = nodes_r_ref[...]
    rmask = rmask_ref[...]
    pred_start = pred_start_ref[...]
    nodes_q = nodes_q_ref[...]
    free_at = free_at_ref[...]
    qmask = qmask_ref[...]

    in_window = (pred_start[None, :] >= cur_end[:, None]) & (
        pred_start[None, :] < ext_end[:, None]
    )
    needs_r = nodes_q[None, :] > (free_at[None, :] - nodes_r[:, None])
    c = in_window & needs_r & (qmask[None, :] > 0.0) & (rmask[:, None] > 0.0)
    tile_any = jnp.max(c.astype(jnp.float32), axis=1)

    @pl.when(qi == 0)
    def _init():
        out_ref[...] = tile_any

    @pl.when(qi != 0)
    def _fold():
        out_ref[...] = jnp.maximum(out_ref[...], tile_any)


@functools.partial(jax.jit, static_argnames=("block_r", "block_q"))
def conflict(
    cur_end, ext_end, nodes_r, rmask,
    pred_start, nodes_q, free_at, qmask,
    *, block_r=BLOCK_R, block_q=BLOCK_Q,
):
    """Extension-delay conflict flags (Pallas).

    Args:
      cur_end, ext_end, nodes_r, rmask: f32[R] running-job operands.
      pred_start, nodes_q, free_at, qmask: f32[Q] queued-job operands.
      block_r, block_q: tile sizes; must divide R and Q.

    Returns:
      f32[R]: 1.0 where extending job r would delay at least one queued
      job. Semantics match :func:`..ref.conflict_ref`.
    """
    (r,) = cur_end.shape
    (q,) = pred_start.shape
    if r % block_r != 0 or q % block_q != 0:
        raise ValueError(f"R={r}, Q={q} must be multiples of ({block_r}, {block_q})")
    grid = (r // block_r, q // block_q)
    r_spec = pl.BlockSpec((block_r,), lambda i, j: (i,))
    q_spec = pl.BlockSpec((block_q,), lambda i, j: (j,))
    return pl.pallas_call(
        _conflict_kernel,
        grid=grid,
        in_specs=[r_spec, r_spec, r_spec, r_spec, q_spec, q_spec, q_spec, q_spec],
        out_specs=r_spec,
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(cur_end, ext_end, nodes_r, rmask, pred_start, nodes_q, free_at, qmask)
