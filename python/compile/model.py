"""Layer-2 JAX decision model for the tailtamer autonomy loop.

``decision_model`` is the compute graph the Rust daemon executes (via a
PJRT-compiled HLO artifact) on every poll tick. It fuses the two Layer-1
Pallas kernels:

  1. :func:`kernels.ckpt_stats` — per running job: last checkpoint,
     count, mean/std of the observed checkpoint intervals;
  2. a prediction step — next checkpoint = last + mean + safety * std,
     candidate extended end = next + margin, and whether the next
     checkpoint still *fits* the current time limit;
  3. :func:`kernels.conflict` — whether extending each job would delay
     any queued job (the Hybrid policy's guard).

Everything is f32 and fixed-shape: the Rust side pads each batch to the
smallest shipped (R, Q, H) variant. The *policy* (early-cancel vs extend
vs leave alone) stays in Rust — it is control flow over these outputs.

Input order (must match rust/src/runtime marshalling; recorded in the
artifact manifest):

  0 ts         f32[R, H]  checkpoint timestamps (0-padded)
  1 mask       f32[R, H]  validity mask
  2 cur_end    f32[R]     current expected end (start + current limit)
  3 nodes_r    f32[R]     nodes held by each running job
  4 rmask      f32[R]     running-row validity
  5 pred_start f32[Q]     backfill-predicted start of queued jobs
  6 nodes_q    f32[Q]     nodes requested by queued jobs
  7 free_at    f32[Q]     free nodes at pred_start under current limits
  8 qmask      f32[Q]     queued-row validity
  9 params     f32[2]     [margin, safety]

Output tuple (all f32[R]):

  0 pred_next  predicted next checkpoint time (-1 if no estimate)
  1 ext_end    candidate extended end (-1 if no estimate)
  2 fits       1.0 if the next checkpoint fits the current limit
  3 conflict   1.0 if extension would delay a queued job
  4 count      observed checkpoints
  5 mean_int   estimated checkpoint interval (-1 if no estimate)
  6 delay_cost worst-case extension delay cost, node-seconds (the
               threshold-Hybrid policy's input; 0 when no conflict)
"""

import jax.numpy as jnp

from .kernels import ckpt_stats, conflict, delay_cost

#: Shipped (R, Q, H) shape variants. The Rust runtime picks the smallest
#: variant that fits the live batch and pads with masked rows.
VARIANTS = ((16, 64, 16), (64, 256, 32))


def decision_model(ts, mask, cur_end, nodes_r, rmask, pred_start, nodes_q, free_at, qmask, params):
    """Full per-poll-tick decision analytics. See module docstring."""
    margin = params[0]
    safety = params[1]

    last, count, mean, std = ckpt_stats(ts, mask)
    have = count >= 2.0

    pred_next = jnp.where(have, last + mean + safety * std, -1.0)
    ext_end = jnp.where(have, pred_next + margin, -1.0)
    fits = jnp.where(have & (pred_next + margin <= cur_end), 1.0, 0.0)

    rmask_eff = rmask * have.astype(jnp.float32)
    conf = conflict(cur_end, ext_end, nodes_r, rmask_eff, pred_start, nodes_q, free_at, qmask)
    cost = delay_cost(cur_end, ext_end, nodes_r, rmask_eff, pred_start, nodes_q, free_at, qmask)
    return pred_next, ext_end, fits, conf, count, mean, cost


def example_args(r, q, h):
    """ShapeDtypeStructs for lowering one (R, Q, H) variant."""
    import jax

    f32 = jnp.float32
    return (
        jax.ShapeDtypeStruct((r, h), f32),
        jax.ShapeDtypeStruct((r, h), f32),
        jax.ShapeDtypeStruct((r,), f32),
        jax.ShapeDtypeStruct((r,), f32),
        jax.ShapeDtypeStruct((r,), f32),
        jax.ShapeDtypeStruct((q,), f32),
        jax.ShapeDtypeStruct((q,), f32),
        jax.ShapeDtypeStruct((q,), f32),
        jax.ShapeDtypeStruct((q,), f32),
        jax.ShapeDtypeStruct((2,), f32),
    )
