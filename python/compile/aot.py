"""AOT export: lower the Layer-2 decision model to HLO-text artifacts.

Run once at build time (``make artifacts``); the Rust runtime loads the
resulting ``decision_r{R}_q{Q}_h{H}.hlo.txt`` files via
``HloModuleProto::from_text_file`` and compiles them on the PJRT CPU
client. Python never runs on the request path.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly. Lowered with ``return_tuple=True`` — the Rust side
unwraps the tuple.
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import VARIANTS, decision_model, example_args


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


INPUT_ORDER = [
    "ts[R,H]", "mask[R,H]", "cur_end[R]", "nodes_r[R]", "rmask[R]",
    "pred_start[Q]", "nodes_q[Q]", "free_at[Q]", "qmask[Q]", "params[2]",
]
OUTPUT_ORDER = [
    "pred_next[R]", "ext_end[R]", "fits[R]", "conflict[R]", "count[R]", "mean_int[R]",
    "delay_cost[R]",
]


def export_variant(out_dir: str, r: int, q: int, h: int) -> dict:
    """Lower one (R, Q, H) variant and write its HLO text. Returns manifest entry."""
    lowered = jax.jit(decision_model).lower(*example_args(r, q, h))
    text = to_hlo_text(lowered)
    name = f"decision_r{r}_q{q}_h{h}.hlo.txt"
    path = os.path.join(out_dir, name)
    with open(path, "w") as f:
        f.write(text)
    return {
        "file": name,
        "r": r,
        "q": q,
        "h": h,
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "bytes": len(text),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    entries = [export_variant(args.out, r, q, h) for (r, q, h) in VARIANTS]
    manifest = {
        "model": "tailtamer decision_model",
        "inputs": INPUT_ORDER,
        "outputs": OUTPUT_ORDER,
        "variants": entries,
        "jax": jax.__version__,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    for e in entries:
        print(f"wrote {e['file']} ({e['bytes']} bytes)")
    print(f"wrote manifest.json ({len(entries)} variants)")


if __name__ == "__main__":
    main()
