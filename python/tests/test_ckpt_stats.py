"""Layer-1 correctness: ckpt_stats Pallas kernel vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ckpt_stats
from compile.kernels.ref import NO_ESTIMATE, ckpt_stats_ref

from .conftest import make_history


def assert_matches_ref(ts, mask):
    got = ckpt_stats(jnp.asarray(ts), jnp.asarray(mask))
    want = ckpt_stats_ref(jnp.asarray(ts), jnp.asarray(mask))
    names = ["last", "count", "mean_int", "std_int"]
    for name, g, w in zip(names, got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-5, atol=1e-4, err_msg=name
        )


def test_matches_ref_random(rng):
    ts, mask = make_history(rng, 32, 16)
    assert_matches_ref(ts, mask)


def test_matches_ref_with_jitter(rng):
    ts, mask = make_history(rng, 32, 16, jitter=0.3)
    assert_matches_ref(ts, mask)


def test_empty_rows():
    ts = np.zeros((8, 16), np.float32)
    mask = np.zeros((8, 16), np.float32)
    last, count, mean, std = (np.asarray(x) for x in ckpt_stats(jnp.asarray(ts), jnp.asarray(mask)))
    assert (last == 0).all()
    assert (count == 0).all()
    assert (mean == NO_ESTIMATE).all()
    assert (std == 0).all()


def test_single_checkpoint_has_no_estimate():
    ts = np.zeros((8, 16), np.float32)
    mask = np.zeros((8, 16), np.float32)
    ts[:, 0] = 123.0
    mask[:, 0] = 1.0
    last, count, mean, std = (np.asarray(x) for x in ckpt_stats(jnp.asarray(ts), jnp.asarray(mask)))
    assert (last == 123.0).all()
    assert (count == 1).all()
    assert (mean == NO_ESTIMATE).all()


def test_exact_periodic_interval():
    """A perfectly periodic reporter must estimate exactly its interval."""
    h = 16
    k = np.arange(h, dtype=np.float32)
    ts = np.tile(100.0 + 420.0 * k, (8, 1)).astype(np.float32)
    mask = np.ones((8, h), np.float32)
    last, count, mean, std = (np.asarray(x) for x in ckpt_stats(jnp.asarray(ts), jnp.asarray(mask)))
    np.testing.assert_allclose(mean, 420.0, rtol=1e-6)
    np.testing.assert_allclose(std, 0.0, atol=1e-2)
    np.testing.assert_allclose(last, 100.0 + 420.0 * (h - 1))


def test_mean_equals_telescoped_range(rng):
    """Mean of successive deltas == (last-first)/(n-1) for gap-free rows."""
    ts, mask = make_history(rng, 16, 16)
    last, count, mean, _ = (np.asarray(x) for x in ckpt_stats(jnp.asarray(ts), jnp.asarray(mask)))
    for i in range(16):
        n = int(mask[i].sum())
        if n >= 2:
            valid = ts[i, :n]
            np.testing.assert_allclose(mean[i], (valid[-1] - valid[0]) / (n - 1), rtol=1e-4)


def test_bad_block_size_rejected():
    ts = np.zeros((10, 16), np.float32)
    with pytest.raises(ValueError, match="multiple"):
        ckpt_stats(jnp.asarray(ts), jnp.asarray(ts), block_r=8)


@settings(max_examples=25, deadline=None)
@given(
    r_blocks=st.integers(1, 4),
    h=st.integers(2, 32),
    seed=st.integers(0, 2**32 - 1),
    jitter=st.floats(0.0, 0.4),
)
def test_hypothesis_shapes_and_jitter(r_blocks, h, seed, jitter):
    rng = np.random.default_rng(seed)
    ts, mask = make_history(rng, 8 * r_blocks, h, jitter=jitter)
    assert_matches_ref(ts, mask)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_hypothesis_large_timestamps(seed):
    """Timestamps at the scale of a full workload run (~1e5 s) stay exact enough."""
    rng = np.random.default_rng(seed)
    ts, mask = make_history(rng, 16, 16)
    ts = ts + 100_000.0 * mask
    assert_matches_ref(ts.astype(np.float32), mask)
