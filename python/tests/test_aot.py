"""AOT artifact pipeline: export, manifest integrity, round-trip execution."""

import hashlib
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.model import VARIANTS, decision_model

from .conftest import make_history, make_queue


def test_export_writes_all_variants_and_manifest():
    with tempfile.TemporaryDirectory() as d:
        entries = [aot.export_variant(d, r, q, h) for (r, q, h) in VARIANTS]
        for e in entries:
            path = os.path.join(d, e["file"])
            assert os.path.exists(path)
            text = open(path).read()
            assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]
            assert text.startswith("HloModule")
            # The interchange contract: parameters in the documented order
            # and a tuple root (return_tuple=True).
            assert f'f32[{e["r"]},{e["h"]}]' in text


def test_hlo_text_has_expected_entry_layout():
    with tempfile.TemporaryDirectory() as d:
        e = aot.export_variant(d, *VARIANTS[0])
        text = open(os.path.join(d, e["file"])).read()
        header = text.splitlines()[0]
        r, q, h = VARIANTS[0]
        # 10 parameters: 2 matrices, 3 R-vectors, 4 Q-vectors, params[2]
        assert header.count(f"f32[{r},{h}]") == 2
        assert header.count(f"f32[{q}]") == 4
        assert f"f32[2]" in header


def test_repo_manifest_matches_artifacts():
    """If `make artifacts` has run, the checked manifest must be consistent."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    man_path = os.path.join(art, "manifest.json")
    if not os.path.exists(man_path):
        import pytest

        pytest.skip("artifacts not built")
    man = json.load(open(man_path))
    assert man["inputs"] == aot.INPUT_ORDER
    assert man["outputs"] == aot.OUTPUT_ORDER
    for e in man["variants"]:
        text = open(os.path.join(art, e["file"])).read()
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]


def test_lowered_text_stable_under_concrete_args(rng):
    """Lowering with ShapeDtypeStructs == lowering with concrete arrays.

    The artifact is produced from abstract shapes; the daemon feeds it
    concrete batches — both must describe the same module. (The numeric
    round trip of the text loader itself is covered by the Rust
    integration tests, which execute the shipped artifacts via PJRT and
    compare against the NativeEngine oracle.)
    """
    r, q, h = VARIANTS[0]
    ts, mask = make_history(rng, r, h)
    ce = (np.max(ts, axis=1) + 500.0).astype(np.float32)
    nr = np.ones(r, np.float32)
    rm = (mask.sum(axis=1) > 0).astype(np.float32)
    ps, nq, fa, qm = make_queue(rng, q)
    params = np.array([30.0, 0.5], np.float32)
    batch = (ts, mask, ce, nr, rm, ps, nq, fa, qm, params)

    from compile.model import example_args

    concrete = aot.to_hlo_text(
        jax.jit(decision_model).lower(*(jnp.asarray(a) for a in batch))
    )
    abstract = aot.to_hlo_text(jax.jit(decision_model).lower(*example_args(r, q, h)))
    assert concrete == abstract
