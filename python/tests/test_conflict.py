"""Layer-1 correctness: conflict Pallas kernel vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import conflict
from compile.kernels.ref import conflict_ref

from .conftest import make_queue


def run_both(ce, ee, nr, rm, ps, nq, fa, qm):
    args = tuple(jnp.asarray(a) for a in (ce, ee, nr, rm, ps, nq, fa, qm))
    return np.asarray(conflict(*args)), np.asarray(conflict_ref(*args))


def rand_running(rng, r, horizon=50_000.0):
    ce = rng.uniform(0.0, horizon, r).astype(np.float32)
    ee = (ce + rng.uniform(0.0, 2000.0, r)).astype(np.float32)
    nr = rng.integers(1, 8, r).astype(np.float32)
    rm = (rng.random(r) < 0.85).astype(np.float32)
    return ce, ee, nr, rm


def test_matches_ref_random(rng):
    ce, ee, nr, rm = rand_running(rng, 16)
    ps, nq, fa, qm = make_queue(rng, 64)
    got, want = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    np.testing.assert_array_equal(got, want)


def test_no_queue_no_conflict(rng):
    ce, ee, nr, rm = rand_running(rng, 8)
    ps, nq, fa, _ = make_queue(rng, 64)
    qm = np.zeros(64, np.float32)
    got, _ = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    assert (got == 0).all()


def test_masked_running_rows_never_conflict(rng):
    ce, ee, nr, _ = rand_running(rng, 8)
    rm = np.zeros(8, np.float32)
    ps, nq, fa, qm = make_queue(rng, 64)
    got, _ = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    assert (got == 0).all()


def test_non_multiple_shapes_rejected():
    import pytest

    one = np.zeros(1, np.float32)
    q64 = np.zeros(64, np.float32)
    with pytest.raises(ValueError, match="multiples"):
        conflict(*(jnp.asarray(a) for a in (one, one, one, one, q64, q64, q64, q64)))


def test_window_semantics_hand_case_r8():
    ce = np.full(8, 100.0, np.float32)
    ee = np.full(8, 200.0, np.float32)
    nr = np.full(8, 4.0, np.float32)
    rm = np.zeros(8, np.float32)
    rm[0] = 1.0
    ps = np.array([150.0, 250.0, 150.0, 99.0] + [0.0] * 60, np.float32)
    nq = np.array([10.0, 10.0, 2.0, 10.0] + [0.0] * 60, np.float32)
    fa = np.array([12.0, 12.0, 12.0, 12.0] + [0.0] * 60, np.float32)
    qm = np.array([1.0, 1.0, 1.0, 1.0] + [0.0] * 60, np.float32)
    got, want = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    np.testing.assert_array_equal(got, want)
    assert got[0] == 1.0  # q0 triggers
    assert (got[1:] == 0.0).all()


def test_boundary_inclusive_exclusive():
    """pred_start == cur_end is in-window; pred_start == ext_end is not."""
    ce = np.full(8, 100.0, np.float32)
    ee = np.full(8, 200.0, np.float32)
    nr = np.full(8, 20.0, np.float32)
    rm = np.ones(8, np.float32)
    ps = np.zeros(64, np.float32)
    nq = np.zeros(64, np.float32)
    fa = np.zeros(64, np.float32)
    qm = np.zeros(64, np.float32)
    ps[0], nq[0], fa[0], qm[0] = 100.0, 1.0, 0.0, 1.0  # at cur_end -> conflict
    ps[1], nq[1], fa[1], qm[1] = 200.0, 1.0, 0.0, 1.0  # at ext_end -> no
    got, want = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    np.testing.assert_array_equal(got, want)
    assert (got == 1.0).all()  # q0 alone causes conflict for every row
    qm[0] = 0.0
    got2, _ = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    assert (got2 == 0.0).all()


@settings(max_examples=25, deadline=None)
@given(
    r_blocks=st.integers(1, 8),
    q_blocks=st.integers(1, 4),
    seed=st.integers(0, 2**32 - 1),
)
def test_hypothesis_tiled_grids(r_blocks, q_blocks, seed):
    """The OR-accumulation across Q tiles must match the flat oracle."""
    rng = np.random.default_rng(seed)
    ce, ee, nr, rm = rand_running(rng, 8 * r_blocks)
    ps, nq, fa, qm = make_queue(rng, 64 * q_blocks)
    got, want = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    np.testing.assert_array_equal(got, want)
