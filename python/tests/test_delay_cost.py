"""Layer-1 correctness: delay_cost Pallas kernel vs pure-jnp oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import conflict, delay_cost
from compile.kernels.ref import conflict_ref, delay_cost_ref

from .conftest import make_queue


def run_both(ce, ee, nr, rm, ps, nq, fa, qm):
    args = tuple(jnp.asarray(a) for a in (ce, ee, nr, rm, ps, nq, fa, qm))
    return np.asarray(delay_cost(*args)), np.asarray(delay_cost_ref(*args))


def rand_running(rng, r, horizon=50_000.0):
    ce = rng.uniform(0.0, horizon, r).astype(np.float32)
    ee = (ce + rng.uniform(0.0, 2000.0, r)).astype(np.float32)
    nr = rng.integers(1, 8, r).astype(np.float32)
    rm = (rng.random(r) < 0.85).astype(np.float32)
    return ce, ee, nr, rm


def test_matches_ref_random(rng):
    ce, ee, nr, rm = rand_running(rng, 16)
    ps, nq, fa, qm = make_queue(rng, 64)
    got, want = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_hand_case_cost_arithmetic():
    # One running job extended 100 -> 200; two conflicting queued jobs at
    # 150 (3 nodes) and 180 (2 nodes): cost = 50*3 + 20*2 = 190.
    ce = np.full(8, 100.0, np.float32)
    ee = np.full(8, 200.0, np.float32)
    nr = np.full(8, 20.0, np.float32)  # r holds everything -> any q needs it
    rm = np.zeros(8, np.float32)
    rm[0] = 1.0
    ps = np.zeros(64, np.float32)
    nq = np.zeros(64, np.float32)
    fa = np.zeros(64, np.float32)
    qm = np.zeros(64, np.float32)
    ps[0], nq[0], qm[0] = 150.0, 3.0, 1.0
    ps[1], nq[1], qm[1] = 180.0, 2.0, 1.0
    ps[2], nq[2], qm[2] = 250.0, 5.0, 1.0  # outside window
    got, want = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    np.testing.assert_allclose(got, want)
    assert got[0] == 50.0 * 3.0 + 20.0 * 2.0
    assert (got[1:] == 0.0).all()


def test_cost_zero_iff_no_conflict(rng):
    """cost > 0 exactly where the conflict kernel flags a delay."""
    ce, ee, nr, rm = rand_running(rng, 16)
    ps, nq, fa, qm = make_queue(rng, 64)
    args = tuple(jnp.asarray(a) for a in (ce, ee, nr, rm, ps, nq, fa, qm))
    cost = np.asarray(delay_cost(*args))
    flag = np.asarray(conflict(*args))
    flag_ref = np.asarray(conflict_ref(*args))
    np.testing.assert_array_equal(flag, flag_ref)
    # Conflicting q's are strictly inside the window, so push > 0.
    np.testing.assert_array_equal(cost > 0.0, flag > 0.0)


@settings(max_examples=20, deadline=None)
@given(
    r_blocks=st.integers(1, 4),
    q_blocks=st.integers(1, 4),
    seed=st.integers(0, 2**32 - 1),
)
def test_hypothesis_sum_fold_across_tiles(r_blocks, q_blocks, seed):
    """The add-accumulation across Q tiles must match the flat oracle."""
    rng = np.random.default_rng(seed)
    ce, ee, nr, rm = rand_running(rng, 8 * r_blocks)
    ps, nq, fa, qm = make_queue(rng, 64 * q_blocks)
    got, want = run_both(ce, ee, nr, rm, ps, nq, fa, qm)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-2)
