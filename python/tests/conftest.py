"""Shared fixtures/strategies for the tailtamer python test suite."""

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0xC0FFEE)


def make_history(rng, r, h, *, interval_lo=30.0, interval_hi=900.0, jitter=0.0):
    """Synthesize a (ts, mask) checkpoint-history batch.

    Each row is an ascending timestamp sequence with a per-row base
    interval and optional uniform jitter; row i has a random number of
    valid entries in [0, h].
    """
    base = rng.uniform(0.0, 5000.0, (r, 1)).astype(np.float32)
    iv = rng.uniform(interval_lo, interval_hi, (r, 1)).astype(np.float32)
    k = np.arange(h, dtype=np.float32)[None, :]
    ts = base + k * iv
    if jitter > 0.0:
        steps = rng.uniform(-jitter, jitter, (r, h)).astype(np.float32) * iv
        steps[:, 0] = 0.0
        ts = ts + np.cumsum(steps * 0.0, axis=1) + steps  # bounded jitter, keeps order for jitter < 0.5
    n = rng.integers(0, h + 1, r)
    mask = (k < n[:, None]).astype(np.float32)
    ts = (ts * mask).astype(np.float32)
    return ts, mask


def make_queue(rng, q, *, horizon=50_000.0, max_nodes=20):
    """Synthesize queued-job operands (pred_start, nodes_q, free_at, qmask)."""
    ps = rng.uniform(0.0, horizon, q).astype(np.float32)
    nq = rng.integers(1, max_nodes + 1, q).astype(np.float32)
    fa = rng.integers(0, max_nodes + 1, q).astype(np.float32)
    qm = (rng.random(q) < 0.85).astype(np.float32)
    return ps, nq, fa, qm
