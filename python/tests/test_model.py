"""Layer-2 correctness: decision_model vs decision_ref, plus shape checks."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import decision_ref
from compile.model import VARIANTS, decision_model, example_args

from .conftest import make_history, make_queue


def make_batch(rng, r, q, h, margin=30.0, safety=0.5):
    ts, mask = make_history(rng, r, h)
    ce = (np.max(ts, axis=1) + rng.uniform(0.0, 1000.0, r)).astype(np.float32)
    nr = rng.integers(1, 8, r).astype(np.float32)
    rm = (mask.sum(axis=1) > 0).astype(np.float32)
    ps, nq, fa, qm = make_queue(rng, q)
    params = np.array([margin, safety], np.float32)
    return (ts, mask, ce, nr, rm, ps, nq, fa, qm, params)


def run_both(batch):
    args = tuple(jnp.asarray(a) for a in batch)
    got = decision_model(*args)
    want = decision_ref(*args)
    return [np.asarray(g) for g in got], [np.asarray(w) for w in want]


NAMES = ["pred_next", "ext_end", "fits", "conflict", "count", "mean_int", "delay_cost"]


def test_matches_ref(rng):
    got, want = run_both(make_batch(rng, 16, 64, 16))
    for n, g, w in zip(NAMES, got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-3, err_msg=n)


def test_output_shapes(rng):
    for (r, q, h) in VARIANTS:
        got, _ = run_both(make_batch(rng, r, q, h))
        for g in got:
            assert g.shape == (r,)


def test_fits_semantics():
    """A job whose predicted next checkpoint fits must not be flagged."""
    r, q, h = 16, 64, 16
    ts = np.zeros((r, h), np.float32)
    mask = np.zeros((r, h), np.float32)
    # 3 checkpoints at 420/840/1260 (the paper's scaled 7-minute interval).
    for k, t in enumerate((420.0, 840.0, 1260.0)):
        ts[:, k] = t
        mask[:, k] = 1.0
    ce = np.full(r, 1440.0, np.float32)  # the 24 h limit, scaled
    nr = np.ones(r, np.float32)
    rm = np.ones(r, np.float32)
    ps, nq, fa, qm = (np.zeros(q, np.float32),) * 4
    params = np.array([30.0, 0.0], np.float32)
    got, _ = run_both((ts, mask, ce, nr, rm, ps, nq, fa, qm, params))
    pred_next, ext_end, fits = got[0], got[1], got[2]
    np.testing.assert_allclose(pred_next, 1680.0)  # next ckpt past the limit
    np.testing.assert_allclose(ext_end, 1710.0)
    assert (fits == 0.0).all()

    # With only 2 checkpoints observed (k=1..2) the next one (1260) fits.
    mask[:, 2] = 0.0
    ts[:, 2] = 0.0
    got, _ = run_both((ts, mask, ce, nr, rm, ps, nq, fa, qm, params))
    np.testing.assert_allclose(got[0], 1260.0)
    assert (got[2] == 1.0).all()


def test_no_estimate_rows_are_sentineled(rng):
    r, q, h = 16, 64, 16
    batch = list(make_batch(rng, r, q, h))
    batch[1] = np.zeros((r, h), np.float32)  # no checkpoints at all
    got, _ = run_both(tuple(batch))
    assert (got[0] == -1.0).all()  # pred_next
    assert (got[2] == 0.0).all()  # fits
    assert (got[3] == 0.0).all()  # conflict: no estimate -> no extension


@settings(max_examples=15, deadline=None)
@given(variant=st.sampled_from(VARIANTS), seed=st.integers(0, 2**32 - 1))
def test_hypothesis_variants(variant, seed):
    r, q, h = variant
    rng = np.random.default_rng(seed)
    got, want = run_both(make_batch(rng, r, q, h))
    for n, g, w in zip(NAMES, got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-3, err_msg=n)


def test_lowering_is_deterministic():
    """Two lowerings of the same variant produce identical HLO text."""
    from compile.aot import to_hlo_text

    r, q, h = VARIANTS[0]
    t1 = to_hlo_text(jax.jit(decision_model).lower(*example_args(r, q, h)))
    t2 = to_hlo_text(jax.jit(decision_model).lower(*example_args(r, q, h)))
    assert t1 == t2
