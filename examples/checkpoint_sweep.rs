//! Ablation sweeps over the knobs the paper's Discussion calls out:
//!
//! 1. **checkpoint interval** — benefits scale with misalignment: the
//!    tail (limit mod interval) sets the baseline waste;
//! 2. **checkpointing-job share** — "benefits scale with the proportion
//!    of jobs that use checkpoints";
//! 3. **daemon poll period** — the residual tail under EarlyCancel is
//!    the detection delay, ~U(0, poll)/2 on average;
//! 4. **checkpoint jitter** — stresses the interval estimator (safety
//!    factor compensates).
//!
//! ```sh
//! cargo run --release --example checkpoint_sweep [-- --quick]
//! ```

use tailtamer::config::Experiment;
use tailtamer::daemon::{Policy, run_scenario};
use tailtamer::metrics::summarize;

fn run(exp: &Experiment, policy: Policy) -> tailtamer::metrics::Summary {
    let specs = exp.build_workload();
    let (jobs, stats, _) = run_scenario(&specs, exp.slurm.clone(), policy, exp.daemon.clone(), None);
    summarize(policy.name(), &jobs, &stats)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let base_exp = Experiment::default();

    println!("== sweep 1: checkpoint interval (EarlyCancel vs Baseline) ==");
    println!("{:>10} {:>14} {:>14} {:>11} {:>12}", "interval", "base tail", "EC tail", "reduction", "ckpts/job");
    let intervals: &[i64] = if quick { &[300, 420, 600] } else { &[180, 300, 420, 500, 600, 720, 1000] };
    for &interval in intervals {
        let mut exp = base_exp.clone();
        exp.workload.ckpt_interval = interval;
        let base = run(&exp, Policy::Baseline);
        let ec = run(&exp, Policy::EarlyCancel);
        println!(
            "{:>9}s {:>14} {:>14} {:>10.1}% {:>12.1}",
            interval,
            base.tail_waste,
            ec.tail_waste,
            ec.tail_waste_reduction(&base),
            base.total_checkpoints as f64 / 109.0,
        );
    }

    println!();
    println!("== sweep 2: checkpointing-job share (Hybrid) ==");
    println!("{:>12} {:>14} {:>14} {:>12}", "ckpt jobs", "base tail", "hybrid tail", "CPU saved");
    let shares: &[usize] = if quick { &[50, 109] } else { &[25, 50, 109, 150, 217] };
    for &n in shares {
        let mut exp = base_exp.clone();
        // Shift jobs between the two TIMEOUT buckets, total constant.
        exp.pm100.timeout_at_cap = n;
        exp.pm100.timeout_below_cap = 217usize.saturating_sub(n);
        let base = run(&exp, Policy::Baseline);
        let hy = run(&exp, Policy::Hybrid);
        println!(
            "{:>12} {:>14} {:>14} {:>11.2}%",
            n,
            base.tail_waste,
            hy.tail_waste,
            (1.0 - hy.total_cpu_time as f64 / base.total_cpu_time as f64) * 100.0,
        );
    }

    println!();
    println!("== sweep 3: daemon poll period (EarlyCancel residual tail) ==");
    println!("{:>10} {:>14} {:>11}", "poll", "EC tail", "reduction");
    let polls: &[i64] = if quick { &[20, 60] } else { &[5, 10, 20, 40, 60, 120] };
    let base = run(&base_exp, Policy::Baseline);
    for &poll in polls {
        let mut exp = base_exp.clone();
        exp.daemon.poll_period = poll;
        let ec = run(&exp, Policy::EarlyCancel);
        println!("{:>9}s {:>14} {:>10.1}%", poll, ec.tail_waste, ec.tail_waste_reduction(&base));
    }

    println!();
    println!("== sweep 4: checkpoint jitter (EarlyCancel, safety=1.0) ==");
    println!("{:>10} {:>14} {:>11} {:>14}", "jitter", "EC tail", "reduction", "ckpts kept");
    let jits: &[f64] = if quick { &[0.0, 0.2] } else { &[0.0, 0.05, 0.1, 0.2, 0.3] };
    for &j in jits {
        let mut exp = base_exp.clone();
        exp.workload.ckpt_jitter = j;
        exp.daemon.safety = 1.0;
        let b = run(&exp, Policy::Baseline);
        let ec = run(&exp, Policy::EarlyCancel);
        println!(
            "{:>10.2} {:>14} {:>10.1}% {:>14}",
            j,
            ec.tail_waste,
            ec.tail_waste_reduction(&b),
            ec.total_checkpoints,
        );
    }

    println!();
    println!("Reading: the paper's 95% number is sweep 3 at poll=20s; sweeps 1-2 show");
    println!("the savings scale with misalignment and checkpointer share (Discussion §6).");
}
