//! End-to-end reproduction of the paper's evaluation (Table 1 + Fig. 4).
//!
//! This is the repository's headline driver: it builds the PM100-like
//! 773-job workload (556 COMPLETED / 108 TIMEOUT / 109 checkpointing),
//! replays it on the 20-node Slurm-like simulator under all four
//! policies with the daemon's decisions computed by the **AOT-compiled
//! JAX/Pallas model via PJRT** (falling back to the native oracle if
//! artifacts are missing), and prints the paper's Table 1 and Fig. 4.
//!
//! ```sh
//! make artifacts && cargo run --release --example reproduce_table1
//! ```
//!
//! Expected shape vs the paper: ~95% tail-waste reduction for all three
//! policies; EarlyCancel saves ~1.3% CPU and shrinks the makespan;
//! Extend adds exactly +109 checkpoints and grows CPU/makespan;
//! weighted wait improves for EarlyCancel/Hybrid and worsens for
//! Extend. See EXPERIMENTS.md for the recorded run.

use tailtamer::analytics::{DecisionEngine, NativeEngine};
use tailtamer::config::Experiment;
use tailtamer::daemon::{Policy, run_scenario};
use tailtamer::metrics::summarize;
use tailtamer::report::{render_fig4, render_table1};
use tailtamer::runtime::{PjrtEngine, default_artifacts_dir};

fn make_engine() -> (Box<dyn DecisionEngine>, &'static str) {
    match PjrtEngine::load(&default_artifacts_dir()) {
        Ok(e) => (Box::new(e), "pjrt (AOT JAX/Pallas decision model)"),
        Err(err) => {
            eprintln!("note: PJRT unavailable ({err:#}); using native oracle");
            (Box::new(NativeEngine::new()), "native (pure-rust oracle)")
        }
    }
}

fn main() {
    let exp = Experiment::default(); // the paper's setup: 20 nodes, 60x scale, 420 s ckpts, 20 s poll
    let specs = exp.build_workload();
    let ckpt_jobs = specs.iter().filter(|s| s.ckpt.is_some()).count();
    println!(
        "workload: {} jobs ({} checkpointing), cluster {} nodes, seed {}",
        specs.len(),
        ckpt_jobs,
        exp.slurm.nodes,
        exp.pm100.seed
    );

    let mut summaries = Vec::new();
    for policy in Policy::ALL {
        let (engine, engine_name) = make_engine();
        let t0 = std::time::Instant::now();
        let (jobs, stats, dstats) =
            run_scenario(&specs, exp.slurm.clone(), policy, exp.daemon.clone(), Some(engine));
        println!(
            "{:<22} done in {:>5.2}s  (engine={}, calls={}, cancels={}, extensions={})",
            policy.name(),
            t0.elapsed().as_secs_f64(),
            engine_name,
            dstats.engine_calls,
            dstats.cancels,
            dstats.extensions
        );
        summaries.push(summarize(policy.name(), &jobs, &stats));
    }

    println!();
    println!("{}", render_table1(&summaries));
    println!("{}", render_fig4(&summaries));

    // The paper's headline claims, asserted.
    let base = &summaries[0];
    for s in &summaries[1..] {
        let red = s.tail_waste_reduction(base);
        assert!(red > 90.0, "{}: tail-waste reduction {red:.1}% < 90%", s.policy);
    }
    let ec = &summaries[1];
    let cpu_saving = (1.0 - ec.total_cpu_time as f64 / base.total_cpu_time as f64) * 100.0;
    println!("EarlyCancel total CPU saving: {cpu_saving:.2}% (paper: ~1.3%)");
    assert!(cpu_saving > 0.5, "EarlyCancel must save CPU time");
    assert_eq!(summaries[2].total_checkpoints, base.total_checkpoints + 109);
    println!("\nAll headline checks passed.");
}
