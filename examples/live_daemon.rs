//! The autonomy loop LIVE: real threads, real files, real wall clock.
//!
//! Reproduces Fig. 2's architecture with actual moving parts: synthetic
//! checkpointing applications run as threads appending timestamps to
//! spool files (the paper's temp-file protocol); a wall-clock mock
//! slurmctld schedules jobs FIFO+backfill; the same `Autonomy` daemon
//! used in simulation polls `squeue`, predicts checkpoints with the
//! AOT-compiled JAX/Pallas model (PJRT), and issues
//! `scontrol`/`scancel`.
//!
//! Time is dilated (default 240x) so the 24-minute scaled workload
//! finishes in a few wall seconds.
//!
//! ```sh
//! make artifacts && cargo run --release --example live_daemon [-- --quick]
//! ```

use std::time::Duration;

use tailtamer::analytics::NativeEngine;
use tailtamer::daemon::{Autonomy, DaemonConfig, Policy};
use tailtamer::live::{LiveConfig, run_live};
use tailtamer::runtime::{PjrtEngine, default_artifacts_dir};
use tailtamer::slurm::JobSpec;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let speed = if quick { 480.0 } else { 240.0 };

    let specs = vec![
        // Two misaligned checkpointing apps with different intervals.
        JobSpec::new("ck-420", 1440, 2880, 1).with_ckpt(420),
        JobSpec::new("ck-300", 1440, 2880, 1).with_ckpt(300),
        // An opaque sleeper the daemon must not touch.
        JobSpec::new("sleeper", 700, 600, 1),
        // A queued job that wants the whole cluster (exercises Hybrid's
        // delay check against real backfill predictions).
        JobSpec::new("big", 600, 500, 4),
    ];

    let engine: Box<dyn tailtamer::analytics::DecisionEngine> =
        match PjrtEngine::load(&default_artifacts_dir()) {
            Ok(e) => {
                println!("engine: pjrt (AOT JAX/Pallas), variants {:?}", e.shapes());
                Box::new(e)
            }
            Err(err) => {
                println!("engine: native (pjrt unavailable: {err:#})");
                Box::new(NativeEngine::new())
            }
        };

    let mut daemon = Autonomy::new(
        Policy::Hybrid,
        DaemonConfig { margin: 60, ..Default::default() },
        engine,
    );

    let cfg = LiveConfig { nodes: 4, speed, poll_period: 20, sched_tick_ms: 10 };
    let spool = std::env::temp_dir().join(format!("tailtamer_live_example_{}", std::process::id()));
    println!("spool dir: {} (apps append, daemon reads)", spool.display());
    println!("running {} jobs at {speed}x wall speed...\n", specs.len());

    let t0 = std::time::Instant::now();
    let out = run_live(cfg, specs, &mut daemon, &spool, Duration::from_secs(90)).expect("live run");

    println!("{:<8} {:>10} {:>12} {:>7} {:>7} {:>16} {:>9}", "job", "state", "adjustment", "start", "end", "reported ckpts", "tail");
    for j in &out {
        println!(
            "{:<8} {:>10} {:>12} {:>7} {:>7} {:>16} {:>9}",
            j.name,
            format!("{:?}", j.state),
            j.adjustment.map(|a| format!("{a:?}")).unwrap_or_else(|| "-".into()),
            j.start,
            j.end,
            j.reported_ckpts.len(),
            j.tail_waste(),
        );
    }
    println!(
        "\nwall time: {:.1}s, daemon polls: {}, engine calls: {}, mean engine latency: {:.0}us",
        t0.elapsed().as_secs_f64(),
        daemon.stats.polls,
        daemon.stats.engine_calls,
        daemon.mean_engine_nanos() / 1000.0
    );
    let _ = std::fs::remove_dir_all(&spool);

    // The loop must have adjusted both checkpointing jobs and left the
    // sleeper alone.
    assert!(out[0].adjustment.is_some(), "ck-420 must be adjusted");
    assert!(out[1].adjustment.is_some(), "ck-300 must be adjusted");
    assert!(out[2].adjustment.is_none(), "sleeper must be untouched");
    println!("live autonomy loop OK");
}
