//! Quickstart: the autonomy loop on a 4-node cluster in ~40 lines.
//!
//! One misaligned checkpointing job (24 min limit, 7 min checkpoints —
//! the paper's canonical scaled shape), one opaque timeout job, and one
//! well-behaved job. Run each policy and watch the tail waste move.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tailtamer::daemon::{DaemonConfig, Policy, run_scenario};
use tailtamer::metrics::{job_tail_waste, summarize};
use tailtamer::slurm::{JobSpec, SlurmConfig};

fn main() {
    // A tiny workload: the paper's mechanism in miniature.
    let specs = vec![
        // Checkpointing app: limit 1440 s, checkpoints every 420 s. The
        // 4th checkpoint (1680) misses the limit -> 180 s of tail waste
        // unless the daemon intervenes.
        JobSpec::new("ckpt-app", 1440, 2880, 1).with_ckpt(420),
        // A job whose user limit was simply too small; it reports no
        // checkpoints, so the daemon leaves it alone.
        JobSpec::new("opaque", 600, 1200, 2),
        // A job that finishes comfortably inside its limit.
        JobSpec::new("well-sized", 900, 700, 1),
    ];

    println!("policy                | ckpt-app end | state      | tail waste (core-s)");
    println!("----------------------+--------------+------------+--------------------");
    for policy in Policy::ALL {
        let (jobs, stats, _) = run_scenario(
            &specs,
            SlurmConfig { nodes: 4, ..Default::default() },
            policy,
            DaemonConfig::default(),
            None, // native engine; pass Some(PjrtEngine::load(..)) for the AOT path
        );
        let ck = &jobs[0];
        println!(
            "{:<21} | {:>12} | {:<10} | {:>8}",
            policy.name(),
            ck.end.unwrap(),
            format!("{:?}", ck.state),
            job_tail_waste(ck),
        );
        // The summary carries every Table 1 metric if you want more:
        let _ = summarize(policy.name(), &jobs, &stats);
    }

    println!();
    println!("Baseline wastes 180 s x 48 cores; EarlyCancel ends right after the");
    println!("last fitting checkpoint; Extend/Hybrid buy a 4th checkpoint first.");
}
