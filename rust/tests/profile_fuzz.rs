//! Differential fuzz for the capacity-profile structures: random
//! `add_release` / `extend_releases` / `shift_release` /
//! `find_earliest` / `free_at` / `reserve` / `copy_from` sequences
//! replayed against the flat breakpoint-list [`Profile`] and the
//! min-augmented [`CapTree`], asserting identical behaviour op for op —
//! down to the exact breakpoint sets, degenerate (equal-free)
//! breakpoints included.
//!
//! Sequences are generated ledger-style, mirroring how the scheduler
//! actually drives the structures: a base profile encodes the releases
//! of an allocated job set (so every shift — including the grace
//! re-clamp `rel <= t` path — stays capacity-legal), and the working
//! copy only receives reservations at `find_earliest`-feasible starts.

use tailtamer::cluster::{CapTree, Profile};
use tailtamer::prop_assert;
use tailtamer::proptest_lite::run_prop_cases;
use tailtamer::simtime::Time;

fn tree_points(tree: &CapTree) -> Vec<(Time, u32)> {
    let mut out = Vec::new();
    tree.points_into(&mut out);
    out
}

#[test]
fn prop_captree_matches_flat_profile_op_for_op() {
    run_prop_cases("captree_vs_flat_ops", 0xCAB7, 120, |rng| {
        let total = rng.int_in(2, 64) as u32;
        let t0 = rng.int_in(0, 500);

        // Ledger: allocated "jobs" whose releases the base profile will
        // encode, exactly like the scheduler's running set. Partial
        // sums never exceed `total`, so every op below is legal.
        let mut ledger: Vec<(Time, u32)> = Vec::new();
        let mut left = total;
        while left > 0 && rng.chance(0.8) {
            let n = rng.int_in(1, left as i64) as u32;
            let rel = t0 + rng.int_in(1, 3_000);
            ledger.push((rel, n));
            left -= n;
        }
        let free0 = left;

        let mut base_flat = Profile::new(t0, free0, total);
        let mut base_tree = CapTree::new(t0, free0, total);

        // First half lands as one sorted batch (`extend_releases`), the
        // rest arrives one by one (`add_release`) interleaved with
        // shifts of already-live releases.
        let split = ledger.len() / 2;
        let mut live: Vec<(Time, u32)> = ledger[..split].to_vec();
        base_flat.extend_releases(live.iter().copied());
        base_tree.extend_releases(live.iter().copied());
        prop_assert!(
            base_flat.points() == tree_points(&base_tree).as_slice(),
            "base breakpoints diverged after extend_releases"
        );
        let mut singles = ledger[split..].to_vec();

        for _ in 0..30 {
            if rng.chance(0.5) && !singles.is_empty() {
                // A job "starts": its release joins the base directly.
                let (rel, n) = singles.pop().unwrap();
                base_flat.add_release(rel, n);
                base_tree.add_release(rel, n);
                live.push((rel, n));
                prop_assert!(
                    base_flat.points() == tree_points(&base_tree).as_slice(),
                    "breakpoints diverged after add_release({rel}, {n})"
                );
            } else if !live.is_empty() {
                // A limit update moves a live release — including the
                // grace re-clamp path (rel <= t pushes it to t + 1).
                let i = rng.int_in(0, live.len() as i64 - 1) as usize;
                let (old, n) = live[i];
                let new = if rng.chance(0.3) {
                    let now = old + rng.int_in(0, 200); // "now" >= rel
                    now + 1
                } else if rng.chance(0.5) {
                    old + rng.int_in(1, 800) // extension
                } else {
                    (old - rng.int_in(1, 800)).max(t0) // shortened limit
                };
                base_flat.shift_release(old, new, n);
                base_tree.shift_release(old, new, n);
                live[i] = (new, n);
                prop_assert!(
                    base_flat.points() == tree_points(&base_tree).as_slice(),
                    "breakpoints diverged after shift_release({old} -> {new}, {n})"
                );
            }
        }

        // The per-pass copy into the working pair, then placement
        // queries and reservations, scheduler style: reservations land
        // only at `find_earliest`-feasible starts, so capacity holds.
        let mut flat = Profile::new(0, 0, 1);
        let mut tree = CapTree::new(0, 0, 1);
        flat.copy_from(&base_flat);
        tree.copy_from(&base_tree);
        for _ in 0..rng.int_in(1, 25) {
            let nodes = rng.int_in(1, total as i64) as u32;
            let dur = rng.int_in(1, 1_500);
            let after = t0 + rng.int_in(0, 4_000);
            let s_flat = flat.find_earliest(nodes, dur, after);
            let s_tree = tree.find_earliest(nodes, dur, after);
            prop_assert!(
                s_flat == s_tree,
                "find_earliest({nodes}, {dur}, {after}) diverged: flat {s_flat}, tree {s_tree}"
            );
            prop_assert!(
                flat.free_at(s_flat) == tree.free_at(s_flat),
                "free_at({s_flat}) diverged"
            );
            if rng.chance(0.7) {
                flat.reserve(s_flat, s_flat + dur, nodes);
                tree.reserve(s_flat, s_flat + dur, nodes);
                prop_assert!(
                    flat.points() == tree_points(&tree).as_slice(),
                    "breakpoints diverged after reserve([{s_flat}, {}), {nodes})",
                    s_flat + dur
                );
            }
        }

        // Full step-function sweep: every breakpoint (degenerate ones
        // included) plus random probe times.
        for &(bt, bv) in flat.points() {
            prop_assert!(
                tree.free_at(bt) == bv,
                "tree disagrees at breakpoint t={bt}: {} vs {bv}",
                tree.free_at(bt)
            );
        }
        for _ in 0..40 {
            let q = t0 + rng.int_in(0, 8_000);
            prop_assert!(flat.free_at(q) == tree.free_at(q), "free_at({q}) diverged");
        }
        Ok(())
    });
}
