//! Crash-kill-replay: the event-sourced journal must reconstruct a
//! killed daemon **exactly**.
//!
//! The harness runs a simulation whose daemon is crashed mid-run —
//! the `Autonomy` is dropped on the floor, then rebuilt with
//! [`Autonomy::replay`] from its journal and resumed — and asserts the
//! finished run is bit-identical (job records, `SlurmStats`,
//! deterministic `DaemonStats`) to an uninterrupted, *unjournaled*
//! run. That pins two claims at once: journaling is behaviorally
//! invisible, and replay loses nothing. Covered on random workloads ×
//! random registry policies × random kill points and snapshot
//! cadences, on the 773-job paper cohort for every registry policy,
//! and for torn journal tails (a crash mid-write discards at most the
//! unfinished block).

use std::path::{Path, PathBuf};

use tailtamer::daemon::{Autonomy, DaemonConfig, DaemonStats};
use tailtamer::policy::PolicySpec;
use tailtamer::prop_assert;
use tailtamer::proptest_lite::{Rng, run_prop_cases};
use tailtamer::simtime::Time;
use tailtamer::slurm::{DaemonHook, Job, JobSpec, SlurmConfig, SlurmControl, SlurmStats, Slurmd};

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tt_journal_{}_{tag}.log", std::process::id()))
}

/// [`DaemonHook`] that crashes its daemon at chosen poll counts: the
/// `Autonomy` is dropped (all in-memory state gone), rebuilt from the
/// journal, and re-attached to the same journal file for the rest of
/// the run.
struct KillReplayHook {
    inner: Option<Autonomy>,
    path: PathBuf,
    kill_at_polls: Vec<u64>,
    snap_every: u64,
    polls: u64,
    pub kills_done: usize,
}

impl KillReplayHook {
    fn new(inner: Autonomy, path: PathBuf, mut kill_at_polls: Vec<u64>, snap_every: u64) -> Self {
        kill_at_polls.sort_unstable();
        let mut h = Self { inner: Some(inner), path, kill_at_polls, snap_every, polls: 0, kills_done: 0 };
        h.inner.as_mut().unwrap().set_journal_snapshot_every(snap_every);
        h
    }

    fn maybe_crash(&mut self) {
        if self.kills_done < self.kill_at_polls.len()
            && self.polls >= self.kill_at_polls[self.kills_done]
        {
            self.kills_done += 1;
            drop(self.inner.take()); // the crash: nothing survives but the journal
            let mut d = Autonomy::replay(&self.path).expect("replay after crash");
            d.enable_journal(&self.path).expect("resume journaling after replay");
            d.set_journal_snapshot_every(self.snap_every);
            self.inner = Some(d);
        }
    }

    fn into_stats(self) -> DaemonStats {
        self.inner.unwrap().stats.deterministic()
    }
}

impl DaemonHook for KillReplayHook {
    fn poll_period(&self) -> Option<Time> {
        self.inner.as_ref().unwrap().poll_period()
    }
    fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
        self.polls += 1;
        self.maybe_crash();
        self.inner.as_mut().unwrap().on_poll(t, ctl);
    }
    fn poll_elidable(&self) -> bool {
        self.inner.as_ref().unwrap().poll_elidable()
    }
    fn note_elided_polls(&mut self, n: u64) {
        self.inner.as_mut().unwrap().note_elided_polls(n);
    }
}

fn run_plain(
    specs: &[JobSpec],
    cfg: &SlurmConfig,
    policy: PolicySpec,
    dcfg: &DaemonConfig,
) -> (Vec<Job>, SlurmStats, DaemonStats) {
    let mut sim = Slurmd::new(cfg.clone());
    for s in specs {
        sim.submit(s.clone());
    }
    let mut daemon = Autonomy::native(policy, dcfg.clone());
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    (sim.into_jobs(), stats, daemon.stats.deterministic())
}

fn run_killed(
    specs: &[JobSpec],
    cfg: &SlurmConfig,
    policy: PolicySpec,
    dcfg: &DaemonConfig,
    path: &Path,
    kill_at_polls: Vec<u64>,
    snap_every: u64,
) -> (Vec<Job>, SlurmStats, DaemonStats, usize) {
    let mut sim = Slurmd::new(cfg.clone());
    for s in specs {
        sim.submit(s.clone());
    }
    let jcfg = DaemonConfig { journal_path: Some(path.display().to_string()), ..dcfg.clone() };
    let daemon = Autonomy::native(policy, jcfg);
    assert!(daemon.journaling(), "journal must attach at construction");
    let mut hook = KillReplayHook::new(daemon, path.to_path_buf(), kill_at_polls, snap_every);
    sim.run(&mut hook);
    let stats = sim.stats.clone();
    let kills = hook.kills_done;
    (sim.into_jobs(), stats, hook.into_stats(), kills)
}

fn random_workload(rng: &mut Rng) -> (Vec<JobSpec>, SlurmConfig) {
    let n = rng.int_in(1, 30) as usize;
    let nodes_total = rng.int_in(2, 10) as u32;
    let mut specs = Vec::with_capacity(n);
    let mut t = 0;
    for i in 0..n {
        let nodes = rng.int_in(1, nodes_total as i64) as u32;
        let limit = rng.int_in(60, 2000);
        let duration =
            if rng.chance(0.4) { limit + rng.int_in(1, 2000) } else { rng.int_in(30, limit.max(31)) };
        let mut spec = JobSpec::new(&format!("j{i}"), limit, duration, nodes);
        if rng.chance(0.6) {
            spec = spec.with_ckpt(rng.int_in(40, 700));
        }
        if rng.chance(0.5) {
            t += rng.int_in(0, 90);
            spec.submit = t;
        }
        specs.push(spec);
    }
    (specs, SlurmConfig { nodes: nodes_total, ..Default::default() })
}

fn random_policy_spec(rng: &mut Rng) -> PolicySpec {
    match rng.int_in(0, 6) {
        0 => PolicySpec::Baseline,
        1 => PolicySpec::EarlyCancel,
        2 => PolicySpec::Extend,
        3 => PolicySpec::Hybrid,
        4 => PolicySpec::ExtendBudget { budget: rng.int_in(60, 4000) },
        5 => PolicySpec::TailAware { frac: rng.f64_in(0.01, 2.0) },
        _ => PolicySpec::HybridBackoff { step: rng.int_in(1, 300) },
    }
}

#[test]
fn prop_killed_and_replayed_runs_are_bit_identical() {
    let mut total_kills = 0usize;
    let path = tmp_path("prop");
    run_prop_cases("crash_kill_replay", 0xC4A54, 24, |rng| {
        let (specs, cfg) = random_workload(rng);
        let policy = random_policy_spec(rng);
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            use_priors: rng.chance(0.3),
            batch_actions: rng.chance(0.3),
            ..Default::default()
        };
        let snap_every = rng.int_in(1, 6) as u64;
        let mut kills = vec![rng.int_in(2, 40) as u64];
        if rng.chance(0.4) {
            kills.push(rng.int_in(2, 80) as u64);
        }
        let tag = policy.name();
        let (jobs, stats, dstats) = run_plain(&specs, &cfg, policy.clone(), &dcfg);
        let (kj, ks, kd, done) =
            run_killed(&specs, &cfg, policy.clone(), &dcfg, &path, kills, snap_every);
        prop_assert!(jobs == kj, "{tag}: job records diverged after crash+replay");
        prop_assert!(stats == ks, "{tag}: SlurmStats diverged after crash+replay");
        prop_assert!(
            dstats == kd,
            "{tag}: DaemonStats diverged after crash+replay: {dstats:?} vs {kd:?}"
        );
        total_kills += done;
        Ok(())
    });
    let _ = std::fs::remove_file(&path);
    assert!(total_kills > 0, "no crash ever fired across 24 random workloads");
}

#[test]
fn cohort_crash_replay_is_exact_for_every_registry_policy() {
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    let path = tmp_path("cohort");
    let mut policies = PolicySpec::legacy_all().to_vec();
    policies.extend(PolicySpec::parameterized_defaults());
    for policy in policies {
        let tag = policy.name();
        let (jobs, stats, dstats) = run_plain(&specs, &exp.slurm, policy.clone(), &exp.daemon);
        // Two mid-run crashes, snapshots every 16 ticks: the second
        // replay reads a journal the first recovery wrote.
        let (kj, ks, kd, done) = run_killed(
            &specs,
            &exp.slurm,
            policy.clone(),
            &exp.daemon,
            &path,
            vec![50, 150],
            16,
        );
        assert_eq!(jobs, kj, "{tag}: cohort job records diverged after crash+replay");
        assert_eq!(stats, ks, "{tag}: cohort SlurmStats diverged after crash+replay");
        assert_eq!(dstats, kd, "{tag}: cohort DaemonStats diverged after crash+replay");
        if !policy.is_baseline() {
            assert_eq!(done, 2, "{tag}: both cohort crashes must fire");
        }
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn full_journal_replays_to_the_final_daemon_state() {
    // No crash: replay of a complete journal equals the daemon that
    // wrote it, and a replayed daemon is not journaling (the file it
    // was rebuilt from must never be clobbered).
    let path = tmp_path("full");
    let specs = vec![
        JobSpec::new("ck-a", 1440, 2880, 1).with_ckpt(420),
        JobSpec::new("ck-b", 1440, 900, 1).with_ckpt(300),
        JobSpec::new("plain", 600, 1200, 1),
    ];
    let cfg = SlurmConfig { nodes: 4, ..Default::default() };
    let mut sim = Slurmd::new(cfg);
    for s in &specs {
        sim.submit(s.clone());
    }
    let dcfg = DaemonConfig {
        journal_path: Some(path.display().to_string()),
        ..Default::default()
    };
    let mut daemon = Autonomy::native(PolicySpec::Hybrid, dcfg);
    daemon.set_journal_snapshot_every(4);
    sim.run(&mut daemon);
    let replayed = Autonomy::replay(&path).expect("full replay");
    assert!(!replayed.journaling(), "replay must not clobber its own input");
    assert_eq!(
        daemon.stats.deterministic(),
        replayed.stats.deterministic(),
        "replayed stats must equal the writer's"
    );

    // Torn tails: a crash mid-write leaves a partial final block. Any
    // byte-level truncation of the tail must still replay cleanly,
    // losing at most the unfinished block.
    let full = std::fs::read(&path).expect("read journal");
    let full_polls = replayed.stats.polls;
    let torn = tmp_path("torn");
    for cut in [1usize, 3, 17, 64] {
        if full.len() <= cut + 64 {
            break; // keep the header + genesis snapshot intact
        }
        std::fs::write(&torn, &full[..full.len() - cut]).unwrap();
        let r = Autonomy::replay(&torn)
            .unwrap_or_else(|e| panic!("torn tail (cut {cut}) must replay: {e:#}"));
        assert!(
            r.stats.polls <= full_polls,
            "torn replay cannot know more than the full journal"
        );
    }
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(&torn);
}
