//! Integration: the scaled workload generator and the parallel sweep
//! harness — the scenario space beyond the paper's 773-job cohort.

use std::sync::Arc;

use tailtamer::daemon::{DaemonConfig, Policy, run_scenario};
use tailtamer::slurm::{JobState, SlurmConfig};
use tailtamer::sweep::{Scenario, policy_grid, run_sweep};
use tailtamer::workload::{Arrival, ScaledConfig};

#[test]
fn scaled_generator_stretches_both_axes() {
    let cfg = ScaledConfig { jobs: 5_000, nodes: 512, seed: 3, ..Default::default() };
    let specs = cfg.build();
    assert_eq!(specs.len(), 5_000);
    assert!(specs.iter().all(|s| s.nodes >= 1 && s.nodes <= 512));
    assert!(specs.iter().any(|s| s.nodes > 20), "requests must grow with the pool");
    let ckpt = specs.iter().filter(|s| s.ckpt.is_some()).count();
    let frac = ckpt as f64 / specs.len() as f64;
    assert!((frac - 109.0 / 773.0).abs() < 0.01, "ckpt share {frac:.3}");
    // Determinism across calls.
    assert_eq!(specs, cfg.build());
}

#[test]
fn staggered_scaled_workload_replays_end_to_end() {
    let cfg = ScaledConfig {
        jobs: 500,
        nodes: 64,
        seed: 11,
        arrival: Arrival::Staggered { mean_gap: 10 },
        ..Default::default()
    };
    let specs = cfg.build();
    let (jobs, stats, _) = run_scenario(
        &specs,
        SlurmConfig { nodes: 64, ..Default::default() },
        Policy::EarlyCancel,
        DaemonConfig::default(),
        None,
    );
    assert_eq!(jobs.len(), 500);
    for j in &jobs {
        assert!(j.state.is_terminal(), "{} not terminal", j.id);
        assert!(j.start.unwrap() >= j.spec.submit, "{} started before arrival", j.id);
    }
    assert_eq!(stats.sched_main_started + stats.sched_backfill_started, 500);
    assert!(jobs.iter().any(|j| j.state == JobState::Cancelled), "the daemon must act");
}

#[test]
fn parallel_sweep_is_deterministic_and_complete() {
    let specs = Arc::new(
        ScaledConfig { jobs: 600, nodes: 48, seed: 5, ..Default::default() }.build(),
    );
    let grid: Vec<Scenario> = policy_grid(
        "600j/48n",
        specs,
        SlurmConfig { nodes: 48, ..Default::default() },
        DaemonConfig::default(),
    );
    assert_eq!(grid.len(), 4);

    let serial = run_sweep(&grid, 1);
    let wide = run_sweep(&grid, 8); // more threads than scenarios: fine
    assert_eq!(serial.len(), 4);
    for (a, b) in serial.iter().zip(&wide) {
        assert_eq!(a.policy, b.policy);
        assert_eq!(a.summary, b.summary, "{:?} diverged across thread counts", a.policy);
        // engine_nanos is wall clock — compare only the deterministic
        // fields.
        assert_eq!(
            a.daemon_stats.deterministic(),
            b.daemon_stats.deterministic(),
            "{:?} daemon stats diverged",
            a.policy
        );
    }

    // The ablation story survives scaling: every policy removes most of
    // the baseline tail waste.
    let base = &serial[0].summary;
    assert!(base.tail_waste > 0);
    for r in &serial[1..] {
        let red = r.summary.tail_waste_reduction(base);
        assert!(red > 80.0, "{:?}: only {red:.1}% reduction", r.policy);
    }
}
