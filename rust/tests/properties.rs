//! Property-based integration tests over the whole coordinator, using
//! the in-crate `proptest_lite` substrate (proptest is not in the
//! offline vendor set — see DESIGN.md §1).
//!
//! Each property runs dozens of randomized workloads through the full
//! simulator + daemon and checks an invariant that must hold for every
//! policy, seed, and cluster size.

use tailtamer::daemon::{DaemonConfig, Policy, run_scenario};
use tailtamer::metrics::{job_cpu_time, job_tail_waste, summarize};
use tailtamer::proptest_lite::{Rng, run_prop, run_prop_cases};
use tailtamer::prop_assert;
use tailtamer::slurm::{Adjustment, Job, JobSpec, JobState, SlurmConfig};

/// A random mixed workload: sized jobs, over/under-estimated limits,
/// some checkpointing with optional jitter.
fn random_workload(rng: &mut Rng, max_jobs: usize, max_nodes: u32) -> (Vec<JobSpec>, SlurmConfig) {
    let n = rng.int_in(1, max_jobs as i64) as usize;
    let nodes_total = rng.int_in(2, max_nodes as i64) as u32;
    let mut specs = Vec::with_capacity(n);
    for i in 0..n {
        let nodes = rng.int_in(1, nodes_total as i64) as u32;
        let limit = rng.int_in(60, 2000);
        let duration = if rng.chance(0.3) {
            limit + rng.int_in(1, 2000) // will time out
        } else {
            rng.int_in(30, limit.max(31))
        };
        let mut spec = JobSpec::new(&format!("p{i}"), limit, duration, nodes);
        if rng.chance(0.4) {
            spec.ckpt = Some(tailtamer::slurm::CkptSpec {
                interval: rng.int_in(40, 700),
                jitter_frac: if rng.chance(0.5) { rng.f64_in(0.0, 0.3) } else { 0.0 },
                seed: rng.next_u64(),
            });
        }
        specs.push(spec);
    }
    let cfg = SlurmConfig {
        nodes: nodes_total,
        backfill_interval: rng.int_in(10, 60),
        over_time_limit: if rng.chance(0.2) { rng.int_in(0, 120) } else { 0 },
        ..Default::default()
    };
    (specs, cfg)
}

fn random_policy(rng: &mut Rng) -> Policy {
    Policy::ALL[rng.int_in(0, 3) as usize]
}

fn run_random(rng: &mut Rng) -> (Vec<Job>, SlurmConfig, Policy) {
    let (specs, cfg) = random_workload(rng, 60, 16);
    let policy = random_policy(rng);
    let daemon_cfg = DaemonConfig {
        poll_period: rng.int_in(5, 40),
        margin: rng.int_in(0, 60),
        safety: rng.f64_in(0.0, 1.5),
        ..Default::default()
    };
    let (jobs, _, _) = run_scenario(&specs, cfg.clone(), policy, daemon_cfg, None);
    (jobs, cfg, policy)
}

#[test]
fn prop_every_job_terminates_sanely() {
    run_prop("terminates_sanely", 0xA11CE, |rng| {
        let (jobs, _, _) = run_random(rng);
        for j in &jobs {
            prop_assert!(j.state.is_terminal(), "{} not terminal: {:?}", j.id, j.state);
            let (start, end) = (j.start.unwrap(), j.end.unwrap());
            prop_assert!(start >= j.spec.submit, "{} started before submit", j.id);
            prop_assert!(end >= start, "{} ends before start", j.id);
            prop_assert!(j.started_by.is_some(), "{} has no scheduler attribution", j.id);
        }
        Ok(())
    });
}

#[test]
fn prop_nodes_never_oversubscribed() {
    // Reconstruct utilization from the final schedule with an interval
    // sweep: at no instant may allocated nodes exceed the cluster.
    run_prop("no_oversubscription", 0xB0B, |rng| {
        let (jobs, cfg, _) = run_random(rng);
        let mut events: Vec<(i64, i64)> = Vec::new();
        for j in &jobs {
            if j.elapsed() > 0 {
                events.push((j.start.unwrap(), j.spec.nodes as i64));
                events.push((j.end.unwrap(), -(j.spec.nodes as i64)));
            }
        }
        events.sort_unstable();
        let mut used = 0i64;
        for &(t, d) in &events {
            used += d;
            prop_assert!(
                used <= cfg.nodes as i64,
                "{used} nodes allocated at t={t} on a {}-node cluster",
                cfg.nodes
            );
        }
        prop_assert!(used == 0, "allocation leak: {used} nodes never released");
        Ok(())
    });
}

#[test]
fn prop_completed_and_opaque_jobs_have_zero_tail_waste() {
    run_prop("zero_tail_for_safe_jobs", 0xC0DE, |rng| {
        let (jobs, _, _) = run_random(rng);
        for j in &jobs {
            if j.state == JobState::Completed || !j.is_checkpointing() {
                prop_assert!(job_tail_waste(j) == 0, "{} unexpected tail waste", j.id);
            }
            prop_assert!(job_tail_waste(j) >= 0, "{} negative tail waste", j.id);
        }
        Ok(())
    });
}

#[test]
fn prop_baseline_never_touches_jobs() {
    run_prop("baseline_hands_off", 0xF00, |rng| {
        let (specs, cfg) = random_workload(rng, 40, 12);
        let (jobs, _, dstats) =
            run_scenario(&specs, cfg, Policy::Baseline, DaemonConfig::default(), None);
        prop_assert!(dstats.cancels == 0 && dstats.extensions == 0, "baseline acted");
        for j in &jobs {
            prop_assert!(j.adjustment.is_none(), "{} adjusted under baseline", j.id);
            prop_assert!(j.cur_limit == j.spec.time_limit, "{} limit changed", j.id);
            prop_assert!(j.state != JobState::Cancelled, "{} cancelled under baseline", j.id);
        }
        Ok(())
    });
}

#[test]
fn prop_non_reporting_jobs_never_adjusted() {
    run_prop("opaque_untouched", 0xDEAD, |rng| {
        let (jobs, _, _) = run_random(rng);
        for j in &jobs {
            if !j.is_checkpointing() {
                prop_assert!(j.adjustment.is_none(), "{} opaque but adjusted", j.id);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_cpu_time_accounting_is_conserved() {
    run_prop("cpu_conservation", 0xCAFE, |rng| {
        let (jobs, _, _) = run_random(rng);
        let total: i64 = jobs.iter().map(job_cpu_time).sum();
        let recomputed: i64 = jobs.iter().map(|j| j.elapsed() * j.spec.cores as i64).sum();
        prop_assert!(total == recomputed, "CPU accounting drifted: {total} vs {recomputed}");
        let stats = tailtamer::slurm::SlurmStats::default();
        let s = summarize("x", &jobs, &stats);
        prop_assert!(s.total_cpu_time == total, "summary disagrees");
        prop_assert!(s.tail_waste <= total, "tail waste exceeds total CPU");
        Ok(())
    });
}

#[test]
fn prop_early_cancel_tail_bounded_by_poll_period() {
    // Under jitter-free checkpointing, an early-cancelled job's residual
    // tail is at most one poll period (+1 s boundary slack).
    run_prop_cases("ec_tail_bound", 0x5EED, 48, |rng| {
        let (mut specs, cfg) = random_workload(rng, 30, 12);
        for s in &mut specs {
            if let Some(c) = &mut s.ckpt {
                c.jitter_frac = 0.0;
            }
        }
        let poll = rng.int_in(5, 40);
        let (jobs, _, _) = run_scenario(
            &specs,
            cfg,
            Policy::EarlyCancel,
            DaemonConfig { poll_period: poll, ..Default::default() },
            None,
        );
        for j in &jobs {
            if j.adjustment == Some(Adjustment::EarlyCancelled) {
                let bound = (poll + 1) * j.spec.cores as i64;
                prop_assert!(
                    job_tail_waste(j) <= bound,
                    "{}: tail {} > bound {bound} (poll {poll})",
                    j.id,
                    job_tail_waste(j)
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_extension_is_at_most_once_and_bounded() {
    // An extended job's final limit exceeds the user limit by at most
    // (interval * (1+jitter) + margin + poll + 1) — one checkpoint.
    run_prop_cases("single_bounded_extension", 0xE27, 48, |rng| {
        let (specs, cfg) = random_workload(rng, 30, 12);
        let margin = rng.int_in(0, 60);
        let poll = rng.int_in(5, 40);
        let (jobs, _, _) = run_scenario(
            &specs,
            cfg,
            Policy::Extend,
            DaemonConfig { poll_period: poll, margin, safety: 1.0, ..Default::default() },
            None,
        );
        for j in &jobs {
            if j.adjustment == Some(Adjustment::Extended) {
                let c = j.spec.ckpt.as_ref().unwrap();
                let worst_interval =
                    ((c.interval as f64) * (1.0 + c.jitter_frac) * 2.0) as i64 + 2;
                let bound = j.spec.time_limit + worst_interval + margin + poll + 1;
                prop_assert!(
                    j.cur_limit <= bound,
                    "{}: limit {} exceeds one-checkpoint bound {bound}",
                    j.id,
                    j.cur_limit
                );
                let end = j.end.unwrap() - j.start.unwrap();
                prop_assert!(end <= bound, "{}: ran past the extension bound", j.id);
            } else {
                prop_assert!(
                    j.cur_limit <= j.spec.time_limit || j.adjustment.is_some(),
                    "{}: limit grew without an extension tag",
                    j.id
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_policies_never_reduce_checkpoints_when_predictions_are_exact() {
    // Under exact predictions (no jitter, no safety margin) adjustments
    // must never lose checkpoints vs the baseline run. With jitter or a
    // non-zero margin the daemon may deliberately sacrifice a boundary
    // checkpoint that lands inside the risk zone — the trade-off the
    // paper's Limitations section describes — so the invariant is
    // stated for the exact regime only.
    // Two further paper-regime constraints: (a) checkpointing jobs all
    // time out (duration > limit) — a checkpointer that would COMPLETE
    // can be cancelled mid-final-segment because the daemon cannot see
    // durations (see daemon docs, "completion hazard"); (b) no
    // OverTimeLimit grace — the daemon predicts against the limit, not
    // the grace window, so baseline grace-era checkpoints are invisible
    // to it.
    run_prop_cases("no_lost_checkpoints", 0x90D, 32, |rng| {
        let (mut specs, mut cfg) = random_workload(rng, 30, 12);
        cfg.over_time_limit = 0;
        for s in &mut specs {
            if let Some(c) = &mut s.ckpt {
                c.jitter_frac = 0.0;
                s.duration = s.duration.max(s.time_limit * 2); // always past the limit
            }
        }
        let dcfg = DaemonConfig { margin: 0, safety: 0.0, ..Default::default() };
        let count = |policy| {
            let (jobs, stats, _) = run_scenario(&specs, cfg.clone(), policy, dcfg.clone(), None);
            summarize("x", &jobs, &stats).total_checkpoints
        };
        let base = count(Policy::Baseline);
        for p in [Policy::EarlyCancel, Policy::Extend, Policy::Hybrid] {
            let c = count(p);
            prop_assert!(c >= base, "{p:?} lost checkpoints: {c} < {base}");
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Golden equivalence, three-way: the optimized scheduler core with the
// min-augmented capacity tree, the same core with the flat profile, and
// the retained naive seed implementation (rust/src/slurm/reference.rs).
// This is the guard for the whole hot-path overhaul: augmented-descent
// placement, arena profile, incremental base rebuild, single-pass
// pending compaction, dense hot-path tables, allocation-free poll
// path — all must be behaviorally invisible.
//
// The Recorder hook logs squeue at every poll and therefore keeps its
// default `poll_elidable() == false`: these runs exercise the blind
// poll path on the optimized cores. The elided-vs-blind-vs-naive axis
// (no-op poll elision, delta report cursors) has its own three-way
// golden suite in rust/tests/poll_elision.rs.
// ---------------------------------------------------------------------

use tailtamer::daemon::Autonomy;
use tailtamer::simtime::Time;
use tailtamer::slurm::reference::NaiveSlurmd;
use tailtamer::slurm::{
    BackfillProfile, DaemonHook, QueueSnapshot, SlurmControl, SlurmStats, Slurmd,
};

/// Wraps a daemon and records the full `squeue` view at every poll, so
/// the equivalence check covers backfill *predictions* (start times,
/// free-at-start) and limits mid-flight, not just final outcomes.
struct Recorder {
    inner: Autonomy,
    log: Vec<QueueSnapshot>,
}

impl DaemonHook for Recorder {
    fn poll_period(&self) -> Option<Time> {
        self.inner.poll_period()
    }
    fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
        self.log.push(ctl.squeue());
        self.inner.on_poll(t, ctl);
    }
}

#[test]
fn prop_optimized_core_matches_naive_reference() {
    run_prop_cases("golden_equivalence", 0x601D, 40, |rng| {
        let (mut specs, cfg) = random_workload(rng, 50, 14);
        // Half the cases exercise staggered arrivals (Ev::Submit).
        if rng.chance(0.5) {
            let mut t = 0;
            for s in &mut specs {
                t += rng.int_in(0, 120);
                s.submit = t;
            }
        }
        let policy = random_policy(rng);
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            safety: rng.f64_in(0.0, 1.0),
            ..Default::default()
        };

        let run_core = |kind: BackfillProfile| {
            let cfg = SlurmConfig { backfill_profile: kind, ..cfg.clone() };
            let mut sim = Slurmd::new(cfg);
            for s in &specs {
                sim.submit(s.clone());
            }
            let mut rec = Recorder { inner: Autonomy::native(policy, dcfg.clone()), log: Vec::new() };
            sim.run(&mut rec);
            let stats: SlurmStats = sim.stats.clone();
            (sim.into_jobs(), stats, rec.log)
        };
        let (tree_jobs, tree_stats, tree_log) = run_core(BackfillProfile::Tree);
        let (flat_jobs, flat_stats, flat_log) = run_core(BackfillProfile::Flat);
        let (ref_jobs, ref_stats, ref_log) = {
            let mut sim = NaiveSlurmd::new(cfg.clone());
            for s in &specs {
                sim.submit(s.clone());
            }
            let mut rec = Recorder { inner: Autonomy::native(policy, dcfg.clone()), log: Vec::new() };
            sim.run(&mut rec);
            let stats: SlurmStats = sim.stats.clone();
            (sim.into_jobs(), stats, rec.log)
        };

        prop_assert!(
            tree_jobs == ref_jobs,
            "{policy:?}: tree-core job records diverged (starts/ends/states/limits/adjustments)"
        );
        prop_assert!(
            flat_jobs == ref_jobs,
            "{policy:?}: flat-core job records diverged (starts/ends/states/limits/adjustments)"
        );
        prop_assert!(
            tree_stats == ref_stats,
            "{policy:?}: tree SlurmStats diverged: {tree_stats:?} vs {ref_stats:?}"
        );
        prop_assert!(
            flat_stats == ref_stats,
            "{policy:?}: flat SlurmStats diverged: {flat_stats:?} vs {ref_stats:?}"
        );
        prop_assert!(
            tree_log == ref_log,
            "{policy:?}: tree per-poll squeue views (incl. backfill predictions) diverged"
        );
        prop_assert!(
            flat_log == ref_log,
            "{policy:?}: flat per-poll squeue views (incl. backfill predictions) diverged"
        );
        Ok(())
    });
}

#[test]
fn golden_equivalence_on_the_paper_cohort() {
    // The exact workload the headline numbers come from, all four
    // policies, byte-for-byte equal outcomes — tree core, flat core,
    // and the naive seed core. run_scenario uses the default config,
    // so the optimized cores run with poll elision ON here while the
    // naive reference polls blind: this is also the elided-vs-naive
    // golden axis on the cohort (elided-vs-blind is pinned in
    // rust/tests/poll_elision.rs).
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    for policy in Policy::ALL {
        let run_core = |kind: BackfillProfile| {
            let cfg = SlurmConfig { backfill_profile: kind, ..exp.slurm.clone() };
            run_scenario(&specs, cfg, policy, exp.daemon.clone(), None)
        };
        let (tree_jobs, tree_stats, _) = run_core(BackfillProfile::Tree);
        let (flat_jobs, flat_stats, _) = run_core(BackfillProfile::Flat);
        let mut sim = NaiveSlurmd::new(exp.slurm.clone());
        for s in &specs {
            sim.submit(s.clone());
        }
        let mut daemon = Autonomy::native(policy, exp.daemon.clone());
        sim.run(&mut daemon);
        assert_eq!(sim.stats, tree_stats, "{policy:?} tree stats diverged");
        assert_eq!(sim.stats, flat_stats, "{policy:?} flat stats diverged");
        let ref_jobs = sim.into_jobs();
        assert_eq!(ref_jobs, tree_jobs, "{policy:?} tree jobs diverged");
        assert_eq!(ref_jobs, flat_jobs, "{policy:?} flat jobs diverged");
    }
}

// ---------------------------------------------------------------------
// Policy-layer golden equivalence: the three legacy enum policies
// re-expressed on the DecisionPolicy pipeline must be bit-identical to
// the retained legacy driver (Autonomy::legacy_reference) — job
// records, SlurmStats, and deterministic DaemonStats — on random
// workloads and on the exact 773-job paper cohort. This is the guard
// for the whole policy-layer refactor: the staged pipeline (eligibility
// gate → fit prediction → action selection → budget accounting) must be
// behaviorally invisible for the paper's policies.
// ---------------------------------------------------------------------

use tailtamer::daemon::DaemonStats;
use tailtamer::policy::PolicySpec;

fn run_daemon_on(
    specs: &[JobSpec],
    cfg: &SlurmConfig,
    mut daemon: Autonomy,
) -> (Vec<Job>, SlurmStats, DaemonStats) {
    let mut sim = Slurmd::new(cfg.clone());
    for s in specs {
        sim.submit(s.clone());
    }
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    (sim.into_jobs(), stats, daemon.stats.deterministic())
}

#[test]
fn prop_pipeline_reexpression_matches_legacy_driver() {
    run_prop_cases("pipeline_vs_legacy", 0x9019, 48, |rng| {
        let (mut specs, cfg) = random_workload(rng, 50, 14);
        if rng.chance(0.5) {
            let mut t = 0;
            for s in &mut specs {
                t += rng.int_in(0, 120);
                s.submit = t;
            }
        }
        let policy = random_policy(rng);
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            safety: rng.f64_in(0.0, 1.0),
            max_delay_cost: if rng.chance(0.3) { rng.f64_in(0.0, 1e5) } else { 0.0 },
            ..Default::default()
        };
        let (pj, ps, pd) = run_daemon_on(&specs, &cfg, Autonomy::native(policy, dcfg.clone()));
        let (lj, ls, ld) =
            run_daemon_on(&specs, &cfg, Autonomy::legacy_reference(policy, dcfg.clone()));
        prop_assert!(pj == lj, "{policy:?}: pipeline job records diverged from legacy");
        prop_assert!(ps == ls, "{policy:?}: pipeline SlurmStats diverged from legacy");
        prop_assert!(pd == ld, "{policy:?}: DaemonStats diverged: {pd:?} vs {ld:?}");
        Ok(())
    });
}

#[test]
fn pipeline_matches_legacy_on_the_paper_cohort() {
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    for policy in Policy::ALL {
        let (pj, ps, pd) =
            run_daemon_on(&specs, &exp.slurm, Autonomy::native(policy, exp.daemon.clone()));
        let (lj, ls, ld) = run_daemon_on(
            &specs,
            &exp.slurm,
            Autonomy::legacy_reference(policy, exp.daemon.clone()),
        );
        assert_eq!(pj, lj, "{policy:?}: cohort job records diverged");
        assert_eq!(ps, ls, "{policy:?}: cohort SlurmStats diverged");
        assert_eq!(pd, ld, "{policy:?}: cohort DaemonStats diverged");
    }
}

#[test]
fn prop_parameterized_policies_hold_core_invariants() {
    // The new policies must satisfy the same global safety properties
    // as the legacy ones: sane termination, no oversubscription (via
    // the optimized-vs-naive reference), and adjustment-tag discipline.
    run_prop_cases("param_policy_invariants", 0x9A7A, 36, |rng| {
        let (specs, cfg) = random_workload(rng, 40, 12);
        let spec = match rng.int_in(0, 2) {
            0 => PolicySpec::ExtendBudget { budget: rng.int_in(60, 4000) },
            1 => PolicySpec::TailAware { frac: rng.f64_in(0.01, 2.0) },
            _ => PolicySpec::HybridBackoff { step: rng.int_in(1, 300) },
        };
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            ..Default::default()
        };
        let (jobs, _, dstats) =
            run_scenario(&specs, cfg.clone(), spec.clone(), dcfg.clone(), None);
        for j in &jobs {
            prop_assert!(j.state.is_terminal(), "{}: {} not terminal", spec.name(), j.id);
            if !j.is_checkpointing() {
                prop_assert!(j.adjustment.is_none(), "{}: opaque adjusted", spec.name());
            }
            prop_assert!(job_tail_waste(j) >= 0, "{}: negative tail", spec.name());
        }
        if let PolicySpec::ExtendBudget { budget } = &spec {
            // Per-job budgets bound the spend: approval is against the
            // predicted need, and the control plane may clamp a grant
            // up to the current poll instant (+1 s), so each job's
            // spend is at most budget + poll_period + 1.
            let per_job = (*budget + dcfg.poll_period + 1) as u64;
            prop_assert!(
                dstats.budget_spent <= jobs.len() as u64 * per_job,
                "budget overdrawn: {} > {} x {per_job}",
                dstats.budget_spent,
                jobs.len()
            );
        }
        // Determinism: the same spec replays identically.
        let (jobs2, _, dstats2) = run_scenario(&specs, cfg, spec.clone(), dcfg, None);
        prop_assert!(jobs == jobs2, "{}: nondeterministic jobs", spec.name());
        prop_assert!(
            dstats.deterministic() == dstats2.deterministic(),
            "{}: nondeterministic stats",
            spec.name()
        );
        Ok(())
    });
}

#[test]
fn prop_simulation_is_deterministic() {
    run_prop_cases("determinism", 0xD37, 16, |rng| {
        let (specs, cfg) = random_workload(rng, 40, 12);
        let policy = random_policy(rng);
        let run = || {
            let (jobs, stats, _) =
                run_scenario(&specs, cfg.clone(), policy, DaemonConfig::default(), None);
            summarize("x", &jobs, &stats)
        };
        let (a, b) = (run(), run());
        prop_assert!(a == b, "same inputs produced different summaries");
        Ok(())
    });
}
