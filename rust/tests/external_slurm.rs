//! The external slurmctld binding against the bundled fake-slurmctld
//! script: well-formed parses, malformed-row skipping, rejection,
//! hung-command timeouts, and genuinely parallel batched updates.
//!
//! No real Slurm anywhere: `tests/fake_slurm/fake_slurmctld.sh` plays
//! each site command from canned state under a temp directory.

#![cfg(unix)]

use std::path::PathBuf;

use tailtamer::slurm::{ExternalConfig, ExternalSlurm, JobId, SlurmControl};

/// Per-test scratch dir the fake ctld reads/writes.
fn state_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tt_fake_slurm_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).expect("create fake state dir");
    d
}

/// Command line for one fake role. Tests run with the crate root as
/// cwd, so the script path is relative to `rust/`.
fn fake(role: &str, state: &std::path::Path) -> String {
    format!("sh tests/fake_slurm/fake_slurmctld.sh {role} {}", state.display())
}

fn ctl(state: &std::path::Path, squeue_role: &str, scontrol_role: &str) -> ExternalSlurm {
    ExternalSlurm::new(ExternalConfig {
        squeue_cmd: fake(squeue_role, state),
        scontrol_cmd: fake(scontrol_role, state),
        scancel_cmd: fake("scancel", state),
        timeout_ms: 2_000,
        spool_dir: Some(state.join("spool").display().to_string()),
    })
    .expect("construct external binding")
}

fn read_updates(state: &std::path::Path) -> Vec<String> {
    std::fs::read_to_string(state.join("updates.log"))
        .unwrap_or_default()
        .lines()
        .map(str::to_string)
        .collect()
}

#[test]
fn well_formed_squeue_output_parses_into_a_snapshot() {
    let state = state_dir("parse");
    std::fs::write(
        state.join("queue.txt"),
        "101|sim-a|4|RUNNING|1970-01-01T01:00:00|1:00:00\n\
         102|sim-b|1|R|1970-01-01T01:30:00|2-00:00:00\n\
         203|waiting|2|PENDING|N/A|30\n\
         204|done|1|COMPLETED|1970-01-01T00:00:00|30\n",
    )
    .unwrap();
    let ctl = ctl(&state, "squeue", "scontrol");
    let snap = ctl.squeue();
    assert_eq!(snap.running.len(), 2, "two RUNNING rows");
    assert_eq!(snap.pending.len(), 1, "one PENDING row; COMPLETED ignored");
    assert_eq!(ctl.parse_errors(), 0);
    let a = &snap.running[0];
    assert_eq!((a.id, &*a.name, a.nodes), (JobId(101), "sim-a", 4));
    assert_eq!((a.start, a.cur_limit, a.expected_end), (3_600, 3_600, 7_200));
    let b = &snap.running[1];
    assert_eq!(b.cur_limit, 172_800, "2-00:00:00 is two days");
    let p = &snap.pending[0];
    assert_eq!((p.id, p.nodes, p.cur_limit), (JobId(203), 2, 1_800));
    assert!(p.prediction.is_none());
    let _ = std::fs::remove_dir_all(&state);
}

#[test]
fn malformed_rows_are_skipped_and_counted_not_fatal() {
    let state = state_dir("malformed");
    std::fs::write(
        state.join("queue.txt"),
        "101|ok|1|RUNNING|1970-01-01T01:00:00|30\n\
         totally garbage\n\
         xx|bad-id|1|RUNNING|1970-01-01T01:00:00|30\n\
         103|bad-date|1|RUNNING|yesterdayish|30\n\
         104|bad-limit|1|PENDING|N/A|UNLIMITED\n\
         105|ok-too|1|PENDING|N/A|45\n",
    )
    .unwrap();
    let ctl = ctl(&state, "squeue", "scontrol");
    let snap = ctl.squeue();
    assert_eq!(snap.running.len(), 1, "the one good RUNNING row survives");
    assert_eq!(snap.pending.len(), 1, "the one good PENDING row survives");
    assert_eq!(snap.pending[0].id, JobId(105));
    assert_eq!(ctl.parse_errors(), 4, "each bad row counted once");
    let _ = std::fs::remove_dir_all(&state);
}

#[test]
fn updates_reach_the_ctld_in_minutes_and_rejections_surface() {
    let state = state_dir("updates");
    let mut ctl = ctl(&state, "squeue", "scontrol");
    // 3601 s must round UP to 61 minutes — never grant less than asked.
    ctl.scontrol_update_limit(JobId(7), 3_601).expect("accepting ctld");
    assert_eq!(read_updates(&state), vec!["update JobId=7 TimeLimit=61"]);
    std::fs::write(state.join("reject"), "").unwrap();
    let err = ctl.scontrol_update_limit(JobId(7), 3_601).expect_err("rejecting ctld");
    assert!(err.contains("exited with"), "nonzero exit surfaces as Err: {err}");
    assert_eq!(ctl.rpc_failures, 1);
    let _ = std::fs::remove_dir_all(&state);
}

#[test]
fn hung_commands_are_killed_at_the_deadline() {
    let state = state_dir("hang");
    let mut ctl = ExternalSlurm::new(ExternalConfig {
        squeue_cmd: fake("hang", &state),
        scontrol_cmd: fake("hang", &state),
        scancel_cmd: fake("hang", &state),
        timeout_ms: 200,
        spool_dir: None,
    })
    .unwrap();
    let t0 = std::time::Instant::now();
    let snap = ctl.squeue();
    assert!(snap.running.is_empty() && snap.pending.is_empty(), "hung squeue degrades to empty");
    let err = ctl.scontrol_update_limit(JobId(1), 600).expect_err("hung scontrol");
    assert!(err.contains("timed out"), "timeout names itself: {err}");
    assert_eq!(ctl.timeouts, 1);
    assert_eq!(ctl.rpc_failures, 1);
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(25),
        "both calls must return at their deadline, not the script's sleep"
    );
    let _ = std::fs::remove_dir_all(&state);
}

#[test]
fn broken_ctld_exit_codes_do_not_panic() {
    let state = state_dir("fail");
    let ctl = ctl(&state, "fail", "fail");
    let snap = ctl.squeue();
    assert!(snap.running.is_empty() && snap.pending.is_empty());
    let _ = std::fs::remove_dir_all(&state);
}

#[test]
fn concurrent_batched_updates_keep_submission_order() {
    let state = state_dir("concurrent");
    let mut ctl = ctl(&state, "squeue", "scontrol");
    let updates: Vec<(JobId, i64)> = (1..=6).map(|i| (JobId(i), (i as i64) * 600)).collect();
    let rs = ctl.scontrol_update_limits_concurrent(&updates, 3);
    assert_eq!(rs.len(), 6, "one result per update");
    assert!(rs.iter().all(Result::is_ok), "accepting ctld: all Ok");
    let mut logged = read_updates(&state);
    assert_eq!(logged.len(), 6, "every update spawned one scontrol");
    // Completion order is whatever the pool did; the *set* must match.
    logged.sort();
    let mut expect: Vec<String> =
        (1..=6).map(|i| format!("update JobId={i} TimeLimit={}", i * 10)).collect();
    expect.sort();
    assert_eq!(logged, expect);
    let _ = std::fs::remove_dir_all(&state);
}

#[test]
fn ckpt_reports_come_from_the_spool_dir() {
    let state = state_dir("spool");
    let ctl = ctl(&state, "squeue", "scontrol");
    std::fs::write(state.join("spool").join("ckpt_progress.42"), "100\n200\n").unwrap();
    assert_eq!(ctl.read_ckpt_reports(JobId(42)), vec![100, 200]);
    assert!(ctl.read_ckpt_reports(JobId(43)).is_empty());
    let _ = std::fs::remove_dir_all(&state);
}
