//! Integration: the PJRT-compiled JAX/Pallas decision model must agree
//! with the native Rust oracle on every batch.
//!
//! Compiled only with `--features pjrt` (the default build ships a
//! stub engine whose `load` always errors; see `rust/src/runtime/`).
//!
//! These tests execute the real `artifacts/*.hlo.txt` produced by
//! `make artifacts`. If the artifacts are missing the tests are skipped
//! with a notice (bare `cargo test` before `make artifacts` stays
//! green; the Makefile's `test` target builds them first).
#![cfg(feature = "pjrt")]

use tailtamer::analytics::{DecisionBatch, DecisionEngine, NativeEngine};
use tailtamer::proptest_lite::Rng;
use tailtamer::runtime::{PjrtEngine, default_artifacts_dir};
use tailtamer::slurm::JobId;

fn pjrt_or_skip() -> Option<PjrtEngine> {
    match PjrtEngine::load(&default_artifacts_dir()) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP: pjrt artifacts unavailable: {err:#}");
            None
        }
    }
}

fn random_batch(rng: &mut Rng, r: usize, q: usize, h: usize) -> DecisionBatch {
    let mut b = DecisionBatch::empty(r, q, h, rng.int_in(0, 60) as f32, rng.f64_in(0.0, 2.0) as f32);
    for i in 0..r {
        if rng.chance(0.2) {
            continue; // leave some rows masked
        }
        let n = rng.int_in(0, h as i64) as usize;
        let base = rng.int_in(0, 5_000);
        let iv = rng.int_in(30, 900);
        let hist: Vec<i64> = (1..=n as i64)
            .map(|k| base + k * iv + rng.int_in(-iv / 4, iv / 4))
            .collect();
        if hist.windows(2).any(|w| w[1] <= w[0]) {
            continue; // keep histories strictly increasing
        }
        if !hist.is_empty() {
            let cur_end = hist.last().unwrap() + rng.int_in(0, 2 * iv);
            b.set_row(i, JobId(i as u32), &hist, cur_end, rng.int_in(1, 16) as u32);
        }
    }
    for k in 0..q {
        if rng.chance(0.15) {
            continue;
        }
        b.set_queue(k, rng.int_in(0, 80_000), rng.int_in(1, 20) as u32, rng.int_in(0, 20) as u32);
    }
    b
}

fn assert_outputs_match(
    batch: &DecisionBatch,
    native: &mut NativeEngine,
    pjrt: &mut PjrtEngine,
    ctx: &str,
) {
    let a = native.evaluate(batch).unwrap();
    let b = pjrt.evaluate(batch).unwrap();
    // Binary decisions must match exactly.
    assert_eq!(a.fits, b.fits, "{ctx}: fits");
    assert_eq!(a.conflict, b.conflict, "{ctx}: conflict");
    assert_eq!(a.count, b.count, "{ctx}: count");
    // Continuous outputs to f32 reduction tolerance (XLA may reassociate).
    for (name, x, y) in [
        ("pred_next", &a.pred_next, &b.pred_next),
        ("ext_end", &a.ext_end, &b.ext_end),
        ("mean_int", &a.mean_int, &b.mean_int),
    ] {
        for (i, (u, v)) in x.iter().zip(y.iter()).enumerate() {
            assert!(
                (u - v).abs() <= 0.05 + u.abs() * 1e-5,
                "{ctx}: {name}[{i}] native={u} pjrt={v}"
            );
        }
    }
}

#[test]
fn canonical_job_matches() {
    let Some(mut pjrt) = pjrt_or_skip() else { return };
    let mut native = NativeEngine::new();
    let mut b = DecisionBatch::empty(16, 64, 16, 30.0, 0.0);
    b.set_row(0, JobId(0), &[420, 840, 1260], 1440, 1);
    let out = pjrt.evaluate(&b).unwrap();
    assert_eq!(out.pred_next[0], 1680.0);
    assert_eq!(out.fits[0], 0.0);
    assert_outputs_match(&b, &mut native, &mut pjrt, "canonical");
}

#[test]
fn exact_variant_shapes_match() {
    let Some(mut pjrt) = pjrt_or_skip() else { return };
    let mut native = NativeEngine::new();
    let mut rng = Rng::new(11);
    for (r, q, h) in pjrt.shapes() {
        for case in 0..8 {
            let b = random_batch(&mut rng, r, q, h);
            assert_outputs_match(&b, &mut native, &mut pjrt, &format!("variant {r}x{q}x{h} case {case}"));
        }
    }
}

#[test]
fn padded_odd_shapes_match() {
    let Some(mut pjrt) = pjrt_or_skip() else { return };
    let mut native = NativeEngine::new();
    let mut rng = Rng::new(23);
    for &(r, q, h) in &[(1, 1, 2), (3, 7, 5), (10, 100, 16), (17, 65, 17), (40, 200, 30)] {
        for case in 0..4 {
            let b = random_batch(&mut rng, r, q, h);
            assert_outputs_match(&b, &mut native, &mut pjrt, &format!("padded {r}x{q}x{h} case {case}"));
        }
    }
}

#[test]
fn oversized_batch_is_rejected_cleanly() {
    let Some(mut pjrt) = pjrt_or_skip() else { return };
    let b = DecisionBatch::empty(65, 64, 16, 30.0, 0.0);
    let err = pjrt.evaluate(&b).unwrap_err();
    assert!(err.to_string().contains("exceeds"), "{err}");
}

#[test]
fn full_scenario_identical_under_both_engines() {
    // The strongest equivalence: an entire 72-job simulation, decision
    // for decision, must produce identical job outcomes.
    let Some(pjrt) = pjrt_or_skip() else { return };
    use tailtamer::config::Experiment;
    use tailtamer::daemon::{Policy, run_scenario};
    use tailtamer::metrics::summarize;

    let mut exp = Experiment::default();
    exp.pm100.completed = 50;
    exp.pm100.timeout_below_cap = 10;
    exp.pm100.timeout_at_cap = 12;
    exp.pm100.max_nodes = 8;
    exp.slurm.nodes = 8;
    let specs = exp.build_workload();

    for policy in [Policy::EarlyCancel, Policy::Extend, Policy::Hybrid] {
        let (jobs_n, stats_n, _) =
            run_scenario(&specs, exp.slurm.clone(), policy, exp.daemon.clone(), None);
        let (jobs_p, stats_p, _) = run_scenario(
            &specs,
            exp.slurm.clone(),
            policy,
            exp.daemon.clone(),
            Some(Box::new(PjrtEngine::load(&default_artifacts_dir()).unwrap())),
        );
        let a = summarize(policy.name(), &jobs_n, &stats_n);
        let b = summarize(policy.name(), &jobs_p, &stats_p);
        assert_eq!(a, b, "native and pjrt scenarios diverged under {policy:?}");
        for (x, y) in jobs_n.iter().zip(&jobs_p) {
            assert_eq!(x.end, y.end, "job {} end", x.id);
            assert_eq!(x.adjustment, y.adjustment, "job {} adjustment", x.id);
        }
    }
    drop(pjrt);
}
