#!/bin/sh
# Fake slurmctld for exercising the external binding without a real
# Slurm: each role mimics one site command. The binding is configured
# with e.g.
#
#   squeue_cmd   = "sh tests/fake_slurm/fake_slurmctld.sh squeue <state-dir>"
#   scontrol_cmd = "sh tests/fake_slurm/fake_slurmctld.sh scontrol <state-dir>"
#
# and appends its usual arguments; roles ignore what they don't need.
#
# Roles:
#   squeue <dir>    print <dir>/queue.txt (the canned queue), plus any
#                   formatting args the binding appended are ignored
#   scontrol <dir>  log the update args to <dir>/updates.log; exit 1
#                   if <dir>/reject exists (a rejecting slurmctld)
#   scancel <dir>   log the cancel to <dir>/updates.log
#   hang <dir>      sleep far past any test timeout (hung slurmctld)
#   fail <dir>      exit 3 with no output (broken slurmctld)

role="$1"
state="$2"
shift 2 2>/dev/null || true

case "$role" in
  squeue)
    if [ -f "$state/queue.txt" ]; then
      cat "$state/queue.txt"
    fi
    ;;
  scontrol)
    echo "$@" >> "$state/updates.log"
    if [ -e "$state/reject" ]; then
      exit 1
    fi
    ;;
  scancel)
    echo "cancel $@" >> "$state/updates.log"
    ;;
  hang)
    sleep 30
    ;;
  fail)
    exit 3
    ;;
  *)
    echo "fake_slurmctld: unknown role: $role" >&2
    exit 2
    ;;
esac
exit 0
