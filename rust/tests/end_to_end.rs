//! End-to-end integration: the full paper workload through the whole
//! stack, plus config/CSV plumbing.

use tailtamer::config::Experiment;
use tailtamer::daemon::{Policy, run_scenario};
use tailtamer::metrics::summarize;
use tailtamer::report::{render_table1, summaries_csv};
use tailtamer::workload::{FilterSpec, Pm100Config, WorkloadSpec};

/// The headline run: all four policies over the 773-job cohort.
/// Mirrors examples/reproduce_table1.rs with hard assertions.
#[test]
fn table1_shape_reproduces() {
    let exp = Experiment::default();
    let specs = exp.build_workload();
    assert_eq!(specs.len(), 773);

    let mut summaries = Vec::new();
    for policy in Policy::ALL {
        let (jobs, stats, _) =
            run_scenario(&specs, exp.slurm.clone(), policy, exp.daemon.clone(), None);
        summaries.push(summarize(policy.name(), &jobs, &stats));
    }
    let (base, ec, ext, hy) = (&summaries[0], &summaries[1], &summaries[2], &summaries[3]);

    // Job-outcome rows (Table 1, exact).
    assert_eq!(base.timeout, 217);
    assert_eq!(base.completed, 556);
    for s in &summaries[1..] {
        assert_eq!(s.timeout, 108, "{}: non-checkpointing timeouts unchanged", s.policy);
        assert_eq!(s.completed, 556);
        assert_eq!(s.early_cancelled + s.extended, 109, "{}", s.policy);
    }
    assert_eq!(ec.early_cancelled, 109);
    assert_eq!(ext.extended, 109);
    assert!(hy.early_cancelled > 0 && hy.extended > 0, "hybrid must mix");

    // Checkpoints: EC preserves, Extend gains exactly one per job.
    assert_eq!(base.total_checkpoints, 327);
    assert_eq!(ec.total_checkpoints, 327);
    assert_eq!(ext.total_checkpoints, 436);
    assert!(hy.total_checkpoints > 327 && hy.total_checkpoints < 436);

    // Headline: ~95% tail-waste reduction (gate at 90%).
    for s in &summaries[1..] {
        let red = s.tail_waste_reduction(base);
        assert!((90.0..100.0).contains(&red), "{}: {red:.1}%", s.policy);
    }

    // CPU/makespan directions.
    assert!(ec.total_cpu_time < base.total_cpu_time, "EC saves CPU");
    assert!(ext.total_cpu_time > base.total_cpu_time, "Extend adds (useful) CPU");
    assert!(ec.makespan < base.makespan);
    assert!(ext.makespan > base.makespan);

    // Weighted wait: EC/Hybrid improve, Extend degrades (Fig. 4).
    assert!(ec.weighted_avg_wait < base.weighted_avg_wait);
    assert!(hy.weighted_avg_wait < base.weighted_avg_wait);
    assert!(ext.weighted_avg_wait > base.weighted_avg_wait);

    // Scheduler accounting: every job started exactly once.
    for s in &summaries {
        assert_eq!(s.sched_main + s.sched_backfill, 773, "{}", s.policy);
    }

    // Render paths don't panic and carry the data.
    let table = render_table1(&summaries);
    assert!(table.contains("941,760") || table.contains(&format!("{}", base.tail_waste)));
    let csv = summaries_csv(&summaries);
    assert_eq!(csv.lines().count(), 5);
}

#[test]
fn shipped_configs_load_and_run() {
    for name in [
        "configs/paper.toml",
        "configs/jittered.toml",
        "configs/smoke.toml",
        "configs/tailaware.toml",
    ] {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(name);
        let mut exp = Experiment::load(&path).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        // Run the smoke config end to end (the others are too big for
        // a per-config integration run; table1_shape covers paper.toml's
        // parameters via defaults).
        if name.ends_with("smoke.toml") {
            exp.engine = tailtamer::config::EngineKind::Native;
            let specs = exp.build_workload();
            assert_eq!(specs.len(), 72);
            let (jobs, stats, dstats) =
                run_scenario(&specs, exp.slurm.clone(), exp.policy, exp.daemon.clone(), None);
            let s = summarize("smoke", &jobs, &stats);
            assert_eq!(s.total_jobs, 72);
            assert_eq!(s.early_cancelled, 12, "all 12 checkpointing jobs cancelled");
            assert!(dstats.cancels == 12);
        }
    }
}

#[test]
fn trace_csv_roundtrip_drives_identical_simulation() {
    use tailtamer::workload::{csv, generate_cohort, scale, to_job_specs};
    let cohort = generate_cohort(&Pm100Config { completed: 30, timeout_below_cap: 5, timeout_at_cap: 6, max_nodes: 8, seed: 9 });
    let mut buf = Vec::new();
    csv::write_csv(&mut buf, &cohort).unwrap();
    let back = csv::read_csv(std::io::Cursor::new(buf)).unwrap();

    let spec = WorkloadSpec::default();
    let a = to_job_specs(&scale(&cohort, 60), &spec);
    let b = to_job_specs(&scale(&back, 60), &spec);
    assert_eq!(a, b);

    let slurm = tailtamer::slurm::SlurmConfig { nodes: 8, ..Default::default() };
    let (ja, sa, _) = run_scenario(&a, slurm.clone(), Policy::Hybrid, Default::default(), None);
    let (jb, sb, _) = run_scenario(&b, slurm, Policy::Hybrid, Default::default(), None);
    assert_eq!(summarize("x", &ja, &sa), summarize("x", &jb, &sb));
}

#[test]
fn swf_fixture_drives_a_pinned_cohort_anchor() {
    // The bundled archive excerpt, through the exact `simulate --trace
    // sample.swf` pipeline (parse -> 60x scale -> adapt -> baseline
    // run). Every number here is hand-derivable from the fixture, so
    // this is the e2e anchor for the whole SWF ingest path.
    use tailtamer::workload::{scale, swf, to_job_specs};
    let t = swf::load_swf(std::path::Path::new("tests/fixtures/sample.swf")).unwrap();
    assert_eq!((t.records.len(), t.malformed), (12, 2));
    let specs = to_job_specs(&scale(&t.records, 60), &WorkloadSpec::default());
    let run = || {
        let (jobs, stats, _) = run_scenario(
            &specs,
            tailtamer::slurm::SlurmConfig::default(),
            Policy::Baseline,
            Default::default(),
            None,
        );
        summarize("swf", &jobs, &stats)
    };
    let s = run();
    assert_eq!(s.total_jobs, 12);
    assert_eq!(s.completed, 7);
    assert_eq!(s.timeout, 5, "rows 1, 4, 6, 9, 12 hit their limits");
    assert_eq!(s.node_failed, 0, "failures default off");
    assert_eq!(s.failed_tail_waste, 0);
    // The three cap timeouts each lose 180 s past their 1260 s
    // checkpoint: 180 x (96 + 48 + 480) cores.
    assert_eq!(s.tail_waste, 112_320);
    assert_eq!(s.sched_main + s.sched_backfill, 12, "every job started once");
    // And the whole path is deterministic run to run.
    assert_eq!(s, run());
}

#[test]
fn filter_pipeline_matches_paper_reduction() {
    // The paper: 1,074,576 raw jobs -> 773 after filters. Small-scale
    // mirror: chaff-augmented raw set filters back to exactly the cohort.
    let cfg = Pm100Config::default();
    let raw = tailtamer::workload::generate_raw(&cfg, 3.0);
    let filtered = tailtamer::workload::filter(&raw, &FilterSpec::default());
    assert_eq!(filtered.len(), 773);
    let n_ckpt = filtered
        .iter()
        .filter(|r| r.state == tailtamer::workload::TraceState::Timeout && r.time_limit == 86400)
        .count();
    assert_eq!(n_ckpt, 109);
}

#[test]
fn io_correlated_noise_still_beats_baseline() {
    // Future work §8: shared-filesystem contention stretches checkpoint
    // intervals in a correlated way. The loop must still remove most of
    // the tail (the estimator sees the stretch as higher std; safety
    // widens predictions accordingly).
    use tailtamer::workload::ionoise::{LoadProfile, apply_io_noise};
    let mut exp = Experiment::default();
    exp.daemon.safety = 1.0;
    let specs = exp.build_workload();
    let load = LoadProfile::synthetic(120_000, 60, 86_400, 12, 0xae51);
    let plans = apply_io_noise(&specs, 0.4, &load);

    let run = |policy| {
        let mut sim = tailtamer::slurm::Slurmd::new(exp.slurm.clone());
        for (s, plan) in specs.iter().zip(&plans) {
            sim.submit_with_plan(s.clone(), plan.clone());
        }
        let mut d = tailtamer::daemon::Autonomy::native(policy, exp.daemon.clone());
        sim.run(&mut d);
        let stats = sim.stats.clone();
        summarize("io", &sim.into_jobs(), &stats)
    };
    let base = run(Policy::Baseline);
    let ec = run(Policy::EarlyCancel);
    assert!(base.tail_waste > 0);
    // Under correlated stretching the *relative* reduction is
    // alignment-luck dependent (a stretched checkpoint can land right at
    // the limit, zeroing the baseline tail — with seed 0xae51/beta 0.4
    // the shared plan hits 1439 vs limit 1440). The robust guarantees
    // are absolute: the loop keeps every job's residual tail within the
    // detection bound, totalling far below the paper-regime baseline.
    let poll_bound: i64 = 109 * (exp.daemon.poll_period + 1) * 48;
    assert!(
        ec.tail_waste <= poll_bound,
        "EC tail {} exceeds poll bound {poll_bound}",
        ec.tail_waste
    );
    assert!(
        ec.tail_waste < 941_760 / 10,
        "EC tail must stay an order below the paper-regime baseline"
    );
}

#[test]
fn different_seeds_preserve_the_headline() {
    // The 95% claim must be robust to workload resampling, not a
    // seed-42 artifact.
    for seed in [7, 1234, 0xFEED] {
        let mut exp = Experiment::default();
        exp.pm100.seed = seed;
        let specs = exp.build_workload();
        let run = |p| {
            let (jobs, stats, _) = run_scenario(&specs, exp.slurm.clone(), p, exp.daemon.clone(), None);
            summarize("x", &jobs, &stats)
        };
        let base = run(Policy::Baseline);
        let ec = run(Policy::EarlyCancel);
        let red = ec.tail_waste_reduction(&base);
        assert!(red > 90.0, "seed {seed}: reduction {red:.1}%");
        assert!(ec.total_cpu_time < base.total_cpu_time, "seed {seed}: no CPU saving");
    }
}
