//! Shared integration-test support.
//!
//! The flaky control surface used by the poll-elision, policy-layer,
//! and backfill-ondemand suites: a [`SlurmControl`] proxy that rejects
//! the first K control actions (scancel / scontrol), exercising the
//! daemon's per-tick retry path, plus the [`DaemonHook`] wrapper that
//! threads it around an [`Autonomy`] daemon.
#![allow(dead_code)] // each test binary uses a subset of this module

use tailtamer::daemon::Autonomy;
use tailtamer::simtime::Time;
use tailtamer::slurm::{Adjustment, DaemonHook, JobId, QueueSnapshot, SlurmControl};

/// Control-surface proxy that rejects the first K actions.
///
/// Rejection is **per action**, not per RPC: a batched
/// `scontrol_update_limits` call consumes one token per update it
/// carries, so the AIMD controller observes the same rejection stream
/// whether or not batching is on. `latency_ms` adds a wall-clock stall
/// to every mutating action (live-mode tests only; keep it 0 in
/// simulation suites).
pub struct FlakyCtl<'a> {
    pub inner: &'a mut dyn SlurmControl,
    pub rejects_left: &'a mut u32,
    pub injected: &'a mut u32,
    pub latency_ms: u64,
}

impl FlakyCtl<'_> {
    fn gate(&mut self) -> Result<(), String> {
        if self.latency_ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(self.latency_ms));
        }
        if *self.rejects_left > 0 {
            *self.rejects_left -= 1;
            *self.injected += 1;
            return Err("injected control failure".into());
        }
        Ok(())
    }
}

impl SlurmControl for FlakyCtl<'_> {
    fn control_now(&self) -> Time {
        self.inner.control_now()
    }
    fn squeue(&self) -> QueueSnapshot {
        self.inner.squeue()
    }
    fn squeue_into(&self, out: &mut QueueSnapshot) {
        self.inner.squeue_into(out)
    }
    fn read_ckpt_reports(&self, id: JobId) -> Vec<Time> {
        self.inner.read_ckpt_reports(id)
    }
    fn read_ckpt_reports_into(&self, id: JobId, out: &mut Vec<Time>) {
        self.inner.read_ckpt_reports_into(id, out)
    }
    fn read_new_ckpt_reports_into(&self, id: JobId, cursor: &mut usize, out: &mut Vec<Time>) {
        self.inner.read_new_ckpt_reports_into(id, cursor, out)
    }
    fn scontrol_update_limit(&mut self, id: JobId, new_limit: Time) -> Result<(), String> {
        self.gate()?;
        self.inner.scontrol_update_limit(id, new_limit)
    }
    fn scontrol_update_limits(&mut self, updates: &[(JobId, Time)]) -> Vec<Result<(), String>> {
        updates
            .iter()
            .map(|&(id, limit)| {
                self.gate()?;
                self.inner.scontrol_update_limit(id, limit)
            })
            .collect()
    }
    fn scancel(&mut self, id: JobId) -> Result<(), String> {
        self.gate()?;
        self.inner.scancel(id)
    }
    fn mark_adjustment(&mut self, id: JobId, adj: Adjustment) {
        self.inner.mark_adjustment(id, adj)
    }
}

/// [`Autonomy`] wrapper injecting [`FlakyCtl`] into every poll.
pub struct FlakyHook {
    pub inner: Autonomy,
    pub rejects_left: u32,
    /// Rejections actually injected (consumed from `rejects_left`).
    pub injected: u32,
    /// Wall-clock stall per mutating action, milliseconds.
    pub latency_ms: u64,
}

impl FlakyHook {
    pub fn new(inner: Autonomy, rejects: u32) -> Self {
        Self { inner, rejects_left: rejects, injected: 0, latency_ms: 0 }
    }

    /// Also stall every mutating action (live-mode suites: a slow ctld
    /// must degrade the daemon, never hang it).
    pub fn with_latency(mut self, latency_ms: u64) -> Self {
        self.latency_ms = latency_ms;
        self
    }
}

impl DaemonHook for FlakyHook {
    fn poll_period(&self) -> Option<Time> {
        self.inner.poll_period()
    }
    fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
        let mut proxy = FlakyCtl {
            inner: ctl,
            rejects_left: &mut self.rejects_left,
            injected: &mut self.injected,
            latency_ms: self.latency_ms,
        };
        self.inner.on_poll(t, &mut proxy);
    }
    fn poll_elidable(&self) -> bool {
        self.inner.poll_elidable()
    }
    fn note_elided_polls(&mut self, n: u64) {
        self.inner.note_elided_polls(n);
    }
}
