//! The resilience layer: windowed retry budgets and AIMD-batched
//! control actions.
//!
//! Doctrine: resilience must be **invisible on a clean control
//! surface**. Budgets only meter *retries* of rejected actions, and
//! batching defers the same limit math to one RPC — so with no faults
//! injected, a budgeted/batched daemon is pinned bit-identical to the
//! plain one (jobs, `SlurmStats`, deterministic `DaemonStats` modulo
//! the batch RPC counters that only exist in batched mode). Under
//! faults, the daemon degrades: exhausted budgets suppress retries
//! until the window refills, and the AIMD window shrinks toward safe
//! singles.

mod common;

use common::FlakyHook;
use tailtamer::daemon::{Autonomy, DaemonConfig, DaemonStats, Policy};
use tailtamer::policy::PolicySpec;
use tailtamer::proptest_lite::{Rng, run_prop_cases};
use tailtamer::prop_assert;
use tailtamer::slurm::{Adjustment, Job, JobSpec, JobState, SlurmConfig, SlurmStats, Slurmd};

fn norm(s: DaemonStats) -> DaemonStats {
    s.deterministic()
}

/// Deterministic stats with the batched-mode RPC counters zeroed, for
/// comparing a batched run against an unbatched one (everything else
/// must match bit-for-bit).
fn norm_batch(s: DaemonStats) -> DaemonStats {
    DaemonStats { batch_calls: 0, batched_updates: 0, ..s.deterministic() }
}

fn run_sim(
    specs: &[JobSpec],
    cfg: &SlurmConfig,
    policy: PolicySpec,
    dcfg: DaemonConfig,
) -> (Vec<Job>, SlurmStats, DaemonStats) {
    let mut sim = Slurmd::new(cfg.clone());
    for s in specs {
        sim.submit(s.clone());
    }
    let mut daemon = Autonomy::native(policy, dcfg);
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    (sim.into_jobs(), stats, daemon.stats)
}

fn random_workload(rng: &mut Rng) -> (Vec<JobSpec>, SlurmConfig) {
    let n = rng.int_in(1, 30) as usize;
    let nodes_total = rng.int_in(2, 10) as u32;
    let mut specs = Vec::with_capacity(n);
    let mut t = 0;
    for i in 0..n {
        let nodes = rng.int_in(1, nodes_total as i64) as u32;
        let limit = rng.int_in(60, 2000);
        let duration =
            if rng.chance(0.4) { limit + rng.int_in(1, 2000) } else { rng.int_in(30, limit.max(31)) };
        let mut spec = JobSpec::new(&format!("r{i}"), limit, duration, nodes);
        if rng.chance(0.6) {
            spec = spec.with_ckpt(rng.int_in(40, 700));
        }
        if rng.chance(0.5) {
            t += rng.int_in(0, 90);
            spec.submit = t;
        }
        specs.push(spec);
    }
    let cfg = SlurmConfig { nodes: nodes_total, ..Default::default() };
    (specs, cfg)
}

fn random_policy_spec(rng: &mut Rng) -> PolicySpec {
    match rng.int_in(0, 6) {
        0 => PolicySpec::Baseline,
        1 => PolicySpec::EarlyCancel,
        2 => PolicySpec::Extend,
        3 => PolicySpec::Hybrid,
        4 => PolicySpec::ExtendBudget { budget: rng.int_in(60, 4000) },
        5 => PolicySpec::TailAware { frac: rng.f64_in(0.01, 2.0) },
        _ => PolicySpec::HybridBackoff { step: rng.int_in(1, 300) },
    }
}

// ---------------------------------------------------------------------
// Clean control surface: budgets and batching are behaviorally
// invisible (the tentpole's bit-identity pin).
// ---------------------------------------------------------------------

#[test]
fn prop_clean_surface_budgeted_and_batched_runs_are_bit_identical() {
    run_prop_cases("resilience_golden", 0xB0D9E7, 32, |rng| {
        let (specs, cfg) = random_workload(rng);
        let policy = random_policy_spec(rng);
        let base = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            ..Default::default()
        };
        let tag = policy.name();
        let (jobs, stats, dstats) = run_sim(&specs, &cfg, policy.clone(), base.clone());

        // Unlimited budget (capacity 0 = pre-budget behavior).
        let unlimited = DaemonConfig { retry_budget: 0, ..base.clone() };
        let (j2, s2, d2) = run_sim(&specs, &cfg, policy.clone(), unlimited);
        prop_assert!(jobs == j2, "{tag}: jobs diverged under retry_budget=0");
        prop_assert!(stats == s2, "{tag}: SlurmStats diverged under retry_budget=0");
        prop_assert!(
            norm(dstats.clone()) == norm(d2),
            "{tag}: DaemonStats diverged under retry_budget=0"
        );

        // Tight budget: no rejections happen, so no token is ever drawn.
        let tight = DaemonConfig { retry_budget: 1, retry_window: 60, ..base.clone() };
        let (j3, s3, d3) = run_sim(&specs, &cfg, policy.clone(), tight);
        prop_assert!(jobs == j3, "{tag}: jobs diverged under a tight budget");
        prop_assert!(stats == s3, "{tag}: SlurmStats diverged under a tight budget");
        prop_assert!(
            norm(dstats.clone()) == norm(d3),
            "{tag}: DaemonStats diverged under a tight budget"
        );
        prop_assert!(d3.budget_exhausted == 0, "{tag}: clean surface must not exhaust");

        // AIMD batching: same jobs, same cluster stats, same daemon
        // stats apart from the batch RPC counters.
        let batched = DaemonConfig { batch_actions: true, ..base.clone() };
        let (j4, s4, d4) = run_sim(&specs, &cfg, policy.clone(), batched);
        prop_assert!(jobs == j4, "{tag}: jobs diverged under batching");
        prop_assert!(stats == s4, "{tag}: SlurmStats diverged under batching");
        prop_assert!(
            norm_batch(dstats.clone()) == norm_batch(d4.clone()),
            "{tag}: DaemonStats diverged under batching: {dstats:?} vs {d4:?}"
        );
        prop_assert!(
            d4.batched_updates == d4.extensions,
            "{tag}: batched mode routes every extension through the batch RPC"
        );
        Ok(())
    });
}

#[test]
fn clean_surface_is_pinned_on_the_paper_cohort() {
    // One cohort policy is enough to pin the full-scale path (the
    // elision and replay suites sweep the whole registry); Extend
    // maximizes batched traffic.
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    let cfg = exp.slurm.clone();
    let (jobs, stats, dstats) =
        run_sim(&specs, &cfg, PolicySpec::Extend, exp.daemon.clone());
    let batched = DaemonConfig { batch_actions: true, ..exp.daemon.clone() };
    let (j2, s2, d2) = run_sim(&specs, &cfg, PolicySpec::Extend, batched);
    assert_eq!(jobs, j2, "cohort jobs diverged under batching");
    assert_eq!(stats, s2, "cohort SlurmStats diverged under batching");
    assert_eq!(norm_batch(dstats), norm_batch(d2.clone()), "cohort DaemonStats diverged");
    assert!(d2.batch_calls > 0, "the cohort must exercise the batch RPC");
    assert!(
        d2.batch_calls < d2.batched_updates,
        "AIMD must amortize RPCs on the cohort: {} calls for {} updates",
        d2.batch_calls,
        d2.batched_updates
    );
}

// ---------------------------------------------------------------------
// Faulty control surface: budget exhaustion, refill, and degradation.
// ---------------------------------------------------------------------

/// One early-cancel target plus a flaky surface, driven to completion.
fn run_flaky(rejects: u32, dcfg: DaemonConfig) -> (Vec<Job>, FlakyHook) {
    let mut sim = Slurmd::new(SlurmConfig { nodes: 2, ..Default::default() });
    sim.submit(JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420));
    let mut hook = FlakyHook::new(Autonomy::native(Policy::EarlyCancel, dcfg), rejects);
    sim.run(&mut hook);
    (sim.into_jobs(), hook)
}

#[test]
fn exhausted_retry_budget_degrades_to_noop_and_refills() {
    // Budget: 1 retry per 100 s window. Attempt schedule (polls every
    // 20 s, first ¬fits verdict at 1280): 1280 is a *first* attempt
    // (free, rejected), 1300 draws the refilled token (rejected),
    // 1320–1380 are suppressed (bucket empty), 1400 refills and lands.
    let dcfg = DaemonConfig { retry_budget: 1, retry_window: 100, ..Default::default() };
    let (jobs, hook) = run_flaky(2, dcfg.clone());
    let d = hook.inner.stats;
    assert_eq!(hook.injected, 2, "both injected rejections are consumed");
    assert_eq!(d.scontrol_errors, 2, "each rejection counted once: {d:?}");
    assert!(
        d.budget_exhausted >= 3,
        "suppressed retries must be recorded: {d:?}"
    );
    assert_eq!(jobs[0].state, JobState::Cancelled, "the cancel lands after the refill");
    assert_eq!(jobs[0].adjustment, Some(Adjustment::EarlyCancelled));
    let end = jobs[0].end.unwrap();
    assert!(
        (1380..=1420).contains(&end),
        "cancel waits for the window refill, not the next poll: end={end}"
    );

    // A permanently hostile surface: the budget caps the attempt rate
    // and the job simply times out — no wedge, no unbounded retry spam.
    let (jobs, hook) = run_flaky(u32::MAX, dcfg);
    let d = hook.inner.stats;
    assert_eq!(jobs[0].state, JobState::Timeout, "degraded to baseline behavior");
    assert!(
        d.scontrol_errors <= 4,
        "budget must cap the attempt rate (1 free + ~1 per 100 s window): {d:?}"
    );
    assert!(d.budget_exhausted >= 4, "the suppressed ticks are visible: {d:?}");
}

#[test]
fn unlimited_budget_retries_every_tick() {
    // Capacity 0 disables metering: the pre-budget behavior (one retry
    // per poll) is still reachable and still pinned.
    let dcfg = DaemonConfig { retry_budget: 0, ..Default::default() };
    let (jobs, hook) = run_flaky(3, dcfg);
    let d = hook.inner.stats;
    assert_eq!(d.scontrol_errors, 3);
    assert_eq!(d.budget_exhausted, 0, "unlimited budget never exhausts");
    assert_eq!(jobs[0].state, JobState::Cancelled);
    let end = jobs[0].end.unwrap();
    assert!((1280..=1280 + 3 * 20).contains(&end), "per-tick retries: end={end}");
}

// ---------------------------------------------------------------------
// AIMD batch sizing.
// ---------------------------------------------------------------------

#[test]
fn aimd_slow_start_then_amortizes_identical_extensions() {
    // Four identical checkpointers reach the same ¬fits verdict on the
    // same tick, so each extension round flushes 4 updates. The AIMD
    // window slow-starts at 1 (first round: windows of 1, 2, 1 = three
    // RPCs) and converges to one RPC per round.
    let specs: Vec<JobSpec> =
        (0..4).map(|i| JobSpec::new(&format!("ck{i}"), 1440, 2880, 1).with_ckpt(420)).collect();
    let cfg = SlurmConfig { nodes: 8, ..Default::default() };
    let base = DaemonConfig::default();
    let (jobs, stats, dstats) = run_sim(&specs, &cfg, PolicySpec::Extend, base.clone());
    let batched_cfg = DaemonConfig { batch_actions: true, ..base };
    let (j2, s2, d2) = run_sim(&specs, &cfg, PolicySpec::Extend, batched_cfg);
    assert_eq!(jobs, j2, "batched extensions must land identically");
    assert_eq!(stats, s2);
    assert_eq!(norm_batch(dstats), norm_batch(d2.clone()));
    assert_eq!(d2.batched_updates, d2.extensions, "every extension went through the batch");
    assert!(d2.batched_updates >= 8, "four jobs, several extension rounds: {d2:?}");
    assert!(
        d2.batch_calls < d2.batched_updates,
        "AIMD amortizes same-tick updates: {} calls for {} updates",
        d2.batch_calls,
        d2.batched_updates
    );
    // Slow start is visible: the first round cannot fit 4 updates in
    // one RPC, so the total call count exceeds the number of rounds.
    let rounds = d2.extensions / 4;
    assert!(
        d2.batch_calls > rounds,
        "round one must split under slow start: {} calls, {} rounds",
        d2.batch_calls,
        rounds
    );
}

#[test]
fn aimd_window_halves_on_batched_rejections() {
    // Same four-job workload, but the first 2 actions are rejected:
    // the AIMD controller must halve back toward singles, every
    // rejection must be counted, and the extensions still land.
    let specs: Vec<JobSpec> =
        (0..4).map(|i| JobSpec::new(&format!("ck{i}"), 1440, 2880, 1).with_ckpt(420)).collect();
    let mut sim = Slurmd::new(SlurmConfig { nodes: 8, ..Default::default() });
    for s in &specs {
        sim.submit(s.clone());
    }
    let dcfg = DaemonConfig { batch_actions: true, ..Default::default() };
    let mut hook = FlakyHook::new(Autonomy::native(PolicySpec::Extend, dcfg), 2);
    sim.run(&mut hook);
    let jobs = sim.into_jobs();
    let d = hook.inner.stats;
    assert_eq!(hook.injected, 2);
    assert_eq!(d.scontrol_errors, 2, "per-update rejections inside a batch are counted: {d:?}");
    assert!(d.batch_calls > 0);
    for j in &jobs {
        assert_eq!(j.adjustment, Some(Adjustment::Extended), "extensions land despite faults");
    }
}
