//! Live-mode fault injection: the wall-clock loop against a flaky,
//! slow control plane.
//!
//! Two fault layers are exercised: the shared [`common::FlakyHook`]
//! proxy (rejections + per-action latency between the daemon and
//! `LiveCtld`, the same layer the simulation golden suites use) and
//! [`LiveConfig::flaky_rejects`] (rejections inside the mock ctld
//! itself, the knob the CI smoke drives via `--flaky`). Either way the
//! run must *terminate* with the degradation visible in stats — never
//! hang, never wedge.

mod common;

use std::time::Duration;

use common::FlakyHook;
use tailtamer::daemon::{Autonomy, DaemonConfig, Policy};
use tailtamer::live::{LiveConfig, run_live};
use tailtamer::slurm::{Adjustment, JobSpec, JobState};

fn tmpdir(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!("tt_live_res_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

#[test]
fn flaky_proxy_degrades_gracefully_and_the_cancel_lands() {
    let dir = tmpdir("proxy");
    let cfg = LiveConfig { nodes: 2, speed: 240.0, sched_tick_ms: 10, ..LiveConfig::default() };
    // 1440 sim-s limit at 240x = 6 wall-s; ckpts every 420 sim-s mean
    // the early cancel fires around sim 1280 with ~8 polls to spare
    // for the two injected rejections.
    let specs = vec![JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420)];
    let daemon =
        Autonomy::native(Policy::EarlyCancel, DaemonConfig { margin: 60, ..Default::default() });
    let mut hook = FlakyHook::new(daemon, 2).with_latency(3);
    let out = run_live(cfg, specs, &mut hook, &dir, Duration::from_secs(30)).unwrap();
    assert_eq!(hook.injected, 2, "both rejections served through the live loop");
    let d = &hook.inner.stats;
    assert!(d.scontrol_errors >= 2, "live rejections must be counted: {d:?}");
    let j = &out.jobs[0];
    assert_eq!(j.state, JobState::Cancelled, "retry lands after faults: {:?}", j.reported_ckpts);
    assert_eq!(j.adjustment, Some(Adjustment::EarlyCancelled));
    // The proxy rejected before reaching LiveCtld: the ctld served no
    // injected faults of its own and only the landed actions as RPCs.
    assert_eq!(out.injected_faults, 0);
    assert!(out.scancels >= 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn flaky_ctld_config_injects_and_reports_faults() {
    let dir = tmpdir("ctld");
    // The ctld itself rejects the first 2 mutating actions (the
    // `tailtamer live --flaky 2` path): the daemon retries through
    // them and the report carries the fault count.
    let cfg = LiveConfig {
        nodes: 2,
        speed: 240.0,
        sched_tick_ms: 10,
        flaky_rejects: 2,
        ..LiveConfig::default()
    };
    let specs = vec![JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420)];
    let mut daemon =
        Autonomy::native(Policy::EarlyCancel, DaemonConfig { margin: 60, ..Default::default() });
    let out = run_live(cfg, specs, &mut daemon, &dir, Duration::from_secs(30)).unwrap();
    assert_eq!(out.injected_faults, 2, "the ctld served its injected faults");
    assert!(daemon.stats.scontrol_errors >= 2, "{:?}", daemon.stats);
    assert_eq!(out.jobs[0].state, JobState::Cancelled);
    // Every attempt was one RPC: the rejected ones count too.
    assert!(
        out.scontrol_rpcs >= out.scancels + 2,
        "rejected attempts are round trips: rpcs={} cancels={}",
        out.scontrol_rpcs,
        out.scancels
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn batched_live_run_reduces_control_rpcs() {
    let dir = tmpdir("batch");
    // Two identical checkpointers under Extend reach the same verdict
    // on the same tick: batching folds their updates into shared RPCs,
    // so round trips stay below one-per-action.
    let cfg = LiveConfig { nodes: 2, speed: 240.0, sched_tick_ms: 10, ..LiveConfig::default() };
    let specs = vec![
        JobSpec::new("ck-a", 900, 1400, 1).with_ckpt(420),
        JobSpec::new("ck-b", 900, 1400, 1).with_ckpt(420),
    ];
    let mut daemon = Autonomy::native(
        Policy::Extend,
        DaemonConfig { margin: 60, batch_actions: true, ..Default::default() },
    );
    let out = run_live(cfg, specs, &mut daemon, &dir, Duration::from_secs(30)).unwrap();
    let d = &daemon.stats;
    assert!(d.batch_calls > 0, "live extends must flow through the batch RPC: {d:?}");
    assert_eq!(d.batched_updates, d.extensions, "{d:?}");
    assert!(
        out.scontrol_updates >= d.extensions,
        "landed updates at the ctld cover the daemon's extensions"
    );
    for j in &out.jobs {
        assert_eq!(j.adjustment, Some(Adjustment::Extended), "{}: {:?}", j.name, j.state);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
