//! No-op poll elision: the event-driven autonomy loop must be
//! **behaviorally invisible**.
//!
//! The control plane elides daemon polls it can prove are no-ops
//! (`SlurmConfig::poll_elision`, on by default). These tests run
//! identical workloads three ways — elision on, forced blind polling,
//! and the retained naive reference core — and assert bit-identical
//! job records, adjustments, `SlurmStats`, and `DaemonStats`
//! (wall-clock `engine_nanos` excluded, the only nondeterministic
//! field). Covered specifically:
//!
//! - random mixed workloads across all four policies, staggered
//!   arrivals and OverTimeLimit grace included;
//! - the rejected-action retry path (a control surface that rejects
//!   the first few actions: the daemon's `row_cache` holds a 0.0
//!   verdict, so every tick must re-run until the retry lands);
//! - a job whose reports go quiet mid-run (checkpoint plan exhausted:
//!   its next-visibility entry disappears, elision keeps going).

mod common;

use common::FlakyHook;
use tailtamer::daemon::{Autonomy, DaemonConfig, DaemonStats, Policy};
use tailtamer::policy::PolicySpec;
use tailtamer::proptest_lite::{Rng, run_prop_cases};
use tailtamer::prop_assert;
use tailtamer::simtime::Time;
use tailtamer::slurm::reference::NaiveSlurmd;
use tailtamer::slurm::{Adjustment, Job, JobSpec, JobState, SlurmConfig, SlurmStats, Slurmd};

/// `DaemonStats` with the wall-clock field zeroed, so runs compare
/// bit-identically on everything deterministic.
fn norm(s: DaemonStats) -> DaemonStats {
    s.deterministic()
}

struct SimRun {
    jobs: Vec<Job>,
    stats: SlurmStats,
    dstats: DaemonStats,
    polls_elided: u64,
}

fn run_optimized(
    specs: &[JobSpec],
    plans: &[Option<Vec<Time>>],
    cfg: &SlurmConfig,
    policy: impl Into<PolicySpec>,
    dcfg: &DaemonConfig,
    elide: bool,
) -> SimRun {
    let mut sim = Slurmd::new(SlurmConfig { poll_elision: elide, ..cfg.clone() });
    for (i, s) in specs.iter().enumerate() {
        sim.submit_with_plan(s.clone(), plans.get(i).cloned().flatten());
    }
    let mut daemon = Autonomy::native(policy, dcfg.clone());
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    let polls_elided = sim.polls_elided();
    SimRun { jobs: sim.into_jobs(), stats, dstats: norm(daemon.stats), polls_elided }
}

fn run_reference(
    specs: &[JobSpec],
    plans: &[Option<Vec<Time>>],
    cfg: &SlurmConfig,
    policy: impl Into<PolicySpec>,
    dcfg: &DaemonConfig,
) -> SimRun {
    let mut sim = NaiveSlurmd::new(cfg.clone());
    for (i, s) in specs.iter().enumerate() {
        sim.submit_with_plan(s.clone(), plans.get(i).cloned().flatten());
    }
    let mut daemon = Autonomy::native(policy, dcfg.clone());
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    SimRun { jobs: sim.into_jobs(), stats, dstats: norm(daemon.stats), polls_elided: 0 }
}

fn random_workload(rng: &mut Rng) -> (Vec<JobSpec>, SlurmConfig) {
    let n = rng.int_in(1, 40) as usize;
    let nodes_total = rng.int_in(2, 12) as u32;
    let mut specs = Vec::with_capacity(n);
    let mut t = 0;
    let staggered = rng.chance(0.5);
    for i in 0..n {
        let nodes = rng.int_in(1, nodes_total as i64) as u32;
        let limit = rng.int_in(60, 2000);
        let duration = if rng.chance(0.4) {
            limit + rng.int_in(1, 2000) // will time out
        } else {
            rng.int_in(30, limit.max(31))
        };
        let mut spec = JobSpec::new(&format!("e{i}"), limit, duration, nodes);
        if rng.chance(0.5) {
            spec.ckpt = Some(tailtamer::slurm::CkptSpec {
                interval: rng.int_in(40, 700),
                jitter_frac: if rng.chance(0.5) { rng.f64_in(0.0, 0.3) } else { 0.0 },
                seed: rng.next_u64(),
            });
        }
        if staggered {
            t += rng.int_in(0, 120);
            spec.submit = t;
        }
        specs.push(spec);
    }
    let cfg = SlurmConfig {
        nodes: nodes_total,
        backfill_interval: rng.int_in(10, 60),
        over_time_limit: if rng.chance(0.2) { rng.int_in(0, 120) } else { 0 },
        ..Default::default()
    };
    (specs, cfg)
}

fn assert_identical(tag: &str, a: &SimRun, b: &SimRun) -> Result<(), String> {
    prop_assert!(a.jobs == b.jobs, "{tag}: job records diverged");
    prop_assert!(a.stats == b.stats, "{tag}: SlurmStats diverged: {:?} vs {:?}", a.stats, b.stats);
    prop_assert!(
        a.dstats == b.dstats,
        "{tag}: DaemonStats diverged: {:?} vs {:?}",
        a.dstats,
        b.dstats
    );
    Ok(())
}

/// The whole policy family — legacy four plus the parameterized three
/// at varied parameters — so elision is proven behaviorally invisible
/// for every policy the daemon can run, not just the paper's.
fn random_policy_spec(rng: &mut Rng) -> PolicySpec {
    match rng.int_in(0, 6) {
        0 => PolicySpec::Baseline,
        1 => PolicySpec::EarlyCancel,
        2 => PolicySpec::Extend,
        3 => PolicySpec::Hybrid,
        4 => PolicySpec::ExtendBudget { budget: rng.int_in(60, 4000) },
        5 => PolicySpec::TailAware { frac: rng.f64_in(0.01, 2.0) },
        _ => PolicySpec::HybridBackoff { step: rng.int_in(1, 300) },
    }
}

#[test]
fn prop_elided_blind_and_naive_runs_are_bit_identical() {
    let mut total_elided = 0u64;
    run_prop_cases("elision_golden", 0xE11DE, 48, |rng| {
        let (specs, cfg) = random_workload(rng);
        let policy = random_policy_spec(rng);
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            safety: rng.f64_in(0.0, 1.0),
            ..Default::default()
        };
        let plans = vec![None; specs.len()];
        let elided = run_optimized(&specs, &plans, &cfg, policy.clone(), &dcfg, true);
        let blind = run_optimized(&specs, &plans, &cfg, policy.clone(), &dcfg, false);
        let naive = run_reference(&specs, &plans, &cfg, policy.clone(), &dcfg);
        prop_assert!(blind.polls_elided == 0, "blind mode must not elide");
        assert_identical(&format!("{} elided-vs-blind", policy.name()), &elided, &blind)?;
        assert_identical(&format!("{} elided-vs-naive", policy.name()), &elided, &naive)?;
        total_elided += elided.polls_elided;
        Ok(())
    });
    assert!(total_elided > 0, "elision never fired across 48 random workloads");
}

#[test]
fn elision_is_exact_on_the_paper_cohort() {
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    let plans = vec![None; specs.len()];
    for policy in Policy::ALL {
        let elided = run_optimized(&specs, &plans, &exp.slurm, policy, &exp.daemon, true);
        let blind = run_optimized(&specs, &plans, &exp.slurm, policy, &exp.daemon, false);
        assert_eq!(elided.jobs, blind.jobs, "{policy:?}: cohort job records diverged");
        assert_eq!(elided.stats, blind.stats, "{policy:?}: cohort SlurmStats diverged");
        assert_eq!(elided.dstats, blind.dstats, "{policy:?}: cohort DaemonStats diverged");
        if policy != Policy::Baseline {
            assert!(
                elided.polls_elided > 0,
                "{policy:?}: the 773-job cohort must elide some polls"
            );
        }
    }
    // The parameterized policies must be exactly as elision-safe on the
    // cohort as the legacy ones (their verdicts — budget exhaustion,
    // tail-aware Leave, backoff margins — are all input-pure).
    for spec in PolicySpec::parameterized_defaults() {
        let elided = run_optimized(&specs, &plans, &exp.slurm, spec.clone(), &exp.daemon, true);
        let blind = run_optimized(&specs, &plans, &exp.slurm, spec.clone(), &exp.daemon, false);
        assert_eq!(elided.jobs, blind.jobs, "{}: cohort job records diverged", spec.name());
        assert_eq!(elided.stats, blind.stats, "{}: cohort SlurmStats diverged", spec.name());
        assert_eq!(elided.dstats, blind.dstats, "{}: cohort DaemonStats diverged", spec.name());
        assert!(elided.polls_elided > 0, "{}: cohort must elide some polls", spec.name());
    }
}

// ---------------------------------------------------------------------
// Rejected-action retry path: a control surface that rejects the first
// K actions (common::FlakyHook, shared with the policy-layer and
// backfill-ondemand suites). The daemon's row cache keeps the 0.0
// verdict, every tick re-attempts (matching blind polling tick for
// tick), and elision resumes once the action finally lands.
// ---------------------------------------------------------------------


#[test]
fn rejected_actions_block_elision_until_retried() {
    let run = |elide: bool| {
        let mut sim = Slurmd::new(SlurmConfig {
            nodes: 4,
            poll_elision: elide,
            ..Default::default()
        });
        sim.submit(JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420));
        sim.submit(JobSpec::new("filler", 2400, 2400, 1));
        let mut hook =
            FlakyHook::new(Autonomy::native(Policy::EarlyCancel, DaemonConfig::default()), 3);
        sim.run(&mut hook);
        let stats = sim.stats.clone();
        let elided_polls = sim.polls_elided();
        (sim.into_jobs(), stats, norm(hook.inner.stats), hook.injected, elided_polls)
    };
    let (ejobs, estats, edstats, einjected, elided) = run(true);
    let (bjobs, bstats, bdstats, binjected, blind_elided) = run(false);
    assert_eq!(ejobs, bjobs, "job records diverged under injected rejections");
    assert_eq!(estats, bstats, "SlurmStats diverged under injected rejections");
    assert_eq!(edstats, bdstats, "DaemonStats diverged under injected rejections");
    assert_eq!(einjected, binjected, "both modes must attempt the same actions");
    assert_eq!(einjected, 3, "all injected rejections must be consumed");
    assert_eq!(edstats.scontrol_errors, 3, "each rejection is counted once: {edstats:?}");
    assert_eq!(blind_elided, 0);
    assert!(elided > 0, "elision must resume after the retry lands");
    // The cancel eventually lands: three rejected polls, then success.
    let ck = &ejobs[0];
    assert_eq!(ck.state, JobState::Cancelled);
    assert_eq!(ck.adjustment, Some(Adjustment::EarlyCancelled));
    let end = ck.end.unwrap();
    assert!(
        (1280..=1280 + 3 * 20).contains(&end),
        "cancel lands after 3 per-tick retries: end={end}"
    );
}

#[test]
fn rejected_extensions_are_retried_identically() {
    let run = |elide: bool| {
        let mut sim = Slurmd::new(SlurmConfig {
            nodes: 4,
            poll_elision: elide,
            ..Default::default()
        });
        sim.submit(JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420));
        let mut hook =
            FlakyHook::new(Autonomy::native(Policy::Extend, DaemonConfig::default()), 2);
        sim.run(&mut hook);
        let stats = sim.stats.clone();
        let elided_polls = sim.polls_elided();
        (sim.into_jobs(), stats, norm(hook.inner.stats), elided_polls)
    };
    let (ejobs, estats, edstats, elided) = run(true);
    let (bjobs, bstats, bdstats, _) = run(false);
    assert_eq!(ejobs, bjobs);
    assert_eq!(estats, bstats);
    assert_eq!(edstats, bdstats);
    assert_eq!(edstats.scontrol_errors, 2);
    assert_eq!(edstats.extensions, 1, "the extension lands on the third attempt");
    assert!(elided > 0);
    assert_eq!(ejobs[0].adjustment, Some(Adjustment::Extended));
}

// ---------------------------------------------------------------------
// Reports going quiet mid-run: the job's plan is exhausted long before
// it ends, so its next-visibility entry vanishes and the control plane
// keeps eliding — while the blind run keeps re-reading emptiness.
// ---------------------------------------------------------------------

#[test]
fn quiet_reporter_stays_bit_identical_and_elidable() {
    // Reports at 100/200/300, then silence; the job overruns and hits
    // its 2000 s limit. pred_next = 400 (+margin 30) fits 2000, so the
    // daemon leaves it alone and every later poll is provably a no-op.
    let specs = vec![JobSpec::new("quiet", 2000, 2500, 1)];
    let plans = vec![Some(vec![100, 200, 300])];
    let cfg = SlurmConfig { nodes: 2, ..Default::default() };
    let dcfg = DaemonConfig::default();
    for policy in [Policy::EarlyCancel, Policy::Extend, Policy::Hybrid] {
        let elided = run_optimized(&specs, &plans, &cfg, policy, &dcfg, true);
        let blind = run_optimized(&specs, &plans, &cfg, policy, &dcfg, false);
        let naive = run_reference(&specs, &plans, &cfg, policy, &dcfg);
        assert_eq!(elided.jobs, blind.jobs, "{policy:?}");
        assert_eq!(elided.stats, blind.stats, "{policy:?}");
        assert_eq!(elided.dstats, blind.dstats, "{policy:?}");
        assert_eq!(elided.jobs, naive.jobs, "{policy:?} vs naive");
        assert_eq!(elided.stats, naive.stats, "{policy:?} vs naive");
        assert_eq!(elided.dstats, naive.dstats, "{policy:?} vs naive");
        // ~100 polls over the run; after t=300 every one is a no-op.
        assert!(
            elided.polls_elided > 50,
            "{policy:?}: quiet stretch must be elided ({} elided)",
            elided.polls_elided
        );
        assert_eq!(elided.jobs[0].state, JobState::Timeout, "{policy:?}: untouched");
        assert!(elided.jobs[0].adjustment.is_none(), "{policy:?}: no adjustment");
    }
}
