//! Integration tests for the pluggable decision-policy layer
//! (`rust/src/policy/`): the row-gate saturation regression, the
//! parameterized policy family end to end on the paper cohort, and the
//! backoff policy's behavior under injected control failures.

mod common;

use common::FlakyHook;
use tailtamer::daemon::{Autonomy, DaemonConfig, DaemonStats, Policy};
use tailtamer::metrics::summarize;
use tailtamer::policy::PolicySpec;
use tailtamer::simtime::Time;
use tailtamer::slurm::{Adjustment, Job, JobSpec, JobState, SlurmConfig, Slurmd};

// ---------------------------------------------------------------------
// Row-gate saturation regression (the ROADMAP "Latent" item).
//
// The job reports a fitting checkpoint every 100 s against a 2000 s
// limit with a 4-entry history window: 19 fitting checkpoints, far more
// than the window. Under the fixed gate (keyed on the total-ingested
// cursor) the row keeps being re-evaluated after the window saturates,
// so the eventual ¬fits flip is seen and the job is cancelled. Under
// the retained legacy gate (keyed on the saturating window length,
// reachable only via Autonomy::legacy_reference +
// DaemonConfig::legacy_row_gate) the row freezes at its last "fits"
// verdict and the job silently times out — the seed's bug, preserved
// as executable documentation.
// ---------------------------------------------------------------------

fn saturating_spec() -> JobSpec {
    JobSpec::new("sat", 2000, 3000, 1).with_ckpt(100)
}

fn run_gate_scenario(mut daemon: Autonomy) -> (Job, DaemonStats) {
    let mut sim = Slurmd::new(SlurmConfig { nodes: 2, ..Default::default() });
    sim.submit(saturating_spec());
    sim.run(&mut daemon);
    (sim.into_jobs().remove(0), daemon.stats)
}

#[test]
fn saturated_history_job_is_still_cancelled() {
    let window = DaemonConfig { history_window: 4, ..Default::default() };
    // The pipeline driver (the default) sees the late ¬fits flip.
    let (job, stats) = run_gate_scenario(Autonomy::native(Policy::EarlyCancel, window.clone()));
    assert_eq!(job.state, JobState::Cancelled, "fixed gate must cancel");
    assert_eq!(job.adjustment, Some(Adjustment::EarlyCancelled));
    let end = job.end.unwrap();
    assert!(
        (1900..=1900 + 21).contains(&end),
        "cancel lands after the last fitting checkpoint: end={end}"
    );
    assert_eq!(stats.cancels, 1);

    // The legacy reference with the default (fixed) gate agrees.
    let (job, stats) =
        run_gate_scenario(Autonomy::legacy_reference(Policy::EarlyCancel, window.clone()));
    assert_eq!(job.state, JobState::Cancelled, "legacy driver shares the fixed gate");
    assert_eq!(stats.cancels, 1);

    // The buggy gate is reachable only from the legacy reference: the
    // row freezes once the window saturates and the job times out.
    let legacy = DaemonConfig { legacy_row_gate: true, ..window.clone() };
    let (job, stats) =
        run_gate_scenario(Autonomy::legacy_reference(Policy::EarlyCancel, legacy.clone()));
    assert_eq!(job.state, JobState::Timeout, "the seed's blind spot, preserved");
    assert!(job.adjustment.is_none());
    assert_eq!(stats.cancels, 0);

    // The pipeline driver ignores the reference-only knob.
    let (job, _) = run_gate_scenario(Autonomy::native(Policy::EarlyCancel, legacy));
    assert_eq!(job.state, JobState::Cancelled, "pipeline never uses the legacy gate");
}

#[test]
fn unsaturated_histories_are_gate_agnostic() {
    // With the window wider than the checkpoint count the two gates
    // are equivalent — the legacy mode reproduces the fixed results
    // bit for bit (the regression is *only* about saturation).
    let wide = DaemonConfig { history_window: 32, ..Default::default() };
    let legacy_wide = DaemonConfig { legacy_row_gate: true, ..wide.clone() };
    let (a, sa) = run_gate_scenario(Autonomy::legacy_reference(Policy::EarlyCancel, wide));
    let (b, sb) = run_gate_scenario(Autonomy::legacy_reference(Policy::EarlyCancel, legacy_wide));
    assert_eq!(a, b);
    assert_eq!(sa.deterministic(), sb.deterministic());
    assert_eq!(a.state, JobState::Cancelled);
}

// ---------------------------------------------------------------------
// The parameterized family on the exact 773-job paper cohort: the
// tail-aware threshold sweeps from "cancel everything EC would" down to
// "leave every tail alone" (baseline), and the extension budget bounds
// total granted seconds.
// ---------------------------------------------------------------------

#[test]
fn tail_aware_threshold_sweeps_between_ec_and_baseline_on_the_cohort() {
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    let run = |spec: PolicySpec| {
        let (jobs, stats, dstats) = tailtamer::daemon::run_scenario(
            &specs,
            exp.slurm.clone(),
            spec,
            exp.daemon.clone(),
            None,
        );
        (summarize("x", &jobs, &stats), dstats)
    };
    let (base, _) = run(PolicySpec::Baseline);
    let (ec, _) = run(PolicySpec::EarlyCancel);
    // Cohort geometry: every checkpointing job carries ~180 s of tail
    // against ~1260 s of checkpointed work (ratio ~0.143).
    let (strict, sd) = run(PolicySpec::TailAware { frac: 0.05 });
    assert_eq!(strict.early_cancelled, ec.early_cancelled, "strict threshold acts like EC");
    assert_eq!(strict.tail_waste, ec.tail_waste);
    let (lax, ld) = run(PolicySpec::TailAware { frac: 5.0 });
    assert_eq!(lax.tail_waste, base.tail_waste, "lax threshold accepts every tail");
    assert_eq!(lax.early_cancelled, 0);
    assert!(ld.policy_declines > 0, "declines are counted: {ld:?}");
    assert_eq!(sd.policy_declines, 0);
    // The boundary case: 0.143 sits between 0.1 and 0.25.
    let (mid, _) = run(PolicySpec::TailAware { frac: 0.25 });
    assert_eq!(mid.tail_waste, base.tail_waste, "0.25 tolerates the cohort's 0.143 tails");
}

#[test]
fn extension_budget_is_respected_on_the_cohort() {
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    let run = |budget: Time| {
        let (jobs, stats, dstats) = tailtamer::daemon::run_scenario(
            &specs,
            exp.slurm.clone(),
            PolicySpec::ExtendBudget { budget },
            exp.daemon.clone(),
            None,
        );
        (summarize("x", &jobs, &stats), dstats)
    };
    let (one, d_one) = run(500); // fits exactly one ~450 s extension
    let (many, d_many) = run(2_000); // several
    assert!(d_one.extensions >= 1);
    assert!(
        d_many.extensions > d_one.extensions,
        "a bigger budget buys more extensions: {} vs {}",
        d_many.extensions,
        d_one.extensions
    );
    assert!(
        many.total_checkpoints > one.total_checkpoints,
        "extra extensions buy extra checkpoints"
    );
    // Spend never exceeds (extended jobs) x budget.
    assert!(d_one.budget_spent <= one.extended as u64 * 500);
    assert!(d_many.budget_spent <= many.extended as u64 * 2_000);
}

// ---------------------------------------------------------------------
// hybrid-backoff under injected control failures (common::FlakyHook,
// shared with the poll-elision and backfill-ondemand suites): after a
// rejected extension the retried extension targets a wider margin, so
// the granted limit exceeds plain Hybrid's under the identical failure.
// ---------------------------------------------------------------------


#[test]
fn backoff_widens_the_retried_extension() {
    let run = |spec: PolicySpec, rejects: u32| {
        let mut sim = Slurmd::new(SlurmConfig { nodes: 4, ..Default::default() });
        sim.submit(JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420));
        let mut hook = FlakyHook::new(Autonomy::native(spec, DaemonConfig::default()), rejects);
        sim.run(&mut hook);
        (sim.into_jobs().remove(0), hook.inner.stats)
    };
    // Clean run: backoff is decision-identical to Hybrid (no extra).
    let (hy0, _) = run(PolicySpec::Hybrid, 0);
    let (bo0, _) = run(PolicySpec::HybridBackoff { step: 200 }, 0);
    assert_eq!(hy0, bo0, "no rejections -> no backoff");

    // One injected rejection: both eventually extend, but the backoff
    // retry targets pred_next + margin + step, so the granted limit is
    // wider by about one step.
    let (hy1, hs) = run(PolicySpec::Hybrid, 1);
    let (bo1, bs) = run(PolicySpec::HybridBackoff { step: 200 }, 1);
    assert_eq!(hy1.adjustment, Some(Adjustment::Extended));
    assert_eq!(bo1.adjustment, Some(Adjustment::Extended));
    assert_eq!(hs.scontrol_errors, 1);
    assert_eq!(bs.scontrol_errors, 1);
    assert!(
        bo1.cur_limit >= hy1.cur_limit + 150,
        "backoff widens the retried extension: {} vs {}",
        bo1.cur_limit,
        hy1.cur_limit
    );
}

// ---------------------------------------------------------------------
// Shipped TOML with a [policy] table drives the layer end to end.
// ---------------------------------------------------------------------

#[test]
fn tailaware_config_loads_and_runs() {
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("configs/tailaware.toml");
    let exp = tailtamer::config::Experiment::load(&path).expect("shipped config parses");
    assert_eq!(exp.policy, PolicySpec::TailAware { frac: 0.05 });
    let specs = exp.build_workload();
    let (jobs, stats, dstats) = tailtamer::daemon::run_scenario(
        &specs,
        exp.slurm.clone(),
        exp.policy.clone(),
        exp.daemon.clone(),
        None,
    );
    let s = summarize(&exp.policy.display(), &jobs, &stats);
    assert_eq!(s.total_jobs, 72);
    assert!(dstats.cancels > 0, "the strict threshold must act on the smoke cohort");
}
