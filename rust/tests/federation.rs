//! Federation golden equivalence (see `rust/src/slurm/fed.rs`).
//!
//! Four pinned identities, the guards for the whole sharded-simulation
//! layer:
//!
//! 1. **Merged ≡ Sharded**: the deterministic `(time, shard, seq)`
//!    step interleaving must be bit-identical to running each shard
//!    serially to completion — job records, `SlurmStats`, and
//!    deterministic `DaemonStats` — for shard counts {1, 2, 4, 7} on
//!    random workloads across the policy registry.
//! 2. **Parallel ≡ Merged ≡ Sharded**: the multi-threaded per-shard
//!    drive (`FedDrive::Parallel`) must be bit-identical to both
//!    serial drives, whatever the worker count — including S ≫ threads
//!    oversubscription, threads ≫ S over-provisioning, and fault
//!    injection inside the parallel run; a panicking shard must
//!    surface as an error (a propagated panic), never a deadlock or a
//!    partially recombined result.
//! 3. **1-shard federation ≡ the plain single-queue run**: partition,
//!    merge driver, and recombination must be the identity at S=1.
//! 4. **Retirement is invisible**: disabling dense-table retirement
//!    (`SlurmConfig::retirement = false`) must not change a single
//!    observable bit — it only changes resident memory, which the
//!    staggered-arrival test pins as sublinear in total ids.

mod common;

use std::panic::{AssertUnwindSafe, catch_unwind};
use std::sync::atomic::{AtomicU32, Ordering};

use common::FlakyHook;
use tailtamer::daemon::{Autonomy, DaemonConfig, run_scenario};
use tailtamer::policy::PolicySpec;
use tailtamer::prop_assert;
use tailtamer::proptest_lite::{Rng, run_prop_cases};
use tailtamer::slurm::fed::{self, FedDrive, FedOutcome, run_federation};
use tailtamer::slurm::{CkptSpec, JobSpec, SlurmConfig, Slurmd};
use tailtamer::workload::scaled::{Arrival, ScaledConfig};

/// One spec per registry policy, at its default parameters.
fn registry_policies() -> Vec<PolicySpec> {
    vec![
        PolicySpec::Baseline,
        PolicySpec::EarlyCancel,
        PolicySpec::Extend,
        PolicySpec::Hybrid,
        PolicySpec::ExtendBudget { budget: 1_200 },
        PolicySpec::TailAware { frac: 0.25 },
        PolicySpec::HybridBackoff { step: 60 },
    ]
}

/// Random mixed workload (mirrors `tests/properties.rs`): sized jobs,
/// over/under-estimated limits, some checkpointers, optional staggered
/// arrivals — the regime where cross-shard same-instant ties actually
/// occur.
fn random_workload(rng: &mut Rng, max_jobs: usize, max_nodes: u32) -> (Vec<JobSpec>, SlurmConfig) {
    let n = rng.int_in(1, max_jobs as i64) as usize;
    let nodes_total = rng.int_in(2, max_nodes as i64) as u32;
    let mut specs = Vec::with_capacity(n);
    let mut t = 0;
    let stagger = rng.chance(0.5);
    for i in 0..n {
        let nodes = rng.int_in(1, nodes_total as i64) as u32;
        let limit = rng.int_in(60, 2000);
        let duration = if rng.chance(0.3) {
            limit + rng.int_in(1, 2000)
        } else {
            rng.int_in(30, limit.max(31))
        };
        let mut spec = JobSpec::new(&format!("f{i}"), limit, duration, nodes);
        if rng.chance(0.4) {
            spec.ckpt = Some(CkptSpec {
                interval: rng.int_in(40, 700),
                jitter_frac: if rng.chance(0.5) { rng.f64_in(0.0, 0.3) } else { 0.0 },
                seed: rng.next_u64(),
            });
        }
        if stagger {
            t += rng.int_in(0, 120);
            spec.submit = t;
        }
        specs.push(spec);
    }
    let cfg = SlurmConfig {
        nodes: nodes_total,
        backfill_interval: rng.int_in(10, 60),
        over_time_limit: if rng.chance(0.2) { rng.int_in(0, 120) } else { 0 },
        ..Default::default()
    };
    (specs, cfg)
}

fn assert_outcomes_identical(a: &FedOutcome, b: &FedOutcome, what: &str) {
    assert_eq!(a.jobs, b.jobs, "{what}: job records diverged");
    assert_eq!(a.stats, b.stats, "{what}: SlurmStats diverged");
    assert_eq!(
        a.daemon_stats.deterministic(),
        b.daemon_stats.deterministic(),
        "{what}: deterministic DaemonStats diverged"
    );
}

#[test]
fn prop_merged_drive_matches_sharded_reference() {
    run_prop_cases("fed_merged_vs_sharded", 0xFED0, 24, |rng| {
        let (specs, cfg) = random_workload(rng, 40, 12);
        let policies = registry_policies();
        let policy = &policies[rng.int_in(0, policies.len() as i64 - 1) as usize];
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            safety: rng.f64_in(0.0, 1.0),
            ..Default::default()
        };
        for shards in [1usize, 2, 4, 7] {
            let merged = run_federation(&specs, shards, &cfg, policy, &dcfg, FedDrive::Merged);
            let sharded = run_federation(&specs, shards, &cfg, policy, &dcfg, FedDrive::Sharded);
            prop_assert!(
                merged.jobs == sharded.jobs,
                "{}/S={shards}: merged job records diverged from sharded",
                policy.name()
            );
            prop_assert!(
                merged.stats == sharded.stats,
                "{}/S={shards}: merged SlurmStats diverged",
                policy.name()
            );
            prop_assert!(
                merged.daemon_stats.deterministic() == sharded.daemon_stats.deterministic(),
                "{}/S={shards}: merged DaemonStats diverged",
                policy.name()
            );
            // The parallel drive joins the identity, on a worker count
            // that cycles under/at/over the shard count across cases.
            let threads = 1 + shards % 3;
            let parallel =
                run_federation(&specs, shards, &cfg, policy, &dcfg, FedDrive::Parallel { threads });
            prop_assert!(
                parallel.jobs == merged.jobs,
                "{}/S={shards}/T={threads}: parallel job records diverged",
                policy.name()
            );
            prop_assert!(
                parallel.stats == merged.stats,
                "{}/S={shards}/T={threads}: parallel SlurmStats diverged",
                policy.name()
            );
            prop_assert!(
                parallel.daemon_stats.deterministic() == merged.daemon_stats.deterministic(),
                "{}/S={shards}/T={threads}: parallel DaemonStats diverged",
                policy.name()
            );
            // Master id order survives recombination.
            for (m, j) in merged.jobs.iter().enumerate() {
                prop_assert!(j.id.0 as usize == m, "S={shards}: id {m} rewritten wrong");
            }
        }
        // The 1-shard federation is the plain single-queue run.
        let one = run_federation(&specs, 1, &cfg, policy, &dcfg, FedDrive::Merged);
        let (jobs, stats, dstats) =
            run_scenario(&specs, cfg.clone(), policy.clone(), dcfg.clone(), None);
        prop_assert!(one.jobs == jobs, "{}: S=1 jobs != single-queue", policy.name());
        prop_assert!(one.stats == stats, "{}: S=1 stats != single-queue", policy.name());
        prop_assert!(
            one.daemon_stats.deterministic() == dstats.deterministic(),
            "{}: S=1 daemon stats != single-queue",
            policy.name()
        );
        Ok(())
    });
}

#[test]
fn federation_identities_hold_on_the_paper_cohort() {
    // The exact 773-job workload the headline numbers come from, every
    // registry policy: Merged ≡ Sharded at S ∈ {2, 4}, and the 1-shard
    // federation ≡ the plain run.
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    for policy in registry_policies() {
        for shards in [2usize, 4] {
            let merged =
                run_federation(&specs, shards, &exp.slurm, &policy, &exp.daemon, FedDrive::Merged);
            let sharded =
                run_federation(&specs, shards, &exp.slurm, &policy, &exp.daemon, FedDrive::Sharded);
            assert_outcomes_identical(
                &merged,
                &sharded,
                &format!("cohort {}/S={shards}", policy.name()),
            );
            let parallel = run_federation(
                &specs,
                shards,
                &exp.slurm,
                &policy,
                &exp.daemon,
                FedDrive::Parallel { threads: 3 },
            );
            assert_outcomes_identical(
                &parallel,
                &merged,
                &format!("cohort parallel {}/S={shards}", policy.name()),
            );
            assert_eq!(merged.jobs.len(), specs.len());
        }
        let one = run_federation(&specs, 1, &exp.slurm, &policy, &exp.daemon, FedDrive::Merged);
        let (jobs, stats, dstats) =
            run_scenario(&specs, exp.slurm.clone(), policy.clone(), exp.daemon.clone(), None);
        assert_eq!(one.jobs, jobs, "cohort {}: S=1 != single-queue", policy.name());
        assert_eq!(one.stats, stats, "cohort {}: S=1 stats", policy.name());
        assert_eq!(
            one.daemon_stats.deterministic(),
            dstats.deterministic(),
            "cohort {}: S=1 daemon stats",
            policy.name()
        );
    }
}

#[test]
fn more_shards_than_jobs_leaves_empty_shards_harmless() {
    // 3 jobs over 7 shards: four shards simulate nothing and must still
    // start, drain, and recombine cleanly.
    let specs: Vec<JobSpec> =
        (0..3).map(|i| JobSpec::new(&format!("e{i}"), 600, 300, 1)).collect();
    let cfg = SlurmConfig { nodes: 4, ..Default::default() };
    let dcfg = DaemonConfig::default();
    let policy = PolicySpec::Hybrid;
    let merged = run_federation(&specs, 7, &cfg, &policy, &dcfg, FedDrive::Merged);
    let sharded = run_federation(&specs, 7, &cfg, &policy, &dcfg, FedDrive::Sharded);
    assert_outcomes_identical(&merged, &sharded, "empty shards");
    assert_eq!(merged.jobs.len(), 3);
    assert!(merged.jobs.iter().all(|j| j.state.is_terminal()));
}

#[test]
fn retirement_is_observably_invisible_and_bounds_memory() {
    // An *undersaturated* staggered stream (small base-size requests on
    // a 64-node pool, arrivals slower than the drain rate) keeps the
    // live id window narrow, so the terminal prefix retires
    // continuously; turning retirement off must not change one
    // observable bit, only the resident footprint.
    let wl = ScaledConfig {
        jobs: 2_000,
        nodes: 64,
        arrival: Arrival::Staggered { mean_gap: 60 },
        rescale_nodes: false,
        ..Default::default()
    };
    let specs = wl.build();
    let on = SlurmConfig { nodes: 64, ..Default::default() };
    let off = SlurmConfig { nodes: 64, retirement: false, ..Default::default() };
    let dcfg = DaemonConfig::default();
    let policy = PolicySpec::EarlyCancel;
    for shards in [1usize, 4] {
        let with = run_federation(&specs, shards, &on, &policy, &dcfg, FedDrive::Merged);
        let without = run_federation(&specs, shards, &off, &policy, &dcfg, FedDrive::Merged);
        assert_outcomes_identical(
            &with,
            &without,
            &format!("retirement on/off, S={shards}"),
        );
        assert!(with.retired > 0, "S={shards}: retirement never engaged");
        assert_eq!(without.retired, 0, "S={shards}: disabled retirement retired ids");
        // Sublinear resident memory: well under the never-retired
        // footprint (total ids x per-id table bytes).
        let full = specs.len() * fed::unretired_bytes_per_id();
        assert!(
            with.peak_table_bytes < full / 2,
            "S={shards}: peak {} not sublinear vs full {}",
            with.peak_table_bytes,
            full
        );
        assert!(
            with.peak_table_bytes <= without.peak_table_bytes,
            "S={shards}: retirement increased the peak"
        );
    }
}

#[test]
fn parallel_drive_survives_shard_oversubscription() {
    // 23 shards on 4 workers (S ≫ cores: the AIMD claim queue has to
    // batch) and on 64 workers (threads ≫ S: the clamp has to bite) —
    // both bit-identical to the serial sharded reference.
    let wl = ScaledConfig {
        jobs: 600,
        nodes: 48,
        seed: 23,
        arrival: Arrival::Staggered { mean_gap: 15 },
        rescale_nodes: false,
        ..Default::default()
    };
    let specs = wl.build();
    let cfg = SlurmConfig { nodes: 48, ..Default::default() };
    let dcfg = DaemonConfig::default();
    let policy = PolicySpec::Hybrid;
    let sharded = run_federation(&specs, 23, &cfg, &policy, &dcfg, FedDrive::Sharded);
    for threads in [4usize, 64] {
        let parallel =
            run_federation(&specs, 23, &cfg, &policy, &dcfg, FedDrive::Parallel { threads });
        assert_outcomes_identical(
            &parallel,
            &sharded,
            &format!("oversubscription S=23/T={threads}"),
        );
        assert_eq!(parallel.peak_table_bytes, sharded.peak_table_bytes);
        assert_eq!(parallel.retired, sharded.retired);
    }
}

#[test]
fn flaky_ctl_injection_inside_a_parallel_drive_is_thread_count_invariant() {
    // Fault injection inside a genuinely parallel run: every shard's
    // daemon is wrapped in FlakyHook (first 2 control actions per
    // shard rejected), driven through drive_shards_parallel on 1 and
    // then 4 workers. The per-shard rejection budget is deterministic,
    // so both drives must recombine bit-identically — the retry path
    // is exercised *inside* worker threads, not around them.
    let specs: Vec<JobSpec> = (0..120)
        .map(|i| {
            // Checkpointing jobs that outlive their limits: EarlyCancel
            // acts (scancel), so the flaky gate has actions to reject.
            let mut s = JobSpec::new(&format!("fl{i}"), 900, 1_500 + (i as i64 % 5) * 200, 1);
            s.ckpt = Some(CkptSpec { interval: 240, jitter_frac: 0.0, seed: i as u64 });
            s
        })
        .collect();
    let cfg = SlurmConfig { nodes: 12, ..Default::default() };
    let dcfg = DaemonConfig::default();
    let policy = PolicySpec::EarlyCancel;
    let parts = fed::partition(&specs, 4);
    let injected = AtomicU32::new(0);
    let run = |k: usize| {
        let mut sim = Slurmd::new(cfg.clone());
        for s in &parts[k] {
            sim.submit(s.clone());
        }
        let daemon = Autonomy::native(policy.clone(), dcfg.clone());
        let mut hook = FlakyHook::new(daemon, 2);
        sim.run(&mut hook);
        injected.fetch_add(hook.injected, Ordering::Relaxed);
        let stats = sim.stats.clone();
        let peak = sim.peak_table_bytes() + hook.inner.peak_table_bytes();
        let retired = sim.jobs_retired();
        fed::ShardRun {
            jobs: sim.into_jobs(),
            stats,
            daemon_stats: hook.inner.stats,
            peak_table_bytes: peak,
            retired,
            drive_nanos: 0,
        }
    };
    let serial = fed::recombine(fed::drive_shards_parallel(4, 1, &run));
    let parallel = fed::recombine(fed::drive_shards_parallel(4, 4, &run));
    assert_outcomes_identical(&parallel, &serial, "flaky parallel drive");
    assert_eq!(parallel.peak_table_bytes, serial.peak_table_bytes);
    assert!(
        injected.load(Ordering::Relaxed) > 0,
        "the flaky gate never fired — the test exercised nothing"
    );
    assert!(
        serial.daemon_stats.scontrol_errors > 0,
        "rejections must be visible in the daemon's deterministic stats"
    );
}

#[test]
fn panicking_shard_surfaces_as_error_without_deadlock() {
    // A worker panic must propagate out of drive_shards_parallel (via
    // the thread scope) — the caller gets an unwind, never a hang and
    // never a partially recombined federation.
    let specs: Vec<JobSpec> =
        (0..8).map(|i| JobSpec::new(&format!("p{i}"), 600, 300, 1)).collect();
    let cfg = SlurmConfig { nodes: 4, ..Default::default() };
    let dcfg = DaemonConfig::default();
    let policy = PolicySpec::Hybrid;
    let parts = fed::partition(&specs, 4);
    let result = catch_unwind(AssertUnwindSafe(|| {
        fed::drive_shards_parallel(4, 2, |k| {
            if k == 2 {
                panic!("injected shard failure");
            }
            fed::run_shard(&parts[k], &cfg, &policy, &dcfg)
        })
    }));
    assert!(result.is_err(), "a panicking shard must fail the whole drive");
}
