//! Failure injection: the daemon against misbehaving applications and
//! control-surface races (the paper's Limitations section, §6, made
//! executable).
//!
//! A mock `SlurmControl` wraps the real simulator state but corrupts
//! what the daemon *observes* — duplicated, reordered, truncated, or
//! stuck checkpoint reports; rejected control actions come from the
//! shared [`common::FlakyCtl`] proxy layered on top.

mod common;

use tailtamer::daemon::{Autonomy, DaemonConfig, Policy};
use tailtamer::simtime::Time;
use tailtamer::slurm::{
    Adjustment, JobId, JobSpec, QueueSnapshot, SlurmControl,
};

/// A single-running-job mock whose reports the test scripts directly.
struct MockCtl {
    now: Time,
    cur_limit: Time,
    start: Time,
    nodes: u32,
    reports: Vec<Time>,
    cancelled_at: Option<Time>,
    updates: Vec<Time>,
    adjustment: Option<Adjustment>,
}

impl MockCtl {
    fn new(limit: Time) -> Self {
        Self {
            now: 0,
            cur_limit: limit,
            start: 0,
            nodes: 1,
            reports: Vec::new(),
            cancelled_at: None,
            updates: Vec::new(),
            adjustment: None,
        }
    }

    fn running(&self) -> bool {
        self.cancelled_at.is_none() && self.now < self.start + self.cur_limit
    }
}

impl SlurmControl for MockCtl {
    fn control_now(&self) -> Time {
        self.now
    }

    fn squeue(&self) -> QueueSnapshot {
        let running = if self.running() {
            vec![tailtamer::slurm::RunningInfo {
                id: JobId(0),
                name: "mock-1".into(),
                nodes: self.nodes,
                start: self.start,
                cur_limit: self.cur_limit,
                expected_end: self.start + self.cur_limit,
            }]
        } else {
            vec![]
        };
        QueueSnapshot { now: self.now, running, pending: vec![] }
    }

    fn read_ckpt_reports(&self, _id: JobId) -> Vec<Time> {
        self.reports.clone()
    }

    fn scontrol_update_limit(&mut self, _id: JobId, new_limit: Time) -> Result<(), String> {
        self.cur_limit = new_limit;
        self.updates.push(new_limit);
        Ok(())
    }

    fn scancel(&mut self, _id: JobId) -> Result<(), String> {
        self.cancelled_at = Some(self.now);
        Ok(())
    }

    fn mark_adjustment(&mut self, _id: JobId, adj: Adjustment) {
        self.adjustment = Some(adj);
    }
}

fn drive(daemon: &mut Autonomy, ctl: &mut MockCtl, script: &[(Time, &[Time])]) {
    // script: at poll time T, the report file contains exactly these
    // timestamps (the mock replaces wholesale — duplication/reordering
    // is up to the script).
    for &(t, reports) in script {
        ctl.now = t;
        ctl.reports = reports.to_vec();
        if ctl.running() {
            daemon.tick(t, ctl);
        }
    }
}

#[test]
fn duplicate_and_reordered_reports_are_tolerated() {
    let mut d = Autonomy::native(Policy::EarlyCancel, DaemonConfig::default());
    let mut ctl = MockCtl::new(1440);
    drive(
        &mut d,
        &mut ctl,
        &[
            (430, &[420, 420]),                    // duplicated line
            (850, &[840, 420, 840]),               // reordered + duplicated
            (860, &[420, 840]),                    // re-read, fits (1260+30 <= 1440)
            (1270, &[420, 840, 1260, 1260, 420]),  // full garbage mix
        ],
    );
    // Despite the noise, the estimate is 420 and the cancel lands after
    // the last fitting checkpoint.
    assert_eq!(ctl.cancelled_at, Some(1270));
    assert_eq!(ctl.adjustment, Some(Adjustment::EarlyCancelled));
}

#[test]
fn stuck_application_gets_no_extension() {
    // The application reports twice and then hangs. pred_next passes
    // without a new checkpoint; since pred_next+margin stays below the
    // limit (fits), the daemon must NOT extend a stuck job — it times
    // out at its original limit (the paper's "stuck jobs must not get
    // extra time" motivation for progress-aware adjustment).
    let mut d = Autonomy::native(Policy::Extend, DaemonConfig::default());
    let mut ctl = MockCtl::new(1440);
    let script: Vec<(Time, &[Time])> = (1..=70).map(|k| (k * 20, [420i64, 840].as_slice())).collect();
    drive(&mut d, &mut ctl, &script);
    assert!(ctl.updates.is_empty(), "stuck job must not be extended: {:?}", ctl.updates);
    assert_eq!(ctl.cancelled_at, None, "extend policy never cancels unextended jobs");
}

#[test]
fn one_checkpoint_is_never_enough() {
    for policy in [Policy::EarlyCancel, Policy::Extend, Policy::Hybrid] {
        let mut d = Autonomy::native(policy, DaemonConfig::default());
        let mut ctl = MockCtl::new(500);
        // A single checkpoint close to the limit: no interval estimate,
        // no action, whatever the policy.
        let script: Vec<(Time, &[Time])> = (1..=24).map(|k| (k * 20, [480i64].as_slice())).collect();
        drive(&mut d, &mut ctl, &script);
        assert_eq!(ctl.cancelled_at, None, "{policy:?} acted on 1 checkpoint");
        assert!(ctl.updates.is_empty(), "{policy:?} extended on 1 checkpoint");
    }
}

#[test]
fn rejected_control_actions_do_not_wedge_the_daemon() {
    // Rejections come from the shared FlakyCtl proxy (the same layer
    // the three-way golden suites and the live harness use), not a
    // bespoke mock flag.
    let mut d = Autonomy::native(Policy::EarlyCancel, DaemonConfig::default());
    let mut ctl = MockCtl::new(1440);
    let (mut rejects_left, mut injected) = (u32::MAX, 0);
    for &(t, reports) in &[
        (430, [420].as_slice()),
        (850, [420, 840].as_slice()),
        (1270, [420, 840, 1260].as_slice()),
        (1290, [420, 840, 1260].as_slice()),
    ] {
        ctl.now = t;
        ctl.reports = reports.to_vec();
        if ctl.running() {
            let mut proxy = common::FlakyCtl {
                inner: &mut ctl,
                rejects_left: &mut rejects_left,
                injected: &mut injected,
                latency_ms: 0,
            };
            d.tick(t, &mut proxy);
        }
    }
    assert_eq!(ctl.cancelled_at, None);
    assert!(injected >= 2, "proxy must have served rejections: {injected}");
    assert!(d.stats.scontrol_errors >= 2, "errors must be counted: {:?}", d.stats);
    // Permission restored: the next poll succeeds (no proxy).
    ctl.now = 1310;
    d.tick(1310, &mut ctl);
    assert_eq!(ctl.cancelled_at, Some(1310), "daemon must retry after errors");
}

#[test]
fn reports_from_the_future_do_not_crash_prediction() {
    // A broken clock reports a timestamp beyond the limit; the daemon
    // should simply see ¬fits and cancel (EarlyCancel) without panicking.
    let mut d = Autonomy::native(Policy::EarlyCancel, DaemonConfig::default());
    let mut ctl = MockCtl::new(1440);
    drive(&mut d, &mut ctl, &[(430, &[420]), (850, &[420, 9999])]);
    // interval estimate 9579 -> next at 19578: cancel right away.
    assert_eq!(ctl.cancelled_at, Some(850));
}

#[test]
fn shrinking_report_file_is_ignored_not_replayed() {
    // A truncated (rotated) report file must not roll the history back.
    let mut d = Autonomy::native(Policy::EarlyCancel, DaemonConfig::default());
    let mut ctl = MockCtl::new(1440);
    drive(
        &mut d,
        &mut ctl,
        &[
            (430, &[420]),
            (850, &[420, 840]),
            (870, &[]),        // file rotated away
            (890, &[420]),     // partially restored
            (1270, &[420, 840, 1260]),
        ],
    );
    assert_eq!(ctl.cancelled_at, Some(1270), "history must survive truncation");
}

#[test]
fn completion_hazard_is_real_and_documented() {
    // Executable documentation of the daemon's "completion hazard" (see
    // daemon module docs): a reporting job that would COMPLETE at 550
    // inside its 600 s limit, with checkpoints every 200 s (at 200 and
    // 400; the next, 600+margin, does not fit), is early cancelled at
    // ~400 because the daemon cannot see durations.
    use tailtamer::daemon::run_scenario;
    use tailtamer::slurm::{JobState, SlurmConfig};
    let specs = vec![JobSpec::new("completing-ck", 600, 550, 1).with_ckpt(200)];
    let (jobs, _, _) = run_scenario(
        &specs,
        SlurmConfig { nodes: 2, ..Default::default() },
        Policy::EarlyCancel,
        DaemonConfig::default(),
        None,
    );
    assert_eq!(jobs[0].state, JobState::Cancelled, "the hazard fires");
    assert!(jobs[0].end.unwrap() < 550, "cancelled before it would have completed");
    // Extend leaves the job to complete (the extension fits the next
    // checkpoint, which never happens because the job ends first).
    let (jobs, _, _) = run_scenario(
        &specs,
        SlurmConfig { nodes: 2, ..Default::default() },
        Policy::Extend,
        DaemonConfig::default(),
        None,
    );
    assert_eq!(jobs[0].state, JobState::Completed, "Extend avoids the hazard here");
}

#[test]
fn daemon_survives_job_vanishing_between_snapshot_and_action() {
    // Covered end-to-end: under Extend, the mock's job can be set
    // non-running right before the acting tick; extend_to re-snapshots
    // and reports an error instead of panicking.
    let mut d = Autonomy::native(Policy::Extend, DaemonConfig::default());
    let mut ctl = MockCtl::new(1440);
    drive(&mut d, &mut ctl, &[(430, &[420]), (850, &[420, 840])]);
    // Job hits ¬fits exactly when it stops running.
    ctl.now = 1441; // past the limit -> squeue shows nothing running
    ctl.reports = vec![420, 840, 1260];
    d.tick(1441, &mut ctl);
    assert!(ctl.updates.is_empty());
    assert_eq!(ctl.cancelled_at, None);
}
