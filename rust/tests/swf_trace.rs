//! SWF ingestion golden tests over the bundled archive excerpt
//! (`tests/fixtures/sample.swf`): every parsed record is pinned by
//! hand, as are the malformed-row count and the downstream job-spec
//! anchor the CI smoke run diffs (`trace-summary:` fields).

use std::path::Path;

use tailtamer::workload::swf::{SwfTrace, load_swf, read_swf};
use tailtamer::workload::trace::{TraceRecord, TraceState};
use tailtamer::workload::{WorkloadSpec, scale, to_job_specs};

fn fixture() -> SwfTrace {
    load_swf(Path::new("tests/fixtures/sample.swf")).expect("bundled fixture loads")
}

/// Hand-construct the expected record for one fixture row.
#[allow(clippy::too_many_arguments)]
fn rec(
    submit: i64,
    partition: u32,
    queue: u32,
    nodes: u32,
    cores: u32,
    time_limit: i64,
    run_time: i64,
    state: TraceState,
) -> TraceRecord {
    TraceRecord { submit, partition, queue, nodes, cores, time_limit, run_time, state, exclusive: true }
}

#[test]
fn fixture_parses_twelve_records_and_counts_two_malformed() {
    let t = fixture();
    assert_eq!(t.records.len(), 12, "{:?}", t.records);
    // Row 13 is truncated to 17 fields, row 14 has a non-numeric
    // runtime: both skipped, both counted, nothing else rejected.
    assert_eq!(t.malformed, 2);
}

#[test]
fn fixture_records_match_the_hand_computed_mapping() {
    let t = fixture();
    use TraceState::{Completed, Timeout};
    let want = vec![
        // Ran out its 24 h request on 96 cores (2 nodes).
        rec(0, 1, 1, 2, 96, 86400, 86400, Timeout),
        rec(60, 1, 1, 1, 48, 14400, 7200, Completed),
        rec(120, 1, 1, 3, 144, 86400, 43200, Completed),
        // Allocated procs unknown -> requested procs (48).
        rec(180, 1, 1, 1, 48, 86400, 86400, Timeout),
        // Requested procs unknown -> allocated procs (96).
        rec(240, 1, 1, 2, 96, 7200, 3600, Completed),
        // Runtime unknown -> requested time, which makes it a timeout.
        rec(300, 1, 1, 1, 48, 21600, 21600, Timeout),
        // Real-valued avg-CPU field is unused and must not reject.
        rec(360, 1, 1, 5, 240, 43200, 10800, Completed),
        // Requested time unknown -> limit defaults to 2 x runtime.
        rec(420, 1, 2, 1, 48, 10800, 5400, Completed),
        rec(480, 1, 1, 10, 480, 86400, 86400, Timeout),
        // Unknown submit clamps to the epoch.
        rec(0, 1, 1, 1, 48, 3600, 1800, Completed),
        rec(600, 2, 1, 2, 96, 86400, 64800, Completed),
        // Both processor fields unknown -> 1-core serial job.
        rec(660, 1, 1, 1, 1, 14400, 14400, Timeout),
    ];
    assert_eq!(t.records, want);
}

#[test]
fn fixture_feeds_the_standard_scale_and_adapt_pipeline() {
    // The exact pipeline `simulate --trace sample.swf` runs with the
    // default 60x scale: these four numbers ARE the `trace-summary:`
    // line CI smokes (jobs=12 malformed=2 ckpt_jobs=3
    // total_duration=12120).
    let t = fixture();
    let scaled = scale(&t.records, 60);
    let specs = to_job_specs(&scaled, &WorkloadSpec::default());
    assert_eq!(specs.len(), 12);
    // The three 24 h-cap timeouts (rows 1, 4, 9) become checkpointing
    // jobs; the sub-cap timeouts (rows 6, 12) stay opaque.
    assert_eq!(specs.iter().filter(|s| s.ckpt.is_some()).count(), 3);
    let total: i64 = specs.iter().map(|s| s.duration).sum();
    assert_eq!(total, 12_120);
    // Spot-check the scaled shapes: a cap timeout doubles its 1440 s
    // scaled limit; a completed job keeps its scaled runtime.
    assert_eq!((specs[0].time_limit, specs[0].duration), (1440, 2880));
    assert_eq!((specs[1].time_limit, specs[1].duration), (240, 120));
    // Everything is released at t=0 in original submit order.
    assert!(specs.iter().all(|s| s.submit == 0));
}

#[test]
fn reading_via_path_and_via_stream_agree() {
    let bytes = std::fs::read("tests/fixtures/sample.swf").unwrap();
    let via_stream = read_swf(std::io::Cursor::new(bytes)).unwrap();
    assert_eq!(via_stream, fixture());
}
