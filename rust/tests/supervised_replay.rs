//! Kill-9-under-supervisor: a daemon run under [`Supervised`] — killed
//! mid-run (including *inside* the journal-rotation window) and
//! rebuilt from its journal chain by the supervisor — must be
//! **bit-identical** (job records, `SlurmStats`, deterministic
//! `DaemonStats`) to an uninterrupted, unjournaled run.
//!
//! This is the PR 6 crash-kill-replay pin re-proved through the
//! supervision layer, with the new rotation machinery underneath:
//! random workloads × random registry policies × random kill points
//! (clean and mid-rotation), plus the 773-job paper cohort for every
//! registry policy with rotation enabled — where the journal chain is
//! also asserted *bounded*: live rotated segments never exceed the
//! keep limit even though the run writes many times the rotation
//! threshold.

use std::path::{Path, PathBuf};

use tailtamer::daemon::{
    Autonomy, DaemonConfig, DaemonStats, KillKind, Supervised, SupervisorStats,
};
use tailtamer::policy::PolicySpec;
use tailtamer::prop_assert;
use tailtamer::proptest_lite::{Rng, run_prop_cases};
use tailtamer::slurm::{Job, JobSpec, SlurmConfig, SlurmStats, Slurmd};

fn tmp_path(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("tt_supervised_{}_{tag}.log", std::process::id()))
}

fn cleanup(path: &Path) {
    let _ = std::fs::remove_file(path);
    for (_, seg) in tailtamer::journal::live_segments(path) {
        let _ = std::fs::remove_file(seg);
    }
}

fn run_plain(
    specs: &[JobSpec],
    cfg: &SlurmConfig,
    policy: PolicySpec,
    dcfg: &DaemonConfig,
) -> (Vec<Job>, SlurmStats, DaemonStats) {
    let mut sim = Slurmd::new(cfg.clone());
    for s in specs {
        sim.submit(s.clone());
    }
    let mut daemon = Autonomy::native(policy, dcfg.clone());
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    (sim.into_jobs(), stats, daemon.stats.deterministic())
}

#[allow(clippy::too_many_arguments)]
fn run_supervised(
    specs: &[JobSpec],
    cfg: &SlurmConfig,
    policy: PolicySpec,
    dcfg: &DaemonConfig,
    path: &Path,
    kills: &[(u64, KillKind)],
    snap_every: u64,
) -> (Vec<Job>, SlurmStats, DaemonStats, SupervisorStats, usize) {
    cleanup(path);
    let mut sim = Slurmd::new(cfg.clone());
    for s in specs {
        sim.submit(s.clone());
    }
    let jcfg = DaemonConfig { journal_path: Some(path.display().to_string()), ..dcfg.clone() };
    let daemon = Autonomy::native(policy, jcfg);
    let mut sup = Supervised::new(daemon, path, snap_every);
    for &(p, k) in kills {
        sup = sup.kill_at(p, k);
    }
    sim.run(&mut sup);
    let stats = sim.stats.clone();
    let kills_done = sup.kills_done();
    let (dstats, sstats) = sup.into_stats();
    (sim.into_jobs(), stats, dstats, sstats, kills_done)
}

fn random_workload(rng: &mut Rng) -> (Vec<JobSpec>, SlurmConfig) {
    let n = rng.int_in(1, 30) as usize;
    let nodes_total = rng.int_in(2, 10) as u32;
    let mut specs = Vec::with_capacity(n);
    let mut t = 0;
    for i in 0..n {
        let nodes = rng.int_in(1, nodes_total as i64) as u32;
        let limit = rng.int_in(60, 2000);
        let duration =
            if rng.chance(0.4) { limit + rng.int_in(1, 2000) } else { rng.int_in(30, limit.max(31)) };
        let mut spec = JobSpec::new(&format!("j{i}"), limit, duration, nodes);
        if rng.chance(0.6) {
            spec = spec.with_ckpt(rng.int_in(40, 700));
        }
        if rng.chance(0.5) {
            t += rng.int_in(0, 90);
            spec.submit = t;
        }
        specs.push(spec);
    }
    (specs, SlurmConfig { nodes: nodes_total, ..Default::default() })
}

fn random_policy_spec(rng: &mut Rng) -> PolicySpec {
    match rng.int_in(0, 6) {
        0 => PolicySpec::Baseline,
        1 => PolicySpec::EarlyCancel,
        2 => PolicySpec::Extend,
        3 => PolicySpec::Hybrid,
        4 => PolicySpec::ExtendBudget { budget: rng.int_in(60, 4000) },
        5 => PolicySpec::TailAware { frac: rng.f64_in(0.01, 2.0) },
        _ => PolicySpec::HybridBackoff { step: rng.int_in(1, 300) },
    }
}

#[test]
fn prop_supervised_kill_and_restart_is_bit_identical() {
    let mut total_kills = 0usize;
    let path = tmp_path("prop");
    run_prop_cases("supervised_kill_restart", 0x5C4B0, 20, |rng| {
        let (specs, cfg) = random_workload(rng);
        let policy = random_policy_spec(rng);
        // Rotation on for most cases (tiny threshold so short runs
        // rotate for real), off for some — both must be invisible.
        let rotate = if rng.chance(0.75) { rng.int_in(256, 2048) as u64 } else { 0 };
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            use_priors: rng.chance(0.3),
            batch_actions: rng.chance(0.3),
            rpc_concurrency: if rng.chance(0.3) { 4 } else { 1 },
            journal_rotate_bytes: rotate,
            journal_keep_segments: rng.int_in(1, 4) as u32,
            ..Default::default()
        };
        let snap_every = rng.int_in(1, 6) as u64;
        let mut kills = vec![(
            rng.int_in(2, 40) as u64,
            if rng.chance(0.5) { KillKind::MidRotation } else { KillKind::Clean },
        )];
        if rng.chance(0.4) {
            kills.push((rng.int_in(2, 80) as u64, KillKind::Clean));
        }
        kills.sort_unstable_by_key(|&(p, _)| p);
        let tag = policy.name();
        let (jobs, stats, dstats) = run_plain(&specs, &cfg, policy.clone(), &dcfg);
        let (kj, ks, kd, sstats, done) =
            run_supervised(&specs, &cfg, policy.clone(), &dcfg, &path, &kills, snap_every);
        prop_assert!(jobs == kj, "{tag}: job records diverged under supervision");
        prop_assert!(stats == ks, "{tag}: SlurmStats diverged under supervision");
        prop_assert!(
            dstats == kd,
            "{tag}: DaemonStats diverged under supervision: {dstats:?} vs {kd:?}"
        );
        prop_assert!(
            sstats.restarts as usize == done,
            "{tag}: every kill must be one accounted restart"
        );
        total_kills += done;
        Ok(())
    });
    cleanup(&path);
    assert!(total_kills > 0, "no kill ever fired across 20 random workloads");
}

#[test]
fn cohort_supervised_restart_is_exact_and_disk_stays_bounded() {
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    let path = tmp_path("cohort");
    const ROTATE: u64 = 4_096;
    const KEEP: u32 = 2;
    let dcfg = DaemonConfig {
        journal_rotate_bytes: ROTATE,
        journal_keep_segments: KEEP,
        ..exp.daemon.clone()
    };
    let mut policies = PolicySpec::legacy_all().to_vec();
    policies.extend(PolicySpec::parameterized_defaults());
    for policy in policies {
        let tag = policy.name();
        let (jobs, stats, dstats) = run_plain(&specs, &exp.slurm, policy.clone(), &dcfg);
        // Two kills: one clean, one landing exactly inside the rotation
        // window (base renamed away, fresh base never created). The
        // second recovery reads a chain the first recovery wrote.
        let kills = [(50, KillKind::Clean), (150, KillKind::MidRotation)];
        let (kj, ks, kd, sstats, done) =
            run_supervised(&specs, &exp.slurm, policy.clone(), &dcfg, &path, &kills, 16);
        assert_eq!(jobs, kj, "{tag}: cohort job records diverged under supervision");
        assert_eq!(stats, ks, "{tag}: cohort SlurmStats diverged under supervision");
        assert_eq!(kd, dstats, "{tag}: cohort DaemonStats diverged under supervision");
        if !policy.is_baseline() {
            assert_eq!(done, 2, "{tag}: both cohort kills must fire");
            assert_eq!(sstats.restarts, 2, "{tag}: two restarts accounted");
            assert!(
                sstats.backoff_ms_total >= 300,
                "{tag}: capped-exponential backoff accounted (100 + 200 ms)"
            );
        }
        // Bounded disk: rotated segments on disk never exceed the keep
        // limit, no matter how long the run journaled.
        let live = tailtamer::journal::live_segments(&path);
        assert!(
            live.len() <= KEEP as usize,
            "{tag}: {} rotated segments on disk, keep limit {KEEP}",
            live.len()
        );
    }
    cleanup(&path);
}

#[test]
fn mid_rotation_kill_with_rotation_forced_every_snapshot() {
    // rotate_bytes = 1: every snapshot rotates, so the mid-rotation
    // kill window is entered from a chain that is all segments. The
    // supervised run must still match the plain one bit-for-bit.
    let specs = vec![
        JobSpec::new("ck-a", 1440, 2880, 1).with_ckpt(420),
        JobSpec::new("ck-b", 1440, 900, 1).with_ckpt(300),
        JobSpec::new("plain", 600, 1200, 1),
    ];
    let cfg = SlurmConfig { nodes: 4, ..Default::default() };
    let path = tmp_path("midrot");
    let dcfg = DaemonConfig {
        journal_rotate_bytes: 1,
        journal_keep_segments: 3,
        ..Default::default()
    };
    let (jobs, stats, dstats) = run_plain(&specs, &cfg, PolicySpec::Hybrid, &dcfg);
    let kills = [(3, KillKind::MidRotation), (9, KillKind::MidRotation)];
    let (kj, ks, kd, sstats, done) =
        run_supervised(&specs, &cfg, PolicySpec::Hybrid, &dcfg, &path, &kills, 2);
    assert_eq!(done, 2, "both mid-rotation kills fire");
    assert_eq!(sstats.restarts, 2);
    assert_eq!(jobs, kj, "job records diverged across mid-rotation kills");
    assert_eq!(stats, ks, "SlurmStats diverged across mid-rotation kills");
    assert_eq!(dstats, kd, "DaemonStats diverged across mid-rotation kills");
    cleanup(&path);
}
