//! Differential failure-injection suite (see `FailureConfig` /
//! `FailurePlan` in `rust/src/slurm/ctld.rs`).
//!
//! Two layers of guarantees:
//!
//! 1. **Failures off is invisible.** `mtbf = 0` must leave every
//!    observable bit — job records, `SlurmStats`, deterministic
//!    `DaemonStats` — identical to the pre-failure seed path, whatever
//!    the other `[failures]` knobs say, across the whole policy
//!    registry, on random workloads and on the 773-job paper cohort.
//! 2. **Failures on obey the physics.** Fuzzed over mtbf × drain ×
//!    rekill × policy × poll-elision × backfill-profile × federation
//!    shards: no job survives its node's death (a NODE_FAILED job ended
//!    while running, within its own duration), failed-job tail waste is
//!    exactly the runtime since the last visible checkpoint (the whole
//!    run for opaque jobs), counters reconcile between `SlurmStats`,
//!    job records, and `metrics::Summary`, and every reference axis
//!    (blind polls, flat profile, the naive seed core, Merged ≡
//!    Sharded ≡ Parallel federation with per-shard failure plans) stays
//!    bit-identical.

use tailtamer::config::Experiment;
use tailtamer::daemon::{Autonomy, DaemonConfig, run_scenario};
use tailtamer::metrics::{job_tail_waste, summarize};
use tailtamer::policy::PolicySpec;
use tailtamer::prop_assert;
use tailtamer::proptest_lite::{Rng, run_prop_cases};
use tailtamer::slurm::fed::{FedDrive, run_federation};
use tailtamer::slurm::reference::NaiveSlurmd;
use tailtamer::slurm::{
    BackfillProfile, CkptSpec, FailureConfig, JobSpec, JobState, SlurmConfig, Slurmd,
};

/// One spec per registry policy, at its default parameters.
fn registry_policies() -> Vec<PolicySpec> {
    vec![
        PolicySpec::Baseline,
        PolicySpec::EarlyCancel,
        PolicySpec::Extend,
        PolicySpec::Hybrid,
        PolicySpec::ExtendBudget { budget: 1_200 },
        PolicySpec::TailAware { frac: 0.25 },
        PolicySpec::HybridBackoff { step: 60 },
    ]
}

/// Random mixed workload (mirrors `tests/federation.rs`).
fn random_workload(rng: &mut Rng, max_jobs: usize, max_nodes: u32) -> (Vec<JobSpec>, SlurmConfig) {
    let n = rng.int_in(1, max_jobs as i64) as usize;
    let nodes_total = rng.int_in(2, max_nodes as i64) as u32;
    let mut specs = Vec::with_capacity(n);
    let mut t = 0;
    let stagger = rng.chance(0.5);
    for i in 0..n {
        let nodes = rng.int_in(1, nodes_total as i64) as u32;
        let limit = rng.int_in(60, 2000);
        let duration = if rng.chance(0.3) {
            limit + rng.int_in(1, 2000)
        } else {
            rng.int_in(30, limit.max(31))
        };
        let mut spec = JobSpec::new(&format!("nf{i}"), limit, duration, nodes);
        if rng.chance(0.4) {
            spec.ckpt = Some(CkptSpec {
                interval: rng.int_in(40, 700),
                jitter_frac: if rng.chance(0.5) { rng.f64_in(0.0, 0.3) } else { 0.0 },
                seed: rng.next_u64(),
            });
        }
        if stagger {
            t += rng.int_in(0, 120);
            spec.submit = t;
        }
        specs.push(spec);
    }
    let cfg = SlurmConfig {
        nodes: nodes_total,
        backfill_interval: rng.int_in(10, 60),
        over_time_limit: if rng.chance(0.2) { rng.int_in(0, 120) } else { 0 },
        ..Default::default()
    };
    (specs, cfg)
}

/// An mtbf = 0 config with every *other* failure knob deliberately
/// non-default: all of them must be inert without a plan.
fn noisy_off_config(base: &SlurmConfig) -> SlurmConfig {
    SlurmConfig {
        failures: FailureConfig {
            mtbf: 0,
            drain_secs: 77,
            drain_frac: 0.93,
            seed: 0xdead_beef,
            rekill: false,
        },
        ..base.clone()
    }
}

#[test]
fn failures_off_is_invisible_on_the_paper_cohort() {
    let exp = Experiment::default();
    let specs = exp.build_workload();
    for policy in registry_policies() {
        let (jobs_a, stats_a, da) =
            run_scenario(&specs, exp.slurm.clone(), policy.clone(), exp.daemon.clone(), None);
        let (jobs_b, stats_b, db) = run_scenario(
            &specs,
            noisy_off_config(&exp.slurm),
            policy.clone(),
            exp.daemon.clone(),
            None,
        );
        assert_eq!(jobs_a, jobs_b, "{}: mtbf=0 changed job records", policy.name());
        assert_eq!(stats_a, stats_b, "{}: mtbf=0 changed SlurmStats", policy.name());
        assert_eq!(
            da.deterministic(),
            db.deterministic(),
            "{}: mtbf=0 changed DaemonStats",
            policy.name()
        );
        assert_eq!(
            (stats_a.node_failures, stats_a.node_drains, stats_a.jobs_failed),
            (0, 0, 0),
            "{}: failure counters must stay zero without a plan",
            policy.name()
        );
        assert!(jobs_a.iter().all(|j| j.state != JobState::NodeFailed));
    }
}

#[test]
fn prop_failures_off_is_invisible_on_random_workloads() {
    run_prop_cases("failures_off_invisible", 0x0FF_5EED, 24, |rng| {
        let (specs, cfg) = random_workload(rng, 40, 12);
        let policies = registry_policies();
        let policy = policies[rng.int_in(0, policies.len() as i64 - 1) as usize].clone();
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            safety: rng.f64_in(0.0, 1.0),
            ..Default::default()
        };
        let (jobs_a, stats_a, da) =
            run_scenario(&specs, cfg.clone(), policy.clone(), dcfg.clone(), None);
        let (jobs_b, stats_b, db) =
            run_scenario(&specs, noisy_off_config(&cfg), policy.clone(), dcfg.clone(), None);
        prop_assert!(jobs_a == jobs_b, "{}: mtbf=0 changed job records", policy.name());
        prop_assert!(stats_a == stats_b, "{}: mtbf=0 changed SlurmStats", policy.name());
        prop_assert!(
            da.deterministic() == db.deterministic(),
            "{}: mtbf=0 changed DaemonStats",
            policy.name()
        );
        Ok(())
    });
}

#[test]
fn prop_failure_injection_invariants() {
    run_prop_cases("failure_invariants", 0xFA11_ED, 18, |rng| {
        let (specs, cfg0) = random_workload(rng, 36, 10);
        let failures = FailureConfig {
            mtbf: rng.int_in(40, 1500),
            drain_secs: rng.int_in(5, 400),
            drain_frac: rng.f64_in(0.0, 1.0),
            seed: rng.next_u64(),
            rekill: rng.chance(0.5),
        };
        let cfg = SlurmConfig { failures, ..cfg0 };
        let policies = registry_policies();
        let policy = policies[rng.int_in(0, policies.len() as i64 - 1) as usize].clone();
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            // Thread the hazard term the way config loading does.
            failure_mtbf: cfg.failures.mtbf,
            ..Default::default()
        };

        let (jobs, stats, dstats) =
            run_scenario(&specs, cfg.clone(), policy.clone(), dcfg.clone(), None);

        // --- Physics invariants on the primary run. ---
        let mut node_failed_jobs = 0u64;
        for j in &jobs {
            prop_assert!(j.state.is_terminal(), "{}: job {} not terminal", policy.name(), j.id);
            if j.state == JobState::NodeFailed {
                node_failed_jobs += 1;
                let (Some(start), Some(end)) = (j.start, j.end) else {
                    return Err(format!("{}: NODE_FAILED {} without start/end", policy.name(), j.id));
                };
                // Killed while running: terminated at its last visible
                // instant, never past its own natural duration.
                prop_assert!(end >= start, "{}: {} ended before it started", policy.name(), j.id);
                prop_assert!(
                    end - start <= j.spec.duration,
                    "{}: {} survived past its duration",
                    policy.name(),
                    j.id
                );
                // Failed tail waste = runtime since the last visible
                // checkpoint; the whole run for opaque jobs.
                let expected = if j.is_checkpointing() {
                    (end - j.completed_ckpts(end).last().unwrap_or(start)) * j.spec.cores as i64
                } else {
                    (end - start) * j.spec.cores as i64
                };
                prop_assert!(
                    job_tail_waste(j) == expected,
                    "{}: {} tail waste {} != recomputed {expected}",
                    policy.name(),
                    j.id,
                    job_tail_waste(j)
                );
            }
        }
        prop_assert!(
            stats.jobs_failed == node_failed_jobs,
            "{}: stats.jobs_failed {} != {} NODE_FAILED records",
            policy.name(),
            stats.jobs_failed,
            node_failed_jobs
        );
        // Every killed job took a node down; idle kills add more.
        prop_assert!(
            stats.node_failures >= stats.jobs_failed,
            "{}: node_failures {} < jobs_failed {}",
            policy.name(),
            stats.node_failures,
            stats.jobs_failed
        );
        let s = summarize(&policy.name(), &jobs, &stats);
        prop_assert!(
            s.node_failed as u64 == stats.jobs_failed,
            "{}: Summary.node_failed disagrees with SlurmStats",
            policy.name()
        );
        prop_assert!(
            s.failed_tail_waste >= 0 && s.failed_tail_waste <= s.tail_waste,
            "{}: failed waste {} outside total {}",
            policy.name(),
            s.failed_tail_waste,
            s.tail_waste
        );

        // --- Determinism: the same plan replays bit-identically. ---
        let (jobs2, stats2, d2) =
            run_scenario(&specs, cfg.clone(), policy.clone(), dcfg.clone(), None);
        prop_assert!(
            jobs == jobs2 && stats == stats2 && dstats.deterministic() == d2.deterministic(),
            "{}: failure plan replay diverged",
            policy.name()
        );

        // --- Reference axes stay bit-identical under failures. ---
        let blind = SlurmConfig { poll_elision: false, ..cfg.clone() };
        let (jb, sb, db) = run_scenario(&specs, blind, policy.clone(), dcfg.clone(), None);
        prop_assert!(
            jb == jobs && sb == stats && db.deterministic() == dstats.deterministic(),
            "{}: blind polls diverged under failures",
            policy.name()
        );
        let flat = SlurmConfig { backfill_profile: BackfillProfile::Flat, ..cfg.clone() };
        let (jf, sf, _) = run_scenario(&specs, flat, policy.clone(), dcfg.clone(), None);
        prop_assert!(
            jf == jobs && sf == stats,
            "{}: flat profile diverged under failures",
            policy.name()
        );
        // The naive seed core grew identical failure semantics.
        let mut sim = NaiveSlurmd::new(cfg.clone());
        for sp in &specs {
            sim.submit(sp.clone());
        }
        let mut daemon = Autonomy::native(policy.clone(), dcfg.clone());
        sim.run(&mut daemon);
        prop_assert!(
            sim.stats == stats,
            "{}: naive SlurmStats diverged under failures",
            policy.name()
        );
        prop_assert!(
            sim.into_jobs() == jobs,
            "{}: naive job records diverged under failures",
            policy.name()
        );

        // --- Federation: failure plans partition per shard (each shard
        // owns a full per-cluster plan), and all three drives agree. ---
        for shards in [2usize, 3] {
            let merged = run_federation(&specs, shards, &cfg, &policy, &dcfg, FedDrive::Merged);
            let sharded = run_federation(&specs, shards, &cfg, &policy, &dcfg, FedDrive::Sharded);
            prop_assert!(
                merged.jobs == sharded.jobs && merged.stats == sharded.stats,
                "{}/S={shards}: Merged != Sharded under failures",
                policy.name()
            );
            let parallel =
                run_federation(&specs, shards, &cfg, &policy, &dcfg, FedDrive::Parallel {
                    threads: 2,
                });
            prop_assert!(
                parallel.jobs == merged.jobs && parallel.stats == merged.stats,
                "{}/S={shards}: Parallel != Merged under failures",
                policy.name()
            );
        }
        Ok(())
    });
}

#[test]
fn kill_only_plan_on_the_saturated_cohort_fails_jobs() {
    // 773 jobs released at t=0 on 20 nodes saturate the cluster for the
    // whole early makespan, so a kill-only plan's first event (due
    // within 2*mtbf-1 s) is guaranteed a busy victim.
    let exp = Experiment::default();
    let specs = exp.build_workload();
    let cfg = SlurmConfig {
        failures: FailureConfig {
            mtbf: 200,
            drain_secs: 120,
            drain_frac: 0.0,
            ..Default::default()
        },
        ..exp.slurm.clone()
    };
    let policy = PolicySpec::EarlyCancel;
    let (jobs, stats, _) = run_scenario(&specs, cfg.clone(), policy.clone(), exp.daemon.clone(), None);
    assert!(jobs.iter().all(|j| j.state.is_terminal()), "run must drain to completion");
    assert!(stats.jobs_failed > 0, "saturated cluster + kill-only plan must fail jobs");
    assert_eq!(stats.node_drains, 0, "drain_frac=0 must never drain");
    let s = summarize("ec", &jobs, &stats);
    assert_eq!(s.node_failed as u64, stats.jobs_failed);
    assert!(s.failed_tail_waste > 0, "hundreds of kills leave nonzero residue");
    assert!(s.tail_waste >= s.failed_tail_waste);
    // Merged ≡ Sharded ≡ Parallel holds on the cohort under failures.
    for shards in [2usize, 4] {
        let merged = run_federation(&specs, shards, &cfg, &policy, &exp.daemon, FedDrive::Merged);
        let sharded = run_federation(&specs, shards, &cfg, &policy, &exp.daemon, FedDrive::Sharded);
        assert_eq!(merged.jobs, sharded.jobs, "cohort S={shards}: jobs diverged");
        assert_eq!(merged.stats, sharded.stats, "cohort S={shards}: stats diverged");
        let parallel =
            run_federation(&specs, shards, &cfg, &policy, &exp.daemon, FedDrive::Parallel {
                threads: 3,
            });
        assert_eq!(parallel.jobs, merged.jobs, "cohort S={shards}: parallel jobs diverged");
        assert_eq!(parallel.stats, merged.stats, "cohort S={shards}: parallel stats diverged");
    }
}

#[test]
fn drain_only_plan_never_kills() {
    let exp = Experiment::default();
    let specs = exp.build_workload();
    let cfg = SlurmConfig {
        failures: FailureConfig {
            mtbf: 300,
            drain_secs: 90,
            drain_frac: 1.0,
            ..Default::default()
        },
        ..exp.slurm.clone()
    };
    let (jobs, stats, _) =
        run_scenario(&specs, cfg, PolicySpec::Baseline, exp.daemon.clone(), None);
    assert!(jobs.iter().all(|j| j.state.is_terminal()));
    assert_eq!(stats.jobs_failed, 0, "a drain-only plan must never kill a job");
    assert_eq!(stats.node_failures, 0);
    assert!(jobs.iter().all(|j| j.state != JobState::NodeFailed));
    assert!(stats.node_drains > 0, "the saturated cluster's first event must mark a drain");
    let s = summarize("base", &jobs, &stats);
    assert_eq!((s.node_failed, s.failed_tail_waste), (0, 0));
}

#[test]
fn rekill_false_absorbs_repeat_kills_on_a_draining_victim() {
    // Single node, mtbf=1 (every gap is exactly 1 s): the first event
    // drains the running job; with rekill=false every subsequent kill
    // aimed at the still-draining victim is absorbed, so the job runs
    // out its natural duration and the only down event is the drain.
    let mut cfg = SlurmConfig { nodes: 1, ..Default::default() };
    cfg.failures = FailureConfig {
        mtbf: 1,
        drain_secs: 5,
        drain_frac: 0.0,
        rekill: false,
        ..Default::default()
    };
    let mut sim = Slurmd::new(cfg.clone());
    sim.submit(JobSpec::new("victim", 100, 60, 1));
    // Pre-mark via a drain-only twin config is not possible with
    // drain_frac=0, so drive the drain through the fuzz surface
    // instead: drain_frac=1.0 for the twin, then compare.
    let mut drain_cfg = cfg.clone();
    drain_cfg.failures.drain_frac = 1.0;
    drain_cfg.failures.rekill = false;
    let mut twin = Slurmd::new(drain_cfg);
    twin.submit(JobSpec::new("victim", 100, 60, 1));
    twin.run(&mut tailtamer::slurm::NoDaemon);
    let twin_stats = twin.stats.clone();
    let twin_jobs = twin.into_jobs();
    assert_eq!(twin_jobs[0].state, JobState::Completed, "drained job finishes naturally");
    assert_eq!(twin_jobs[0].end, Some(60));
    assert_eq!(twin_stats.jobs_failed, 0);
    assert_eq!(twin_stats.node_drains, 1, "repeat drains on the same victim are absorbed");

    // The kill-only rekill=false run: the first kill fires (victim not
    // draining), so exactly one job dies — rekill=false only shields
    // *draining* victims.
    sim.run(&mut tailtamer::slurm::NoDaemon);
    let stats = sim.stats.clone();
    let jobs = sim.into_jobs();
    assert_eq!(jobs[0].state, JobState::NodeFailed);
    assert_eq!(jobs[0].end, Some(1), "first kill lands at t=1 (mtbf=1 gaps are exactly 1)");
    assert_eq!(stats.jobs_failed, 1);
}
