//! Differential fuzz: the windowed conflict scan against the retained
//! naive O(R·Q) loop (`NativeEngine::naive`, the second oracle).
//!
//! The windowed scan sorts queue columns by `pred_start` and narrows
//! each row's conflict window to a `partition_point` range, but
//! accumulates matches in original column order — so every output,
//! including the order-sensitive f32 `delay_cost` sums, must be
//! **bit-identical** to the naive loop. These properties hammer that
//! claim with adversarial batches: duplicated/boundary `pred_start`
//! values, masked rows and columns, degenerate histories, zero-width
//! windows, and chunked evaluation through the daemon's own batch
//! shapes.

use tailtamer::analytics::{DecisionBatch, DecisionEngine, DecisionOutputs, NativeEngine};
use tailtamer::proptest_lite::{Rng, run_prop_cases};
use tailtamer::prop_assert;
use tailtamer::simtime::Time;
use tailtamer::slurm::JobId;

/// A hostile random batch: clustered pred_starts (duplicates and exact
/// window-boundary hits are likely), partial masks, short histories.
fn hostile_batch(rng: &mut Rng) -> DecisionBatch {
    let r = rng.int_in(1, 48) as usize;
    let q = rng.int_in(0, 300) as usize;
    let h = rng.int_in(2, 24) as usize;
    let margin = rng.int_in(0, 90) as f32;
    let safety = if rng.chance(0.5) { rng.f64_in(0.0, 1.5) as f32 } else { 0.0 };
    let mut b = DecisionBatch::empty(r, q, h, margin, safety);

    // A small pool of interval/base values makes cross-row window
    // boundaries collide with queue pred_starts on purpose.
    let base_pool: Vec<Time> = (0..4).map(|_| rng.int_in(0, 2000)).collect();
    let iv_pool: Vec<Time> = (0..4).map(|_| rng.int_in(50, 800)).collect();

    for i in 0..r {
        if rng.chance(0.15) {
            continue; // masked row
        }
        let n = rng.int_in(0, h as i64) as usize;
        let base = base_pool[rng.int_in(0, 3) as usize];
        let iv = iv_pool[rng.int_in(0, 3) as usize];
        let hist: Vec<Time> = (1..=n as i64).map(|k| base + k * iv).collect();
        if hist.is_empty() {
            continue;
        }
        let cur_end = hist.last().unwrap() + rng.int_in(0, 2 * iv);
        b.set_row(i, JobId(i as u32), &hist, cur_end, rng.int_in(1, 8) as u32);
    }
    for k in 0..q {
        if rng.chance(0.1) {
            continue; // masked column
        }
        // Half the columns aim straight at a window edge: cur_end,
        // cur_end + interval + margin (≈ ext_end), or a duplicate of
        // a pool value — the exact `>=`/`<` boundary cases.
        let ps = if rng.chance(0.5) {
            let base = base_pool[rng.int_in(0, 3) as usize];
            let iv = iv_pool[rng.int_in(0, 3) as usize];
            base + iv * rng.int_in(1, 6) + if rng.chance(0.5) { margin as Time } else { 0 }
        } else {
            rng.int_in(0, 8000)
        };
        b.set_queue(k, ps, rng.int_in(1, 16) as u32, rng.int_in(0, 20) as u32);
    }
    b
}

#[test]
fn prop_windowed_scan_is_bit_identical_to_naive() {
    let mut windowed = NativeEngine::new();
    let mut naive = NativeEngine::naive();
    run_prop_cases("windowed_vs_naive", 0xC0F1, 300, |rng| {
        let b = hostile_batch(rng);
        let a = windowed.evaluate(&b).unwrap();
        let n = naive.evaluate(&b).unwrap();
        prop_assert!(
            a == n,
            "windowed scan diverged at R={} Q={} H={} margin={} safety={}",
            b.r,
            b.q,
            b.h,
            b.params[0],
            b.params[1]
        );
        Ok(())
    });
}

#[test]
fn prop_pooled_outputs_match_fresh_allocations() {
    // evaluate_into through one long-lived pooled buffer must match
    // evaluate's fresh outputs on every batch — no cross-batch residue.
    let mut windowed = NativeEngine::new();
    let mut pooled = DecisionOutputs::default();
    run_prop_cases("pooled_outputs", 0xB00F, 100, |rng| {
        let b = hostile_batch(rng);
        windowed.evaluate_into(&b, &mut pooled).unwrap();
        let fresh = windowed.evaluate(&b).unwrap();
        prop_assert!(pooled == fresh, "pooled outputs diverged at R={} Q={}", b.r, b.q);
        Ok(())
    });
}

#[test]
fn windowed_scan_handles_degenerate_shapes() {
    let mut windowed = NativeEngine::new();
    let mut naive = NativeEngine::naive();
    // Empty queue, all-masked queue, single row, zero-width window
    // (ext_end == cur_end when margin = 0 and the next checkpoint
    // lands exactly on the limit).
    let mut b = DecisionBatch::empty(2, 4, 4, 0.0, 0.0);
    b.set_row(0, JobId(0), &[100, 200], 300, 1); // pred_next 300 == cur_end
    b.set_queue(0, 300, 5, 2);
    b.set_queue(1, 299, 5, 2);
    let a = windowed.evaluate(&b).unwrap();
    let n = naive.evaluate(&b).unwrap();
    assert_eq!(a, n);
    // fits: 300 + 0 <= 300 -> the window never opens.
    assert_eq!(a.fits[0], 1.0);
    assert_eq!(a.conflict[0], 0.0);

    let empty_q = DecisionBatch::empty(3, 0, 4, 30.0, 0.0);
    assert_eq!(windowed.evaluate(&empty_q).unwrap(), naive.evaluate(&empty_q).unwrap());
}
