//! On-demand backfill ticks: the event-driven tick chain must be
//! **behaviorally invisible**.
//!
//! `BackfillTicks::OnDemand` (the default) replaces the seed's
//! perpetual 30 s `Ev::BackfillTick` self-reschedule with a virtual
//! tick chain that materializes work only at grid slots where a pass
//! actually runs, batch-skipping clean slots with synthesized
//! `backfill_skipped`/`SlurmStats::events` accounting. These tests run
//! identical workloads three ways — on-demand, forced perpetual
//! ticking, and the retained naive reference core (which is perpetual
//! by construction) — and assert bit-identical job records,
//! adjustments, `SlurmStats`, and deterministic `DaemonStats`. Covered:
//!
//! - random mixed workloads across the whole policy family, staggered
//!   arrivals, OverTimeLimit grace, random backfill intervals, poll
//!   elision on and off, and random flaky-control injection (rejected
//!   scancel/scontrol actions retried every tick);
//! - the 773-job paper cohort per policy;
//! - the named edge cases of the equivalence proof: dirty-while-tick-
//!   pending dedup, grace re-clamp plus re-dirtying at the exact grid
//!   instant, a quiet stretch many intervals long with a mid-stretch
//!   scancel, and an empty-cluster idle-to-termination run.

mod common;

use common::FlakyHook;
use tailtamer::daemon::{Autonomy, DaemonConfig, DaemonStats, Policy};
use tailtamer::policy::PolicySpec;
use tailtamer::prop_assert;
use tailtamer::proptest_lite::{Rng, run_prop_cases};
use tailtamer::simtime::Time;
use tailtamer::slurm::reference::NaiveSlurmd;
use tailtamer::slurm::{
    Adjustment, BackfillTicks, DaemonHook, Job, JobId, JobSpec, JobState, SlurmConfig,
    SlurmControl, SlurmStats, Slurmd,
};

struct SimRun {
    jobs: Vec<Job>,
    stats: SlurmStats,
    dstats: DaemonStats,
    ticks_elided: u64,
    events_popped: u64,
    /// Control-action rejections the flaky proxy injected (0 when no
    /// injection was requested); both tick modes must consume the
    /// same rejections for the retry trajectories to be comparable.
    injected: u32,
}

fn run_mode(
    specs: &[JobSpec],
    cfg: &SlurmConfig,
    policy: impl Into<PolicySpec>,
    dcfg: &DaemonConfig,
    ticks: BackfillTicks,
    rejects: u32,
) -> SimRun {
    let mut sim = Slurmd::new(SlurmConfig { backfill_ticks: ticks, ..cfg.clone() });
    for s in specs {
        sim.submit(s.clone());
    }
    let mut hook = FlakyHook::new(Autonomy::native(policy, dcfg.clone()), rejects);
    sim.run(&mut hook);
    let stats = sim.stats.clone();
    let ticks_elided = sim.backfill_ticks_elided();
    let events_popped = sim.events_processed();
    SimRun {
        jobs: sim.into_jobs(),
        stats,
        dstats: hook.inner.stats.deterministic(),
        ticks_elided,
        events_popped,
        injected: hook.injected,
    }
}

fn run_naive(
    specs: &[JobSpec],
    cfg: &SlurmConfig,
    policy: impl Into<PolicySpec>,
    dcfg: &DaemonConfig,
    rejects: u32,
) -> SimRun {
    let mut sim = NaiveSlurmd::new(cfg.clone());
    for s in specs {
        sim.submit(s.clone());
    }
    let mut hook = FlakyHook::new(Autonomy::native(policy, dcfg.clone()), rejects);
    sim.run(&mut hook);
    let stats = sim.stats.clone();
    SimRun {
        jobs: sim.into_jobs(),
        stats,
        dstats: hook.inner.stats.deterministic(),
        ticks_elided: 0,
        events_popped: 0,
        injected: hook.injected,
    }
}

fn assert_identical(tag: &str, a: &SimRun, b: &SimRun) -> Result<(), String> {
    prop_assert!(a.jobs == b.jobs, "{tag}: job records diverged");
    prop_assert!(
        a.injected == b.injected,
        "{tag}: both modes must attempt the same actions ({} vs {})",
        a.injected,
        b.injected
    );
    prop_assert!(a.stats == b.stats, "{tag}: SlurmStats diverged: {:?} vs {:?}", a.stats, b.stats);
    prop_assert!(
        a.dstats == b.dstats,
        "{tag}: DaemonStats diverged: {:?} vs {:?}",
        a.dstats,
        b.dstats
    );
    Ok(())
}

fn random_workload(rng: &mut Rng) -> (Vec<JobSpec>, SlurmConfig) {
    let n = rng.int_in(1, 40) as usize;
    let nodes_total = rng.int_in(2, 12) as u32;
    let mut specs = Vec::with_capacity(n);
    let mut t = 0;
    let staggered = rng.chance(0.5);
    for i in 0..n {
        let nodes = rng.int_in(1, nodes_total as i64) as u32;
        let limit = rng.int_in(60, 2000);
        let duration = if rng.chance(0.4) {
            limit + rng.int_in(1, 2000) // will time out
        } else {
            rng.int_in(30, limit.max(31))
        };
        let mut spec = JobSpec::new(&format!("b{i}"), limit, duration, nodes);
        if rng.chance(0.5) {
            spec.ckpt = Some(tailtamer::slurm::CkptSpec {
                interval: rng.int_in(40, 700),
                jitter_frac: if rng.chance(0.5) { rng.f64_in(0.0, 0.3) } else { 0.0 },
                seed: rng.next_u64(),
            });
        }
        if staggered {
            // Gaps regularly exceed the backfill interval, so the
            // chain's quiet-stretch batching is exercised, not just
            // its slot-by-slot path.
            t += rng.int_in(0, 400);
            spec.submit = t;
        }
        specs.push(spec);
    }
    let cfg = SlurmConfig {
        nodes: nodes_total,
        backfill_interval: rng.int_in(10, 60),
        over_time_limit: if rng.chance(0.3) { rng.int_in(0, 300) } else { 0 },
        poll_elision: rng.chance(0.5),
        ..Default::default()
    };
    (specs, cfg)
}

fn random_policy_spec(rng: &mut Rng) -> PolicySpec {
    match rng.int_in(0, 6) {
        0 => PolicySpec::Baseline,
        1 => PolicySpec::EarlyCancel,
        2 => PolicySpec::Extend,
        3 => PolicySpec::Hybrid,
        4 => PolicySpec::ExtendBudget { budget: rng.int_in(60, 4000) },
        5 => PolicySpec::TailAware { frac: rng.f64_in(0.01, 2.0) },
        _ => PolicySpec::HybridBackoff { step: rng.int_in(1, 300) },
    }
}

#[test]
fn prop_ondemand_perpetual_and_naive_runs_are_bit_identical() {
    let mut total_elided = 0u64;
    run_prop_cases("backfill_ondemand_golden", 0xBF0D, 48, |rng| {
        let (specs, cfg) = random_workload(rng);
        let policy = random_policy_spec(rng);
        let dcfg = DaemonConfig {
            poll_period: rng.int_in(5, 40),
            margin: rng.int_in(0, 60),
            safety: rng.f64_in(0.0, 1.0),
            ..Default::default()
        };
        // Random flaky-control injection: the first K control actions
        // are rejected, so the daemon's per-tick retry path runs under
        // both tick modes.
        let rejects = if rng.chance(0.3) { rng.int_in(1, 5) as u32 } else { 0 };
        let od = run_mode(&specs, &cfg, policy.clone(), &dcfg, BackfillTicks::OnDemand, rejects);
        let pp = run_mode(&specs, &cfg, policy.clone(), &dcfg, BackfillTicks::Perpetual, rejects);
        let naive = run_naive(&specs, &cfg, policy.clone(), &dcfg, rejects);
        prop_assert!(pp.ticks_elided == 0, "perpetual mode must not elide ticks");
        prop_assert!(
            od.events_popped <= pp.events_popped,
            "on-demand popped more events than perpetual"
        );
        assert_identical(&format!("{} ondemand-vs-perpetual", policy.name()), &od, &pp)?;
        assert_identical(&format!("{} ondemand-vs-naive", policy.name()), &od, &naive)?;
        total_elided += od.ticks_elided;
        Ok(())
    });
    assert!(total_elided > 0, "tick elision never fired across 48 random workloads");
}

#[test]
fn ondemand_is_exact_on_the_paper_cohort() {
    let exp = tailtamer::config::Experiment::default();
    let specs = exp.build_workload();
    for policy in Policy::ALL {
        let od = run_mode(&specs, &exp.slurm, policy, &exp.daemon, BackfillTicks::OnDemand, 0);
        let pp = run_mode(&specs, &exp.slurm, policy, &exp.daemon, BackfillTicks::Perpetual, 0);
        let naive = run_naive(&specs, &exp.slurm, policy, &exp.daemon, 0);
        assert_eq!(od.jobs, pp.jobs, "{policy:?}: cohort job records diverged");
        assert_eq!(od.stats, pp.stats, "{policy:?}: cohort SlurmStats diverged");
        assert_eq!(od.dstats, pp.dstats, "{policy:?}: cohort DaemonStats diverged");
        assert_eq!(od.jobs, naive.jobs, "{policy:?}: cohort diverged from naive");
        assert_eq!(od.stats, naive.stats, "{policy:?}: cohort stats diverged from naive");
        assert!(od.ticks_elided > 0, "{policy:?}: the cohort must skip some tick slots");
        assert!(od.events_popped < pp.events_popped, "{policy:?}: no event saving");
    }
    for spec in PolicySpec::parameterized_defaults() {
        let od = run_mode(&specs, &exp.slurm, spec.clone(), &exp.daemon, BackfillTicks::OnDemand, 0);
        let pp =
            run_mode(&specs, &exp.slurm, spec.clone(), &exp.daemon, BackfillTicks::Perpetual, 0);
        assert_eq!(od.jobs, pp.jobs, "{}: cohort job records diverged", spec.name());
        assert_eq!(od.stats, pp.stats, "{}: cohort SlurmStats diverged", spec.name());
        assert_eq!(od.dstats, pp.dstats, "{}: cohort DaemonStats diverged", spec.name());
    }
}

// ---------------------------------------------------------------------
// Named edge cases of the equivalence proof.
// ---------------------------------------------------------------------

/// Two dirtying arrivals inside one backfill interval: the chain holds
/// exactly one upcoming slot, so the second transition must not
/// schedule a second pass for the same grid instant.
#[test]
fn dirty_while_tick_pending_never_double_schedules() {
    let run = |ticks| {
        let mut sim = Slurmd::new(SlurmConfig {
            nodes: 4,
            backfill_ticks: ticks,
            ..Default::default()
        });
        // A holder so the arrivals cannot start via the main scheduler
        // (each arrival only dirties the backfill state).
        sim.submit(JobSpec::new("hold", 2000, 2000, 4));
        for (i, at) in [5i64, 12, 17].into_iter().enumerate() {
            let mut s = JobSpec::new(&format!("a{i}"), 100, 80, 1);
            s.submit = at;
            sim.submit(s);
        }
        sim.run(&mut tailtamer::slurm::NoDaemon);
        (sim.stats.clone(), sim.into_jobs())
    };
    let (od_stats, od_jobs) = run(BackfillTicks::OnDemand);
    let (pp_stats, pp_jobs) = run(BackfillTicks::Perpetual);
    assert_eq!(od_jobs, pp_jobs);
    assert_eq!(od_stats, pp_stats, "one pass at t=30 must cover all three arrivals");
}

/// A grace-overrunning job whose encoded release is re-clamped through
/// the *incremental* base-profile path (a limit-only change keeps the
/// cached base valid), with the dirtying scontrol landing at the exact
/// grid instant — the pass must run at that same instant, not one
/// interval later.
#[test]
fn grace_reclamp_and_same_instant_redirty_stay_exact() {
    struct ExtendAt(Time, bool);
    impl DaemonHook for ExtendAt {
        fn poll_period(&self) -> Option<Time> {
            Some(30) // aligned with the 30 s backfill grid
        }
        fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
            if !self.1 && t >= self.0 {
                self.1 = true;
                // Limit-only change: keeps the cached base profile
                // valid, so the next pass folds it in incrementally and
                // re-clamps the grace overrunner's stale release.
                ctl.scontrol_update_limit(JobId(1), 2100).unwrap();
            }
        }
    }
    let run = |sim: &mut dyn ErasedSim| {
        sim.submit_spec(JobSpec::new("overrun", 60, 400, 1)); // grace 60..360
        sim.submit_spec(JobSpec::new("steady", 2000, 1900, 1));
        sim.submit_spec(JobSpec::new("queued", 300, 250, 2)); // pending until both release
        let mut hook = ExtendAt(150, false);
        sim.drive(&mut hook)
    };
    let cfg = SlurmConfig { nodes: 2, over_time_limit: 300, ..Default::default() };
    let mut od = OptSim(Slurmd::new(SlurmConfig {
        backfill_ticks: BackfillTicks::OnDemand,
        ..cfg.clone()
    }));
    let mut pp = OptSim(Slurmd::new(SlurmConfig {
        backfill_ticks: BackfillTicks::Perpetual,
        ..cfg.clone()
    }));
    let mut nv = RefSim(NaiveSlurmd::new(cfg));
    let (od_jobs, od_stats) = run(&mut od);
    let (pp_jobs, pp_stats) = run(&mut pp);
    let (nv_jobs, nv_stats) = run(&mut nv);
    assert_eq!(od_jobs, pp_jobs);
    assert_eq!(od_stats, pp_stats);
    assert_eq!(od_jobs, nv_jobs);
    assert_eq!(od_stats, nv_stats);
    // The overrunner times out inside grace; the queued job waits for
    // the steady holder's (extended) release.
    assert_eq!(od_jobs[0].state, JobState::Timeout);
    assert_eq!(od_jobs[0].end, Some(360));
    assert_eq!(od_jobs[2].start, Some(1900));
}

/// A quiet stretch hundreds of intervals long, with the daemon's
/// scancel landing mid-stretch: the chain must batch-skip the quiet
/// slots (events popped collapse) while staying bit-identical.
#[test]
fn quiet_stretch_with_midstream_scancel_collapses_event_count() {
    let specs = vec![
        JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420), // cancelled ~1280
        JobSpec::new("long", 20_000, 20_000, 1),          // opaque, runs to 20000
    ];
    let cfg = SlurmConfig { nodes: 2, ..Default::default() };
    let dcfg = DaemonConfig::default();
    let od = run_mode(&specs, &cfg, Policy::EarlyCancel, &dcfg, BackfillTicks::OnDemand, 0);
    let pp = run_mode(&specs, &cfg, Policy::EarlyCancel, &dcfg, BackfillTicks::Perpetual, 0);
    let naive = run_naive(&specs, &cfg, Policy::EarlyCancel, &dcfg, 0);
    assert_eq!(od.jobs, pp.jobs);
    assert_eq!(od.stats, pp.stats);
    assert_eq!(od.dstats, pp.dstats);
    assert_eq!(od.jobs, naive.jobs);
    assert_eq!(od.stats, naive.stats);
    assert_eq!(od.dstats, naive.dstats);
    assert_eq!(od.jobs[0].state, JobState::Cancelled);
    assert_eq!(od.jobs[0].adjustment, Some(Adjustment::EarlyCancelled));
    // ~620 tick slots over the run; after the cancel at ~1280 the
    // stretch to 20000 is one clean batch.
    assert!(od.ticks_elided > 500, "quiet slots must be skipped: {}", od.ticks_elided);
    assert!(
        od.events_popped * 3 < pp.events_popped,
        "the event loop must sleep to the next real event: {} vs {}",
        od.events_popped,
        pp.events_popped
    );
}

/// Zero jobs: the run must still execute the perpetual reference's
/// single t=0 pass (and first daemon poll) and terminate with
/// identical accounting.
#[test]
fn empty_cluster_idles_to_termination_identically() {
    for daemonized in [false, true] {
        let run = |ticks| {
            let mut sim =
                Slurmd::new(SlurmConfig { nodes: 4, backfill_ticks: ticks, ..Default::default() });
            if daemonized {
                let mut d = Autonomy::native(Policy::EarlyCancel, DaemonConfig::default());
                sim.run(&mut d);
            } else {
                sim.run(&mut tailtamer::slurm::NoDaemon);
            }
            (sim.stats.clone(), sim.events_processed())
        };
        let (od_stats, od_popped) = run(BackfillTicks::OnDemand);
        let (pp_stats, pp_popped) = run(BackfillTicks::Perpetual);
        assert_eq!(od_stats, pp_stats, "daemonized={daemonized}");
        assert_eq!(od_stats.backfill_passes, 1, "exactly the t=0 pass");
        assert!(od_popped <= pp_popped, "daemonized={daemonized}");
    }
}

// ---------------------------------------------------------------------
// Plumbing: a thin object-safe facade so the deterministic edge-case
// tests can drive Slurmd and NaiveSlurmd through one code path (the
// flaky-control proxy lives in tests/common/mod.rs, shared with the
// poll-elision and policy-layer suites).
// ---------------------------------------------------------------------

trait ErasedSim {
    fn submit_spec(&mut self, spec: JobSpec);
    fn drive(&mut self, hook: &mut dyn DaemonHook) -> (Vec<Job>, SlurmStats);
}

struct OptSim(Slurmd);
impl ErasedSim for OptSim {
    fn submit_spec(&mut self, spec: JobSpec) {
        self.0.submit(spec);
    }
    fn drive(&mut self, hook: &mut dyn DaemonHook) -> (Vec<Job>, SlurmStats) {
        self.0.run(hook);
        (self.0.jobs().to_vec(), self.0.stats.clone())
    }
}

struct RefSim(NaiveSlurmd);
impl ErasedSim for RefSim {
    fn submit_spec(&mut self, spec: JobSpec) {
        self.0.submit(spec);
    }
    fn drive(&mut self, hook: &mut dyn DaemonHook) -> (Vec<Job>, SlurmStats) {
        self.0.run(hook);
        (self.0.jobs().to_vec(), self.0.stats.clone())
    }
}

