//! Pluggable decision policies: the paper's *family* of early-cancel /
//! extend policies as a first-class, parameterized layer.
//!
//! The seed hard-coded three policies as a closed enum whose logic was
//! inlined in the daemon core; adding one meant editing the daemon, the
//! config parser, and every sweep grid by hand. This module replaces
//! that with:
//!
//! - [`PolicySpec`]: a *parsed* policy — name plus validated parameters
//!   — round-trippable through TOML (`[policy]` table or the
//!   `daemon.policy` string), the CLI (`--policy extend-budget:1200`),
//!   and the sweep grid. The parameter registry ([`REGISTRY`]) drives
//!   parsing, range validation, unknown-key diagnostics, and
//!   `--list-policies`.
//! - [`DecisionPolicy`]: the compiled pipeline the daemon drives. A
//!   spec is compiled once per run ([`PolicySpec::compile`]) against
//!   the [`DaemonConfig`]; per-job state (extension counts, spent
//!   budget, rejected actions) lives in the daemon's dense tables and
//!   is handed back through [`RowCtx`], so policy objects stay
//!   immutable and trivially shareable across sweep threads.
//!
//! ## The staged pipeline
//!
//! For every running row whose predicted next checkpoint does not fit,
//! the daemon runs four stages:
//!
//! 1. **eligibility gate** — [`DecisionPolicy::may_extend`]: may this
//!    job still be extended (max-extensions / budget exhaustion)?
//! 2. **fit prediction** — [`DecisionPolicy::extra_margin`]: extra fit
//!    slack on top of `DaemonConfig::margin` (the backoff policy widens
//!    it after rejected actions); re-applied to the engine's
//!    `pred_next` in the same f32 arithmetic the engine uses, so a zero
//!    extra reproduces the engine's `fits` bit verbatim.
//! 3. **action selection** — [`DecisionPolicy::select`]: Extend, Cancel,
//!    or Leave (let the job run to its natural end).
//! 4. **budget accounting** — shared driver code: granted extension
//!    seconds, extension counts, and rejection counts are recorded in
//!    the daemon's dense tables and in `DaemonStats`
//!    (`budget_spent` / `policy_declines`), then fed back via `RowCtx`.
//!
//! ## The determinism contract
//!
//! A policy's decision must be a pure function of [`RowCtx`] and
//! [`EngineRow`] — never of wall-clock `now`. The control plane elides
//! provably no-op polls (`SlurmConfig::poll_elision`): a row whose
//! inputs are unchanged is not re-presented, so a time-varying decision
//! would diverge from blind polling. Rows with a *rejected* action are
//! re-presented every tick (the daemon holds a retry verdict), which is
//! why [`RowCtx::rejections`]-driven behavior (backoff) stays exact.
//!
//! The three legacy policies re-expressed here are pinned bit-identical
//! to the retained legacy driver (`Autonomy::legacy_reference`) by
//! `rust/tests/properties.rs` and `rust/tests/policy_layer.rs`.

use std::collections::BTreeMap;

use crate::bail;
use crate::config::Value;
use crate::daemon::{DaemonConfig, Policy};
use crate::errors::Result;
use crate::simtime::Time;
use crate::slurm::JobId;

/// What the policy wants done with a not-fitting row.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// `scontrol update TimeLimit` so the next checkpoint fits.
    Extend,
    /// `scancel` now — everything after the last checkpoint is waste.
    Cancel,
    /// Deliberately do nothing (tail-aware: the remaining tail is cheap
    /// relative to the checkpointed work). Stable until inputs change.
    Leave,
}

/// Per-row context the daemon hands to every pipeline stage. All fields
/// derive from the queue snapshot and the daemon's own dense tables —
/// never from wall-clock time (see the determinism contract above).
#[derive(Debug, Clone, Copy)]
pub struct RowCtx {
    pub id: JobId,
    /// Job start time (absolute sim time).
    pub start: Time,
    /// Expected end under the current limit (absolute sim time).
    pub cur_end: Time,
    pub nodes: u32,
    /// Newest reported checkpoint timestamp (absolute sim time).
    pub last_ckpt: Time,
    /// Extensions already granted to this job.
    pub extensions: u32,
    /// Extension seconds already granted to this job.
    pub ext_secs: Time,
    /// Control actions (scancel/scontrol) rejected for this job so far.
    pub rejections: u32,
}

/// The engine outputs relevant to action selection, with the policy's
/// extra margin already folded into `ext_end`.
#[derive(Debug, Clone, Copy)]
pub struct EngineRow {
    /// Predicted next checkpoint completion (f32, engine arithmetic).
    pub pred_next: f32,
    /// Extension target: `pred_next + margin + extra_margin`.
    pub ext_end: f32,
    /// Would extending to `ext_end` delay any queued job?
    pub conflict: bool,
    /// Worst-case delay cost of that extension, node-seconds.
    pub delay_cost: f64,
}

/// A compiled decision policy (see the module docs for the pipeline).
///
/// Implementations are immutable: all per-job state lives in the
/// daemon's dense tables and arrives through [`RowCtx`].
///
/// # The purity rule
///
/// A policy's decision must be a **pure function of [`RowCtx`] and
/// [`EngineRow`] — never of wall-clock `now`** (and not of any other
/// mutable or ambient state). The control plane elides provably no-op
/// polls and batch-skips quiet backfill tick slots
/// (`SlurmConfig::poll_elision`, `backfill_ticks = "on-demand"`): a row
/// whose inputs are unchanged is simply not re-presented, so a
/// time-varying decision would silently diverge from the blind /
/// perpetual reference modes that the equivalence suites pin
/// bit-identical. Rows with a *rejected* action are re-presented every
/// tick (the daemon holds a retry verdict), which is why
/// [`RowCtx::rejections`]-driven behaviour (backoff) stays exact.
///
/// # Writing a custom policy
///
/// Implement the trait (stages 1–3; stage 4, budget accounting, is
/// shared driver code) as a pure row function:
///
/// ```
/// use tailtamer::policy::{Action, DecisionPolicy, EngineRow, RowCtx};
///
/// /// Extend while the job is young, cancel once it has consumed more
/// /// than `max_work` seconds — all derived from the row, never from
/// /// a clock.
/// struct WorkCapped {
///     max_work: i64,
/// }
///
/// impl DecisionPolicy for WorkCapped {
///     fn may_extend(&self, row: &RowCtx) -> bool {
///         row.extensions == 0 && row.last_ckpt - row.start < self.max_work
///     }
///     fn select(&self, _row: &RowCtx, out: &EngineRow, may_extend: bool) -> Action {
///         if may_extend && !out.conflict { Action::Extend } else { Action::Cancel }
///     }
/// }
///
/// let policy = WorkCapped { max_work: 2_000 };
/// let row = RowCtx {
///     id: tailtamer::slurm::JobId(7),
///     start: 0,
///     cur_end: 1440,
///     nodes: 1,
///     last_ckpt: 1260,
///     extensions: 0,
///     ext_secs: 0,
///     rejections: 0,
/// };
/// let out = EngineRow { pred_next: 1680.0, ext_end: 1710.0, conflict: false, delay_cost: 0.0 };
/// assert_eq!(policy.select(&row, &out, policy.may_extend(&row)), Action::Extend);
/// ```
///
/// To *ship* a policy through config, CLI, and sweeps, add one
/// [`REGISTRY`] entry (name, aliases, parameter ranges) plus the
/// matching [`PolicySpec`] variant arms (`from_params`, `name`,
/// `display`, `compile`) — everything else (TOML `[policy]` tables,
/// `--policy`/`--policies`, `--list-policies`, report columns, bench
/// fields) picks it up from the spec.
pub trait DecisionPolicy {
    /// Whether the daemon polls at all (Baseline: `false`).
    fn active(&self) -> bool {
        true
    }

    /// Stage 1 — eligibility gate: may this job still be extended?
    fn may_extend(&self, row: &RowCtx) -> bool;

    /// Stage 2 — extra fit margin (seconds, f32) on top of the
    /// configured margin. Zero reproduces the engine's fit bit exactly.
    fn extra_margin(&self, row: &RowCtx) -> f32 {
        let _ = row;
        0.0
    }

    /// Stage 3 — action selection for a not-fitting row. `may_extend`
    /// is stage 1's verdict for this row.
    fn select(&self, row: &RowCtx, out: &EngineRow, may_extend: bool) -> Action;
}

// ---------------------------------------------------------------------
// PolicySpec: the parsed, parameterized policy family.
// ---------------------------------------------------------------------

/// A parsed policy: name + validated parameters. The canonical string
/// form ([`name`](Self::name)) round-trips through
/// [`parse`](Self::parse), TOML, and the CLI.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    /// No adjustments (the paper's comparison baseline).
    Baseline,
    /// Cancel after the last checkpoint that fits the initial limit.
    EarlyCancel,
    /// Extend for exactly one more checkpoint, then cancel gracefully.
    Extend,
    /// Extend iff no queued job would be delayed; else cancel early.
    Hybrid,
    /// Extend repeatedly while a per-job budget of extension seconds
    /// lasts; cancel once the next extension would not fit the budget.
    ExtendBudget { budget: Time },
    /// TARE-style tail-aware cancellation: cancel only when the
    /// predicted tail waste (current end minus last checkpoint) exceeds
    /// `frac` × the checkpointed work (last checkpoint minus start);
    /// otherwise leave the job alone.
    TailAware { frac: f64 },
    /// Hybrid whose fit margin widens by `step` seconds after each
    /// rejected control action for that job (capped at 10 × `step`) —
    /// a jitter-robust variant that turns conservative exactly where
    /// the control surface has proven flaky.
    HybridBackoff { step: Time },
}

/// One parameter a policy accepts: TOML key, inclusive range, default.
#[derive(Debug, Clone, Copy)]
pub struct ParamSpec {
    pub key: &'static str,
    pub min: f64,
    pub max: f64,
    pub default: f64,
    pub doc: &'static str,
}

/// One policy family entry: canonical name, CLI aliases, parameters.
#[derive(Debug, Clone, Copy)]
pub struct PolicyInfo {
    pub name: &'static str,
    pub aliases: &'static [&'static str],
    pub doc: &'static str,
    pub params: &'static [ParamSpec],
}

/// The policy registry — the single authority for names, aliases,
/// parameter keys, ranges, and defaults. Parsing (string and table
/// form), validation diagnostics, and `--list-policies` all read it.
pub const REGISTRY: &[PolicyInfo] = &[
    PolicyInfo {
        name: "baseline",
        aliases: &["none"],
        doc: "no adjustments (the paper's comparison baseline)",
        params: &[],
    },
    PolicyInfo {
        name: "early-cancel",
        aliases: &["earlycancel", "ec"],
        doc: "cancel after the last checkpoint that fits the limit",
        params: &[],
    },
    PolicyInfo {
        name: "extend",
        aliases: &["extension", "tle"],
        doc: "extend for exactly one more checkpoint, then cancel",
        params: &[],
    },
    PolicyInfo {
        name: "hybrid",
        aliases: &[],
        doc: "extend iff no queued job would be delayed, else cancel",
        params: &[],
    },
    PolicyInfo {
        name: "extend-budget",
        aliases: &["extendbudget"],
        doc: "extend repeatedly while a per-job extension budget lasts",
        params: &[ParamSpec {
            key: "budget",
            min: 1.0,
            max: 86_400.0,
            default: 1_200.0,
            doc: "extension budget per job, seconds",
        }],
    },
    PolicyInfo {
        name: "tail-aware",
        aliases: &["tailaware", "tare"],
        doc: "cancel only when predicted tail waste exceeds FRAC x the checkpointed work",
        params: &[ParamSpec {
            key: "tail_frac",
            min: 1e-6,
            max: 100.0,
            default: 0.25,
            doc: "tail-waste threshold as a fraction of checkpointed work",
        }],
    },
    PolicyInfo {
        name: "hybrid-backoff",
        aliases: &["hybridbackoff"],
        doc: "hybrid whose fit margin widens after each rejected action",
        params: &[ParamSpec {
            key: "backoff_step",
            min: 1.0,
            max: 3_600.0,
            default: 60.0,
            doc: "extra fit margin per rejected action, seconds",
        }],
    },
];

/// Look a policy up by canonical name or alias.
pub fn registry_entry(name: &str) -> Option<&'static PolicyInfo> {
    REGISTRY.iter().find(|p| p.name == name || p.aliases.contains(&name))
}

fn known_names() -> String {
    REGISTRY.iter().map(|p| p.name).collect::<Vec<_>>().join(", ")
}

impl From<Policy> for PolicySpec {
    fn from(p: Policy) -> Self {
        match p {
            Policy::Baseline => PolicySpec::Baseline,
            Policy::EarlyCancel => PolicySpec::EarlyCancel,
            Policy::Extend => PolicySpec::Extend,
            Policy::Hybrid => PolicySpec::Hybrid,
        }
    }
}

impl std::fmt::Display for PolicySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

impl PolicySpec {
    /// The three legacy autonomy policies plus baseline — the default
    /// sweep/compare grid (the paper's Table 1 shape).
    pub fn legacy_all() -> [PolicySpec; 4] {
        [PolicySpec::Baseline, PolicySpec::EarlyCancel, PolicySpec::Extend, PolicySpec::Hybrid]
    }

    /// The shipped parameterized (non-legacy) policies at their
    /// registry defaults — what benches and sweeps race by default.
    pub fn parameterized_defaults() -> [PolicySpec; 3] {
        [
            PolicySpec::ExtendBudget { budget: 1_200 },
            PolicySpec::TailAware { frac: 0.25 },
            PolicySpec::HybridBackoff { step: 60 },
        ]
    }

    /// Canonical spec string: round-trips through [`parse`](Self::parse)
    /// and keys every per-policy report column and bench field.
    pub fn name(&self) -> String {
        match self {
            PolicySpec::Baseline => "baseline".into(),
            PolicySpec::EarlyCancel => "early-cancel".into(),
            PolicySpec::Extend => "extend".into(),
            PolicySpec::Hybrid => "hybrid".into(),
            PolicySpec::ExtendBudget { budget } => format!("extend-budget:{budget}"),
            PolicySpec::TailAware { frac } => format!("tail-aware:{frac}"),
            PolicySpec::HybridBackoff { step } => format!("hybrid-backoff:{step}"),
        }
    }

    /// Human title for tables (legacy names match the paper's Table 1).
    pub fn display(&self) -> String {
        match self {
            PolicySpec::Baseline => "Baseline".into(),
            PolicySpec::EarlyCancel => "Early Cancellation".into(),
            PolicySpec::Extend => "Time Limit Extension".into(),
            PolicySpec::Hybrid => "Hybrid Approach".into(),
            PolicySpec::ExtendBudget { budget } => format!("Extension Budget ({budget} s)"),
            PolicySpec::TailAware { frac } => format!("Tail-Aware Cancel ({frac})"),
            PolicySpec::HybridBackoff { step } => format!("Hybrid Backoff ({step} s)"),
        }
    }

    /// Is this the daemon-off baseline?
    pub fn is_baseline(&self) -> bool {
        matches!(self, PolicySpec::Baseline)
    }

    /// Parse the CLI / `daemon.policy` string form:
    /// `name` or `name:param` (the single primary parameter). Errors
    /// name the offending part and list the alternatives.
    pub fn parse(s: &str) -> Result<PolicySpec> {
        let s = s.trim().to_ascii_lowercase();
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p.trim())),
            None => (s.as_str(), None),
        };
        let info = registry_entry(name).ok_or_else(|| {
            crate::errors::Error::msg(format!(
                "unknown policy {name:?}; known policies: {} (see --list-policies)",
                known_names()
            ))
        })?;
        let mut params = BTreeMap::new();
        if let Some(p) = param {
            let Some(spec) = info.params.first() else {
                bail!("policy {:?} takes no parameter (got {p:?})", info.name);
            };
            let v: f64 = p.parse().map_err(|_| {
                crate::errors::Error::msg(format!(
                    "policy {}: parameter {} must be a number (got {p:?})",
                    info.name, spec.key
                ))
            })?;
            params.insert(spec.key.to_string(), Value::Float(v));
        }
        Self::from_params(info.name, &params)
    }

    /// Parse a comma-separated list of spec strings (`--policies`).
    /// At least one policy is required — downstream consumers (the
    /// comparison tables) treat the first entry as the baseline.
    pub fn parse_list(s: &str) -> Result<Vec<PolicySpec>> {
        let list: Vec<PolicySpec> =
            s.split(',').filter(|p| !p.trim().is_empty()).map(Self::parse).collect::<Result<_>>()?;
        if list.is_empty() {
            bail!("empty policy list {s:?}; give at least one policy (see --list-policies)");
        }
        Ok(list)
    }

    /// Build a spec from a name plus a `key = value` parameter table
    /// (the TOML `[policy]` section). Every key must belong to the
    /// named policy; values must sit inside the registry range.
    pub fn from_params(name: &str, params: &BTreeMap<String, Value>) -> Result<PolicySpec> {
        let info = registry_entry(name).ok_or_else(|| {
            crate::errors::Error::msg(format!(
                "unknown policy {name:?}; known policies: {} (see --list-policies)",
                known_names()
            ))
        })?;
        for key in params.keys() {
            if !info.params.iter().any(|p| p.key == key.as_str()) {
                let valid: Vec<&str> = info.params.iter().map(|p| p.key).collect();
                bail!(
                    "policy {}: unknown parameter {key:?}{}",
                    info.name,
                    if valid.is_empty() {
                        " (this policy takes no parameters)".to_string()
                    } else {
                        format!(" (valid: {})", valid.join(", "))
                    }
                );
            }
        }
        let get = |spec: &ParamSpec| -> Result<f64> {
            let v = match params.get(spec.key) {
                Some(v) => v.as_float()?,
                None => spec.default,
            };
            if !(spec.min..=spec.max).contains(&v) {
                bail!(
                    "policy {}: {} = {v} out of range [{}, {}] ({})",
                    info.name,
                    spec.key,
                    spec.min,
                    spec.max,
                    spec.doc
                );
            }
            Ok(v)
        };
        Ok(match info.name {
            "baseline" => PolicySpec::Baseline,
            "early-cancel" => PolicySpec::EarlyCancel,
            "extend" => PolicySpec::Extend,
            "hybrid" => PolicySpec::Hybrid,
            "extend-budget" => PolicySpec::ExtendBudget { budget: get(&info.params[0])? as Time },
            "tail-aware" => PolicySpec::TailAware { frac: get(&info.params[0])? },
            "hybrid-backoff" => {
                PolicySpec::HybridBackoff { step: get(&info.params[0])? as Time }
            }
            // A registry entry without a constructor arm is a wiring
            // bug, but it must fail as a diagnostic, not a panic — the
            // path is reachable from ordinary CLI/TOML input.
            other => bail!(
                "policy {other:?} is registered but has no constructor; \
                 add a from_params arm (and name()/display()/compile())"
            ),
        })
    }

    /// `--list-policies` text, generated from the registry.
    pub fn list_text() -> String {
        use std::fmt::Write as _;
        let mut s = String::from(
            "available policies (--policy NAME[:PARAM] on the CLI,\n\
             `policy = \"NAME[:PARAM]\"` under [daemon], or a [policy] table in TOML):\n",
        );
        for p in REGISTRY {
            let _ = writeln!(s, "  {:<16} {}", p.name, p.doc);
            for par in p.params {
                let _ = writeln!(
                    s,
                    "  {:<16}   param {} — {}, default {}, range [{}, {}]",
                    "", par.key, par.doc, par.default, par.min, par.max
                );
            }
            if !p.aliases.is_empty() {
                let _ = writeln!(s, "  {:<16}   aliases: {}", "", p.aliases.join(", "));
            }
        }
        s
    }

    /// Compile into the staged pipeline the daemon drives. `cfg`
    /// supplies the shared knobs (Hybrid's `max_delay_cost`).
    pub fn compile(&self, cfg: &DaemonConfig) -> Box<dyn DecisionPolicy> {
        match self {
            PolicySpec::Baseline => Box::new(BaselinePolicy),
            PolicySpec::EarlyCancel => Box::new(EarlyCancelPolicy),
            PolicySpec::Extend => Box::new(ExtendPolicy),
            PolicySpec::Hybrid => {
                Box::new(HybridPolicy { max_delay_cost: cfg.max_delay_cost })
            }
            PolicySpec::ExtendBudget { budget } => {
                Box::new(ExtendBudgetPolicy { budget: *budget })
            }
            PolicySpec::TailAware { frac } => Box::new(TailAwarePolicy {
                frac: *frac,
                hazard: if cfg.failure_mtbf > 0 { 1.0 / cfg.failure_mtbf as f64 } else { 0.0 },
            }),
            PolicySpec::HybridBackoff { step } => Box::new(HybridBackoffPolicy {
                max_delay_cost: cfg.max_delay_cost,
                step: *step,
            }),
        }
    }
}

// ---------------------------------------------------------------------
// Compiled policies. The three legacy ones reproduce the retained
// legacy driver decision for decision (pinned by the golden suites).
// ---------------------------------------------------------------------

struct BaselinePolicy;

impl DecisionPolicy for BaselinePolicy {
    fn active(&self) -> bool {
        false
    }
    fn may_extend(&self, _row: &RowCtx) -> bool {
        false
    }
    fn select(&self, _row: &RowCtx, _out: &EngineRow, _may_extend: bool) -> Action {
        unreachable!("baseline never polls")
    }
}

struct EarlyCancelPolicy;

impl DecisionPolicy for EarlyCancelPolicy {
    fn may_extend(&self, _row: &RowCtx) -> bool {
        false
    }
    fn select(&self, _row: &RowCtx, _out: &EngineRow, _may_extend: bool) -> Action {
        Action::Cancel
    }
}

struct ExtendPolicy;

impl DecisionPolicy for ExtendPolicy {
    /// At most one extension (the paper's TLE): after the bonus
    /// checkpoint the next not-fitting poll cancels gracefully.
    fn may_extend(&self, row: &RowCtx) -> bool {
        row.extensions == 0
    }
    fn select(&self, _row: &RowCtx, _out: &EngineRow, may_extend: bool) -> Action {
        if may_extend { Action::Extend } else { Action::Cancel }
    }
}

struct HybridPolicy {
    max_delay_cost: f64,
}

impl DecisionPolicy for HybridPolicy {
    fn may_extend(&self, row: &RowCtx) -> bool {
        row.extensions == 0
    }
    fn select(&self, _row: &RowCtx, out: &EngineRow, may_extend: bool) -> Action {
        // Strict hybrid at threshold 0 (the conflict flag);
        // threshold-Hybrid tolerates a bounded delay cost.
        if may_extend && (!out.conflict || out.delay_cost <= self.max_delay_cost) {
            Action::Extend
        } else {
            Action::Cancel
        }
    }
}

struct ExtendBudgetPolicy {
    budget: Time,
}

impl DecisionPolicy for ExtendBudgetPolicy {
    fn may_extend(&self, row: &RowCtx) -> bool {
        row.ext_secs < self.budget
    }
    fn select(&self, row: &RowCtx, out: &EngineRow, may_extend: bool) -> Action {
        // The next extension is approved against its *predicted* cost
        // (ext_end - cur_end): it must fit the remaining budget. The
        // control plane may still clamp a stale request up to the
        // current poll instant, so the booked spend can overshoot the
        // budget by at most one poll period (+1 s) on the final grant —
        // the bound the property suite asserts.
        let needed = (out.ext_end.ceil() as Time - row.cur_end).max(1);
        if may_extend && row.ext_secs + needed <= self.budget {
            Action::Extend
        } else {
            Action::Cancel
        }
    }
}

struct TailAwarePolicy {
    frac: f64,
    /// Failure-hazard rate (1/MTBF, from `[failures] mtbf` via
    /// [`DaemonConfig::failure_mtbf`]): with node failures possible,
    /// un-checkpointed tail time is at risk of being lost *twice* —
    /// once at the limit and once at any failure instant inside it —
    /// so the effective tail cost grows with the exposure window.
    /// Exactly 0.0 with failures off, which keeps the verdict
    /// bit-identical to the pre-hazard policy (`tail * 1.0 == tail`).
    hazard: f64,
}

impl DecisionPolicy for TailAwarePolicy {
    fn may_extend(&self, _row: &RowCtx) -> bool {
        false
    }
    fn select(&self, row: &RowCtx, _out: &EngineRow, _may_extend: bool) -> Action {
        // Predicted tail waste if left alone: the run from the last
        // completed checkpoint to the limit. Checkpointed work: start
        // to the last checkpoint. Both derive from the snapshot and
        // the report history, so the verdict is stable until a new
        // checkpoint or a limit change re-presents the row.
        let tail = (row.cur_end - row.last_ckpt).max(0) as f64;
        let work = (row.last_ckpt - row.start).max(0) as f64;
        // Hazard term: expected extra loss ≈ tail · (tail/MTBF) — the
        // probability a failure lands in the exposure window times the
        // tail at stake — so checkpoint value rises as MTBF drops.
        if tail * (1.0 + self.hazard * tail) > self.frac * work {
            Action::Cancel
        } else {
            Action::Leave
        }
    }
}

struct HybridBackoffPolicy {
    max_delay_cost: f64,
    step: Time,
}

impl HybridBackoffPolicy {
    /// Extra fit margin grows one step per rejected action, capped at
    /// ten steps so a permanently failing control surface cannot push
    /// the prediction to infinity.
    fn extra(&self, row: &RowCtx) -> Time {
        (self.step * row.rejections.min(10) as Time).max(0)
    }
}

impl DecisionPolicy for HybridBackoffPolicy {
    fn may_extend(&self, row: &RowCtx) -> bool {
        row.extensions == 0
    }
    fn extra_margin(&self, row: &RowCtx) -> f32 {
        self.extra(row) as f32
    }
    fn select(&self, _row: &RowCtx, out: &EngineRow, may_extend: bool) -> Action {
        if may_extend && (!out.conflict || out.delay_cost <= self.max_delay_cost) {
            Action::Extend
        } else {
            Action::Cancel
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row() -> RowCtx {
        RowCtx {
            id: JobId(0),
            start: 0,
            cur_end: 1440,
            nodes: 1,
            last_ckpt: 1260,
            extensions: 0,
            ext_secs: 0,
            rejections: 0,
        }
    }

    fn out() -> EngineRow {
        EngineRow { pred_next: 1680.0, ext_end: 1710.0, conflict: false, delay_cost: 0.0 }
    }

    #[test]
    fn canonical_names_round_trip() {
        for spec in PolicySpec::legacy_all()
            .into_iter()
            .chain(PolicySpec::parameterized_defaults())
            .chain([
                PolicySpec::ExtendBudget { budget: 333 },
                PolicySpec::TailAware { frac: 0.5 },
                PolicySpec::HybridBackoff { step: 90 },
            ])
        {
            let back = PolicySpec::parse(&spec.name()).unwrap();
            assert_eq!(back, spec, "round trip failed for {}", spec.name());
        }
    }

    #[test]
    fn aliases_and_defaults_parse() {
        assert_eq!(PolicySpec::parse("ec").unwrap(), PolicySpec::EarlyCancel);
        assert_eq!(PolicySpec::parse("tle").unwrap(), PolicySpec::Extend);
        assert_eq!(PolicySpec::parse("none").unwrap(), PolicySpec::Baseline);
        assert_eq!(
            PolicySpec::parse("extend-budget").unwrap(),
            PolicySpec::ExtendBudget { budget: 1_200 },
            "bare name takes the registry default"
        );
        assert_eq!(PolicySpec::parse("tare:0.1").unwrap(), PolicySpec::TailAware { frac: 0.1 });
    }

    #[test]
    fn unknown_and_out_of_range_fail_actionably() {
        let e = PolicySpec::parse("does-not-exist").unwrap_err().to_string();
        assert!(e.contains("unknown policy") && e.contains("early-cancel"), "{e}");
        let e = PolicySpec::parse("extend-budget:0").unwrap_err().to_string();
        assert!(e.contains("out of range"), "{e}");
        let e = PolicySpec::parse("tail-aware:-1").unwrap_err().to_string();
        assert!(e.contains("out of range"), "{e}");
        let e = PolicySpec::parse("hybrid-backoff:999999").unwrap_err().to_string();
        assert!(e.contains("out of range"), "{e}");
        let e = PolicySpec::parse("early-cancel:5").unwrap_err().to_string();
        assert!(e.contains("takes no parameter"), "{e}");
        let e = PolicySpec::parse("extend-budget:abc").unwrap_err().to_string();
        assert!(e.contains("must be a number"), "{e}");
    }

    #[test]
    fn table_form_validates_keys_and_ranges() {
        let mut params = BTreeMap::new();
        params.insert("budget".to_string(), Value::Int(600));
        assert_eq!(
            PolicySpec::from_params("extend-budget", &params).unwrap(),
            PolicySpec::ExtendBudget { budget: 600 }
        );
        let mut wrong = BTreeMap::new();
        wrong.insert("tail_frac".to_string(), Value::Float(0.2));
        let e = PolicySpec::from_params("extend-budget", &wrong).unwrap_err().to_string();
        assert!(e.contains("unknown parameter") && e.contains("budget"), "{e}");
        let e = PolicySpec::from_params("hybrid", &wrong).unwrap_err().to_string();
        assert!(e.contains("takes no parameters"), "{e}");
    }

    #[test]
    fn parse_list_splits_specs() {
        let l = PolicySpec::parse_list("baseline, ec, extend-budget:300").unwrap();
        assert_eq!(
            l,
            vec![
                PolicySpec::Baseline,
                PolicySpec::EarlyCancel,
                PolicySpec::ExtendBudget { budget: 300 }
            ]
        );
        assert!(PolicySpec::parse_list("ec,nope").is_err());
        // Degenerate inputs fail loudly instead of yielding an empty
        // grid that would panic downstream.
        for empty in ["", ",", " , "] {
            let e = PolicySpec::parse_list(empty).unwrap_err().to_string();
            assert!(e.contains("empty policy list"), "{empty:?}: {e}");
        }
    }

    #[test]
    fn list_text_covers_registry() {
        let t = PolicySpec::list_text();
        for p in REGISTRY {
            assert!(t.contains(p.name), "missing {}", p.name);
            for par in p.params {
                assert!(t.contains(par.key), "missing param {}", par.key);
            }
        }
    }

    #[test]
    fn legacy_policies_reproduce_enum_decisions() {
        let cfg = DaemonConfig::default();
        let r = row();
        let o = out();
        let ec = PolicySpec::EarlyCancel.compile(&cfg);
        assert_eq!(ec.select(&r, &o, ec.may_extend(&r)), Action::Cancel);
        let ex = PolicySpec::Extend.compile(&cfg);
        assert_eq!(ex.select(&r, &o, ex.may_extend(&r)), Action::Extend);
        let extended = RowCtx { extensions: 1, ..r };
        assert_eq!(ex.select(&extended, &o, ex.may_extend(&extended)), Action::Cancel);
        let hy = PolicySpec::Hybrid.compile(&cfg);
        assert_eq!(hy.select(&r, &o, hy.may_extend(&r)), Action::Extend);
        let conflicted = EngineRow { conflict: true, delay_cost: 100.0, ..o };
        assert_eq!(hy.select(&r, &conflicted, hy.may_extend(&r)), Action::Cancel);
        let tolerant =
            PolicySpec::Hybrid.compile(&DaemonConfig { max_delay_cost: 1e6, ..cfg.clone() });
        assert_eq!(tolerant.select(&r, &conflicted, tolerant.may_extend(&r)), Action::Extend);
    }

    #[test]
    fn extend_budget_stops_at_the_budget() {
        let p = PolicySpec::ExtendBudget { budget: 500 }.compile(&DaemonConfig::default());
        let r = row();
        // First extension needs 1710 - 1440 = 270 s: fits the budget.
        assert_eq!(p.select(&r, &out(), p.may_extend(&r)), Action::Extend);
        // 270 already spent: another 270 would overdraw 500.
        let spent = RowCtx { extensions: 1, ext_secs: 270, ..r };
        assert_eq!(p.select(&spent, &out(), p.may_extend(&spent)), Action::Cancel);
        // A tighter history (cheaper extension) still fits.
        let cheap = EngineRow { ext_end: 1660.0, ..out() };
        assert_eq!(p.select(&spent, &cheap, p.may_extend(&spent)), Action::Extend);
    }

    #[test]
    fn tail_aware_cancels_only_large_tails() {
        let cfg = DaemonConfig::default();
        // Canonical row: tail 180, work 1260 (ratio ~0.143).
        let r = row();
        let strict = PolicySpec::TailAware { frac: 0.1 }.compile(&cfg);
        assert_eq!(strict.select(&r, &out(), false), Action::Cancel);
        let lax = PolicySpec::TailAware { frac: 0.25 }.compile(&cfg);
        assert_eq!(lax.select(&r, &out(), false), Action::Leave);
        // No checkpointed work at all: any tail is infinite relative.
        let fresh = RowCtx { last_ckpt: 0, ..r };
        assert_eq!(lax.select(&fresh, &out(), false), Action::Cancel);
    }

    #[test]
    fn tail_aware_hazard_raises_checkpoint_value() {
        // Canonical row: tail 180, work 1260; frac 0.25 leaves it
        // alone in a calm cluster (180 < 315)...
        let spec = PolicySpec::TailAware { frac: 0.25 };
        let calm = spec.compile(&DaemonConfig::default());
        assert_eq!(calm.select(&row(), &out(), false), Action::Leave);
        // ...but with MTBF 200 s the hazard term inflates the tail
        // cost: 180 · (1 + 180/200) = 342 > 315 → cancel early.
        let cfg = DaemonConfig { failure_mtbf: 200, ..DaemonConfig::default() };
        let hazardous = spec.compile(&cfg);
        assert_eq!(hazardous.select(&row(), &out(), false), Action::Cancel);
        // Long MTBF: the term is negligible, verdict unchanged.
        let mild = spec.compile(&DaemonConfig { failure_mtbf: 1_000_000, ..cfg });
        assert_eq!(mild.select(&row(), &out(), false), Action::Leave);
    }

    #[test]
    fn backoff_margin_grows_and_caps() {
        let p = PolicySpec::HybridBackoff { step: 60 }.compile(&DaemonConfig::default());
        assert_eq!(p.extra_margin(&row()), 0.0);
        assert_eq!(p.extra_margin(&RowCtx { rejections: 2, ..row() }), 120.0);
        assert_eq!(p.extra_margin(&RowCtx { rejections: 50, ..row() }), 600.0, "capped at 10 steps");
    }
}
