//! Checkpoint progress reporting.
//!
//! The paper's protocol is deliberately minimal: after each completed
//! checkpoint the application appends a timestamp to a per-job file;
//! the daemon reads these files on every poll. This module provides
//!
//! - [`ReportBook`]: the daemon-side per-job rolling history (last `H`
//!   timestamps, matching the decision model's history window), fed
//!   from whatever transport is in use;
//! - [`FileSpool`]: the real temp-file transport for live mode —
//!   applications append `"<unix_ts>\n"` lines, the daemon lists and
//!   reads the spool directory (exactly Fig. 2's mechanism). The
//!   simulated transport is [`crate::slurm::SlurmControl::read_ckpt_reports`].

use std::io::Write;
use std::path::PathBuf;

use crate::errors::{Context, Result};

use crate::jobtable::JobTable;
use crate::simtime::Time;
use crate::slurm::JobId;

/// Rolling per-job checkpoint history, bounded to the newest `cap`
/// entries (the decision model's H window).
///
/// Stored as a sliding window over a doubled backing buffer: pushes
/// append, and only when the buffer reaches `2·cap` entries is the live
/// window copied back to the front. Amortized O(1) per push — the seed
/// did a `remove(0)` memmove of the whole window on *every* ingest once
/// full — while [`timestamps`](Self::timestamps) keeps returning one
/// contiguous ascending slice.
#[derive(Debug, Clone)]
pub struct History {
    cap: usize,
    ts: Vec<Time>,
}

impl History {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 2, "need at least two timestamps to estimate an interval");
        Self { cap, ts: Vec::with_capacity(2 * cap) }
    }

    /// Timestamps currently retained (the newest ≤ `cap`), ascending.
    pub fn timestamps(&self) -> &[Time] {
        &self.ts[self.ts.len().saturating_sub(self.cap)..]
    }

    pub fn len(&self) -> usize {
        self.ts.len().min(self.cap)
    }

    pub fn is_empty(&self) -> bool {
        self.ts.is_empty()
    }

    pub fn last(&self) -> Option<Time> {
        self.ts.last().copied()
    }

    fn push(&mut self, t: Time) {
        debug_assert!(self.ts.last().is_none_or(|&l| t > l));
        if self.ts.len() == 2 * self.cap {
            // Compact: slide the live window back to the front. Happens
            // once per `cap` pushes, so pushes stay amortized O(1).
            self.ts.copy_within(self.cap.., 0);
            self.ts.truncate(self.cap);
        }
        self.ts.push(t);
    }
}

/// Daemon-side ledger of every reporting job's history.
///
/// Stored as a dense [`JobTable`]`<Option<History>>` indexed by the
/// dense [`JobId`], matching the daemon's other per-job tables
/// (§Perf): the hot-path lookups — one `history()` per candidate row
/// per poll, one `ingest()` per running reporting job — are an index
/// and a branch instead of a hash. Entries are `None` until a job
/// first reports and again after [`forget`](Self::forget), which frees
/// that job's history buffer — so the *history* memory is bounded by
/// the widest concurrent reporting set, while the table spine is
/// bounded by the live id window: the daemon retires the spine behind
/// the control plane's watermark ([`retire_to`](Self::retire_to)), so
/// at federation scale it does not grow one word per id ever seen.
#[derive(Debug)]
pub struct ReportBook {
    window: usize,
    jobs: JobTable<Option<History>>,
    /// Jobs with a live history (`Some` slots).
    live: usize,
    /// Total reports ingested (observability).
    pub ingested: u64,
}

impl ReportBook {
    pub fn new(window: usize) -> Self {
        Self { window, jobs: JobTable::new(), live: 0, ingested: 0 }
    }

    /// Ingest the *full* report list for `id` (the transport always
    /// returns the whole file); only strictly newer timestamps extend
    /// the history — replayed or reordered lines are ignored, which is
    /// what makes the daemon robust to duplicated writes.
    pub fn ingest(&mut self, id: JobId, reports: &[Time]) {
        if reports.is_empty() {
            return;
        }
        let idx = id.0 as usize;
        self.jobs.ensure(idx + 1);
        let slot = &mut self.jobs[idx];
        if slot.is_none() {
            *slot = Some(History::new(self.window));
            self.live += 1;
        }
        let h = slot.as_mut().expect("just ensured");
        let newest = h.last().unwrap_or(Time::MIN);
        for &t in reports {
            if t > newest && h.last().is_none_or(|l| t > l) {
                h.push(t);
                self.ingested += 1;
            }
        }
    }

    pub fn history(&self, id: JobId) -> Option<&History> {
        self.jobs.get(id.0 as usize)?.as_ref()
    }

    /// Drop state for a finished job.
    pub fn forget(&mut self, id: JobId) {
        if let Some(slot) = self.jobs.get_mut(id.0 as usize) {
            if slot.take().is_some() {
                self.live -= 1;
            }
        }
    }

    /// Retire the table spine below `watermark` (caller guarantees all
    /// those jobs were already [`forget`](Self::forget)ten — the
    /// daemon clamps by its lowest tracked id).
    pub fn retire_to(&mut self, watermark: usize) {
        self.jobs.retire_to(watermark);
    }

    /// High-water resident bytes of the table spine (history buffers
    /// are bounded separately by the reporting window).
    pub fn peak_bytes(&self) -> usize {
        self.jobs.peak_bytes()
    }

    pub fn tracked(&self) -> usize {
        self.live
    }
}

/// The live-mode temp-file transport (one file per job in a spool dir).
#[derive(Debug, Clone)]
pub struct FileSpool {
    dir: PathBuf,
}

impl FileSpool {
    pub fn new(dir: impl Into<PathBuf>) -> Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir).with_context(|| format!("create spool {}", dir.display()))?;
        Ok(Self { dir })
    }

    pub fn path_for(&self, id: JobId) -> PathBuf {
        self.dir.join(format!("ckpt_progress.{}", id.0))
    }

    /// Application side: report a completed checkpoint.
    pub fn report(&self, id: JobId, ts: Time) -> Result<()> {
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.path_for(id))?;
        writeln!(f, "{ts}")?;
        Ok(())
    }

    /// Daemon side: read a job's reported timestamps (ascending; bad
    /// lines are skipped — a crashing app must not wedge the daemon).
    pub fn read(&self, id: JobId) -> Vec<Time> {
        let Ok(data) = std::fs::read_to_string(self.path_for(id)) else {
            return Vec::new();
        };
        let mut out: Vec<Time> = data.lines().filter_map(|l| l.trim().parse().ok()).collect();
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Jobs with a report file present.
    pub fn reporting_jobs(&self) -> Vec<JobId> {
        let Ok(entries) = std::fs::read_dir(&self.dir) else {
            return Vec::new();
        };
        let mut ids: Vec<JobId> = entries
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                e.file_name()
                    .to_str()?
                    .strip_prefix("ckpt_progress.")?
                    .parse()
                    .ok()
                    .map(JobId)
            })
            .collect();
        ids.sort();
        ids
    }

    /// Remove a finished job's file.
    pub fn remove(&self, id: JobId) {
        let _ = std::fs::remove_file(self.path_for(id));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn history_keeps_newest_window() {
        let mut h = History::new(4);
        for t in [10, 20, 30, 40, 50, 60] {
            h.push(t);
        }
        assert_eq!(h.timestamps(), &[30, 40, 50, 60]);
        assert_eq!(h.last(), Some(60));
        assert_eq!(h.len(), 4);
    }

    #[test]
    fn history_window_survives_compaction_boundaries() {
        // Drive far past several 2·cap compactions and check the
        // ascending-slice contract at every step.
        let cap = 4;
        let mut h = History::new(cap);
        for k in 1..=100i64 {
            h.push(k * 10);
            let ts = h.timestamps();
            assert_eq!(ts.len(), (k as usize).min(cap));
            assert_eq!(h.len(), ts.len());
            assert_eq!(*ts.last().unwrap(), k * 10);
            assert!(ts.windows(2).all(|w| w[1] - w[0] == 10), "gap at k={k}: {ts:?}");
            assert_eq!(h.last(), Some(k * 10));
        }
        // Backing storage stays bounded by 2·cap.
        assert!(h.ts.len() <= 2 * cap);
    }

    #[test]
    fn book_ignores_duplicates_and_stale() {
        let mut b = ReportBook::new(8);
        b.ingest(JobId(1), &[100, 200]);
        b.ingest(JobId(1), &[100, 200, 300]); // full-file re-read
        b.ingest(JobId(1), &[250]); // stale/odd line
        assert_eq!(b.history(JobId(1)).unwrap().timestamps(), &[100, 200, 300]);
        assert_eq!(b.ingested, 3);
    }

    #[test]
    fn book_tracks_multiple_jobs_independently() {
        let mut b = ReportBook::new(8);
        b.ingest(JobId(1), &[100]);
        b.ingest(JobId(2), &[50, 60]);
        assert_eq!(b.tracked(), 2);
        b.forget(JobId(1));
        assert_eq!(b.tracked(), 1);
        assert!(b.history(JobId(1)).is_none());
    }

    #[test]
    fn empty_reports_do_not_create_entries() {
        let mut b = ReportBook::new(8);
        b.ingest(JobId(5), &[]);
        assert_eq!(b.tracked(), 0);
    }

    #[test]
    fn file_spool_roundtrip() {
        let dir = std::env::temp_dir().join(format!("tt_spool_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = FileSpool::new(&dir).unwrap();
        spool.report(JobId(3), 420).unwrap();
        spool.report(JobId(3), 840).unwrap();
        spool.report(JobId(7), 100).unwrap();
        assert_eq!(spool.read(JobId(3)), vec![420, 840]);
        assert_eq!(spool.reporting_jobs(), vec![JobId(3), JobId(7)]);
        assert_eq!(spool.read(JobId(99)), Vec::<Time>::new());
        spool.remove(JobId(3));
        assert_eq!(spool.reporting_jobs(), vec![JobId(7)]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_spool_tolerates_garbage_lines() {
        let dir = std::env::temp_dir().join(format!("tt_spool_g_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spool = FileSpool::new(&dir).unwrap();
        std::fs::write(spool.path_for(JobId(1)), "420\nnot-a-number\n\n840\n840\n").unwrap();
        assert_eq!(spool.read(JobId(1)), vec![420, 840]);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
