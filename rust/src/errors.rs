//! Minimal error substrate (the offline vendor set has no `anyhow`).
//!
//! Provides the small surface the crate actually uses from `anyhow`:
//! a single string-chained [`Error`] type, the crate-wide [`Result`]
//! alias, a [`Context`] trait for both `Result` and `Option`, and the
//! [`bail!`](crate::bail) / [`err!`](crate::err) macros. Context is
//! chained textually (`"outer: inner"`), which is all the CLI and the
//! tests ever inspect.

use std::fmt;

/// A boxed, human-readable error message with textual context chain.
#[derive(Debug)]
pub struct Error(Box<str>);

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error(m.to_string().into_boxed_str())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<String> for Error {
    fn from(s: String) -> Self {
        Error(s.into_boxed_str())
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Self {
        Error::msg(s)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseIntError> for Error {
    fn from(e: std::num::ParseIntError) -> Self {
        Error::msg(e)
    }
}

impl From<std::num::ParseFloatError> for Error {
    fn from(e: std::num::ParseFloatError) -> Self {
        Error::msg(e)
    }
}

/// Crate-wide result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a `Result` or `Option` (mirrors `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format_args!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format_args!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($t:tt)*) => { $crate::errors::Error::msg(format_args!($($t)*)) }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => { return Err($crate::err!($($t)*).into()) }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("broke at {}", 42);
    }

    #[test]
    fn bail_formats() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke at 42");
    }

    #[test]
    fn context_chains_on_result() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1: inner");
    }

    #[test]
    fn context_on_option() {
        let o: Option<u32> = None;
        assert_eq!(o.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(5).context("missing").unwrap(), 5);
    }

    #[test]
    fn conversions_compose_with_question_mark() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn alternate_format_is_harmless() {
        // Call sites use anyhow's `{e:#}` chain format; ours is flat.
        let e = err!("top");
        assert_eq!(format!("{e:#}"), "top");
    }
}
