//! I/O-load-correlated checkpoint noise (paper §8, future work item 1).
//!
//! The paper's future work proposes integrating "real-time I/O load to
//! account for the potential slowdown of checkpoints due to system
//! noise". Checkpoint writes share the parallel filesystem, so their
//! durations are **not** i.i.d.: they stretch together when the system
//! is busy. This module provides
//!
//! - [`LoadProfile`]: a synthetic system I/O load timeline `L(t) ∈
//!   [0, 1]` — diurnal base + seeded bursts — standing in for an
//!   LDMS-style monitor feed (ref [1, 15] of the paper);
//! - [`correlated_plan`]: a checkpoint plan whose k-th interval is
//!   `I · (1 + beta · L(t_k))` — the *same* load stretches every job
//!   checkpointing at the same time, which is the regime that breaks
//!   i.i.d.-jitter estimators;
//! - a workload hook ([`apply_io_noise`]) that rewrites a job set's
//!   checkpoint plans against one shared profile.
//!
//! The `ablation_sweeps` bench compares the daemon under i.i.d. vs
//! correlated noise; the safety factor (std-based) compensates for both
//! because correlated stretching *raises the observed interval std* of
//! each individual history.

use crate::proptest_lite::Rng;
use crate::simtime::Time;
use crate::slurm::JobSpec;

/// Synthetic system I/O load timeline, piecewise constant per bucket.
#[derive(Debug, Clone)]
pub struct LoadProfile {
    bucket: Time,
    /// Load in [0, 1] per bucket.
    levels: Vec<f64>,
}

impl LoadProfile {
    /// Diurnal base (period `day`) plus `bursts` random high-load
    /// windows, seeded and deterministic.
    pub fn synthetic(horizon: Time, bucket: Time, day: Time, bursts: usize, seed: u64) -> Self {
        assert!(bucket > 0 && horizon > 0 && day > 0);
        let n = (horizon / bucket + 1) as usize;
        let mut rng = Rng::new(seed);
        let mut levels = vec![0.0f64; n];
        for (i, l) in levels.iter_mut().enumerate() {
            let t = i as f64 * bucket as f64;
            let phase = (t / day as f64) * std::f64::consts::TAU;
            // Busy "daytime" half: base load 0.2–0.5.
            *l = 0.35 + 0.15 * phase.sin();
        }
        for _ in 0..bursts {
            let at = rng.int_in(0, n as i64 - 1) as usize;
            let width = rng.int_in(1, (n as i64 / 20).max(2)) as usize;
            let height = rng.f64_in(0.4, 0.6);
            for l in levels.iter_mut().skip(at).take(width) {
                *l = (*l + height).min(1.0);
            }
        }
        Self { bucket, levels }
    }

    /// A flat (quiet) profile — useful as the control.
    pub fn quiet(horizon: Time, bucket: Time) -> Self {
        Self { bucket, levels: vec![0.0; (horizon / bucket + 1) as usize] }
    }

    /// Load at absolute time `t` (clamped to the profile's ends).
    pub fn at(&self, t: Time) -> f64 {
        let i = (t.max(0) / self.bucket) as usize;
        self.levels[i.min(self.levels.len() - 1)]
    }

    /// Mean load over the whole horizon.
    pub fn mean(&self) -> f64 {
        self.levels.iter().sum::<f64>() / self.levels.len() as f64
    }
}

/// Checkpoint plan with load-correlated intervals: the k-th interval is
/// `interval * (1 + beta * L(start + t_k))`. Offsets are relative to
/// `start` and cover `[0, horizon)`, like `CkptSpec::plan`.
pub fn correlated_plan(
    interval: Time,
    beta: f64,
    start: Time,
    horizon: Time,
    load: &LoadProfile,
) -> Vec<Time> {
    assert!(interval >= 1 && beta >= 0.0);
    let mut out = Vec::new();
    let mut t = 0i64;
    loop {
        let stretch = 1.0 + beta * load.at(start + t);
        t += ((interval as f64) * stretch).round().max(1.0) as Time;
        if t >= horizon {
            return out;
        }
        out.push(t);
    }
}

/// Rewrite every checkpointing job's plan against a shared load profile.
/// Returns per-job plans keyed by position in `specs` (None for
/// non-checkpointing jobs); pair with
/// [`crate::slurm::Slurmd::submit_with_plan`].
pub fn apply_io_noise(specs: &[JobSpec], beta: f64, load: &LoadProfile) -> Vec<Option<Vec<Time>>> {
    specs
        .iter()
        .map(|s| {
            s.ckpt.as_ref().map(|c| {
                // Start times are unknown pre-schedule; the paper's jobs
                // all release at t=0 and start within the makespan, so
                // the plan is drawn at the submit-time load estimate
                // (offset 0). This keeps plans per-job deterministic
                // while still correlated through the shared profile.
                correlated_plan(c.interval, beta, 0, s.duration, load)
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_is_bounded_and_deterministic() {
        let p = LoadProfile::synthetic(100_000, 60, 86_400, 8, 7);
        for t in (0..100_000).step_by(997) {
            let l = p.at(t);
            assert!((0.0..=1.0).contains(&l), "L({t}) = {l}");
        }
        let p2 = LoadProfile::synthetic(100_000, 60, 86_400, 8, 7);
        assert_eq!(p.at(50_000), p2.at(50_000));
        assert!(p.mean() > 0.1 && p.mean() < 0.9);
        assert_eq!(LoadProfile::quiet(1000, 60).mean(), 0.0);
    }

    #[test]
    fn quiet_profile_reproduces_fixed_plan() {
        let quiet = LoadProfile::quiet(10_000, 60);
        let plan = correlated_plan(420, 0.5, 0, 2880, &quiet);
        assert_eq!(plan, vec![420, 840, 1260, 1680, 2100, 2520]);
    }

    #[test]
    fn load_stretches_intervals() {
        let busy = LoadProfile { bucket: 60, levels: vec![1.0; 200] };
        let plan = correlated_plan(420, 0.5, 0, 2880, &busy);
        // Every interval stretched to 630.
        assert_eq!(plan, vec![630, 1260, 1890, 2520]);
        // And beta=0 is immune to load.
        let plan0 = correlated_plan(420, 0.0, 0, 2880, &busy);
        assert_eq!(plan0, vec![420, 840, 1260, 1680, 2100, 2520]);
    }

    #[test]
    fn correlation_is_shared_across_jobs() {
        // Two jobs checkpointing through the same burst see the same
        // stretch — the defining property i.i.d. jitter lacks.
        let mut levels = vec![0.0; 100];
        for l in levels.iter_mut().take(30).skip(10) {
            *l = 1.0;
        }
        let p = LoadProfile { bucket: 60, levels };
        let a = correlated_plan(420, 0.5, 0, 5000, &p);
        let b = correlated_plan(420, 0.5, 0, 5000, &p);
        assert_eq!(a, b);
        // The burst spans 600..1800: intervals *starting* inside it
        // stretch to 630; the ones before and well after stay at 420.
        let steps: Vec<Time> =
            std::iter::once(a[0]).chain(a.windows(2).map(|w| w[1] - w[0])).collect();
        assert_eq!(steps[0], 420, "starts before the burst");
        assert!(steps.iter().any(|&s| s == 630), "some interval must stretch: {steps:?}");
        assert_eq!(*steps.last().unwrap(), 420, "post-burst intervals relax");
    }

    #[test]
    fn apply_io_noise_only_touches_checkpointers() {
        let specs = vec![
            JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420),
            JobSpec::new("plain", 600, 500, 1),
        ];
        let p = LoadProfile::synthetic(10_000, 60, 86_400, 2, 3);
        let plans = apply_io_noise(&specs, 0.3, &p);
        assert!(plans[0].is_some());
        assert!(plans[1].is_none());
        let plan = plans[0].as_ref().unwrap();
        assert!(!plan.is_empty());
        assert!(plan.iter().all(|&t| t < 2880));
    }
}
