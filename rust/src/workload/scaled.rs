//! Scaled synthetic workloads: stretch the PM100-calibrated cohort to
//! arbitrary job and node counts (1k jobs to federation-scale millions,
//! 20–4096 nodes; see [`ScaledConfig::build_sharded`]).
//!
//! The paper replays 773 jobs on 20 nodes; the ROADMAP's target regime
//! is month-long traces with 100k+ jobs — the scale TARE evaluates
//! runtime predictors in, and the regime where RL backfilling needs
//! millions of fast simulator steps. This module keeps the paper's
//! calibrated *marginals* (state mix, node-count shape, limit
//! clustering, the 24 h-cap checkpointing population) and scales two
//! axes independently:
//!
//! - **job count**: the COMPLETED / TIMEOUT-below-cap / TIMEOUT-at-cap
//!   mix keeps the cohort's 556:108:109 proportions;
//! - **node count**: per-job node requests are scaled by
//!   `nodes / 20` (the paper's cluster size) and clamped to the pool,
//!   preserving the distribution's shape at any cluster size.
//!
//! Arrivals are either the paper's all-at-t=0 release (default,
//! backward compatible) or a staggered stream with exponential
//! inter-arrival gaps — exercising the scheduler's `Ev::Submit` path.

use crate::proptest_lite::Rng;
use crate::simtime::Time;
use crate::slurm::JobSpec;

use super::pm100::Pm100Config;
use super::trace::{TraceRecord, WorkloadSpec, scale, to_job_specs};

/// How jobs enter the system.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Arrival {
    /// Everything released at t=0, priority = trace order (the paper).
    AllAtZero,
    /// Exponential inter-arrival gaps with the given mean (seconds,
    /// scaled time); priority = arrival order.
    Staggered { mean_gap: Time },
}

/// Scaled-workload shape.
#[derive(Debug, Clone)]
pub struct ScaledConfig {
    /// Total jobs (the cohort's state mix is preserved).
    pub jobs: usize,
    /// Cluster size; node requests are rescaled from the 20-node base.
    pub nodes: u32,
    pub seed: u64,
    pub arrival: Arrival,
    /// Trace time scale (paper: 60, 1 h → 1 min).
    pub scale_factor: Time,
    /// `true` (default): node requests stretch with the pool, keeping
    /// the paper's ~7-jobs-running utilization shape at any size.
    /// `false`: keep the 1–16-node base requests, producing the
    /// *high-concurrency* regime (hundreds–thousands of concurrent
    /// jobs on a big pool) that stresses the scheduler's per-running-job
    /// hot paths.
    pub rescale_nodes: bool,
}

impl Default for ScaledConfig {
    fn default() -> Self {
        Self {
            jobs: 20_000,
            nodes: 1024,
            seed: 42,
            arrival: Arrival::AllAtZero,
            scale_factor: 60,
            rescale_nodes: true,
        }
    }
}

/// The paper cohort's state proportions (556 : 108 : 109 of 773).
const BASE: (usize, usize, usize) = (556, 108, 109);
const BASE_TOTAL: usize = BASE.0 + BASE.1 + BASE.2;
/// The paper's cluster size the node distribution is calibrated to.
const BASE_NODES: u32 = 20;

impl ScaledConfig {
    /// The underlying pm100 generator config with proportional counts.
    pub fn pm100(&self) -> Pm100Config {
        assert!(self.jobs >= 1, "empty workload");
        let completed = self.jobs * BASE.0 / BASE_TOTAL;
        let below = self.jobs * BASE.1 / BASE_TOTAL;
        let at_cap = self.jobs - completed - below;
        Pm100Config {
            completed,
            timeout_below_cap: below,
            timeout_at_cap: at_cap,
            // Generate with the calibrated 20-node shape; node counts
            // are rescaled afterwards.
            max_nodes: BASE_NODES,
            seed: self.seed,
        }
    }

    /// Generate the scaled cohort in *original* (unscaled-time) units.
    pub fn cohort(&self) -> Vec<TraceRecord> {
        assert!(self.nodes >= 1, "empty cluster");
        let mut out = super::pm100::generate_cohort(&self.pm100());
        if self.rescale_nodes && self.nodes != BASE_NODES {
            for r in &mut out {
                r.nodes = (r.nodes * self.nodes / BASE_NODES).clamp(1, self.nodes);
                r.cores = r.nodes * super::pm100::CORES_PER_NODE;
            }
        } else if !self.rescale_nodes {
            for r in &mut out {
                r.nodes = r.nodes.min(self.nodes);
                r.cores = r.nodes * super::pm100::CORES_PER_NODE;
            }
        }
        out
    }

    /// Generate submittable job specs (cohort → scale → adapt →
    /// arrivals).
    pub fn build(&self) -> Vec<JobSpec> {
        let scaled = scale(&self.cohort(), self.scale_factor);
        let mut specs = to_job_specs(&scaled, &WorkloadSpec::default());
        if let Arrival::Staggered { mean_gap } = self.arrival {
            assert!(mean_gap >= 1, "mean inter-arrival gap must be >= 1 s");
            let mut rng = Rng::new(self.seed ^ 0x5747a66e_a221_71ed);
            let mut t: Time = 0;
            for s in &mut specs {
                // Exponential gap, rounded, floored at 0 so bursts stay
                // possible; arrival order preserves trace priority.
                let u = rng.next_f64();
                t += (-(1.0 - u).ln() * mean_gap as f64).round() as Time;
                s.submit = t;
            }
        }
        specs
    }

    /// [`build`](Self::build) partitioned for a federation of `shards`
    /// clusters (round-robin, master id `m` → shard `m % shards`; see
    /// [`crate::slurm::fed`]).
    ///
    /// ## Shard-invariant seeding
    ///
    /// The master workload is generated **once**, from the single seed
    /// and the single arrival RNG stream, and only then partitioned —
    /// there is no per-shard generator state, so every per-shard RNG
    /// draw sequence is by construction a subsequence of the master
    /// stream. Consequently the shard count can never perturb the
    /// merged workload: reinterleaving `build_sharded(S)` yields
    /// exactly `build()` for every `S` (pinned by the
    /// `shard_count_never_perturbs_the_workload` test). Deriving
    /// per-shard seeds instead (e.g. `seed ^ shard`) would silently
    /// re-roll every marginal whenever the shard count changed, making
    /// federation results incomparable across shard counts.
    pub fn build_sharded(&self, shards: usize) -> Vec<Vec<JobSpec>> {
        crate::slurm::fed::partition(&self.build(), shards)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TraceState;

    #[test]
    fn preserves_state_mix_at_any_size() {
        for jobs in [773, 2000, 20_000] {
            let cfg = ScaledConfig { jobs, nodes: 128, ..Default::default() };
            let cohort = cfg.cohort();
            assert_eq!(cohort.len(), jobs);
            let at_cap = cohort
                .iter()
                .filter(|r| r.state == TraceState::Timeout && r.time_limit == 86_400)
                .count();
            let frac = at_cap as f64 / jobs as f64;
            let base = BASE.2 as f64 / BASE_TOTAL as f64;
            assert!((frac - base).abs() < 0.01, "jobs={jobs}: ckpt share {frac:.3}");
        }
    }

    #[test]
    fn node_counts_scale_with_the_pool() {
        let small = ScaledConfig { jobs: 1000, nodes: 20, ..Default::default() };
        let big = ScaledConfig { jobs: 1000, nodes: 1024, ..Default::default() };
        let max_small = small.cohort().iter().map(|r| r.nodes).max().unwrap();
        let max_big = big.cohort().iter().map(|r| r.nodes).max().unwrap();
        assert!(max_small <= 20);
        assert!(max_big <= 1024);
        assert!(max_big > 100, "node requests must stretch: {max_big}");
        assert!(big.cohort().iter().all(|r| r.nodes >= 1 && r.cores == r.nodes * 48));
    }

    #[test]
    fn all_at_zero_is_backward_compatible() {
        let specs = ScaledConfig { jobs: 500, nodes: 64, ..Default::default() }.build();
        assert_eq!(specs.len(), 500);
        assert!(specs.iter().all(|s| s.submit == 0));
        assert!(specs.iter().any(|s| s.ckpt.is_some()));
    }

    #[test]
    fn staggered_arrivals_are_monotone_and_deterministic() {
        let cfg = ScaledConfig {
            jobs: 400,
            nodes: 64,
            arrival: Arrival::Staggered { mean_gap: 30 },
            ..Default::default()
        };
        let a = cfg.build();
        let b = cfg.build();
        assert_eq!(a, b, "same seed, same arrivals");
        assert!(a.windows(2).all(|w| w[0].submit <= w[1].submit), "arrivals ascending");
        assert!(a.last().unwrap().submit > 0, "gaps actually accumulate");
        let mean = a.last().unwrap().submit as f64 / a.len() as f64;
        assert!((10.0..90.0).contains(&mean), "mean gap {mean:.1} near 30");
    }

    #[test]
    fn unscaled_nodes_give_high_concurrency() {
        let cfg = ScaledConfig {
            jobs: 1000,
            nodes: 2048,
            rescale_nodes: false,
            ..Default::default()
        };
        let cohort = cfg.cohort();
        assert!(cohort.iter().all(|r| r.nodes <= 20), "base requests kept");
        // Many base-size jobs fit the big pool at once.
        let avg: f64 =
            cohort.iter().map(|r| r.nodes as f64).sum::<f64>() / cohort.len() as f64;
        assert!(avg < 5.0, "avg request stays small: {avg:.1}");
    }

    #[test]
    fn shard_count_never_perturbs_the_workload() {
        let cfg = ScaledConfig {
            jobs: 500,
            nodes: 64,
            arrival: Arrival::Staggered { mean_gap: 20 },
            ..Default::default()
        };
        let master = cfg.build();
        for shards in [1usize, 2, 4, 7] {
            let parts = cfg.build_sharded(shards);
            assert_eq!(parts.len(), shards);
            // Reassemble by the id scheme: master m = shard m%S local m/S.
            let mut merged = Vec::with_capacity(master.len());
            for m in 0..master.len() {
                merged.push(parts[m % shards][m / shards].clone());
            }
            assert_eq!(merged, master, "S={shards} perturbed the merged workload");
        }
    }

    #[test]
    fn other_seeds_change_the_workload() {
        let a = ScaledConfig { jobs: 300, nodes: 64, ..Default::default() }.build();
        let b = ScaledConfig { jobs: 300, nodes: 64, seed: 7, ..Default::default() }.build();
        assert_ne!(a, b);
    }
}
