//! Synthetic PM100-calibrated workload generator.
//!
//! The real PM100 dataset is not redistributable here, so this module
//! generates a statistically equivalent cohort, calibrated to the
//! paper's Fig. 3 and Table 1 (all numbers in *original* Marconi units;
//! the caller scales by 60x afterwards):
//!
//! - 773 jobs: 556 COMPLETED, 108 TIMEOUT below the cap, 109 TIMEOUT at
//!   the 24 h cap (the future checkpointing jobs);
//! - node counts heavy at 1–4 with a thin tail (capped at the 20-node
//!   test system, as the paper adapted them);
//! - checkpointing jobs are small (~1 node): Fig. 3's cap-timeout
//!   population, which makes baseline tail waste ≈ 109 × 3 min × 48
//!   cores ≈ 0.9 M core-seconds, matching Table 1's 875,520;
//! - time limits cluster on round hours with a spike at the 24 h cap;
//! - total CPU time lands near Table 1's 58.8 M core-seconds.
//!
//! `generate_raw` additionally produces an *unfiltered* superset
//! (short jobs, other partitions/queues, shared nodes) so the filter
//! pipeline in [`super::trace`] is exercised end to end, mirroring the
//! paper's "1,074,576 jobs → 773" reduction at small scale.

use crate::proptest_lite::Rng;
use crate::simtime::Time;

use super::trace::{TraceRecord, TraceState};

const HOUR: Time = 3600;
/// Marconi cores per node (PM100).
pub const CORES_PER_NODE: u32 = 48;
/// The 24 h maximum limit on the paper's partition.
pub const MAX_LIMIT: Time = 24 * HOUR;

/// Cohort shape, defaulted to the paper's counts.
#[derive(Debug, Clone)]
pub struct Pm100Config {
    pub completed: usize,
    pub timeout_below_cap: usize,
    pub timeout_at_cap: usize,
    pub max_nodes: u32,
    pub seed: u64,
}

impl Default for Pm100Config {
    fn default() -> Self {
        Self {
            completed: 556,
            timeout_below_cap: 108,
            timeout_at_cap: 109,
            max_nodes: 20,
            seed: 42,
        }
    }
}

impl Pm100Config {
    pub fn total(&self) -> usize {
        self.completed + self.timeout_below_cap + self.timeout_at_cap
    }
}

/// Node-count distribution for the general population: heavy at 1–4
/// nodes with a thin tail, capped at `max_nodes` (Fig. 3, middle-left,
/// adapted to the 20-node test system).
fn draw_nodes(rng: &mut Rng, max_nodes: u32) -> u32 {
    let buckets: [(u32, f64); 8] = [
        (1, 0.45),
        (2, 0.20),
        (3, 0.07),
        (4, 0.11),
        (6, 0.05),
        (8, 0.07),
        (12, 0.03),
        (16, 0.02),
    ];
    let weights: Vec<f64> = buckets.iter().map(|&(_, w)| w).collect();
    buckets[rng.weighted(&weights)].0.min(max_nodes)
}

/// Checkpointing (cap-timeout) jobs are single-node (Fig. 3: the 24 h
/// population sits at the small end; this also pins baseline tail waste
/// at 109 x 180 s x 48 cores = 941,760 core-seconds, within 8% of
/// Table 1's 875,520).
fn draw_ckpt_nodes(_rng: &mut Rng, max_nodes: u32) -> u32 {
    1.min(max_nodes).max(1)
}

/// Round-value user limits (users pick whole hours; Fig. 3 top-right).
fn draw_limit_below_cap(rng: &mut Rng) -> Time {
    let hours: [(Time, f64); 7] = [
        (2, 0.10),
        (4, 0.15),
        (6, 0.15),
        (8, 0.20),
        (10, 0.10),
        (12, 0.20),
        (20, 0.10),
    ];
    let weights: Vec<f64> = hours.iter().map(|&(_, w)| w).collect();
    hours[rng.weighted(&weights)].0 * HOUR
}

/// A submission instant inside May 2020 (trace epoch = month start),
/// diurnally modulated: submissions concentrate in working hours.
fn draw_submit(rng: &mut Rng) -> Time {
    let day = rng.int_in(0, 30);
    let hour_w: Vec<f64> = (0..24)
        .map(|h| if (8..20).contains(&h) { 3.0 } else { 1.0 })
        .collect();
    let hour = rng.weighted(&hour_w) as Time;
    day * 24 * HOUR + hour * HOUR + rng.int_in(0, HOUR - 1)
}

/// Generate the calibrated 773-job cohort (original units), sorted by
/// original submission time — which becomes the replay priority order.
pub fn generate_cohort(cfg: &Pm100Config) -> Vec<TraceRecord> {
    let mut rng = Rng::new(cfg.seed);
    let mut out: Vec<TraceRecord> = Vec::with_capacity(cfg.total());

    let base = |submit: Time, nodes: u32| TraceRecord {
        submit,
        partition: 1,
        queue: 1,
        nodes,
        cores: nodes * CORES_PER_NODE,
        time_limit: 0,
        run_time: 0,
        state: TraceState::Completed,
        exclusive: true,
    };

    // COMPLETED: runtime log-uniform in [1 h, ~23.8 h); the user limit
    // overshoots it by 1.1–2.5x (rule-of-thumb padding), capped at 24 h.
    for _ in 0..cfg.completed {
        let nodes = draw_nodes(&mut rng, cfg.max_nodes);
        let run = rng.log_int_in(2 * HOUR, MAX_LIMIT - 600);
        let limit_raw = ((run as f64) * rng.f64_in(1.1, 2.5)) as Time;
        // Users request whole hours.
        let limit = ((limit_raw + HOUR - 1) / HOUR * HOUR).min(MAX_LIMIT);
        let mut r = base(draw_submit(&mut rng), nodes);
        r.time_limit = limit;
        r.run_time = run.min(limit);
        r.state = TraceState::Completed;
        out.push(r);
    }

    // TIMEOUT below the cap: underestimated limits.
    for _ in 0..cfg.timeout_below_cap {
        let nodes = draw_nodes(&mut rng, cfg.max_nodes);
        let limit = draw_limit_below_cap(&mut rng);
        let mut r = base(draw_submit(&mut rng), nodes);
        r.time_limit = limit;
        r.run_time = limit; // ran into the limit
        r.state = TraceState::Timeout;
        out.push(r);
    }

    // TIMEOUT at the cap: the future checkpointing jobs.
    for _ in 0..cfg.timeout_at_cap {
        let nodes = draw_ckpt_nodes(&mut rng, cfg.max_nodes);
        let mut r = base(draw_submit(&mut rng), nodes);
        r.time_limit = MAX_LIMIT;
        r.run_time = MAX_LIMIT;
        r.state = TraceState::Timeout;
        out.push(r);
    }

    out.sort_by_key(|r| r.submit);
    out
}

/// Generate an *unfiltered* superset around the cohort: adds jobs that
/// the paper's filters drop (short, shared-node, other partition/queue,
/// other months), interleaved. `extra_factor` controls how much chaff.
pub fn generate_raw(cfg: &Pm100Config, extra_factor: f64) -> Vec<TraceRecord> {
    let mut rng = Rng::new(cfg.seed ^ 0xdead_beef);
    let mut out = generate_cohort(cfg);
    let extras = ((cfg.total() as f64) * extra_factor) as usize;
    for _ in 0..extras {
        let nodes = draw_nodes(&mut rng, cfg.max_nodes);
        let mut r = TraceRecord {
            submit: draw_submit(&mut rng),
            partition: 1,
            queue: 1,
            nodes,
            cores: nodes * CORES_PER_NODE,
            time_limit: 4 * HOUR,
            run_time: rng.int_in(60, 4 * HOUR),
            state: TraceState::Completed,
            exclusive: true,
        };
        // Make it fail at least one filter.
        match rng.int_in(0, 3) {
            0 => r.run_time = rng.int_in(1, HOUR - 1), // too short
            1 => r.partition = rng.int_in(2, 5) as u32,
            2 => r.queue = rng.int_in(2, 4) as u32,
            _ => r.exclusive = false,
        }
        out.push(r);
    }
    out.sort_by_key(|r| r.submit);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{FilterSpec, WorkloadSpec, filter, scale, to_job_specs};

    #[test]
    fn cohort_has_paper_counts() {
        let cfg = Pm100Config::default();
        let cohort = generate_cohort(&cfg);
        assert_eq!(cohort.len(), 773);
        let completed = cohort.iter().filter(|r| r.state == TraceState::Completed).count();
        let at_cap = cohort
            .iter()
            .filter(|r| r.state == TraceState::Timeout && r.time_limit == MAX_LIMIT)
            .count();
        assert_eq!(completed, 556);
        assert_eq!(at_cap, 109);
        assert_eq!(cohort.len() - completed - at_cap, 108);
    }

    #[test]
    fn cohort_is_deterministic_and_seed_sensitive() {
        let cfg = Pm100Config::default();
        assert_eq!(generate_cohort(&cfg), generate_cohort(&cfg));
        let other = Pm100Config { seed: 43, ..cfg };
        assert_ne!(generate_cohort(&Pm100Config::default()), generate_cohort(&other));
    }

    #[test]
    fn cohort_respects_invariants() {
        let cohort = generate_cohort(&Pm100Config::default());
        for r in &cohort {
            assert!(r.nodes >= 1 && r.nodes <= 20);
            assert_eq!(r.cores, r.nodes * CORES_PER_NODE);
            assert!(r.run_time >= 3600, "paper filter: >= 1 h runtime");
            assert!(r.time_limit <= MAX_LIMIT);
            assert!(r.run_time <= r.time_limit);
            if r.state == TraceState::Completed {
                assert!(r.run_time <= r.time_limit);
            }
        }
        // Sorted by original submission.
        assert!(cohort.windows(2).all(|w| w[0].submit <= w[1].submit));
    }

    #[test]
    fn total_cpu_time_is_in_table1_ballpark() {
        // Table 1's numbers are measured on the *scaled* experiment:
        // baseline Total CPU Time = 58,816,100 core-seconds. Accept ±20%.
        let cohort = scale(&generate_cohort(&Pm100Config::default()), 60);
        let total: i64 = cohort.iter().map(|r| r.run_time * r.cores as i64).sum();
        let target = 58_816_100;
        let ratio = total as f64 / target as f64;
        assert!((0.8..1.2).contains(&ratio), "total={total}, target={target}, ratio={ratio:.3}");
    }

    #[test]
    fn baseline_tail_waste_is_in_table1_ballpark() {
        // 109 checkpointing jobs, limit 1440 s, ckpts at 420/840/1260:
        // tail = 180 s x cores. Table 1 baseline: 875,520 core-seconds.
        let cohort = scale(&generate_cohort(&Pm100Config::default()), 60);
        let tail: i64 = cohort
            .iter()
            .filter(|r| r.state == TraceState::Timeout && r.time_limit == 1440)
            .map(|r| 180 * r.cores as i64)
            .sum();
        let target = 875_520;
        let ratio = tail as f64 / target as f64;
        assert!((0.8..1.25).contains(&ratio), "tail={tail}, target={target}, ratio={ratio:.3}");
    }

    #[test]
    fn raw_superset_filters_back_to_cohort() {
        let cfg = Pm100Config::default();
        let raw = generate_raw(&cfg, 2.0);
        assert!(raw.len() > 2 * cfg.total());
        let spec = FilterSpec::default();
        let filtered = filter(&raw, &spec);
        assert_eq!(filtered.len(), cfg.total(), "chaff must be fully filtered");
    }

    #[test]
    fn end_to_end_pipeline_produces_109_checkpointers() {
        let cohort = generate_cohort(&Pm100Config::default());
        let scaled = scale(&cohort, 60);
        let specs = to_job_specs(&scaled, &WorkloadSpec::default());
        assert_eq!(specs.len(), 773);
        assert_eq!(specs.iter().filter(|s| s.ckpt.is_some()).count(), 109);
        for s in &specs {
            assert!(s.time_limit >= 60, "scaled limits are >= 1 min");
            assert!(s.duration >= 1);
        }
    }
}
