//! Young–Daly optimal checkpoint intervals (paper §2, refs [12, 26]).
//!
//! The paper positions its mechanism against the theory of *choosing*
//! checkpoint intervals: Young's first-order optimum
//! `W = sqrt(2 * C * M)` and Daly's higher-order refinement, where `C`
//! is the checkpoint write cost and `M` the mean time between failures.
//! The autonomy loop is complementary — whatever interval an
//! application picks, the loop re-aligns the *time limit* to it. This
//! module provides the formulas so experiments can generate
//! theory-driven workloads (see `ablation_sweeps` and the workload
//! helpers), plus the expected-waste model used to sanity-check them.

/// Young's first-order optimal checkpoint interval (compute segment
/// between checkpoints), seconds. `cost` = checkpoint write time C,
/// `mtbf` = mean time between failures M.
pub fn young_interval(cost: f64, mtbf: f64) -> f64 {
    assert!(cost > 0.0 && mtbf > 0.0);
    (2.0 * cost * mtbf).sqrt()
}

/// Daly's higher-order estimate (valid for `cost < 2 * mtbf`; falls
/// back to `mtbf` beyond, as in the original paper).
pub fn daly_interval(cost: f64, mtbf: f64) -> f64 {
    assert!(cost > 0.0 && mtbf > 0.0);
    if cost >= 2.0 * mtbf {
        return mtbf;
    }
    let x = (cost / (2.0 * mtbf)).sqrt();
    (2.0 * cost * mtbf).sqrt() * (1.0 + x / 3.0 + x * x / 9.0) - cost
}

/// Expected fraction of time wasted (checkpoint overhead + expected
/// re-execution after a failure) for interval `w`, first-order model:
/// `waste(w) = C/w + w/(2M)`.
pub fn waste_fraction(w: f64, cost: f64, mtbf: f64) -> f64 {
    assert!(w > 0.0);
    cost / w + w / (2.0 * mtbf)
}

/// Assign Young-optimal intervals to a set of (cost, mtbf) profiles,
/// rounded to whole seconds with a floor of 1.
pub fn assign_intervals(profiles: &[(f64, f64)]) -> Vec<i64> {
    profiles
        .iter()
        .map(|&(c, m)| young_interval(c, m).round().max(1.0) as i64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn young_formula() {
        // C = 60 s, M = 24 h: W = sqrt(2*60*86400) ≈ 3220 s.
        let w = young_interval(60.0, 86_400.0);
        assert!((w - 3220.0).abs() < 1.0, "{w}");
    }

    #[test]
    fn daly_close_to_young_for_small_cost() {
        let (c, m) = (10.0, 100_000.0);
        let y = young_interval(c, m);
        let d = daly_interval(c, m);
        assert!((d - y).abs() / y < 0.05, "young {y} vs daly {d}");
        // Degenerate regime falls back to M.
        assert_eq!(daly_interval(300.0, 100.0), 100.0);
    }

    #[test]
    fn young_minimizes_first_order_waste() {
        let (c, m) = (30.0, 50_000.0);
        let w_opt = young_interval(c, m);
        let f_opt = waste_fraction(w_opt, c, m);
        for w in [w_opt * 0.5, w_opt * 0.8, w_opt * 1.25, w_opt * 2.0] {
            assert!(waste_fraction(w, c, m) > f_opt, "w={w} beats the optimum");
        }
    }

    #[test]
    fn assignment_is_elementwise() {
        let out = assign_intervals(&[(60.0, 86_400.0), (0.5, 1.0)]);
        assert_eq!(out.len(), 2);
        assert!((out[0] - 3220).abs() <= 1);
        assert_eq!(out[1], 1);
    }

    #[test]
    fn paper_scale_sanity() {
        // At the paper's scaled setting, a 7 s write cost and ~3.5 h
        // scaled MTBF give an interval near the 420 s the paper uses —
        // i.e. the synthetic schedule is Young-plausible.
        let w = young_interval(7.0, 12_600.0);
        assert!((w - 420.0).abs() < 1.0, "{w}");
    }
}
