//! SWF (Standard Workload Format) trace ingestion.
//!
//! The Parallel Workloads Archive publishes decades of production HPC
//! traces in SWF: `;`-prefixed comment headers followed by one job per
//! line, 18 whitespace-separated numeric fields, with `-1` meaning
//! "unknown" per field. This reader is the lenient counterpart of the
//! strict [`super::csv`] parser: real archive files contain partial
//! rows and irregular whitespace, so malformed rows are *skipped and
//! counted* (the [`crate::slurm::external`] squeue idiom) instead of
//! failing the load, and the count is surfaced so callers can print it.
//!
//! Field mapping into [`TraceRecord`] (SWF fields are 1-indexed):
//!
//! | SWF field            | #  | use                                      |
//! |----------------------|----|------------------------------------------|
//! | Submit Time          | 2  | `submit` (`-1` → 0)                      |
//! | Run Time             | 4  | `run_time` (`-1` → Requested Time, else row is malformed) |
//! | Allocated Processors | 5  | `cores` fallback when field 8 is unknown |
//! | Requested Processors | 8  | `cores`; `nodes` = ⌈cores / 48⌉          |
//! | Requested Time       | 9  | `time_limit` (`-1` → 2 × run time)       |
//! | Queue Number         | 15 | `queue` (`-1` → 0)                       |
//! | Partition Number     | 16 | `partition` (`-1` → 0)                   |
//!
//! The remaining fields (wait time, memory, status, uid/gid, app,
//! dependency chain) are irrelevant to the simulator and never parsed —
//! only counted, so a truncated row is still rejected. Terminal state
//! is *derived*, not read from SWF's status field: a job whose runtime
//! reached its limit is a [`TraceState::Timeout`] (the population the
//! autonomy loop acts on), anything shorter a [`TraceState::Completed`]
//! — SWF status conflates failure modes the simulator does not model.
//! Jobs are marked exclusive (SWF allocates whole processors), so the
//! default [`super::FilterSpec`] exclusivity filter keeps them.

use std::io::BufRead;
use std::path::Path;

use crate::errors::{Context, Result};
use crate::warn_log;

use super::trace::{TraceRecord, TraceState};

/// Marconi-like accounting: 48 cores per node (matches [`super::pm100`]).
pub const CORES_PER_NODE: u32 = 48;

/// Every SWF data row has exactly this many whitespace-separated fields.
pub const SWF_FIELDS: usize = 18;

/// A parsed SWF trace: the usable records plus how many rows were
/// dropped as malformed (wrong field count, unparseable numerics, or
/// unknown runtime with no requested-time fallback).
#[derive(Debug, Clone, PartialEq)]
pub struct SwfTrace {
    pub records: Vec<TraceRecord>,
    pub malformed: u64,
}

/// Parse one data row (already split on whitespace). `None` = malformed.
fn parse_row(fields: &[&str]) -> Option<TraceRecord> {
    if fields.len() != SWF_FIELDS {
        return None;
    }
    // Only the fields the simulator consumes are parsed; each must at
    // least be a well-formed integer (`-1` is the in-band unknown).
    let int = |i: usize| -> Option<i64> { fields[i - 1].parse::<i64>().ok() };
    let submit = int(2)?;
    let run_raw = int(4)?;
    let alloc_procs = int(5)?;
    let req_procs = int(8)?;
    let req_time = int(9)?;
    let queue = int(15)?;
    let partition = int(16)?;

    // Runtime: the one field with no safe default. An unknown runtime
    // falls back to the requested time (the job at least held its
    // allocation that long in most archives' semantics); unknown on
    // both sides means the row carries no usable duration.
    let run_time = if run_raw >= 0 {
        run_raw
    } else if req_time > 0 {
        req_time
    } else {
        return None;
    };
    let cores = if req_procs > 0 {
        req_procs as u32
    } else if alloc_procs > 0 {
        alloc_procs as u32
    } else {
        1
    };
    let nodes = cores.div_ceil(CORES_PER_NODE).max(1);
    let time_limit = if req_time > 0 { req_time } else { run_time.max(1) * 2 };
    let state = if run_time >= time_limit { TraceState::Timeout } else { TraceState::Completed };
    Some(TraceRecord {
        submit: submit.max(0),
        partition: partition.max(0) as u32,
        queue: queue.max(0) as u32,
        nodes,
        cores,
        time_limit,
        run_time: run_time.max(1),
        state,
        exclusive: true,
    })
}

/// Read an SWF stream: skip `;` comment headers and blank lines, parse
/// data rows leniently (malformed rows are counted, warned, skipped).
pub fn read_swf(r: impl BufRead) -> Result<SwfTrace> {
    let mut out = SwfTrace { records: Vec::new(), malformed: 0 };
    for (i, line) in r.lines().enumerate() {
        let line = line.with_context(|| format!("swf line {}", i + 1))?;
        let line = line.trim();
        if line.is_empty() || line.starts_with(';') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        match parse_row(&fields) {
            Some(rec) => out.records.push(rec),
            None => {
                out.malformed += 1;
                warn_log!("skipping malformed swf row {}: {line:?}", i + 1);
            }
        }
    }
    Ok(out)
}

/// Load an SWF file from disk.
pub fn load_swf(path: &Path) -> Result<SwfTrace> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    read_swf(std::io::BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A well-formed 18-field row with the given (1-indexed) overrides.
    fn row(overrides: &[(usize, &str)]) -> String {
        let mut f: Vec<String> = vec![
            "1".into(),     // 1 job number
            "0".into(),     // 2 submit
            "10".into(),    // 3 wait
            "3600".into(),  // 4 run time
            "96".into(),    // 5 allocated procs
            "-1".into(),    // 6 avg cpu
            "-1".into(),    // 7 used mem
            "96".into(),    // 8 requested procs
            "7200".into(),  // 9 requested time
            "-1".into(),    // 10 requested mem
            "1".into(),     // 11 status
            "7".into(),     // 12 uid
            "3".into(),     // 13 gid
            "-1".into(),    // 14 app
            "1".into(),     // 15 queue
            "1".into(),     // 16 partition
            "-1".into(),    // 17 preceding job
            "-1".into(),    // 18 think time
        ];
        for &(i, v) in overrides {
            f[i - 1] = v.to_string();
        }
        f.join(" ")
    }

    #[test]
    fn parses_a_canonical_row() {
        let t = read_swf(std::io::Cursor::new(row(&[]))).unwrap();
        assert_eq!(t.malformed, 0);
        assert_eq!(t.records.len(), 1);
        let r = &t.records[0];
        assert_eq!(
            r,
            &TraceRecord {
                submit: 0,
                partition: 1,
                queue: 1,
                nodes: 2, // ceil(96 / 48)
                cores: 96,
                time_limit: 7200,
                run_time: 3600,
                state: TraceState::Completed,
                exclusive: true,
            }
        );
    }

    #[test]
    fn comment_headers_and_blanks_are_skipped_silently() {
        let data = format!(
            "; Version: 2.2\n; Computer: Marconi-like\n;\n\n{}\n\n",
            row(&[])
        );
        let t = read_swf(std::io::Cursor::new(data)).unwrap();
        assert_eq!(t.records.len(), 1);
        assert_eq!(t.malformed, 0);
    }

    #[test]
    fn a_job_that_ran_out_its_limit_is_a_timeout() {
        let t = read_swf(std::io::Cursor::new(row(&[(4, "7200")]))).unwrap();
        assert_eq!(t.records[0].state, TraceState::Timeout);
        // Over the limit (archives record a grace overshoot) too.
        let t = read_swf(std::io::Cursor::new(row(&[(4, "7231")]))).unwrap();
        assert_eq!(t.records[0].state, TraceState::Timeout);
        assert_eq!(t.records[0].run_time, 7231);
    }

    #[test]
    fn minus_one_sentinels_fall_back_per_field() {
        // Unknown submit clamps to the epoch.
        let t = read_swf(std::io::Cursor::new(row(&[(2, "-1")]))).unwrap();
        assert_eq!(t.records[0].submit, 0);
        // Unknown requested procs falls back to allocated procs.
        let t = read_swf(std::io::Cursor::new(row(&[(8, "-1"), (5, "50")]))).unwrap();
        assert_eq!(t.records[0].cores, 50);
        assert_eq!(t.records[0].nodes, 2);
        // Both unknown: a 1-core serial job.
        let t = read_swf(std::io::Cursor::new(row(&[(8, "-1"), (5, "-1")]))).unwrap();
        assert_eq!(t.records[0].cores, 1);
        assert_eq!(t.records[0].nodes, 1);
        // Unknown requested time: limit defaults to 2x runtime (and the
        // derived state is then COMPLETED, not TIMEOUT).
        let t = read_swf(std::io::Cursor::new(row(&[(9, "-1")]))).unwrap();
        assert_eq!(t.records[0].time_limit, 7200);
        assert_eq!(t.records[0].state, TraceState::Completed);
        // Unknown runtime falls back to the requested time -> TIMEOUT.
        let t = read_swf(std::io::Cursor::new(row(&[(4, "-1")]))).unwrap();
        assert_eq!(t.records[0].run_time, 7200);
        assert_eq!(t.records[0].state, TraceState::Timeout);
        // Unknown queue/partition map to 0.
        let t = read_swf(std::io::Cursor::new(row(&[(15, "-1"), (16, "-1")]))).unwrap();
        assert_eq!((t.records[0].queue, t.records[0].partition), (0, 0));
    }

    #[test]
    fn malformed_rows_are_counted_not_fatal() {
        let truncated = row(&[]).rsplit_once(' ').unwrap().0.to_string(); // 17 fields
        let garbage = row(&[(4, "3h")]); // unparseable used field
        let no_duration = row(&[(4, "-1"), (9, "-1")]); // no usable runtime
        let data = format!("{}\n{truncated}\n{garbage}\n{no_duration}\n{}\n", row(&[]), row(&[]));
        let t = read_swf(std::io::Cursor::new(data)).unwrap();
        assert_eq!(t.records.len(), 2);
        assert_eq!(t.malformed, 3);
    }

    #[test]
    fn unused_fields_may_be_non_integer() {
        // Field 6 (avg cpu) is a real in many archive files; it is
        // counted but never parsed.
        let t = read_swf(std::io::Cursor::new(row(&[(6, "1591.18")]))).unwrap();
        assert_eq!(t.records.len(), 1);
        assert_eq!(t.malformed, 0);
    }
}
