//! Workload substrate: the paper's PM100-derived job trace, rebuilt.
//!
//! The paper extracts 773 jobs from CINECA Marconi's PM100 dataset
//! (May 2020, partition 1, queue 1, COMPLETED/TIMEOUT, >= 1 h runtime),
//! scales durations by 60x (1 h -> 1 min), releases everything at t=0
//! on a 20-node cluster, and turns the 109 jobs that timed out at the
//! 24 h cap into synthetic checkpointing jobs (7-min scaled interval).
//!
//! The real dataset is not available offline, so [`pm100`] provides a
//! statistically calibrated synthetic generator reproducing Fig. 3's
//! marginals; [`trace`] implements the filter -> scale -> adapt pipeline
//! as reusable code; [`csv`] reads/writes the trace format so a real
//! PM100 extract can be dropped in unchanged.

pub mod csv;
pub mod ionoise;
pub mod pm100;
pub mod scaled;
pub mod swf;
pub mod trace;
pub mod youngdaly;

pub use pm100::{Pm100Config, generate_cohort, generate_raw};
pub use scaled::{Arrival, ScaledConfig};
pub use trace::{FilterSpec, TraceRecord, TraceState, WorkloadSpec, filter, scale, to_job_specs};
