//! Trace records and the paper's filter → scale → adapt pipeline.

use crate::simtime::Time;
use crate::slurm::{CkptSpec, JobSpec};

/// Terminal state of a trace job (the paper filters to these two).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceState {
    Completed,
    Timeout,
}

impl TraceState {
    pub fn as_str(self) -> &'static str {
        match self {
            TraceState::Completed => "COMPLETED",
            TraceState::Timeout => "TIMEOUT",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "COMPLETED" => Some(TraceState::Completed),
            "TIMEOUT" => Some(TraceState::Timeout),
            _ => None,
        }
    }
}

/// One job as recorded in the (PM100-like) trace, in original units.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Original submission time, seconds since the trace epoch.
    pub submit: Time,
    /// Partition / queue labels (the paper filters Partition=1, Queue=1).
    pub partition: u32,
    pub queue: u32,
    pub nodes: u32,
    /// Allocated cores (Marconi: 48 per node).
    pub cores: u32,
    /// User-provided time limit, seconds (original).
    pub time_limit: Time,
    /// Realized runtime, seconds (original).
    pub run_time: Time,
    pub state: TraceState,
    /// Whether the job ran exclusively on its nodes (filter criterion).
    pub exclusive: bool,
}

/// The paper's trace filters (Section 4, "Workload Construction").
#[derive(Debug, Clone)]
pub struct FilterSpec {
    pub partition: Option<u32>,
    pub queue: Option<u32>,
    /// Keep jobs submitted within `[month_start, month_end)`.
    pub submit_window: Option<(Time, Time)>,
    /// Minimum original runtime (paper: 1 h — shorter jobs would run
    /// only seconds after scaling).
    pub min_run_time: Time,
    pub exclusive_only: bool,
}

impl Default for FilterSpec {
    fn default() -> Self {
        Self {
            partition: Some(1),
            queue: Some(1),
            submit_window: None,
            min_run_time: 3600,
            exclusive_only: true,
        }
    }
}

/// Apply the filter pipeline, preserving trace order.
pub fn filter(records: &[TraceRecord], spec: &FilterSpec) -> Vec<TraceRecord> {
    records
        .iter()
        .filter(|r| spec.partition.is_none_or(|p| r.partition == p))
        .filter(|r| spec.queue.is_none_or(|q| r.queue == q))
        .filter(|r| {
            spec.submit_window
                .is_none_or(|(s, e)| r.submit >= s && r.submit < e)
        })
        .filter(|r| r.run_time >= spec.min_run_time)
        .filter(|r| !spec.exclusive_only || r.exclusive)
        .cloned()
        .collect()
}

/// Scale a record's times down by `factor` (paper: 60, 1 h → 1 min),
/// rounding limits up and runtimes to the nearest second, with a 1 s
/// floor so nothing degenerates.
pub fn scale(records: &[TraceRecord], factor: Time) -> Vec<TraceRecord> {
    records
        .iter()
        .map(|r| TraceRecord {
            time_limit: (r.time_limit + factor - 1) / factor,
            run_time: (r.run_time / factor).max(1),
            ..r.clone()
        })
        .collect()
}

/// How to adapt scaled trace records into synthetic jobs.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    /// Jobs that timed out at this (scaled) limit are adapted as
    /// checkpointing apps (paper: the 24 h cap → 1440 s scaled).
    pub ckpt_at_limit: Time,
    /// Scaled checkpoint interval (paper: 7 min → 420 s).
    pub ckpt_interval: Time,
    /// Checkpoint-interval jitter fraction (0 = the paper's fixed
    /// schedule; > 0 exercises the estimator under noise).
    pub ckpt_jitter: f64,
    /// Seed for per-job jitter streams.
    pub seed: u64,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        Self { ckpt_at_limit: 1440, ckpt_interval: 420, ckpt_jitter: 0.0, seed: 0x7a117a3e }
    }
}

/// Adapt scaled records to submittable synthetic jobs:
///
/// - everything is released at t=0, priority = original submit order
///   (records must already be sorted by `submit`);
/// - COMPLETED jobs become sleep jobs with `duration = run_time`;
/// - TIMEOUT jobs get `duration = 2 × limit` (they will hit any limit
///   the scheduler enforces, like the originals did);
/// - TIMEOUT jobs at the cap additionally checkpoint periodically.
pub fn to_job_specs(records: &[TraceRecord], spec: &WorkloadSpec) -> Vec<JobSpec> {
    records
        .iter()
        .enumerate()
        .map(|(i, r)| {
            let is_ckpt = r.state == TraceState::Timeout && r.time_limit >= spec.ckpt_at_limit;
            let duration = match r.state {
                TraceState::Completed => r.run_time.min(r.time_limit),
                TraceState::Timeout => r.time_limit * 2,
            };
            JobSpec {
                name: format!("pm100-{i:04}").into(),
                submit: 0,
                time_limit: r.time_limit,
                duration,
                nodes: r.nodes,
                cores: r.cores,
                ckpt: is_ckpt.then(|| CkptSpec {
                    interval: spec.ckpt_interval,
                    jitter_frac: spec.ckpt_jitter,
                    seed: spec.seed.wrapping_add(i as u64),
                }),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(submit: Time, run: Time, limit: Time, state: TraceState) -> TraceRecord {
        TraceRecord {
            submit,
            partition: 1,
            queue: 1,
            nodes: 2,
            cores: 96,
            time_limit: limit,
            run_time: run,
            state,
            exclusive: true,
        }
    }

    #[test]
    fn filter_drops_short_and_foreign() {
        let mut records = vec![
            rec(0, 7200, 86400, TraceState::Completed),
            rec(1, 1800, 86400, TraceState::Completed), // too short
            rec(2, 7200, 86400, TraceState::Timeout),
        ];
        records[2].partition = 2; // wrong partition
        let out = filter(&records, &FilterSpec::default());
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].submit, 0);
    }

    #[test]
    fn filter_submit_window() {
        let records = vec![
            rec(100, 7200, 86400, TraceState::Completed),
            rec(200, 7200, 86400, TraceState::Completed),
        ];
        let spec = FilterSpec { submit_window: Some((0, 150)), ..Default::default() };
        assert_eq!(filter(&records, &spec).len(), 1);
    }

    #[test]
    fn filter_exclusive_only() {
        let mut records = vec![rec(0, 7200, 86400, TraceState::Completed)];
        records[0].exclusive = false;
        assert_eq!(filter(&records, &FilterSpec::default()).len(), 0);
        let spec = FilterSpec { exclusive_only: false, ..Default::default() };
        assert_eq!(filter(&records, &spec).len(), 1);
    }

    #[test]
    fn scale_60x_rounds_sensibly() {
        let records = vec![rec(0, 86400, 86400, TraceState::Timeout)];
        let out = scale(&records, 60);
        assert_eq!(out[0].time_limit, 1440); // 24 h -> 24 min
        assert_eq!(out[0].run_time, 1440);
        let records = vec![rec(0, 3661, 86401, TraceState::Completed)];
        let out = scale(&records, 60);
        assert_eq!(out[0].run_time, 61);
        assert_eq!(out[0].time_limit, 1441); // limits round UP
    }

    #[test]
    fn adapt_designates_checkpointers() {
        let records = vec![
            // timed out at the cap -> checkpointing
            TraceRecord { time_limit: 1440, run_time: 1440, state: TraceState::Timeout, ..rec(0, 0, 0, TraceState::Timeout) },
            // timed out below the cap -> opaque
            TraceRecord { time_limit: 600, run_time: 600, state: TraceState::Timeout, ..rec(1, 0, 0, TraceState::Timeout) },
            // completed -> sleep job
            TraceRecord { time_limit: 600, run_time: 400, state: TraceState::Completed, ..rec(2, 0, 0, TraceState::Completed) },
        ];
        let specs = to_job_specs(&records, &WorkloadSpec::default());
        assert!(specs[0].ckpt.is_some());
        assert_eq!(specs[0].duration, 2880);
        assert!(specs[1].ckpt.is_none());
        assert_eq!(specs[1].duration, 1200);
        assert!(specs[2].ckpt.is_none());
        assert_eq!(specs[2].duration, 400);
        assert!(specs.iter().all(|s| s.submit == 0));
    }
}
