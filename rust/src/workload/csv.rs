//! CSV trace I/O.
//!
//! Column format (one header line, comma-separated, no quoting — none of
//! the fields contain commas):
//!
//! ```text
//! submit,partition,queue,nodes,cores,time_limit,run_time,state,exclusive
//! ```
//!
//! This is deliberately a projection of the PM100 job table's relevant
//! columns so a real extract can be converted with a one-line awk.

use std::io::{BufRead, Write};
use std::path::Path;

use crate::bail;
use crate::errors::{Context, Result};

use super::trace::{TraceRecord, TraceState};

pub const HEADER: &str = "submit,partition,queue,nodes,cores,time_limit,run_time,state,exclusive";

/// Serialize records to CSV.
pub fn write_csv(w: &mut impl Write, records: &[TraceRecord]) -> Result<()> {
    writeln!(w, "{HEADER}")?;
    for r in records {
        writeln!(
            w,
            "{},{},{},{},{},{},{},{},{}",
            r.submit,
            r.partition,
            r.queue,
            r.nodes,
            r.cores,
            r.time_limit,
            r.run_time,
            r.state.as_str(),
            r.exclusive as u8,
        )?;
    }
    Ok(())
}

pub fn save_csv(path: &Path, records: &[TraceRecord]) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?,
    );
    write_csv(&mut f, records)
}

/// Parse records from CSV (strict: every row must be well-formed).
pub fn read_csv(r: impl BufRead) -> Result<Vec<TraceRecord>> {
    let mut lines = r.lines();
    let header = lines.next().context("empty trace file")??;
    if header.trim() != HEADER {
        bail!("unexpected header: {header:?} (want {HEADER:?})");
    }
    let mut out = Vec::new();
    for (i, line) in lines.enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let fields: Vec<&str> = line.split(',').collect();
        if fields.len() != 9 {
            bail!("row {}: expected 9 fields, got {}", i + 2, fields.len());
        }
        let parse_int = |s: &str, what: &str| -> Result<i64> {
            s.parse::<i64>().with_context(|| format!("row {}: bad {what}: {s:?}", i + 2))
        };
        out.push(TraceRecord {
            submit: parse_int(fields[0], "submit")?,
            partition: parse_int(fields[1], "partition")? as u32,
            queue: parse_int(fields[2], "queue")? as u32,
            nodes: parse_int(fields[3], "nodes")? as u32,
            cores: parse_int(fields[4], "cores")? as u32,
            time_limit: parse_int(fields[5], "time_limit")?,
            run_time: parse_int(fields[6], "run_time")?,
            state: TraceState::parse(fields[7])
                .with_context(|| format!("row {}: bad state {:?}", i + 2, fields[7]))?,
            exclusive: match fields[8] {
                "0" => false,
                "1" => true,
                other => bail!("row {}: bad exclusive flag {other:?}", i + 2),
            },
        });
    }
    Ok(out)
}

pub fn load_csv(path: &Path) -> Result<Vec<TraceRecord>> {
    let f = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    read_csv(std::io::BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::pm100::{Pm100Config, generate_cohort};

    #[test]
    fn roundtrip_preserves_records() {
        let records = generate_cohort(&Pm100Config::default());
        let mut buf = Vec::new();
        write_csv(&mut buf, &records).unwrap();
        let back = read_csv(std::io::Cursor::new(buf)).unwrap();
        assert_eq!(records, back);
    }

    #[test]
    fn rejects_bad_header() {
        let err = read_csv(std::io::Cursor::new("wrong,header\n")).unwrap_err();
        assert!(err.to_string().contains("unexpected header"));
    }

    #[test]
    fn rejects_short_rows() {
        let data = format!("{HEADER}\n1,2,3\n");
        let err = read_csv(std::io::Cursor::new(data)).unwrap_err();
        assert!(err.to_string().contains("expected 9 fields"));
    }

    #[test]
    fn rejects_bad_state() {
        let data = format!("{HEADER}\n0,1,1,2,96,100,50,FAILED,1\n");
        let err = read_csv(std::io::Cursor::new(data)).unwrap_err();
        assert!(err.to_string().contains("bad state"));
    }

    #[test]
    fn skips_blank_lines() {
        let data = format!("{HEADER}\n\n0,1,1,2,96,100,50,COMPLETED,1\n\n");
        let recs = read_csv(std::io::Cursor::new(data)).unwrap();
        assert_eq!(recs.len(), 1);
    }
}
