//! Experiment configuration: a TOML-subset parser plus the typed
//! experiment config it populates.
//!
//! The offline vendor set has no `serde`/`toml`, so [`parse`] implements
//! the subset the project needs from scratch: `[section]` headers,
//! `key = value` pairs with integers, floats, booleans, and quoted
//! strings, `#` comments. Unknown keys are rejected by the typed layer
//! (typos should fail loudly, not silently fall back to defaults).
//!
//! See `configs/*.toml` for shipped experiment files.

use std::collections::BTreeMap;
use std::path::Path;

use crate::bail;
use crate::errors::{Context, Result};

use crate::daemon::DaemonConfig;
use crate::policy::PolicySpec;
use crate::slurm::SlurmConfig;
use crate::workload::{Pm100Config, WorkloadSpec};

/// A parsed scalar value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    /// The value as an integer (exact match only — floats don't
    /// silently truncate).
    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    /// The value as a float (integers widen losslessly).
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(f) => Ok(*f),
            Value::Int(i) => Ok(*i as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }

    /// The value as a boolean.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    /// The value as a string slice (quoted values only).
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }
}

/// `section.key -> value` map (top-level keys live under `""`).
pub type Table = BTreeMap<(String, String), Value>;

/// Parse the TOML subset. Line-oriented; errors carry line numbers.
pub fn parse(text: &str) -> Result<Table> {
    let mut out = Table::new();
    let mut section = String::new();
    for (ln, raw) in text.lines().enumerate() {
        // Strip the first `#` that sits outside a quoted string (an
        // even number of `"` precedes it).
        let comment_at = raw
            .char_indices()
            .scan(0usize, |quotes, (i, c)| {
                if c == '"' {
                    *quotes += 1;
                }
                Some((i, c, *quotes))
            })
            .find(|&(_, c, quotes)| c == '#' && quotes % 2 == 0)
            .map(|(i, _, _)| i);
        let line = match comment_at {
            Some(i) => &raw[..i],
            None => raw,
        }
        .trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            section = name.trim().to_string();
            if section.is_empty() {
                bail!("line {}: empty section name", ln + 1);
            }
            continue;
        }
        let Some((key, val)) = line.split_once('=') else {
            bail!("line {}: expected `key = value`, got {line:?}", ln + 1);
        };
        let key = key.trim().to_string();
        let val = val.trim();
        let value = if let Some(s) = val.strip_prefix('"') {
            let Some(s) = s.strip_suffix('"') else {
                bail!("line {}: unterminated string", ln + 1);
            };
            Value::Str(s.to_string())
        } else if val == "true" {
            Value::Bool(true)
        } else if val == "false" {
            Value::Bool(false)
        } else if let Ok(i) = val.parse::<i64>() {
            Value::Int(i)
        } else if let Ok(f) = val.parse::<f64>() {
            Value::Float(f)
        } else {
            bail!("line {}: cannot parse value {val:?}", ln + 1);
        };
        if out.insert((section.clone(), key.clone()), value).is_some() {
            bail!("line {}: duplicate key {section}.{key}", ln + 1);
        }
    }
    Ok(out)
}

/// Which analytics backend the daemon uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// AOT-compiled JAX/Pallas model via PJRT (production).
    Pjrt,
    /// Pure-Rust oracle.
    Native,
}

impl Default for EngineKind {
    /// PJRT when the feature (and its vendored xla crate) is compiled
    /// in; the native oracle otherwise, so the default build's CLI
    /// works without artifacts.
    fn default() -> Self {
        if cfg!(feature = "pjrt") { EngineKind::Pjrt } else { EngineKind::Native }
    }
}

impl EngineKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "pjrt" => Some(EngineKind::Pjrt),
            "native" => Some(EngineKind::Native),
            _ => None,
        }
    }
}

/// Everything one experiment run needs.
#[derive(Debug, Clone)]
pub struct Experiment {
    pub slurm: SlurmConfig,
    pub daemon: DaemonConfig,
    pub workload: WorkloadSpec,
    pub pm100: Pm100Config,
    pub policy: PolicySpec,
    pub engine: EngineKind,
    /// Scale factor applied to the generated trace (paper: 60).
    pub scale_factor: i64,
    /// External slurmctld binding ([`crate::slurm::ExternalSlurm`]):
    /// `None` until a `[slurm]` external key (`squeue_cmd`,
    /// `scontrol_cmd`, `scancel_cmd`, `external_timeout_ms`,
    /// `spool_dir`) opts in.
    pub external: Option<crate::slurm::ExternalConfig>,
    /// Federation shard count ([`crate::slurm::fed`]): 1 (default)
    /// runs the classic single-cluster simulation; >1 partitions the
    /// workload round-robin over that many independent clusters and
    /// merges them deterministically.
    pub shards: u32,
    /// Worker threads for the parallel federation drive
    /// ([`crate::slurm::fed::FedDrive::Parallel`]): 0 (default) means
    /// auto — the machine's available parallelism clamped to the shard
    /// count. Ignored when `shards == 1`.
    pub fed_threads: u32,
    /// Workload trace file (`[workload] trace` / `--trace`): a CSV in
    /// the repo's export format ([`crate::workload::csv`]) or, by
    /// `.swf` extension, a Standard Workload Format archive trace
    /// ([`crate::workload::swf`]). `None` generates the synthetic
    /// PM100-style cohort instead.
    pub trace: Option<String>,
}

impl Default for Experiment {
    fn default() -> Self {
        Self {
            slurm: SlurmConfig::default(),
            daemon: DaemonConfig::default(),
            workload: WorkloadSpec::default(),
            pm100: Pm100Config::default(),
            policy: PolicySpec::Hybrid,
            engine: EngineKind::default(),
            scale_factor: 60,
            external: None,
            shards: 1,
            fed_threads: 0,
            trace: None,
        }
    }
}

impl Experiment {
    /// The external-binding config, created with defaults on the first
    /// `[slurm]` external key.
    pub fn external_mut(&mut self) -> &mut crate::slurm::ExternalConfig {
        self.external.get_or_insert_with(Default::default)
    }

    /// Populate from a parsed table; every key must be known.
    ///
    /// Policies come in two equivalent spellings: the inline string
    /// form (`policy = "extend-budget:1200"` under `[daemon]`) and the
    /// table form — a `[policy]` section with `name = "extend-budget"`
    /// plus that policy's parameter keys (`budget = 1200`), validated
    /// against the [`crate::policy::REGISTRY`] with unknown-key and
    /// out-of-range diagnostics. Setting both is ambiguous and fails.
    pub fn from_table(table: &Table) -> Result<Self> {
        let mut e = Experiment::default();
        let mut daemon_policy: Option<PolicySpec> = None;
        let mut policy_name: Option<String> = None;
        let mut policy_params: BTreeMap<String, Value> = BTreeMap::new();
        for ((section, key), value) in table {
            let ctx = || format!("config key {section}.{key}");
            match (section.as_str(), key.as_str()) {
                // The [policy] table: `name` picks the policy, every
                // other key must be one of its registered parameters
                // (validated together after the scan).
                ("policy", "name") => {
                    policy_name = Some(value.as_str().with_context(ctx)?.to_string())
                }
                ("policy", _) => {
                    policy_params.insert(key.clone(), value.clone());
                    continue;
                }
                ("slurm", "nodes") => e.slurm.nodes = value.as_int().with_context(ctx)? as u32,
                ("slurm", "backfill_interval") => e.slurm.backfill_interval = value.as_int().with_context(ctx)?,
                ("slurm", "backfill_max_jobs") => e.slurm.backfill_max_jobs = value.as_int().with_context(ctx)? as usize,
                ("slurm", "over_time_limit") => e.slurm.over_time_limit = value.as_int().with_context(ctx)?,
                ("slurm", "backfill_profile") => {
                    e.slurm.backfill_profile =
                        crate::slurm::BackfillProfile::parse(value.as_str().with_context(ctx)?)
                            .with_context(|| format!("unknown backfill profile {value:?}"))?
                }
                ("slurm", "poll_elision") => e.slurm.poll_elision = value.as_bool().with_context(ctx)?,
                // External slurmctld binding: any of these keys opts in
                // (the rest default, see `ExternalConfig::default`).
                ("slurm", "squeue_cmd") => {
                    e.external_mut().squeue_cmd = value.as_str().with_context(ctx)?.to_string()
                }
                ("slurm", "scontrol_cmd") => {
                    e.external_mut().scontrol_cmd = value.as_str().with_context(ctx)?.to_string()
                }
                ("slurm", "scancel_cmd") => {
                    e.external_mut().scancel_cmd = value.as_str().with_context(ctx)?.to_string()
                }
                ("slurm", "external_timeout_ms") => {
                    e.external_mut().timeout_ms = value.as_int().with_context(ctx)?.max(1) as u64
                }
                ("slurm", "spool_dir") => {
                    e.external_mut().spool_dir =
                        Some(value.as_str().with_context(ctx)?.to_string())
                }
                ("slurm", "retirement") => {
                    e.slurm.retirement = value.as_bool().with_context(ctx)?
                }
                ("federation", "shards") => {
                    e.shards = value.as_int().with_context(ctx)?.max(1) as u32
                }
                ("federation", "threads") => {
                    e.fed_threads = value.as_int().with_context(ctx)?.max(0) as u32
                }
                ("slurm", "backfill_ticks") => {
                    e.slurm.backfill_ticks =
                        crate::slurm::BackfillTicks::parse(value.as_str().with_context(ctx)?)
                            .with_context(|| format!("unknown backfill ticks mode {value:?} (on-demand|perpetual)"))?
                }
                ("daemon", "poll_period") => e.daemon.poll_period = value.as_int().with_context(ctx)?,
                ("daemon", "margin") => e.daemon.margin = value.as_int().with_context(ctx)?,
                ("daemon", "safety") => e.daemon.safety = value.as_float().with_context(ctx)?,
                ("daemon", "history_window") => e.daemon.history_window = value.as_int().with_context(ctx)? as usize,
                ("daemon", "conflict_horizon") => e.daemon.conflict_horizon = value.as_int().with_context(ctx)?,
                ("daemon", "max_delay_cost") => e.daemon.max_delay_cost = value.as_float().with_context(ctx)?,
                ("daemon", "use_priors") => e.daemon.use_priors = value.as_bool().with_context(ctx)?,
                ("daemon", "chunk_r") => e.daemon.chunk_r = value.as_int().with_context(ctx)? as usize,
                ("daemon", "chunk_q") => e.daemon.chunk_q = value.as_int().with_context(ctx)? as usize,
                ("daemon", "retry_budget") => e.daemon.retry_budget = value.as_int().with_context(ctx)? as u32,
                ("daemon", "retry_window") => e.daemon.retry_window = value.as_int().with_context(ctx)?,
                ("daemon", "batch_actions") => e.daemon.batch_actions = value.as_bool().with_context(ctx)?,
                ("daemon", "batch_window") => e.daemon.batch_window = value.as_int().with_context(ctx)? as usize,
                ("daemon", "journal_path") => {
                    e.daemon.journal_path = Some(value.as_str().with_context(ctx)?.to_string())
                }
                ("daemon", "journal_rotate_bytes") => {
                    e.daemon.journal_rotate_bytes =
                        value.as_int().with_context(ctx)?.max(0) as u64
                }
                ("daemon", "journal_keep_segments") => {
                    e.daemon.journal_keep_segments =
                        value.as_int().with_context(ctx)?.max(0) as u32
                }
                ("daemon", "rpc_concurrency") => {
                    e.daemon.rpc_concurrency = value.as_int().with_context(ctx)?.max(1) as u32
                }
                ("daemon", "policy") => {
                    daemon_policy =
                        Some(PolicySpec::parse(value.as_str().with_context(ctx)?).with_context(ctx)?)
                }
                ("daemon", "engine") => {
                    e.engine = EngineKind::parse(value.as_str().with_context(ctx)?)
                        .with_context(|| format!("unknown engine {value:?}"))?
                }
                // Seeded node-failure plan ([`crate::slurm::FailureConfig`]).
                ("failures", "mtbf") => {
                    e.slurm.failures.mtbf = value.as_int().with_context(ctx)?.max(0)
                }
                ("failures", "drain_secs") => {
                    e.slurm.failures.drain_secs = value.as_int().with_context(ctx)?.max(0)
                }
                ("failures", "drain_frac") => {
                    e.slurm.failures.drain_frac =
                        value.as_float().with_context(ctx)?.clamp(0.0, 1.0)
                }
                ("failures", "seed") => {
                    e.slurm.failures.seed = value.as_int().with_context(ctx)? as u64
                }
                ("failures", "rekill") => {
                    e.slurm.failures.rekill = value.as_bool().with_context(ctx)?
                }
                ("workload", "trace") => {
                    e.trace = Some(value.as_str().with_context(ctx)?.to_string())
                }
                ("workload", "ckpt_at_limit") => e.workload.ckpt_at_limit = value.as_int().with_context(ctx)?,
                ("workload", "ckpt_interval") => e.workload.ckpt_interval = value.as_int().with_context(ctx)?,
                ("workload", "ckpt_jitter") => e.workload.ckpt_jitter = value.as_float().with_context(ctx)?,
                ("workload", "seed") => e.workload.seed = value.as_int().with_context(ctx)? as u64,
                ("workload", "scale_factor") => e.scale_factor = value.as_int().with_context(ctx)?,
                ("pm100", "completed") => e.pm100.completed = value.as_int().with_context(ctx)? as usize,
                ("pm100", "timeout_below_cap") => e.pm100.timeout_below_cap = value.as_int().with_context(ctx)? as usize,
                ("pm100", "timeout_at_cap") => e.pm100.timeout_at_cap = value.as_int().with_context(ctx)? as usize,
                ("pm100", "max_nodes") => e.pm100.max_nodes = value.as_int().with_context(ctx)? as u32,
                ("pm100", "seed") => e.pm100.seed = value.as_int().with_context(ctx)? as u64,
                _ => bail!("unknown config key: {section}.{key}"),
            }
        }
        match (daemon_policy, policy_name) {
            (Some(_), Some(_)) => {
                bail!("set either daemon.policy or a [policy] table, not both")
            }
            (Some(spec), None) => {
                if !policy_params.is_empty() {
                    bail!(
                        "[policy] parameters given without a [policy] name \
                         (daemon.policy takes inline `name:param` form)"
                    );
                }
                e.policy = spec;
            }
            (None, Some(name)) => {
                e.policy = PolicySpec::from_params(&name, &policy_params)
                    .with_context(|| "config section [policy]".to_string())?;
            }
            (None, None) => {
                if !policy_params.is_empty() {
                    bail!("[policy] section needs a `name` key (see --list-policies)");
                }
            }
        }
        // Cross-section derived value, assigned after the scan so the
        // BTreeMap's alphabetical section order can't matter: the
        // tail-aware hazard term keys off the cluster's failure MTBF.
        e.daemon.failure_mtbf = e.slurm.failures.mtbf;
        Ok(e)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read config {}", path.display()))?;
        Self::from_table(&parse(&text).with_context(|| format!("parse {}", path.display()))?)
    }

    /// Generate this experiment's job specs (cohort → scale → adapt).
    pub fn build_workload(&self) -> Vec<crate::slurm::JobSpec> {
        let cohort = crate::workload::generate_cohort(&self.pm100);
        let scaled = crate::workload::scale(&cohort, self.scale_factor);
        crate::workload::to_job_specs(&scaled, &self.workload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_scalars_comments() {
        let t = parse(
            r#"
# top comment
top = 1
[slurm]
nodes = 20          # trailing comment
backfill_interval = 30
[daemon]
policy = "hybrid"
safety = 0.5
enabled = true
"#,
        )
        .unwrap();
        assert_eq!(t[&("".into(), "top".into())], Value::Int(1));
        assert_eq!(t[&("slurm".into(), "nodes".into())], Value::Int(20));
        // `#` after a closed string is a comment; inside one it isn't.
        let t2 = parse("x = \"pjrt\"   # comment\ny = \"a#b\"\n").unwrap();
        assert_eq!(t2[&("".into(), "x".into())], Value::Str("pjrt".into()));
        assert_eq!(t2[&("".into(), "y".into())], Value::Str("a#b".into()));
        assert_eq!(t[&("daemon".into(), "policy".into())], Value::Str("hybrid".into()));
        assert_eq!(t[&("daemon".into(), "safety".into())], Value::Float(0.5));
        assert_eq!(t[&("daemon".into(), "enabled".into())], Value::Bool(true));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("not a kv line").is_err());
        assert!(parse("[   ]").is_err());
        assert!(parse("x = \"unterminated").is_err());
        assert!(parse("x = what").is_err());
        assert!(parse("x = 1\nx = 2").is_err());
    }

    #[test]
    fn experiment_from_full_table() {
        let t = parse(
            r#"
[slurm]
nodes = 10
over_time_limit = 60
backfill_profile = "flat"
poll_elision = false
backfill_ticks = "perpetual"
[daemon]
poll_period = 10
policy = "early-cancel"
engine = "native"
[workload]
ckpt_interval = 300
scale_factor = 30
[pm100]
completed = 50
timeout_below_cap = 10
timeout_at_cap = 12
seed = 7
"#,
        )
        .unwrap();
        let e = Experiment::from_table(&t).unwrap();
        assert_eq!(e.slurm.nodes, 10);
        assert_eq!(e.slurm.over_time_limit, 60);
        assert_eq!(e.slurm.backfill_profile, crate::slurm::BackfillProfile::Flat);
        assert!(!e.slurm.poll_elision);
        assert_eq!(e.slurm.backfill_ticks, crate::slurm::BackfillTicks::Perpetual);
        assert_eq!(e.daemon.poll_period, 10);
        assert_eq!(e.policy, PolicySpec::EarlyCancel);
        assert_eq!(e.engine, EngineKind::Native);
        assert_eq!(e.workload.ckpt_interval, 300);
        assert_eq!(e.scale_factor, 30);
        assert_eq!(e.pm100.total(), 72);
        let specs = e.build_workload();
        assert_eq!(specs.len(), 72);
    }

    #[test]
    fn resilience_keys_parse() {
        let t = parse(
            r#"
[daemon]
retry_budget = 3
retry_window = 120
batch_actions = true
batch_window = 8
journal_path = "/tmp/tt.journal"
"#,
        )
        .unwrap();
        let e = Experiment::from_table(&t).unwrap();
        assert_eq!(e.daemon.retry_budget, 3);
        assert_eq!(e.daemon.retry_window, 120);
        assert!(e.daemon.batch_actions);
        assert_eq!(e.daemon.batch_window, 8);
        assert_eq!(e.daemon.journal_path.as_deref(), Some("/tmp/tt.journal"));
        // Defaults: budgets on (8/600), batching and journaling off.
        let d = Experiment::default().daemon;
        assert_eq!((d.retry_budget, d.retry_window), (8, 600));
        assert!(!d.batch_actions);
        assert_eq!(d.journal_path, None);
    }

    #[test]
    fn service_layer_keys_parse() {
        let t = parse(
            r#"
[daemon]
journal_rotate_bytes = 65536
journal_keep_segments = 3
rpc_concurrency = 4

[slurm]
squeue_cmd = "ssh ctld squeue"
external_timeout_ms = 2500
spool_dir = "/var/spool/tailtamer"
"#,
        )
        .unwrap();
        let e = Experiment::from_table(&t).unwrap();
        assert_eq!(e.daemon.journal_rotate_bytes, 65_536);
        assert_eq!(e.daemon.journal_keep_segments, 3);
        assert_eq!(e.daemon.rpc_concurrency, 4);
        let ext = e.external.expect("any external key opts in");
        assert_eq!(ext.squeue_cmd, "ssh ctld squeue");
        assert_eq!(ext.scontrol_cmd, "scontrol", "untouched keys keep defaults");
        assert_eq!(ext.timeout_ms, 2_500);
        assert_eq!(ext.spool_dir.as_deref(), Some("/var/spool/tailtamer"));
        // Defaults: rotation off, two retained segments, serial RPCs,
        // no external binding.
        let d = Experiment::default();
        assert_eq!(d.daemon.journal_rotate_bytes, 0);
        assert_eq!(d.daemon.journal_keep_segments, 2);
        assert_eq!(d.daemon.rpc_concurrency, 1);
        assert!(d.external.is_none());
    }

    #[test]
    fn federation_keys_parse() {
        let t = parse("[federation]\nshards = 4\nthreads = 2\n[slurm]\nretirement = false\n")
            .unwrap();
        let e = Experiment::from_table(&t).unwrap();
        assert_eq!(e.shards, 4);
        assert_eq!(e.fed_threads, 2);
        assert!(!e.slurm.retirement);
        // Defaults: one shard (classic path), auto threads, retirement
        // on.
        let d = Experiment::default();
        assert_eq!(d.shards, 1);
        assert_eq!(d.fed_threads, 0, "0 = auto (available parallelism clamped to shards)");
        assert!(d.slurm.retirement);
        // Shard counts clamp to at least 1; negative thread counts
        // clamp back to auto.
        let t = parse("[federation]\nshards = 0\nthreads = -3\n").unwrap();
        let e = Experiment::from_table(&t).unwrap();
        assert_eq!(e.shards, 1);
        assert_eq!(e.fed_threads, 0);
    }

    #[test]
    fn failure_keys_parse() {
        let t = parse(
            r#"
[failures]
mtbf = 3600
drain_secs = 300
drain_frac = 0.4
seed = 99
rekill = false
[workload]
trace = "traces/kit.swf"
"#,
        )
        .unwrap();
        let e = Experiment::from_table(&t).unwrap();
        assert_eq!(e.slurm.failures.mtbf, 3600);
        assert_eq!(e.slurm.failures.drain_secs, 300);
        assert_eq!(e.slurm.failures.drain_frac, 0.4);
        assert_eq!(e.slurm.failures.seed, 99);
        assert!(!e.slurm.failures.rekill);
        assert_eq!(e.trace.as_deref(), Some("traces/kit.swf"));
        // The hazard MTBF is threaded into the daemon after the scan.
        assert_eq!(e.daemon.failure_mtbf, 3600);
        // Defaults: failures off, no trace, hazard zero.
        let d = Experiment::default();
        assert_eq!(d.slurm.failures.mtbf, 0);
        assert_eq!(d.daemon.failure_mtbf, 0);
        assert!(d.trace.is_none());
        // Out-of-range fractions clamp, negative windows clamp to 0.
        let t = parse("[failures]\nmtbf = 10\ndrain_frac = 7.5\ndrain_secs = -4\n").unwrap();
        let e = Experiment::from_table(&t).unwrap();
        assert_eq!(e.slurm.failures.drain_frac, 1.0);
        assert_eq!(e.slurm.failures.drain_secs, 0);
        assert_eq!(e.daemon.failure_mtbf, 10);
    }

    #[test]
    fn unknown_keys_fail_loudly() {
        let t = parse("[daemon]\npoll_perod = 20\n").unwrap();
        let err = Experiment::from_table(&t).unwrap_err();
        assert!(err.to_string().contains("unknown config key"));
    }

    #[test]
    fn backfill_ticks_parses_and_defaults_on_demand() {
        let e = Experiment::from_table(&parse("[slurm]\nbackfill_ticks = \"on-demand\"\n").unwrap())
            .unwrap();
        assert_eq!(e.slurm.backfill_ticks, crate::slurm::BackfillTicks::OnDemand);
        assert_eq!(
            Experiment::default().slurm.backfill_ticks,
            crate::slurm::BackfillTicks::OnDemand,
            "on-demand is the production default"
        );
        let err = Experiment::from_table(&parse("[slurm]\nbackfill_ticks = \"sometimes\"\n").unwrap())
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown backfill ticks mode"), "{err}");
    }

    #[test]
    fn inline_policy_specs_round_trip() {
        for spec in [
            PolicySpec::Baseline,
            PolicySpec::EarlyCancel,
            PolicySpec::ExtendBudget { budget: 900 },
            PolicySpec::TailAware { frac: 0.5 },
            PolicySpec::HybridBackoff { step: 45 },
        ] {
            let text = format!("[daemon]\npolicy = \"{}\"\n", spec.name());
            let e = Experiment::from_table(&parse(&text).unwrap())
                .unwrap_or_else(|err| panic!("{}: {err:#}", spec.name()));
            assert_eq!(e.policy, spec, "TOML round trip for {}", spec.name());
        }
    }

    #[test]
    fn policy_table_form_parses_and_validates() {
        let t = parse("[policy]\nname = \"extend-budget\"\nbudget = 777\n").unwrap();
        let e = Experiment::from_table(&t).unwrap();
        assert_eq!(e.policy, PolicySpec::ExtendBudget { budget: 777 });

        // Defaults apply when only the name is given.
        let t = parse("[policy]\nname = \"tail-aware\"\n").unwrap();
        assert_eq!(
            Experiment::from_table(&t).unwrap().policy,
            PolicySpec::TailAware { frac: 0.25 }
        );

        // Unknown policy names are actionable.
        let t = parse("[policy]\nname = \"nope\"\n").unwrap();
        let err = Experiment::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("unknown policy") && err.contains("extend-budget"), "{err}");

        // Unknown parameter keys list the valid ones.
        let t = parse("[policy]\nname = \"tail-aware\"\nbudget = 5\n").unwrap();
        let err = Experiment::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("unknown parameter") && err.contains("tail_frac"), "{err}");

        // Out-of-range values name the range.
        let t = parse("[policy]\nname = \"extend-budget\"\nbudget = 0\n").unwrap();
        let err = Experiment::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("out of range"), "{err}");

        // Params without a name are rejected.
        let t = parse("[policy]\nbudget = 5\n").unwrap();
        let err = Experiment::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("needs a `name`"), "{err}");
    }

    #[test]
    fn both_policy_spellings_conflict() {
        let t = parse("[daemon]\npolicy = \"hybrid\"\n[policy]\nname = \"extend\"\n").unwrap();
        let err = Experiment::from_table(&t).unwrap_err().to_string();
        assert!(err.contains("not both"), "{err}");
        let bad = parse("[daemon]\npolicy = \"nope\"\n").unwrap();
        let err = Experiment::from_table(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown policy"), "{err}");
    }

    #[test]
    fn defaults_match_paper() {
        let e = Experiment::default();
        assert_eq!(e.slurm.nodes, 20);
        assert_eq!(e.slurm.backfill_profile, crate::slurm::BackfillProfile::Tree);
        assert!(e.slurm.poll_elision, "elision is the default");
        assert_eq!(e.policy, PolicySpec::Hybrid);
        assert_eq!(e.daemon.poll_period, 20);
        assert_eq!(e.workload.ckpt_interval, 420);
        assert_eq!(e.scale_factor, 60);
        assert_eq!(e.pm100.total(), 773);
    }
}
