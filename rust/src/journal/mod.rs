//! Event-sourced durability for the autonomy loop.
//!
//! A live daemon restart used to lose every delta-read cursor, rolling
//! history, budget bucket, and prior — state the bit-identity doctrine
//! guarantees is *reconstructible* in simulation but that live mode
//! simply dropped. This module makes the daemon crash-safe the
//! es-entity way: an **append-only journal** of everything the daemon
//! observed and did, plus periodic full-state snapshots, so
//! [`crate::daemon::Autonomy::replay`] rebuilds the exact pre-crash
//! state by restoring the last snapshot and re-running the journaled
//! ticks against the *recorded* control-surface interactions (no live
//! cluster needed).
//!
//! ## Format (line-oriented text, one file per daemon)
//!
//! ```text
//! J tailtamer-journal v1          header: magic
//! H <policy> <cfg fields...>      header: spec + DaemonConfig scalars
//! S <n>                           snapshot block: n state lines ...
//! <state lines>
//! E                               ... terminator
//! P <n>                           n elided/inactive polls (atomic line)
//! T <now>                         tick block at sim time `now` ...
//! Q ...                           op: squeue result
//! N <id> <cursor> <k> <ts...>     op: delta report read
//! U <id> <limit> +|- <err>        op: scontrol_update_limit result
//! B <k> {<id> <limit> +|- <err>}* op: batched update results
//! C <id> +|- <err>                op: scancel result
//! K                               ... terminator
//! ```
//!
//! Every block is buffered in memory and written with **one**
//! `write + flush`, terminator last, so a crash can only tear the
//! *final* block — the parser discards an unterminated (or otherwise
//! garbled) tail, losing at most the unfinished tick. Floats travel as
//! IEEE bit patterns and job names are percent-encoded, so decode is
//! exact.
//!
//! The daemon-side integration lives in [`crate::daemon`]:
//! [`RecordingCtl`] tees each tick's control calls into the writer, and
//! replay feeds them back through [`ReplayCtl`], which flags any
//! divergence between the recorded trace and the re-run decisions.
//! Both proxies buffer through `RefCell` because the read half of
//! [`SlurmControl`] is `&self`.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::Write as _;
use std::path::Path;

use crate::daemon::DaemonConfig;
use crate::errors::{Context, Error, Result};
use crate::simtime::Time;
use crate::slurm::{
    Adjustment, BackfillPrediction, JobId, PendingInfo, QueueSnapshot, RunningInfo, SlurmControl,
};

const MAGIC: &str = "J tailtamer-journal v1";

/// Default ticks between full-state snapshots (bounds replay work to
/// the journal's tail).
const SNAPSHOT_EVERY: u64 = 64;

/// Percent-encode a string into a single whitespace-free token
/// (space, `%`, and non-printable bytes escape to `%xx`; the empty
/// string encodes as a bare `%`, which no non-empty encoding produces).
pub fn encode_str(s: &str) -> String {
    if s.is_empty() {
        return "%".into();
    }
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'%' => out.push_str("%25"),
            0x21..=0x7e => out.push(b as char),
            _ => out.push_str(&format!("%{b:02x}")),
        }
    }
    out
}

/// Inverse of [`encode_str`].
pub fn decode_str(s: &str) -> String {
    if s == "%" {
        return String::new();
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' && i + 3 <= bytes.len() {
            if let Ok(v) = u8::from_str_radix(&s[i + 1..i + 3], 16) {
                out.push(v);
                i += 3;
                continue;
            }
        }
        out.push(bytes[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn encode_res(r: &Result<(), String>) -> String {
    match r {
        Ok(()) => "+".into(),
        Err(e) => format!("- {}", encode_str(e)),
    }
}

/// One recorded control-surface interaction inside a tick.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// A `squeue`/`squeue_into` result (the tick's input snapshot; the
    /// unbatched extend path takes a second one per action).
    Squeue(QueueSnapshot),
    /// A delta report read: the cursor after the call and the newly
    /// visible timestamps.
    Reports { id: JobId, cursor_after: usize, ts: Vec<Time> },
    /// A single limit update and its outcome.
    Update { id: JobId, limit: Time, result: Result<(), String> },
    /// One batched `scontrol_update_limits` call.
    Batch { updates: Vec<(JobId, Time, Result<(), String>)> },
    /// A cancel and its outcome.
    Cancel { id: JobId, result: Result<(), String> },
}

/// One complete journal block.
#[derive(Debug, Clone, PartialEq)]
pub enum Block {
    /// Polls that executed no tick: elided by the control plane or
    /// inactive (Baseline). Replay adds them to the poll counter.
    Polls(u64),
    /// One executed tick and everything it observed/did.
    Tick { now: Time, ops: Vec<Op> },
    /// A full daemon state snapshot (opaque to this module; encoded and
    /// restored by [`crate::daemon::Autonomy`]).
    Snapshot(String),
}

/// A parsed journal.
#[derive(Debug)]
pub struct Journal {
    /// [`crate::policy::PolicySpec::name`] of the writing daemon.
    pub policy: String,
    /// The writing daemon's config (journal_path excluded — a replayed
    /// daemon must never clobber the file it is replaying).
    pub cfg: DaemonConfig,
    /// Complete blocks, in write order; a torn tail is already dropped.
    pub blocks: Vec<Block>,
}

fn encode_header(policy: &str, c: &DaemonConfig) -> String {
    format!(
        "H {} {} {} {} {} {} {} {} {} {} {} {} {} {} {}",
        encode_str(policy),
        c.poll_period,
        c.margin,
        c.safety.to_bits(),
        c.history_window,
        c.conflict_horizon,
        c.max_delay_cost.to_bits(),
        u8::from(c.use_priors),
        c.chunk_r,
        c.chunk_q,
        u8::from(c.legacy_row_gate),
        c.retry_budget,
        c.retry_window,
        u8::from(c.batch_actions),
        c.batch_window
    )
}

fn decode_header(line: &str) -> Result<(String, DaemonConfig)> {
    let mut it = line.split_whitespace();
    let mut next = || it.next().ok_or_else(|| Error::msg("truncated journal header"));
    if next()? != "H" {
        crate::bail!("journal header must start with H");
    }
    let policy = decode_str(next()?);
    let cfg = DaemonConfig {
        poll_period: next()?.parse()?,
        margin: next()?.parse()?,
        safety: f64::from_bits(next()?.parse()?),
        history_window: next()?.parse()?,
        conflict_horizon: next()?.parse()?,
        max_delay_cost: f64::from_bits(next()?.parse()?),
        use_priors: next()? == "1",
        chunk_r: next()?.parse()?,
        chunk_q: next()?.parse()?,
        legacy_row_gate: next()? == "1",
        retry_budget: next()?.parse()?,
        retry_window: next()?.parse()?,
        batch_actions: next()? == "1",
        batch_window: next()?.parse()?,
        journal_path: None,
    };
    Ok((policy, cfg))
}

/// The append-only writer. Ticks buffer in memory and hit the file as
/// one atomic write-plus-flush in [`end_tick`](Self::end_tick), so the
/// file never holds a half-tick followed by good data. The buffer sits
/// behind a `RefCell` because ops are recorded from the `&self` read
/// half of the control surface.
pub struct JournalWriter {
    file: std::fs::File,
    tick_buf: RefCell<String>,
    ticks_since_snapshot: u64,
    snapshot_every: u64,
}

impl JournalWriter {
    /// Create (truncate) `path` and write the header.
    pub fn create(path: &Path, policy: &str, cfg: &DaemonConfig) -> Result<Self> {
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("create journal {}", path.display()))?;
        writeln!(file, "{MAGIC}")?;
        writeln!(file, "{}", encode_header(policy, cfg))?;
        file.flush()?;
        Ok(Self {
            file,
            tick_buf: RefCell::new(String::new()),
            ticks_since_snapshot: 0,
            snapshot_every: SNAPSHOT_EVERY,
        })
    }

    /// Ticks between periodic snapshots (tests drop this to 1–4 to
    /// exercise multi-snapshot journals on short runs).
    pub fn set_snapshot_every(&mut self, n: u64) {
        self.snapshot_every = n.max(1);
    }

    /// Record `n` polls that executed no tick (elided or inactive).
    pub fn note_polls(&mut self, n: u64) -> Result<()> {
        writeln!(self.file, "P {n}")?;
        self.file.flush()?;
        Ok(())
    }

    /// Open a tick block (buffered; nothing hits the file yet).
    pub fn begin_tick(&mut self, now: Time) {
        let mut buf = self.tick_buf.borrow_mut();
        buf.clear();
        use std::fmt::Write as _;
        let _ = writeln!(buf, "T {now}");
    }

    fn op_line(&self, line: &str) {
        let mut buf = self.tick_buf.borrow_mut();
        buf.push_str(line);
        buf.push('\n');
    }

    /// Close the tick block: one write + flush, terminator last.
    pub fn end_tick(&mut self) -> Result<()> {
        let mut buf = self.tick_buf.borrow_mut();
        buf.push_str("K\n");
        self.file.write_all(buf.as_bytes())?;
        self.file.flush()?;
        buf.clear();
        self.ticks_since_snapshot += 1;
        Ok(())
    }

    /// Whether the periodic snapshot cadence has elapsed.
    pub fn snapshot_due(&self) -> bool {
        self.ticks_since_snapshot >= self.snapshot_every
    }

    /// Append a full-state snapshot block (resets the cadence).
    pub fn snapshot(&mut self, state: &str) -> Result<()> {
        let lines: Vec<&str> = state.lines().collect();
        let mut buf = format!("S {}\n", lines.len());
        for l in lines {
            buf.push_str(l);
            buf.push('\n');
        }
        buf.push_str("E\n");
        self.file.write_all(buf.as_bytes())?;
        self.file.flush()?;
        self.ticks_since_snapshot = 0;
        Ok(())
    }
}

/// Control-surface proxy that tees every observation and action result
/// of a tick into the journal while delegating to the real surface.
pub struct RecordingCtl<'a> {
    inner: &'a mut dyn SlurmControl,
    j: &'a JournalWriter,
}

impl<'a> RecordingCtl<'a> {
    pub fn new(inner: &'a mut dyn SlurmControl, j: &'a mut JournalWriter) -> Self {
        Self { inner, j }
    }

    fn rec_snapshot(&self, s: &QueueSnapshot) {
        use std::fmt::Write as _;
        let mut l = format!("Q {} R {}", s.now, s.running.len());
        for r in &s.running {
            let _ = write!(
                l,
                " {} {} {} {} {} {}",
                r.id.0,
                encode_str(&r.name),
                r.nodes,
                r.start,
                r.cur_limit,
                r.expected_end
            );
        }
        let _ = write!(l, " P {}", s.pending.len());
        for p in &s.pending {
            let _ = write!(l, " {} {} {}", p.id.0, p.nodes, p.cur_limit);
            match p.prediction {
                None => l.push_str(" -"),
                Some(pr) => {
                    let _ = write!(l, " {} {}", pr.start, pr.free_at_start);
                }
            }
        }
        self.j.op_line(&l);
    }
}

impl SlurmControl for RecordingCtl<'_> {
    fn control_now(&self) -> Time {
        // Not recorded: the daemon's tick receives `now` as an argument
        // and never reads the clock through the control surface.
        self.inner.control_now()
    }

    fn squeue(&self) -> QueueSnapshot {
        let mut out = QueueSnapshot::default();
        self.squeue_into(&mut out);
        out
    }

    fn squeue_into(&self, out: &mut QueueSnapshot) {
        self.inner.squeue_into(out);
        self.rec_snapshot(out);
    }

    fn read_ckpt_reports(&self, id: JobId) -> Vec<Time> {
        // Unused by the daemon (it reads via the delta cursor); not
        // recorded.
        self.inner.read_ckpt_reports(id)
    }

    fn read_new_ckpt_reports_into(&self, id: JobId, cursor: &mut usize, out: &mut Vec<Time>) {
        use std::fmt::Write as _;
        self.inner.read_new_ckpt_reports_into(id, cursor, out);
        let mut l = format!("N {} {} {}", id.0, *cursor, out.len());
        for t in out.iter() {
            let _ = write!(l, " {t}");
        }
        self.j.op_line(&l);
    }

    fn scontrol_update_limit(&mut self, id: JobId, new_limit: Time) -> Result<(), String> {
        let r = self.inner.scontrol_update_limit(id, new_limit);
        self.j.op_line(&format!("U {} {} {}", id.0, new_limit, encode_res(&r)));
        r
    }

    fn scontrol_update_limits(&mut self, updates: &[(JobId, Time)]) -> Vec<Result<(), String>> {
        use std::fmt::Write as _;
        let rs = self.inner.scontrol_update_limits(updates);
        let mut l = format!("B {}", updates.len());
        for (&(id, lim), r) in updates.iter().zip(&rs) {
            let _ = write!(l, " {} {} {}", id.0, lim, encode_res(r));
        }
        self.j.op_line(&l);
        rs
    }

    fn scancel(&mut self, id: JobId) -> Result<(), String> {
        let r = self.inner.scancel(id);
        self.j.op_line(&format!("C {} {}", id.0, encode_res(&r)));
        r
    }

    fn mark_adjustment(&mut self, id: JobId, adj: Adjustment) {
        // Accounting-only, no daemon-observable return: not recorded.
        self.inner.mark_adjustment(id, adj);
    }
}

/// Replay-side control surface: serves the recorded ops back to the
/// daemon in order. Any mismatch between what the re-run daemon asks
/// and what the journal recorded is latched as a divergence (checked by
/// [`crate::daemon::Autonomy::replay`] after every tick).
pub struct ReplayCtl {
    now: Time,
    ops: RefCell<VecDeque<Op>>,
    diverged: RefCell<Option<String>>,
}

impl ReplayCtl {
    pub fn new(now: Time, ops: Vec<Op>) -> Self {
        Self { now, ops: RefCell::new(ops.into()), diverged: RefCell::new(None) }
    }

    /// Recorded ops not consumed by the replayed tick.
    pub fn remaining(&self) -> usize {
        self.ops.borrow().len()
    }

    /// First divergence between the journal and the re-run, if any.
    pub fn take_diverged(&mut self) -> Option<String> {
        self.diverged.borrow_mut().take()
    }

    fn pop(&self) -> Option<Op> {
        self.ops.borrow_mut().pop_front()
    }

    fn diverge(&self, msg: String) {
        let mut d = self.diverged.borrow_mut();
        if d.is_none() {
            *d = Some(msg);
        }
    }
}

impl SlurmControl for ReplayCtl {
    fn control_now(&self) -> Time {
        self.now
    }

    fn squeue(&self) -> QueueSnapshot {
        let mut out = QueueSnapshot::default();
        self.squeue_into(&mut out);
        out
    }

    fn squeue_into(&self, out: &mut QueueSnapshot) {
        match self.pop() {
            Some(Op::Squeue(s)) => *out = s,
            other => {
                self.diverge(format!("expected Q, journal has {other:?}"));
                *out = QueueSnapshot::default();
            }
        }
    }

    fn read_ckpt_reports(&self, _id: JobId) -> Vec<Time> {
        self.diverge("unrecorded full report read".into());
        Vec::new()
    }

    fn read_new_ckpt_reports_into(&self, id: JobId, cursor: &mut usize, out: &mut Vec<Time>) {
        out.clear();
        match self.pop() {
            Some(Op::Reports { id: rid, cursor_after, ts }) if rid == id => {
                *cursor = cursor_after;
                out.extend(ts);
            }
            other => self.diverge(format!("expected N {}, journal has {other:?}", id.0)),
        }
    }

    fn scontrol_update_limit(&mut self, id: JobId, new_limit: Time) -> Result<(), String> {
        match self.pop() {
            Some(Op::Update { id: rid, limit, result }) if rid == id && limit == new_limit => {
                result
            }
            other => {
                self.diverge(format!("expected U {} {}, journal has {other:?}", id.0, new_limit));
                Err("journal divergence".into())
            }
        }
    }

    fn scontrol_update_limits(&mut self, updates: &[(JobId, Time)]) -> Vec<Result<(), String>> {
        match self.pop() {
            Some(Op::Batch { updates: rec })
                if rec.len() == updates.len()
                    && rec.iter().zip(updates).all(|(r, u)| r.0 == u.0 && r.1 == u.1) =>
            {
                rec.into_iter().map(|(_, _, r)| r).collect()
            }
            other => {
                self.diverge(format!("expected B x{}, journal has {other:?}", updates.len()));
                updates.iter().map(|_| Err("journal divergence".into())).collect()
            }
        }
    }

    fn scancel(&mut self, id: JobId) -> Result<(), String> {
        match self.pop() {
            Some(Op::Cancel { id: rid, result }) if rid == id => result,
            other => {
                self.diverge(format!("expected C {}, journal has {other:?}", id.0));
                Err("journal divergence".into())
            }
        }
    }

    fn mark_adjustment(&mut self, _id: JobId, _adj: Adjustment) {}
}

fn parse_res(it: &mut std::str::SplitWhitespace<'_>) -> Option<Result<(), String>> {
    match it.next()? {
        "+" => Some(Ok(())),
        "-" => Some(Err(decode_str(it.next()?))),
        _ => None,
    }
}

fn parse_op(line: &str) -> Option<Op> {
    let mut it = line.split_whitespace();
    match it.next()? {
        "Q" => {
            let now: Time = it.next()?.parse().ok()?;
            if it.next()? != "R" {
                return None;
            }
            let nr: usize = it.next()?.parse().ok()?;
            let mut running = Vec::with_capacity(nr);
            for _ in 0..nr {
                let id = JobId(it.next()?.parse().ok()?);
                let name: std::sync::Arc<str> = decode_str(it.next()?).into();
                let nodes = it.next()?.parse().ok()?;
                let start = it.next()?.parse().ok()?;
                let cur_limit = it.next()?.parse().ok()?;
                let expected_end = it.next()?.parse().ok()?;
                running.push(RunningInfo { id, name, nodes, start, cur_limit, expected_end });
            }
            if it.next()? != "P" {
                return None;
            }
            let np: usize = it.next()?.parse().ok()?;
            let mut pending = Vec::with_capacity(np);
            for _ in 0..np {
                let id = JobId(it.next()?.parse().ok()?);
                let nodes = it.next()?.parse().ok()?;
                let cur_limit = it.next()?.parse().ok()?;
                let prediction = match it.next()? {
                    "-" => None,
                    tok => Some(BackfillPrediction {
                        start: tok.parse().ok()?,
                        free_at_start: it.next()?.parse().ok()?,
                    }),
                };
                pending.push(PendingInfo { id, nodes, cur_limit, prediction });
            }
            Some(Op::Squeue(QueueSnapshot { now, running, pending }))
        }
        "N" => {
            let id = JobId(it.next()?.parse().ok()?);
            let cursor_after: usize = it.next()?.parse().ok()?;
            let k: usize = it.next()?.parse().ok()?;
            let mut ts = Vec::with_capacity(k);
            for _ in 0..k {
                ts.push(it.next()?.parse().ok()?);
            }
            Some(Op::Reports { id, cursor_after, ts })
        }
        "U" => {
            let id = JobId(it.next()?.parse().ok()?);
            let limit: Time = it.next()?.parse().ok()?;
            Some(Op::Update { id, limit, result: parse_res(&mut it)? })
        }
        "B" => {
            let k: usize = it.next()?.parse().ok()?;
            let mut updates = Vec::with_capacity(k);
            for _ in 0..k {
                let id = JobId(it.next()?.parse().ok()?);
                let limit: Time = it.next()?.parse().ok()?;
                updates.push((id, limit, parse_res(&mut it)?));
            }
            Some(Op::Batch { updates })
        }
        "C" => {
            let id = JobId(it.next()?.parse().ok()?);
            Some(Op::Cancel { id, result: parse_res(&mut it)? })
        }
        _ => None,
    }
}

/// Parse a journal file: header plus every **complete** block. A torn
/// tail — unterminated block, truncated line, partial write — ends the
/// parse silently: crash recovery keeps everything up to the last
/// terminator and drops the rest.
pub fn parse(path: &Path) -> Result<Journal> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read journal {}", path.display()))?;
    let mut lines = text.lines();
    if lines.next() != Some(MAGIC) {
        crate::bail!("{}: not a tailtamer journal", path.display());
    }
    let hline = lines.next().ok_or_else(|| Error::msg("journal missing header"))?;
    let (policy, cfg) = decode_header(hline)?;
    let mut blocks = Vec::new();
    'outer: while let Some(line) = lines.next() {
        let mut it = line.split_whitespace();
        match it.next() {
            None => continue,
            Some("P") => {
                let Some(n) = it.next().and_then(|t| t.parse().ok()) else { break };
                blocks.push(Block::Polls(n));
            }
            Some("T") => {
                let Some(now) = it.next().and_then(|t| t.parse().ok()) else { break };
                let mut ops = Vec::new();
                loop {
                    let Some(l) = lines.next() else { break 'outer };
                    if l == "K" {
                        blocks.push(Block::Tick { now, ops });
                        break;
                    }
                    match parse_op(l) {
                        Some(op) => ops.push(op),
                        None => break 'outer,
                    }
                }
            }
            Some("S") => {
                let Some(n) = it.next().and_then(|t| t.parse::<usize>().ok()) else { break };
                let mut buf = String::new();
                for _ in 0..n {
                    let Some(l) = lines.next() else { break 'outer };
                    buf.push_str(l);
                    buf.push('\n');
                }
                if lines.next() != Some("E") {
                    break 'outer;
                }
                blocks.push(Block::Snapshot(buf));
            }
            Some(_) => break,
        }
    }
    Ok(Journal { policy, cfg, blocks })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tt_journal_{tag}_{}.log", std::process::id()))
    }

    #[test]
    fn string_encoding_roundtrips() {
        for s in ["plain", "with space", "100%", "naïve-jöb", "", "a%20b", "%"] {
            let enc = encode_str(s);
            assert!(!enc.contains(char::is_whitespace), "{enc:?}");
            assert!(!enc.is_empty());
            assert_eq!(decode_str(&enc), s, "via {enc:?}");
        }
    }

    #[test]
    fn header_roundtrips_bit_exact() {
        let cfg = DaemonConfig {
            safety: 1.5,
            max_delay_cost: 0.1, // not exactly representable: bits must survive
            use_priors: true,
            retry_budget: 3,
            batch_actions: true,
            journal_path: Some("ignored".into()),
            ..Default::default()
        };
        let line = encode_header("tail-aware:0.25", &cfg);
        let (policy, back) = decode_header(&line).unwrap();
        assert_eq!(policy, "tail-aware:0.25");
        assert_eq!(back.safety.to_bits(), cfg.safety.to_bits());
        assert_eq!(back.max_delay_cost.to_bits(), cfg.max_delay_cost.to_bits());
        assert!(back.use_priors && back.batch_actions);
        assert_eq!(back.retry_budget, 3);
        assert_eq!(back.journal_path, None, "journal_path never travels");
    }

    fn sample_ops() -> Vec<Op> {
        vec![
            Op::Squeue(QueueSnapshot {
                now: 40,
                running: vec![RunningInfo {
                    id: JobId(2),
                    name: "ck job".into(),
                    nodes: 3,
                    start: 0,
                    cur_limit: 1440,
                    expected_end: 1440,
                }],
                pending: vec![
                    PendingInfo { id: JobId(5), nodes: 1, cur_limit: 600, prediction: None },
                    PendingInfo {
                        id: JobId(6),
                        nodes: 2,
                        cur_limit: 600,
                        prediction: Some(BackfillPrediction { start: 1440, free_at_start: 4 }),
                    },
                ],
            }),
            Op::Reports { id: JobId(2), cursor_after: 3, ts: vec![420, 840] },
            Op::Update { id: JobId(2), limit: 1711, result: Ok(()) },
            Op::Update { id: JobId(2), limit: 1712, result: Err("denied: no perm".into()) },
            Op::Batch {
                updates: vec![
                    (JobId(2), 1713, Ok(())),
                    (JobId(3), 900, Err("not running".into())),
                ],
            },
            Op::Cancel { id: JobId(2), result: Ok(()) },
        ]
    }

    /// A mock surface whose results the recorder should tee verbatim.
    struct Scripted {
        ops: Vec<Op>,
        i: usize,
    }

    impl SlurmControl for Scripted {
        fn control_now(&self) -> Time {
            0
        }
        fn squeue(&self) -> QueueSnapshot {
            match &self.ops[self.i] {
                Op::Squeue(s) => s.clone(),
                _ => panic!("script mismatch"),
            }
        }
        fn read_ckpt_reports(&self, _id: JobId) -> Vec<Time> {
            Vec::new()
        }
        fn read_new_ckpt_reports_into(&self, _id: JobId, cursor: &mut usize, out: &mut Vec<Time>) {
            match &self.ops[self.i] {
                Op::Reports { cursor_after, ts, .. } => {
                    *cursor = *cursor_after;
                    out.clear();
                    out.extend(ts);
                }
                _ => panic!("script mismatch"),
            }
        }
        fn scontrol_update_limit(&mut self, _id: JobId, _l: Time) -> Result<(), String> {
            match &self.ops[self.i] {
                Op::Update { result, .. } => result.clone(),
                _ => panic!("script mismatch"),
            }
        }
        fn scontrol_update_limits(&mut self, _u: &[(JobId, Time)]) -> Vec<Result<(), String>> {
            match &self.ops[self.i] {
                Op::Batch { updates } => updates.iter().map(|(_, _, r)| r.clone()).collect(),
                _ => panic!("script mismatch"),
            }
        }
        fn scancel(&mut self, _id: JobId) -> Result<(), String> {
            match &self.ops[self.i] {
                Op::Cancel { result, .. } => result.clone(),
                _ => panic!("script mismatch"),
            }
        }
        fn mark_adjustment(&mut self, _id: JobId, _adj: Adjustment) {}
    }

    /// Drive every sample op through `ctl`, asserting the surface
    /// serves exactly the scripted observations and results.
    fn drive(ctl: &mut dyn SlurmControl, ops: &[Op], select: impl Fn(usize)) {
        for (i, op) in ops.iter().enumerate() {
            select(i);
            match op {
                Op::Squeue(s) => assert_eq!(&ctl.squeue(), s),
                Op::Reports { id, cursor_after, ts } => {
                    let (mut c, mut out) = (0usize, Vec::new());
                    ctl.read_new_ckpt_reports_into(*id, &mut c, &mut out);
                    assert_eq!((c, &out), (*cursor_after, ts));
                }
                Op::Update { id, limit, result } => {
                    assert_eq!(&ctl.scontrol_update_limit(*id, *limit), result);
                }
                Op::Batch { updates } => {
                    let args: Vec<_> = updates.iter().map(|&(id, l, _)| (id, l)).collect();
                    let want: Vec<_> = updates.iter().map(|(_, _, r)| r.clone()).collect();
                    assert_eq!(ctl.scontrol_update_limits(&args), want);
                }
                Op::Cancel { id, result } => {
                    assert_eq!(&ctl.scancel(*id), result);
                }
            }
        }
    }

    #[test]
    fn write_record_parse_roundtrips() {
        let path = tmp("rt");
        let cfg = DaemonConfig::default();
        let mut w = JournalWriter::create(&path, "early-cancel", &cfg).unwrap();
        w.snapshot("meta 0 0 0 1 0\nstats 0 0 0 0 0 0 0 0 0 0 0 0 0 0").unwrap();
        w.note_polls(2).unwrap();
        let ops = sample_ops();
        w.begin_tick(40);
        {
            let mut script = Scripted { ops: ops.clone(), i: 0 };
            // Scripted picks its op by index; re-borrow per op so the
            // index can advance between recorder calls.
            for (k, op) in ops.iter().enumerate() {
                script.i = k;
                let mut rec = RecordingCtl::new(&mut script, &mut w);
                drive(&mut rec, std::slice::from_ref(op), |_| ());
            }
        }
        w.end_tick().unwrap();
        drop(w);

        let j = parse(&path).unwrap();
        assert_eq!(j.policy, "early-cancel");
        assert_eq!(j.blocks.len(), 3);
        assert!(matches!(&j.blocks[0], Block::Snapshot(s) if s.starts_with("meta ")));
        assert_eq!(j.blocks[1], Block::Polls(2));
        match &j.blocks[2] {
            Block::Tick { now, ops: parsed } => {
                assert_eq!(*now, 40);
                assert_eq!(parsed, &ops);
            }
            other => panic!("expected tick, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replayed_ops_match_recording() {
        let ops = sample_ops();
        let mut rc = ReplayCtl::new(40, ops.clone());
        drive(&mut rc, &ops, |_| ());
        assert_eq!(rc.remaining(), 0);
        assert_eq!(rc.take_diverged(), None);
    }

    #[test]
    fn torn_tail_is_discarded() {
        let path = tmp("torn");
        let cfg = DaemonConfig::default();
        let mut w = JournalWriter::create(&path, "extend", &cfg).unwrap();
        w.snapshot("meta 0 0 0 1 0").unwrap();
        w.begin_tick(20);
        w.end_tick().unwrap();
        drop(w);
        let whole = parse(&path).unwrap();
        assert_eq!(whole.blocks.len(), 2);

        // Crash mid-tick: opened block, some ops, no terminator.
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        writeln!(f, "T 40").unwrap();
        writeln!(f, "C 3 +").unwrap();
        write!(f, "U 3 14").unwrap(); // torn line, no newline
        drop(f);
        let j = parse(&path).unwrap();
        assert_eq!(j.blocks, whole.blocks, "torn tick dropped wholesale");

        // Crash mid-snapshot: S promises more lines than exist.
        std::fs::write(
            &path,
            format!("{MAGIC}\n{}\nS 3\nonly one line\n", encode_header("extend", &cfg)),
        )
        .unwrap();
        let j = parse(&path).unwrap();
        assert!(j.blocks.is_empty(), "half snapshot dropped: {:?}", j.blocks);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replay_ctl_flags_divergence() {
        let mut rc = ReplayCtl::new(40, vec![Op::Cancel { id: JobId(1), result: Ok(()) }]);
        {
            let ctl: &mut dyn SlurmControl = &mut rc;
            assert!(ctl.scancel(JobId(2)).is_err(), "wrong id must not be served");
        }
        assert!(rc.take_diverged().is_some());

        let mut rc = ReplayCtl::new(40, vec![Op::Cancel { id: JobId(1), result: Ok(()) }]);
        {
            let ctl: &mut dyn SlurmControl = &mut rc;
            assert_eq!(ctl.scancel(JobId(1)), Ok(()));
        }
        assert_eq!(rc.take_diverged(), None);
        assert_eq!(rc.remaining(), 0);
    }
}
