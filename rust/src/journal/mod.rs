//! Event-sourced durability for the autonomy loop.
//!
//! A live daemon restart used to lose every delta-read cursor, rolling
//! history, budget bucket, and prior — state the bit-identity doctrine
//! guarantees is *reconstructible* in simulation but that live mode
//! simply dropped. This module makes the daemon crash-safe the
//! es-entity way: an **append-only journal** of everything the daemon
//! observed and did, plus periodic full-state snapshots, so
//! [`crate::daemon::Autonomy::replay`] rebuilds the exact pre-crash
//! state by restoring the last snapshot and re-running the journaled
//! ticks against the *recorded* control-surface interactions (no live
//! cluster needed).
//!
//! ## Format (line-oriented text, one segment chain per daemon)
//!
//! ```text
//! J tailtamer-journal v1          header: magic
//! H <policy> <cfg fields...>      header: spec + DaemonConfig scalars
//! X <hex64>                       checksum (FNV-1a 64) of the 2 header lines
//! S <n>                           snapshot block: n state lines ...
//! <state lines>
//! E                               ... terminator
//! X <hex64>                       checksum of the S..E block
//! P <n>                           n elided/inactive polls (atomic line)
//! T <now>                         tick block at sim time `now` ...
//! Q ...                           op: squeue result
//! N <id> <cursor> <k> <ts...>     op: delta report read
//! U <id> <limit> +|- <err>        op: scontrol_update_limit result
//! B <k> {<id> <limit> +|- <err>}* op: batched update results
//! C <id> +|- <err>                op: scancel result
//! K                               ... terminator
//! X <hex64>                       checksum of the T..K block
//! ```
//!
//! Every block is buffered in memory and written with **one**
//! `write + flush`, terminator last, so a crash can only tear the
//! *final* block — the parser discards an unterminated (or otherwise
//! garbled) tail, losing at most the unfinished tick. Floats travel as
//! IEEE bit patterns and job names are percent-encoded, so decode is
//! exact.
//!
//! Every written block is followed by an `X` checksum line covering
//! the block's exact on-disk bytes, so *corruption* (a bit flip, a
//! mid-file truncation) is diagnosed at the record that tore — with
//! segment and byte offset — instead of surfacing later as replay
//! divergence. Checksums are **optional on read** (hand-written and
//! pre-rotation journals stay valid); a garbled checksum line at the
//! tail is treated as a torn tail.
//!
//! ## Rotation (bounded disk over unbounded uptime)
//!
//! With `journal_rotate_bytes > 0` the base path is the **active
//! segment**; once it crosses the threshold the next snapshot rotates
//! it: the base is renamed to `<path>.<seq>` (zero-padded, ascending),
//! a fresh base is created with the same header, and the snapshot is
//! written to it first. Every rotated-in segment therefore *opens*
//! with a full-state snapshot, so replay only ever needs the newest
//! segments and older ones are pruned once more than
//! `journal_keep_segments` rotated files remain. Pruning runs only
//! after the fresh segment holds its snapshot: a crash anywhere inside
//! the rotation window leaves a recoverable chain, and [`parse`]
//! reads the whole chain (rotated segments oldest-first, then the
//! base) as one journal.
//!
//! The daemon-side integration lives in [`crate::daemon`]:
//! [`RecordingCtl`] tees each tick's control calls into the writer, and
//! replay feeds them back through [`ReplayCtl`], which flags any
//! divergence between the recorded trace and the re-run decisions.
//! Both proxies buffer through `RefCell` because the read half of
//! [`SlurmControl`] is `&self`.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::daemon::DaemonConfig;
use crate::errors::{Context, Error, Result};
use crate::simtime::Time;
use crate::slurm::{
    Adjustment, BackfillPrediction, JobId, PendingInfo, QueueSnapshot, RunningInfo, SlurmControl,
};

const MAGIC: &str = "J tailtamer-journal v1";

/// Default ticks between full-state snapshots (bounds replay work to
/// the journal's tail).
const SNAPSHOT_EVERY: u64 = 64;

/// FNV-1a 64 over a block's exact on-disk bytes (newlines included).
/// Dependency-free, stable across platforms, and plenty for torn/flip
/// detection — this is an integrity check, not a cryptographic one.
fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Path of rotated segment `seq` for journal `base`
/// (`<base>.<seq:06>`).
fn seg_path(base: &Path, seq: u64) -> PathBuf {
    let mut s = base.as_os_str().to_os_string();
    s.push(format!(".{seq:06}"));
    PathBuf::from(s)
}

/// Rotated segment files currently on disk for `base`, sorted oldest
/// (lowest sequence) first. The base path itself — the active
/// segment — is not included.
pub fn live_segments(base: &Path) -> Vec<(u64, PathBuf)> {
    let Some(name) = base.file_name().and_then(|n| n.to_str()) else {
        return Vec::new();
    };
    let dir = match base.parent() {
        Some(p) if !p.as_os_str().is_empty() => p,
        _ => Path::new("."),
    };
    let prefix = format!("{name}.");
    let mut out = Vec::new();
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let fname = e.file_name();
            let Some(f) = fname.to_str() else { continue };
            if let Some(suffix) = f.strip_prefix(&prefix) {
                if suffix.len() >= 6 && suffix.bytes().all(|b| b.is_ascii_digit()) {
                    if let Ok(seq) = suffix.parse::<u64>() {
                        out.push((seq, e.path()));
                    }
                }
            }
        }
    }
    out.sort_unstable_by_key(|&(seq, _)| seq);
    out
}

/// Percent-encode a string into a single whitespace-free token
/// (space, `%`, and non-printable bytes escape to `%xx`; the empty
/// string encodes as a bare `%`, which no non-empty encoding produces).
pub fn encode_str(s: &str) -> String {
    if s.is_empty() {
        return "%".into();
    }
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'%' => out.push_str("%25"),
            0x21..=0x7e => out.push(b as char),
            _ => out.push_str(&format!("%{b:02x}")),
        }
    }
    out
}

/// Inverse of [`encode_str`].
pub fn decode_str(s: &str) -> String {
    if s == "%" {
        return String::new();
    }
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' && i + 3 <= bytes.len() {
            if let Ok(v) = u8::from_str_radix(&s[i + 1..i + 3], 16) {
                out.push(v);
                i += 3;
                continue;
            }
        }
        out.push(bytes[i]);
        i += 1;
    }
    String::from_utf8_lossy(&out).into_owned()
}

fn encode_res(r: &Result<(), String>) -> String {
    match r {
        Ok(()) => "+".into(),
        Err(e) => format!("- {}", encode_str(e)),
    }
}

/// One recorded control-surface interaction inside a tick.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// A `squeue`/`squeue_into` result (the tick's input snapshot; the
    /// unbatched extend path takes a second one per action).
    Squeue(QueueSnapshot),
    /// A delta report read: the cursor after the call and the newly
    /// visible timestamps.
    Reports { id: JobId, cursor_after: usize, ts: Vec<Time> },
    /// A single limit update and its outcome.
    Update { id: JobId, limit: Time, result: Result<(), String> },
    /// One batched `scontrol_update_limits` call.
    Batch { updates: Vec<(JobId, Time, Result<(), String>)> },
    /// A cancel and its outcome.
    Cancel { id: JobId, result: Result<(), String> },
}

/// One complete journal block.
#[derive(Debug, Clone, PartialEq)]
pub enum Block {
    /// Polls that executed no tick: elided by the control plane or
    /// inactive (Baseline). Replay adds them to the poll counter.
    Polls(u64),
    /// One executed tick and everything it observed/did.
    Tick { now: Time, ops: Vec<Op> },
    /// A full daemon state snapshot (opaque to this module; encoded and
    /// restored by [`crate::daemon::Autonomy`]).
    Snapshot(String),
}

/// A parsed journal.
#[derive(Debug)]
pub struct Journal {
    /// [`crate::policy::PolicySpec::name`] of the writing daemon.
    pub policy: String,
    /// The writing daemon's config (journal_path excluded — a replayed
    /// daemon must never clobber the file it is replaying).
    pub cfg: DaemonConfig,
    /// Complete blocks, in write order; a torn tail is already dropped.
    pub blocks: Vec<Block>,
    /// Number of segment files the chain parse consumed (1 for an
    /// unrotated journal).
    pub segments: usize,
}

fn encode_header(policy: &str, c: &DaemonConfig) -> String {
    format!(
        "H {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {} {}",
        encode_str(policy),
        c.poll_period,
        c.margin,
        c.safety.to_bits(),
        c.history_window,
        c.conflict_horizon,
        c.max_delay_cost.to_bits(),
        u8::from(c.use_priors),
        c.chunk_r,
        c.chunk_q,
        u8::from(c.legacy_row_gate),
        c.retry_budget,
        c.retry_window,
        u8::from(c.batch_actions),
        c.batch_window,
        c.journal_rotate_bytes,
        c.journal_keep_segments,
        c.rpc_concurrency
    )
}

fn decode_header(line: &str) -> Result<(String, DaemonConfig)> {
    let mut it = line.split_whitespace();
    let mut next = || it.next().ok_or_else(|| Error::msg("truncated journal header"));
    if next()? != "H" {
        crate::bail!("journal header must start with H");
    }
    let policy = decode_str(next()?);
    let cfg = DaemonConfig {
        poll_period: next()?.parse()?,
        margin: next()?.parse()?,
        safety: f64::from_bits(next()?.parse()?),
        history_window: next()?.parse()?,
        conflict_horizon: next()?.parse()?,
        max_delay_cost: f64::from_bits(next()?.parse()?),
        use_priors: next()? == "1",
        chunk_r: next()?.parse()?,
        chunk_q: next()?.parse()?,
        legacy_row_gate: next()? == "1",
        retry_budget: next()?.parse()?,
        retry_window: next()?.parse()?,
        batch_actions: next()? == "1",
        batch_window: next()?.parse()?,
        journal_rotate_bytes: next()?.parse()?,
        journal_keep_segments: next()?.parse()?,
        rpc_concurrency: next()?.parse()?,
        journal_path: None,
    };
    Ok((policy, cfg))
}

/// The append-only writer. Ticks buffer in memory and hit the file as
/// one atomic write-plus-flush in [`end_tick`](Self::end_tick), so the
/// file never holds a half-tick followed by good data. The buffer sits
/// behind a `RefCell` because ops are recorded from the `&self` read
/// half of the control surface. With `journal_rotate_bytes > 0` the
/// writer also owns the segment chain (see the module docs).
pub struct JournalWriter {
    file: std::fs::File,
    path: PathBuf,
    /// Magic + header + header checksum: replayed verbatim into every
    /// rotated-in segment.
    header_block: String,
    tick_buf: RefCell<String>,
    ticks_since_snapshot: u64,
    snapshot_every: u64,
    /// Rotate the active segment at the next snapshot once it exceeds
    /// this many bytes (0 disables rotation).
    rotate_bytes: u64,
    /// Rotated segments retained before pruning.
    keep_segments: usize,
    /// Bytes written to the active segment so far.
    seg_bytes: u64,
    /// Next rotation sequence number.
    next_seq: u64,
    /// Rotated segments still on disk: (sequence, bytes).
    retained: VecDeque<(u64, u64)>,
    disk_peak_bytes: u64,
    segments_rotated: u64,
    segments_pruned: u64,
    /// Set by [`kill_mid_rotation`](Self::kill_mid_rotation): every
    /// later write fails, modeling a daemon dead inside the rotation
    /// window.
    dead: bool,
}

impl JournalWriter {
    /// Create (truncate) `path` and write the header. Stale rotated
    /// segments from a previous run are removed: a fresh writer owns
    /// the whole chain, and its first snapshot makes the base segment
    /// self-sufficient, so old history would only confuse [`parse`].
    pub fn create(path: &Path, policy: &str, cfg: &DaemonConfig) -> Result<Self> {
        for (_, seg) in live_segments(path) {
            let _ = std::fs::remove_file(&seg);
        }
        let mut header_block = format!("{MAGIC}\n{}\n", encode_header(policy, cfg));
        {
            use std::fmt::Write as _;
            let x = fnv64(header_block.as_bytes());
            let _ = writeln!(header_block, "X {x:016x}");
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("create journal {}", path.display()))?;
        file.write_all(header_block.as_bytes())?;
        file.flush()?;
        let seg_bytes = header_block.len() as u64;
        Ok(Self {
            file,
            path: path.to_path_buf(),
            header_block,
            tick_buf: RefCell::new(String::new()),
            ticks_since_snapshot: 0,
            snapshot_every: SNAPSHOT_EVERY,
            rotate_bytes: cfg.journal_rotate_bytes,
            keep_segments: cfg.journal_keep_segments as usize,
            seg_bytes,
            next_seq: 1,
            retained: VecDeque::new(),
            disk_peak_bytes: seg_bytes,
            segments_rotated: 0,
            segments_pruned: 0,
            dead: false,
        })
    }

    /// Ticks between periodic snapshots (tests drop this to 1–4 to
    /// exercise multi-snapshot journals on short runs).
    pub fn set_snapshot_every(&mut self, n: u64) {
        self.snapshot_every = n.max(1);
    }

    /// Append one complete block: checksum line added, one write plus
    /// flush, terminator (and checksum) last.
    fn write_block(&mut self, block: &str) -> Result<()> {
        if self.dead {
            crate::bail!("journal writer killed mid-rotation");
        }
        use std::fmt::Write as _;
        let mut buf = String::with_capacity(block.len() + 24);
        buf.push_str(block);
        let _ = writeln!(buf, "X {:016x}", fnv64(block.as_bytes()));
        self.file.write_all(buf.as_bytes())?;
        self.file.flush()?;
        self.seg_bytes += buf.len() as u64;
        self.note_peak();
        Ok(())
    }

    fn note_peak(&mut self) {
        let total = self.seg_bytes + self.retained.iter().map(|&(_, b)| b).sum::<u64>();
        self.disk_peak_bytes = self.disk_peak_bytes.max(total);
    }

    /// Record `n` polls that executed no tick (elided or inactive).
    pub fn note_polls(&mut self, n: u64) -> Result<()> {
        self.write_block(&format!("P {n}\n"))
    }

    /// Open a tick block (buffered; nothing hits the file yet).
    pub fn begin_tick(&mut self, now: Time) {
        let mut buf = self.tick_buf.borrow_mut();
        buf.clear();
        use std::fmt::Write as _;
        let _ = writeln!(buf, "T {now}");
    }

    fn op_line(&self, line: &str) {
        let mut buf = self.tick_buf.borrow_mut();
        buf.push_str(line);
        buf.push('\n');
    }

    /// Close the tick block: one write + flush, terminator last.
    pub fn end_tick(&mut self) -> Result<()> {
        let block = {
            let mut buf = self.tick_buf.borrow_mut();
            buf.push_str("K\n");
            std::mem::take(&mut *buf)
        };
        self.write_block(&block)?;
        self.ticks_since_snapshot += 1;
        Ok(())
    }

    /// Whether the periodic snapshot cadence has elapsed.
    pub fn snapshot_due(&self) -> bool {
        self.ticks_since_snapshot >= self.snapshot_every
    }

    /// Append a full-state snapshot block (resets the cadence).
    ///
    /// Rotation happens only here, *before* the snapshot is written:
    /// every rotated-in segment therefore opens with a full snapshot
    /// and replay never needs the pruned past. Pruning runs only after
    /// the fresh segment holds its snapshot, so a crash anywhere
    /// inside the rotation window leaves a recoverable chain.
    pub fn snapshot(&mut self, state: &str) -> Result<()> {
        if self.rotate_bytes > 0 && self.seg_bytes >= self.rotate_bytes {
            self.rotate()?;
        }
        let lines: Vec<&str> = state.lines().collect();
        let mut buf = format!("S {}\n", lines.len());
        for l in lines {
            buf.push_str(l);
            buf.push('\n');
        }
        buf.push_str("E\n");
        self.write_block(&buf)?;
        self.ticks_since_snapshot = 0;
        self.prune();
        Ok(())
    }

    /// Rename the active segment to its sequence name and start a
    /// fresh base segment with the same header.
    fn rotate(&mut self) -> Result<()> {
        if self.dead {
            crate::bail!("journal writer killed mid-rotation");
        }
        self.file.flush()?;
        let seq = self.next_seq;
        self.next_seq += 1;
        let seg = seg_path(&self.path, seq);
        std::fs::rename(&self.path, &seg)
            .with_context(|| format!("rotate journal into {}", seg.display()))?;
        self.retained.push_back((seq, self.seg_bytes));
        self.segments_rotated += 1;
        let mut file = std::fs::File::create(&self.path)
            .with_context(|| format!("recreate journal {}", self.path.display()))?;
        file.write_all(self.header_block.as_bytes())?;
        file.flush()?;
        self.file = file;
        self.seg_bytes = self.header_block.len() as u64;
        self.note_peak();
        Ok(())
    }

    /// Remove rotated segments beyond the keep window (oldest first).
    fn prune(&mut self) {
        while self.retained.len() > self.keep_segments {
            let (seq, _) = self.retained.pop_front().expect("len checked");
            let seg = seg_path(&self.path, seq);
            if let Err(e) = std::fs::remove_file(&seg) {
                crate::warn_log!("prune journal segment {}: {e}", seg.display());
            }
            self.segments_pruned += 1;
        }
    }

    /// Test hook: die exactly inside the rotation crash window — the
    /// old segment has been renamed away but the fresh base segment
    /// does not exist yet. Every later write fails; recovery must
    /// rebuild from the rotated segments alone.
    pub fn kill_mid_rotation(&mut self) -> Result<()> {
        self.file.flush()?;
        let seq = self.next_seq;
        self.next_seq += 1;
        let seg = seg_path(&self.path, seq);
        std::fs::rename(&self.path, &seg)
            .with_context(|| format!("rotate journal into {}", seg.display()))?;
        self.retained.push_back((seq, self.seg_bytes));
        self.segments_rotated += 1;
        self.dead = true;
        Ok(())
    }

    /// `(segments_rotated, segments_pruned, disk_peak_bytes)` so far.
    /// Peak counts the active segment plus every retained rotated
    /// segment at its largest simultaneous extent.
    pub fn rotation_stats(&self) -> (u64, u64, u64) {
        (self.segments_rotated, self.segments_pruned, self.disk_peak_bytes)
    }
}

/// Control-surface proxy that tees every observation and action result
/// of a tick into the journal while delegating to the real surface.
pub struct RecordingCtl<'a> {
    inner: &'a mut dyn SlurmControl,
    j: &'a JournalWriter,
}

impl<'a> RecordingCtl<'a> {
    pub fn new(inner: &'a mut dyn SlurmControl, j: &'a mut JournalWriter) -> Self {
        Self { inner, j }
    }

    fn rec_snapshot(&self, s: &QueueSnapshot) {
        use std::fmt::Write as _;
        let mut l = format!("Q {} R {}", s.now, s.running.len());
        for r in &s.running {
            let _ = write!(
                l,
                " {} {} {} {} {} {}",
                r.id.0,
                encode_str(&r.name),
                r.nodes,
                r.start,
                r.cur_limit,
                r.expected_end
            );
        }
        let _ = write!(l, " P {}", s.pending.len());
        for p in &s.pending {
            let _ = write!(l, " {} {} {}", p.id.0, p.nodes, p.cur_limit);
            match p.prediction {
                None => l.push_str(" -"),
                Some(pr) => {
                    let _ = write!(l, " {} {}", pr.start, pr.free_at_start);
                }
            }
        }
        self.j.op_line(&l);
    }
}

impl SlurmControl for RecordingCtl<'_> {
    fn control_now(&self) -> Time {
        // Not recorded: the daemon's tick receives `now` as an argument
        // and never reads the clock through the control surface.
        self.inner.control_now()
    }

    fn squeue(&self) -> QueueSnapshot {
        let mut out = QueueSnapshot::default();
        self.squeue_into(&mut out);
        out
    }

    fn squeue_into(&self, out: &mut QueueSnapshot) {
        self.inner.squeue_into(out);
        self.rec_snapshot(out);
    }

    fn read_ckpt_reports(&self, id: JobId) -> Vec<Time> {
        // Unused by the daemon (it reads via the delta cursor); not
        // recorded.
        self.inner.read_ckpt_reports(id)
    }

    fn read_new_ckpt_reports_into(&self, id: JobId, cursor: &mut usize, out: &mut Vec<Time>) {
        use std::fmt::Write as _;
        self.inner.read_new_ckpt_reports_into(id, cursor, out);
        let mut l = format!("N {} {} {}", id.0, *cursor, out.len());
        for t in out.iter() {
            let _ = write!(l, " {t}");
        }
        self.j.op_line(&l);
    }

    fn scontrol_update_limit(&mut self, id: JobId, new_limit: Time) -> Result<(), String> {
        let r = self.inner.scontrol_update_limit(id, new_limit);
        self.j.op_line(&format!("U {} {} {}", id.0, new_limit, encode_res(&r)));
        r
    }

    fn scontrol_update_limits(&mut self, updates: &[(JobId, Time)]) -> Vec<Result<(), String>> {
        use std::fmt::Write as _;
        let rs = self.inner.scontrol_update_limits(updates);
        let mut l = format!("B {}", updates.len());
        for (&(id, lim), r) in updates.iter().zip(&rs) {
            let _ = write!(l, " {} {} {}", id.0, lim, encode_res(r));
        }
        self.j.op_line(&l);
        rs
    }

    fn scontrol_update_limits_concurrent(
        &mut self,
        updates: &[(JobId, Time)],
        parallelism: usize,
    ) -> Vec<Result<(), String>> {
        use std::fmt::Write as _;
        // Same journal record as the serial batched call: results are
        // in submission order by contract, so the pool width is a
        // transport detail replay does not need.
        let rs = self.inner.scontrol_update_limits_concurrent(updates, parallelism);
        let mut l = format!("B {}", updates.len());
        for (&(id, lim), r) in updates.iter().zip(&rs) {
            let _ = write!(l, " {} {} {}", id.0, lim, encode_res(r));
        }
        self.j.op_line(&l);
        rs
    }

    fn scancel(&mut self, id: JobId) -> Result<(), String> {
        let r = self.inner.scancel(id);
        self.j.op_line(&format!("C {} {}", id.0, encode_res(&r)));
        r
    }

    fn mark_adjustment(&mut self, id: JobId, adj: Adjustment) {
        // Accounting-only, no daemon-observable return: not recorded.
        self.inner.mark_adjustment(id, adj);
    }
}

/// Replay-side control surface: serves the recorded ops back to the
/// daemon in order. Any mismatch between what the re-run daemon asks
/// and what the journal recorded is latched as a divergence (checked by
/// [`crate::daemon::Autonomy::replay`] after every tick).
pub struct ReplayCtl {
    now: Time,
    ops: RefCell<VecDeque<Op>>,
    diverged: RefCell<Option<String>>,
}

impl ReplayCtl {
    pub fn new(now: Time, ops: Vec<Op>) -> Self {
        Self { now, ops: RefCell::new(ops.into()), diverged: RefCell::new(None) }
    }

    /// Recorded ops not consumed by the replayed tick.
    pub fn remaining(&self) -> usize {
        self.ops.borrow().len()
    }

    /// First divergence between the journal and the re-run, if any.
    pub fn take_diverged(&mut self) -> Option<String> {
        self.diverged.borrow_mut().take()
    }

    fn pop(&self) -> Option<Op> {
        self.ops.borrow_mut().pop_front()
    }

    fn diverge(&self, msg: String) {
        let mut d = self.diverged.borrow_mut();
        if d.is_none() {
            *d = Some(msg);
        }
    }
}

impl SlurmControl for ReplayCtl {
    fn control_now(&self) -> Time {
        self.now
    }

    fn squeue(&self) -> QueueSnapshot {
        let mut out = QueueSnapshot::default();
        self.squeue_into(&mut out);
        out
    }

    fn squeue_into(&self, out: &mut QueueSnapshot) {
        match self.pop() {
            Some(Op::Squeue(s)) => *out = s,
            other => {
                self.diverge(format!("expected Q, journal has {other:?}"));
                *out = QueueSnapshot::default();
            }
        }
    }

    fn read_ckpt_reports(&self, _id: JobId) -> Vec<Time> {
        self.diverge("unrecorded full report read".into());
        Vec::new()
    }

    fn read_new_ckpt_reports_into(&self, id: JobId, cursor: &mut usize, out: &mut Vec<Time>) {
        out.clear();
        match self.pop() {
            Some(Op::Reports { id: rid, cursor_after, ts }) if rid == id => {
                *cursor = cursor_after;
                out.extend(ts);
            }
            other => self.diverge(format!("expected N {}, journal has {other:?}", id.0)),
        }
    }

    fn scontrol_update_limit(&mut self, id: JobId, new_limit: Time) -> Result<(), String> {
        match self.pop() {
            Some(Op::Update { id: rid, limit, result }) if rid == id && limit == new_limit => {
                result
            }
            other => {
                self.diverge(format!("expected U {} {}, journal has {other:?}", id.0, new_limit));
                Err("journal divergence".into())
            }
        }
    }

    fn scontrol_update_limits(&mut self, updates: &[(JobId, Time)]) -> Vec<Result<(), String>> {
        match self.pop() {
            Some(Op::Batch { updates: rec })
                if rec.len() == updates.len()
                    && rec.iter().zip(updates).all(|(r, u)| r.0 == u.0 && r.1 == u.1) =>
            {
                rec.into_iter().map(|(_, _, r)| r).collect()
            }
            other => {
                self.diverge(format!("expected B x{}, journal has {other:?}", updates.len()));
                updates.iter().map(|_| Err("journal divergence".into())).collect()
            }
        }
    }

    fn scancel(&mut self, id: JobId) -> Result<(), String> {
        match self.pop() {
            Some(Op::Cancel { id: rid, result }) if rid == id => result,
            other => {
                self.diverge(format!("expected C {}, journal has {other:?}", id.0));
                Err("journal divergence".into())
            }
        }
    }

    fn mark_adjustment(&mut self, _id: JobId, _adj: Adjustment) {}
}

fn parse_res(it: &mut std::str::SplitWhitespace<'_>) -> Option<Result<(), String>> {
    match it.next()? {
        "+" => Some(Ok(())),
        "-" => Some(Err(decode_str(it.next()?))),
        _ => None,
    }
}

fn parse_op(line: &str) -> Option<Op> {
    let mut it = line.split_whitespace();
    match it.next()? {
        "Q" => {
            let now: Time = it.next()?.parse().ok()?;
            if it.next()? != "R" {
                return None;
            }
            let nr: usize = it.next()?.parse().ok()?;
            let mut running = Vec::with_capacity(nr);
            for _ in 0..nr {
                let id = JobId(it.next()?.parse().ok()?);
                let name: std::sync::Arc<str> = decode_str(it.next()?).into();
                let nodes = it.next()?.parse().ok()?;
                let start = it.next()?.parse().ok()?;
                let cur_limit = it.next()?.parse().ok()?;
                let expected_end = it.next()?.parse().ok()?;
                running.push(RunningInfo { id, name, nodes, start, cur_limit, expected_end });
            }
            if it.next()? != "P" {
                return None;
            }
            let np: usize = it.next()?.parse().ok()?;
            let mut pending = Vec::with_capacity(np);
            for _ in 0..np {
                let id = JobId(it.next()?.parse().ok()?);
                let nodes = it.next()?.parse().ok()?;
                let cur_limit = it.next()?.parse().ok()?;
                let prediction = match it.next()? {
                    "-" => None,
                    tok => Some(BackfillPrediction {
                        start: tok.parse().ok()?,
                        free_at_start: it.next()?.parse().ok()?,
                    }),
                };
                pending.push(PendingInfo { id, nodes, cur_limit, prediction });
            }
            Some(Op::Squeue(QueueSnapshot { now, running, pending }))
        }
        "N" => {
            let id = JobId(it.next()?.parse().ok()?);
            let cursor_after: usize = it.next()?.parse().ok()?;
            let k: usize = it.next()?.parse().ok()?;
            let mut ts = Vec::with_capacity(k);
            for _ in 0..k {
                ts.push(it.next()?.parse().ok()?);
            }
            Some(Op::Reports { id, cursor_after, ts })
        }
        "U" => {
            let id = JobId(it.next()?.parse().ok()?);
            let limit: Time = it.next()?.parse().ok()?;
            Some(Op::Update { id, limit, result: parse_res(&mut it)? })
        }
        "B" => {
            let k: usize = it.next()?.parse().ok()?;
            let mut updates = Vec::with_capacity(k);
            for _ in 0..k {
                let id = JobId(it.next()?.parse().ok()?);
                let limit: Time = it.next()?.parse().ok()?;
                updates.push((id, limit, parse_res(&mut it)?));
            }
            Some(Op::Batch { updates })
        }
        "C" => {
            let id = JobId(it.next()?.parse().ok()?);
            Some(Op::Cancel { id, result: parse_res(&mut it)? })
        }
        _ => None,
    }
}

/// Byte-offset-tracking line scanner: `str::lines` cannot say *where*
/// a corrupt record sits, and the checksum diagnostics must name the
/// offending offset. A final unterminated line is still yielded (the
/// op parser decides whether it is whole).
struct Scan<'a> {
    text: &'a str,
    pos: usize,
}

impl<'a> Scan<'a> {
    fn next(&mut self) -> Option<&'a str> {
        if self.pos >= self.text.len() {
            return None;
        }
        let rest = &self.text[self.pos..];
        match rest.find('\n') {
            Some(i) => {
                self.pos += i + 1;
                Some(&rest[..i])
            }
            None => {
                self.pos = self.text.len();
                Some(rest)
            }
        }
    }
}

/// Outcome of looking for an `X` checksum line after a block.
enum XCheck {
    /// Verified, or absent — checksums are optional on read so
    /// hand-written and pre-checksum journals stay valid.
    Ok,
    /// A garbled/torn `X` line at the tail: stop parsing; the block it
    /// followed is complete and kept.
    Stop,
}

fn check_x(sc: &mut Scan<'_>, path: &Path, block_start: usize, block_end: usize) -> Result<XCheck> {
    let save = sc.pos;
    let Some(line) = sc.next() else { return Ok(XCheck::Ok) };
    let Some(tok) = line.strip_prefix("X ") else {
        sc.pos = save; // not a checksum line: leave it for the block loop
        return Ok(XCheck::Ok);
    };
    if tok.len() != 16 || !tok.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Ok(XCheck::Stop);
    }
    let want = u64::from_str_radix(tok, 16).expect("hex verified above");
    let got = fnv64(sc.text[block_start..block_end].as_bytes());
    if got != want {
        crate::bail!(
            "{}: checksum mismatch for the record at byte {block_start}: journal record corrupt",
            path.display()
        );
    }
    Ok(XCheck::Ok)
}

/// Parse one segment file: header plus every **complete** block. A
/// torn tail — unterminated block, truncated line, partial write —
/// ends the parse silently: crash recovery keeps everything up to the
/// last terminator and drops the rest. *Corruption* is different from
/// tearing and is a hard error naming segment + byte offset: a
/// checksum mismatch, or a truncated snapshot followed by more data
/// (only the final block of a segment can legitimately tear).
fn parse_segment(path: &Path, text: &str) -> Result<(String, DaemonConfig, Vec<Block>)> {
    let mut sc = Scan { text, pos: 0 };
    if sc.next() != Some(MAGIC) {
        crate::bail!("{}: not a tailtamer journal", path.display());
    }
    let hline = sc
        .next()
        .ok_or_else(|| Error::msg(format!("{}: torn journal header at byte 0", path.display())))?;
    let (policy, cfg) = decode_header(hline)
        .with_context(|| format!("{}: torn or corrupt journal header at byte 0", path.display()))?;
    let header_end = sc.pos;
    let mut blocks = Vec::new();
    if matches!(check_x(&mut sc, path, 0, header_end)?, XCheck::Stop) {
        return Ok((policy, cfg, blocks));
    }
    'outer: loop {
        let block_start = sc.pos;
        let Some(line) = sc.next() else { break };
        let mut it = line.split_whitespace();
        match it.next() {
            None => continue,
            Some("P") => {
                let Some(n) = it.next().and_then(|t| t.parse().ok()) else { break };
                let block_end = sc.pos;
                blocks.push(Block::Polls(n));
                if matches!(check_x(&mut sc, path, block_start, block_end)?, XCheck::Stop) {
                    break;
                }
            }
            Some("T") => {
                let Some(now) = it.next().and_then(|t| t.parse().ok()) else { break };
                let mut ops = Vec::new();
                loop {
                    let Some(l) = sc.next() else { break 'outer };
                    if l == "K" {
                        let block_end = sc.pos;
                        blocks.push(Block::Tick { now, ops });
                        if matches!(check_x(&mut sc, path, block_start, block_end)?, XCheck::Stop)
                        {
                            break 'outer;
                        }
                        break;
                    }
                    match parse_op(l) {
                        Some(op) => ops.push(op),
                        None => break 'outer,
                    }
                }
            }
            Some("S") => {
                let Some(n) = it.next().and_then(|t| t.parse::<usize>().ok()) else { break };
                let mut buf = String::new();
                for _ in 0..n {
                    let Some(l) = sc.next() else { break 'outer }; // torn tail at EOF
                    buf.push_str(l);
                    buf.push('\n');
                }
                match sc.next() {
                    None => break 'outer, // torn tail at EOF
                    Some("E") => {}
                    Some(_) => crate::bail!(
                        "{}: truncated snapshot record at byte {block_start}: S promised {n} \
                         state lines but the E terminator is missing and more data follows",
                        path.display()
                    ),
                }
                let block_end = sc.pos;
                blocks.push(Block::Snapshot(buf));
                if matches!(check_x(&mut sc, path, block_start, block_end)?, XCheck::Stop) {
                    break;
                }
            }
            Some(_) => break,
        }
    }
    Ok((policy, cfg, blocks))
}

/// Does `text` begin with a decodable magic + header? Used to tell a
/// rotation-window crash (base segment torn inside its header) from
/// real corruption.
fn has_complete_header(text: &str) -> bool {
    let mut sc = Scan { text, pos: 0 };
    if sc.next() != Some(MAGIC) {
        return false;
    }
    match sc.next() {
        Some(h) => decode_header(h).is_ok(),
        None => false,
    }
}

/// Parse a journal **chain**: every rotated segment still on disk
/// (oldest first), then the base path — concatenated into one block
/// stream. Single-file journals behave exactly as before. All
/// segments must share the first segment's header; the only tolerated
/// oddity is a missing or header-torn *base* when rotated segments
/// exist, which is precisely the crash window of a rotation (rename
/// done, fresh base not yet complete).
pub fn parse(path: &Path) -> Result<Journal> {
    let mut paths: Vec<PathBuf> = live_segments(path).into_iter().map(|(_, p)| p).collect();
    if path.exists() || paths.is_empty() {
        paths.push(path.to_path_buf());
    }
    let n_seg = paths.len();
    let mut first: Option<(String, DaemonConfig)> = None;
    let mut first_header = String::new();
    let mut blocks = Vec::new();
    for (i, p) in paths.iter().enumerate() {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("read journal {}", p.display()))?;
        let last = i + 1 == paths.len();
        if i > 0 && last && !has_complete_header(&text) {
            crate::warn_log!(
                "journal segment {} torn inside its header (crash mid-rotation); \
                 recovering from the rotated segments",
                p.display()
            );
            continue;
        }
        let hline = text.lines().nth(1).unwrap_or("").to_string();
        let (policy, cfg, seg_blocks) = parse_segment(p, &text)?;
        match &first {
            None => {
                first_header = hline;
                first = Some((policy, cfg));
            }
            Some(_) => {
                if hline != first_header {
                    crate::bail!(
                        "{}: segment header differs from the chain's first segment",
                        p.display()
                    );
                }
            }
        }
        blocks.extend(seg_blocks);
    }
    let (policy, cfg) = first.expect("at least one segment parses or errors above");
    Ok(Journal { policy, cfg, blocks, segments: n_seg })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tt_journal_{tag}_{}.log", std::process::id()))
    }

    #[test]
    fn string_encoding_roundtrips() {
        for s in ["plain", "with space", "100%", "naïve-jöb", "", "a%20b", "%"] {
            let enc = encode_str(s);
            assert!(!enc.contains(char::is_whitespace), "{enc:?}");
            assert!(!enc.is_empty());
            assert_eq!(decode_str(&enc), s, "via {enc:?}");
        }
    }

    #[test]
    fn header_roundtrips_bit_exact() {
        let cfg = DaemonConfig {
            safety: 1.5,
            max_delay_cost: 0.1, // not exactly representable: bits must survive
            use_priors: true,
            retry_budget: 3,
            batch_actions: true,
            journal_path: Some("ignored".into()),
            ..Default::default()
        };
        let line = encode_header("tail-aware:0.25", &cfg);
        let (policy, back) = decode_header(&line).unwrap();
        assert_eq!(policy, "tail-aware:0.25");
        assert_eq!(back.safety.to_bits(), cfg.safety.to_bits());
        assert_eq!(back.max_delay_cost.to_bits(), cfg.max_delay_cost.to_bits());
        assert!(back.use_priors && back.batch_actions);
        assert_eq!(back.retry_budget, 3);
        assert_eq!(back.journal_path, None, "journal_path never travels");
    }

    fn sample_ops() -> Vec<Op> {
        vec![
            Op::Squeue(QueueSnapshot {
                now: 40,
                running: vec![RunningInfo {
                    id: JobId(2),
                    name: "ck job".into(),
                    nodes: 3,
                    start: 0,
                    cur_limit: 1440,
                    expected_end: 1440,
                }],
                pending: vec![
                    PendingInfo { id: JobId(5), nodes: 1, cur_limit: 600, prediction: None },
                    PendingInfo {
                        id: JobId(6),
                        nodes: 2,
                        cur_limit: 600,
                        prediction: Some(BackfillPrediction { start: 1440, free_at_start: 4 }),
                    },
                ],
            }),
            Op::Reports { id: JobId(2), cursor_after: 3, ts: vec![420, 840] },
            Op::Update { id: JobId(2), limit: 1711, result: Ok(()) },
            Op::Update { id: JobId(2), limit: 1712, result: Err("denied: no perm".into()) },
            Op::Batch {
                updates: vec![
                    (JobId(2), 1713, Ok(())),
                    (JobId(3), 900, Err("not running".into())),
                ],
            },
            Op::Cancel { id: JobId(2), result: Ok(()) },
        ]
    }

    /// A mock surface whose results the recorder should tee verbatim.
    struct Scripted {
        ops: Vec<Op>,
        i: usize,
    }

    impl SlurmControl for Scripted {
        fn control_now(&self) -> Time {
            0
        }
        fn squeue(&self) -> QueueSnapshot {
            match &self.ops[self.i] {
                Op::Squeue(s) => s.clone(),
                _ => panic!("script mismatch"),
            }
        }
        fn read_ckpt_reports(&self, _id: JobId) -> Vec<Time> {
            Vec::new()
        }
        fn read_new_ckpt_reports_into(&self, _id: JobId, cursor: &mut usize, out: &mut Vec<Time>) {
            match &self.ops[self.i] {
                Op::Reports { cursor_after, ts, .. } => {
                    *cursor = *cursor_after;
                    out.clear();
                    out.extend(ts);
                }
                _ => panic!("script mismatch"),
            }
        }
        fn scontrol_update_limit(&mut self, _id: JobId, _l: Time) -> Result<(), String> {
            match &self.ops[self.i] {
                Op::Update { result, .. } => result.clone(),
                _ => panic!("script mismatch"),
            }
        }
        fn scontrol_update_limits(&mut self, _u: &[(JobId, Time)]) -> Vec<Result<(), String>> {
            match &self.ops[self.i] {
                Op::Batch { updates } => updates.iter().map(|(_, _, r)| r.clone()).collect(),
                _ => panic!("script mismatch"),
            }
        }
        fn scancel(&mut self, _id: JobId) -> Result<(), String> {
            match &self.ops[self.i] {
                Op::Cancel { result, .. } => result.clone(),
                _ => panic!("script mismatch"),
            }
        }
        fn mark_adjustment(&mut self, _id: JobId, _adj: Adjustment) {}
    }

    /// Drive every sample op through `ctl`, asserting the surface
    /// serves exactly the scripted observations and results.
    fn drive(ctl: &mut dyn SlurmControl, ops: &[Op], select: impl Fn(usize)) {
        for (i, op) in ops.iter().enumerate() {
            select(i);
            match op {
                Op::Squeue(s) => assert_eq!(&ctl.squeue(), s),
                Op::Reports { id, cursor_after, ts } => {
                    let (mut c, mut out) = (0usize, Vec::new());
                    ctl.read_new_ckpt_reports_into(*id, &mut c, &mut out);
                    assert_eq!((c, &out), (*cursor_after, ts));
                }
                Op::Update { id, limit, result } => {
                    assert_eq!(&ctl.scontrol_update_limit(*id, *limit), result);
                }
                Op::Batch { updates } => {
                    let args: Vec<_> = updates.iter().map(|&(id, l, _)| (id, l)).collect();
                    let want: Vec<_> = updates.iter().map(|(_, _, r)| r.clone()).collect();
                    assert_eq!(ctl.scontrol_update_limits(&args), want);
                }
                Op::Cancel { id, result } => {
                    assert_eq!(&ctl.scancel(*id), result);
                }
            }
        }
    }

    #[test]
    fn write_record_parse_roundtrips() {
        let path = tmp("rt");
        let cfg = DaemonConfig::default();
        let mut w = JournalWriter::create(&path, "early-cancel", &cfg).unwrap();
        w.snapshot("meta 0 0 0 1 0\nstats 0 0 0 0 0 0 0 0 0 0 0 0 0 0").unwrap();
        w.note_polls(2).unwrap();
        let ops = sample_ops();
        w.begin_tick(40);
        {
            let mut script = Scripted { ops: ops.clone(), i: 0 };
            // Scripted picks its op by index; re-borrow per op so the
            // index can advance between recorder calls.
            for (k, op) in ops.iter().enumerate() {
                script.i = k;
                let mut rec = RecordingCtl::new(&mut script, &mut w);
                drive(&mut rec, std::slice::from_ref(op), |_| ());
            }
        }
        w.end_tick().unwrap();
        drop(w);

        let j = parse(&path).unwrap();
        assert_eq!(j.policy, "early-cancel");
        assert_eq!(j.blocks.len(), 3);
        assert!(matches!(&j.blocks[0], Block::Snapshot(s) if s.starts_with("meta ")));
        assert_eq!(j.blocks[1], Block::Polls(2));
        match &j.blocks[2] {
            Block::Tick { now, ops: parsed } => {
                assert_eq!(*now, 40);
                assert_eq!(parsed, &ops);
            }
            other => panic!("expected tick, got {other:?}"),
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replayed_ops_match_recording() {
        let ops = sample_ops();
        let mut rc = ReplayCtl::new(40, ops.clone());
        drive(&mut rc, &ops, |_| ());
        assert_eq!(rc.remaining(), 0);
        assert_eq!(rc.take_diverged(), None);
    }

    #[test]
    fn torn_tail_is_discarded() {
        let path = tmp("torn");
        let cfg = DaemonConfig::default();
        let mut w = JournalWriter::create(&path, "extend", &cfg).unwrap();
        w.snapshot("meta 0 0 0 1 0").unwrap();
        w.begin_tick(20);
        w.end_tick().unwrap();
        drop(w);
        let whole = parse(&path).unwrap();
        assert_eq!(whole.blocks.len(), 2);

        // Crash mid-tick: opened block, some ops, no terminator.
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        writeln!(f, "T 40").unwrap();
        writeln!(f, "C 3 +").unwrap();
        write!(f, "U 3 14").unwrap(); // torn line, no newline
        drop(f);
        let j = parse(&path).unwrap();
        assert_eq!(j.blocks, whole.blocks, "torn tick dropped wholesale");

        // Crash mid-snapshot: S promises more lines than exist.
        std::fs::write(
            &path,
            format!("{MAGIC}\n{}\nS 3\nonly one line\n", encode_header("extend", &cfg)),
        )
        .unwrap();
        let j = parse(&path).unwrap();
        assert!(j.blocks.is_empty(), "half snapshot dropped: {:?}", j.blocks);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn rotation_bounds_disk_and_chain_parse_sees_one_stream() {
        let path = tmp("rot");
        let cfg = DaemonConfig {
            journal_rotate_bytes: 256,
            journal_keep_segments: 2,
            ..Default::default()
        };
        let mut w = JournalWriter::create(&path, "early-cancel", &cfg).unwrap();
        let state = "meta 0 0 0 1 1 0\nstats 0 0 0 0 0 0 0 0 0 0 0 0 0 0";
        for i in 0..40u64 {
            w.begin_tick(i * 20);
            w.end_tick().unwrap();
            w.snapshot(state).unwrap();
        }
        let (rotated, pruned, peak) = w.rotation_stats();
        assert!(rotated >= 10, "a 256-byte threshold must rotate many times: {rotated}");
        assert!(pruned > 0, "segments beyond the keep window must be pruned: {pruned}");
        assert!(peak >= 256, "peak tracks the whole chain: {peak}");
        let segs = live_segments(&path);
        assert!(segs.len() <= 2, "disk exceeds the keep limit: {} segments", segs.len());
        drop(w);

        let j = parse(&path).unwrap();
        assert!(j.segments >= 2, "chain parse must walk rotated segments: {}", j.segments);
        assert!(
            matches!(j.blocks.last(), Some(Block::Snapshot(_))),
            "chain must end with the final snapshot"
        );
        // Every rotated-in segment opens with a full snapshot: that is
        // what lets old segments be pruned without losing replayability.
        for (_, seg) in &segs {
            let text = std::fs::read_to_string(seg).unwrap();
            let (_, _, blocks) = parse_segment(seg, &text).unwrap();
            assert!(
                matches!(blocks.first(), Some(Block::Snapshot(_))),
                "rotated segment {} must open with a snapshot",
                seg.display()
            );
        }
        for (_, seg) in segs {
            let _ = std::fs::remove_file(seg);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn mid_rotation_kill_window_is_recoverable() {
        let path = tmp("midrot");
        // rotate_bytes = 1: every snapshot rotates first.
        let cfg = DaemonConfig {
            journal_rotate_bytes: 1,
            journal_keep_segments: 4,
            ..Default::default()
        };
        let mut w = JournalWriter::create(&path, "extend", &cfg).unwrap();
        w.snapshot("meta 7 0 0 1 1 0").unwrap();
        w.begin_tick(20);
        w.end_tick().unwrap();
        w.kill_mid_rotation().unwrap();
        assert!(w.end_tick().is_err(), "writes after a mid-rotation kill must fail");
        assert!(w.snapshot("meta 8 0 0 1 1 0").is_err());
        drop(w);

        assert!(!path.exists(), "the base segment is gone inside the rotation window");
        let j = parse(&path).unwrap();
        let last_snap = j.blocks.iter().rev().find_map(|b| match b {
            Block::Snapshot(s) => Some(s.clone()),
            _ => None,
        });
        assert_eq!(
            last_snap.as_deref(),
            Some("meta 7 0 0 1 1 0\n"),
            "recovery reads the rotated segments alone"
        );
        for (_, seg) in live_segments(&path) {
            let _ = std::fs::remove_file(seg);
        }
    }

    #[test]
    fn bit_flip_is_diagnosed_with_segment_and_offset() {
        let path = tmp("flip");
        let cfg = DaemonConfig::default();
        let mut w = JournalWriter::create(&path, "hybrid", &cfg).unwrap();
        w.snapshot("meta 3 0 0 1 1 0\nstats 0 0 0 0 0 0 0 0 0 0 0 0 0 0").unwrap();
        w.note_polls(5).unwrap();
        drop(w);
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip a digit inside the snapshot payload: still parseable
        // text, so only the checksum can catch it.
        let needle = b"meta 3";
        let i = bytes.windows(needle.len()).position(|win| win == needle).unwrap();
        bytes[i + 5] = b'9';
        std::fs::write(&path, &bytes).unwrap();
        let msg = format!("{:#}", parse(&path).unwrap_err());
        assert!(msg.contains("checksum mismatch"), "{msg}");
        assert!(msg.contains("at byte"), "diagnostic must name the offset: {msg}");
        assert!(msg.contains("flip"), "diagnostic must name the segment: {msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn torn_header_is_diagnosed_not_panicking() {
        let path = tmp("tornhdr");
        std::fs::write(&path, format!("{MAGIC}\nH early-cancel 20 30")).unwrap();
        let msg = format!("{:#}", parse(&path).unwrap_err());
        assert!(msg.contains("header"), "{msg}");
        assert!(msg.contains("byte 0"), "diagnostic must name the offset: {msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn snapshot_truncation_mid_file_is_diagnosed() {
        let path = tmp("midtrunc");
        let cfg = DaemonConfig::default();
        let hdr = encode_header("extend", &cfg);
        // The snapshot promises 3 state lines but loses its E
        // terminator mid-file — later blocks follow, so this is
        // corruption, not a torn tail.
        std::fs::write(&path, format!("{MAGIC}\n{hdr}\nS 3\nonly one line\nP 2\nT 40\nK\n"))
            .unwrap();
        let msg = format!("{:#}", parse(&path).unwrap_err());
        assert!(msg.contains("truncated snapshot record at byte"), "{msg}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn replay_ctl_flags_divergence() {
        let mut rc = ReplayCtl::new(40, vec![Op::Cancel { id: JobId(1), result: Ok(()) }]);
        {
            let ctl: &mut dyn SlurmControl = &mut rc;
            assert!(ctl.scancel(JobId(2)).is_err(), "wrong id must not be served");
        }
        assert!(rc.take_diverged().is_some());

        let mut rc = ReplayCtl::new(40, vec![Op::Cancel { id: JobId(1), result: Ok(()) }]);
        {
            let ctl: &mut dyn SlurmControl = &mut rc;
            assert_eq!(ctl.scancel(JobId(1)), Ok(()));
        }
        assert_eq!(rc.take_diverged(), None);
        assert_eq!(rc.remaining(), 0);
    }
}
