//! `tailtamer` — leader binary: generate workloads, run scenarios,
//! compare policies, and drive the live autonomy loop.
//!
//! ```text
//! tailtamer gen      [--seed N] [--out trace.csv]        write the PM100-like cohort
//! tailtamer simulate [--policy P] [--config F] [...]     one scenario, summary to stdout
//! tailtamer compare  [--config F] [--csv out.csv] [...]  all four policies -> Table 1 + Fig 4
//! tailtamer sweep    [--jobs N] [--nodes N] [--threads N] parallel scaled ablation grid
//!                    [--policies a,b:1,c] [--shards N]    ... over any PolicySpec list,
//!                                                         optionally as an N-cluster federation
//! tailtamer live     [--policy P] [--speed X]            wall-clock demo with real reporting
//!                    [--flaky N] [--journal F]            ... with fault injection + durability
//! tailtamer supervise --journal F [...]                  live under a restart supervisor
//!                    (= live --supervise; kill -9 the child and it resumes from the journal)
//! tailtamer engines                                      list decision-engine status
//! tailtamer --replay journal.log                         rebuild a crashed daemon from its journal
//! tailtamer --list-policies                              the policy registry + parameters
//! ```
//!
//! Policies are [`tailtamer::policy::PolicySpec`] strings everywhere:
//! the legacy four plus parameterized ones like `extend-budget:1200`,
//! `tail-aware:0.25`, `hybrid-backoff:60`.

use std::path::PathBuf;

use tailtamer::bail;
use tailtamer::errors::{Context, Result};

use tailtamer::cli::Args;
use tailtamer::config::{EngineKind, Experiment};
use tailtamer::daemon::{Autonomy, DaemonConfig, run_scenario};
use tailtamer::metrics::summarize;
use tailtamer::policy::PolicySpec;
use tailtamer::report::{render_fig4, render_policy_matrix, render_table1, summaries_csv};
use tailtamer::runtime::{PjrtEngine, default_artifacts_dir};
use tailtamer::analytics::{DecisionEngine, NativeEngine};

const VALUE_KEYS: &[&str] = &[
    "seed", "policy", "policies", "out", "csv", "config", "engine", "speed", "nodes", "trace",
    "ckpt-interval", "poll-period", "margin", "scale", "jobs", "threads", "mean-gap",
    "backfill-profile", "flaky", "journal", "replay", "journal-rotate-bytes",
    "journal-keep-segments", "rpc-concurrency", "shards", "fed-threads", "mtbf", "drain-secs",
];
// `--quick` is NOT here: it belongs to the bench/example binaries
// (`cargo bench -- --quick`), which parse their own argv — the
// tailtamer binary accepting-but-ignoring it was usage.txt drift.
const FLAG_KEYS: &[&str] = &[
    "help", "stagger", "keep-node-sizes", "blind-poll", "perpetual-backfill", "list-policies",
    "supervise", "supervised-child",
];

fn main() {
    tailtamer::logging::set_max_level(tailtamer::logging::Level::Info);
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() -> ! {
    eprint!("{}", include_str!("usage.txt"));
    std::process::exit(2);
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1), VALUE_KEYS, FLAG_KEYS)?;
    if args.flag("list-policies") {
        print!("{}", PolicySpec::list_text());
        return Ok(());
    }
    if let Some(p) = args.get("replay") {
        // Crash recovery is a first-class entry point: no command, no
        // config — everything needed travels in the journal header.
        return cmd_replay(&PathBuf::from(p));
    }
    if args.flag("help") || args.positional().is_empty() {
        usage();
    }
    let mut experiment = match args.get("config") {
        Some(p) => Experiment::load(&PathBuf::from(p))?,
        None => Experiment::default(),
    };
    if let Some(seed) = args.get("seed") {
        experiment.pm100.seed = seed.parse().context("--seed")?;
    }
    experiment.workload.ckpt_interval =
        args.get_i64("ckpt-interval", experiment.workload.ckpt_interval)?;
    experiment.daemon.poll_period = args.get_i64("poll-period", experiment.daemon.poll_period)?;
    experiment.daemon.margin = args.get_i64("margin", experiment.daemon.margin)?;
    experiment.scale_factor = args.get_i64("scale", experiment.scale_factor)?;
    if let Some(n) = args.get("nodes") {
        experiment.slurm.nodes = n.parse().context("--nodes")?;
    }
    if let Some(e) = args.get("engine") {
        experiment.engine = EngineKind::parse(e).context("--engine must be pjrt|native")?;
    }
    if let Some(j) = args.get("journal") {
        // Event-sourced durability: every tick is appended here and a
        // crashed run resumes via `--replay` (same key as TOML
        // `daemon.journal_path`).
        experiment.daemon.journal_path = Some(j.to_string());
    }
    experiment.daemon.journal_rotate_bytes = args
        .get_i64("journal-rotate-bytes", experiment.daemon.journal_rotate_bytes as i64)?
        .max(0) as u64;
    experiment.daemon.journal_keep_segments = args
        .get_i64("journal-keep-segments", experiment.daemon.journal_keep_segments as i64)?
        .max(0) as u32;
    experiment.daemon.rpc_concurrency =
        args.get_i64("rpc-concurrency", experiment.daemon.rpc_concurrency as i64)?.max(1) as u32;
    experiment.slurm.failures.mtbf =
        args.get_i64("mtbf", experiment.slurm.failures.mtbf)?.max(0);
    experiment.slurm.failures.drain_secs =
        args.get_i64("drain-secs", experiment.slurm.failures.drain_secs)?.max(0);
    // Keep the tail-aware hazard term in sync with a CLI-overridden
    // MTBF (mirrors the cross-section assignment in config loading).
    experiment.daemon.failure_mtbf = experiment.slurm.failures.mtbf;
    experiment.shards = args.get_i64("shards", experiment.shards as i64)?.max(1) as u32;
    experiment.fed_threads =
        args.get_i64("fed-threads", experiment.fed_threads as i64)?.max(0) as u32;
    if let Some(p) = args.get("backfill-profile") {
        experiment.slurm.backfill_profile = tailtamer::slurm::BackfillProfile::parse(p)
            .context("--backfill-profile must be tree|flat")?;
    }
    if args.flag("blind-poll") {
        // Reference mode: execute every daemon poll tick instead of
        // eliding provably no-op ones (results are bit-identical).
        experiment.slurm.poll_elision = false;
    }
    if args.flag("perpetual-backfill") {
        // Reference mode: pop one backfill tick per interval forever
        // instead of scheduling ticks on demand (results are
        // bit-identical).
        experiment.slurm.backfill_ticks = tailtamer::slurm::BackfillTicks::Perpetual;
    }

    match args.positional()[0].as_str() {
        "gen" => cmd_gen(&args, &experiment),
        "simulate" => cmd_simulate(&args, &experiment),
        "compare" => cmd_compare(&args, &experiment),
        "sweep" => cmd_sweep(&args, &experiment),
        "live" => cmd_live(&args, &experiment, args.flag("supervise")),
        "supervise" => cmd_live(&args, &experiment, true),
        "engines" => cmd_engines(),
        other => bail!("unknown command {other:?} (see --help)"),
    }
}

fn make_engine(kind: EngineKind) -> Result<Box<dyn DecisionEngine>> {
    Ok(match kind {
        EngineKind::Native => Box::new(NativeEngine::new()),
        EngineKind::Pjrt => Box::new(
            PjrtEngine::load(&default_artifacts_dir())
                .context("loading PJRT decision model (run `make artifacts`, or use --engine native)")?,
        ),
    })
}

fn cmd_gen(args: &Args, e: &Experiment) -> Result<()> {
    let cohort = tailtamer::workload::generate_cohort(&e.pm100);
    let out = PathBuf::from(args.get_or("out", "trace.csv"));
    tailtamer::workload::csv::save_csv(&out, &cohort)?;
    println!(
        "wrote {} jobs to {} (seed {})",
        cohort.len(),
        out.display(),
        e.pm100.seed
    );
    Ok(())
}

fn load_specs(args: &Args, e: &Experiment) -> Result<Vec<tailtamer::slurm::JobSpec>> {
    // `--trace` wins over the config file's `[workload] trace`; the
    // extension picks the parser (`.swf` = Standard Workload Format,
    // anything else the strict CSV projection).
    let trace = args.get("trace").map(str::to_string).or_else(|| e.trace.clone());
    let Some(p) = trace else { return Ok(e.build_workload()) };
    let path = PathBuf::from(&p);
    let is_swf = path.extension().is_some_and(|x| x.eq_ignore_ascii_case("swf"));
    let (records, malformed) = if is_swf {
        let t = tailtamer::workload::swf::load_swf(&path)?;
        (t.records, t.malformed)
    } else {
        (tailtamer::workload::csv::load_csv(&path)?, 0)
    };
    let scaled = tailtamer::workload::scale(&records, e.scale_factor);
    let specs = tailtamer::workload::to_job_specs(&scaled, &e.workload);
    if is_swf {
        // Deterministic ingest anchor (no wall-clock fields): CI runs
        // the bundled fixture twice and diffs this line.
        println!(
            "trace-summary: source=swf jobs={} malformed={} ckpt_jobs={} total_duration={}",
            specs.len(),
            malformed,
            specs.iter().filter(|s| s.ckpt.is_some()).count(),
            specs.iter().map(|s| s.duration).sum::<tailtamer::simtime::Time>(),
        );
    }
    Ok(specs)
}

fn cmd_simulate(args: &Args, e: &Experiment) -> Result<()> {
    let policy = match args.get("policy") {
        Some(p) => PolicySpec::parse(p).context("--policy")?,
        None => e.policy.clone(),
    };
    let specs = load_specs(args, e)?;
    if e.shards > 1 {
        return cmd_simulate_federated(e, &policy, &specs);
    }
    let engine = make_engine(e.engine)?;
    let t0 = std::time::Instant::now();
    let (jobs, stats, dstats) =
        run_scenario(&specs, e.slurm.clone(), policy.clone(), e.daemon.clone(), Some(engine));
    let s = summarize(&policy.display(), &jobs, &stats);
    println!("{}", render_table1(std::slice::from_ref(&s)));
    println!(
        "daemon: polls={} engine_calls={} cancels={} extensions={} mean_engine={:.1}us",
        dstats.polls,
        dstats.engine_calls,
        dstats.cancels,
        dstats.extensions,
        dstats.engine_nanos as f64 / dstats.engine_calls.max(1) as f64 / 1000.0
    );
    println!("wall: {:.2}s", t0.elapsed().as_secs_f64());
    Ok(())
}

/// `simulate --shards N`: run the workload as an N-cluster federation
/// with the parallel per-shard drive (`--fed-threads`, default auto;
/// bit-identical to the merged/sharded reference drives — see
/// `tailtamer::slurm::fed`).
fn cmd_simulate_federated(
    e: &Experiment,
    policy: &PolicySpec,
    specs: &[tailtamer::slurm::JobSpec],
) -> Result<()> {
    use tailtamer::slurm::fed;
    use tailtamer::slurm::{FedDrive, run_federation};
    if e.engine == EngineKind::Pjrt {
        tailtamer::warn_log!(
            "federation shards use the native decision engine (bit-identical oracle); \
             --engine pjrt is ignored with --shards > 1"
        );
    }
    let shards = e.shards as usize;
    let threads = if e.fed_threads == 0 {
        fed::default_fed_threads(shards)
    } else {
        (e.fed_threads as usize).min(shards)
    };
    let t0 = std::time::Instant::now();
    let out = run_federation(
        specs,
        shards,
        &e.slurm,
        policy,
        &e.daemon,
        FedDrive::Parallel { threads },
    );
    let s = summarize(&policy.display(), &out.jobs, &out.stats);
    println!("{}", render_table1(std::slice::from_ref(&s)));
    let d = &out.daemon_stats;
    println!(
        "federation: shards={} threads={} retired={} peak_table_bytes={} drive={:.2}s recombine={:.3}s",
        e.shards,
        threads,
        out.retired,
        out.peak_table_bytes,
        out.drive_nanos as f64 / 1e9,
        out.recombine_nanos as f64 / 1e9
    );
    println!(
        "daemon: polls={} engine_calls={} cancels={} extensions={}",
        d.polls, d.engine_calls, d.cancels, d.extensions
    );
    // Deterministic one-liner (no wall-clock fields): CI diffs this
    // line across --fed-threads values to smoke the drive identity.
    println!(
        "fed-summary: jobs={} tail_waste={} cancels={} extensions={} retired={} peak_table_bytes={}",
        out.jobs.len(),
        s.tail_waste,
        d.cancels,
        d.extensions,
        out.retired,
        out.peak_table_bytes
    );
    println!("wall: {:.2}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_compare(args: &Args, e: &Experiment) -> Result<()> {
    let specs = load_specs(args, e)?;
    // One engine for all four scenarios: the PJRT executables compile
    // once (the daemon state is per-scenario; the engine is stateless).
    let shared = tailtamer::analytics::SharedEngine(match e.engine {
        EngineKind::Native => std::rc::Rc::new(std::cell::RefCell::new(NativeEngine::new())),
        EngineKind::Pjrt => std::rc::Rc::new(std::cell::RefCell::new(
            PjrtEngine::load(&default_artifacts_dir())
                .context("loading PJRT decision model (run `make artifacts`, or use --engine native)")?,
        )),
    });
    // The paper's 4-policy grid by default; `--policies` swaps in any
    // PolicySpec list (the first entry is the comparison baseline).
    let policies: Vec<PolicySpec> = match args.get("policies") {
        Some(list) => PolicySpec::parse_list(list).context("--policies")?,
        None => PolicySpec::legacy_all().to_vec(),
    };
    let mut summaries = Vec::new();
    for policy in &policies {
        let (jobs, stats, _) = run_scenario(
            &specs,
            e.slurm.clone(),
            policy.clone(),
            e.daemon.clone(),
            Some(Box::new(shared.clone())),
        );
        summaries.push(summarize(&policy.display(), &jobs, &stats));
        tailtamer::info!("{} done", policy.display());
    }
    println!("{}", render_table1(&summaries));
    println!("{}", render_fig4(&summaries));
    // Compare cells are unmetered (shared engine, no federation): the
    // perf columns render as dashes.
    let matrix: Vec<(String, tailtamer::metrics::Summary, f64, usize)> = policies
        .iter()
        .zip(&summaries)
        .map(|(p, s)| (p.name(), s.clone(), 0.0, 0))
        .collect();
    println!("{}", render_policy_matrix(&matrix));
    if let Some(csv) = args.get("csv") {
        std::fs::write(csv, summaries_csv(&summaries))?;
        println!("wrote {csv}");
    }
    Ok(())
}

/// `tailtamer sweep`: the policy × workload ablation grid at scale,
/// across OS threads, with deterministic per-scenario seeds (results
/// are identical to a serial run).
fn cmd_sweep(args: &Args, e: &Experiment) -> Result<()> {
    use std::sync::Arc;
    use tailtamer::sweep::{default_threads, run_sweep, run_sweep_sharded, spec_grid};
    use tailtamer::workload::{Arrival, ScaledConfig};

    let jobs = args.get_i64("jobs", 20_000)?.max(1) as usize;
    let nodes = args.get_i64("nodes", 1024)?.max(1) as u32;
    let arrival = if args.flag("stagger") {
        Arrival::Staggered { mean_gap: args.get_i64("mean-gap", 30)?.max(1) }
    } else {
        Arrival::AllAtZero
    };
    let cfg = ScaledConfig {
        jobs,
        nodes,
        seed: e.pm100.seed,
        arrival,
        scale_factor: e.scale_factor,
        rescale_nodes: !args.flag("keep-node-sizes"),
    };
    let t0 = std::time::Instant::now();
    let specs = Arc::new(cfg.build());
    tailtamer::info!("generated {} jobs for {} nodes in {:.2?}", specs.len(), nodes, t0.elapsed());

    let policies: Vec<PolicySpec> = match args.get("policies") {
        Some(list) => PolicySpec::parse_list(list).context("--policies")?,
        None => PolicySpec::legacy_all().to_vec(),
    };
    let slurm = tailtamer::slurm::SlurmConfig { nodes, ..e.slurm.clone() };
    let grid = spec_grid(
        &format!("{}j/{}n", jobs, nodes),
        specs,
        slurm,
        e.daemon.clone(),
        &policies,
    );
    let shards = e.shards.max(1) as usize;
    let threads = match args.get_i64("threads", 0)? {
        n if n <= 0 => default_threads(grid.len() * shards),
        n => n as usize,
    };
    let t0 = std::time::Instant::now();
    let results = if shards > 1 {
        run_sweep_sharded(&grid, threads, shards)
    } else {
        run_sweep(&grid, threads)
    };
    let wall = t0.elapsed();

    let summaries: Vec<_> = results.iter().map(|r| r.summary.clone()).collect();
    println!("{}", render_table1(&summaries));
    println!("{}", render_fig4(&summaries));
    let matrix: Vec<(String, tailtamer::metrics::Summary, f64, usize)> = results
        .iter()
        .map(|r| (r.policy.name(), r.summary.clone(), r.jobs_per_sec, r.peak_table_bytes))
        .collect();
    println!("{}", render_policy_matrix(&matrix));
    for r in &results {
        println!(
            "{:<24} {:<22} drive {:>8.2?} + recombine {:>8.2?}  ({:.0} jobs/s, peak tables {} B)",
            r.label,
            r.policy.name(),
            r.drive,
            r.recombine,
            r.jobs_per_sec,
            r.peak_table_bytes
        );
    }
    println!(
        "sweep: {} scenarios x {} shard(s) on {} threads in {:.2?} (sum of cells {:.2?})",
        results.len(),
        shards,
        threads,
        wall,
        results.iter().map(|r| r.wall).sum::<std::time::Duration>()
    );
    if let Some(csv) = args.get("csv") {
        std::fs::write(csv, summaries_csv(&summaries))?;
        println!("wrote {csv}");
    }
    Ok(())
}

fn cmd_live(args: &Args, e: &Experiment, supervise: bool) -> Result<()> {
    use tailtamer::live::{LiveConfig, run_live};
    if supervise && !args.flag("supervised-child") {
        return cmd_supervise(e);
    }
    // --policy wins; otherwise the config file's policy; otherwise the
    // demo default (early-cancel shows the mechanism fastest live).
    let policy = match args.get("policy") {
        Some(p) => PolicySpec::parse(p).context("--policy")?,
        None if args.get("config").is_some() => e.policy.clone(),
        None => PolicySpec::EarlyCancel,
    };
    let speed = args.get_f64("speed", 120.0)?;
    let flaky = args.get_i64("flaky", 0)?.max(0) as u32;
    let cfg = LiveConfig {
        nodes: e.slurm.nodes.min(4),
        speed,
        poll_period: e.daemon.poll_period,
        sched_tick_ms: 10,
        flaky_rejects: flaky,
    };
    let specs = vec![
        tailtamer::slurm::JobSpec::new("ck-a", 1440, 2880, 1).with_ckpt(420),
        tailtamer::slurm::JobSpec::new("ck-b", 1440, 2880, 1).with_ckpt(300),
        tailtamer::slurm::JobSpec::new("sleep", 600, 500, 1),
    ];
    // The live demo showcases the resilience layer: actions are AIMD-
    // batched (the RPC line below shows the reduction) and, with
    // `--journal`, every tick lands in the crash-recovery log.
    //
    // A supervised child that finds a non-empty journal is a *restart*:
    // it rebuilds the daemon from the journal (the tested
    // `enable_journal`-after-`replay` path) instead of starting fresh.
    let resumed = if args.flag("supervised-child") {
        match &e.daemon.journal_path {
            Some(p) => {
                let base = std::path::Path::new(p);
                let have = std::fs::metadata(base).map(|m| m.len() > 0).unwrap_or(false)
                    || !tailtamer::journal::live_segments(base).is_empty();
                if have {
                    let (mut d, info) = Autonomy::replay_info(base)
                        .with_context(|| format!("supervised child resuming {p}"))?;
                    d.enable_journal(base).context("re-attach journaling after replay")?;
                    println!(
                        "supervised-child: resumed from {p} (ticks_replayed={} segments={})",
                        info.ticks_replayed, info.segments
                    );
                    Some(d)
                } else {
                    None
                }
            }
            None => None,
        }
    } else {
        None
    };
    let mut daemon = match resumed {
        Some(d) => d,
        None => Autonomy::new(
            policy.clone(),
            DaemonConfig { margin: 60, batch_actions: true, ..e.daemon.clone() },
            make_engine(e.engine)?,
        ),
    };
    let dir = std::env::temp_dir().join(format!("tailtamer_live_{}", std::process::id()));
    println!(
        "live: {} jobs, speed {speed}x, policy {}, engine {}{}{}",
        specs.len(),
        policy.name(),
        daemon.engine_name(),
        if flaky > 0 { ", flaky ctld" } else { "" },
        if daemon.journaling() { ", journaling" } else { "" },
    );
    let out = run_live(cfg, specs, &mut daemon, &dir, std::time::Duration::from_secs(120))?;
    for j in &out.jobs {
        println!(
            "{:8} state={:?} adj={:?} [{} .. {}] ckpts={:?} tail={} core-s",
            j.name,
            j.state,
            j.adjustment,
            j.start,
            j.end,
            j.reported_ckpts,
            j.tail_waste()
        );
    }
    let actions = out.scontrol_updates + out.scancels;
    // A run that never issued an RPC has no meaningful reduction
    // percentage — print `n/a`, never NaN (see `metrics::rpc_reduction`).
    let reduction = match tailtamer::metrics::rpc_reduction(actions, out.scontrol_rpcs) {
        Some(r) => format!("{r:.0}% reduction"),
        None => "reduction n/a".to_string(),
    };
    println!(
        "control plane: {} RPCs for {} landed actions ({} updates, {} cancels) — {reduction}, {} injected faults",
        out.scontrol_rpcs, actions, out.scontrol_updates, out.scancels, out.injected_faults,
    );
    let d = daemon.stats.deterministic();
    println!(
        "daemon: polls={} batch_calls={} batched_updates={} scontrol_errors={} budget_exhausted={}",
        d.polls, d.batch_calls, d.batched_updates, d.scontrol_errors, d.budget_exhausted
    );
    // Deterministic one-liner of job *outcomes* only (sorted by name):
    // the CI supervisor smoke diffs this line between an uninterrupted
    // run and a kill-9-and-restart run.
    let mut outcomes: Vec<String> = out
        .jobs
        .iter()
        .map(|j| format!("{}={}", j.name, format!("{:?}", j.state).to_lowercase()))
        .collect();
    outcomes.sort();
    println!("live-summary: {}", outcomes.join(" "));
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

/// `tailtamer supervise` (or `live --supervise`): run the live daemon
/// as a *restartable unit*. The supervisor spawns its own binary as
/// `live --supervised-child`; when the child dies abnormally (crash,
/// `kill -9`) it accounts the recovery cost from the journal, sleeps a
/// capped exponential backoff, and respawns — the child finds the
/// non-empty journal and resumes via replay. A clean child exit ends
/// supervision.
///
/// The *cluster* here is the live demo's in-process mock, so a respawn
/// restarts the workload from its specs; what survives the kill is the
/// daemon's journaled state. The bit-identity claim for
/// kill-and-resume lives in the in-process harness
/// (`rust/tests/supervised_replay.rs`); this loop is the operational
/// wrapper, smoke-tested in CI by `kill -9` mid-run and diffing the
/// final `live-summary:` line against an uninterrupted run.
fn cmd_supervise(e: &Experiment) -> Result<()> {
    const MAX_RESTARTS: u64 = 5;
    let Some(journal) = e.daemon.journal_path.clone() else {
        bail!("supervise needs --journal PATH (restarts recover from the journal)");
    };
    let exe = std::env::current_exe().context("locate own binary")?;
    // Re-issue our own argv at the child, demoted to a plain live run:
    // `supervise` -> `live`, `--supervise` dropped, `--supervised-child`
    // appended so the child knows a non-empty journal means *resume*.
    let child_args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| a != "--supervise")
        .map(|a| if a == "supervise" { "live".to_string() } else { a })
        .chain(std::iter::once("--supervised-child".to_string()))
        .collect();
    // A fresh supervision episode starts from a clean journal; stale
    // segments from a previous run must not be chained into this one.
    let base = PathBuf::from(&journal);
    let _ = std::fs::remove_file(&base);
    for (_, seg) in tailtamer::journal::live_segments(&base) {
        let _ = std::fs::remove_file(seg);
    }

    let mut restarts = 0u64;
    let mut ticks_recovered = 0u64;
    let mut replay_secs = 0.0f64;
    let mut backoff_ms = 100u64;
    loop {
        let status = std::process::Command::new(&exe)
            .args(&child_args)
            .status()
            .context("spawn supervised child")?;
        if status.success() {
            break;
        }
        if restarts >= MAX_RESTARTS {
            bail!("supervised child kept dying after {restarts} restarts; giving up");
        }
        restarts += 1;
        // Account what the restart will cost: a dry replay of the
        // journal the child will itself recover from. An unreadable /
        // absent journal means the child died before its first write —
        // it will simply start fresh.
        let t0 = std::time::Instant::now();
        match Autonomy::replay_info(&base) {
            Ok((_, info)) => ticks_recovered += info.ticks_replayed,
            Err(err) => {
                tailtamer::warn_log!("journal not replayable yet ({err:#}); child restarts fresh")
            }
        }
        replay_secs += t0.elapsed().as_secs_f64();
        eprintln!("supervisor: child died ({status}); restart {restarts} in {backoff_ms} ms");
        std::thread::sleep(std::time::Duration::from_millis(backoff_ms));
        backoff_ms = (backoff_ms * 2).min(5_000);
    }
    println!(
        "supervisor: restarts={restarts} replay_secs={replay_secs:.3} ticks_recovered={ticks_recovered}"
    );
    Ok(())
}

/// `tailtamer --replay journal.log`: rebuild the daemon a journaled run
/// would have produced — restore the last complete snapshot, re-run
/// every tick after it against the recorded control surface — and print
/// its deterministic stats. The recovery path the crash-kill-replay
/// tests pin bit-identical.
fn cmd_replay(path: &PathBuf) -> Result<()> {
    let t0 = std::time::Instant::now();
    let (d, info) = Autonomy::replay_info(path)
        .with_context(|| format!("replaying {}", path.display()))?;
    let s = d.stats.deterministic();
    println!(
        "replayed {} (policy {}, engine {}, segments={} ticks_replayed={})",
        path.display(),
        d.spec.name(),
        d.engine_name(),
        info.segments,
        info.ticks_replayed
    );
    println!(
        "deterministic stats: polls={} engine_calls={} batch_rows={} cancels={} extensions={}",
        s.polls, s.engine_calls, s.batch_rows, s.cancels, s.extensions
    );
    println!(
        "resilience: scontrol_errors={} budget_exhausted={} policy_declines={} batch_calls={} batched_updates={}",
        s.scontrol_errors, s.budget_exhausted, s.policy_declines, s.batch_calls, s.batched_updates
    );
    println!("wall: {:.3}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_engines() -> Result<()> {
    println!("native: available (pure-rust oracle)");
    match PjrtEngine::load(&default_artifacts_dir()) {
        Ok(e) => println!("pjrt:   available, variants {:?}", e.shapes()),
        Err(err) => println!("pjrt:   UNAVAILABLE ({err:#})"),
    }
    Ok(())
}
