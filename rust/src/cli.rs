//! Minimal CLI argument parsing (no `clap` in the offline vendor set).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional
//! arguments, with typed accessors and an "unknown argument" check so
//! typos fail loudly.

use std::collections::BTreeMap;

use crate::bail;
use crate::errors::{Context, Result};

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    known: Vec<&'static str>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    /// `value_keys` lists options that take a value; everything else
    /// starting with `--` is a boolean flag.
    pub fn parse(
        raw: impl IntoIterator<Item = String>,
        value_keys: &[&'static str],
        flag_keys: &[&'static str],
    ) -> Result<Self> {
        let mut out = Args::default();
        out.known = value_keys.iter().chain(flag_keys.iter()).copied().collect();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                let (key, inline) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if value_keys.contains(&key.as_str()) {
                    let v = match inline {
                        Some(v) => v,
                        None => it.next().with_context(|| format!("--{key} needs a value"))?,
                    };
                    out.opts.insert(key, v);
                } else if flag_keys.contains(&key.as_str()) {
                    if inline.is_some() {
                        bail!("--{key} does not take a value");
                    }
                    out.flags.push(key);
                } else {
                    bail!("unknown argument --{key}");
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> bool {
        debug_assert!(self.known.contains(&name), "unregistered flag {name}");
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        debug_assert!(self.known.contains(&name), "unregistered option {name}");
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_i64(&self, name: &str, default: i64) -> Result<i64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad integer {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{name}: bad float {v:?}")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Result<Args> {
        Args::parse(
            args.iter().map(|s| s.to_string()),
            &["seed", "policy", "out"],
            &["quick", "verbose"],
        )
    }

    #[test]
    fn parses_options_flags_positionals() {
        let a = parse(&["compare", "--seed", "7", "--policy=hybrid", "--quick", "trace.csv"]).unwrap();
        assert_eq!(a.positional(), &["compare", "trace.csv"]);
        assert_eq!(a.get("seed"), Some("7"));
        assert_eq!(a.get("policy"), Some("hybrid"));
        assert!(a.flag("quick"));
        assert!(!a.flag("verbose"));
        assert_eq!(a.get_i64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(parse(&["--nope"]).is_err());
        assert!(parse(&["--seed"]).is_err());
        assert!(parse(&["--quick=1"]).is_err());
        let a = parse(&["--seed", "x"]).unwrap();
        assert!(a.get_i64("seed", 0).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]).unwrap();
        assert_eq!(a.get_or("policy", "hybrid"), "hybrid");
        assert_eq!(a.get_i64("seed", 42).unwrap(), 42);
        assert_eq!(a.get_f64("seed", 1.5).unwrap(), 1.5);
    }
}
