//! Job model: specifications, lifecycle state, and checkpoint plans.

use std::sync::Arc;

use crate::simtime::Time;

/// Index into the simulator's job table. Stable for the lifetime of a
/// simulation; also used as the priority rank (lower id = higher
/// priority, i.e. FIFO by submission order, the test system's default).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u32);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job{}", self.0)
    }
}

/// Slurm-visible job states (the subset the paper's workload exercises).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    /// Finished before its (possibly adjusted) limit.
    Completed,
    /// Hit its (possibly adjusted) limit.
    Timeout,
    /// Cancelled by `scancel` (the daemon's early cancellation).
    Cancelled,
    /// Killed because a node it was running on failed (`Ev::NodeFail`):
    /// the job terminates at the failure instant and everything since
    /// its last visible checkpoint is lost (its own tail-waste class in
    /// [`crate::metrics`]).
    NodeFailed,
}

impl JobState {
    pub fn is_terminal(self) -> bool {
        matches!(
            self,
            JobState::Completed | JobState::Timeout | JobState::Cancelled | JobState::NodeFailed
        )
    }
}

/// Which scheduler path started the job (Slurm's `SchedMain` vs
/// `SchedBackfill` accounting, Table 1 rows 6–7).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StartedBy {
    Main,
    Backfill,
}

/// Daemon adjustment applied to a job (Table 1 rows 2–3). A job receives
/// at most one adjustment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Adjustment {
    EarlyCancelled,
    Extended,
}

/// Checkpointing behaviour of the application inside a job.
///
/// The application checkpoints at (approximately) fixed intervals and
/// reports each completed checkpoint by timestamp — the paper's
/// temp-file protocol. `jitter_frac` models checkpoint-duration noise:
/// each interval is `interval * (1 + U(-jitter_frac, +jitter_frac))`
/// drawn from a per-job deterministic stream.
#[derive(Debug, Clone, PartialEq)]
pub struct CkptSpec {
    pub interval: Time,
    pub jitter_frac: f64,
    pub seed: u64,
}

impl CkptSpec {
    pub fn fixed(interval: Time) -> Self {
        Self { interval, jitter_frac: 0.0, seed: 0 }
    }

    /// Checkpoint completion offsets (relative to job start), strictly
    /// increasing, covering `[0, horizon)`.
    pub fn plan(&self, horizon: Time) -> Vec<Time> {
        let mut rng = crate::proptest_lite::Rng::new(self.seed ^ 0x9e3779b97f4a7c15);
        let mut out = Vec::new();
        let mut t = 0i64;
        loop {
            let mut step = self.interval;
            if self.jitter_frac > 0.0 {
                let u = rng.next_f64() * 2.0 - 1.0; // U(-1, 1)
                step = ((self.interval as f64) * (1.0 + self.jitter_frac * u)).round() as Time;
                step = step.max(1);
            }
            t += step;
            if t >= horizon {
                return out;
            }
            out.push(t);
        }
    }
}

/// Immutable submission-time description of a job.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Interned job name: cloning a spec (or snapshotting the queue)
    /// bumps a refcount instead of copying the string (§Perf).
    pub name: Arc<str>,
    /// Submission time in seconds. 0 (the paper's replay) releases the
    /// job before the simulation starts; positive values arrive through
    /// a scheduled submit event (staggered-arrival scenarios).
    pub submit: Time,
    /// User-provided time limit, seconds.
    pub time_limit: Time,
    /// True execution time if never limited, seconds. For the synthetic
    /// checkpointing jobs this exceeds the limit (they originally hit
    /// the 24 h cap on Marconi).
    pub duration: Time,
    /// Whole nodes allocated exclusively.
    pub nodes: u32,
    /// Accounting cores (original trace cores; Marconi-like 48/node).
    pub cores: u32,
    /// Checkpointing applications report progress; `None` = opaque job.
    pub ckpt: Option<CkptSpec>,
}

impl JobSpec {
    /// Convenience constructor for tests and examples.
    pub fn new(name: &str, time_limit: Time, duration: Time, nodes: u32) -> Self {
        Self {
            name: Arc::from(name),
            submit: 0,
            time_limit,
            duration,
            nodes,
            cores: nodes * 48,
            ckpt: None,
        }
    }

    pub fn with_ckpt(mut self, interval: Time) -> Self {
        self.ckpt = Some(CkptSpec::fixed(interval));
        self
    }
}

/// A job's full simulator-side record.
#[derive(Debug, Clone, PartialEq)]
pub struct Job {
    pub id: JobId,
    pub spec: JobSpec,
    pub state: JobState,
    /// Current (possibly daemon-adjusted) time limit.
    pub cur_limit: Time,
    pub start: Option<Time>,
    pub end: Option<Time>,
    pub started_by: Option<StartedBy>,
    pub adjustment: Option<Adjustment>,
    /// Planned checkpoint offsets relative to start (empty if
    /// non-checkpointing). Only entries `< end - start` complete.
    pub ckpt_plan: Vec<Time>,
}

impl Job {
    pub fn new(id: JobId, spec: JobSpec) -> Self {
        let cur_limit = spec.time_limit;
        // The plan horizon is the job's true duration: a job cannot
        // checkpoint past its own completion, and limit extensions are
        // bounded by termination either way.
        let ckpt_plan = spec.ckpt.as_ref().map(|c| c.plan(spec.duration)).unwrap_or_default();
        Self {
            id,
            spec,
            state: JobState::Pending,
            cur_limit,
            start: None,
            end: None,
            started_by: None,
            adjustment: None,
            ckpt_plan,
        }
    }

    pub fn is_checkpointing(&self) -> bool {
        !self.ckpt_plan.is_empty()
    }

    /// Expected end as the *scheduler* sees it: start + current limit.
    pub fn expected_end(&self) -> Option<Time> {
        self.start.map(|s| s + self.cur_limit)
    }

    /// The end the job will actually reach under the current limit
    /// (+`grace` of OverTimeLimit): completion or timeout.
    pub fn actual_end(&self, grace: Time) -> Option<Time> {
        self.start.map(|s| s + self.spec.duration.min(self.cur_limit + grace))
    }

    /// Would the job COMPLETE (rather than time out) under the current
    /// limit (+grace)?
    pub fn completes(&self, grace: Time) -> bool {
        self.spec.duration <= self.cur_limit + grace
    }

    /// Checkpoint completion times (absolute), given the realized end.
    ///
    /// A checkpoint whose timestamp coincides with the termination
    /// instant counts as completed: the write is modelled as atomic at
    /// its timestamp, and early cancellation deliberately lands right
    /// after a completed checkpoint.
    pub fn completed_ckpts(&self, end: Time) -> impl Iterator<Item = Time> + '_ {
        let start = self.start.expect("job never started");
        self.ckpt_plan
            .iter()
            .map(move |&o| start + o)
            .take_while(move |&t| t <= end)
    }

    /// Wall-clock execution time actually consumed.
    pub fn elapsed(&self) -> Time {
        match (self.start, self.end) {
            (Some(s), Some(e)) => e - s,
            _ => 0,
        }
    }

    /// Queue wait time (start − submit).
    pub fn wait(&self) -> Option<Time> {
        self.start.map(|s| s - self.spec.submit)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_plan_is_periodic() {
        let c = CkptSpec::fixed(420);
        assert_eq!(c.plan(1440), vec![420, 840, 1260]);
        assert_eq!(c.plan(421), vec![420]);
        assert_eq!(c.plan(420), vec![]); // strictly before horizon
    }

    #[test]
    fn jittered_plan_is_monotone_and_bounded() {
        let c = CkptSpec { interval: 420, jitter_frac: 0.3, seed: 7 };
        let plan = c.plan(100_000);
        assert!(!plan.is_empty());
        for w in plan.windows(2) {
            let step = w[1] - w[0];
            assert!(step >= (420.0 * 0.69) as i64 && step <= (420.0 * 1.31) as i64);
        }
        // Deterministic per seed.
        assert_eq!(plan, c.plan(100_000));
        assert_ne!(plan, CkptSpec { seed: 8, ..c }.plan(100_000));
    }

    #[test]
    fn job_end_semantics() {
        // The paper's canonical checkpointing job: 24 min limit (scaled
        // 24 h), true duration past the limit, 7 min checkpoints.
        let spec = JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420);
        let mut j = Job::new(JobId(0), spec);
        j.start = Some(100);
        assert_eq!(j.expected_end(), Some(1540));
        assert_eq!(j.actual_end(0), Some(1540));
        assert!(!j.completes(0));
        let ckpts: Vec<_> = j.completed_ckpts(1540).collect();
        assert_eq!(ckpts, vec![520, 940, 1360]);

        // Extension to fit the 4th checkpoint.
        j.cur_limit = 1680 + 30;
        assert_eq!(j.actual_end(0), Some(100 + 1710));
        let ckpts: Vec<_> = j.completed_ckpts(1810).collect();
        assert_eq!(ckpts.len(), 4);
    }

    #[test]
    fn completion_beats_limit() {
        let spec = JobSpec::new("ok", 1440, 900, 2);
        let mut j = Job::new(JobId(1), spec);
        j.start = Some(0);
        assert!(j.completes(0));
        assert_eq!(j.actual_end(0), Some(900));
        assert!(!j.is_checkpointing());
    }

    #[test]
    fn grace_allows_completion() {
        let spec = JobSpec::new("g", 100, 110, 1);
        let mut j = Job::new(JobId(2), spec);
        j.start = Some(0);
        assert!(!j.completes(0));
        assert!(j.completes(15));
        assert_eq!(j.actual_end(15), Some(110));
    }
}
