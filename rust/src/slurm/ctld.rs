//! `Slurmd`: the central-management-daemon simulator.
//!
//! A from-scratch, event-driven reimplementation of the Slurm behaviours
//! the paper's autonomy loop interacts with:
//!
//! - **SchedMain** — the priority scheduler: on every state change, walk
//!   the pending queue in priority (FIFO submission) order and start
//!   jobs until the first one that does not fit; stop there so a small
//!   job can never leapfrog the queue head outside of backfill.
//! - **SchedBackfill** — conservative backfill on a periodic tick
//!   (default 30 s): build the capacity [`CapacityProfile`] from running jobs'
//!   *expected* ends (start + current limit), walk pending jobs in
//!   priority order, start those whose earliest feasible start is *now*,
//!   and leave a reservation for every other examined job. Reservations
//!   guarantee a backfilled job never delays a higher-priority one. The
//!   pass also records each pending job's predicted start and the free
//!   node count at that instant — exactly the `squeue`-derived signals
//!   the paper's daemon consumes.
//! - **scontrol / squeue / scancel** — the control surface the daemon
//!   uses: time-limit updates (with event rescheduling via lazy
//!   invalidation), queue snapshots, and cancellation.
//! - **OverTimeLimit** — the blanket grace period Slurm offers (the
//!   paper's strawman alternative); configurable, default off.
//!
//! Timeouts are modelled faithfully: a job ends at
//! `start + min(duration, cur_limit + grace)` — COMPLETED if its true
//! duration fit, TIMEOUT otherwise, CANCELLED if scancel'ed first.
//!
//! ## Hot-path design (EXPERIMENTS.md §Perf)
//!
//! The scheduler core is allocation-free in the steady state:
//!
//! - the backfill pass removes started jobs from the pending queue with
//!   one in-place compaction (O(P)) instead of a `retain` per started
//!   job (O(S·P));
//! - placement runs against a [`CapacityProfile`]: by default the
//!   min-augmented capacity tree ([`crate::cluster::CapTree`]), whose
//!   `find_earliest` is an O(log B) augmented descent and whose
//!   reservations are lazy range-adds, turning the pass from O(P·B)
//!   toward O(P·log B); `backfill_profile = "flat"` selects the flat
//!   breakpoint-list arena instead (both are pooled across passes);
//! - when only job *limits* changed since the previous pass, the
//!   running-jobs base profile is refreshed incrementally via
//!   `shift_release` instead of rebuilt;
//! - the per-job tables on the allocate/release/end paths
//!   (`scheduled_end`, `bf_release`, `Cluster`'s allocation table) are
//!   dense vectors indexed by the dense [`JobId`] — no hashing;
//! - `squeue`/checkpoint reads go through the `*_into` variants of
//!   [`SlurmControl`], writing into caller-provided buffers; job names
//!   are interned `Arc<str>`, so a snapshot row never copies a string;
//! - checkpoint reports flow through **delta cursors**
//!   (`read_new_ckpt_reports_into`): each report crosses the control
//!   surface once over a job's life instead of the full prefix being
//!   re-materialized every poll;
//! - provably no-op daemon polls are **elided**: the control plane
//!   tracks a queue/report epoch plus the next report-visibility
//!   instant, and [`Slurmd::run`] fast-forwards `Ev::DaemonPoll`
//!   across quiet stretches with accounting preserved — steady-state
//!   poll cost is proportional to *change*, not to R, Q, or elapsed
//!   time (`SlurmConfig::poll_elision`; blind polling retained as the
//!   reference mode);
//! - backfill ticks are **on-demand** ([`BackfillTicks::OnDemand`],
//!   the default): instead of a perpetual 30 s `Ev::BackfillTick`
//!   self-reschedule popping one slot per interval forever, the event
//!   loop runs a *virtual tick chain* that materializes work only at
//!   the grid slots where a pass actually runs. Clean slots are
//!   batch-skipped in O(1) with their `backfill_skipped` /
//!   `SlurmStats::events` accounting synthesized, and same-instant
//!   ordering against queued events is reproduced exactly via a seq
//!   watermark ([`EventQueue::peek`]) — so job records and all
//!   deterministic stats stay bit-identical to the perpetual
//!   reference mode, while the event loop (and with it the poll
//!   fast-forward barrier) sleeps to the next *real* event over quiet
//!   stretches.
//!
//! Correctness is pinned by `rust/src/slurm/reference.rs`: a retained
//! naive implementation that the golden-equivalence property test
//! (`rust/tests/properties.rs`) compares against, outcome for outcome —
//! three-way, covering both the tree and the flat placement structure.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::cluster::{BackfillProfile, CapacityProfile, Cluster};
use crate::jobtable::JobTable;
use crate::simtime::{EventQueue, Time};

use super::job::{Adjustment, Job, JobId, JobSpec, JobState, StartedBy};

/// How the backfill scheduler's periodic tick is driven.
///
/// Both modes act at the same 30 s grid instants (multiples of
/// [`SlurmConfig::backfill_interval`]) and produce bit-identical job
/// records and [`SlurmStats`]; they differ only in how many events the
/// loop physically pops. The equivalence is pinned three ways
/// (on-demand / perpetual / naive reference) by
/// `rust/tests/backfill_ondemand.rs`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackfillTicks {
    /// Schedule tick work only when a `bf_dirty` false→true transition
    /// makes the next grid slot a real pass; batch-skip clean slots
    /// with synthesized accounting. The production default: steady-state
    /// event-loop cost is proportional to change, not elapsed time.
    #[default]
    OnDemand,
    /// The seed behaviour: one `Ev::BackfillTick` popped per interval
    /// for the whole simulation, rescheduling itself unconditionally.
    /// Retained as the reference mode the on-demand chain is pinned
    /// bit-identical against.
    Perpetual,
}

impl BackfillTicks {
    /// Parse the `backfill_ticks` TOML value.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "on-demand" | "ondemand" => Some(BackfillTicks::OnDemand),
            "perpetual" => Some(BackfillTicks::Perpetual),
            _ => None,
        }
    }
}

/// Deterministic seeded failure plan (`[failures]` TOML / `--mtbf`):
/// nodes die or drain at seeded pseudo-random instants, independent of
/// every other randomness stream in the crate.
///
/// **Determinism rule**: the plan draws from one dedicated SplitMix64
/// stream ([`crate::proptest_lite::Rng`]) seeded by
/// [`seed`](Self::seed), in a fixed order — per event, first the
/// `(gap, kind)` pair when the event is scheduled, then the victim
/// slot when it fires. Gaps are drawn integer-only, uniform on
/// `[1, 2·mtbf − 1]` (mean = mtbf), so the plan is exactly
/// reproducible across platforms (no `ln`, no float accumulation).
/// The optimized and the naive reference core consume the stream at
/// identical points, which is what keeps failure runs inside the
/// repo's bit-identity doctrine; `mtbf == 0` disables the axis
/// entirely (no stream exists, no events queue — byte-identical to
/// the pre-failure path).
#[derive(Debug, Clone, PartialEq)]
pub struct FailureConfig {
    /// Mean time between failure events, seconds. 0 (default) disables
    /// the failure axis completely.
    pub mtbf: Time,
    /// Repair window: a node lost to a kill or a completed drain
    /// returns to service this many seconds later.
    pub drain_secs: Time,
    /// Fraction of failure events that *drain* (mark the victim's node
    /// for removal at job end) instead of killing outright.
    pub drain_frac: f64,
    /// Seed of the plan's dedicated randomness stream.
    pub seed: u64,
    /// Rekill policy: whether a kill event may take down a job whose
    /// node is already draining. `false` absorbs the kill into the
    /// drain in progress (the job survives to its scheduled end).
    pub rekill: bool,
}

impl Default for FailureConfig {
    fn default() -> Self {
        Self { mtbf: 0, drain_secs: 600, drain_frac: 0.25, seed: 0x5eed_fa11, rekill: true }
    }
}

/// The live randomness stream behind a [`FailureConfig`] — shared
/// machinery so [`Slurmd`] and the naive reference core consume draws
/// at identical points (see the config's determinism rule).
#[derive(Debug, Clone)]
pub struct FailurePlan {
    rng: crate::proptest_lite::Rng,
    mtbf: Time,
    drain_frac: f64,
}

impl FailurePlan {
    /// `None` when the axis is disabled (`mtbf == 0`): no stream, no
    /// events, bit-identical to the pre-failure path.
    pub fn new(cfg: &FailureConfig) -> Option<Self> {
        (cfg.mtbf > 0).then(|| Self {
            rng: crate::proptest_lite::Rng::new(cfg.seed),
            mtbf: cfg.mtbf,
            drain_frac: cfg.drain_frac,
        })
    }

    /// Draw the next failure's `(gap, is_drain)`: gap uniform on
    /// `[1, 2·mtbf − 1]` (integer-only, mean = mtbf), kind Bernoulli by
    /// `drain_frac`. Drawn at schedule time — one pair per event.
    pub fn next_event(&mut self) -> (Time, bool) {
        let span = (2 * self.mtbf - 1).max(1) as u64;
        let gap = 1 + (self.rng.next_u64() % span) as Time;
        let drain = self.rng.chance(self.drain_frac);
        (gap, drain)
    }

    /// Draw the victim slot at fire time: uniform over all `total`
    /// nodes — busy, idle, and already-down alike — so failure pressure
    /// on running jobs scales with utilization.
    pub fn victim_slot(&mut self, total: u32) -> u32 {
        (self.rng.next_u64() % total.max(1) as u64) as u32
    }
}

/// Scheduler configuration (the subset of `slurm.conf` that matters).
#[derive(Debug, Clone)]
pub struct SlurmConfig {
    /// Compute nodes in the partition (paper test system: 20).
    pub nodes: u32,
    /// Backfill scheduler period (`bf_interval`, default 30 s).
    pub backfill_interval: Time,
    /// Max pending jobs examined per backfill pass (`bf_max_job_test`).
    pub backfill_max_jobs: usize,
    /// `OverTimeLimit` grace seconds added before enforcing a timeout.
    pub over_time_limit: Time,
    /// Backfill placement structure: the min-augmented capacity tree
    /// (default) or the flat breakpoint-list profile. Behaviourally
    /// identical; the tree is sublinear in breakpoints per placement.
    pub backfill_profile: BackfillProfile,
    /// Elide provably no-op daemon polls (default on): when nothing
    /// observable changed since the last poll — queue/report epoch
    /// untouched, no newly visible checkpoint, no pending retried
    /// action — the control plane fast-forwards `Ev::DaemonPoll`
    /// instead of re-running the O(R+Q) tick. Decision trajectory and
    /// stats stay bit-identical to blind polling (the property suite
    /// asserts it three ways); `false` forces the blind reference mode.
    pub poll_elision: bool,
    /// How backfill ticks are driven: on-demand (default) pops an event
    /// only at grid slots where a pass runs; perpetual pops one tick
    /// per interval forever (the retained reference mode). Results are
    /// bit-identical either way — see [`BackfillTicks`].
    pub backfill_ticks: BackfillTicks,
    /// Retire the dense per-job side tables behind the leading terminal
    /// prefix of the job table (default on): once every id below a
    /// watermark is terminal, the `scheduled_end` / `predictions` /
    /// `bf_release` slots (and, via [`DaemonHook::retire_to`], the
    /// daemon's tables) are freed, so resident table memory is O(live
    /// id window), not O(total ids) — the federation-scale requirement.
    /// Behaviour-neutral by construction (all guards on those tables
    /// are value-based); `false` keeps the reference grow-only mode.
    pub retirement: bool,
    /// Seeded node-failure plan (`[failures]` TOML); the default
    /// (`mtbf == 0`) disables the axis entirely.
    pub failures: FailureConfig,
}

impl Default for SlurmConfig {
    fn default() -> Self {
        Self {
            nodes: 20,
            backfill_interval: 30,
            backfill_max_jobs: 1000,
            over_time_limit: 0,
            backfill_profile: BackfillProfile::default(),
            poll_elision: true,
            backfill_ticks: BackfillTicks::default(),
            retirement: true,
            failures: FailureConfig::default(),
        }
    }
}

/// Scheduler / control-surface operation counters (Table 1 rows and
/// perf observability).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SlurmStats {
    /// Jobs started by the main priority scheduler.
    pub sched_main_started: u64,
    /// Jobs started by the backfill scheduler.
    pub sched_backfill_started: u64,
    /// Backfill passes actually executed (dirty ticks).
    pub backfill_passes: u64,
    /// Backfill ticks skipped because nothing changed.
    pub backfill_skipped: u64,
    /// `scontrol update TimeLimit` calls accepted.
    pub scontrol_updates: u64,
    /// `scancel` calls accepted.
    pub scancels: u64,
    /// Total events processed (incl. stale ones skipped).
    pub events: u64,
    /// Stale end events skipped via lazy invalidation.
    pub stale_events: u64,
    /// Nodes taken out of service by an `Ev::NodeFail` kill (busy or
    /// idle victim; hits on already-down nodes and rekill-absorbed
    /// kills don't count). 0 with failures off ([`FailureConfig`]).
    pub node_failures: u64,
    /// `Ev::NodeDrain` events that took effect: a drain mark placed on
    /// a running job, or an idle node taken straight out of service.
    pub node_drains: u64,
    /// Jobs terminated as [`crate::slurm::JobState::NodeFailed`].
    pub jobs_failed: u64,
}

impl SlurmStats {
    /// Fold another shard's counters into this one — the federation
    /// merge point sums per-shard stats into one cross-cluster record
    /// ([`crate::slurm::fed`]).
    pub fn absorb(&mut self, o: &SlurmStats) {
        self.sched_main_started += o.sched_main_started;
        self.sched_backfill_started += o.sched_backfill_started;
        self.backfill_passes += o.backfill_passes;
        self.backfill_skipped += o.backfill_skipped;
        self.scontrol_updates += o.scontrol_updates;
        self.scancels += o.scancels;
        self.events += o.events;
        self.stale_events += o.stale_events;
        self.node_failures += o.node_failures;
        self.node_drains += o.node_drains;
        self.jobs_failed += o.jobs_failed;
    }
}

/// Per-pending-job output of the last backfill pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BackfillPrediction {
    pub start: Time,
    /// Free nodes at `start` *before* this job's own reservation,
    /// including every higher-priority reservation.
    pub free_at_start: u32,
}

/// One running job's row in a [`QueueSnapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct RunningInfo {
    pub id: JobId,
    /// Job name (the appdb keys application priors off it); interned,
    /// so cloning a row is a refcount bump.
    pub name: Arc<str>,
    pub nodes: u32,
    pub start: Time,
    pub cur_limit: Time,
    /// `start + cur_limit`: when the scheduler expects the node release.
    pub expected_end: Time,
}

/// One pending job's row in a [`QueueSnapshot`].
#[derive(Debug, Clone, PartialEq)]
pub struct PendingInfo {
    pub id: JobId,
    pub nodes: u32,
    pub cur_limit: Time,
    /// Filled by the most recent backfill pass (None before the first).
    pub prediction: Option<BackfillPrediction>,
}

/// What `squeue` shows the daemon.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct QueueSnapshot {
    pub now: Time,
    pub running: Vec<RunningInfo>,
    pub pending: Vec<PendingInfo>,
}

/// The control surface the autonomy daemon talks to. Implemented by the
/// simulator here and by the live-mode slurmctld ([`crate::live`]), so
/// the daemon logic is identical in both.
pub trait SlurmControl {
    fn control_now(&self) -> Time;
    fn squeue(&self) -> QueueSnapshot;
    /// Allocation-free `squeue`: write the snapshot into a caller-owned
    /// buffer (cleared first). The daemon's poll loop uses this so the
    /// steady state allocates nothing (§Perf); the default delegates to
    /// [`squeue`](Self::squeue) for simple implementations.
    fn squeue_into(&self, out: &mut QueueSnapshot) {
        *out = self.squeue();
    }
    /// Checkpoint timestamps job `id` has reported so far (the paper's
    /// temp-file contents), ascending.
    fn read_ckpt_reports(&self, id: JobId) -> Vec<Time>;
    /// Allocation-free report read into a caller-owned scratch vector
    /// (cleared first). Default delegates to
    /// [`read_ckpt_reports`](Self::read_ckpt_reports).
    fn read_ckpt_reports_into(&self, id: JobId, out: &mut Vec<Time>) {
        out.clear();
        out.extend(self.read_ckpt_reports(id));
    }
    /// Delta report read: fill `out` (cleared first) with only the
    /// reports the caller has not consumed yet — `*cursor` is the
    /// caller's consumed count and is advanced to the total visible
    /// count. The daemon keeps one cursor per job, so each checkpoint
    /// crosses the transport **once** over the job's life instead of
    /// the whole O(C) prefix being re-materialized every poll (§Perf).
    ///
    /// The default is the naive full re-read minus the consumed prefix
    /// (what [`crate::slurm::reference::NaiveSlurmd`] and live
    /// transports use). A transport whose report list can shrink
    /// (rotated/truncated file) resets the cursor to the new count;
    /// the daemon-side ledger dedups any re-delivered timestamps.
    fn read_new_ckpt_reports_into(&self, id: JobId, cursor: &mut usize, out: &mut Vec<Time>) {
        self.read_ckpt_reports_into(id, out);
        let n = out.len();
        out.drain(..(*cursor).min(n));
        *cursor = n;
    }
    /// `scontrol update JobId=<id> TimeLimit=<secs>`; rejects terminal
    /// jobs and limits that lie in the past.
    fn scontrol_update_limit(&mut self, id: JobId, new_limit: Time) -> Result<(), String>;
    /// Batched `scontrol update`: apply every `(id, new_limit)` pair
    /// and return exactly one result per update, in order. The default
    /// is a loop of singles — the simulator, the naive reference, and
    /// simple mocks stay blind to batching, which is what keeps the
    /// batched daemon bit-identical to the unbatched one on a clean
    /// surface. A real control plane overrides this with one RPC
    /// ([`crate::live::LiveCtld`] does, and counts the saved calls).
    fn scontrol_update_limits(&mut self, updates: &[(JobId, Time)]) -> Vec<Result<(), String>> {
        updates.iter().map(|&(id, l)| self.scontrol_update_limit(id, l)).collect()
    }
    /// [`scontrol_update_limits`](Self::scontrol_update_limits) with an
    /// advisory worker-pool width for transports that can issue the
    /// per-update RPCs in parallel (`parallelism` is the daemon's AIMD
    /// concurrency controller output, see
    /// [`crate::daemon::DaemonConfig::rpc_concurrency`]). Results must
    /// come back one per update **in submission order** regardless of
    /// completion order. The default ignores the width and delegates to
    /// the serial batched call, so every in-sim surface is bit-identical
    /// to serial by construction; only real process-spawning transports
    /// (e.g. `ExternalSlurm`) override this.
    fn scontrol_update_limits_concurrent(
        &mut self,
        updates: &[(JobId, Time)],
        parallelism: usize,
    ) -> Vec<Result<(), String>> {
        let _ = parallelism;
        self.scontrol_update_limits(updates)
    }
    /// `scancel <id>`: terminate now.
    fn scancel(&mut self, id: JobId) -> Result<(), String>;
    /// Tag the accounting record with the daemon's adjustment kind.
    fn mark_adjustment(&mut self, id: JobId, adj: Adjustment);
}

/// Hook driven by the simulator's event loop: the autonomy daemon.
pub trait DaemonHook {
    /// Poll period (the paper: 20 s). `None` disables polling.
    fn poll_period(&self) -> Option<Time>;
    fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl);
    /// Whether a poll with provably unchanged inputs (same queue/report
    /// epoch, no newly visible checkpoint) would be a no-op for this
    /// hook, so the control plane may elide it. Must be `false` while
    /// the hook has time-dependent work pending — e.g. a rejected
    /// control action it retries every tick. Defaults to `false`, so
    /// custom hooks (tests, recorders) keep blind polling unless they
    /// opt in.
    fn poll_elidable(&self) -> bool {
        false
    }
    /// Account `n` polls the control plane elided as provably no-op, so
    /// observability counters stay bit-identical to blind polling.
    fn note_elided_polls(&mut self, n: u64) {
        let _ = n;
    }
    /// Every id below `watermark` is terminal and will never appear in
    /// a queue snapshot again: the hook may free its dense per-job
    /// state for those ids ([`SlurmConfig::retirement`]). Must be
    /// behaviour-neutral — freeing retired slots may not change the
    /// decision trajectory or any deterministic stat. Defaults to a
    /// no-op, so reference hooks (tests, recorders, the naive core's
    /// daemons) keep grow-only tables.
    fn retire_to(&mut self, watermark: JobId) {
        let _ = watermark;
    }
}

/// A no-op hook: the Baseline scenario (no daemon).
pub struct NoDaemon;

impl DaemonHook for NoDaemon {
    fn poll_period(&self) -> Option<Time> {
        None
    }
    fn on_poll(&mut self, _t: Time, _ctl: &mut dyn SlurmControl) {}
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    /// A job with `submit > 0` enters the pending queue (staggered
    /// arrivals; the paper's replay releases everything at t=0).
    Submit(JobId),
    /// A job reaches its currently scheduled end.
    End(JobId),
    BackfillTick,
    DaemonPoll,
    /// A seeded failure-plan kill instant ([`FailureConfig`]): the
    /// drawn victim slot decides whether a running job dies
    /// ([`JobState::NodeFailed`]) and its node goes down, an idle node
    /// goes down, or (already-down slot) nothing happens.
    NodeFail,
    /// A seeded drain instant: a busy victim's node is marked for
    /// removal when its job releases it; an idle victim leaves service
    /// immediately.
    NodeDrain,
    /// A downed node's repair window elapsed: it re-enters service
    /// (and the backfill profile, via the next base rebuild).
    NodeUp,
}

/// The simulator. See module docs.
pub struct Slurmd {
    pub cfg: SlurmConfig,
    cluster: Cluster,
    jobs: Vec<Job>,
    /// Pending job ids in priority (submission) order.
    pending: Vec<JobId>,
    events: EventQueue<Ev>,
    /// Authoritative scheduled end per running job (lazy invalidation:
    /// an `End` event is real iff it matches this slot), dense by job
    /// id — the seed hashed a map on every end event (§Perf). Grown
    /// lazily at start and retired behind the terminal-prefix
    /// watermark, so residency is O(live id window) (§Federation).
    scheduled_end: JobTable<Option<Time>>,
    /// Dense per-job predictions from the last backfill pass (indexed
    /// by job id; cheaper than a hash map in the pass's inner loop).
    predictions: JobTable<Option<BackfillPrediction>>,
    /// Set when the resource picture changed since the last backfill.
    bf_dirty: bool,
    /// Working capacity profile for the backfill pass (arena, reused):
    /// tree or flat per `SlurmConfig::backfill_profile`.
    profile: CapacityProfile,
    /// Running-jobs-only base profile cached between passes.
    bf_base: CapacityProfile,
    /// Whether `bf_base` still matches the running set (no job started
    /// or ended since it was built). Limit-only changes keep it valid
    /// and are folded in incrementally.
    bf_base_valid: bool,
    /// Release time currently encoded in `bf_base` per running job,
    /// dense by job id (stale `Some` entries for terminal jobs are
    /// never read: only ids in `running` are consulted).
    bf_release: JobTable<Option<Time>>,
    /// Retirement watermark: the leading terminal prefix of the job
    /// table. Advanced amortizedly after each event; every advance
    /// retires the dense side tables here and in the daemon
    /// ([`DaemonHook::retire_to`]). Stays 0 with
    /// `SlurmConfig::retirement` off.
    watermark: usize,
    /// Running jobs whose limit changed since the last backfill pass.
    limit_changed: Vec<JobId>,
    /// Scratch: jobs started by the current pass (pending index, id).
    bf_started: Vec<(usize, JobId)>,
    /// Jobs whose `predictions` slot was set by the last pass: the next
    /// pass clears exactly these instead of wiping the whole O(N) table
    /// (the seed's `fill(None)`) — §Perf.
    pred_touched: Vec<JobId>,
    /// Running jobs in id order: `squeue` and the profile rebuild walk
    /// this instead of scanning the whole job table — O(R), not O(N),
    /// per poll at 100k-job scale (§Perf).
    running: BTreeSet<JobId>,
    terminal: usize,
    /// Incrementally maintained extrema for [`makespan`](Self::makespan)
    /// (the seed recomputed both with full job-table scans per call).
    min_submit: Option<Time>,
    max_end: Option<Time>,
    /// Peak working-profile breakpoint count across backfill passes
    /// (the B in the placement cost; reported by the sim_scale bench).
    peak_breakpoints: usize,
    /// Queue/report epoch: bumped on every daemon-observable state
    /// change (submit into pending, job start/end, limit update). A
    /// poll tick whose epoch matches the last executed poll — and that
    /// precedes [`next_report_visible`](Self::next_report_visible) —
    /// sees bit-identical inputs and can be elided (§Perf).
    poll_epoch: u64,
    /// Epoch as of the last *executed* (non-elided) daemon poll.
    last_polled_epoch: u64,
    /// Earliest future instant at which any running reporting job's
    /// next planned checkpoint becomes visible; recomputed after each
    /// executed poll (the running set is frozen between epoch bumps,
    /// so the cached value stays exact until then).
    next_report_visible: Time,
    /// Daemon polls elided as provably no-op (perf observability; NOT
    /// part of [`SlurmStats`], which stays bit-identical to blind
    /// polling).
    polls_elided: u64,
    /// On-demand tick chain: the next grid slot the perpetual reference
    /// would pop a `BackfillTick` at. Doubles as the dedup guard — the
    /// chain holds exactly one upcoming slot, so concurrent dirtying
    /// inside one interval can never double-schedule a pass.
    bf_next_slot: Time,
    /// Ordering watermark for the slot above: the queue seq the
    /// perpetual tick event would carry (snapshotted via
    /// [`EventQueue::next_seq`] whenever a slot is consumed, i.e. at
    /// the perpetual push point). The virtual tick fires before a
    /// queued same-instant event iff that event's seq is >= this —
    /// exactly the FIFO tie-break the physical tick would have won.
    bf_tick_seq: u64,
    /// Set once the chain stops (the perpetual reference would stop
    /// rescheduling: first tick processed with all jobs terminal).
    /// `true` at rest and throughout perpetual-mode runs.
    bf_chain_done: bool,
    /// Clean backfill grid slots batch-skipped by the on-demand chain
    /// (perf observability; their `backfill_skipped`/`events`
    /// accounting is synthesized into [`SlurmStats`], which stays
    /// bit-identical to the perpetual mode).
    bf_ticks_elided: u64,
    /// Live failure plan ([`FailureConfig`]); `None` (failures off)
    /// keeps every hot path byte-identical to the pre-failure code.
    fail_plan: Option<FailurePlan>,
    /// Running jobs whose node is marked to drain: the node leaves
    /// service the moment the job releases it ([`Self::finish_job`]).
    draining: BTreeSet<JobId>,
    /// Return instants of nodes currently down, one entry per node
    /// (matched and removed by its `Ev::NodeUp`); the base-profile
    /// rebuild chains these through the captree's range-add path.
    down_until: Vec<Time>,
    pub stats: SlurmStats,
}

// Thread-safety audit for the parallel federation drive
// ([`crate::slurm::fed::FedDrive::Parallel`]): the step API
// (`run`/`start`/`step`/`next_step_time`) is `&mut self` over fully
// owned state — no `Rc`, no interior mutability, no raw pointers — so
// a whole shard (simulator + its snapshots) moves onto a federation
// worker thread and back. Compile-time enforced so a future field
// (say, an `Rc`-cached profile) can't silently break the parallel
// drive.
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<Slurmd>();
    assert_send::<SlurmStats>();
    assert_send::<QueueSnapshot>();
};

impl Slurmd {
    pub fn new(cfg: SlurmConfig) -> Self {
        let cluster = Cluster::new(cfg.nodes);
        let nodes = cfg.nodes;
        let kind = cfg.backfill_profile;
        let fail_plan = FailurePlan::new(&cfg.failures);
        Self {
            cfg,
            cluster,
            jobs: Vec::new(),
            pending: Vec::new(),
            events: EventQueue::new(),
            scheduled_end: JobTable::new(),
            predictions: JobTable::new(),
            bf_dirty: true,
            profile: CapacityProfile::new(kind, 0, nodes, nodes),
            bf_base: CapacityProfile::new(kind, 0, nodes, nodes),
            bf_base_valid: false,
            bf_release: JobTable::new(),
            watermark: 0,
            limit_changed: Vec::new(),
            bf_started: Vec::new(),
            pred_touched: Vec::new(),
            running: BTreeSet::new(),
            terminal: 0,
            min_submit: None,
            max_end: None,
            peak_breakpoints: 0,
            poll_epoch: 0,
            // != poll_epoch, so the first poll always executes.
            last_polled_epoch: u64::MAX,
            next_report_visible: Time::MIN,
            polls_elided: 0,
            bf_next_slot: 0,
            bf_tick_seq: 0,
            bf_chain_done: true,
            bf_ticks_elided: 0,
            fail_plan,
            draining: BTreeSet::new(),
            down_until: Vec::new(),
            stats: SlurmStats::default(),
        }
    }

    /// Submit a job. `submit <= now` (the paper's replay submits
    /// everything at t=0) enters the pending queue immediately;
    /// `submit > now` schedules an arrival event, enabling
    /// staggered-arrival scenarios ([`crate::workload::scaled`]).
    /// Priority stays FIFO by arrival: equal-time arrivals keep
    /// submission-call order.
    pub fn submit(&mut self, spec: JobSpec) -> JobId {
        assert!(spec.submit >= 0, "negative submit time");
        let id = JobId(self.jobs.len() as u32);
        let submit = spec.submit;
        self.jobs.push(Job::new(id, spec));
        // The dense side tables (`scheduled_end`, `bf_release`,
        // `predictions`) grow lazily at first use — at start_job /
        // inside the backfill pass — so at federation scale residency
        // tracks the active id frontier, not the submit burst.
        self.min_submit = Some(match self.min_submit {
            Some(m) => m.min(submit),
            None => submit,
        });
        if submit <= self.events.now() {
            self.pending.push(id);
            self.bf_dirty = true;
            self.poll_epoch += 1;
        } else {
            self.events.push(submit, Ev::Submit(id));
        }
        id
    }

    /// Submit with an explicit checkpoint-plan override (offsets
    /// relative to start) — used by the I/O-noise substrate
    /// ([`crate::workload::ionoise`]) where plans are drawn against a
    /// shared load profile rather than per-job jitter streams.
    pub fn submit_with_plan(&mut self, spec: JobSpec, plan: Option<Vec<Time>>) -> JobId {
        let id = self.submit(spec);
        if let Some(plan) = plan {
            debug_assert!(plan.windows(2).all(|w| w[0] < w[1]), "plan must be ascending");
            self.jobs[id.0 as usize].ckpt_plan = plan;
        }
        id
    }

    /// The full record of one job (panics on an unknown id).
    pub fn job(&self, id: JobId) -> &Job {
        &self.jobs[id.0 as usize]
    }

    /// All job records, indexed by dense [`JobId`].
    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    /// Consume the simulator, keeping only the job records.
    pub fn into_jobs(self) -> Vec<Job> {
        self.jobs
    }

    /// Current simulation time (the last processed event's timestamp).
    pub fn now(&self) -> Time {
        self.events.now()
    }

    /// The cluster resource model (free/total nodes, allocations).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Whether every submitted job reached a terminal state.
    pub fn all_done(&self) -> bool {
        self.terminal == self.jobs.len()
    }

    /// Run the whole simulation to completion with the given daemon:
    /// [`start`](Self::start), then [`step`](Self::step) to
    /// exhaustion. The federation driver ([`crate::slurm::fed`])
    /// interleaves the same steps across shards instead.
    pub fn run(&mut self, daemon: &mut dyn DaemonHook) {
        self.start(daemon);
        while self.step(daemon) {}
        assert!(self.all_done(), "simulation ended with live jobs");
    }

    /// Prologue of [`run`](Self::run): the t=0 scheduling wave, the
    /// backfill tick-chain init, and the first daemon poll. Call once
    /// before the first [`step`](Self::step).
    pub fn start(&mut self, daemon: &mut dyn DaemonHook) {
        assert!(self.cfg.backfill_interval > 0, "backfill_interval must be positive");
        // Initial scheduling wave at t=0.
        self.run_main_sched();
        match self.cfg.backfill_ticks {
            BackfillTicks::Perpetual => self.events.push(0, Ev::BackfillTick),
            BackfillTicks::OnDemand => {
                // The perpetual reference pushes its t=0 tick exactly
                // here; the on-demand chain records that push point as
                // its first slot + ordering watermark instead.
                self.bf_next_slot = 0;
                self.bf_tick_seq = self.events.next_seq();
                self.bf_chain_done = false;
            }
        }
        if let Some(p) = daemon.poll_period() {
            assert!(p > 0);
            self.events.push(p, Ev::DaemonPoll);
        }
        // Failure plan (if any): the first kill/drain instant enters
        // the queue last at t=0 — the fixed push order both cores
        // share, so same-instant FIFO ties resolve identically.
        self.schedule_next_failure();
    }

    /// The (time, seq) merge key of this shard's next step, or `None`
    /// when [`step`](Self::step) has no work left (queue drained and
    /// tick chain done). The on-demand chain's pending grid slot is a
    /// *virtual* event: it participates with its push-point watermark
    /// seq, exactly the tie-break [`run_due_backfill_ticks`] applies,
    /// so the federation merge ([`crate::slurm::fed`]) sees the same
    /// total order a physical queue would. The seq component only
    /// orders events *within* this shard; cross-shard ties resolve by
    /// (time, shard, seq) at the merge point.
    pub fn next_step_time(&self) -> Option<(Time, u64)> {
        let head = self.events.peek();
        if !self.bf_chain_done {
            // The chain owes work even on an empty queue (its final
            // drain/accounting step), so it always yields a key.
            let slot = (self.bf_next_slot, self.bf_tick_seq);
            return Some(match head {
                Some((t, seq)) if slot.0 > t || (slot.0 == t && slot.1 > seq) => (t, seq),
                _ => slot,
            });
        }
        head
    }

    /// One event-loop iteration: drain the due backfill grid slots,
    /// then pop and process one event. Returns `false` once no work
    /// remains — after which [`all_done`](Self::all_done) must hold.
    /// Step-granular, not event-granular: a step batches the due
    /// tick-chain work with one popped event, which is the unit the
    /// federation merge interleaves (sound because shards share no
    /// mutable state).
    pub fn step(&mut self, daemon: &mut dyn DaemonHook) -> bool {
        // On-demand mode: consume every backfill grid slot that the
        // perpetual reference would pop before the queue head —
        // passes run for real, clean slots are batch-skipped.
        self.run_due_backfill_ticks();
        let Some((t, ev)) = self.events.pop() else { return false };
        self.stats.events += 1;
        match ev {
            Ev::Submit(id) => {
                // Arrival: enqueue and schedule on state change,
                // exactly like Slurm's submit-triggered SchedMain.
                self.pending.push(id);
                self.bf_dirty = true;
                self.poll_epoch += 1;
                self.run_main_sched();
            }
            Ev::End(id) => {
                // Value-based staleness check: a retired id's slot
                // reads None through the forgiving `get` (terminal
                // jobs always clear it first), so stale End events
                // aimed below the watermark fall through here too.
                if self.scheduled_end.get(id.0 as usize).copied().flatten() == Some(t)
                    && self.jobs[id.0 as usize].state == JobState::Running
                {
                    self.finish_job(id, t, None);
                    self.run_main_sched();
                } else {
                    self.stats.stale_events += 1;
                }
            }
            Ev::BackfillTick => {
                if self.bf_dirty {
                    self.run_backfill(t);
                } else {
                    self.stats.backfill_skipped += 1;
                }
                if !self.all_done() {
                    self.events.push(t + self.cfg.backfill_interval, Ev::BackfillTick);
                }
            }
            Ev::DaemonPoll => {
                // No-op poll elision (§Perf): with the queue/report
                // epoch untouched since the last executed poll, no
                // newly visible checkpoint, and the hook reporting
                // no pending time-dependent work, this tick's
                // inputs are bit-identical to the previous poll's —
                // the tick is provably a no-op. Skip the O(R+Q)
                // body, and fast-forward over every following poll
                // slot that provably stays quiet: nothing can
                // change before the next queued event or the next
                // report-visibility instant. Accounting (the
                // hook's poll counter, `SlurmStats::events`) is
                // preserved, so elided, blind, and naive runs stay
                // bit-identical end to end.
                let elide = self.cfg.poll_elision
                    && daemon.poll_elidable()
                    && self.poll_epoch == self.last_polled_epoch
                    && t < self.next_report_visible;
                if elide {
                    daemon.note_elided_polls(1);
                    self.polls_elided += 1;
                    if !self.all_done() {
                        if let Some(p) = daemon.poll_period() {
                            // In perpetual mode the queued tick
                            // bounds the jump at one backfill
                            // interval via peek_time; on-demand
                            // removes that cap, so only a *pending
                            // pass* (which bumps the poll epoch)
                            // re-enters the barrier.
                            let barrier = self
                                .next_report_visible
                                .min(self.events.peek_time().unwrap_or(t))
                                .min(self.next_backfill_pass_time());
                            // First grid slot at or past the
                            // barrier (at least the next one).
                            let k = ((barrier - t).max(0) + p - 1).div_euclid(p).max(1);
                            let skipped = (k - 1) as u64;
                            self.stats.events += skipped;
                            self.polls_elided += skipped;
                            daemon.note_elided_polls(skipped);
                            self.events.push(t + k * p, Ev::DaemonPoll);
                        }
                    }
                } else {
                    daemon.on_poll(t, self);
                    self.last_polled_epoch = self.poll_epoch;
                    // Elision bookkeeping only: the blind reference
                    // mode never consults the visibility instant,
                    // so it must not pay the O(R·log C) scan either
                    // (it is the baseline the elided path is raced
                    // against).
                    if self.cfg.poll_elision {
                        self.next_report_visible = self.next_report_visibility(t);
                    }
                    if !self.all_done() {
                        if let Some(p) = daemon.poll_period() {
                            self.events.push(t + p, Ev::DaemonPoll);
                        }
                    }
                }
            }
            Ev::NodeFail => self.handle_node_event(t, false),
            Ev::NodeDrain => self.handle_node_event(t, true),
            Ev::NodeUp => self.handle_node_up(t),
        }
        self.maybe_retire(daemon);
        // The chain may still owe its final pass (the last finish
        // set bf_dirty): report more work so run_due_backfill_ticks
        // drains it next step, exactly like the perpetual
        // reference's last queued tick.
        !(self.all_done() && self.events.is_empty() && self.bf_chain_done)
    }

    /// Advance the retirement watermark over the leading terminal
    /// prefix of the job table (amortized: each job is scanned past
    /// once over the run) and retire the dense side tables — ours and
    /// the daemon's — behind it. No-op with retirement disabled.
    fn maybe_retire(&mut self, daemon: &mut dyn DaemonHook) {
        if !self.cfg.retirement {
            return;
        }
        let mut w = self.watermark;
        while w < self.jobs.len() && self.jobs[w].state.is_terminal() {
            w += 1;
        }
        if w == self.watermark {
            return;
        }
        self.watermark = w;
        daemon.retire_to(JobId(w as u32));
        self.scheduled_end.retire_to(w);
        self.predictions.retire_to(w);
        self.bf_release.retire_to(w);
    }

    /// On-demand tick chain (see [`BackfillTicks::OnDemand`]): consume
    /// every backfill grid slot that orders before the current queue
    /// head, i.e. every slot whose perpetual `Ev::BackfillTick` would
    /// pop before the head under the queue's (time, seq) FIFO order.
    ///
    /// A dirty slot runs the pass for real (clock advanced to the grid
    /// instant, `SlurmStats::events` counted as the perpetual pop would
    /// have been). A clean stretch is skipped in **one O(1) batch**: no
    /// event fires inside it, so `bf_dirty` cannot flip mid-stretch and
    /// every slot in it is provably a skip — only the
    /// `backfill_skipped`/`events` accounting is synthesized. The
    /// watermark is re-snapshotted whenever a slot is consumed, which
    /// is exactly the moment the perpetual loop would push the *next*
    /// tick, so same-instant ordering against queued events (End
    /// events landing on the grid, fast-forwarded daemon polls) stays
    /// faithful slot for slot.
    fn run_due_backfill_ticks(&mut self) {
        if self.bf_chain_done {
            return; // perpetual mode, or the chain already drained
        }
        let interval = self.cfg.backfill_interval;
        loop {
            let head = self.events.peek();
            let fires = match head {
                Some((t, seq)) => {
                    self.bf_next_slot < t || (self.bf_next_slot == t && self.bf_tick_seq <= seq)
                }
                // Empty queue: the perpetual reference keeps ticking
                // until the pass after the final termination. (With
                // live jobs left this would spin forever there; here
                // the chain drains and the run asserts instead.)
                None => true,
            };
            if !fires {
                return;
            }
            if self.bf_dirty {
                let t = self.bf_next_slot;
                self.events.advance_to(t);
                self.stats.events += 1;
                self.run_backfill(t);
                self.bf_tick_seq = self.events.next_seq();
                self.bf_next_slot = t + interval;
                if self.all_done() {
                    self.bf_chain_done = true;
                    return;
                }
            } else if let Some((t, seq)) = head {
                // Batch every clean slot strictly before the head's
                // timestamp. The slot AT `t` may only be consumed when
                // it is the *first* unconsumed slot (k == 0): only then
                // is `bf_tick_seq` its true push-point watermark. Once
                // this batch consumes an earlier slot, the perpetual
                // reference would push the tick-at-`t` *now* — after
                // the head event entered the queue — so that tick
                // orders after the head and must wait (the watermark
                // refresh below encodes exactly that).
                let mut k = if t > self.bf_next_slot {
                    (t - self.bf_next_slot + interval - 1).div_euclid(interval)
                } else {
                    0
                };
                if k == 0 {
                    // fires established bf_next_slot == t with the
                    // (valid, first-slot) watermark winning the tie.
                    debug_assert!(self.bf_next_slot == t && self.bf_tick_seq <= seq);
                    k = 1;
                }
                self.stats.events += k as u64;
                self.stats.backfill_skipped += k as u64;
                self.bf_ticks_elided += k as u64;
                self.bf_next_slot += k * interval;
                self.bf_tick_seq = self.events.next_seq();
            } else {
                // Empty queue and nothing dirty: the perpetual loop's
                // next tick would be one clean skip — and with all jobs
                // terminal it would stop rescheduling.
                self.stats.events += 1;
                self.stats.backfill_skipped += 1;
                self.bf_ticks_elided += 1;
                self.bf_chain_done = true;
                return;
            }
        }
    }

    /// Earliest instant at which the on-demand tick chain will run a
    /// real pass (`Time::MAX` when none is pending). A pass bumps the
    /// poll epoch — it rewrites the backfill predictions `squeue`
    /// exposes — so the elided-poll fast-forward must not jump across
    /// it. In perpetual mode every tick is a queued event and the
    /// barrier's peek-time term already covers this.
    fn next_backfill_pass_time(&self) -> Time {
        if !self.bf_chain_done && self.bf_dirty { self.bf_next_slot } else { Time::MAX }
    }

    /// Start `id` on the cluster right now.
    fn start_job(&mut self, id: JobId, t: Time, by: StartedBy) {
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Pending);
        job.state = JobState::Running;
        job.start = Some(t);
        job.started_by = Some(by);
        let end = job.actual_end(self.cfg.over_time_limit).unwrap();
        self.cluster.allocate(id.0 as u64, job.spec.nodes);
        // Lazy side-table growth (§Perf): slots materialize at first
        // start, so the resident width of the dense tables tracks the
        // live id window, not every id ever submitted.
        self.scheduled_end.ensure(id.0 as usize + 1);
        self.bf_release.ensure(id.0 as usize + 1);
        self.scheduled_end[id.0 as usize] = Some(end);
        self.events.push(end, Ev::End(id));
        if let Some(p) = self.predictions.get_mut(id.0 as usize) {
            *p = None;
        }
        match by {
            StartedBy::Main => self.stats.sched_main_started += 1,
            StartedBy::Backfill => self.stats.sched_backfill_started += 1,
        }
        self.bf_dirty = true;
        self.bf_base_valid = false; // running set changed
        self.poll_epoch += 1;
        self.running.insert(id);
    }

    /// Terminate `id` at `t`. `forced` carries the scancel state.
    fn finish_job(&mut self, id: JobId, t: Time, forced: Option<JobState>) {
        let grace = self.cfg.over_time_limit;
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Running);
        job.end = Some(t);
        job.state = forced.unwrap_or(if job.completes(grace) {
            JobState::Completed
        } else {
            JobState::Timeout
        });
        self.cluster.release(id.0 as u64);
        self.scheduled_end[id.0 as usize] = None;
        self.terminal += 1;
        self.bf_dirty = true;
        self.bf_base_valid = false; // running set changed
        self.poll_epoch += 1;
        self.running.remove(&id);
        self.max_end = Some(match self.max_end {
            Some(m) => m.max(t),
            None => t,
        });
        // Drain completion: a node marked to drain leaves service the
        // moment its job releases it — whatever ended the job (natural
        // end, scancel, or a rekill). Guarded on the plan so the
        // failures-off path never touches the drain set.
        if self.fail_plan.is_some() && self.draining.remove(&id) {
            self.take_node_down(t);
        }
    }

    /// Take one (currently free) node out of service at `t` and queue
    /// its return after the repair window.
    fn take_node_down(&mut self, t: Time) {
        self.cluster.fail_node();
        let ret = t + self.cfg.failures.drain_secs;
        self.down_until.push(ret);
        self.events.push(ret, Ev::NodeUp);
    }

    /// Queue the plan's next kill/drain instant (no-op with failures
    /// off, or once every job is terminal — leftover queued plan
    /// events then drain as no-ops, identically in both cores).
    fn schedule_next_failure(&mut self) {
        let Some(plan) = &mut self.fail_plan else { return };
        let (gap, drain) = plan.next_event();
        let t = self.events.now() + gap;
        self.events.push(t, if drain { Ev::NodeDrain } else { Ev::NodeFail });
    }

    /// One `Ev::NodeFail` (`drain == false`) or `Ev::NodeDrain`
    /// (`drain == true`) instant. Victim slot `u` is drawn uniform
    /// over all nodes; slots are ordered (busy by id-ordered running
    /// walk | already-down | idle), the order both cores share.
    fn handle_node_event(&mut self, t: Time, drain: bool) {
        if self.all_done() {
            return; // late plan event after the last job: inert
        }
        let total = self.cluster.total();
        let down = self.cluster.down();
        let busy = self.cluster.used();
        let u = self
            .fail_plan
            .as_mut()
            .expect("node events only exist with a live plan")
            .victim_slot(total);
        if u < busy {
            // Walk the id-ordered running set to the job owning slot u
            // (same order as squeue and the naive core's id scan).
            let mut acc = 0u32;
            let mut victim = None;
            for &id in &self.running {
                acc += self.jobs[id.0 as usize].spec.nodes;
                if u < acc {
                    victim = Some(id);
                    break;
                }
            }
            let victim = victim.expect("busy slots are covered by running jobs");
            if drain {
                if self.draining.insert(victim) {
                    self.stats.node_drains += 1;
                }
            } else if self.cfg.failures.rekill || !self.draining.contains(&victim) {
                // Kill: the job terminates NOW; everything since its
                // last visible checkpoint is lost (metrics). All its
                // nodes release, then the one failed node goes down.
                self.draining.remove(&victim);
                self.stats.node_failures += 1;
                self.stats.jobs_failed += 1;
                self.finish_job(victim, t, Some(JobState::NodeFailed));
                self.take_node_down(t);
                self.run_main_sched();
            }
            // else: rekill=false and the victim's node already drains —
            // the kill is absorbed by the drain in progress.
        } else if u < busy + down {
            // Already-down node: nothing further to take out.
        } else {
            // Idle node: leaves service immediately (drain == kill
            // here, they differ only in which counter ticks).
            if drain {
                self.stats.node_drains += 1;
            } else {
                self.stats.node_failures += 1;
            }
            self.take_node_down(t);
            self.bf_dirty = true;
            self.bf_base_valid = false; // free-node count changed
            self.poll_epoch += 1;
        }
        self.schedule_next_failure();
    }

    /// `Ev::NodeUp`: the matching down node's repair window elapsed.
    /// The restore itself always happens (cluster bookkeeping stays
    /// consistent even while leftover events drain after the last
    /// job); the scheduling side effects only fire on a live run.
    fn handle_node_up(&mut self, t: Time) {
        let pos = self
            .down_until
            .iter()
            .position(|&r| r == t)
            .expect("NodeUp matches a pending return instant");
        self.down_until.swap_remove(pos);
        self.cluster.restore_node();
        if !self.all_done() {
            self.bf_dirty = true;
            self.bf_base_valid = false; // free-node count changed
            self.poll_epoch += 1;
            self.run_main_sched();
        }
    }

    /// Main priority scheduler: FIFO until the first job that can't
    /// start (see module docs).
    #[allow(clippy::needless_range_loop)] // start_job needs &mut self
    fn run_main_sched(&mut self) {
        let t = self.events.now();
        let mut started = 0usize;
        for i in 0..self.pending.len() {
            let id = self.pending[i];
            let nodes = self.jobs[id.0 as usize].spec.nodes;
            if self.cluster.fits(nodes) {
                self.start_job(id, t, StartedBy::Main);
                started += 1;
            } else {
                break;
            }
        }
        if started > 0 {
            self.pending.drain(..started);
        }
    }

    /// Refresh the running-jobs base profile for a pass at time `t`.
    ///
    /// The scheduler plans on *limits*, not true durations. A job
    /// inside its OverTimeLimit grace window has already passed its
    /// expected end but still holds nodes: model its release as
    /// imminent (t+1), never as already-free — otherwise backfill
    /// would start jobs on occupied nodes (caught by the cluster's
    /// over-allocation invariant).
    ///
    /// When the running set is unchanged since the last pass (only
    /// limits moved, the daemon steady state), releases are shifted in
    /// place instead of rebuilding the whole step function (§Perf).
    fn refresh_base_profile(&mut self, t: Time) {
        if self.bf_base_valid {
            let Self { bf_base, bf_release, limit_changed, jobs, .. } = self;
            // Fold in limit updates since the last pass.
            for id in limit_changed.drain(..) {
                let job = &jobs[id.0 as usize];
                if job.state != JobState::Running {
                    continue; // ended since: base was invalidated anyway
                }
                let new = job.expected_end().unwrap().max(t + 1);
                let old = bf_release[id.0 as usize]
                    .as_mut()
                    .expect("running job must have an encoded release");
                if new != *old {
                    bf_base.shift_release(*old, new, job.spec.nodes);
                    *old = new;
                }
            }
            // Re-clamp releases that fell into the past (grace overrun):
            // the job still holds nodes, so its release stays imminent.
            let Self { bf_base, bf_release, running, jobs, .. } = self;
            for &id in running.iter() {
                let rel = bf_release[id.0 as usize]
                    .as_mut()
                    .expect("running job has a release");
                if *rel <= t {
                    bf_base.shift_release(*rel, t + 1, jobs[id.0 as usize].spec.nodes);
                    *rel = t + 1;
                }
            }
        } else {
            self.limit_changed.clear();
            for &id in &self.running {
                let rel = self.jobs[id.0 as usize].expected_end().unwrap().max(t + 1);
                self.bf_release[id.0 as usize] = Some(rel);
            }
            let Self { bf_base, bf_release, running, jobs, cluster, down_until, .. } = self;
            bf_base.reset(t, cluster.free(), cluster.total());
            // Down nodes re-enter the profile through the same
            // range-add path as job releases: one node returning at
            // its repair instant (clamped imminent-future, like a
            // grace-overrun release, if a pass lands exactly on it).
            bf_base.extend_releases(
                running
                    .iter()
                    .map(|&id| {
                        let rel = bf_release[id.0 as usize].expect("release set above");
                        (rel, jobs[id.0 as usize].spec.nodes)
                    })
                    .chain(down_until.iter().map(|&ret| (ret.max(t + 1), 1))),
            );
            self.bf_base_valid = true;
        }
    }

    /// Conservative backfill pass (see module docs). O(R + P·log B)
    /// per pass with the default tree placement structure (O(R + P·B)
    /// with the flat one; B = profile breakpoints), with zero
    /// allocations in the steady state: the profile arena, the
    /// started-jobs scratch, and the predictions table are all pooled
    /// across passes.
    fn run_backfill(&mut self, t: Time) {
        self.stats.backfill_passes += 1;
        self.bf_dirty = false;
        // A pass rewrites the backfill predictions `squeue` exposes, so
        // it is a daemon-observable change: bump the poll epoch so the
        // elision contract (queue/report state frozen between executed
        // polls) holds for ANY hook, not just ones that ignore pending
        // predictions. Cheap: passes only run after an epoch-bumping
        // mutation set bf_dirty anyway.
        self.poll_epoch += 1;
        self.refresh_base_profile(t);
        // Invariant: the only Some entries are the previous pass's
        // touched slots — clear exactly those (O(E), not O(N)). A
        // touched id can retire between passes (its job ended), so the
        // clear goes through the forgiving accessor; the table itself
        // grows lazily per examined id below, never O(total jobs).
        for id in self.pred_touched.drain(..) {
            if let Some(p) = self.predictions.get_mut(id.0 as usize) {
                *p = None;
            }
        }

        {
            let Self {
                profile,
                bf_base,
                bf_started,
                pending,
                jobs,
                predictions,
                pred_touched,
                cfg,
                ..
            } = self;
            profile.copy_from(bf_base);
            bf_started.clear();
            for (examined, &id) in pending.iter().enumerate() {
                if examined >= cfg.backfill_max_jobs {
                    break;
                }
                let (nodes, limit) = {
                    let j = &jobs[id.0 as usize];
                    (j.spec.nodes, j.cur_limit.max(1))
                };
                let s = profile.find_earliest(nodes, limit, t);
                let free = profile.free_at(s);
                predictions.ensure(id.0 as usize + 1);
                predictions[id.0 as usize] =
                    Some(BackfillPrediction { start: s, free_at_start: free });
                pred_touched.push(id);
                profile.reserve(s, s.saturating_add(limit), nodes);
                if s == t {
                    bf_started.push((examined, id));
                }
            }
            // Remove every started job from the pending queue in ONE
            // in-place compaction (bf_started indices are ascending) —
            // the seed's per-job `retain` was O(S·P) (§Perf).
            if !bf_started.is_empty() {
                let mut w = 0usize;
                let mut si = 0usize;
                for r in 0..pending.len() {
                    if si < bf_started.len() && bf_started[si].0 == r {
                        si += 1;
                        continue;
                    }
                    pending[w] = pending[r];
                    w += 1;
                }
                pending.truncate(w);
            }
        }
        // Track the working profile's peak breakpoint count right after
        // the reservations landed — the B the placement cost depends on.
        self.peak_breakpoints = self.peak_breakpoints.max(self.profile.len());
        // Start the backfilled jobs (scratch is swapped out so the
        // &mut self calls below don't alias it, then swapped back to
        // keep its capacity pooled).
        let mut started = std::mem::take(&mut self.bf_started);
        for &(_, id) in &started {
            self.start_job(id, t, StartedBy::Backfill);
        }
        started.clear();
        self.bf_started = started;
    }

    /// Run one main-scheduler pass immediately (testing / benching /
    /// live drivers; [`run`](Self::run) does this automatically).
    pub fn sched_now(&mut self) {
        self.run_main_sched();
    }

    /// Run one backfill pass immediately (testing / benching).
    pub fn backfill_now(&mut self) {
        let t = self.events.now();
        self.run_backfill(t);
    }

    /// Makespan so far (max end − min submit); meaningful once done.
    /// O(1): the extrema are maintained on submit/finish instead of
    /// the seed's two full job-table scans per call.
    pub fn makespan(&self) -> Time {
        self.max_end.unwrap_or(0) - self.min_submit.unwrap_or(0)
    }

    /// Peak breakpoint count the working capacity profile reached
    /// across all backfill passes (perf observability; the `sim_scale`
    /// bench records it per regime in `BENCH_hotpath.json`).
    pub fn peak_profile_breakpoints(&self) -> usize {
        self.peak_breakpoints
    }

    /// Daemon polls elided as provably no-op (perf observability; the
    /// `sim_scale` bench records it per regime as `poll<i>_elided`).
    pub fn polls_elided(&self) -> u64 {
        self.polls_elided
    }

    /// Clean backfill grid slots the on-demand tick chain batch-skipped
    /// instead of popping (perf observability; always 0 in perpetual
    /// mode). Their `backfill_skipped`/`events` accounting is
    /// synthesized, so [`SlurmStats`] stays bit-identical across modes;
    /// the saving shows up in [`events_processed`](Self::events_processed).
    pub fn backfill_ticks_elided(&self) -> u64 {
        self.bf_ticks_elided
    }

    /// High-water resident bytes across this shard's dense per-job
    /// side tables (scheduled ends, backfill predictions, encoded
    /// releases). The federation BENCH regime sums this with the
    /// daemon's [`peak_table_bytes`](crate::daemon::Autonomy::peak_table_bytes)
    /// and gates the total sublinear in ids simulated.
    pub fn peak_table_bytes(&self) -> usize {
        self.scheduled_end.peak_bytes() + self.predictions.peak_bytes() + self.bf_release.peak_bytes()
    }

    /// Ids below the retirement watermark — every job the dense tables
    /// have demonstrably reclaimed (0 with `retirement` disabled).
    pub fn jobs_retired(&self) -> u64 {
        self.watermark as u64
    }

    /// Earliest instant strictly after `t` at which any running
    /// reporting job's next planned checkpoint becomes visible
    /// (`Time::MAX` if none will). Exact until the next epoch bump:
    /// the running set — and with it every live checkpoint plan — is
    /// frozen between bumps, and a bump forces a recomputation at the
    /// next executed poll anyway. O(R·log C).
    fn next_report_visibility(&self, t: Time) -> Time {
        let mut vis = Time::MAX;
        for &id in &self.running {
            let j = &self.jobs[id.0 as usize];
            if j.ckpt_plan.is_empty() {
                continue;
            }
            let start = j.start.unwrap();
            // First planned checkpoint not yet visible at `t` (the
            // plan is ascending, so this is a binary search).
            let k = j.ckpt_plan.partition_point(|&o| start + o <= t);
            if let Some(&o) = j.ckpt_plan.get(k) {
                vis = vis.min(start + o);
            }
        }
        vis
    }

    /// Events processed (perf counter passthrough).
    pub fn events_processed(&self) -> u64 {
        self.events.processed()
    }
}

impl SlurmControl for Slurmd {
    fn control_now(&self) -> Time {
        self.now()
    }

    fn squeue(&self) -> QueueSnapshot {
        let mut out = QueueSnapshot::default();
        self.squeue_into(&mut out);
        out
    }

    fn squeue_into(&self, out: &mut QueueSnapshot) {
        out.now = self.now();
        out.running.clear();
        out.pending.clear();
        // The maintained id-ordered running set makes this O(R), not a
        // scan of the whole job table (same row order as a scan).
        for &id in &self.running {
            let j = &self.jobs[id.0 as usize];
            debug_assert_eq!(j.state, JobState::Running);
            out.running.push(RunningInfo {
                id: j.id,
                name: j.spec.name.clone(), // Arc refcount bump
                nodes: j.spec.nodes,
                start: j.start.unwrap(),
                cur_limit: j.cur_limit,
                expected_end: j.expected_end().unwrap(),
            });
        }
        for &id in &self.pending {
            let j = &self.jobs[id.0 as usize];
            out.pending.push(PendingInfo {
                id,
                nodes: j.spec.nodes,
                cur_limit: j.cur_limit,
                prediction: self.predictions.get(id.0 as usize).copied().flatten(),
            });
        }
    }

    fn read_ckpt_reports(&self, id: JobId) -> Vec<Time> {
        let mut out = Vec::new();
        self.read_ckpt_reports_into(id, &mut out);
        out
    }

    fn read_ckpt_reports_into(&self, id: JobId, out: &mut Vec<Time>) {
        out.clear();
        let j = &self.jobs[id.0 as usize];
        let Some(start) = j.start else { return };
        // Reports visible now: everything checkpointed so far, bounded
        // by the job's end (same boundary rule as `completed_ckpts`).
        // The plan is ascending, so the horizon cutoff is a binary
        // search, not a scan — the daemon polls this for every running
        // job every 20 s.
        let horizon = j.end.unwrap_or(Time::MAX).min(self.now());
        let visible = j.ckpt_plan.partition_point(|&o| start + o <= horizon);
        out.extend(j.ckpt_plan[..visible].iter().map(|&o| start + o));
    }

    fn read_new_ckpt_reports_into(&self, id: JobId, cursor: &mut usize, out: &mut Vec<Time>) {
        out.clear();
        let j = &self.jobs[id.0 as usize];
        let Some(start) = j.start else {
            *cursor = 0;
            return;
        };
        // Delta cursor (§Perf): the visible prefix of the ascending
        // plan only ever grows, so resume the horizon search from the
        // caller's consumed count and emit just the new suffix —
        // O(new + log C) instead of re-materializing the whole prefix.
        let horizon = j.end.unwrap_or(Time::MAX).min(self.now());
        let from = (*cursor).min(j.ckpt_plan.len());
        let visible = from + j.ckpt_plan[from..].partition_point(|&o| start + o <= horizon);
        out.extend(j.ckpt_plan[from..visible].iter().map(|&o| start + o));
        *cursor = visible;
    }

    fn scontrol_update_limit(&mut self, id: JobId, new_limit: Time) -> Result<(), String> {
        let now = self.now();
        let grace = self.cfg.over_time_limit;
        let job = &mut self.jobs[id.0 as usize];
        if job.state != JobState::Running {
            return Err(format!("{id}: not running"));
        }
        let start = job.start.unwrap();
        if start + new_limit < now {
            return Err(format!("{id}: new limit {new_limit}s ends in the past"));
        }
        job.cur_limit = new_limit;
        let end = job.actual_end(grace).unwrap().max(now);
        self.scheduled_end[id.0 as usize] = Some(end);
        self.events.push(end, Ev::End(id));
        self.stats.scontrol_updates += 1;
        self.bf_dirty = true;
        self.poll_epoch += 1;
        // A limit-only change keeps the cached base profile valid; the
        // next backfill pass folds it in incrementally.
        self.limit_changed.push(id);
        Ok(())
    }

    fn scancel(&mut self, id: JobId) -> Result<(), String> {
        let now = self.now();
        if self.jobs[id.0 as usize].state != JobState::Running {
            return Err(format!("{id}: not running"));
        }
        self.stats.scancels += 1;
        self.finish_job(id, now, Some(JobState::Cancelled));
        self.run_main_sched();
        Ok(())
    }

    fn mark_adjustment(&mut self, id: JobId, adj: Adjustment) {
        self.jobs[id.0 as usize].adjustment = Some(adj);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim(nodes: u32) -> Slurmd {
        Slurmd::new(SlurmConfig { nodes, ..Default::default() })
    }

    #[test]
    fn single_job_completes() {
        let mut s = sim(4);
        let id = s.submit(JobSpec::new("a", 100, 60, 2));
        s.run(&mut NoDaemon);
        let j = s.job(id);
        assert_eq!(j.state, JobState::Completed);
        assert_eq!(j.start, Some(0));
        assert_eq!(j.end, Some(60));
        assert_eq!(j.started_by, Some(StartedBy::Main));
        assert_eq!(s.makespan(), 60);
    }

    #[test]
    fn single_job_times_out() {
        let mut s = sim(4);
        let id = s.submit(JobSpec::new("t", 100, 500, 1));
        s.run(&mut NoDaemon);
        let j = s.job(id);
        assert_eq!(j.state, JobState::Timeout);
        assert_eq!(j.end, Some(100));
    }

    #[test]
    fn over_time_limit_grace_lets_near_misses_complete() {
        let mut s = Slurmd::new(SlurmConfig { nodes: 1, over_time_limit: 60, ..Default::default() });
        let a = s.submit(JobSpec::new("near", 100, 130, 1));
        let b = s.submit(JobSpec::new("far", 100, 500, 1));
        s.run(&mut NoDaemon);
        assert_eq!(s.job(a).state, JobState::Completed);
        assert_eq!(s.job(a).end, Some(130));
        assert_eq!(s.job(b).state, JobState::Timeout);
        assert_eq!(s.job(b).elapsed(), 160); // limit + grace
    }

    #[test]
    fn fifo_priority_blocks_head_of_line() {
        // 4 nodes. job0 takes 4 (runs 0..100). job1 needs 4. job2 needs 1
        // and is short — without backfill it must NOT start before job1.
        let mut s = Slurmd::new(SlurmConfig {
            nodes: 4,
            backfill_interval: 1_000_000, // effectively disable backfill
            ..Default::default()
        });
        let j0 = s.submit(JobSpec::new("j0", 100, 100, 4));
        let j1 = s.submit(JobSpec::new("j1", 100, 100, 4));
        let j2 = s.submit(JobSpec::new("j2", 10, 10, 1));
        s.run(&mut NoDaemon);
        assert_eq!(s.job(j0).start, Some(0));
        assert_eq!(s.job(j1).start, Some(100));
        assert_eq!(s.job(j2).start, Some(200), "main sched must not leapfrog");
        assert_eq!(s.stats.sched_main_started, 3);
        assert_eq!(s.stats.sched_backfill_started, 0);
    }

    #[test]
    fn backfill_fills_hole_without_delaying_head() {
        // 4 nodes. j0 holds all 4 until 100. j1 (priority head) needs 4.
        // j2 needs 1 node for 50 s: fits entirely before j1's start.
        let mut s = Slurmd::new(SlurmConfig { nodes: 4, backfill_interval: 30, ..Default::default() });
        let j0 = s.submit(JobSpec::new("j0", 100, 100, 4));
        let j1 = s.submit(JobSpec::new("j1", 100, 100, 4));
        let j2 = s.submit(JobSpec::new("j2", 50, 50, 1));
        s.run(&mut NoDaemon);
        assert_eq!(s.job(j0).start, Some(0));
        // j2 cannot backfill: j0 holds ALL nodes until 100, so the first
        // free instant is 100, where j1 has the reservation.
        assert_eq!(s.job(j1).start, Some(100));
        assert_eq!(s.job(j2).start, Some(200));

        // Now leave one node free: j0 takes 3 of 4.
        let mut s = Slurmd::new(SlurmConfig { nodes: 4, backfill_interval: 30, ..Default::default() });
        let j0 = s.submit(JobSpec::new("j0", 100, 100, 3));
        let j1 = s.submit(JobSpec::new("j1", 100, 100, 4));
        let j2 = s.submit(JobSpec::new("j2", 50, 50, 1));
        s.run(&mut NoDaemon);
        assert_eq!(s.job(j0).start, Some(0));
        // j2 starts at the first backfill tick (t=0) on the free node and
        // finishes at 50 < 100, so j1 is not delayed.
        assert_eq!(s.job(j2).start, Some(0));
        assert_eq!(s.job(j2).started_by, Some(StartedBy::Backfill));
        assert_eq!(s.job(j1).start, Some(100));
        assert_eq!(s.stats.sched_backfill_started, 1);
    }

    #[test]
    fn backfill_respects_reservation_duration() {
        // One free node until 100. A 1-node job with a 200 s limit would
        // overlap j1's 4-node reservation at t=100 -> must NOT backfill.
        let mut s = Slurmd::new(SlurmConfig { nodes: 4, backfill_interval: 30, ..Default::default() });
        let j0 = s.submit(JobSpec::new("j0", 100, 100, 3));
        let j1 = s.submit(JobSpec::new("j1", 100, 100, 4));
        let j2 = s.submit(JobSpec::new("j2", 200, 200, 1));
        s.run(&mut NoDaemon);
        assert_eq!(s.job(j0).start, Some(0));
        assert_eq!(s.job(j1).start, Some(100));
        assert_eq!(s.job(j2).start, Some(200));
        let _ = j2;
    }

    #[test]
    fn squeue_reports_predictions() {
        let mut s = Slurmd::new(SlurmConfig { nodes: 4, backfill_interval: 30, ..Default::default() });
        s.submit(JobSpec::new("j0", 1000, 1000, 4));
        s.submit(JobSpec::new("j1", 100, 100, 2));

        // Drive manually: initial main sched + one backfill pass.
        s.run_main_sched();
        s.run_backfill(0);
        let snap = s.squeue();
        assert_eq!(snap.running.len(), 1);
        assert_eq!(snap.pending.len(), 1);
        let p = snap.pending[0].prediction.expect("backfill must predict");
        assert_eq!(p.start, 1000);
        assert_eq!(p.free_at_start, 4);
    }

    #[test]
    fn scontrol_extension_moves_timeout() {
        let mut s = sim(2);
        let id = s.submit(JobSpec::new("x", 100, 10_000, 1).with_ckpt(40));
        struct ExtendOnce(bool);
        impl DaemonHook for ExtendOnce {
            fn poll_period(&self) -> Option<Time> {
                Some(20)
            }
            fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
                if !self.0 && t >= 60 {
                    self.0 = true;
                    ctl.scontrol_update_limit(JobId(0), 150).unwrap();
                    ctl.mark_adjustment(JobId(0), Adjustment::Extended);
                }
            }
        }
        let mut hook = ExtendOnce(false);
        s.run(&mut hook);
        let j = s.job(id);
        assert_eq!(j.state, JobState::Timeout);
        assert_eq!(j.end, Some(150));
        assert_eq!(j.adjustment, Some(Adjustment::Extended));
        assert_eq!(s.stats.scontrol_updates, 1);
        assert!(s.stats.stale_events >= 1, "the original End event must be invalidated");
    }

    #[test]
    fn scancel_frees_nodes_immediately() {
        let mut s = sim(2);
        let a = s.submit(JobSpec::new("a", 1000, 1000, 2));
        let b = s.submit(JobSpec::new("b", 50, 50, 2));
        struct CancelAt(Time, bool);
        impl DaemonHook for CancelAt {
            fn poll_period(&self) -> Option<Time> {
                Some(10)
            }
            fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
                if !self.1 && t >= self.0 {
                    self.1 = true;
                    ctl.scancel(JobId(0)).unwrap();
                }
            }
        }
        let mut hook = CancelAt(100, false);
        s.run(&mut hook);
        assert_eq!(s.job(a).state, JobState::Cancelled);
        assert_eq!(s.job(a).end, Some(100));
        // b starts right at the cancellation (main sched runs inline).
        assert_eq!(s.job(b).start, Some(100));
        assert_eq!(s.makespan(), 150);
    }

    #[test]
    fn ckpt_reports_visible_up_to_now() {
        let mut s = sim(1);
        s.submit(JobSpec::new("c", 200, 10_000, 1).with_ckpt(40));
        struct Check;
        impl DaemonHook for Check {
            fn poll_period(&self) -> Option<Time> {
                Some(50)
            }
            fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
                let reports = ctl.read_ckpt_reports(JobId(0));
                // Bounded by now and by the job end (timeout at 200; the
                // checkpoint landing exactly at 200 counts as completed).
                let expect: Vec<Time> =
                    (1..).map(|k| k * 40).take_while(|&x| x <= t.min(200)).collect();
                assert_eq!(reports, expect, "at t={t}");
            }
        }
        s.run(&mut Check);
        let final_reports = s.read_ckpt_reports(JobId(0));
        assert_eq!(final_reports, vec![40, 80, 120, 160, 200]);
    }

    #[test]
    fn over_time_limit_grace_never_overallocates() {
        // Regression: a job overrunning into its grace window still
        // holds nodes; backfill must not start anything on them.
        let mut s = Slurmd::new(SlurmConfig {
            nodes: 4,
            over_time_limit: 300,
            backfill_interval: 30,
            ..Default::default()
        });
        // Overrunner: limit 100, true duration 350 -> runs 100..400 in
        // grace, holding all 4 nodes.
        s.submit(JobSpec::new("overrun", 100, 350, 4));
        // A stream of small jobs that backfill will try to place the
        // moment the profile thinks nodes are free.
        for i in 0..6 {
            s.submit(JobSpec::new(&format!("s{i}"), 120, 60, 2));
        }
        s.run(&mut NoDaemon); // panics on over-allocation if broken
        assert_eq!(s.job(JobId(0)).state, JobState::Completed);
        assert_eq!(s.job(JobId(0)).elapsed(), 350);
    }

    #[test]
    fn stats_account_every_start() {
        let mut s = Slurmd::new(SlurmConfig { nodes: 8, ..Default::default() });
        let mut rng = crate::proptest_lite::Rng::new(3);
        for i in 0..50 {
            let nodes = rng.int_in(1, 8) as u32;
            let dur = rng.int_in(10, 400);
            let limit = dur + rng.int_in(0, 200);
            s.submit(JobSpec::new(&format!("j{i}"), limit, dur, nodes));
        }
        s.run(&mut NoDaemon);
        assert_eq!(s.stats.sched_main_started + s.stats.sched_backfill_started, 50);
        assert!(s.jobs().iter().all(|j| j.state == JobState::Completed));
    }

    #[test]
    fn staggered_submission_waits_for_arrival() {
        let mut s = sim(2);
        let a = s.submit(JobSpec::new("first", 100, 50, 1));
        let mut late = JobSpec::new("late", 100, 50, 1);
        late.submit = 200;
        let b = s.submit(late);
        s.run(&mut NoDaemon);
        assert_eq!(s.job(a).start, Some(0));
        assert_eq!(s.job(b).start, Some(200), "arrival gates the start");
        assert_eq!(s.job(b).wait(), Some(0));
        assert_eq!(s.job(b).state, JobState::Completed);
        assert_eq!(s.makespan(), 250); // max end 250 - min submit 0
    }

    #[test]
    fn staggered_arrivals_keep_fifo_priority() {
        // Two 2-node jobs on a 2-node cluster arriving at 10 and 20:
        // the later one must queue behind the earlier one.
        let mut s = sim(2);
        let mk = |name: &str, at| {
            let mut j = JobSpec::new(name, 500, 400, 2);
            j.submit = at;
            j
        };
        let a = s.submit(mk("a", 10));
        let b = s.submit(mk("b", 20));
        let c = s.submit(mk("c", 20)); // same instant as b: call order wins
        s.run(&mut NoDaemon);
        assert_eq!(s.job(a).start, Some(10));
        assert_eq!(s.job(b).start, Some(410));
        assert_eq!(s.job(c).start, Some(810));
    }

    #[test]
    fn incremental_profile_survives_limit_updates() {
        // A long holder plus a queue; between backfill passes the
        // holder's limit is extended twice (base profile refreshed
        // incrementally), and predictions must track the new release.
        let mut s = Slurmd::new(SlurmConfig { nodes: 4, backfill_interval: 30, ..Default::default() });
        let hold = s.submit(JobSpec::new("hold", 1000, 5000, 4));
        let q = s.submit(JobSpec::new("queued", 100, 100, 4));
        struct ExtendTwice(u8);
        impl DaemonHook for ExtendTwice {
            fn poll_period(&self) -> Option<Time> {
                Some(50)
            }
            fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
                if (self.0 == 0 && t >= 100) || (self.0 == 1 && t >= 200) {
                    self.0 += 1;
                    let new = 1000 + 500 * self.0 as Time;
                    ctl.scontrol_update_limit(JobId(0), new).unwrap();
                }
                if t == 250 {
                    // After two extensions the queued job's predicted
                    // start must sit at the holder's new expected end.
                    let snap = ctl.squeue();
                    let p = snap.pending[0].prediction.expect("predicted");
                    assert_eq!(p.start, 2000);
                }
            }
        }
        s.run(&mut ExtendTwice(0));
        assert_eq!(s.job(hold).end, Some(2000), "timeout at the extended limit");
        assert_eq!(s.job(q).start, Some(2000));
        assert!(s.stats.scontrol_updates == 2);
    }

    #[test]
    fn makespan_tracks_extrema_incrementally() {
        // Staggered arrivals, all strictly after t=0: min-submit must
        // come from the specs, not default to the clock, and the
        // incrementally maintained extrema must match a full scan.
        let mut s = sim(2);
        let mk = |name: &str, at, dur| {
            let mut j = JobSpec::new(name, dur, dur, 1);
            j.submit = at;
            j
        };
        s.submit(mk("a", 50, 100));
        s.submit(mk("b", 30, 40));
        // Mid-run (nothing ended yet): same value the seed's scans gave
        // (max-end defaults to 0 with no terminal job).
        assert_eq!(s.makespan(), -30);
        s.run(&mut NoDaemon);
        let scan_end = s.jobs().iter().filter_map(|j| j.end).max().unwrap();
        let scan_submit = s.jobs().iter().map(|j| j.spec.submit).min().unwrap();
        assert_eq!(s.makespan(), scan_end - scan_submit);
        assert_eq!(s.makespan(), 120); // max end 150 − min submit 30
    }

    #[test]
    fn flat_and_tree_cores_agree_on_a_small_mix() {
        let run = |kind| {
            let mut s = Slurmd::new(SlurmConfig {
                nodes: 4,
                backfill_profile: kind,
                ..Default::default()
            });
            s.submit(JobSpec::new("j0", 100, 100, 3));
            s.submit(JobSpec::new("j1", 100, 100, 4));
            s.submit(JobSpec::new("j2", 50, 50, 1));
            s.run(&mut NoDaemon);
            (s.stats.clone(), s.into_jobs())
        };
        let (tree_stats, tree_jobs) = run(BackfillProfile::Tree);
        let (flat_stats, flat_jobs) = run(BackfillProfile::Flat);
        assert_eq!(tree_jobs, flat_jobs);
        assert_eq!(tree_stats, flat_stats);
    }

    #[test]
    fn squeue_into_reuses_buffers() {
        let mut s = sim(4);
        s.submit(JobSpec::new("a", 1000, 1000, 4));
        s.submit(JobSpec::new("b", 100, 100, 2));
        s.sched_now();
        s.backfill_now();
        let mut snap = QueueSnapshot::default();
        s.squeue_into(&mut snap);
        assert_eq!(snap.running.len(), 1);
        assert_eq!(snap.pending.len(), 1);
        // Re-fill: stale rows must be cleared, content identical.
        let again = s.squeue();
        s.squeue_into(&mut snap);
        assert_eq!(snap.running.len(), again.running.len());
        assert_eq!(snap.pending.len(), again.pending.len());
        assert_eq!(snap.pending[0].prediction.map(|p| p.start), again.pending[0].prediction.map(|p| p.start));

        let mut reports = vec![99; 8]; // dirty scratch must be cleared
        s.read_ckpt_reports_into(JobId(0), &mut reports);
        assert!(reports.is_empty(), "job a has no checkpoint plan");
    }

    #[test]
    fn delta_cursor_reads_each_report_once() {
        let mut s = sim(1);
        s.submit(JobSpec::new("c", 500, 10_000, 1).with_ckpt(40));
        struct CursorCheck {
            cursor: usize,
            seen: Vec<Time>,
        }
        impl DaemonHook for CursorCheck {
            fn poll_period(&self) -> Option<Time> {
                Some(50)
            }
            fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
                let mut new = Vec::new();
                ctl.read_new_ckpt_reports_into(JobId(0), &mut self.cursor, &mut new);
                // Delta + full read must agree: seen ++ new == full.
                self.seen.extend(&new);
                let full = ctl.read_ckpt_reports(JobId(0));
                assert_eq!(self.seen, full, "at t={t}");
                assert_eq!(self.cursor, full.len());
                // Re-reading immediately yields nothing new.
                let mut again = vec![7; 3];
                ctl.read_new_ckpt_reports_into(JobId(0), &mut self.cursor, &mut again);
                assert!(again.is_empty());
            }
        }
        let mut hook = CursorCheck { cursor: 0, seen: Vec::new() };
        s.run(&mut hook);
        assert_eq!(hook.seen, vec![40, 80, 120, 160, 200, 240, 280, 320, 360, 400, 440, 480]);
    }

    #[test]
    fn elision_fast_forwards_noop_polls_with_identical_stats() {
        // A reporting job with sparse checkpoints and a tight poll: the
        // elided run must skip most ticks while keeping SlurmStats and
        // the hook's poll count bit-identical to blind polling.
        struct CountingHook {
            polls: u64,
            stable: bool,
        }
        impl DaemonHook for CountingHook {
            fn poll_period(&self) -> Option<Time> {
                Some(10)
            }
            fn on_poll(&mut self, _t: Time, ctl: &mut dyn SlurmControl) {
                self.polls += 1;
                // Touch the control surface like a real daemon would.
                let mut snap = QueueSnapshot::default();
                ctl.squeue_into(&mut snap);
            }
            fn poll_elidable(&self) -> bool {
                self.stable
            }
            fn note_elided_polls(&mut self, n: u64) {
                self.polls += n;
            }
        }
        let run = |elide: bool| {
            let mut s = Slurmd::new(SlurmConfig {
                nodes: 2,
                poll_elision: elide,
                ..Default::default()
            });
            s.submit(JobSpec::new("ck", 2000, 2000, 1).with_ckpt(500));
            s.submit(JobSpec::new("plain", 1500, 1500, 1));
            let mut hook = CountingHook { polls: 0, stable: true };
            s.run(&mut hook);
            (s.stats.clone(), hook.polls, s.polls_elided(), s.into_jobs())
        };
        let (es, ep, elided, ejobs) = run(true);
        let (bs, bp, blind_elided, bjobs) = run(false);
        assert_eq!(es, bs, "SlurmStats must be bit-identical");
        assert_eq!(ep, bp, "hook poll accounting must be bit-identical");
        assert_eq!(ejobs, bjobs);
        assert_eq!(blind_elided, 0);
        assert!(elided > ep / 2, "most ticks must be elided: {elided}/{ep}");
    }

    #[test]
    fn ondemand_ticks_match_perpetual_on_a_small_mix() {
        let run = |ticks| {
            let mut s = Slurmd::new(SlurmConfig {
                nodes: 4,
                backfill_ticks: ticks,
                ..Default::default()
            });
            s.submit(JobSpec::new("j0", 100, 100, 3));
            s.submit(JobSpec::new("j1", 100, 100, 4));
            s.submit(JobSpec::new("j2", 50, 50, 1));
            let mut late = JobSpec::new("late", 400, 350, 2);
            late.submit = 500; // quiet stretch, then a fresh arrival
            s.submit(late);
            s.run(&mut NoDaemon);
            (s.stats.clone(), s.events_processed(), s.backfill_ticks_elided(), s.into_jobs())
        };
        let (od_stats, od_popped, od_elided, od_jobs) = run(BackfillTicks::OnDemand);
        let (pp_stats, pp_popped, pp_elided, pp_jobs) = run(BackfillTicks::Perpetual);
        assert_eq!(od_jobs, pp_jobs);
        assert_eq!(od_stats, pp_stats, "synthesized accounting must be exact");
        assert_eq!(pp_elided, 0, "perpetual mode never elides ticks");
        assert!(od_elided > 0, "the 400 s quiet stretch must skip slots");
        assert!(od_popped < pp_popped, "on-demand must pop fewer events: {od_popped} vs {pp_popped}");
    }

    #[test]
    fn ondemand_runs_the_final_pass_after_the_last_finish() {
        // The perpetual loop always ends with one pass popped after the
        // last job terminates (the finish dirties the state); the chain
        // must drain that pass even though the queue is already empty.
        let run = |ticks| {
            let mut s = Slurmd::new(SlurmConfig { nodes: 2, backfill_ticks: ticks, ..Default::default() });
            s.submit(JobSpec::new("a", 100, 70, 1));
            s.run(&mut NoDaemon);
            s.stats.clone()
        };
        let od = run(BackfillTicks::OnDemand);
        let pp = run(BackfillTicks::Perpetual);
        assert_eq!(od, pp);
        assert!(od.backfill_passes >= 2, "t=0 pass + the post-finish pass");
    }

    #[test]
    fn unstable_hooks_are_never_elided() {
        // poll_elidable() defaults to false: every tick executes.
        let mut s = sim(2);
        s.submit(JobSpec::new("ck", 2000, 2000, 1).with_ckpt(500));
        struct Plain(u64);
        impl DaemonHook for Plain {
            fn poll_period(&self) -> Option<Time> {
                Some(20)
            }
            fn on_poll(&mut self, _t: Time, _ctl: &mut dyn SlurmControl) {
                self.0 += 1;
            }
        }
        let mut hook = Plain(0);
        s.run(&mut hook);
        assert_eq!(s.polls_elided(), 0);
        assert!(hook.0 > 90, "every slot executed: {}", hook.0);
    }

    #[test]
    fn failure_plan_draws_are_bounded_and_seeded() {
        assert!(
            FailurePlan::new(&FailureConfig::default()).is_none(),
            "mtbf 0 disables the axis entirely"
        );
        let cfg = FailureConfig { mtbf: 100, ..Default::default() };
        let mut a = FailurePlan::new(&cfg).unwrap();
        let mut b = FailurePlan::new(&cfg).unwrap();
        let mut sum = 0i64;
        for _ in 0..1000 {
            let (gap, kind) = a.next_event();
            assert_eq!((gap, kind), b.next_event(), "same seed, same stream");
            assert!((1..=199).contains(&gap), "gap uniform on [1, 2·mtbf − 1]: {gap}");
            sum += gap;
        }
        assert!((80..=120).contains(&(sum / 1000)), "mean gap ≈ mtbf: {}", sum / 1000);
        assert!(a.victim_slot(5) < 5);
    }

    #[test]
    fn a_kill_on_a_full_cluster_fails_the_running_job() {
        // mtbf=1 makes every gap exactly 1 and a 1-node cluster makes
        // the victim walk deterministic: the kill lands at t=1.
        let mut s = Slurmd::new(SlurmConfig {
            nodes: 1,
            failures: FailureConfig {
                mtbf: 1,
                drain_frac: 0.0,
                drain_secs: 5,
                ..Default::default()
            },
            ..Default::default()
        });
        let id = s.submit(JobSpec::new("victim", 100, 100, 1));
        s.run(&mut NoDaemon);
        let j = s.job(id);
        assert_eq!(j.state, JobState::NodeFailed);
        assert_eq!(j.end, Some(1));
        assert_eq!(s.stats.jobs_failed, 1);
        assert_eq!(s.stats.node_failures, 1);
        assert_eq!(s.stats.node_drains, 0);
        // The repair window elapsed inside the drain of leftover
        // events: the node is back.
        assert_eq!(s.cluster().down(), 0);
        assert_eq!(s.cluster().free(), 1);
        // The original End event went stale via lazy invalidation.
        assert!(s.stats.stale_events >= 1);
    }

    #[test]
    fn a_drain_waits_for_the_job_and_then_repairs() {
        let mut s = Slurmd::new(SlurmConfig {
            nodes: 1,
            failures: FailureConfig {
                mtbf: 1,
                drain_frac: 1.0,
                drain_secs: 7,
                ..Default::default()
            },
            ..Default::default()
        });
        let id = s.submit(JobSpec::new("survivor", 50, 40, 1));
        s.run(&mut NoDaemon);
        let j = s.job(id);
        assert_eq!(j.state, JobState::Completed, "a drain never kills");
        assert_eq!(j.end, Some(40));
        assert_eq!(s.stats.jobs_failed, 0);
        // Re-drains of an already-marked node don't re-count.
        assert_eq!(s.stats.node_drains, 1);
        assert_eq!(s.cluster().down(), 0);
        assert_eq!(s.cluster().free(), 1);
    }

    #[test]
    fn a_kill_releases_the_jobs_other_nodes() {
        // A 3-node job dies at t=1: ONE node goes down, the other two
        // immediately serve the next pending job.
        let mut s = Slurmd::new(SlurmConfig {
            nodes: 3,
            failures: FailureConfig {
                mtbf: 1,
                drain_frac: 0.0,
                drain_secs: 1000,
                ..Default::default()
            },
            ..Default::default()
        });
        let big = s.submit(JobSpec::new("big", 100, 100, 3));
        let next = s.submit(JobSpec::new("next", 20, 1, 2));
        s.run(&mut NoDaemon);
        assert_eq!(s.job(big).state, JobState::NodeFailed);
        assert_eq!(s.job(big).end, Some(1));
        let n = s.job(next);
        assert_eq!(n.state, JobState::Completed);
        assert_eq!(n.start, Some(1), "surviving nodes serve it at the kill instant");
        assert_eq!(n.end, Some(2));
        assert_eq!(s.stats.jobs_failed, 1);
        assert_eq!(s.stats.node_failures, 1);
        assert_eq!(s.cluster().down(), 0, "repair window elapsed in the event drain");
        assert_eq!(s.cluster().free(), 3);
    }
}
