//! External slurmctld binding: a [`SlurmControl`] that shells out to a
//! real site's `squeue`/`scontrol`/`scancel`.
//!
//! The daemon logic never changes — [`ExternalSlurm`] is just another
//! control surface, configured with the command lines to run
//! (`[slurm] squeue_cmd/scontrol_cmd/scancel_cmd` in TOML). Every
//! invocation is hardened the way a production poll loop has to be:
//!
//! - **Timeouts**: each child gets `timeout_ms` of wall time, then is
//!   killed (`kill(2)`) and reported as a failed RPC. A hung slurmctld
//!   must never wedge the poll loop.
//! - **Nonzero exits** become `Err` results (retried by the daemon's
//!   token-bucket machinery like any rejection), never panics.
//! - **Malformed output lines** are skipped with a warning and counted
//!   in [`ExternalSlurm::parse_errors`]; one garbled row cannot poison
//!   the whole snapshot.
//!
//! `squeue` is invoked with an explicit pipe-separated format
//! (`--noheader -o %A|%j|%D|%T|%S|%l`), so parsing does not depend on
//! site column configuration. Checkpoint reports come from the same
//! [`FileSpool`](crate::ckpt::FileSpool) directory live mode uses
//! (Fig. 2's temp-file protocol is transport-independent).
//!
//! [`scontrol_update_limits_concurrent`](SlurmControl::scontrol_update_limits_concurrent)
//! is genuinely parallel here: up to `parallelism` `scontrol` children
//! run at once on scoped threads, results returned in submission order
//! — the actuator the daemon's AIMD RPC-concurrency controller sizes.
//!
//! All of this is exercised against a bundled fake-slurmctld shell
//! script (`rust/tests/fake_slurm/`) — well-formed output, malformed
//! rows, and hung commands — so no real Slurm is needed to test the
//! binding.

use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use crate::ckpt::FileSpool;
use crate::simtime::Time;
use crate::slurm::{
    Adjustment, JobId, PendingInfo, QueueSnapshot, RunningInfo, SlurmControl,
};
use crate::warn_log;

/// How to reach the site's Slurm (TOML `[slurm]` keys with the same
/// names plus `_cmd`). Commands are split on whitespace: the first
/// token is the executable, the rest are leading arguments — so
/// `"ssh ctld squeue"` or `"sh tests/fake_slurm/fake_slurmctld.sh squeue d"`
/// both work without a shell.
#[derive(Debug, Clone, PartialEq)]
pub struct ExternalConfig {
    /// Queue listing command; `--noheader -o <fmt>` is appended.
    pub squeue_cmd: String,
    /// Limit-update command; `update JobId=.. TimeLimit=..` is appended.
    pub scontrol_cmd: String,
    /// Cancel command; the job id is appended.
    pub scancel_cmd: String,
    /// Per-invocation wall-time budget before the child is killed.
    pub timeout_ms: u64,
    /// Checkpoint-report spool directory (Fig. 2's temp files).
    pub spool_dir: Option<String>,
}

impl Default for ExternalConfig {
    fn default() -> Self {
        Self {
            squeue_cmd: "squeue".into(),
            scontrol_cmd: "scontrol".into(),
            scancel_cmd: "scancel".into(),
            timeout_ms: 10_000,
            spool_dir: None,
        }
    }
}

/// The external control surface. See the module docs for the hardening
/// contract; the public counters are observability for the supervisor.
pub struct ExternalSlurm {
    cfg: ExternalConfig,
    spool: Option<FileSpool>,
    /// `squeue` rows that failed to parse and were skipped. A `Cell`
    /// because the trait's read path is `&self`; the surface is only
    /// ever driven from one thread (the poll loop).
    parse_errors: std::cell::Cell<u64>,
    /// Children killed for exceeding `timeout_ms`.
    pub timeouts: u64,
    /// RPCs that failed (nonzero exit, spawn failure, or timeout).
    pub rpc_failures: u64,
}

impl ExternalSlurm {
    pub fn new(cfg: ExternalConfig) -> crate::errors::Result<Self> {
        let spool = match &cfg.spool_dir {
            Some(d) => Some(FileSpool::new(d)?),
            None => None,
        };
        Ok(Self {
            cfg,
            spool,
            parse_errors: std::cell::Cell::new(0),
            timeouts: 0,
            rpc_failures: 0,
        })
    }

    /// `squeue` rows skipped as malformed so far.
    pub fn parse_errors(&self) -> u64 {
        self.parse_errors.get()
    }

    /// Parse one `squeue` row into the snapshot; `Err` names what was
    /// wrong with it (the caller skips + counts).
    fn ingest_row(&self, line: &str, out: &mut QueueSnapshot) -> Result<(), String> {
        let mut f = line.split('|');
        let id: u32 = f
            .next()
            .ok_or("missing job id")?
            .trim()
            .parse()
            .map_err(|_| "job id is not a number".to_string())?;
        let name = f.next().ok_or("missing name")?.trim();
        let nodes: u32 = f
            .next()
            .ok_or("missing node count")?
            .trim()
            .parse()
            .map_err(|_| "node count is not a number".to_string())?;
        let state = f.next().ok_or("missing state")?.trim();
        let start = f.next().ok_or("missing start time")?.trim();
        let limit = parse_duration(f.next().ok_or("missing time limit")?.trim())?;
        match state {
            "RUNNING" | "R" => {
                let start = parse_iso_utc(start)?;
                out.running.push(RunningInfo {
                    id: JobId(id),
                    name: name.into(),
                    nodes,
                    start,
                    cur_limit: limit,
                    expected_end: start + limit,
                });
            }
            "PENDING" | "PD" => {
                out.pending.push(PendingInfo {
                    id: JobId(id),
                    nodes,
                    cur_limit: limit,
                    prediction: None,
                });
            }
            // Terminal/transient states (COMPLETED, FAILED, CG, ...)
            // are not the daemon's business on this poll.
            _ => {}
        }
        Ok(())
    }

    fn run(&mut self, base: &str, extra: &[String]) -> Result<String, String> {
        let r = run_cmd(base, extra, self.cfg.timeout_ms);
        if let Err(e) = &r {
            self.rpc_failures += 1;
            if e.contains("timed out") {
                self.timeouts += 1;
            }
        }
        r
    }
}

/// Split a configured command string and run it with `extra` appended,
/// capturing stdout, under a hard wall-time budget. The child is
/// polled every 10 ms; past the deadline it is killed and the call
/// reports a timeout. Stdout is drained on a separate thread so a
/// chatty child can never deadlock against a full pipe.
fn run_cmd(base: &str, extra: &[String], timeout_ms: u64) -> Result<String, String> {
    let mut argv = base.split_whitespace();
    let prog = argv.next().ok_or_else(|| "empty command".to_string())?;
    let mut child = Command::new(prog)
        .args(argv)
        .args(extra)
        .stdin(Stdio::null())
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .map_err(|e| format!("spawn {prog}: {e}"))?;
    let mut stdout = child.stdout.take().ok_or_else(|| "no stdout pipe".to_string())?;
    let reader = std::thread::spawn(move || {
        use std::io::Read as _;
        let mut buf = String::new();
        let _ = stdout.read_to_string(&mut buf);
        buf
    });
    let deadline = Instant::now() + Duration::from_millis(timeout_ms);
    loop {
        match child.try_wait() {
            Ok(Some(status)) => {
                let out = reader.join().unwrap_or_default();
                return if status.success() {
                    Ok(out)
                } else {
                    Err(format!("{prog} exited with {status}"))
                };
            }
            Ok(None) => {
                if Instant::now() >= deadline {
                    let _ = child.kill();
                    let _ = child.wait();
                    // Do NOT join the reader: a grandchild the kill
                    // missed can hold the pipe open past our deadline.
                    // The detached thread exits when the pipe closes.
                    drop(reader);
                    return Err(format!("{prog} timed out after {timeout_ms} ms"));
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            Err(e) => {
                let _ = child.kill();
                drop(reader);
                return Err(format!("wait {prog}: {e}"));
            }
        }
    }
}

/// Minimal `YYYY-MM-DDTHH:MM:SS` → unix seconds (UTC; sites running
/// the daemon next to slurmctld share its clock). Civil-days algorithm,
/// valid for all Gregorian dates.
fn parse_iso_utc(s: &str) -> Result<Time, String> {
    let bad = || format!("bad ISO timestamp {s:?}");
    let (date, time) = s.split_once('T').ok_or_else(bad)?;
    let mut d = date.split('-');
    let (y, m, day) = match (d.next(), d.next(), d.next(), d.next()) {
        (Some(y), Some(m), Some(day), None) => (y, m, day),
        _ => return Err(bad()),
    };
    let mut t = time.split(':');
    let (hh, mm, ss) = match (t.next(), t.next(), t.next(), t.next()) {
        (Some(h), Some(m), Some(s), None) => (h, m, s),
        _ => return Err(bad()),
    };
    let p = |x: &str| x.parse::<i64>().map_err(|_| bad());
    let (y, m, day) = (p(y)?, p(m)?, p(day)?);
    let (hh, mm, ss) = (p(hh)?, p(mm)?, p(ss)?);
    if !(1..=12).contains(&m) || !(1..=31).contains(&day) || hh > 23 || mm > 59 || ss > 60 {
        return Err(bad());
    }
    Ok(days_from_civil(y, m, day) * 86_400 + hh * 3_600 + mm * 60 + ss)
}

/// Days since 1970-01-01 for a proleptic-Gregorian civil date.
fn days_from_civil(y: i64, m: i64, d: i64) -> i64 {
    let y = if m <= 2 { y - 1 } else { y };
    let era = if y >= 0 { y } else { y - 399 } / 400;
    let yoe = y - era * 400;
    let doy = (153 * ((m + 9) % 12) + 2) / 5 + d - 1;
    let doe = yoe * 365 + yoe / 4 - yoe / 100 + doy;
    era * 146_097 + doe - 719_468
}

/// Slurm duration (`[DD-]HH:MM:SS`, `HH:MM:SS`, `MM:SS`, or bare
/// minutes) → seconds. `UNLIMITED`/`NOT_SET` are rejected — the daemon
/// only reasons about bounded limits.
fn parse_duration(s: &str) -> Result<Time, String> {
    let bad = || format!("bad duration {s:?}");
    let (days, rest) = match s.split_once('-') {
        Some((d, r)) => (d.parse::<i64>().map_err(|_| bad())?, r),
        None => (0, s),
    };
    let parts: Vec<&str> = rest.split(':').collect();
    let p = |x: &str| x.parse::<i64>().map_err(|_| bad());
    let secs = match parts.as_slice() {
        [h, m, sec] => p(h)? * 3_600 + p(m)? * 60 + p(sec)?,
        [m, sec] => p(m)? * 60 + p(sec)?,
        [m] => p(m)? * 60,
        _ => return Err(bad()),
    };
    if secs < 0 {
        return Err(bad());
    }
    Ok(days * 86_400 + secs)
}

impl SlurmControl for ExternalSlurm {
    fn control_now(&self) -> Time {
        match std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH) {
            Ok(d) => d.as_secs() as Time,
            Err(_) => 0,
        }
    }

    fn squeue(&self) -> QueueSnapshot {
        // The trait read path is `&self`; counters are updated by the
        // `&mut` RPC paths only, so a failed squeue here degrades to an
        // empty snapshot with a warning (the daemon just sees an idle
        // cluster until the next poll).
        let mut out = QueueSnapshot { now: self.control_now(), ..Default::default() };
        let extra =
            ["--noheader".to_string(), "-o".to_string(), "%A|%j|%D|%T|%S|%l".to_string()];
        let text = match run_cmd(&self.cfg.squeue_cmd, &extra, self.cfg.timeout_ms) {
            Ok(t) => t,
            Err(e) => {
                warn_log!("squeue failed, treating as empty queue: {e}");
                return out;
            }
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Err(e) = self.ingest_row(line, &mut out) {
                self.parse_errors.set(self.parse_errors.get() + 1);
                warn_log!("skipping malformed squeue row {line:?}: {e}");
            }
        }
        out
    }

    fn read_ckpt_reports(&self, id: JobId) -> Vec<Time> {
        self.spool.as_ref().map(|s| s.read(id)).unwrap_or_default()
    }

    fn scontrol_update_limit(&mut self, id: JobId, new_limit: Time) -> Result<(), String> {
        // slurmctld takes TimeLimit in minutes; round up so the granted
        // limit always covers the requested seconds.
        let minutes = (new_limit + 59) / 60;
        let extra = ["update".to_string(), format!("JobId={id}"), format!("TimeLimit={minutes}")];
        self.run(&self.cfg.scontrol_cmd.clone(), &extra).map(|_| ())
    }

    fn scontrol_update_limits_concurrent(
        &mut self,
        updates: &[(JobId, Time)],
        parallelism: usize,
    ) -> Vec<Result<(), String>> {
        let par = parallelism.max(1);
        if par == 1 || updates.len() <= 1 {
            return self.scontrol_update_limits(updates);
        }
        // Real parallelism: `par` scoped workers pull updates off a
        // shared cursor; results are re-sorted by submission index so
        // completion order never leaks into the result.
        let cmd = self.cfg.scontrol_cmd.clone();
        let timeout_ms = self.cfg.timeout_ms;
        let next = std::sync::atomic::AtomicUsize::new(0);
        let collected: std::sync::Mutex<Vec<(usize, Result<(), String>)>> =
            std::sync::Mutex::new(Vec::with_capacity(updates.len()));
        std::thread::scope(|s| {
            for _ in 0..par.min(updates.len()) {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    let Some(&(id, lim)) = updates.get(i) else { break };
                    let minutes = (lim + 59) / 60;
                    let extra = [
                        "update".to_string(),
                        format!("JobId={id}"),
                        format!("TimeLimit={minutes}"),
                    ];
                    let r = run_cmd(&cmd, &extra, timeout_ms).map(|_| ());
                    collected.lock().expect("result lock").push((i, r));
                });
            }
        });
        let mut v = collected.into_inner().expect("scope joined all workers");
        v.sort_unstable_by_key(|&(i, _)| i);
        let out: Vec<Result<(), String>> = v.into_iter().map(|(_, r)| r).collect();
        for e in out.iter().filter_map(|r| r.as_ref().err()) {
            self.rpc_failures += 1;
            if e.contains("timed out") {
                self.timeouts += 1;
            }
        }
        out
    }

    fn scancel(&mut self, id: JobId) -> Result<(), String> {
        self.run(&self.cfg.scancel_cmd.clone(), &[id.to_string()]).map(|_| ())
    }

    fn mark_adjustment(&mut self, _id: JobId, _adj: Adjustment) {
        // Accounting tags are a simulator affordance; a real site's
        // sacct has no such field. Deliberate no-op.
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn iso_parse_matches_known_epochs() {
        assert_eq!(parse_iso_utc("1970-01-01T00:00:00").unwrap(), 0);
        assert_eq!(parse_iso_utc("2009-02-13T23:31:30").unwrap(), 1_234_567_890);
        assert_eq!(parse_iso_utc("2000-03-01T00:00:00").unwrap(), 951_868_800);
        assert!(parse_iso_utc("2026-13-01T00:00:00").is_err());
        assert!(parse_iso_utc("not-a-date").is_err());
    }

    #[test]
    fn duration_parse_covers_slurm_forms() {
        assert_eq!(parse_duration("30").unwrap(), 1_800);
        assert_eq!(parse_duration("05:00").unwrap(), 300);
        assert_eq!(parse_duration("1:00:00").unwrap(), 3_600);
        assert_eq!(parse_duration("2-00:00:00").unwrap(), 172_800);
        assert!(parse_duration("UNLIMITED").is_err());
        assert!(parse_duration("").is_err());
    }
}
