//! Slurm-like scheduling substrate.
//!
//! The paper evaluates its autonomy loop against Slurm 23.11 on a
//! 20-node cluster; no existing Slurm simulator supports dynamic
//! per-job time-limit adjustment, so this module reimplements the
//! relevant subset from scratch (see DESIGN.md §1 for the substitution
//! argument):
//!
//! - [`job`]: job specs, lifecycle states, checkpoint plans;
//! - [`ctld`]: the central daemon — main priority scheduler,
//!   conservative backfill with reservations and start-time prediction,
//!   the `scontrol`/`squeue`/`scancel` control surface, OverTimeLimit;
//! - [`external`]: the production binding — the same control surface
//!   shelling out to a real site's `squeue`/`scontrol`/`scancel`, with
//!   timeout/exit/parse hardening (tested against a bundled
//!   fake-slurmctld script, no real Slurm required);
//! - [`reference`]: the retained naive seed scheduler — perpetual
//!   backfill ticks, blind polls, hash maps and all — the golden
//!   oracle the optimized core is property-tested against
//!   (EXPERIMENTS.md §Perf; untouched by design);
//! - [`fed`]: the sharded multi-cluster federation — per-shard
//!   [`Slurmd`]s merged deterministically by (time, shard, seq), with
//!   dense-table retirement bounding memory at million-job scale.

pub mod ctld;
pub mod external;
pub mod fed;
pub mod job;
pub mod reference;

pub use crate::cluster::BackfillProfile;
pub use external::{ExternalConfig, ExternalSlurm};
pub use fed::{run_federation, FedDrive, FedOutcome};
pub use ctld::{
    BackfillPrediction, BackfillTicks, DaemonHook, FailureConfig, FailurePlan, NoDaemon,
    PendingInfo, QueueSnapshot, RunningInfo, SlurmConfig, SlurmControl, SlurmStats, Slurmd,
};
pub use job::{Adjustment, CkptSpec, Job, JobId, JobSpec, JobState, StartedBy};
