//! The retained **naive** scheduler: the pre-optimization seed
//! implementation, kept as the golden reference for the hot-path
//! overhaul (EXPERIMENTS.md §Perf).
//!
//! [`NaiveSlurmd`] mirrors [`super::Slurmd`]'s semantics exactly —
//! same events, same tie-breaking, same control surface — but with the
//! seed's data structures:
//!
//! - the capacity profile ([`NaiveProfile`]) is rebuilt from scratch on
//!   every backfill pass, with `Vec::insert`-based breakpoint splitting
//!   (O(n) memmove per reservation edge);
//! - started jobs are removed from the pending queue with one
//!   `retain` per job (O(S·P));
//! - `squeue` allocates a fresh snapshot per call.
//!
//! The golden-equivalence property test (`rust/tests/properties.rs`)
//! runs both implementations over random workloads — including
//! staggered arrivals, OverTimeLimit grace, and live daemon policies —
//! and asserts identical starts, ends, states, predictions, and
//! [`SlurmStats`]. The `sim_scale` bench measures the speedup of the
//! optimized core against this baseline and records it in
//! `BENCH_hotpath.json`.

use std::collections::{BTreeSet, HashMap};

use crate::cluster::Cluster;
use crate::simtime::{EventQueue, Time};

use super::ctld::{
    BackfillPrediction, DaemonHook, FailurePlan, PendingInfo, QueueSnapshot, RunningInfo,
    SlurmConfig, SlurmControl, SlurmStats,
};
use super::job::{Adjustment, Job, JobId, JobSpec, JobState, StartedBy};

/// The seed's insert-based capacity profile (see module docs).
#[derive(Debug, Clone)]
pub struct NaiveProfile {
    total: u32,
    points: Vec<(Time, u32)>,
}

impl NaiveProfile {
    pub fn new(now: Time, free: u32, total: u32) -> Self {
        assert!(free <= total);
        Self { total, points: vec![(now, free)] }
    }

    pub fn from_running(
        now: Time,
        cluster: &Cluster,
        expected_end: impl Fn(u64) -> Time,
    ) -> Self {
        let mut p = Self::new(now, cluster.free(), cluster.total());
        let mut releases: Vec<(Time, u32)> = cluster
            .allocations()
            .map(|(j, n)| (expected_end(j).max(now), n))
            .collect();
        releases.sort_unstable();
        for (t, n) in releases {
            p.add_release(t, n);
        }
        p
    }

    fn start(&self) -> Time {
        self.points[0].0
    }

    fn segment_at(&self, t: Time) -> usize {
        debug_assert!(t >= self.start());
        match self.points.binary_search_by_key(&t, |&(bt, _)| bt) {
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    pub fn free_at(&self, t: Time) -> u32 {
        self.points[self.segment_at(t)].1
    }

    pub fn add_release(&mut self, t: Time, nodes: u32) {
        self.apply(t, Time::MAX, nodes as i64);
    }

    pub fn reserve(&mut self, s: Time, e: Time, nodes: u32) {
        assert!(s < e, "empty reservation [{s}, {e})");
        self.apply(s, e, -(nodes as i64));
    }

    fn apply(&mut self, s: Time, e: Time, delta: i64) {
        let s = s.max(self.start());
        if e <= s {
            return;
        }
        self.ensure_breakpoint(s);
        if e != Time::MAX {
            self.ensure_breakpoint(e);
        }
        let lo = self
            .points
            .binary_search_by_key(&s, |&(bt, _)| bt)
            .expect("breakpoint at s ensured above");
        for i in lo..self.points.len() {
            let (t, free) = self.points[i];
            if e != Time::MAX && t >= e {
                break;
            }
            let nf = free as i64 + delta;
            assert!(
                (0..=self.total as i64).contains(&nf),
                "profile capacity violated at t={t}: {free} + {delta}"
            );
            self.points[i].1 = nf as u32;
        }
    }

    fn ensure_breakpoint(&mut self, t: Time) {
        if let Err(i) = self.points.binary_search_by_key(&t, |&(bt, _)| bt) {
            let free = self.points[i - 1].1;
            self.points.insert(i, (t, free));
        }
    }

    pub fn find_earliest(&self, nodes: u32, duration: Time, after: Time) -> Time {
        assert!(nodes <= self.total, "request exceeds cluster size");
        assert!(duration >= 1);
        let after = after.max(self.start());
        let mut candidate: Option<Time> = None;
        let n = self.points.len();
        let first = self.segment_at(after);
        for i in first..n {
            let (t, free) = self.points[i];
            let seg_end = if i + 1 < n { self.points[i + 1].0 } else { Time::MAX };
            if free < nodes {
                candidate = None;
                continue;
            }
            let start = candidate.get_or_insert(t.max(after));
            if seg_end == Time::MAX || seg_end - *start >= duration {
                return *start;
            }
        }
        unreachable!("final segment is infinite");
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    Submit(JobId),
    End(JobId),
    BackfillTick,
    DaemonPoll,
    NodeFail,
    NodeDrain,
    NodeUp,
}

/// The seed scheduler, naive structures and all (see module docs).
pub struct NaiveSlurmd {
    pub cfg: SlurmConfig,
    cluster: Cluster,
    jobs: Vec<Job>,
    pending: Vec<JobId>,
    events: EventQueue<Ev>,
    scheduled_end: HashMap<JobId, Time>,
    predictions: Vec<Option<BackfillPrediction>>,
    bf_dirty: bool,
    terminal: usize,
    /// Seeded failure plan — the SAME [`FailurePlan`] machinery the
    /// optimized core uses, consumed at the same points, so failure
    /// runs stay inside the golden-equivalence contract.
    fail_plan: Option<FailurePlan>,
    /// Running jobs whose node drains when the job releases it.
    draining: BTreeSet<JobId>,
    /// Return instants of nodes currently down (one per node).
    down_until: Vec<Time>,
    pub stats: SlurmStats,
}

impl NaiveSlurmd {
    pub fn new(cfg: SlurmConfig) -> Self {
        let cluster = Cluster::new(cfg.nodes);
        let fail_plan = FailurePlan::new(&cfg.failures);
        Self {
            cfg,
            cluster,
            jobs: Vec::new(),
            pending: Vec::new(),
            events: EventQueue::new(),
            scheduled_end: HashMap::new(),
            predictions: Vec::new(),
            bf_dirty: true,
            terminal: 0,
            fail_plan,
            draining: BTreeSet::new(),
            down_until: Vec::new(),
            stats: SlurmStats::default(),
        }
    }

    pub fn submit(&mut self, spec: JobSpec) -> JobId {
        assert!(spec.submit >= 0, "negative submit time");
        let id = JobId(self.jobs.len() as u32);
        let submit = spec.submit;
        self.jobs.push(Job::new(id, spec));
        if submit <= self.events.now() {
            self.pending.push(id);
            self.bf_dirty = true;
        } else {
            self.events.push(submit, Ev::Submit(id));
        }
        id
    }

    pub fn submit_with_plan(&mut self, spec: JobSpec, plan: Option<Vec<Time>>) -> JobId {
        let id = self.submit(spec);
        if let Some(plan) = plan {
            debug_assert!(plan.windows(2).all(|w| w[0] < w[1]), "plan must be ascending");
            self.jobs[id.0 as usize].ckpt_plan = plan;
        }
        id
    }

    pub fn job(&self, id: JobId) -> &Job {
        &self.jobs[id.0 as usize]
    }

    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    pub fn into_jobs(self) -> Vec<Job> {
        self.jobs
    }

    pub fn now(&self) -> Time {
        self.events.now()
    }

    fn all_done(&self) -> bool {
        self.terminal == self.jobs.len()
    }

    pub fn run(&mut self, daemon: &mut dyn DaemonHook) {
        self.run_main_sched();
        self.events.push(0, Ev::BackfillTick);
        if let Some(p) = daemon.poll_period() {
            assert!(p > 0);
            self.events.push(p, Ev::DaemonPoll);
        }
        // Failure plan last at t=0 — the push order the optimized
        // core's `start` uses, so same-instant FIFO ties match.
        self.schedule_next_failure();

        while let Some((t, ev)) = self.events.pop() {
            self.stats.events += 1;
            match ev {
                Ev::Submit(id) => {
                    self.pending.push(id);
                    self.bf_dirty = true;
                    self.run_main_sched();
                }
                Ev::End(id) => {
                    if self.scheduled_end.get(&id) == Some(&t)
                        && self.jobs[id.0 as usize].state == JobState::Running
                    {
                        self.finish_job(id, t, None);
                        self.run_main_sched();
                    } else {
                        self.stats.stale_events += 1;
                    }
                }
                Ev::BackfillTick => {
                    if self.bf_dirty {
                        self.run_backfill(t);
                    } else {
                        self.stats.backfill_skipped += 1;
                    }
                    if !self.all_done() {
                        self.events.push(t + self.cfg.backfill_interval, Ev::BackfillTick);
                    }
                }
                Ev::DaemonPoll => {
                    daemon.on_poll(t, self);
                    if !self.all_done() {
                        if let Some(p) = daemon.poll_period() {
                            self.events.push(t + p, Ev::DaemonPoll);
                        }
                    }
                }
                Ev::NodeFail => self.handle_node_event(t, false),
                Ev::NodeDrain => self.handle_node_event(t, true),
                Ev::NodeUp => self.handle_node_up(t),
            }
            if self.all_done() && self.events.is_empty() {
                break;
            }
        }
        assert!(self.all_done(), "simulation ended with live jobs");
    }

    fn start_job(&mut self, id: JobId, t: Time, by: StartedBy) {
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Pending);
        job.state = JobState::Running;
        job.start = Some(t);
        job.started_by = Some(by);
        let end = job.actual_end(self.cfg.over_time_limit).unwrap();
        self.cluster.allocate(id.0 as u64, job.spec.nodes);
        self.scheduled_end.insert(id, end);
        self.events.push(end, Ev::End(id));
        if let Some(p) = self.predictions.get_mut(id.0 as usize) {
            *p = None;
        }
        match by {
            StartedBy::Main => self.stats.sched_main_started += 1,
            StartedBy::Backfill => self.stats.sched_backfill_started += 1,
        }
        self.bf_dirty = true;
    }

    fn finish_job(&mut self, id: JobId, t: Time, forced: Option<JobState>) {
        let grace = self.cfg.over_time_limit;
        let job = &mut self.jobs[id.0 as usize];
        debug_assert_eq!(job.state, JobState::Running);
        job.end = Some(t);
        job.state = forced.unwrap_or(if job.completes(grace) {
            JobState::Completed
        } else {
            JobState::Timeout
        });
        self.cluster.release(id.0 as u64);
        self.scheduled_end.remove(&id);
        self.terminal += 1;
        self.bf_dirty = true;
        // Drain completion: the marked node leaves service the moment
        // its job releases it (same hook as the optimized core).
        if self.fail_plan.is_some() && self.draining.remove(&id) {
            self.take_node_down(t);
        }
    }

    fn take_node_down(&mut self, t: Time) {
        self.cluster.fail_node();
        let ret = t + self.cfg.failures.drain_secs;
        self.down_until.push(ret);
        self.events.push(ret, Ev::NodeUp);
    }

    fn schedule_next_failure(&mut self) {
        let Some(plan) = &mut self.fail_plan else { return };
        let (gap, drain) = plan.next_event();
        let t = self.events.now() + gap;
        self.events.push(t, if drain { Ev::NodeDrain } else { Ev::NodeFail });
    }

    /// Mirror of the optimized core's failure handler: identical draw
    /// order, identical slot layout (busy by id-ordered running scan |
    /// already-down | idle), identical all-done early-out.
    fn handle_node_event(&mut self, t: Time, drain: bool) {
        if self.all_done() {
            return;
        }
        let total = self.cluster.total();
        let down = self.cluster.down();
        let busy = self.cluster.used();
        let u = self
            .fail_plan
            .as_mut()
            .expect("node events only exist with a live plan")
            .victim_slot(total);
        if u < busy {
            let mut acc = 0u32;
            let mut victim = None;
            for j in self.jobs.iter().filter(|j| j.state == JobState::Running) {
                acc += j.spec.nodes;
                if u < acc {
                    victim = Some(j.id);
                    break;
                }
            }
            let victim = victim.expect("busy slots are covered by running jobs");
            if drain {
                if self.draining.insert(victim) {
                    self.stats.node_drains += 1;
                }
            } else if self.cfg.failures.rekill || !self.draining.contains(&victim) {
                self.draining.remove(&victim);
                self.stats.node_failures += 1;
                self.stats.jobs_failed += 1;
                self.finish_job(victim, t, Some(JobState::NodeFailed));
                self.take_node_down(t);
                self.run_main_sched();
            }
        } else if u < busy + down {
            // Already-down node: nothing further to take out.
        } else {
            if drain {
                self.stats.node_drains += 1;
            } else {
                self.stats.node_failures += 1;
            }
            self.take_node_down(t);
            self.bf_dirty = true;
        }
        self.schedule_next_failure();
    }

    fn handle_node_up(&mut self, t: Time) {
        let pos = self
            .down_until
            .iter()
            .position(|&r| r == t)
            .expect("NodeUp matches a pending return instant");
        self.down_until.swap_remove(pos);
        self.cluster.restore_node();
        if !self.all_done() {
            self.bf_dirty = true;
            self.run_main_sched();
        }
    }

    #[allow(clippy::needless_range_loop)] // start_job needs &mut self
    fn run_main_sched(&mut self) {
        let t = self.events.now();
        let mut started = 0usize;
        for i in 0..self.pending.len() {
            let id = self.pending[i];
            let nodes = self.jobs[id.0 as usize].spec.nodes;
            if self.cluster.fits(nodes) {
                self.start_job(id, t, StartedBy::Main);
                started += 1;
            } else {
                break;
            }
        }
        if started > 0 {
            self.pending.drain(..started);
        }
    }

    /// The seed backfill pass: fresh profile, per-started-job `retain`.
    fn run_backfill(&mut self, t: Time) {
        self.stats.backfill_passes += 1;
        self.bf_dirty = false;
        let mut profile = NaiveProfile::from_running(t, &self.cluster, |j| {
            self.jobs[j as usize].expected_end().unwrap().max(t + 1)
        });
        // Down nodes re-enter the profile at their repair instants
        // (clamped imminent-future like any past-due release).
        for &ret in &self.down_until {
            profile.add_release(ret.max(t + 1), 1);
        }
        self.predictions.fill(None);
        self.predictions.resize(self.jobs.len(), None);

        let mut started: Vec<JobId> = Vec::new();
        for (examined, &id) in self.pending.iter().enumerate() {
            if examined >= self.cfg.backfill_max_jobs {
                break;
            }
            let (nodes, limit) = {
                let j = &self.jobs[id.0 as usize];
                (j.spec.nodes, j.cur_limit.max(1))
            };
            let s = profile.find_earliest(nodes, limit, t);
            let free = profile.free_at(s);
            self.predictions[id.0 as usize] =
                Some(BackfillPrediction { start: s, free_at_start: free });
            profile.reserve(s, s.saturating_add(limit), nodes);
            if s == t {
                started.push(id);
            }
        }
        for id in started {
            self.pending.retain(|&p| p != id);
            self.start_job(id, t, StartedBy::Backfill);
        }
    }

    pub fn sched_now(&mut self) {
        self.run_main_sched();
    }

    pub fn backfill_now(&mut self) {
        let t = self.events.now();
        self.run_backfill(t);
    }
}

impl SlurmControl for NaiveSlurmd {
    fn control_now(&self) -> Time {
        self.now()
    }

    fn squeue(&self) -> QueueSnapshot {
        let running = self
            .jobs
            .iter()
            .filter(|j| j.state == JobState::Running)
            .map(|j| RunningInfo {
                id: j.id,
                name: j.spec.name.clone(),
                nodes: j.spec.nodes,
                start: j.start.unwrap(),
                cur_limit: j.cur_limit,
                expected_end: j.expected_end().unwrap(),
            })
            .collect();
        let pending = self
            .pending
            .iter()
            .map(|&id| {
                let j = &self.jobs[id.0 as usize];
                PendingInfo {
                    id,
                    nodes: j.spec.nodes,
                    cur_limit: j.cur_limit,
                    prediction: self.predictions.get(id.0 as usize).copied().flatten(),
                }
            })
            .collect();
        QueueSnapshot { now: self.now(), running, pending }
    }

    fn read_ckpt_reports(&self, id: JobId) -> Vec<Time> {
        let j = &self.jobs[id.0 as usize];
        let Some(start) = j.start else { return Vec::new() };
        let horizon = j.end.unwrap_or(Time::MAX).min(self.now());
        j.ckpt_plan
            .iter()
            .map(|&o| start + o)
            .take_while(|&ts| ts <= horizon)
            .collect()
    }

    fn scontrol_update_limit(&mut self, id: JobId, new_limit: Time) -> Result<(), String> {
        let now = self.now();
        let grace = self.cfg.over_time_limit;
        let job = &mut self.jobs[id.0 as usize];
        if job.state != JobState::Running {
            return Err(format!("{id}: not running"));
        }
        let start = job.start.unwrap();
        if start + new_limit < now {
            return Err(format!("{id}: new limit {new_limit}s ends in the past"));
        }
        job.cur_limit = new_limit;
        let end = job.actual_end(grace).unwrap().max(now);
        self.scheduled_end.insert(id, end);
        self.events.push(end, Ev::End(id));
        self.stats.scontrol_updates += 1;
        self.bf_dirty = true;
        Ok(())
    }

    fn scancel(&mut self, id: JobId) -> Result<(), String> {
        let now = self.now();
        if self.jobs[id.0 as usize].state != JobState::Running {
            return Err(format!("{id}: not running"));
        }
        self.stats.scancels += 1;
        self.finish_job(id, now, Some(JobState::Cancelled));
        self.run_main_sched();
        Ok(())
    }

    fn mark_adjustment(&mut self, id: JobId, adj: Adjustment) {
        self.jobs[id.0 as usize].adjustment = Some(adj);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::NoDaemon;

    #[test]
    fn naive_profile_matches_seed_behavior() {
        let mut p = NaiveProfile::new(0, 10, 10);
        p.reserve(50, 150, 4);
        assert_eq!(p.free_at(0), 10);
        assert_eq!(p.free_at(50), 6);
        assert_eq!(p.free_at(150), 10);
        assert_eq!(p.find_earliest(5, 150, 0), 150);
    }

    #[test]
    fn naive_sim_runs_the_canonical_job() {
        let mut s = NaiveSlurmd::new(SlurmConfig { nodes: 4, ..Default::default() });
        let id = s.submit(JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420));
        s.run(&mut NoDaemon);
        assert_eq!(s.job(id).state, JobState::Timeout);
        assert_eq!(s.job(id).end, Some(1440));
    }

    #[test]
    fn naive_and_optimized_agree_under_failures() {
        use crate::slurm::{FailureConfig, Slurmd};
        let cfg = SlurmConfig {
            nodes: 4,
            failures: FailureConfig {
                mtbf: 200,
                drain_frac: 0.5,
                drain_secs: 90,
                ..Default::default()
            },
            ..Default::default()
        };
        let mut a = NaiveSlurmd::new(cfg.clone());
        let mut b = Slurmd::new(cfg);
        for i in 0..10u32 {
            let spec = JobSpec::new(&format!("j{i}"), 300, 250 + 10 * i as i64, 1 + (i % 3));
            a.submit(spec.clone());
            b.submit(spec);
        }
        a.run(&mut NoDaemon);
        b.run(&mut NoDaemon);
        assert_eq!(a.jobs(), b.jobs());
        assert_eq!(a.stats, b.stats);
    }
}
