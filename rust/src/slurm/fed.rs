//! Sharded multi-cluster federation with a deterministic cross-shard
//! merge (§Perf).
//!
//! A federation of `S` clusters runs `S` independent [`Slurmd`] shards
//! — each with its own [`crate::simtime::EventQueue`], its own
//! capacity profile, and its own autonomy daemon — over a round-robin
//! partition of one master workload. Shards share no mutable state, so
//! the federation simulates millions of jobs with per-shard event
//! queues and dense tables bounded by each shard's *live id window*
//! (the retirement watermark, [`crate::jobtable`]), not the total id
//! space.
//!
//! ## JobId scheme
//!
//! Global (master) ids are the positions in the master spec list;
//! round-robin placement makes the mapping pure arithmetic, no lookup
//! tables:
//!
//! ```text
//! master id m  →  shard m % S, local id m / S
//! shard k, local j  →  master id j·S + k
//! ```
//!
//! Each shard simulates under its dense *local* ids (so its tables
//! stay dense and its retirement watermark is a simple prefix);
//! [`reinterleave`] rewrites ids back to master order when the
//! federation's job records are recombined.
//!
//! ## Deterministic merge
//!
//! [`FedDrive::Merged`] interleaves the shards' event loops through
//! the step API ([`Slurmd::next_step_time`] / [`Slurmd::step`]): at
//! every iteration the shard with the minimal `(time, shard, seq)` key
//! steps once. `seq` is the shard-local [`EventQueue`] sequence number
//! — it orders same-instant work *within* a shard (including the
//! on-demand backfill chain's virtual slot, which carries its
//! push-point watermark seq) — and the shard index breaks cross-shard
//! same-instant ties, exactly the discipline the single-queue
//! seq-watermark uses for same-instant entries. The merge is
//! **step-granular**, not event-granular: one step batches a shard's
//! due backfill-chain work with one popped event. That coarseness is
//! sound *because* shards share no mutable state — any interleaving of
//! whole steps yields bit-identical per-shard outcomes, and the
//! deterministic key makes the chosen interleaving reproducible. The
//! federation suite pins `Merged` ≡ [`FedDrive::Sharded`] (each shard
//! run serially to completion) for shard counts {1, 2, 4, 7}, and the
//! 1-shard federation ≡ the plain single-queue run.
//!
//! [`EventQueue`]: crate::simtime::EventQueue

use crate::daemon::{Autonomy, DaemonConfig, DaemonStats};
use crate::policy::PolicySpec;
use crate::simtime::Time;

use super::ctld::{SlurmConfig, SlurmStats, Slurmd};
use super::job::{Job, JobId, JobSpec};

/// How [`run_federation`] drives its shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FedDrive {
    /// Interleave all shards deterministically by `(time, shard, seq)`
    /// through the step API — the federation's production mode.
    Merged,
    /// Run each shard serially to completion — the reference the
    /// merged interleaving is pinned bit-identical to.
    Sharded,
}

/// Recombined outcome of a federation run: master-ordered job records
/// plus summed per-shard counters and perf metrics.
#[derive(Debug, Clone)]
pub struct FedOutcome {
    /// Job records in master id order (ids rewritten from shard-local
    /// to master by [`reinterleave`]).
    pub jobs: Vec<Job>,
    pub stats: SlurmStats,
    pub daemon_stats: DaemonStats,
    /// Summed high-water resident bytes of every shard's dense per-job
    /// tables (control plane + daemon + report book).
    pub peak_table_bytes: usize,
    /// Summed ids below the shards' retirement watermarks.
    pub retired: u64,
}

/// One shard's completed run, before recombination.
#[derive(Debug)]
pub struct ShardRun {
    pub jobs: Vec<Job>,
    pub stats: SlurmStats,
    pub daemon_stats: DaemonStats,
    pub peak_table_bytes: usize,
    pub retired: u64,
}

/// Round-robin partition of the master spec list: master id `m` goes
/// to shard `m % shards` (see the module docs' id scheme). Relative
/// submit order — and thus each shard's local FIFO priority order — is
/// preserved.
pub fn partition(specs: &[JobSpec], shards: usize) -> Vec<Vec<JobSpec>> {
    assert!(shards > 0, "federation needs at least one shard");
    let mut out: Vec<Vec<JobSpec>> =
        (0..shards).map(|_| Vec::with_capacity(specs.len() / shards + 1)).collect();
    for (m, s) in specs.iter().enumerate() {
        out[m % shards].push(s.clone());
    }
    out
}

/// Inverse of [`partition`] on job records: merge per-shard outputs
/// back into master id order, rewriting each record's shard-local id
/// to its master id.
pub fn reinterleave(per_shard: Vec<Vec<Job>>) -> Vec<Job> {
    let s = per_shard.len();
    let total: usize = per_shard.iter().map(Vec::len).sum();
    let mut its: Vec<_> = per_shard.into_iter().map(|v| v.into_iter()).collect();
    let mut out = Vec::with_capacity(total);
    for m in 0..total {
        let mut j = its[m % s].next().expect("round-robin partition is balanced");
        j.id = JobId(m as u32);
        out.push(j);
    }
    out
}

/// Run one shard serially to completion (the unit of work the
/// federation sweep pool steals; also the [`FedDrive::Sharded`]
/// reference path). Native decision engine only: engines are not
/// cloneable across shards, and the native oracle is bit-identical to
/// the PJRT path by the runtime's own golden gate.
pub fn run_shard(
    part: &[JobSpec],
    slurm_cfg: &SlurmConfig,
    policy: &PolicySpec,
    daemon_cfg: &DaemonConfig,
) -> ShardRun {
    let mut sim = Slurmd::new(slurm_cfg.clone());
    for s in part {
        sim.submit(s.clone());
    }
    let mut daemon = Autonomy::native(policy.clone(), daemon_cfg.clone());
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    let peak = sim.peak_table_bytes() + daemon.peak_table_bytes();
    let retired = sim.jobs_retired();
    ShardRun { jobs: sim.into_jobs(), stats, daemon_stats: daemon.stats, peak_table_bytes: peak, retired }
}

/// Recombine completed shard runs (in shard order) into one
/// [`FedOutcome`]: reinterleave the job records, sum the counters.
pub fn recombine(runs: Vec<ShardRun>) -> FedOutcome {
    let mut stats = SlurmStats::default();
    let mut daemon_stats = DaemonStats::default();
    let mut peak_table_bytes = 0usize;
    let mut retired = 0u64;
    let mut per_shard = Vec::with_capacity(runs.len());
    for r in runs {
        stats.absorb(&r.stats);
        daemon_stats.absorb(&r.daemon_stats);
        peak_table_bytes += r.peak_table_bytes;
        retired += r.retired;
        per_shard.push(r.jobs);
    }
    FedOutcome { jobs: reinterleave(per_shard), stats, daemon_stats, peak_table_bytes, retired }
}

/// Simulate `specs` as a federation of `shards` clusters (each sized
/// by `slurm_cfg`, each with its own daemon running `policy`) and
/// recombine the result. See the module docs for the id scheme and the
/// merge discipline.
pub fn run_federation(
    specs: &[JobSpec],
    shards: usize,
    slurm_cfg: &SlurmConfig,
    policy: &PolicySpec,
    daemon_cfg: &DaemonConfig,
    drive: FedDrive,
) -> FedOutcome {
    assert!(shards > 0, "federation needs at least one shard");
    if let FedDrive::Sharded = drive {
        let runs = partition(specs, shards)
            .iter()
            .map(|part| run_shard(part, slurm_cfg, policy, daemon_cfg))
            .collect();
        return recombine(runs);
    }
    // Merged drive: start every shard, then repeatedly step the shard
    // holding the minimal (time, shard, seq) key.
    let mut sims: Vec<Slurmd> = Vec::with_capacity(shards);
    let mut daemons: Vec<Autonomy> = Vec::with_capacity(shards);
    for part in &partition(specs, shards) {
        let mut sim = Slurmd::new(slurm_cfg.clone());
        for s in part {
            sim.submit(s.clone());
        }
        let mut daemon = Autonomy::native(policy.clone(), daemon_cfg.clone());
        sim.start(&mut daemon);
        sims.push(sim);
        daemons.push(daemon);
    }
    let mut live = vec![true; shards];
    let mut remaining = shards;
    while remaining > 0 {
        let mut best: Option<(Time, usize)> = None;
        for (k, sim) in sims.iter().enumerate() {
            if !live[k] {
                continue;
            }
            // A keyless shard still owes one final drain step (which
            // observes completion and returns false): force it to the
            // front so `live` converges.
            let t = sim.next_step_time().map_or(Time::MIN, |(t, _)| t);
            // Strictly-less keeps the earliest shard on same-instant
            // ties — the shard component of the (time, shard, seq)
            // key; seq already ordered the work within its shard.
            if best.is_none_or(|(bt, _)| t < bt) {
                best = Some((t, k));
            }
        }
        let (_, k) = best.expect("live shards always yield a merge key");
        if !sims[k].step(&mut daemons[k]) {
            live[k] = false;
            remaining -= 1;
        }
    }
    let runs = sims
        .into_iter()
        .zip(daemons)
        .map(|(sim, daemon)| {
            assert!(sim.all_done(), "federation shard ended with live jobs");
            let stats = sim.stats.clone();
            let peak = sim.peak_table_bytes() + daemon.peak_table_bytes();
            let retired = sim.jobs_retired();
            ShardRun {
                jobs: sim.into_jobs(),
                stats,
                daemon_stats: daemon.stats,
                peak_table_bytes: peak,
                retired,
            }
        })
        .collect();
    recombine(runs)
}

/// Dense-table bytes one job id would occupy with retirement disabled
/// (every table grown, nothing reclaimed): the per-id footprint the
/// federation BENCH regime multiplies by total ids to gate
/// `fed<i>_peak_table_bytes` sublinear.
pub fn unretired_bytes_per_id() -> usize {
    use std::mem::size_of;
    // Slurmd side tables: scheduled_end, bf_release, predictions.
    size_of::<Option<Time>>() * 2
        + size_of::<Option<super::ctld::BackfillPrediction>>()
        // Autonomy tables: ext_count, ext_secs, rejected, acted,
        // report_cursor, names, in_tracked, row_cache, running_mark.
        + size_of::<u32>() * 2
        + size_of::<Time>()
        + size_of::<bool>() * 2
        + size_of::<usize>()
        + size_of::<Option<std::sync::Arc<str>>>()
        + size_of::<Option<(usize, Time, f32)>>()
        + size_of::<u64>()
        // ReportBook per-id history slot.
        + size_of::<Option<crate::ckpt::History>>()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(i: usize) -> JobSpec {
        JobSpec::new(&format!("j{i}"), 600 + (i as i64 % 7) * 60, 900, 1 + (i as u32 % 3))
    }

    #[test]
    fn partition_is_round_robin_and_reinterleave_inverts_it() {
        let specs: Vec<JobSpec> = (0..11).map(spec).collect();
        let parts = partition(&specs, 4);
        assert_eq!(parts.iter().map(Vec::len).collect::<Vec<_>>(), vec![3, 3, 3, 2]);
        assert_eq!(parts[1][2].name.as_ref(), "j9", "master 9 → shard 1 local 2");
        // Round-trip through fake per-shard job records.
        let per_shard: Vec<Vec<Job>> = parts
            .iter()
            .map(|p| {
                p.iter()
                    .enumerate()
                    .map(|(j, s)| Job::new(JobId(j as u32), s.clone()))
                    .collect()
            })
            .collect();
        let merged = reinterleave(per_shard);
        assert_eq!(merged.len(), specs.len());
        for (m, j) in merged.iter().enumerate() {
            assert_eq!(j.id, JobId(m as u32), "ids rewritten to master order");
            assert_eq!(j.spec.name, specs[m].name, "record order matches the master list");
        }
    }

    #[test]
    fn one_shard_federation_is_the_identity_partition() {
        let specs: Vec<JobSpec> = (0..5).map(spec).collect();
        let parts = partition(&specs, 1);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].len(), 5);
    }

    #[test]
    fn per_id_footprint_is_plausible() {
        let b = unretired_bytes_per_id();
        // Sanity band: a few machine words per table, ten-ish tables.
        assert!(b > 50 && b < 400, "unretired_bytes_per_id = {b}");
    }
}
