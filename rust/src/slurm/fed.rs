//! Sharded multi-cluster federation with a deterministic cross-shard
//! merge and a multi-core parallel drive (§Perf).
//!
//! A federation of `S` clusters runs `S` independent [`Slurmd`] shards
//! — each with its own [`crate::simtime::EventQueue`], its own
//! capacity profile, and its own autonomy daemon — over a round-robin
//! partition of one master workload. Shards share no mutable state, so
//! the federation simulates millions of jobs with per-shard event
//! queues and dense tables bounded by each shard's *live id window*
//! (the retirement watermark, [`crate::jobtable`]), not the total id
//! space.
//!
//! ## JobId scheme
//!
//! Global (master) ids are the positions in the master spec list;
//! round-robin placement makes the mapping pure arithmetic, no lookup
//! tables:
//!
//! ```text
//! master id m  →  shard m % S, local id m / S
//! shard k, local j  →  master id j·S + k
//! ```
//!
//! Each shard simulates under its dense *local* ids (so its tables
//! stay dense and its retirement watermark is a simple prefix);
//! [`reinterleave`] rewrites ids back to master order when the
//! federation's job records are recombined.
//!
//! ## Deterministic merge
//!
//! [`FedDrive::Merged`] interleaves the shards' event loops through
//! the step API ([`Slurmd::next_step_time`] / [`Slurmd::step`]): at
//! every iteration the shard with the minimal `(time, shard, seq)` key
//! steps once. `seq` is the shard-local [`EventQueue`] sequence number
//! — it orders same-instant work *within* a shard (including the
//! on-demand backfill chain's virtual slot, which carries its
//! push-point watermark seq) — and the shard index breaks cross-shard
//! same-instant ties, exactly the discipline the single-queue
//! seq-watermark uses for same-instant entries. The merge is
//! **step-granular**, not event-granular: one step batches a shard's
//! due backfill-chain work with one popped event. That coarseness is
//! sound *because* shards share no mutable state — any interleaving of
//! whole steps yields bit-identical per-shard outcomes, and the
//! deterministic key makes the chosen interleaving reproducible.
//!
//! ## Parallel drive
//!
//! The same no-shared-state property makes the federation
//! embarrassingly parallel: [`FedDrive::Parallel`] drives each shard
//! to completion on a worker thread (`std::thread::scope` — the crate
//! is dependency-free, no rayon). Workers claim shard indices off a
//! shared atomic cursor with a per-worker AIMD claim width
//! ([`ClaimWidth`], the same controller the work-stealing sweep pool
//! uses), so `S ≫ cores` oversubscription degrades gracefully: tiny
//! shards amortize cursor contention into wide claims while a slow
//! claim halves the width so long shards spread back across the pool.
//! Every worker constructs its shard's [`Slurmd`] *and* its
//! [`Autonomy`] daemon — and therefore the daemon's `TickScratch` and
//! arena pools — on its own thread, so there is no cross-shard
//! allocator or cache-line contention on the hot path. (That is also
//! forced by design: [`Autonomy`] is deliberately not `Send` — its
//! engine box is unbounded and `SharedEngine` is `Rc`-based — so
//! daemons *cannot* migrate between threads.) Completed [`ShardRun`]s
//! move back to the caller (`Send`, asserted at compile time below)
//! and recombine **in shard order** through the same deterministic
//! [`reinterleave`] path as every other drive, so the parallel drive
//! changes wall clock only — never job records, [`SlurmStats`], or
//! deterministic [`DaemonStats`]. A panicking shard propagates out of
//! the thread scope as a panic from [`run_federation`]: the run
//! errors, it never deadlocks or recombines a partial result.
//!
//! The federation suite pins `Parallel` ≡ `Merged` ≡
//! [`FedDrive::Sharded`] (each shard run serially to completion)
//! three-way for shard counts {1, 2, 4, 7}, under `S ≫ cores`
//! oversubscription, and with fault injection inside the parallel run;
//! the 1-shard federation ≡ the plain single-queue run.
//!
//! [`EventQueue`]: crate::simtime::EventQueue
//! [`Autonomy`]: crate::daemon::Autonomy
//! [`SharedEngine`]: crate::analytics::SharedEngine

use std::sync::Mutex;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::daemon::{Autonomy, DaemonConfig, DaemonStats};
use crate::policy::PolicySpec;
use crate::simtime::Time;

use super::ctld::{SlurmConfig, SlurmStats, Slurmd};
use super::job::{Job, JobId, JobSpec};

// Compile-time thread-safety audit for the parallel drive: shard
// inputs are shared by reference across workers (`Sync`) and completed
// runs move back to the recombining thread (`Send`). `Autonomy` is
// deliberately neither — see the module docs — which is why every
// worker constructs its daemon locally.
const _: () = {
    const fn send<T: Send>() {}
    const fn sync<T: Sync>() {}
    send::<Slurmd>();
    send::<ShardRun>();
    send::<FedOutcome>();
    sync::<JobSpec>();
    sync::<SlurmConfig>();
    sync::<PolicySpec>();
    sync::<DaemonConfig>();
};

/// How [`run_federation`] drives its shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FedDrive {
    /// Interleave all shards deterministically by `(time, shard, seq)`
    /// through the step API on one thread.
    Merged,
    /// Run each shard serially to completion — the reference the other
    /// drives are pinned bit-identical to.
    Sharded,
    /// Drive each shard to completion on its own worker thread and
    /// recombine in shard order — the federation's production mode
    /// (bit-identical to the other two; only wall clock changes).
    /// `threads == 0` means auto: [`default_fed_threads`].
    Parallel {
        /// Worker-thread count (clamped to the shard count; 0 = auto).
        threads: usize,
    },
}

/// Default parallel-drive worker count: the machine's available
/// parallelism, clamped to the shard count (extra workers would only
/// spin on an empty cursor).
pub fn default_fed_threads(shards: usize) -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get()).min(shards.max(1))
}

/// A claimed batch longer than this halves the worker's claim width
/// (the AIMD decrease); faster batches grow it additively.
pub const AIMD_SLOW_BATCH: Duration = Duration::from_millis(250);
/// Claim-width ceiling — bounds how much work a single claim can
/// serialize onto one worker.
pub const AIMD_WIDTH_CEILING: usize = 16;

/// Per-worker AIMD claim-width governor for atomic-cursor work queues:
/// additive +1 after a fast batch (amortizing cursor contention on
/// tiny units), halve after a slow one (so long units spread back
/// across the pool). Used by the parallel federation drive here and by
/// the work-stealing shard × cell sweep pool ([`crate::sweep`]).
#[derive(Debug, Clone, Copy)]
pub struct ClaimWidth {
    width: usize,
}

impl ClaimWidth {
    pub fn new() -> Self {
        Self { width: 1 }
    }

    /// Units to claim on the next `fetch_add`.
    pub fn get(&self) -> usize {
        self.width
    }

    /// Feed back the wall time of the batch just finished.
    pub fn observe(&mut self, batch_wall: Duration) {
        self.width = if batch_wall > AIMD_SLOW_BATCH {
            (self.width / 2).max(1)
        } else {
            (self.width + 1).min(AIMD_WIDTH_CEILING)
        };
    }
}

impl Default for ClaimWidth {
    fn default() -> Self {
        Self::new()
    }
}

/// Recombined outcome of a federation run: master-ordered job records
/// plus summed per-shard counters and perf metrics.
#[derive(Debug, Clone)]
pub struct FedOutcome {
    /// Job records in master id order (ids rewritten from shard-local
    /// to master by [`reinterleave`]).
    pub jobs: Vec<Job>,
    pub stats: SlurmStats,
    pub daemon_stats: DaemonStats,
    /// Summed high-water resident bytes of every shard's dense per-job
    /// tables (control plane + daemon + report book).
    pub peak_table_bytes: usize,
    /// Summed ids below the shards' retirement watermarks.
    pub retired: u64,
    /// Nanoseconds spent *driving* shards (summed per-shard walls for
    /// the sharded/parallel drives, so the figure is thread-count
    /// independent; merge-loop elapsed for the merged drive). The
    /// throughput denominator — recombination is metered separately.
    pub drive_nanos: u64,
    /// Nanoseconds spent recombining ([`recombine`]: counter sums +
    /// the zero-copy reinterleave).
    pub recombine_nanos: u64,
}

/// One shard's completed run, before recombination.
#[derive(Debug)]
pub struct ShardRun {
    pub jobs: Vec<Job>,
    pub stats: SlurmStats,
    pub daemon_stats: DaemonStats,
    pub peak_table_bytes: usize,
    pub retired: u64,
    /// Wall nanoseconds this shard took to drive (simulation only, not
    /// recombination); summed into [`FedOutcome::drive_nanos`].
    pub drive_nanos: u64,
}

/// Round-robin partition of the master spec list: master id `m` goes
/// to shard `m % shards` (see the module docs' id scheme). Relative
/// submit order — and thus each shard's local FIFO priority order — is
/// preserved.
pub fn partition(specs: &[JobSpec], shards: usize) -> Vec<Vec<JobSpec>> {
    assert!(shards > 0, "federation needs at least one shard");
    let mut out: Vec<Vec<JobSpec>> =
        (0..shards).map(|_| Vec::with_capacity(specs.len() / shards + 1)).collect();
    for (m, s) in specs.iter().enumerate() {
        out[m % shards].push(s.clone());
    }
    out
}

/// Inverse of [`partition`] on job records: merge per-shard outputs
/// back into master id order, rewriting each record's shard-local id
/// to its master id.
///
/// Zero-copy: the master vector is pre-sized once and every record is
/// moved directly into its master slot `j·S + k` — one strided pass
/// per shard, no per-record iterator juggling and no intermediate
/// collections (§Perf; this is the recombination path every drive
/// funnels through, including the parallel one).
pub fn reinterleave(per_shard: Vec<Vec<Job>>) -> Vec<Job> {
    let s = per_shard.len();
    let total: usize = per_shard.iter().map(Vec::len).sum();
    // Safety precondition, checked up front: shard `k` must hold
    // exactly the master ids {m : m % s == k}, i.e. ⌈(total − k) / s⌉
    // records — the invariant `partition` establishes.
    for (k, v) in per_shard.iter().enumerate() {
        assert_eq!(
            v.len(),
            (total + s - k - 1) / s,
            "round-robin partition is balanced (shard {k})"
        );
    }
    let mut out: Vec<Job> = Vec::with_capacity(total);
    let spare = out.spare_capacity_mut();
    for (k, shard_jobs) in per_shard.into_iter().enumerate() {
        for (j, mut job) in shard_jobs.into_iter().enumerate() {
            let m = j * s + k;
            job.id = JobId(m as u32);
            spare[m].write(job);
        }
    }
    // SAFETY: the length asserts above guarantee the write targets
    // {j·s + k : j < len(shard k), k < s} cover 0..total exactly once
    // (the map (j, k) ↦ j·s + k is injective for k < s), so every slot
    // below `total` is initialized exactly once and nothing is
    // double-dropped.
    unsafe { out.set_len(total) };
    out
}

/// Run one shard serially to completion (the unit of work the
/// federation sweep pool steals and the parallel drive's workers
/// claim; also the [`FedDrive::Sharded`] reference path). Native
/// decision engine only: engines are not cloneable across shards, and
/// the native oracle is bit-identical to the PJRT path by the
/// runtime's own golden gate.
pub fn run_shard(
    part: &[JobSpec],
    slurm_cfg: &SlurmConfig,
    policy: &PolicySpec,
    daemon_cfg: &DaemonConfig,
) -> ShardRun {
    let t0 = Instant::now();
    let mut sim = Slurmd::new(slurm_cfg.clone());
    for s in part {
        sim.submit(s.clone());
    }
    let mut daemon = Autonomy::native(policy.clone(), daemon_cfg.clone());
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    let peak = sim.peak_table_bytes() + daemon.peak_table_bytes();
    let retired = sim.jobs_retired();
    ShardRun {
        jobs: sim.into_jobs(),
        stats,
        daemon_stats: daemon.stats,
        peak_table_bytes: peak,
        retired,
        drive_nanos: t0.elapsed().as_nanos() as u64,
    }
}

/// Recombine completed shard runs (in shard order) into one
/// [`FedOutcome`]: reinterleave the job records, sum the counters.
/// Times itself into [`FedOutcome::recombine_nanos`] and sums the
/// runs' [`ShardRun::drive_nanos`] into [`FedOutcome::drive_nanos`].
pub fn recombine(runs: Vec<ShardRun>) -> FedOutcome {
    let t0 = Instant::now();
    let mut stats = SlurmStats::default();
    let mut daemon_stats = DaemonStats::default();
    let mut peak_table_bytes = 0usize;
    let mut retired = 0u64;
    let mut drive_nanos = 0u64;
    let mut per_shard = Vec::with_capacity(runs.len());
    for r in runs {
        stats.absorb(&r.stats);
        daemon_stats.absorb(&r.daemon_stats);
        peak_table_bytes += r.peak_table_bytes;
        retired += r.retired;
        drive_nanos += r.drive_nanos;
        per_shard.push(r.jobs);
    }
    let jobs = reinterleave(per_shard);
    FedOutcome {
        jobs,
        stats,
        daemon_stats,
        peak_table_bytes,
        retired,
        drive_nanos,
        recombine_nanos: t0.elapsed().as_nanos() as u64,
    }
}

/// Drive `shards` units of shard work on `threads` worker threads
/// (clamped to the shard count), returning the completed runs in shard
/// order. The work queue is a shared atomic cursor batch-claimed with
/// the per-worker [`ClaimWidth`] governor, so `shards ≫ threads`
/// oversubscription degrades gracefully.
///
/// `run(k)` is called exactly once per shard index, from whichever
/// worker claims it; it builds all per-shard state (simulator, daemon,
/// scratch pools) thread-locally. A panicking `run` propagates out of
/// the thread scope as a panic from this function once the surviving
/// workers drain — the caller never sees a partial result and never
/// deadlocks. Exposed (not just an internal of [`run_federation`]) so
/// the hostility suite can inject faulty or panicking shard bodies
/// into a genuinely parallel drive.
pub fn drive_shards_parallel<F>(shards: usize, threads: usize, run: F) -> Vec<ShardRun>
where
    F: Fn(usize) -> ShardRun + Sync,
{
    let threads = threads.max(1).min(shards.max(1));
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<ShardRun>>> = (0..shards).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                let mut width = ClaimWidth::new();
                loop {
                    let start = next.fetch_add(width.get(), Ordering::Relaxed);
                    if start >= shards {
                        break;
                    }
                    let end = (start + width.get()).min(shards);
                    let t0 = Instant::now();
                    for k in start..end {
                        *slots[k].lock().unwrap() = Some(run(k));
                    }
                    width.observe(t0.elapsed());
                }
            });
        }
    });
    slots.into_iter().map(|m| m.into_inner().unwrap().expect("every shard ran")).collect()
}

/// Simulate `specs` as a federation of `shards` clusters (each sized
/// by `slurm_cfg`, each with its own daemon running `policy`) and
/// recombine the result. See the module docs for the id scheme, the
/// merge discipline, and the parallel drive.
pub fn run_federation(
    specs: &[JobSpec],
    shards: usize,
    slurm_cfg: &SlurmConfig,
    policy: &PolicySpec,
    daemon_cfg: &DaemonConfig,
    drive: FedDrive,
) -> FedOutcome {
    assert!(shards > 0, "federation needs at least one shard");
    match drive {
        FedDrive::Sharded => {
            let runs = partition(specs, shards)
                .iter()
                .map(|part| run_shard(part, slurm_cfg, policy, daemon_cfg))
                .collect();
            recombine(runs)
        }
        FedDrive::Parallel { threads } => {
            let threads = if threads == 0 { default_fed_threads(shards) } else { threads };
            let parts = partition(specs, shards);
            let runs = drive_shards_parallel(shards, threads, |k| {
                run_shard(&parts[k], slurm_cfg, policy, daemon_cfg)
            });
            recombine(runs)
        }
        FedDrive::Merged => run_federation_merged(specs, shards, slurm_cfg, policy, daemon_cfg),
    }
}

/// The single-threaded deterministic merge: start every shard, then
/// repeatedly step the shard holding the minimal `(time, shard, seq)`
/// key.
fn run_federation_merged(
    specs: &[JobSpec],
    shards: usize,
    slurm_cfg: &SlurmConfig,
    policy: &PolicySpec,
    daemon_cfg: &DaemonConfig,
) -> FedOutcome {
    let t0 = Instant::now();
    let mut sims: Vec<Slurmd> = Vec::with_capacity(shards);
    let mut daemons: Vec<Autonomy> = Vec::with_capacity(shards);
    for part in &partition(specs, shards) {
        let mut sim = Slurmd::new(slurm_cfg.clone());
        for s in part {
            sim.submit(s.clone());
        }
        let mut daemon = Autonomy::native(policy.clone(), daemon_cfg.clone());
        sim.start(&mut daemon);
        sims.push(sim);
        daemons.push(daemon);
    }
    let mut live = vec![true; shards];
    let mut remaining = shards;
    while remaining > 0 {
        let mut best: Option<(Time, usize)> = None;
        for (k, sim) in sims.iter().enumerate() {
            if !live[k] {
                continue;
            }
            // A keyless shard still owes one final drain step (which
            // observes completion and returns false): force it to the
            // front so `live` converges.
            let t = sim.next_step_time().map_or(Time::MIN, |(t, _)| t);
            // Strictly-less keeps the earliest shard on same-instant
            // ties — the shard component of the (time, shard, seq)
            // key; seq already ordered the work within its shard.
            if best.is_none_or(|(bt, _)| t < bt) {
                best = Some((t, k));
            }
        }
        let (_, k) = best.expect("live shards always yield a merge key");
        if !sims[k].step(&mut daemons[k]) {
            live[k] = false;
            remaining -= 1;
        }
    }
    let drive_nanos = t0.elapsed().as_nanos() as u64;
    let runs = sims
        .into_iter()
        .zip(daemons)
        .map(|(sim, daemon)| {
            assert!(sim.all_done(), "federation shard ended with live jobs");
            let stats = sim.stats.clone();
            let peak = sim.peak_table_bytes() + daemon.peak_table_bytes();
            let retired = sim.jobs_retired();
            ShardRun {
                jobs: sim.into_jobs(),
                stats,
                daemon_stats: daemon.stats,
                peak_table_bytes: peak,
                retired,
                // The merge interleaves shards on one thread; per-shard
                // attribution is meaningless, so the whole loop's wall
                // is patched onto the outcome below.
                drive_nanos: 0,
            }
        })
        .collect();
    let mut out = recombine(runs);
    out.drive_nanos = drive_nanos;
    out
}

/// Dense-table bytes one job id would occupy with retirement disabled
/// (every table grown, nothing reclaimed): the per-id footprint the
/// federation BENCH regime multiplies by total ids to gate
/// `fed<i>_peak_table_bytes` sublinear.
pub fn unretired_bytes_per_id() -> usize {
    use std::mem::size_of;
    // Slurmd side tables: scheduled_end, bf_release, predictions.
    size_of::<Option<Time>>() * 2
        + size_of::<Option<super::ctld::BackfillPrediction>>()
        // Autonomy tables: ext_count, ext_secs, rejected, acted,
        // report_cursor, names, in_tracked, row_cache, running_mark.
        + size_of::<u32>() * 2
        + size_of::<Time>()
        + size_of::<bool>() * 2
        + size_of::<usize>()
        + size_of::<Option<std::sync::Arc<str>>>()
        + size_of::<Option<(usize, Time, f32)>>()
        + size_of::<u64>()
        // ReportBook per-id history slot.
        + size_of::<Option<crate::ckpt::History>>()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(i: usize) -> JobSpec {
        JobSpec::new(&format!("j{i}"), 600 + (i as i64 % 7) * 60, 900, 1 + (i as u32 % 3))
    }

    #[test]
    fn partition_is_round_robin_and_reinterleave_inverts_it() {
        let specs: Vec<JobSpec> = (0..11).map(spec).collect();
        let parts = partition(&specs, 4);
        assert_eq!(parts.iter().map(Vec::len).collect::<Vec<_>>(), vec![3, 3, 3, 2]);
        assert_eq!(parts[1][2].name.as_ref(), "j9", "master 9 → shard 1 local 2");
        // Round-trip through fake per-shard job records.
        let per_shard: Vec<Vec<Job>> = parts
            .iter()
            .map(|p| {
                p.iter()
                    .enumerate()
                    .map(|(j, s)| Job::new(JobId(j as u32), s.clone()))
                    .collect()
            })
            .collect();
        let merged = reinterleave(per_shard);
        assert_eq!(merged.len(), specs.len());
        for (m, j) in merged.iter().enumerate() {
            assert_eq!(j.id, JobId(m as u32), "ids rewritten to master order");
            assert_eq!(j.spec.name, specs[m].name, "record order matches the master list");
        }
    }

    #[test]
    #[should_panic(expected = "round-robin partition is balanced")]
    fn reinterleave_rejects_an_unbalanced_partition() {
        // Shard 0 must hold master id 0; handing its record to shard 1
        // violates the round-robin invariant the direct-write
        // recombination relies on, and must fail loudly up front.
        let job = Job::new(JobId(0), spec(0));
        reinterleave(vec![Vec::new(), vec![job]]);
    }

    #[test]
    fn one_shard_federation_is_the_identity_partition() {
        let specs: Vec<JobSpec> = (0..5).map(spec).collect();
        let parts = partition(&specs, 1);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].len(), 5);
    }

    #[test]
    fn claim_width_is_aimd() {
        let mut w = ClaimWidth::new();
        assert_eq!(w.get(), 1);
        for expect in [2, 3, 4] {
            w.observe(Duration::from_millis(1));
            assert_eq!(w.get(), expect, "additive increase");
        }
        w.observe(AIMD_SLOW_BATCH + Duration::from_millis(1));
        assert_eq!(w.get(), 2, "multiplicative decrease");
        for _ in 0..100 {
            w.observe(Duration::ZERO);
        }
        assert_eq!(w.get(), AIMD_WIDTH_CEILING, "ceiling bounds a claim");
        for _ in 0..10 {
            w.observe(Duration::from_secs(1));
        }
        assert_eq!(w.get(), 1, "floor is one unit");
    }

    #[test]
    fn default_fed_threads_clamps_to_the_shard_count() {
        assert_eq!(default_fed_threads(1), 1);
        assert!(default_fed_threads(2) <= 2);
        assert!(default_fed_threads(1024) >= 1);
    }

    #[test]
    fn parallel_drive_matches_sharded_and_meters_phases() {
        let specs: Vec<JobSpec> = (0..24).map(spec).collect();
        let cfg = SlurmConfig { nodes: 6, ..Default::default() };
        let dcfg = DaemonConfig::default();
        let policy = PolicySpec::EarlyCancel;
        let sharded = run_federation(&specs, 3, &cfg, &policy, &dcfg, FedDrive::Sharded);
        for threads in [0usize, 1, 2, 8] {
            let par = run_federation(
                &specs,
                3,
                &cfg,
                &policy,
                &dcfg,
                FedDrive::Parallel { threads },
            );
            assert_eq!(par.jobs, sharded.jobs, "threads={threads}: job records diverged");
            assert_eq!(par.stats, sharded.stats, "threads={threads}: SlurmStats diverged");
            assert_eq!(
                par.daemon_stats.deterministic(),
                sharded.daemon_stats.deterministic(),
                "threads={threads}: DaemonStats diverged"
            );
            assert!(par.drive_nanos > 0, "drive phase metered");
        }
        assert!(sharded.drive_nanos > 0, "sharded drive metered");
    }

    #[test]
    fn per_id_footprint_is_plausible() {
        let b = unretired_bytes_per_id();
        // Sanity band: a few machine words per table, ten-ish tables.
        assert!(b > 50 && b < 400, "unretired_bytes_per_id = {b}");
    }
}
