//! # tailtamer
//!
//! A reproduction of *"An Autonomy Loop for Dynamic HPC Job Time Limit
//! Adjustment"* (Jakobsche et al., 2025) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! Start with `ARCHITECTURE.md` at the repo root: the subsystem map,
//! the event/poll/backfill timeline, the "reference oracles +
//! bit-identity pinning" testing doctrine, and the complete TOML/CLI
//! config reference.
//!
//! The crate provides:
//!
//! - a discrete-event simulation core ([`simtime`]),
//! - a cluster resource model ([`cluster`]),
//! - a from-scratch Slurm-like scheduler ([`slurm`]) with a main priority
//!   scheduler, an EASY backfill scheduler, and the `scontrol`/`squeue`
//!   surface the paper's daemon relies on,
//! - a PM100-calibrated workload substrate ([`workload`]),
//! - checkpoint progress reporting and estimation ([`ckpt`]),
//! - the paper's contribution: the autonomy-loop daemon ([`daemon`])
//!   and its pluggable, parameterized decision-policy layer
//!   ([`policy`]),
//! - scheduling metrics incl. *tail waste* ([`metrics`]),
//! - a PJRT runtime that executes the AOT-compiled JAX/Pallas decision
//!   model from the daemon's hot path ([`runtime`]) and a bit-comparable
//!   native oracle ([`analytics`]),
//! - a wall-clock live mode with file-based checkpoint reporting
//!   ([`live`]),
//! - crash-safe event-sourced durability: an append-only tick journal
//!   with snapshots, checksums, rotation + compaction, and exact
//!   replay ([`journal`]), plus a supervision layer that restarts a
//!   killed daemon from its journal ([`daemon::supervise`]) and an
//!   external binding that drives a real `slurmctld` through
//!   `squeue`/`scontrol` subprocesses ([`slurm::external`]),
//! - a sharded multi-cluster federation layer: per-shard event queues
//!   merged deterministically by (time, shard, seq), dense per-job
//!   tables bounded by a retirement watermark ([`slurm::fed`],
//!   [`jobtable`]),
//! - parallel policy × workload ablation sweeps over OS threads, with
//!   a work-stealing shard×cell pool at federation scale ([`sweep`]),
//! - support substrates: config parsing ([`config`]), CLI ([`cli`]),
//!   property testing ([`proptest_lite`]), reporting ([`report`]),
//!   errors ([`errors`]), logging ([`logging`]).

pub mod analytics;
pub mod ckpt;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod daemon;
pub mod errors;
pub mod jobtable;
pub mod journal;
pub mod live;
pub mod logging;
pub mod metrics;
pub mod policy;
pub mod proptest_lite;
pub mod report;
pub mod runtime;
pub mod simtime;
pub mod slurm;
pub mod sweep;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = errors::Result<T>;
