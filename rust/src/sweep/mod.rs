//! Parallel scenario sweeps: run a policy × workload ablation grid
//! across OS threads.
//!
//! The crate is dependency-free, so parallelism is `std::thread::scope`
//! (no rayon): a shared atomic work index hands scenarios to workers,
//! each worker runs the full deterministic simulation for its scenario
//! (every scenario owns its RNG seeds through its workload — there is
//! no cross-scenario state), and results land in per-scenario slots.
//! The output vector is therefore **identical to the serial run** in
//! both content and order, whatever the thread count — pinned by the
//! `parallel_sweep_matches_serial` tests here and in
//! `rust/tests/sweep_scale.rs`.
//!
//! Grids are built over [`PolicySpec`]s, so parameterized policies
//! (`extend-budget:<secs>`, `tail-aware:<frac>`, …) sweep exactly like
//! the legacy four — [`spec_grid`] takes any policy list;
//! [`policy_grid`] keeps the paper's Table 1 shape.
//!
//! At federation scale one cell no longer fits one thread's patience:
//! [`run_sweep_sharded`] splits every cell into shard×cell work units
//! and runs them on a work-stealing pool — a shared atomic cursor that
//! workers batch-claim with the AIMD width governor (additive +1 per
//! fast batch, halve on a slow one), so claim contention stays low on
//! small units while long-running shard units still spread across the
//! pool. Per-cell results are recombined deterministically
//! ([`crate::slurm::fed::recombine`]), so the output is bit-identical
//! to the serial shard-by-shard run, whatever the thread count or
//! claim widths. The AIMD governor itself lives with the federation
//! ([`crate::slurm::fed::ClaimWidth`]) — the parallel federation drive
//! and this pool share one implementation.
//!
//! When the grid is *narrower* than the pool (cells < threads) the
//! shard × cell flattening can't use every core on the tail cell, so
//! [`run_sweep_sharded`] switches to a nested mode: workers claim
//! whole cells and drive each cell's federation with
//! [`FedDrive::Parallel`](fed::FedDrive::Parallel), splitting the
//! thread budget across in-flight cells. Same recombination path, same
//! bit-identical output.
//!
//! Cell timing is split into **drive** (simulation proper — summed
//! per-shard walls, so the figure is thread-count independent) and
//! **recombine** (counter sums + the zero-copy reinterleave);
//! [`SweepResult::jobs_per_sec`] divides by drive only, so throughput
//! measures the simulator, not the merge bookkeeping.

use std::sync::Arc;
use std::sync::Mutex;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use crate::daemon::{DaemonConfig, DaemonStats, run_scenario_metered};
use crate::metrics::{Summary, summarize};
use crate::policy::PolicySpec;
use crate::slurm::fed;
use crate::slurm::{JobSpec, SlurmConfig};

/// One grid cell: a workload replayed under one policy/configuration.
#[derive(Debug, Clone)]
pub struct Scenario {
    /// Human label for reports (e.g. `"20k-jobs/1024-nodes"`).
    pub label: String,
    /// The workload, shared across cells without copying.
    pub specs: Arc<Vec<JobSpec>>,
    pub slurm: SlurmConfig,
    pub policy: PolicySpec,
    pub daemon: DaemonConfig,
}

/// One finished cell.
#[derive(Debug, Clone)]
pub struct SweepResult {
    pub label: String,
    pub policy: PolicySpec,
    pub summary: Summary,
    pub daemon_stats: DaemonStats,
    /// Total wall time of this cell (`drive + recombine`). For sharded
    /// cells the drive part is the *summed* shard CPU walls, not
    /// elapsed pool time, so the figure is thread-count independent.
    pub wall: Duration,
    /// Simulation-proper wall time (summed per-shard drives).
    pub drive: Duration,
    /// Recombination wall time (counter sums + reinterleave); zero for
    /// unfederated cells.
    pub recombine: Duration,
    /// Jobs simulated per wall second — the BENCH throughput figure,
    /// derived from `drive` only so the simulator's speed is measured
    /// without the merge bookkeeping (which is metered separately).
    pub jobs_per_sec: f64,
    /// Summed high-water resident bytes of the cell's dense per-job
    /// tables (control plane + daemon + report book; all shards).
    pub peak_table_bytes: usize,
}

/// A grid over an arbitrary policy list (one cell per policy).
pub fn spec_grid(
    label: &str,
    specs: Arc<Vec<JobSpec>>,
    slurm: SlurmConfig,
    daemon: DaemonConfig,
    policies: &[PolicySpec],
) -> Vec<Scenario> {
    policies
        .iter()
        .map(|policy| Scenario {
            label: label.to_string(),
            specs: Arc::clone(&specs),
            slurm: slurm.clone(),
            policy: policy.clone(),
            daemon: daemon.clone(),
        })
        .collect()
}

/// The full 4-policy legacy grid over one workload (the paper's Table 1
/// shape).
pub fn policy_grid(
    label: &str,
    specs: Arc<Vec<JobSpec>>,
    slurm: SlurmConfig,
    daemon: DaemonConfig,
) -> Vec<Scenario> {
    spec_grid(label, specs, slurm, daemon, &PolicySpec::legacy_all())
}

/// Default worker count: the machine's parallelism, capped by the grid.
pub fn default_threads(scenarios: usize) -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get()).min(scenarios.max(1))
}

/// Run every scenario, `threads` at a time (1 = serial). Results are in
/// scenario order and bit-identical to a serial run: each cell's
/// simulation is deterministic and shares nothing with its neighbours.
pub fn run_sweep(scenarios: &[Scenario], threads: usize) -> Vec<SweepResult> {
    let threads = threads.max(1).min(scenarios.len().max(1));
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<SweepResult>>> =
        scenarios.iter().map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= scenarios.len() {
                        break;
                    }
                    let sc = &scenarios[i];
                    let t0 = Instant::now();
                    // Each worker builds its own native engine inside
                    // run_scenario — engines are not shared across
                    // threads (the PJRT client is single-threaded by
                    // design; sweeps always use the native oracle).
                    let (jobs, stats, dstats, peak, _retired) = run_scenario_metered(
                        &sc.specs,
                        sc.slurm.clone(),
                        sc.policy.clone(),
                        sc.daemon.clone(),
                        None,
                    );
                    let summary = summarize(&sc.policy.display(), &jobs, &stats);
                    let wall = t0.elapsed();
                    *slots[i].lock().unwrap() = Some(SweepResult {
                        label: sc.label.clone(),
                        policy: sc.policy.clone(),
                        summary,
                        daemon_stats: dstats,
                        wall,
                        drive: wall,
                        recombine: Duration::ZERO,
                        jobs_per_sec: jobs_per_sec(jobs.len(), wall),
                        peak_table_bytes: peak,
                    });
                }
            });
        }
    });

    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("every scenario ran"))
        .collect()
}

fn jobs_per_sec(jobs: usize, wall: Duration) -> f64 {
    let secs = wall.as_secs_f64();
    if secs > 0.0 { jobs as f64 / secs } else { 0.0 }
}

/// Build one cell's [`SweepResult`] from its recombined federation
/// outcome — the single timing/summary path every sharded cell (flat
/// unit-pool or nested parallel) funnels through.
fn cell_result(sc: &Scenario, out: fed::FedOutcome) -> SweepResult {
    let drive = Duration::from_nanos(out.drive_nanos);
    let recombine = Duration::from_nanos(out.recombine_nanos);
    let summary = summarize(&sc.policy.display(), &out.jobs, &out.stats);
    SweepResult {
        label: sc.label.clone(),
        policy: sc.policy.clone(),
        summary,
        daemon_stats: out.daemon_stats,
        wall: drive + recombine,
        drive,
        recombine,
        jobs_per_sec: jobs_per_sec(out.jobs.len(), drive),
        peak_table_bytes: out.peak_table_bytes,
    }
}

/// Run every scenario as a federation of `shards` clusters on a
/// work-stealing pool over shard×cell units (see the module docs).
///
/// Semantics per cell are exactly
/// [`run_federation`](fed::run_federation) with
/// [`FedDrive::Sharded`](fed::FedDrive): each unit is one shard run
/// serially to completion, recombined in shard order afterwards — so
/// results are bit-identical whatever `threads` is, and `shards == 1`
/// reproduces [`run_sweep`]'s cells exactly. Grids narrower than the
/// pool switch to the nested parallel-per-cell mode (module docs),
/// which is the same identity through
/// [`FedDrive::Parallel`](fed::FedDrive::Parallel).
pub fn run_sweep_sharded(
    scenarios: &[Scenario],
    threads: usize,
    shards: usize,
) -> Vec<SweepResult> {
    assert!(shards > 0, "federation needs at least one shard");
    let cells = scenarios.len();
    if cells > 0 && cells < threads && shards > 1 {
        // Nested mode: fewer cells than workers — flattening to
        // shard×cell units would still leave cores idle whenever the
        // tail cell has fewer shards than free workers. Instead claim
        // whole cells and let each cell's federation drive its own
        // shards in parallel with an even split of the thread budget.
        let per_cell = (threads / cells).max(1).min(shards);
        let outer = threads.min(cells);
        let next = AtomicUsize::new(0);
        let slots: Vec<Mutex<Option<SweepResult>>> =
            (0..cells).map(|_| Mutex::new(None)).collect();
        std::thread::scope(|scope| {
            for _ in 0..outer {
                scope.spawn(|| {
                    loop {
                        let c = next.fetch_add(1, Ordering::Relaxed);
                        if c >= cells {
                            break;
                        }
                        let sc = &scenarios[c];
                        let out = fed::run_federation(
                            &sc.specs,
                            shards,
                            &sc.slurm,
                            &sc.policy,
                            &sc.daemon,
                            fed::FedDrive::Parallel { threads: per_cell },
                        );
                        *slots[c].lock().unwrap() = Some(cell_result(sc, out));
                    }
                });
            }
        });
        return slots
            .into_iter()
            .map(|m| m.into_inner().unwrap().expect("every cell ran"))
            .collect();
    }

    // Flat mode: partition every cell's master workload up front
    // (cheap relative to simulation; keeps the unit loop
    // allocation-free) and steal shard×cell units.
    let parts: Vec<Vec<Vec<JobSpec>>> =
        scenarios.iter().map(|sc| fed::partition(&sc.specs, shards)).collect();
    let units = cells * shards;
    let threads = threads.max(1).min(units.max(1));
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<fed::ShardRun>>> = (0..units).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| {
                // Per-worker AIMD claim width (fed::ClaimWidth — the
                // PR 7 controller, shared with the parallel federation
                // drive): batch claims amortize cursor contention on
                // tiny units, while a slow batch halves the width so
                // long shard units spread back across the pool.
                let mut width = fed::ClaimWidth::new();
                loop {
                    let start = next.fetch_add(width.get(), Ordering::Relaxed);
                    if start >= units {
                        break;
                    }
                    let end = (start + width.get()).min(units);
                    let t0 = Instant::now();
                    for u in start..end {
                        let (c, k) = (u / shards, u % shards);
                        let sc = &scenarios[c];
                        // run_shard times its own drive into
                        // ShardRun::drive_nanos.
                        let run =
                            fed::run_shard(&parts[c][k], &sc.slurm, &sc.policy, &sc.daemon);
                        *slots[u].lock().unwrap() = Some(run);
                    }
                    width.observe(t0.elapsed());
                }
            });
        }
    });

    let mut done: Vec<Option<fed::ShardRun>> =
        slots.into_iter().map(|m| m.into_inner().unwrap()).collect();
    scenarios
        .iter()
        .enumerate()
        .map(|(c, sc)| {
            let runs = (0..shards)
                .map(|k| done[c * shards + k].take().expect("every unit ran"))
                .collect();
            cell_result(sc, fed::recombine(runs))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::{Arrival, ScaledConfig};

    fn small_grid() -> Vec<Scenario> {
        let mut grid = Vec::new();
        for (label, arrival) in [
            ("zero", Arrival::AllAtZero),
            ("stagger", Arrival::Staggered { mean_gap: 20 }),
        ] {
            let specs = Arc::new(
                ScaledConfig { jobs: 120, nodes: 24, seed: 9, arrival, ..Default::default() }
                    .build(),
            );
            grid.extend(policy_grid(
                label,
                specs,
                SlurmConfig { nodes: 24, ..Default::default() },
                DaemonConfig::default(),
            ));
        }
        grid
    }

    #[test]
    fn parallel_sweep_matches_serial() {
        let grid = small_grid();
        let serial = run_sweep(&grid, 1);
        let parallel = run_sweep(&grid, 4);
        assert_eq!(serial.len(), parallel.len());
        for (a, b) in serial.iter().zip(&parallel) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.policy, b.policy);
            assert_eq!(a.summary, b.summary, "{} / {:?} diverged", a.label, a.policy);
        }
    }

    #[test]
    fn grid_covers_all_policies() {
        let grid = small_grid();
        assert_eq!(grid.len(), 8);
        let results = run_sweep(&grid[..4], 2);
        assert_eq!(results[0].policy, PolicySpec::Baseline);
        // The autonomy policies must beat baseline tail waste.
        let base = results[0].summary.tail_waste;
        assert!(base > 0);
        for r in &results[1..] {
            assert!(r.summary.tail_waste < base, "{:?}", r.policy);
        }
    }

    #[test]
    fn spec_grid_sweeps_parameterized_policies() {
        let specs = Arc::new(
            ScaledConfig { jobs: 80, nodes: 16, seed: 5, ..Default::default() }.build(),
        );
        let policies = vec![
            PolicySpec::Baseline,
            PolicySpec::TailAware { frac: 0.05 },
            PolicySpec::TailAware { frac: 5.0 },
            PolicySpec::ExtendBudget { budget: 900 },
        ];
        let grid = spec_grid(
            "param",
            specs,
            SlurmConfig { nodes: 16, ..Default::default() },
            DaemonConfig::default(),
            &policies,
        );
        assert_eq!(grid.len(), 4);
        let results = run_sweep(&grid, 2);
        for (r, p) in results.iter().zip(&policies) {
            assert_eq!(&r.policy, p);
            assert_eq!(r.summary.policy, p.display());
        }
        let base = results[0].summary.tail_waste;
        assert!(base > 0);
        // A strict tail-aware threshold cancels like EC; a huge one
        // tolerates every tail and reproduces the baseline waste.
        assert!(results[1].summary.tail_waste < base, "strict threshold must act");
        assert_eq!(results[2].summary.tail_waste, base, "lax threshold leaves all tails");
        assert!(results[3].daemon_stats.budget_spent > 0, "budget policy must spend");
    }

    #[test]
    fn sharded_sweep_is_thread_count_invariant_and_meters_cells() {
        let grid = small_grid();
        let serial = run_sweep_sharded(&grid, 1, 3);
        let wide = run_sweep_sharded(&grid, 4, 3);
        assert_eq!(serial.len(), wide.len());
        for (a, b) in serial.iter().zip(&wide) {
            assert_eq!(a.summary, b.summary, "{} / {:?} diverged", a.label, a.policy);
            assert_eq!(
                a.daemon_stats.deterministic(),
                b.daemon_stats.deterministic(),
                "{} / {:?} daemon stats diverged",
                a.label,
                a.policy
            );
            assert_eq!(a.peak_table_bytes, b.peak_table_bytes);
        }
        for r in &serial {
            assert!(r.jobs_per_sec > 0.0, "throughput metered");
            assert!(r.peak_table_bytes > 0, "peak bytes metered");
            assert!(r.drive > Duration::ZERO, "drive phase metered");
            assert_eq!(r.wall, r.drive + r.recombine, "wall is the phase sum");
        }
    }

    #[test]
    fn nested_parallel_cells_match_the_flat_serial_pool() {
        // 2 cells on 8 threads with 3 shards trips the nested mode
        // (cells < threads): each cell's federation drives its shards
        // with FedDrive::Parallel. Must be bit-identical to the flat
        // serial shard-by-shard pool.
        let full = small_grid();
        let grid = &full[..2];
        let serial = run_sweep_sharded(grid, 1, 3);
        let nested = run_sweep_sharded(grid, 8, 3);
        assert_eq!(serial.len(), nested.len());
        for (a, b) in serial.iter().zip(&nested) {
            assert_eq!(a.summary, b.summary, "{} / {:?} diverged", a.label, a.policy);
            assert_eq!(
                a.daemon_stats.deterministic(),
                b.daemon_stats.deterministic(),
                "{} / {:?} daemon stats diverged",
                a.label,
                a.policy
            );
            assert_eq!(a.peak_table_bytes, b.peak_table_bytes);
        }
        for r in &nested {
            assert!(r.drive > Duration::ZERO, "nested drive metered");
        }
    }

    #[test]
    fn one_shard_sweep_matches_the_plain_sweep() {
        let grid = small_grid();
        let plain = run_sweep(&grid, 2);
        let fed1 = run_sweep_sharded(&grid, 2, 1);
        for (a, b) in plain.iter().zip(&fed1) {
            assert_eq!(a.summary, b.summary);
            assert_eq!(a.daemon_stats.deterministic(), b.daemon_stats.deterministic());
            assert_eq!(a.peak_table_bytes, b.peak_table_bytes);
        }
    }

    #[test]
    fn default_threads_is_sane() {
        assert!(default_threads(100) >= 1);
        assert_eq!(default_threads(0), 1);
        assert!(default_threads(1) <= 1);
    }
}
