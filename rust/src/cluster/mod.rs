//! Cluster resource model.
//!
//! Two pieces:
//!
//! - [`Cluster`]: the instantaneous node pool — how many nodes exist,
//!   how many are free, and which job holds how many. The paper's test
//!   system allocates whole nodes exclusively, so a count-based model
//!   (no node identity) is faithful: any `n` free nodes are equivalent.
//! - [`Profile`]: a future *capacity profile* (step function of free
//!   nodes over time) built from the running jobs' expected ends. The
//!   backfill scheduler uses it to find earliest feasible starts and to
//!   carve out reservations; the autonomy daemon uses it to compute
//!   `free_at(pred_start)` for the Hybrid extension-delay check.
//!
//! The profile is the backfill scheduler's inner loop, so it is built
//! as an arena: every buffer it needs (breakpoints, merge scratch,
//! release collection) lives inside the struct and is reused across
//! passes — zero allocations in the steady state (EXPERIMENTS.md
//! §Perf). Mutations go through a two-vector merge instead of
//! `Vec::insert`, and a base profile can be refreshed incrementally via
//! [`Profile::shift_release`] when only job limits changed.
//!
//! [`captree`] holds the min-augmented capacity tree ([`CapTree`]): the
//! same step function as a balanced tree with subtree-min/max
//! augmentation and lazy range-adds, making `find_earliest` an
//! O(log B) augmented descent instead of an O(B) scan. The scheduler
//! picks between them via [`BackfillProfile`] / [`CapacityProfile`].
//!
//! Nothing here is shared: every `Slurmd` — and therefore every
//! federation shard ([`crate::slurm::fed`]) — owns its own `Cluster`
//! and profile arenas outright, which is what lets the federation
//! driver interleave shard steps in any whole-step order without
//! synchronization and still get bit-identical per-shard outcomes.

pub mod captree;

pub use captree::{BackfillProfile, CapTree, CapacityProfile};

use crate::simtime::Time;

/// Instantaneous node pool.
#[derive(Debug, Clone)]
pub struct Cluster {
    total: u32,
    free: u32,
    /// Nodes taken out of service by a failure/drain event
    /// ([`crate::slurm::FailureConfig`]): neither free nor held by a
    /// job. They return to `free` via [`Cluster::restore_node`] when
    /// their repair window elapses.
    down: u32,
    /// Dense per-job slot indexed by the dense job id
    /// (`JobId.0 as usize`): `(nodes held, index in held_list)`;
    /// `None` = the job holds nothing. Replaces the seed's `HashMap`:
    /// allocate/release/held_by are an index and a branch, no hashing
    /// on the end-event path (§Perf).
    alloc: Vec<Option<(u32, u32)>>,
    /// Compact list of job ids currently holding nodes (swap-remove on
    /// release, position tracked in `alloc`): `allocations()` stays
    /// O(running jobs) however many jobs have come and gone.
    held_list: Vec<u64>,
}

impl Cluster {
    /// A pool of `total` identical nodes, all free.
    pub fn new(total: u32) -> Self {
        Self { total, free: total, down: 0, alloc: Vec::new(), held_list: Vec::new() }
    }

    pub fn total(&self) -> u32 {
        self.total
    }

    pub fn free(&self) -> u32 {
        self.free
    }

    /// Nodes currently out of service (failed/draining repair windows).
    pub fn down(&self) -> u32 {
        self.down
    }

    pub fn used(&self) -> u32 {
        self.total - self.free - self.down
    }

    /// Take one *free* node out of service (a failure or the end of a
    /// drain). Callers release the victim job first, so the node being
    /// lost is free at this instant; panics if none is.
    pub fn fail_node(&mut self) {
        assert!(self.free >= 1, "node failure with no free node to remove");
        self.free -= 1;
        self.down += 1;
    }

    /// Return one down node to service (its repair window elapsed).
    pub fn restore_node(&mut self) {
        assert!(self.down >= 1, "restore with no node down");
        self.down -= 1;
        self.free += 1;
        debug_assert!(self.free + self.down <= self.total);
    }

    /// Nodes currently held by `job`, 0 if none.
    pub fn held_by(&self, job: u64) -> u32 {
        self.alloc
            .get(job as usize)
            .copied()
            .flatten()
            .map(|(nodes, _)| nodes)
            .unwrap_or(0)
    }

    /// Number of distinct jobs holding nodes.
    pub fn running_jobs(&self) -> usize {
        self.held_list.len()
    }

    /// Whether `nodes` can be allocated right now.
    pub fn fits(&self, nodes: u32) -> bool {
        nodes <= self.free
    }

    /// Allocate `nodes` to `job`. Panics on over-allocation or double
    /// allocation — both are simulator logic errors, not runtime
    /// conditions.
    pub fn allocate(&mut self, job: u64, nodes: u32) {
        assert!(nodes >= 1, "job {job}: zero-node allocation");
        assert!(
            nodes <= self.free,
            "job {job}: over-allocation ({nodes} nodes requested, {} free)",
            self.free
        );
        let i = job as usize;
        if self.alloc.len() <= i {
            self.alloc.resize(i + 1, None);
        }
        let pos = self.held_list.len() as u32;
        let prev = self.alloc[i].replace((nodes, pos));
        assert!(prev.is_none(), "job {job}: double allocation");
        self.held_list.push(job);
        self.free -= nodes;
    }

    /// Release `job`'s nodes. Panics if the job holds none.
    pub fn release(&mut self, job: u64) -> u32 {
        let (nodes, pos) = self
            .alloc
            .get_mut(job as usize)
            .and_then(|slot| slot.take())
            .expect("release of unallocated job");
        // Swap-remove from the compact held list and repoint the job
        // that moved into `pos` (if any).
        let pos = pos as usize;
        self.held_list.swap_remove(pos);
        if let Some(&moved) = self.held_list.get(pos) {
            self.alloc[moved as usize]
                .as_mut()
                .expect("held job has a slot")
                .1 = pos as u32;
        }
        self.free += nodes;
        debug_assert!(self.free <= self.total);
        nodes
    }

    /// Iterate over `(job, nodes)` allocations in O(running jobs),
    /// unordered (like the seed's `HashMap`, though deterministically
    /// so; every consumer sorts releases anyway).
    pub fn allocations(&self) -> impl Iterator<Item = (u64, u32)> + '_ {
        self.held_list.iter().map(|&j| {
            let (nodes, _) = self.alloc[j as usize].expect("held job has a slot");
            (j, nodes)
        })
    }
}

/// A step function `t -> free nodes` over `[now, +inf)`.
///
/// Stored as breakpoints `(t_i, free_i)` with `free` constant on
/// `[t_i, t_{i+1})`; the last segment extends to infinity. Invariants:
/// strictly increasing times, `free <= total`. Adjacent breakpoints may
/// carry equal `free` values (degenerate splits left behind by
/// incremental updates); every query is insensitive to them.
#[derive(Debug)]
pub struct Profile {
    total: u32,
    points: Vec<(Time, u32)>,
    /// Pooled suffix-merge scratch for [`apply`](Self::apply) — what
    /// replaces the seed's per-breakpoint `Vec::insert` (§Perf).
    scratch: Vec<(Time, u32)>,
    /// Release-collection scratch for [`extend_releases`](Self::extend_releases).
    releases: Vec<(Time, u32)>,
}

impl Clone for Profile {
    fn clone(&self) -> Self {
        Self {
            total: self.total,
            points: self.points.clone(),
            scratch: Vec::new(),
            releases: Vec::new(),
        }
    }

    fn clone_from(&mut self, src: &Self) {
        self.copy_from(src);
    }
}

impl Profile {
    /// Start a profile at `now` with `free` nodes free out of `total`.
    pub fn new(now: Time, free: u32, total: u32) -> Self {
        assert!(free <= total);
        Self { total, points: vec![(now, free)], scratch: Vec::new(), releases: Vec::new() }
    }

    /// Reset in place to a single breakpoint, keeping every buffer.
    pub fn reset(&mut self, now: Time, free: u32, total: u32) {
        assert!(free <= total);
        self.total = total;
        self.points.clear();
        self.points.push((now, free));
    }

    /// Copy `src`'s step function into `self`, reusing `self`'s buffers.
    pub fn copy_from(&mut self, src: &Profile) {
        self.total = src.total;
        self.points.clear();
        self.points.extend_from_slice(&src.points);
    }

    /// Build the scheduler's view from the instantaneous cluster state
    /// and the running jobs' *expected* ends (start + current limit):
    /// each running job releases its nodes at its expected end.
    pub fn from_running(
        now: Time,
        cluster: &Cluster,
        expected_end: impl Fn(u64) -> Time,
    ) -> Self {
        let mut p = Self::new(now, cluster.free(), cluster.total());
        p.extend_releases(cluster.allocations().map(|(j, n)| (expected_end(j).max(now), n)));
        p
    }

    /// Fold a batch of `(release time, nodes)` pairs into the profile.
    /// Sorted internally, so ascending appends hit the O(1) tail path
    /// of [`add_release`](Self::add_release); the result depends only on
    /// the multiset of pairs, never on input order.
    pub fn extend_releases(&mut self, it: impl IntoIterator<Item = (Time, u32)>) {
        let mut releases = std::mem::take(&mut self.releases);
        releases.clear();
        releases.extend(it);
        releases.sort_unstable();
        for &(t, n) in &releases {
            self.add_release(t, n);
        }
        self.releases = releases;
    }

    fn start(&self) -> Time {
        self.points[0].0
    }

    /// Index of the segment containing time `t` (t must be >= start).
    fn segment_at(&self, t: Time) -> usize {
        debug_assert!(t >= self.start());
        match self.points.binary_search_by_key(&t, |&(bt, _)| bt) {
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// Free nodes at time `t`.
    pub fn free_at(&self, t: Time) -> u32 {
        self.points[self.segment_at(t)].1
    }

    /// `free += nodes` for all `t' >= t` (a running job ends at `t`).
    /// O(1) when `t` lands at or past the last breakpoint — the common
    /// case when releases arrive time-sorted.
    pub fn add_release(&mut self, t: Time, nodes: u32) {
        let (last_t, last_f) = *self.points.last().expect("profile is never empty");
        if t >= last_t {
            let nf = last_f as i64 + nodes as i64;
            assert!(
                nf <= self.total as i64,
                "profile capacity violated at t={t}: {last_f} + {nodes}"
            );
            if t == last_t {
                self.points.last_mut().unwrap().1 = nf as u32;
            } else {
                self.points.push((t, nf as u32));
            }
            return;
        }
        self.apply(t, Time::MAX, nodes as i64);
    }

    /// Move a release previously added at `old` to `new` (a running
    /// job's limit changed). The step function afterwards is exactly
    /// what a from-scratch rebuild with the new release time would
    /// produce, up to degenerate (equal-value) breakpoints.
    pub fn shift_release(&mut self, old: Time, new: Time, nodes: u32) {
        use std::cmp::Ordering::*;
        match new.cmp(&old) {
            Equal => {}
            // Released later: the nodes stay busy over [old, new).
            Greater => self.apply(old, new, -(nodes as i64)),
            // Released earlier: free over [new, old).
            Less => self.apply(new, old, nodes as i64),
        }
    }

    /// `free -= nodes` over `[s, e)` (a reservation or placed job).
    /// Panics if capacity would go negative — callers must check
    /// feasibility first (this preserves the no-over-allocation
    /// invariant through the whole backfill pass).
    pub fn reserve(&mut self, s: Time, e: Time, nodes: u32) {
        assert!(s < e, "empty reservation [{s}, {e})");
        self.apply(s, e, -(nodes as i64));
    }

    /// Add `delta` to the free count over `[s, e)`, splitting segments.
    ///
    /// When breakpoints already exist at both edges (the common case on
    /// warmed-up profiles) this is a pure in-place span update with no
    /// copying at all. Otherwise only the suffix from `s` onward is
    /// re-merged through the pooled scratch buffer — never a
    /// `Vec::insert` memmove per breakpoint, never a full-vector copy,
    /// no allocation once the scratch has warmed up (§Perf).
    fn apply(&mut self, s: Time, e: Time, delta: i64) {
        let s = s.max(self.start());
        if e <= s {
            return;
        }
        let total = self.total as i64;
        let n = self.points.len();
        let (lo, s_exists) = match self.points.binary_search_by_key(&s, |&(bt, _)| bt) {
            Ok(i) => (i, true),
            Err(i) => (i, false),
        };
        let e_exists = e == Time::MAX
            || self.points.binary_search_by_key(&e, |&(bt, _)| bt).is_ok();

        if s_exists && e_exists {
            // Fast path: both edges present — update the span in place.
            for i in lo..n {
                let (t, f) = self.points[i];
                if e != Time::MAX && t >= e {
                    break;
                }
                let nf = f as i64 + delta;
                assert!(
                    (0..=total).contains(&nf),
                    "profile capacity violated at t={t}: {f} + {delta}"
                );
                self.points[i].1 = nf as u32;
            }
            return;
        }

        // Suffix merge: points before `lo` are untouched; rebuild the
        // rest into the scratch buffer, then splice it back.
        let mut out = std::mem::take(&mut self.scratch);
        out.clear();
        let mut i = lo;
        if !s_exists {
            // s > start here (s == start implies an existing point), so
            // lo >= 1 and the straddling segment's level is at lo - 1.
            let f = self.points[lo - 1].1;
            let nf = f as i64 + delta;
            assert!(
                (0..=total).contains(&nf),
                "profile capacity violated at t={s}: {f} + {delta}"
            );
            out.push((s, nf as u32));
        }
        // Apply the delta to every breakpoint in [s, e).
        while i < n && (e == Time::MAX || self.points[i].0 < e) {
            let (t, f) = self.points[i];
            let nf = f as i64 + delta;
            assert!(
                (0..=total).contains(&nf),
                "profile capacity violated at t={t}: {f} + {delta}"
            );
            out.push((t, nf as u32));
            i += 1;
        }
        // Breakpoint at e restores the pre-delta level. A point at or
        // before s always exists, so i >= 1 and points[i - 1] carries
        // the last pre-delta level reaching past e.
        if e != Time::MAX && !(i < n && self.points[i].0 == e) {
            out.push((e, self.points[i - 1].1));
        }
        out.extend_from_slice(&self.points[i..]);
        self.points.truncate(lo);
        self.points.extend_from_slice(&out);
        self.scratch = out;
    }

    /// Earliest `t >= after` such that `nodes` are free during the whole
    /// window `[t, t + duration)`.
    ///
    /// Scans segments left to right; restarts the window whenever a
    /// segment dips below `nodes`. Always succeeds on the infinite final
    /// segment if `nodes <= total` (callers guarantee this).
    pub fn find_earliest(&self, nodes: u32, duration: Time, after: Time) -> Time {
        assert!(nodes <= self.total, "request exceeds cluster size");
        assert!(duration >= 1);
        let after = after.max(self.start());
        let mut candidate: Option<Time> = None;
        let n = self.points.len();
        // Segments ending at or before `after` are irrelevant: start the
        // scan at the segment containing `after`.
        let first = self.segment_at(after);
        for i in first..n {
            let (t, free) = self.points[i];
            let seg_end = if i + 1 < n { self.points[i + 1].0 } else { Time::MAX };
            if free < nodes {
                candidate = None;
                continue;
            }
            let start = candidate.get_or_insert(t.max(after));
            // Window is satisfied once it spans `duration`.
            if seg_end == Time::MAX || seg_end - *start >= duration {
                return *start;
            }
        }
        unreachable!("final segment is infinite");
    }

    /// Breakpoint count (perf observability). Never zero.
    #[allow(clippy::len_without_is_empty)]
    pub fn len(&self) -> usize {
        self.points.len()
    }

    /// The raw breakpoints (for tests and reporting).
    pub fn points(&self) -> &[(Time, u32)] {
        &self.points
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_release_roundtrip() {
        let mut c = Cluster::new(20);
        c.allocate(1, 8);
        c.allocate(2, 12);
        assert_eq!(c.free(), 0);
        assert_eq!(c.held_by(1), 8);
        assert!(!c.fits(1));
        assert_eq!(c.release(1), 8);
        assert_eq!(c.free(), 8);
        assert!(c.fits(8));
        assert_eq!(c.running_jobs(), 1);
    }

    #[test]
    fn allocations_stay_compact_under_churn() {
        // Releasing from the middle exercises the swap-remove path and
        // the moved job's position fix-up.
        let mut c = Cluster::new(10);
        c.allocate(0, 1);
        c.allocate(1, 2);
        c.allocate(2, 3);
        assert_eq!(c.release(1), 2); // middle release: swap-remove
        c.allocate(3, 2);
        let mut got: Vec<_> = c.allocations().collect();
        got.sort_unstable();
        assert_eq!(got, vec![(0, 1), (2, 3), (3, 2)]);
        assert_eq!(c.running_jobs(), 3);
        assert_eq!(c.held_by(1), 0);
        assert_eq!(c.release(2), 3);
        assert_eq!(c.release(0), 1);
        assert_eq!(c.release(3), 2);
        assert_eq!(c.running_jobs(), 0);
        assert_eq!(c.free(), 10);
    }

    #[test]
    fn fail_and_restore_track_down_nodes() {
        let mut c = Cluster::new(10);
        c.allocate(1, 4);
        c.fail_node();
        c.fail_node();
        assert_eq!(c.free(), 4);
        assert_eq!(c.down(), 2);
        assert_eq!(c.used(), 4);
        assert!(!c.fits(5));
        c.restore_node();
        assert_eq!(c.free(), 5);
        assert_eq!(c.down(), 1);
        assert_eq!(c.release(1), 4);
        assert_eq!(c.used(), 0);
        assert_eq!(c.free(), 9);
    }

    #[test]
    #[should_panic(expected = "restore with no node down")]
    fn restore_without_failure_panics() {
        let mut c = Cluster::new(2);
        c.restore_node();
    }

    #[test]
    #[should_panic(expected = "over-allocation")]
    fn overallocation_panics() {
        let mut c = Cluster::new(4);
        c.allocate(1, 5);
    }

    #[test]
    #[should_panic(expected = "double allocation")]
    fn double_allocation_panics() {
        let mut c = Cluster::new(8);
        c.allocate(1, 2);
        c.allocate(1, 2);
    }

    #[test]
    fn profile_from_running() {
        let mut c = Cluster::new(20);
        c.allocate(1, 8); // ends at 100
        c.allocate(2, 4); // ends at 50
        let p = Profile::from_running(0, &c, |j| if j == 1 { 100 } else { 50 });
        assert_eq!(p.free_at(0), 8);
        assert_eq!(p.free_at(49), 8);
        assert_eq!(p.free_at(50), 12);
        assert_eq!(p.free_at(100), 20);
        assert_eq!(p.free_at(1_000_000), 20);
    }

    #[test]
    fn find_earliest_immediate() {
        let p = Profile::new(10, 5, 20);
        assert_eq!(p.find_earliest(5, 100, 10), 10);
        assert_eq!(p.find_earliest(5, 100, 33), 33);
    }

    #[test]
    fn find_earliest_waits_for_release() {
        let mut p = Profile::new(0, 2, 20);
        p.add_release(100, 10);
        assert_eq!(p.find_earliest(4, 50, 0), 100);
        // 2 nodes fit immediately.
        assert_eq!(p.find_earliest(2, 50, 0), 0);
    }

    #[test]
    fn find_earliest_needs_contiguous_window() {
        // free: 10 on [0,100), 2 on [100,200), 10 on [200,inf)
        let mut p = Profile::new(0, 10, 10);
        p.reserve(100, 200, 8);
        // 60 s of 5 nodes fits in [0,100) starting at 0.
        assert_eq!(p.find_earliest(5, 60, 0), 0);
        // 150 s of 5 nodes cannot straddle the dip -> starts at 200.
        assert_eq!(p.find_earliest(5, 150, 0), 200);
        // after=80 pushes the first window past the dip.
        assert_eq!(p.find_earliest(5, 60, 80), 200);
    }

    #[test]
    fn reserve_splits_segments() {
        let mut p = Profile::new(0, 10, 10);
        p.reserve(50, 150, 4);
        assert_eq!(p.free_at(0), 10);
        assert_eq!(p.free_at(50), 6);
        assert_eq!(p.free_at(149), 6);
        assert_eq!(p.free_at(150), 10);
        p.reserve(100, 120, 6);
        assert_eq!(p.free_at(110), 0);
        assert_eq!(p.free_at(130), 6);
    }

    #[test]
    #[should_panic(expected = "capacity violated")]
    fn reserve_over_capacity_panics() {
        let mut p = Profile::new(0, 4, 10);
        p.reserve(0, 10, 5);
    }

    #[test]
    fn window_restarts_after_dip() {
        // free: 8 on [0,10), 0 on [10,20), 8 on [20,inf)
        let mut p = Profile::new(0, 8, 8);
        p.reserve(10, 20, 8);
        assert_eq!(p.find_earliest(1, 15, 0), 20);
        assert_eq!(p.find_earliest(1, 10, 0), 0);
    }

    #[test]
    fn release_then_reserve_interaction() {
        let mut c = Cluster::new(20);
        c.allocate(7, 20);
        let mut p = Profile::from_running(0, &c, |_| 1000);
        assert_eq!(p.free_at(0), 0);
        // Reserve a future job right at the release point.
        let s = p.find_earliest(12, 500, 0);
        assert_eq!(s, 1000);
        p.reserve(s, s + 500, 12);
        assert_eq!(p.free_at(1000), 8);
        assert_eq!(p.find_earliest(10, 100, 0), 1500);
    }

    #[test]
    fn merge_apply_matches_insert_semantics() {
        // The exact case the old insert-based code handled: breakpoints
        // at both ends of a straddling reservation, values preserved
        // outside, the delta applied to every segment inside.
        let mut p = Profile::new(0, 10, 10);
        p.add_release(100, 0); // degenerate breakpoint at 100
        p.reserve(50, 150, 4);
        assert_eq!(p.points(), &[(0, 10), (50, 6), (100, 6), (150, 10)]);
        // Reserving exactly on existing breakpoints adds none.
        p.reserve(50, 150, 2);
        assert_eq!(p.points(), &[(0, 10), (50, 4), (100, 4), (150, 10)]);
    }

    #[test]
    fn shift_release_matches_rebuild() {
        let mut c = Cluster::new(16);
        c.allocate(1, 6); // release 100 -> 400
        c.allocate(2, 4); // release 200
        let mut inc = Profile::from_running(0, &c, |j| if j == 1 { 100 } else { 200 });
        inc.shift_release(100, 400, 6);
        let rebuilt = Profile::from_running(0, &c, |j| if j == 1 { 400 } else { 200 });
        for t in [0, 99, 100, 150, 200, 399, 400, 10_000] {
            assert_eq!(inc.free_at(t), rebuilt.free_at(t), "t={t}");
        }
        // And moving earlier again restores the original.
        inc.shift_release(400, 100, 6);
        let orig = Profile::from_running(0, &c, |j| if j == 1 { 100 } else { 200 });
        for t in [0, 99, 100, 150, 200, 399, 400, 10_000] {
            assert_eq!(inc.free_at(t), orig.free_at(t), "t={t}");
        }
    }

    #[test]
    fn degenerate_breakpoints_do_not_change_queries() {
        // shift_release leaves equal-value breakpoints behind; every
        // query (free_at, find_earliest) must be insensitive to them.
        let mut p = Profile::new(0, 2, 10);
        p.add_release(300, 8);
        p.shift_release(300, 500, 8); // leaves a degenerate point at 300
        assert_eq!(p.free_at(300), 2);
        assert_eq!(p.free_at(500), 10);
        assert_eq!(p.find_earliest(5, 100, 0), 500);
        assert_eq!(p.find_earliest(2, 100, 0), 0);
    }

    #[test]
    fn reset_and_copy_reuse_buffers() {
        let mut a = Profile::new(0, 10, 10);
        a.reserve(10, 20, 3);
        let mut b = Profile::new(0, 0, 1);
        b.copy_from(&a);
        assert_eq!(a.points(), b.points());
        b.reset(5, 7, 8);
        assert_eq!(b.points(), &[(5, 7)]);
        assert_eq!(b.free_at(1_000), 7);
    }

    #[test]
    fn extend_releases_is_order_insensitive() {
        let mut a = Profile::new(0, 0, 12);
        a.extend_releases([(300, 4), (100, 4), (200, 4)]);
        let mut b = Profile::new(0, 0, 12);
        b.extend_releases([(100, 4), (200, 4), (300, 4)]);
        for t in [0, 99, 100, 199, 200, 299, 300, 5000] {
            assert_eq!(a.free_at(t), b.free_at(t), "t={t}");
        }
        assert_eq!(a.free_at(250), 8);
    }
}
