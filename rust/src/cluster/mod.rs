//! Cluster resource model.
//!
//! Two pieces:
//!
//! - [`Cluster`]: the instantaneous node pool — how many nodes exist,
//!   how many are free, and which job holds how many. The paper's test
//!   system allocates whole nodes exclusively, so a count-based model
//!   (no node identity) is faithful: any `n` free nodes are equivalent.
//! - [`Profile`]: a future *capacity profile* (step function of free
//!   nodes over time) built from the running jobs' expected ends. The
//!   backfill scheduler uses it to find earliest feasible starts and to
//!   carve out reservations; the autonomy daemon uses it to compute
//!   `free_at(pred_start)` for the Hybrid extension-delay check.

use std::collections::HashMap;

use crate::simtime::Time;

/// Instantaneous node pool.
#[derive(Debug, Clone)]
pub struct Cluster {
    total: u32,
    free: u32,
    alloc: HashMap<u64, u32>,
}

impl Cluster {
    /// A pool of `total` identical nodes, all free.
    pub fn new(total: u32) -> Self {
        Self { total, free: total, alloc: HashMap::new() }
    }

    pub fn total(&self) -> u32 {
        self.total
    }

    pub fn free(&self) -> u32 {
        self.free
    }

    pub fn used(&self) -> u32 {
        self.total - self.free
    }

    /// Nodes currently held by `job`, 0 if none.
    pub fn held_by(&self, job: u64) -> u32 {
        self.alloc.get(&job).copied().unwrap_or(0)
    }

    /// Number of distinct jobs holding nodes.
    pub fn running_jobs(&self) -> usize {
        self.alloc.len()
    }

    /// Whether `nodes` can be allocated right now.
    pub fn fits(&self, nodes: u32) -> bool {
        nodes <= self.free
    }

    /// Allocate `nodes` to `job`. Panics on over-allocation or double
    /// allocation — both are simulator logic errors, not runtime
    /// conditions.
    pub fn allocate(&mut self, job: u64, nodes: u32) {
        assert!(nodes >= 1, "job {job}: zero-node allocation");
        assert!(
            nodes <= self.free,
            "job {job}: over-allocation ({nodes} nodes requested, {} free)",
            self.free
        );
        let prev = self.alloc.insert(job, nodes);
        assert!(prev.is_none(), "job {job}: double allocation");
        self.free -= nodes;
    }

    /// Release `job`'s nodes. Panics if the job holds none.
    pub fn release(&mut self, job: u64) -> u32 {
        let nodes = self.alloc.remove(&job).expect("release of unallocated job");
        self.free += nodes;
        debug_assert!(self.free <= self.total);
        nodes
    }

    /// Iterate over `(job, nodes)` allocations (unordered).
    pub fn allocations(&self) -> impl Iterator<Item = (u64, u32)> + '_ {
        self.alloc.iter().map(|(&j, &n)| (j, n))
    }
}

/// A step function `t -> free nodes` over `[now, +inf)`.
///
/// Stored as breakpoints `(t_i, free_i)` with `free` constant on
/// `[t_i, t_{i+1})`; the last segment extends to infinity. Invariants:
/// strictly increasing times, `free <= total`.
#[derive(Debug, Clone)]
pub struct Profile {
    total: u32,
    points: Vec<(Time, u32)>,
}

impl Profile {
    /// Start a profile at `now` with `free` nodes free out of `total`.
    pub fn new(now: Time, free: u32, total: u32) -> Self {
        assert!(free <= total);
        Self { total, points: vec![(now, free)] }
    }

    /// Build the scheduler's view from the instantaneous cluster state
    /// and the running jobs' *expected* ends (start + current limit):
    /// each running job releases its nodes at its expected end.
    pub fn from_running(
        now: Time,
        cluster: &Cluster,
        expected_end: impl Fn(u64) -> Time,
    ) -> Self {
        let mut p = Self::new(now, cluster.free(), cluster.total());
        let mut releases: Vec<(Time, u32)> = cluster
            .allocations()
            .map(|(j, n)| (expected_end(j).max(now), n))
            .collect();
        releases.sort_unstable();
        for (t, n) in releases {
            p.add_release(t, n);
        }
        p
    }

    fn start(&self) -> Time {
        self.points[0].0
    }

    /// Index of the segment containing time `t` (t must be >= start).
    fn segment_at(&self, t: Time) -> usize {
        debug_assert!(t >= self.start());
        match self.points.binary_search_by_key(&t, |&(bt, _)| bt) {
            Ok(i) => i,
            Err(i) => i - 1,
        }
    }

    /// Free nodes at time `t`.
    pub fn free_at(&self, t: Time) -> u32 {
        self.points[self.segment_at(t)].1
    }

    /// `free += nodes` for all `t' >= t` (a running job ends at `t`).
    pub fn add_release(&mut self, t: Time, nodes: u32) {
        self.apply(t, Time::MAX, nodes as i64);
    }

    /// `free -= nodes` over `[s, e)` (a reservation or placed job).
    /// Panics if capacity would go negative — callers must check
    /// feasibility first (this preserves the no-over-allocation
    /// invariant through the whole backfill pass).
    pub fn reserve(&mut self, s: Time, e: Time, nodes: u32) {
        assert!(s < e, "empty reservation [{s}, {e})");
        self.apply(s, e, -(nodes as i64));
    }

    /// Add `delta` to the free count over `[s, e)`, splitting segments.
    /// Touches only the affected index range (the profile is the
    /// backfill scheduler's inner loop — see EXPERIMENTS.md §Perf).
    fn apply(&mut self, s: Time, e: Time, delta: i64) {
        let s = s.max(self.start());
        if e <= s {
            return;
        }
        self.ensure_breakpoint(s);
        if e != Time::MAX {
            self.ensure_breakpoint(e);
        }
        let lo = self
            .points
            .binary_search_by_key(&s, |&(bt, _)| bt)
            .expect("breakpoint at s ensured above");
        for i in lo..self.points.len() {
            let (t, free) = self.points[i];
            if e != Time::MAX && t >= e {
                break;
            }
            let nf = free as i64 + delta;
            assert!(
                (0..=self.total as i64).contains(&nf),
                "profile capacity violated at t={t}: {free} + {delta}"
            );
            self.points[i].1 = nf as u32;
        }
    }

    /// Insert a breakpoint at `t` (no-op if one exists).
    fn ensure_breakpoint(&mut self, t: Time) {
        if let Err(i) = self.points.binary_search_by_key(&t, |&(bt, _)| bt) {
            let free = self.points[i - 1].1;
            self.points.insert(i, (t, free));
        }
    }

    /// Earliest `t >= after` such that `nodes` are free during the whole
    /// window `[t, t + duration)`.
    ///
    /// Scans segments left to right; restarts the window whenever a
    /// segment dips below `nodes`. Always succeeds on the infinite final
    /// segment if `nodes <= total` (callers guarantee this).
    pub fn find_earliest(&self, nodes: u32, duration: Time, after: Time) -> Time {
        assert!(nodes <= self.total, "request exceeds cluster size");
        assert!(duration >= 1);
        let after = after.max(self.start());
        let mut candidate: Option<Time> = None;
        let n = self.points.len();
        // Segments ending at or before `after` are irrelevant: start the
        // scan at the segment containing `after`.
        let first = self.segment_at(after);
        for i in first..n {
            let (t, free) = self.points[i];
            let seg_end = if i + 1 < n { self.points[i + 1].0 } else { Time::MAX };
            if free < nodes {
                candidate = None;
                continue;
            }
            let start = candidate.get_or_insert(t.max(after));
            // Window is satisfied once it spans `duration`.
            if seg_end == Time::MAX || seg_end - *start >= duration {
                return *start;
            }
        }
        unreachable!("final segment is infinite");
    }

    /// Breakpoint count (perf observability).
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The raw breakpoints (for tests and reporting).
    pub fn points(&self) -> &[(Time, u32)] {
        &self.points
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allocate_release_roundtrip() {
        let mut c = Cluster::new(20);
        c.allocate(1, 8);
        c.allocate(2, 12);
        assert_eq!(c.free(), 0);
        assert_eq!(c.held_by(1), 8);
        assert!(!c.fits(1));
        assert_eq!(c.release(1), 8);
        assert_eq!(c.free(), 8);
        assert!(c.fits(8));
        assert_eq!(c.running_jobs(), 1);
    }

    #[test]
    #[should_panic(expected = "over-allocation")]
    fn overallocation_panics() {
        let mut c = Cluster::new(4);
        c.allocate(1, 5);
    }

    #[test]
    #[should_panic(expected = "double allocation")]
    fn double_allocation_panics() {
        let mut c = Cluster::new(8);
        c.allocate(1, 2);
        c.allocate(1, 2);
    }

    #[test]
    fn profile_from_running() {
        let mut c = Cluster::new(20);
        c.allocate(1, 8); // ends at 100
        c.allocate(2, 4); // ends at 50
        let p = Profile::from_running(0, &c, |j| if j == 1 { 100 } else { 50 });
        assert_eq!(p.free_at(0), 8);
        assert_eq!(p.free_at(49), 8);
        assert_eq!(p.free_at(50), 12);
        assert_eq!(p.free_at(100), 20);
        assert_eq!(p.free_at(1_000_000), 20);
    }

    #[test]
    fn find_earliest_immediate() {
        let p = Profile::new(10, 5, 20);
        assert_eq!(p.find_earliest(5, 100, 10), 10);
        assert_eq!(p.find_earliest(5, 100, 33), 33);
    }

    #[test]
    fn find_earliest_waits_for_release() {
        let mut p = Profile::new(0, 2, 20);
        p.add_release(100, 10);
        assert_eq!(p.find_earliest(4, 50, 0), 100);
        // 2 nodes fit immediately.
        assert_eq!(p.find_earliest(2, 50, 0), 0);
    }

    #[test]
    fn find_earliest_needs_contiguous_window() {
        // free: 10 on [0,100), 2 on [100,200), 10 on [200,inf)
        let mut p = Profile::new(0, 10, 10);
        p.reserve(100, 200, 8);
        // 60 s of 5 nodes fits in [0,100) starting at 0.
        assert_eq!(p.find_earliest(5, 60, 0), 0);
        // 150 s of 5 nodes cannot straddle the dip -> starts at 200.
        assert_eq!(p.find_earliest(5, 150, 0), 200);
        // after=80 pushes the first window past the dip.
        assert_eq!(p.find_earliest(5, 60, 80), 200);
    }

    #[test]
    fn reserve_splits_segments() {
        let mut p = Profile::new(0, 10, 10);
        p.reserve(50, 150, 4);
        assert_eq!(p.free_at(0), 10);
        assert_eq!(p.free_at(50), 6);
        assert_eq!(p.free_at(149), 6);
        assert_eq!(p.free_at(150), 10);
        p.reserve(100, 120, 6);
        assert_eq!(p.free_at(110), 0);
        assert_eq!(p.free_at(130), 6);
    }

    #[test]
    #[should_panic(expected = "capacity violated")]
    fn reserve_over_capacity_panics() {
        let mut p = Profile::new(0, 4, 10);
        p.reserve(0, 10, 5);
    }

    #[test]
    fn window_restarts_after_dip() {
        // free: 8 on [0,10), 0 on [10,20), 8 on [20,inf)
        let mut p = Profile::new(0, 8, 8);
        p.reserve(10, 20, 8);
        assert_eq!(p.find_earliest(1, 15, 0), 20);
        assert_eq!(p.find_earliest(1, 10, 0), 0);
    }

    #[test]
    fn release_then_reserve_interaction() {
        let mut c = Cluster::new(20);
        c.allocate(7, 20);
        let mut p = Profile::from_running(0, &c, |_| 1000);
        assert_eq!(p.free_at(0), 0);
        // Reserve a future job right at the release point.
        let s = p.find_earliest(12, 500, 0);
        assert_eq!(s, 1000);
        p.reserve(s, s + 500, 12);
        assert_eq!(p.free_at(1000), 8);
        assert_eq!(p.find_earliest(10, 100, 0), 1500);
    }
}
