//! Min-augmented capacity tree: the backfill scheduler's sublinear
//! placement structure.
//!
//! The flat [`Profile`] answers `find_earliest` with a left-to-right
//! scan over its breakpoint array — O(B) per examined job, O(P·B) per
//! backfill pass. At paper scale (20 nodes, ~7 running jobs) that is
//! irrelevant; at the ROADMAP's target regimes (thousands of running
//! jobs and reservations, deep queues, high `bf_max_job_test`) it is
//! the dominant term of every replay, and the autonomy loop makes it
//! worse by dirtying the scheduler on every limit adjustment.
//!
//! [`CapTree`] stores the same step function as a balanced binary tree
//! over the breakpoints, arena-allocated (nodes live in one `Vec`,
//! children are `u32` slot indices, no boxing, no per-node allocation)
//! and augmented with **subtree minimum and maximum free counts** plus
//! a lazy pending-delta per subtree:
//!
//! - `find_earliest` runs by *augmented descent*: whole subtrees whose
//!   min-free already satisfies the request are skipped when hunting
//!   the next blocking dip, and subtrees whose max-free cannot satisfy
//!   it are skipped when hunting the next feasible segment. Each hop is
//!   O(log B); a query costs O((dips crossed + 1)·log B) instead of a
//!   full scan.
//! - `reserve`/`add_release`/`shift_release` are lazy range-adds over
//!   the key range: split, add the delta to one subtree root (with the
//!   capacity check done against the subtree aggregates — exactly
//!   equivalent to the flat per-breakpoint check), merge back. Edge
//!   breakpoints are inserted in O(log B) instead of an O(B) suffix
//!   merge.
//!
//! Tree shape is kept balanced treap-style with deterministic
//! priorities hashed from the arena slot index — no RNG state, no
//! wall-clock, so replays stay exactly reproducible. The structure is
//! behaviourally identical to the flat profile: the differential fuzz
//! (`rust/tests/profile_fuzz.rs`) replays random op sequences against
//! both and asserts identical breakpoints, and the three-way golden
//! equivalence test (`rust/tests/properties.rs`) pins whole-simulation
//! equality of tree-core, flat-core, and the naive seed core.

use crate::simtime::Time;

use super::Profile;

/// Which placement structure the backfill scheduler uses
/// (`SlurmConfig::backfill_profile`; `backfill_profile = "tree"|"flat"`
/// in `configs/*.toml`, `--backfill-profile` on the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackfillProfile {
    /// Min-augmented capacity tree ([`CapTree`]) — the default.
    #[default]
    Tree,
    /// Flat breakpoint-list [`Profile`] — retained as a second oracle
    /// next to the naive seed core, and still the better choice for
    /// tiny profiles.
    Flat,
}

impl BackfillProfile {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "tree" => Some(BackfillProfile::Tree),
            "flat" => Some(BackfillProfile::Flat),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            BackfillProfile::Tree => "tree",
            BackfillProfile::Flat => "flat",
        }
    }
}

/// Arena null: no child.
const NIL: u32 = u32::MAX;

/// Deterministic treap priority for an arena slot: the SplitMix64
/// finalizer over the slot index. Slots are assigned in insertion
/// order, so priorities are independent of keys — the balance argument
/// for random treaps applies — while staying exactly reproducible.
fn prio_for(slot: u32) -> u32 {
    let mut z = (slot as u64).wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    (z ^ (z >> 31)) as u32
}

/// One breakpoint of the step function, as a tree node.
#[derive(Debug, Clone, Copy)]
struct Node {
    /// Breakpoint time; the free level holds on `[t, next key)`.
    t: Time,
    /// Treap heap priority (slot-hashed, deterministic).
    prio: u32,
    left: u32,
    right: u32,
    /// Free nodes on this breakpoint's segment. Correct once every
    /// *ancestor's* pending `lazy` is added.
    val: u32,
    /// Subtree min of `val` (this node included), same convention.
    min: u32,
    /// Subtree max of `val` (this node included), same convention.
    max: u32,
    /// Pending delta for both children's subtrees; already applied to
    /// this node's own `val`/`min`/`max`.
    lazy: i64,
}

/// A step function `t -> free nodes` over `[now, +inf)` as a
/// min/max-augmented balanced tree (see module docs). Same invariants
/// as [`Profile`]: strictly increasing times, values in `[0, total]`,
/// degenerate (equal-value) breakpoints allowed and query-invisible.
#[derive(Debug, Clone)]
pub struct CapTree {
    total: u32,
    /// Node arena; cleared (capacity kept) on reset/copy, never
    /// shrunk — zero steady-state allocations once warm.
    nodes: Vec<Node>,
    root: u32,
    /// First breakpoint's time, cached (it never moves between resets).
    start_t: Time,
    /// Release-collection scratch for [`extend_releases`](Self::extend_releases).
    releases: Vec<(Time, u32)>,
}

impl CapTree {
    /// Start a profile at `now` with `free` nodes free out of `total`.
    pub fn new(now: Time, free: u32, total: u32) -> Self {
        assert!(free <= total);
        let mut tree = Self {
            total,
            nodes: Vec::new(),
            root: NIL,
            start_t: now,
            releases: Vec::new(),
        };
        tree.root = tree.alloc(now, free);
        tree
    }

    /// Reset in place to a single breakpoint, keeping every buffer.
    pub fn reset(&mut self, now: Time, free: u32, total: u32) {
        assert!(free <= total);
        self.total = total;
        self.start_t = now;
        self.nodes.clear();
        self.root = self.alloc(now, free);
    }

    /// Copy `src`'s step function into `self`, reusing `self`'s arena.
    /// One memcpy of the node array — no per-node work.
    pub fn copy_from(&mut self, src: &CapTree) {
        self.total = src.total;
        self.start_t = src.start_t;
        self.root = src.root;
        self.nodes.clear();
        self.nodes.extend_from_slice(&src.nodes);
    }

    fn alloc(&mut self, t: Time, val: u32) -> u32 {
        let slot = self.nodes.len() as u32;
        self.nodes.push(Node {
            t,
            prio: prio_for(slot),
            left: NIL,
            right: NIL,
            val,
            min: val,
            max: val,
            lazy: 0,
        });
        slot
    }

    /// Apply `delta` to a whole subtree (aggregate + pending lazy).
    /// Callers have already proven `0 <= min+delta` and
    /// `max+delta <= total` for the subtree, so the casts are safe.
    fn add_to_subtree(&mut self, idx: u32, delta: i64) {
        if idx == NIL || delta == 0 {
            return;
        }
        let n = &mut self.nodes[idx as usize];
        n.val = (n.val as i64 + delta) as u32;
        n.min = (n.min as i64 + delta) as u32;
        n.max = (n.max as i64 + delta) as u32;
        n.lazy += delta;
    }

    fn push_down(&mut self, idx: u32) {
        let i = idx as usize;
        let lz = self.nodes[i].lazy;
        if lz != 0 {
            let (l, r) = (self.nodes[i].left, self.nodes[i].right);
            self.add_to_subtree(l, lz);
            self.add_to_subtree(r, lz);
            self.nodes[i].lazy = 0;
        }
    }

    fn pull_up(&mut self, idx: u32) {
        let i = idx as usize;
        debug_assert_eq!(self.nodes[i].lazy, 0, "pull_up under pending lazy");
        let (l, r) = (self.nodes[i].left, self.nodes[i].right);
        let mut mn = self.nodes[i].val;
        let mut mx = self.nodes[i].val;
        if l != NIL {
            mn = mn.min(self.nodes[l as usize].min);
            mx = mx.max(self.nodes[l as usize].max);
        }
        if r != NIL {
            mn = mn.min(self.nodes[r as usize].min);
            mx = mx.max(self.nodes[r as usize].max);
        }
        self.nodes[i].min = mn;
        self.nodes[i].max = mx;
    }

    /// Split by key: `(keys < key, keys >= key)`.
    fn split(&mut self, idx: u32, key: Time) -> (u32, u32) {
        if idx == NIL {
            return (NIL, NIL);
        }
        self.push_down(idx);
        if self.nodes[idx as usize].t < key {
            let (l, r) = self.split(self.nodes[idx as usize].right, key);
            self.nodes[idx as usize].right = l;
            self.pull_up(idx);
            (idx, r)
        } else {
            let (l, r) = self.split(self.nodes[idx as usize].left, key);
            self.nodes[idx as usize].left = r;
            self.pull_up(idx);
            (l, idx)
        }
    }

    /// Merge two trees where every key in `a` precedes every key in `b`.
    fn merge(&mut self, a: u32, b: u32) -> u32 {
        if a == NIL {
            return b;
        }
        if b == NIL {
            return a;
        }
        if self.nodes[a as usize].prio >= self.nodes[b as usize].prio {
            self.push_down(a);
            let m = self.merge(self.nodes[a as usize].right, b);
            self.nodes[a as usize].right = m;
            self.pull_up(a);
            a
        } else {
            self.push_down(b);
            let m = self.merge(a, self.nodes[b as usize].left);
            self.nodes[b as usize].left = m;
            self.pull_up(b);
            b
        }
    }

    fn has_key(&self, t: Time) -> bool {
        let mut idx = self.root;
        while idx != NIL {
            let n = &self.nodes[idx as usize];
            if t == n.t {
                return true;
            }
            idx = if t < n.t { n.left } else { n.right };
        }
        false
    }

    /// Insert a breakpoint at `t` carrying its segment's current level,
    /// if one is not already there. O(log B).
    fn ensure_breakpoint(&mut self, t: Time) {
        if self.has_key(t) {
            return;
        }
        let val = self.free_at(t);
        let node = self.alloc(t, val);
        let (a, b) = self.split(self.root, t);
        let ab = self.merge(a, node);
        self.root = self.merge(ab, b);
    }

    /// Add `delta` to the free count over `[s, e)` (`e == Time::MAX`
    /// means the open tail), inserting edge breakpoints when missing —
    /// the tree-side mirror of `Profile::apply`, as a lazy range-add.
    fn apply(&mut self, s: Time, e: Time, delta: i64) {
        let s = s.max(self.start_t);
        if e <= s {
            return;
        }
        self.ensure_breakpoint(s);
        if e != Time::MAX {
            self.ensure_breakpoint(e);
        }
        let (a, bc) = self.split(self.root, s);
        let (b, c) = if e == Time::MAX { (bc, NIL) } else { self.split(bc, e) };
        if b != NIL {
            let nb = &self.nodes[b as usize];
            let (mn, mx) = (nb.min as i64 + delta, nb.max as i64 + delta);
            assert!(
                mn >= 0 && mx <= self.total as i64,
                "profile capacity violated in [{s}, {e}): delta {delta}"
            );
            self.add_to_subtree(b, delta);
        }
        let ab = self.merge(a, b);
        self.root = self.merge(ab, c);
    }

    /// `free += nodes` for all `t' >= t` (a running job ends at `t`).
    pub fn add_release(&mut self, t: Time, nodes: u32) {
        self.apply(t, Time::MAX, nodes as i64);
    }

    /// Move a release previously added at `old` to `new` (a running
    /// job's limit changed). Same semantics as `Profile::shift_release`.
    pub fn shift_release(&mut self, old: Time, new: Time, nodes: u32) {
        use std::cmp::Ordering::*;
        match new.cmp(&old) {
            Equal => {}
            // Released later: the nodes stay busy over [old, new).
            Greater => self.apply(old, new, -(nodes as i64)),
            // Released earlier: free over [new, old).
            Less => self.apply(new, old, nodes as i64),
        }
    }

    /// `free -= nodes` over `[s, e)` (a reservation or placed job).
    /// Panics if capacity would go negative, like the flat profile.
    pub fn reserve(&mut self, s: Time, e: Time, nodes: u32) {
        assert!(s < e, "empty reservation [{s}, {e})");
        self.apply(s, e, -(nodes as i64));
    }

    /// Fold a batch of `(release time, nodes)` pairs into the profile;
    /// result depends only on the multiset of pairs, never on order.
    pub fn extend_releases(&mut self, it: impl IntoIterator<Item = (Time, u32)>) {
        let mut releases = std::mem::take(&mut self.releases);
        releases.clear();
        releases.extend(it);
        releases.sort_unstable();
        for &(t, n) in &releases {
            self.add_release(t, n);
        }
        self.releases = releases;
    }

    /// Free nodes at time `t` (must be >= the profile start): the value
    /// at the greatest key <= `t`, read by a lazy-accumulating descent.
    pub fn free_at(&self, t: Time) -> u32 {
        debug_assert!(t >= self.start_t);
        let mut idx = self.root;
        let mut acc: i64 = 0;
        let mut best: i64 = -1;
        while idx != NIL {
            let n = &self.nodes[idx as usize];
            if n.t <= t {
                best = n.val as i64 + acc;
                acc += n.lazy;
                idx = n.right;
            } else {
                acc += n.lazy;
                idx = n.left;
            }
        }
        debug_assert!(best >= 0, "no breakpoint at or before t={t}");
        best as u32
    }

    /// First breakpoint with key >= `t0` whose free count is below
    /// `nodes`: augmented descent skipping subtrees whose min already
    /// satisfies the request.
    fn first_below(&self, idx: u32, t0: Time, nodes: u32, acc: i64) -> Option<Time> {
        if idx == NIL {
            return None;
        }
        let n = &self.nodes[idx as usize];
        if n.min as i64 + acc >= nodes as i64 {
            return None; // whole subtree stays at or above `nodes`
        }
        let child_acc = acc + n.lazy;
        if n.t > t0 {
            if let Some(hit) = self.first_below(n.left, t0, nodes, child_acc) {
                return Some(hit);
            }
        }
        if n.t >= t0 && (n.val as i64 + acc) < nodes as i64 {
            return Some(n.t);
        }
        self.first_below(n.right, t0, nodes, child_acc)
    }

    /// First breakpoint with key >= `t0` whose free count is at least
    /// `nodes`: augmented descent skipping subtrees whose max cannot.
    fn first_at_least(&self, idx: u32, t0: Time, nodes: u32, acc: i64) -> Option<Time> {
        if idx == NIL {
            return None;
        }
        let n = &self.nodes[idx as usize];
        if (n.max as i64 + acc) < nodes as i64 {
            return None; // whole subtree stays below `nodes`
        }
        let child_acc = acc + n.lazy;
        if n.t > t0 {
            if let Some(hit) = self.first_at_least(n.left, t0, nodes, child_acc) {
                return Some(hit);
            }
        }
        if n.t >= t0 && (n.val as i64 + acc) >= nodes as i64 {
            return Some(n.t);
        }
        self.first_at_least(n.right, t0, nodes, child_acc)
    }

    /// Earliest `t >= after` such that `nodes` are free during the
    /// whole window `[t, t + duration)` — bit-identical to the flat
    /// scan, but hopping dip-to-dip by augmented descent.
    pub fn find_earliest(&self, nodes: u32, duration: Time, after: Time) -> Time {
        assert!(nodes <= self.total, "request exceeds cluster size");
        assert!(duration >= 1);
        let mut cand = after.max(self.start_t);
        if self.free_at(cand) < nodes {
            // The segment containing `after` does not qualify: jump to
            // the first one that does.
            cand = self
                .first_at_least(self.root, cand, nodes, 0)
                .expect("final segment is infinite");
        }
        loop {
            // `cand` sits in a qualifying run; its end is the next dip.
            match self.first_below(self.root, cand + 1, nodes, 0) {
                None => return cand, // run extends to infinity
                Some(dip) => {
                    if dip - cand >= duration {
                        return cand;
                    }
                    cand = self
                        .first_at_least(self.root, dip, nodes, 0)
                        .expect("final segment is infinite");
                }
            }
        }
    }

    /// Breakpoint count (perf observability). Never zero.
    #[allow(clippy::len_without_is_empty)]
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// Write the breakpoints into `out` (cleared first), ascending —
    /// the tree-side mirror of `Profile::points`, for tests/reports.
    pub fn points_into(&self, out: &mut Vec<(Time, u32)>) {
        out.clear();
        self.collect(self.root, 0, out);
    }

    fn collect(&self, idx: u32, acc: i64, out: &mut Vec<(Time, u32)>) {
        if idx == NIL {
            return;
        }
        let n = &self.nodes[idx as usize];
        let child_acc = acc + n.lazy;
        self.collect(n.left, child_acc, out);
        out.push((n.t, (n.val as i64 + acc) as u32));
        self.collect(n.right, child_acc, out);
    }
}

/// The backfill pass's placement structure: the flat breakpoint-list
/// [`Profile`] or the min-augmented [`CapTree`], selected by
/// `SlurmConfig::backfill_profile`. Both expose the same step-function
/// semantics; the differential fuzz and the three-way golden
/// equivalence tests pin them bit-identical.
#[derive(Debug, Clone)]
pub enum CapacityProfile {
    Flat(Profile),
    Tree(CapTree),
}

impl CapacityProfile {
    pub fn new(kind: BackfillProfile, now: Time, free: u32, total: u32) -> Self {
        match kind {
            BackfillProfile::Flat => CapacityProfile::Flat(Profile::new(now, free, total)),
            BackfillProfile::Tree => CapacityProfile::Tree(CapTree::new(now, free, total)),
        }
    }

    pub fn reset(&mut self, now: Time, free: u32, total: u32) {
        match self {
            CapacityProfile::Flat(p) => p.reset(now, free, total),
            CapacityProfile::Tree(t) => t.reset(now, free, total),
        }
    }

    /// Copy `src` into `self`, reusing buffers. Both sides always share
    /// a kind: the scheduler builds them from one config knob.
    pub fn copy_from(&mut self, src: &CapacityProfile) {
        match (self, src) {
            (CapacityProfile::Flat(d), CapacityProfile::Flat(s)) => d.copy_from(s),
            (CapacityProfile::Tree(d), CapacityProfile::Tree(s)) => d.copy_from(s),
            _ => unreachable!("mismatched capacity-profile kinds"),
        }
    }

    pub fn extend_releases(&mut self, it: impl IntoIterator<Item = (Time, u32)>) {
        match self {
            CapacityProfile::Flat(p) => p.extend_releases(it),
            CapacityProfile::Tree(t) => t.extend_releases(it),
        }
    }

    pub fn add_release(&mut self, t: Time, nodes: u32) {
        match self {
            CapacityProfile::Flat(p) => p.add_release(t, nodes),
            CapacityProfile::Tree(tr) => tr.add_release(t, nodes),
        }
    }

    pub fn shift_release(&mut self, old: Time, new: Time, nodes: u32) {
        match self {
            CapacityProfile::Flat(p) => p.shift_release(old, new, nodes),
            CapacityProfile::Tree(t) => t.shift_release(old, new, nodes),
        }
    }

    pub fn reserve(&mut self, s: Time, e: Time, nodes: u32) {
        match self {
            CapacityProfile::Flat(p) => p.reserve(s, e, nodes),
            CapacityProfile::Tree(t) => t.reserve(s, e, nodes),
        }
    }

    pub fn free_at(&self, t: Time) -> u32 {
        match self {
            CapacityProfile::Flat(p) => p.free_at(t),
            CapacityProfile::Tree(tr) => tr.free_at(t),
        }
    }

    pub fn find_earliest(&self, nodes: u32, duration: Time, after: Time) -> Time {
        match self {
            CapacityProfile::Flat(p) => p.find_earliest(nodes, duration, after),
            CapacityProfile::Tree(t) => t.find_earliest(nodes, duration, after),
        }
    }

    /// Breakpoint count (perf observability). Never zero.
    #[allow(clippy::len_without_is_empty)]
    pub fn len(&self) -> usize {
        match self {
            CapacityProfile::Flat(p) => p.len(),
            CapacityProfile::Tree(t) => t.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn points(t: &CapTree) -> Vec<(Time, u32)> {
        let mut out = Vec::new();
        t.points_into(&mut out);
        out
    }

    #[test]
    fn find_earliest_immediate() {
        let p = CapTree::new(10, 5, 20);
        assert_eq!(p.find_earliest(5, 100, 10), 10);
        assert_eq!(p.find_earliest(5, 100, 33), 33);
    }

    #[test]
    fn find_earliest_waits_for_release() {
        let mut p = CapTree::new(0, 2, 20);
        p.add_release(100, 10);
        assert_eq!(p.find_earliest(4, 50, 0), 100);
        // 2 nodes fit immediately.
        assert_eq!(p.find_earliest(2, 50, 0), 0);
    }

    #[test]
    fn find_earliest_needs_contiguous_window() {
        // free: 10 on [0,100), 2 on [100,200), 10 on [200,inf)
        let mut p = CapTree::new(0, 10, 10);
        p.reserve(100, 200, 8);
        assert_eq!(p.find_earliest(5, 60, 0), 0);
        assert_eq!(p.find_earliest(5, 150, 0), 200);
        assert_eq!(p.find_earliest(5, 60, 80), 200);
    }

    #[test]
    fn reserve_splits_segments() {
        let mut p = CapTree::new(0, 10, 10);
        p.reserve(50, 150, 4);
        assert_eq!(p.free_at(0), 10);
        assert_eq!(p.free_at(50), 6);
        assert_eq!(p.free_at(149), 6);
        assert_eq!(p.free_at(150), 10);
        p.reserve(100, 120, 6);
        assert_eq!(p.free_at(110), 0);
        assert_eq!(p.free_at(130), 6);
    }

    #[test]
    #[should_panic(expected = "capacity violated")]
    fn reserve_over_capacity_panics() {
        let mut p = CapTree::new(0, 4, 10);
        p.reserve(0, 10, 5);
    }

    #[test]
    fn window_restarts_after_dip() {
        // free: 8 on [0,10), 0 on [10,20), 8 on [20,inf)
        let mut p = CapTree::new(0, 8, 8);
        p.reserve(10, 20, 8);
        assert_eq!(p.find_earliest(1, 15, 0), 20);
        assert_eq!(p.find_earliest(1, 10, 0), 0);
    }

    #[test]
    fn breakpoints_match_flat_exactly() {
        // Same op sequence against both structures must leave the same
        // breakpoints, including degenerate ones.
        let mut flat = Profile::new(0, 10, 10);
        let mut tree = CapTree::new(0, 10, 10);
        for (s, e, n) in [(50, 150, 4i64), (100, 120, 6), (50, 150, -4), (30, 200, 2)] {
            if n >= 0 {
                flat.reserve(s, e, n as u32);
                tree.reserve(s, e, n as u32);
            } else {
                // "un-reserve" via shift-style positive apply: model a
                // release moving earlier across the window.
                flat.shift_release(e, s, (-n) as u32);
                tree.shift_release(e, s, (-n) as u32);
            }
            assert_eq!(flat.points(), points(&tree).as_slice());
        }
    }

    #[test]
    fn shift_release_matches_flat() {
        let mut flat = Profile::new(0, 6, 16);
        let mut tree = CapTree::new(0, 6, 16);
        flat.extend_releases([(100, 6), (200, 4)]);
        tree.extend_releases([(100, 6), (200, 4)]);
        flat.shift_release(100, 400, 6);
        tree.shift_release(100, 400, 6);
        for t in [0, 99, 100, 150, 200, 399, 400, 10_000] {
            assert_eq!(flat.free_at(t), tree.free_at(t), "t={t}");
        }
        // Grace-re-clamp shape: push a release to just past "now".
        flat.shift_release(200, 301, 4);
        tree.shift_release(200, 301, 4);
        assert_eq!(flat.points(), points(&tree).as_slice());
    }

    #[test]
    fn degenerate_breakpoints_do_not_change_queries() {
        let mut p = CapTree::new(0, 2, 10);
        p.add_release(300, 8);
        p.shift_release(300, 500, 8); // leaves a degenerate point at 300
        assert_eq!(p.free_at(300), 2);
        assert_eq!(p.free_at(500), 10);
        assert_eq!(p.find_earliest(5, 100, 0), 500);
        assert_eq!(p.find_earliest(2, 100, 0), 0);
    }

    #[test]
    fn reset_and_copy_reuse_arena() {
        let mut a = CapTree::new(0, 10, 10);
        a.reserve(10, 20, 3);
        let mut b = CapTree::new(0, 0, 1);
        b.copy_from(&a);
        assert_eq!(points(&a), points(&b));
        assert_eq!(b.free_at(15), 7);
        b.reset(5, 7, 8);
        assert_eq!(points(&b), vec![(5, 7)]);
        assert_eq!(b.free_at(1_000), 7);
        assert_eq!(b.len(), 1);
    }

    #[test]
    fn extend_releases_is_order_insensitive() {
        let mut a = CapTree::new(0, 0, 12);
        a.extend_releases([(300, 4), (100, 4), (200, 4)]);
        let mut b = CapTree::new(0, 0, 12);
        b.extend_releases([(100, 4), (200, 4), (300, 4)]);
        for t in [0, 99, 100, 199, 200, 299, 300, 5000] {
            assert_eq!(a.free_at(t), b.free_at(t), "t={t}");
        }
        assert_eq!(a.free_at(250), 8);
    }

    #[test]
    fn stays_balanced_under_many_breakpoints() {
        // 4k ascending releases then random-order reservations: the
        // slot-hashed priorities must keep queries fast and correct.
        let total = 4_096u32;
        let mut tree = CapTree::new(0, 0, total);
        let mut flat = Profile::new(0, 0, total);
        for i in 0..4_000i64 {
            tree.add_release(10 + i * 7, 1);
            flat.add_release(10 + i * 7, 1);
        }
        let mut rng = crate::proptest_lite::Rng::new(0xCA9);
        for _ in 0..500 {
            let nodes = rng.int_in(1, 64) as u32;
            let dur = rng.int_in(1, 5_000);
            let after = rng.int_in(0, 30_000);
            let s = flat.find_earliest(nodes, dur, after);
            assert_eq!(tree.find_earliest(nodes, dur, after), s);
            flat.reserve(s, s + dur, nodes);
            tree.reserve(s, s + dur, nodes);
        }
        assert_eq!(flat.points(), points(&tree).as_slice());
    }

    #[test]
    fn capacity_profile_dispatches_both_kinds() {
        for kind in [BackfillProfile::Tree, BackfillProfile::Flat] {
            let mut p = CapacityProfile::new(kind, 0, 8, 8);
            p.reserve(10, 20, 8);
            assert_eq!(p.free_at(15), 0);
            assert_eq!(p.find_earliest(1, 15, 0), 20);
            let mut q = CapacityProfile::new(kind, 0, 0, 1);
            q.copy_from(&p);
            assert_eq!(q.free_at(15), 0);
            q.reset(0, 8, 8);
            assert_eq!(q.len(), 1);
            q.extend_releases([(5, 0)]);
            q.add_release(30, 0);
            q.shift_release(30, 40, 0);
            assert_eq!(q.free_at(100), 8);
        }
    }

    #[test]
    fn backfill_profile_parses() {
        assert_eq!(BackfillProfile::parse("tree"), Some(BackfillProfile::Tree));
        assert_eq!(BackfillProfile::parse("flat"), Some(BackfillProfile::Flat));
        assert_eq!(BackfillProfile::parse("nope"), None);
        assert_eq!(BackfillProfile::default(), BackfillProfile::Tree);
        assert_eq!(BackfillProfile::Tree.name(), "tree");
        assert_eq!(BackfillProfile::Flat.name(), "flat");
    }
}
