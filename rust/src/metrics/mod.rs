//! Scheduling metrics and accounting — everything Table 1 reports.
//!
//! Definitions (paper §3, restated in DESIGN.md §4):
//!
//! - **CPU time** of a job: realized execution time × allocated cores
//!   (accounting cores = original trace cores, Marconi-like 48/node).
//! - **Tail waste** of a checkpointing job that did not COMPLETE: CPU
//!   time between its last *completed* checkpoint and its termination.
//!   Non-checkpointing jobs and COMPLETED jobs have zero tail waste.
//! - **Failed tail waste** of a NODE_FAILED job (killed by a node
//!   failure, [`crate::slurm::FailureConfig`]): CPU time since its last
//!   *visible* checkpoint — for a non-checkpointing job the whole run
//!   is lost, since there is nothing to restart from. Accounted in its
//!   own Summary row *and* inside the total tail waste.
//! - **Average wait**: mean of (start − submit) over all jobs.
//! - **Weighted average wait**: node-weighted mean, Σ(nodes·wait)/Σnodes
//!   — the size-fair metric the paper argues for (units: nodes×sec per
//!   node, reported as the paper does).
//! - **Makespan**: max end − min submit.

use crate::simtime::Time;
use crate::slurm::{Adjustment, Job, JobState, SlurmStats};

/// The full set of Table 1 rows for one scenario run.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub policy: String,
    pub total_jobs: usize,
    pub completed: usize,
    /// TIMEOUT jobs *not* touched by the daemon (Table 1 counts
    /// adjusted jobs in their own rows).
    pub timeout: usize,
    pub early_cancelled: usize,
    pub extended: usize,
    pub sched_main: u64,
    pub sched_backfill: u64,
    pub total_checkpoints: u64,
    pub avg_wait: f64,
    pub weighted_avg_wait: f64,
    /// Total tail waste, *including* the failed-job share below.
    pub tail_waste: i64,
    /// Jobs killed by a node failure ([`JobState::NodeFailed`]).
    pub node_failed: usize,
    /// Tail waste of exactly the NODE_FAILED jobs: runtime since each
    /// one's last visible checkpoint (whole runtime when opaque).
    pub failed_tail_waste: i64,
    pub total_cpu_time: i64,
    pub makespan: Time,
}

/// Tail waste of a single (finished) job, in core-seconds.
pub fn job_tail_waste(job: &Job) -> i64 {
    if job.state == JobState::NodeFailed {
        // A node failure loses everything since the last visible
        // checkpoint — and for an opaque job the whole run: unlike a
        // timeout (whose completed work may still be usable output),
        // there is nothing to restart from.
        let (Some(start), Some(end)) = (job.start, job.end) else { return 0 };
        let last = if job.is_checkpointing() {
            job.completed_ckpts(end).last().unwrap_or(start)
        } else {
            start
        };
        return (end - last) * job.spec.cores as i64;
    }
    if !job.is_checkpointing() || job.state == JobState::Completed {
        return 0;
    }
    let (Some(_), Some(end)) = (job.start, job.end) else { return 0 };
    let last_ckpt = job.completed_ckpts(end).last().unwrap_or(job.start.unwrap());
    (end - last_ckpt) * job.spec.cores as i64
}

/// Completed checkpoints of a single (finished) job.
pub fn job_checkpoints(job: &Job) -> u64 {
    match job.end {
        Some(end) => job.completed_ckpts(end).count() as u64,
        None => 0,
    }
}

/// CPU time consumed by a single (finished) job, core-seconds.
pub fn job_cpu_time(job: &Job) -> i64 {
    job.elapsed() * job.spec.cores as i64
}

/// Summarize a finished run.
pub fn summarize(policy: &str, jobs: &[Job], stats: &SlurmStats) -> Summary {
    assert!(
        jobs.iter().all(|j| j.state.is_terminal()),
        "summarize requires a finished run"
    );
    let completed = jobs.iter().filter(|j| j.state == JobState::Completed).count();
    let early_cancelled = jobs
        .iter()
        .filter(|j| j.adjustment == Some(Adjustment::EarlyCancelled))
        .count();
    let extended = jobs.iter().filter(|j| j.adjustment == Some(Adjustment::Extended)).count();
    let timeout = jobs
        .iter()
        .filter(|j| j.state == JobState::Timeout && j.adjustment.is_none())
        .count();

    let waits: Vec<(u32, Time)> = jobs.iter().map(|j| (j.spec.nodes, j.wait().unwrap_or(0))).collect();
    let avg_wait = waits.iter().map(|&(_, w)| w as f64).sum::<f64>() / jobs.len().max(1) as f64;
    let node_sum: f64 = waits.iter().map(|&(n, _)| n as f64).sum();
    let weighted_avg_wait =
        waits.iter().map(|&(n, w)| n as f64 * w as f64).sum::<f64>() / node_sum.max(1.0);

    let makespan = jobs.iter().filter_map(|j| j.end).max().unwrap_or(0)
        - jobs.iter().map(|j| j.spec.submit).min().unwrap_or(0);

    let node_failed = jobs.iter().filter(|j| j.state == JobState::NodeFailed).count();
    let failed_tail_waste = jobs
        .iter()
        .filter(|j| j.state == JobState::NodeFailed)
        .map(job_tail_waste)
        .sum();

    Summary {
        policy: policy.to_string(),
        total_jobs: jobs.len(),
        completed,
        timeout,
        early_cancelled,
        extended,
        sched_main: stats.sched_main_started,
        sched_backfill: stats.sched_backfill_started,
        total_checkpoints: jobs.iter().map(job_checkpoints).sum(),
        avg_wait,
        weighted_avg_wait,
        tail_waste: jobs.iter().map(job_tail_waste).sum(),
        node_failed,
        failed_tail_waste,
        total_cpu_time: jobs.iter().map(job_cpu_time).sum(),
        makespan,
    }
}

impl Summary {
    /// Percentage change of `metric` vs a baseline value (Fig. 4's bars).
    pub fn pct_delta(ours: f64, baseline: f64) -> f64 {
        if baseline == 0.0 { 0.0 } else { (ours - baseline) / baseline * 100.0 }
    }

    /// Tail-waste reduction vs baseline, in percent (the headline 95%).
    pub fn tail_waste_reduction(&self, baseline: &Summary) -> f64 {
        if baseline.tail_waste == 0 {
            0.0
        } else {
            (1.0 - self.tail_waste as f64 / baseline.tail_waste as f64) * 100.0
        }
    }
}

/// Control-plane RPC reduction from AIMD batching, in percent:
/// `unbatched` is what the actions would have cost as one RPC each,
/// `batched` the round trips actually issued. Negative when faults made
/// batching *more* expensive (retried RPCs); `None` when the run landed
/// zero actions — there is no denominator, so any percentage (0%, NaN,
/// ±inf for `batched > 0`) would be a fabricated claim. Callers print
/// `n/a`.
pub fn rpc_reduction(unbatched: u64, batched: u64) -> Option<f64> {
    if unbatched == 0 {
        None
    } else {
        Some((1.0 - batched as f64 / unbatched as f64) * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::{JobId, JobSpec};

    fn finished_job(
        id: u32,
        limit: Time,
        dur: Time,
        nodes: u32,
        ckpt: Option<Time>,
        start: Time,
        end: Time,
        state: JobState,
    ) -> Job {
        let mut spec = JobSpec::new(&format!("j{id}"), limit, dur, nodes);
        if let Some(i) = ckpt {
            spec = spec.with_ckpt(i);
        }
        let mut j = Job::new(JobId(id), spec);
        j.start = Some(start);
        j.end = Some(end);
        j.state = state;
        j
    }

    #[test]
    fn tail_waste_of_paper_canonical_job() {
        // limit 1440, ckpts 420/840/1260, timeout at 1440: tail = 180 s × 48.
        let j = finished_job(0, 1440, 2880, 1, Some(420), 0, 1440, JobState::Timeout);
        assert_eq!(job_tail_waste(&j), 180 * 48);
        assert_eq!(job_checkpoints(&j), 3);
        assert_eq!(job_cpu_time(&j), 1440 * 48);
    }

    #[test]
    fn tail_waste_zero_for_completed_and_opaque() {
        let c = finished_job(0, 1440, 1000, 2, Some(420), 0, 1000, JobState::Completed);
        assert_eq!(job_tail_waste(&c), 0);
        let o = finished_job(1, 600, 1200, 2, None, 0, 600, JobState::Timeout);
        assert_eq!(job_tail_waste(&o), 0);
    }

    #[test]
    fn tail_waste_full_run_if_no_checkpoint_completed() {
        // Interval longer than the limit: zero ckpts, all wasted.
        let j = finished_job(0, 300, 600, 1, Some(400), 100, 400, JobState::Timeout);
        assert_eq!(job_tail_waste(&j), 300 * 48);
        assert_eq!(job_checkpoints(&j), 0);
    }

    #[test]
    fn early_cancel_leaves_only_poll_residue() {
        // Cancelled 12 s after the 1260 ckpt.
        let j = finished_job(0, 1440, 2880, 1, Some(420), 0, 1272, JobState::Cancelled);
        assert_eq!(job_tail_waste(&j), 12 * 48);
    }

    #[test]
    fn node_failed_tail_waste_counts_since_last_visible_ckpt() {
        // Killed 12 s after the 1260 ckpt: same residue as a cancel.
        let j = finished_job(0, 1440, 2880, 1, Some(420), 0, 1272, JobState::NodeFailed);
        assert_eq!(job_tail_waste(&j), 12 * 48);
        // Killed before the first ckpt completes: the whole run so far.
        let k = finished_job(1, 1440, 2880, 2, Some(420), 100, 400, JobState::NodeFailed);
        assert_eq!(job_tail_waste(&k), 300 * 96);
    }

    #[test]
    fn node_failed_opaque_job_loses_the_whole_run() {
        // Unlike a TIMEOUT (zero tail waste for opaque jobs), a node
        // failure leaves nothing to restart from.
        let j = finished_job(0, 600, 1200, 2, None, 50, 450, JobState::NodeFailed);
        assert_eq!(job_tail_waste(&j), 400 * 96);
    }

    #[test]
    fn summary_carries_failed_waste_inside_the_total() {
        let a = finished_job(0, 1440, 2880, 1, Some(420), 0, 1272, JobState::NodeFailed);
        let b = finished_job(1, 1440, 2880, 1, Some(420), 0, 1440, JobState::Timeout);
        let c = finished_job(2, 600, 500, 1, None, 0, 500, JobState::Completed);
        let s = summarize("t", &[a, b, c], &SlurmStats::default());
        assert_eq!(s.node_failed, 1);
        assert_eq!(s.failed_tail_waste, 12 * 48);
        // Total = failed share (12·48) + the timeout's tail (180·48).
        assert_eq!(s.tail_waste, (12 + 180) * 48);
        assert_eq!(s.completed, 1);
        assert_eq!(s.timeout, 1);
    }

    #[test]
    fn rpc_reduction_covers_the_edge_cases() {
        // 16 single-RPC actions collapsed into 4 batches: 75% saved.
        assert!((rpc_reduction(16, 4).unwrap() - 75.0).abs() < 1e-9);
        // Zero actions: no denominator, so no claim — not 0%, not NaN.
        assert_eq!(rpc_reduction(0, 0), None);
        // Zero actions but RPCs issued (all-fault run): still no
        // percentage — the old formula here produced garbage.
        assert_eq!(rpc_reduction(0, 3), None);
        // Fault retries can make batching a net loss — report it as one.
        assert!(rpc_reduction(4, 6).unwrap() < 0.0);
        let v = rpc_reduction(1, 1).unwrap();
        assert!(v.abs() < 1e-9 && !v.is_nan());
    }

    #[test]
    fn weighted_wait_prefers_big_jobs() {
        let jobs = vec![
            finished_job(0, 100, 100, 1, None, 1000, 1100, JobState::Completed),
            finished_job(1, 100, 100, 19, None, 10, 110, JobState::Completed),
        ];
        let s = summarize("t", &jobs, &SlurmStats::default());
        assert!((s.avg_wait - 505.0).abs() < 1e-9);
        // (1*1000 + 19*10) / 20 = 59.5: the big job dominates.
        assert!((s.weighted_avg_wait - 59.5).abs() < 1e-9);
        assert_eq!(s.makespan, 1100);
    }

    #[test]
    fn adjustment_rows_partition_the_timeouts() {
        let mut a = finished_job(0, 1440, 2880, 1, Some(420), 0, 1272, JobState::Cancelled);
        a.adjustment = Some(Adjustment::EarlyCancelled);
        let mut b = finished_job(1, 1690, 2880, 1, Some(420), 0, 1692, JobState::Cancelled);
        b.adjustment = Some(Adjustment::Extended);
        let c = finished_job(2, 600, 1200, 1, None, 0, 600, JobState::Timeout);
        let d = finished_job(3, 600, 500, 1, None, 0, 500, JobState::Completed);
        let s = summarize("t", &[a, b, c, d], &SlurmStats::default());
        assert_eq!(s.early_cancelled, 1);
        assert_eq!(s.extended, 1);
        assert_eq!(s.timeout, 1);
        assert_eq!(s.completed, 1);
    }

    #[test]
    fn reduction_math() {
        let base = Summary {
            tail_waste: 875_520,
            ..summarize("b", &[], &SlurmStats::default())
        };
        let ours = Summary { tail_waste: 43_120, ..base.clone() };
        assert!((ours.tail_waste_reduction(&base) - 95.075).abs() < 0.01);
        assert!((Summary::pct_delta(110.0, 100.0) - 10.0).abs() < 1e-9);
    }
}
