//! Dense per-job tables with a retirement watermark (§Perf).
//!
//! Every hot-path subsystem keys per-job state by the dense
//! [`crate::slurm::JobId`] index: an index and a branch instead of
//! hashing on every access. At federation scale (millions of ids) a
//! naive `Vec<T>` backing makes resident memory O(total ids ever
//! submitted) even though almost all of them are long terminal.
//! [`JobTable`] keeps the same indexed interface but frees a *retired
//! prefix*: once every id below a watermark is terminal — the owner
//! guarantees it only indexes below the watermark through the
//! forgiving [`JobTable::get`] / [`JobTable::get_mut`] accessors —
//! the dead slots are dropped and the base advances, so resident
//! memory tracks the **live id window** (the submitted-but-unretired
//! spread), not total ids.
//!
//! Compaction is amortized O(1) per retired id: the backing `Vec` is
//! drained only once the dead prefix is at least 64 slots *and* at
//! least half the allocation (or all of it), so each element moves
//! O(1) times over its life. `peak_live` records the high-water
//! resident slot count, which [`JobTable::peak_bytes`] converts into
//! the `peak_table_bytes` metric the federation BENCH regime gates.

/// A growable dense table indexed by a *global* id, with a freeable
/// (retired) prefix. Semantically a `Vec<T>` grown with
/// `T::default()`, except indices below the retirement base read as
/// `None` through [`get`](Self::get) and panic through `Index`.
#[derive(Debug, Clone, Default)]
pub struct JobTable<T: Default> {
    /// Global index of `data[0]` — everything below is freed.
    base: usize,
    /// Logical retirement watermark (`base <= retired <= len()`):
    /// slots in `base..retired` are dead but not yet compacted away.
    retired: usize,
    data: Vec<T>,
    /// High-water mark of `data.len()` — the resident-slot peak.
    peak_live: usize,
}

impl<T: Default> JobTable<T> {
    pub fn new() -> Self {
        Self { base: 0, retired: 0, data: Vec::new(), peak_live: 0 }
    }

    /// One past the highest allocated global index (grows, never
    /// shrinks — retirement advances the base, not the end).
    pub fn len(&self) -> usize {
        self.base + self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Global index of the first resident (compacted-to) slot.
    pub fn base(&self) -> usize {
        self.base
    }

    /// Global index below which every slot is retired (logically dead,
    /// possibly not yet compacted).
    pub fn retired(&self) -> usize {
        self.retired
    }

    /// Currently resident slots.
    pub fn live(&self) -> usize {
        self.data.len()
    }

    /// High-water resident slot count over this table's life.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// High-water resident bytes (`peak_live × size_of::<T>()`) — the
    /// per-table contribution to `peak_table_bytes`.
    pub fn peak_bytes(&self) -> usize {
        self.peak_live * std::mem::size_of::<T>()
    }

    /// Grow so `len() >= need`, filling with `T::default()`.
    pub fn ensure(&mut self, need: usize) {
        if need > self.len() {
            self.data.resize_with(need - self.base, T::default);
            self.peak_live = self.peak_live.max(self.data.len());
        }
    }

    /// Forgiving read: `None` for retired (below-base) *and*
    /// never-allocated (past-end) indices alike.
    pub fn get(&self, i: usize) -> Option<&T> {
        i.checked_sub(self.base).and_then(|off| self.data.get(off))
    }

    /// Forgiving write access; same range semantics as
    /// [`get`](Self::get).
    pub fn get_mut(&mut self, i: usize) -> Option<&mut T> {
        i.checked_sub(self.base).and_then(|off| self.data.get_mut(off))
    }

    /// Retire every slot below `watermark`: they become unreadable
    /// through `Index` (still `None` through [`get`](Self::get)) and
    /// their memory is reclaimed on the next amortized compaction.
    /// The watermark is clamped to `len()` and never regresses.
    pub fn retire_to(&mut self, watermark: usize) {
        self.retired = self.retired.max(watermark.min(self.len()));
        let dead = self.retired - self.base;
        // Compact when the dead prefix dominates (or is everything):
        // each element is drained/moved O(1) times over its life.
        if (dead >= 64 && dead * 2 >= self.data.len())
            || (dead > 0 && dead == self.data.len())
        {
            self.data.drain(..dead);
            self.base = self.retired;
            // Return the freed half to the allocator without thrashing
            // on the next growth burst.
            self.data.shrink_to(self.data.len().max(64) * 2);
        }
    }
}

impl<T: Default> std::ops::Index<usize> for JobTable<T> {
    type Output = T;
    fn index(&self, i: usize) -> &T {
        match i.checked_sub(self.base) {
            Some(off) => &self.data[off],
            None => panic!("JobTable: index {i} below retirement base {}", self.base),
        }
    }
}

impl<T: Default> std::ops::IndexMut<usize> for JobTable<T> {
    fn index_mut(&mut self, i: usize) -> &mut T {
        match i.checked_sub(self.base) {
            Some(off) => &mut self.data[off],
            None => panic!("JobTable: index {i} below retirement base {}", self.base),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grows_like_a_vec_and_indexes_globally() {
        let mut t: JobTable<u32> = JobTable::new();
        assert_eq!(t.len(), 0);
        assert!(t.is_empty());
        t.ensure(3);
        assert_eq!(t.len(), 3);
        assert_eq!(t[0], 0);
        t[2] = 7;
        t.ensure(2); // never shrinks
        assert_eq!(t.len(), 3);
        assert_eq!(t[2], 7);
        assert_eq!(t.get(3), None, "past-end get is forgiving");
    }

    #[test]
    fn retire_frees_the_prefix_and_get_stays_forgiving() {
        let mut t: JobTable<Option<i64>> = JobTable::new();
        t.ensure(200);
        for i in 0..200 {
            t[i] = Some(i as i64);
        }
        t.retire_to(150);
        // 150 dead of 200: past both thresholds, so compaction ran.
        assert_eq!(t.base(), 150);
        assert_eq!(t.live(), 50);
        assert_eq!(t.len(), 200, "global length is unaffected");
        assert_eq!(t[175], Some(175));
        assert_eq!(t.get(10), None, "retired get reads None");
        assert_eq!(t.get_mut(10), None);
        // Growth after retirement keeps global semantics.
        t.ensure(210);
        assert_eq!(t.len(), 210);
        assert_eq!(t[205], None);
        // Watermark never regresses.
        t.retire_to(100);
        assert_eq!(t.base(), 150);
    }

    #[test]
    fn compaction_is_thresholded_but_logical_retirement_is_exact() {
        let mut t: JobTable<u8> = JobTable::new();
        t.ensure(1000);
        t.retire_to(10);
        // Dead prefix (10) is below the 64-slot floor: no compaction
        // yet, but the logical watermark holds.
        assert_eq!(t.base(), 0);
        assert_eq!(t.retired(), 10);
        assert_eq!(t.live(), 1000);
        t.retire_to(400);
        // 400 dead of 1000: >= 64 but not >= half — still resident.
        assert_eq!(t.base(), 0);
        t.retire_to(600);
        // 600 of 1000 crosses the half threshold: compacted.
        assert_eq!(t.base(), 600);
        assert_eq!(t.live(), 400);
        // Retiring everything always compacts regardless of size.
        let mut s: JobTable<u8> = JobTable::new();
        s.ensure(8);
        s.retire_to(8);
        assert_eq!(s.base(), 8);
        assert_eq!(s.live(), 0);
    }

    #[test]
    fn peak_tracks_the_high_water_not_the_current_size() {
        let mut t: JobTable<u64> = JobTable::new();
        t.ensure(500);
        t.retire_to(500);
        assert_eq!(t.live(), 0);
        assert_eq!(t.peak_live(), 500);
        assert_eq!(t.peak_bytes(), 500 * std::mem::size_of::<u64>());
        // A smaller live window later never lowers the peak.
        t.ensure(600);
        assert_eq!(t.live(), 100);
        assert_eq!(t.peak_live(), 500);
    }

    #[test]
    #[should_panic(expected = "below retirement base")]
    fn index_below_the_base_panics() {
        let mut t: JobTable<u8> = JobTable::new();
        t.ensure(128);
        t.retire_to(128);
        let _ = t[5];
    }
}
