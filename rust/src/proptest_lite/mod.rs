//! Minimal property-based testing substrate (and the crate's PRNG).
//!
//! The offline vendor set does not include `proptest`, so this module
//! provides the pieces the test suite needs, from scratch:
//!
//! - [`Rng`]: a SplitMix64 PRNG — deterministic, seedable, `u64`/`f64`/
//!   range helpers. Also used by the workload generator and checkpoint
//!   jitter (it is the *only* randomness source in the crate; there is
//!   no wall-clock or OS entropy anywhere, so every run is exactly
//!   reproducible from its seed).
//! - [`run_prop`] / [`run_prop_cases`]: run a property over `n` random
//!   cases; on failure, retry with a simple halving shrink over the
//!   case's seed-derived size parameter and report both the minimal
//!   failing seed and the shrink iteration count that reached it.
//!
//! This is intentionally small: generators are plain
//! `fn(&mut Rng) -> T` closures, and shrinking is seed-replay based
//! (report the failing seed; the failing case is re-derivable), which
//! is what matters for debugging deterministic simulations.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes, and — most
/// importantly — trivially reproducible across platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi]` (inclusive). Panics if `lo > hi`.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick an index by (unnormalized) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Log-uniform integer in `[lo, hi]`: heavy-tailed like HPC job
    /// size/duration distributions.
    pub fn log_int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(0 < lo && lo <= hi);
        let v = self.f64_in((lo as f64).ln(), ((hi + 1) as f64).ln()).exp();
        (v as i64).clamp(lo, hi)
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = (self.next_u64() % (i as u64 + 1)) as usize;
            xs.swap(i, j);
        }
    }
}

/// Outcome of a property over one case.
pub type PropResult = Result<(), String>;

/// Run `prop` over `cases` random cases derived from `base_seed`.
/// Panics (test failure) with the seed of the first failing case, the
/// minimal still-failing seed found by the halving shrink, and how many
/// shrink iterations it took — so the smallest reproduction can be
/// replayed exactly and the shrink's effectiveness is visible.
pub fn run_prop_cases(name: &str, base_seed: u64, cases: u32, mut prop: impl FnMut(&mut Rng) -> PropResult) {
    for i in 0..cases {
        let case_seed = base_seed.wrapping_add(i as u64).wrapping_mul(0x2545f4914f6cdd1d);
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = prop(&mut rng) {
            // Seed-halving shrink: generators draw sizes from the seed
            // stream, so smaller seeds tend to derive smaller cases.
            // Walk the halving chain as long as the property still
            // fails, keeping the last failing seed and its message.
            let (mut min_seed, mut min_msg, mut shrinks) = (case_seed, msg, 0u32);
            let mut candidate = case_seed / 2;
            while candidate < min_seed {
                match prop(&mut Rng::new(candidate)) {
                    Err(m) => {
                        min_seed = candidate;
                        min_msg = m;
                        shrinks += 1;
                        candidate /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property `{name}` failed (case {i}, seed {case_seed:#x}; \
                 minimal seed {min_seed:#x} after {shrinks} shrink iteration(s)): {min_msg}"
            );
        }
    }
}

/// [`run_prop_cases`] with the default case count (64).
pub fn run_prop(name: &str, base_seed: u64, prop: impl FnMut(&mut Rng) -> PropResult) {
    run_prop_cases(name, base_seed, 64, prop)
}

/// Assertion helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)+) => {
        if !$cond {
            return Err(format!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..16).map({ let mut r = Rng::new(1); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..16).map({ let mut r = Rng::new(1); move |_| r.next_u64() }).collect();
        let c: Vec<u64> = (0..16).map({ let mut r = Rng::new(2); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn ranges_are_respected() {
        let mut r = Rng::new(42);
        for _ in 0..10_000 {
            let x = r.int_in(-5, 17);
            assert!((-5..=17).contains(&x));
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
            let l = r.log_int_in(1, 1000);
            assert!((1..=1000).contains(&l));
        }
    }

    #[test]
    fn int_in_covers_endpoints() {
        let mut r = Rng::new(3);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..10_000 {
            match r.int_in(0, 3) {
                0 => lo_seen = true,
                3 => hi_seen = true,
                _ => {}
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn weighted_prefers_heavy_buckets() {
        let mut r = Rng::new(9);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 1.0, 8.0])] += 1;
        }
        assert!(counts[2] > counts[0] * 4);
        assert!(counts[2] > counts[1] * 4);
    }

    #[test]
    fn f64_mean_is_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn run_prop_reports_seed_and_shrink_count() {
        let result = std::panic::catch_unwind(|| {
            run_prop_cases("always_fails", 1, 4, |rng| {
                let x = rng.int_in(0, 100);
                crate::prop_assert!(x > 1000, "x={x} too small");
                Ok(())
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("always_fails"), "{msg}");
        assert!(msg.contains("seed"), "{msg}");
        // An always-failing property shrinks the whole halving chain
        // down to seed 0 — both the minimal seed and the iteration
        // count must be in the report.
        assert!(msg.contains("minimal seed 0x0"), "{msg}");
        assert!(msg.contains("shrink iteration"), "{msg}");
        assert!(!msg.contains("after 0 shrink"), "{msg}");
    }

    #[test]
    fn shrink_stops_at_the_first_passing_seed() {
        // Fails only for seeds >= the original case seed's halving
        // point: the shrink must stop immediately and report the
        // original seed as minimal with zero iterations.
        let result = std::panic::catch_unwind(|| {
            run_prop_cases("no_shrink", 1, 1, |rng| {
                // First case seed is 0x2545f4914f6cdd1d; any halved seed
                // draws a different first u64, so key the failure to the
                // exact original stream.
                let x = rng.next_u64();
                crate::prop_assert!(x != Rng::new(0x2545f4914f6cdd1du64).next_u64(), "original stream");
                Ok(())
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("after 0 shrink iteration(s)"), "{msg}");
        assert!(msg.contains("minimal seed 0x2545f4914f6cdd1d"), "{msg}");
    }

    #[test]
    fn run_prop_passes_trivially() {
        run_prop("tautology", 7, |_| Ok(()));
    }
}
