//! In-process supervisor: run an [`Autonomy`] as a *restartable unit*.
//!
//! [`Supervised`] wraps a journaling daemon behind the [`DaemonHook`]
//! surface. When the daemon "dies" (injected kill points in tests, or
//! a real crash in the CLI supervisor loop that reuses this recovery
//! path), everything in memory is dropped on the floor; the supervisor
//! rebuilds it with [`Autonomy::replay_info`], re-attaches journaling
//! via the tested `enable_journal`-after-`replay` path, and resumes
//! the poll loop. Restart cost is accounted in [`SupervisorStats`].
//!
//! The recovery path is *exactly* the one `rust/tests/journal_replay.rs`
//! pins bit-identical to an uninterrupted unjournaled run —
//! `rust/tests/supervised_replay.rs` re-pins it through this wrapper,
//! including kills landing inside the journal-rotation window
//! ([`KillKind::MidRotation`]).
//!
//! Backoff is capped exponential (100 ms doubling to 5 s). Inside a
//! simulation the supervisor never actually sleeps — sim time is not
//! wall time — so the schedule is *accounted* in
//! [`SupervisorStats::backoff_ms_total`]; the process-level CLI
//! supervisor (`tailtamer supervise`) sleeps it for real.

use std::path::PathBuf;

use crate::simtime::Time;
use crate::slurm::{DaemonHook, SlurmControl};

use super::{Autonomy, DaemonStats};

/// First restart delay of the capped exponential backoff schedule.
pub const BACKOFF_INITIAL_MS: u64 = 100;
/// Backoff ceiling: restarts never wait longer than this.
pub const BACKOFF_CAP_MS: u64 = 5_000;

/// What a supervision episode cost: how often the daemon died and how
/// much work recovery re-did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SupervisorStats {
    /// Daemon deaths handled (each one = full replay + re-attach).
    pub restarts: u64,
    /// Wall time spent inside [`Autonomy::replay_info`], summed.
    pub replay_nanos: u64,
    /// Tick blocks re-executed past the last snapshot, summed over
    /// all restarts.
    pub ticks_recovered: u64,
    /// Backoff the schedule called for, summed (accounted, not slept,
    /// when driving a simulation).
    pub backoff_ms_total: u64,
}

/// How an injected kill lands.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KillKind {
    /// Plain kill -9: the daemon is dropped between journal writes.
    Clean,
    /// The kill lands *inside* the rotation window: the active segment
    /// was already renamed away but the fresh base was never created
    /// (via [`Autonomy::debug_kill_mid_rotation`]), then the daemon is
    /// dropped. Recovery must chain-parse the rotated segments alone.
    MidRotation,
}

/// A supervised daemon: an [`Autonomy`] plus the journal path and
/// snapshot cadence needed to rebuild it from nothing, and an optional
/// schedule of injected kill points (by poll count) for tests.
pub struct Supervised {
    inner: Option<Autonomy>,
    path: PathBuf,
    snapshot_every: u64,
    /// Injected kill points, sorted by poll count.
    kill_at: Vec<(u64, KillKind)>,
    polls: u64,
    kills_done: usize,
    next_backoff_ms: u64,
    stats: SupervisorStats,
}

impl Supervised {
    /// Wrap an already-journaling daemon. `snapshot_every` is pushed
    /// down immediately and re-applied after every restart (replay
    /// does not persist the cadence — it is an operator knob).
    ///
    /// # Panics
    /// If the daemon is not journaling: a supervisor without a journal
    /// has nothing to restart from.
    pub fn new(daemon: Autonomy, path: impl Into<PathBuf>, snapshot_every: u64) -> Self {
        assert!(daemon.journaling(), "a supervised daemon must journal");
        let mut s = Self {
            inner: Some(daemon),
            path: path.into(),
            snapshot_every,
            kill_at: Vec::new(),
            polls: 0,
            kills_done: 0,
            next_backoff_ms: BACKOFF_INITIAL_MS,
            stats: SupervisorStats::default(),
        };
        s.inner.as_mut().unwrap().set_journal_snapshot_every(snapshot_every);
        s
    }

    /// Inject a kill at the given poll count (builder-style; points
    /// are kept sorted). Each fires once, in order.
    pub fn kill_at(mut self, polls: u64, kind: KillKind) -> Self {
        self.kill_at.push((polls, kind));
        self.kill_at.sort_unstable_by_key(|&(p, _)| p);
        self
    }

    /// Injected kills that have fired so far.
    pub fn kills_done(&self) -> usize {
        self.kills_done
    }

    /// Supervision accounting so far.
    pub fn stats(&self) -> SupervisorStats {
        self.stats
    }

    /// The live daemon (for end-of-run assertions).
    pub fn daemon(&self) -> &Autonomy {
        self.inner.as_ref().expect("supervised daemon is always live outside restart")
    }

    /// Consume the wrapper, returning the final deterministic daemon
    /// stats alongside the supervision accounting.
    pub fn into_stats(self) -> (DaemonStats, SupervisorStats) {
        (self.inner.expect("supervised daemon is always live").stats.deterministic(), self.stats)
    }

    /// Kill + restart, shared by injected kill points and (via the CLI
    /// loop) real crashes: drop everything, rebuild from the journal,
    /// re-attach, re-apply the snapshot cadence, account the backoff.
    fn kill_and_restart(&mut self, kind: KillKind) {
        if kind == KillKind::MidRotation {
            // Tear the writer exactly inside the rotation window first:
            // the base segment vanishes mid-rename. Ignore the error —
            // a daemon that already dropped its journal (write failure)
            // still dies; recovery just reads an older chain.
            if let Some(d) = self.inner.as_mut() {
                let _ = d.debug_kill_mid_rotation();
            }
        }
        drop(self.inner.take()); // the crash: nothing survives but the journal
        let t0 = std::time::Instant::now();
        let (mut d, info) = Autonomy::replay_info(&self.path).expect("supervisor replay");
        self.stats.replay_nanos += t0.elapsed().as_nanos() as u64;
        d.enable_journal(&self.path).expect("supervisor re-attach journaling");
        d.set_journal_snapshot_every(self.snapshot_every);
        self.inner = Some(d);
        self.stats.restarts += 1;
        self.stats.ticks_recovered += info.ticks_replayed;
        self.stats.backoff_ms_total += self.next_backoff_ms;
        self.next_backoff_ms = (self.next_backoff_ms * 2).min(BACKOFF_CAP_MS);
    }

    fn maybe_kill(&mut self) {
        if self.kills_done < self.kill_at.len() && self.polls >= self.kill_at[self.kills_done].0 {
            let kind = self.kill_at[self.kills_done].1;
            self.kills_done += 1;
            self.kill_and_restart(kind);
        }
    }
}

impl DaemonHook for Supervised {
    fn poll_period(&self) -> Option<Time> {
        self.daemon().poll_period()
    }

    fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
        self.polls += 1;
        self.maybe_kill();
        self.inner.as_mut().unwrap().on_poll(t, ctl);
    }

    fn poll_elidable(&self) -> bool {
        self.daemon().poll_elidable()
    }

    fn note_elided_polls(&mut self, n: u64) {
        self.inner.as_mut().unwrap().note_elided_polls(n);
    }
}
