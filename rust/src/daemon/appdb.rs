//! Application history database: cross-job checkpoint-interval priors.
//!
//! The paper's future work proposes fine-tuning checkpoint predictions
//! "based on historical/other data from the respective applications".
//! This module implements that loop: every finished reporting job
//! contributes its observed mean interval to a per-application profile
//! (Welford online mean/variance); a *new* job from the same
//! application gets a usable interval estimate after its **first**
//! checkpoint instead of its second — the daemon injects a virtual
//! predecessor timestamp at `t0 − prior_mean`, so the decision engine
//! (Pallas/native, unchanged) sees a two-point history whose mean *is*
//! the prior.
//!
//! Applications are keyed by job name with the trailing run-index
//! stripped (`lammps-0042` → `lammps`), the usual submission-script
//! convention. Profiles persist to a plain `key value value value` text
//! file so the daemon survives restarts with its knowledge intact.

use std::collections::HashMap;
use std::path::Path;

use crate::errors::{Context, Result};

use crate::simtime::Time;

/// Online per-application interval statistics (Welford).
#[derive(Debug, Clone, Default)]
pub struct AppProfile {
    pub runs: u64,
    mean: f64,
    m2: f64,
}

impl AppProfile {
    fn observe(&mut self, interval: f64) {
        self.runs += 1;
        let d = interval - self.mean;
        self.mean += d / self.runs as f64;
        self.m2 += d * (interval - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population std of observed per-run mean intervals.
    pub fn std(&self) -> f64 {
        if self.runs < 2 { 0.0 } else { (self.m2 / self.runs as f64).sqrt() }
    }
}

/// Derive the application key from a job name: strip one trailing
/// run-index group (digits and separators).
pub fn app_key(job_name: &str) -> &str {
    let stripped = job_name.trim_end_matches(|c: char| c.is_ascii_digit());
    let stripped = stripped.trim_end_matches(['-', '_', '.']);
    if stripped.is_empty() { job_name } else { stripped }
}

/// The database.
#[derive(Debug, Default)]
pub struct AppDb {
    profiles: HashMap<String, AppProfile>,
    /// Observations ingested (observability).
    pub observations: u64,
}

impl AppDb {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one finished job's observed mean interval.
    pub fn observe(&mut self, job_name: &str, mean_interval: f64) {
        if !(mean_interval.is_finite() && mean_interval > 0.0) {
            return;
        }
        self.profiles.entry(app_key(job_name).to_string()).or_default().observe(mean_interval);
        self.observations += 1;
    }

    /// Prior (mean, std) for a job's application, if any run history
    /// exists.
    pub fn prior(&self, job_name: &str) -> Option<(f64, f64)> {
        let p = self.profiles.get(app_key(job_name))?;
        (p.runs > 0).then(|| (p.mean(), p.std()))
    }

    /// Inject a virtual predecessor timestamp so a single-checkpoint
    /// history becomes estimable with exactly the prior's mean.
    pub fn seed_history(&self, job_name: &str, history: &[Time]) -> Option<Vec<Time>> {
        if history.len() != 1 {
            return None;
        }
        let (mean, _) = self.prior(job_name)?;
        let t0 = history[0];
        let virt = t0 - mean.round() as Time;
        (virt >= 0).then(|| vec![virt, t0])
    }

    pub fn len(&self) -> usize {
        self.profiles.len()
    }

    pub fn is_empty(&self) -> bool {
        self.profiles.is_empty()
    }

    /// Encode every profile as `key runs mean m2` lines (sorted by
    /// key), the persistence format shared by [`save`](Self::save) and
    /// the journal's state snapshots. The floats are printed with
    /// full round-trip precision, so encode → decode is exact.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        let mut keys: Vec<_> = self.profiles.keys().collect();
        keys.sort();
        for k in keys {
            let p = &self.profiles[k];
            out.push_str(&format!("{k}\t{}\t{}\t{}\n", p.runs, p.mean, p.m2));
        }
        out
    }

    /// Inverse of [`to_text`](Self::to_text). `observations` is not
    /// part of the profile text and stays 0 (persisted restarts start a
    /// fresh ingest count; the journal restores it separately).
    pub fn from_text(text: &str) -> Result<Self> {
        let mut db = Self::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let mut f = line.split('\t');
            let err = || format!("appdb line {}: malformed", i + 1);
            let key = f.next().with_context(err)?.to_string();
            let runs = f.next().with_context(err)?.parse().with_context(err)?;
            let mean = f.next().with_context(err)?.parse().with_context(err)?;
            let m2 = f.next().with_context(err)?.parse().with_context(err)?;
            db.profiles.insert(key, AppProfile { runs, mean, m2 });
        }
        Ok(db)
    }

    /// Persist as `key runs mean m2` lines.
    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_text())
            .with_context(|| format!("write appdb {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read appdb {}", path.display()))?;
        Self::from_text(&text).with_context(|| format!("appdb {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn app_key_strips_run_indices() {
        assert_eq!(app_key("lammps-0042"), "lammps");
        assert_eq!(app_key("gromacs_run_7"), "gromacs_run");
        assert_eq!(app_key("vasp.123"), "vasp");
        assert_eq!(app_key("plain"), "plain");
        assert_eq!(app_key("12345"), "12345"); // all digits: keep
        assert_eq!(app_key("pm100-0007"), "pm100");
    }

    #[test]
    fn welford_matches_naive() {
        let mut p = AppProfile::default();
        let xs = [400.0, 420.0, 440.0, 410.0];
        for x in xs {
            p.observe(x);
        }
        let mean = xs.iter().sum::<f64>() / 4.0;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / 4.0;
        assert!((p.mean() - mean).abs() < 1e-9);
        assert!((p.std() - var.sqrt()).abs() < 1e-9);
    }

    #[test]
    fn priors_shared_across_runs_of_same_app() {
        let mut db = AppDb::new();
        db.observe("sim-001", 420.0);
        db.observe("sim-002", 430.0);
        db.observe("other-1", 100.0);
        let (mean, _) = db.prior("sim-999").unwrap();
        assert!((mean - 425.0).abs() < 1e-9);
        assert_eq!(db.prior("unknown-1"), None);
        assert_eq!(db.len(), 2);
    }

    #[test]
    fn seeding_creates_prior_mean_history() {
        let mut db = AppDb::new();
        db.observe("app-1", 420.0);
        let seeded = db.seed_history("app-2", &[1000]).unwrap();
        assert_eq!(seeded, vec![580, 1000]);
        // Only single-point histories are seeded.
        assert_eq!(db.seed_history("app-2", &[500, 920]), None);
        assert_eq!(db.seed_history("app-2", &[]), None);
        // A prior larger than t0 would go negative: refuse.
        assert_eq!(db.seed_history("app-2", &[100]), None);
    }

    #[test]
    fn garbage_observations_rejected() {
        let mut db = AppDb::new();
        db.observe("x-1", -5.0);
        db.observe("x-1", f64::NAN);
        db.observe("x-1", 0.0);
        assert!(db.is_empty());
        assert_eq!(db.observations, 0);
    }

    #[test]
    fn save_load_roundtrip() {
        let mut db = AppDb::new();
        db.observe("a-1", 400.0);
        db.observe("a-2", 440.0);
        db.observe("b-1", 777.0);
        let path = std::env::temp_dir().join(format!("tt_appdb_{}.tsv", std::process::id()));
        db.save(&path).unwrap();
        let back = AppDb::load(&path).unwrap();
        assert_eq!(back.len(), 2);
        let (m, s) = back.prior("a-3").unwrap();
        assert!((m - 420.0).abs() < 1e-9);
        assert!((s - 20.0).abs() < 1e-9);
        let _ = std::fs::remove_file(&path);
    }
}
