//! The autonomy loop: dynamic job time-limit adjustment.
//!
//! This is the paper's contribution. On every poll tick (default 20 s,
//! matching the paper's daemon) the loop:
//!
//! 1. snapshots the queue (`squeue`): running jobs, pending jobs with
//!    their backfill-predicted starts and free-node counts;
//! 2. reads every running job's checkpoint reports and folds them into
//!    the per-job rolling history ([`crate::ckpt::ReportBook`]);
//! 3. batches all reporting running jobs (R) and all predicted pending
//!    jobs (Q) into one [`DecisionBatch`] and evaluates it on the
//!    configured [`DecisionEngine`] — the AOT-compiled JAX/Pallas model
//!    via PJRT in production, or the native oracle;
//! 4. drives the configured [`DecisionPolicy`] pipeline for every job
//!    whose *predicted next checkpoint does not fit* its current limit
//!    (eligibility gate → fit prediction → action selection → budget
//!    accounting — see [`crate::policy`]). The policy family includes
//!    the paper's three (`early-cancel`, `extend`, `hybrid`) plus
//!    parameterized ones (`extend-budget:<secs>`, `tail-aware:<frac>`,
//!    `hybrid-backoff:<step>`); the legacy enum dispatch is retained
//!    verbatim as a reference driver ([`Autonomy::legacy_reference`])
//!    that the pipeline is pinned bit-identical against.
//!
//! Non-reporting jobs are never touched (the paper's contract), and a
//! job with fewer than two reported checkpoints has no interval
//! estimate, so the loop leaves it alone too.
//!
//! ## Event-driven steady state (§Perf)
//!
//! The loop's steady-state cost is proportional to **change**, not to
//! R, Q, or elapsed time: checkpoint reports arrive through per-job
//! delta cursors ([`SlurmControl::read_new_ckpt_reports_into`], each
//! report ingested exactly once), per-job bookkeeping lives in dense
//! `Vec`s indexed by [`JobId`], engine batches/outputs are pooled
//! arenas, and the control plane elides provably no-op polls entirely
//! (`SlurmConfig::poll_elision` + [`DaemonHook::poll_elidable`]) while
//! keeping the decision trajectory and all deterministic stats
//! bit-identical to blind polling — asserted three ways (elided /
//! blind / naive reference) by `rust/tests/poll_elision.rs`.
//!
//! With on-demand backfill ticks (`backfill_ticks = "on-demand"`, the
//! default since PR 5) the elided-poll fast-forward barrier really is
//! `min(next queued event, next report visibility, next pending
//! backfill *pass*)`: the perpetual 30 s tick no longer sits in the
//! event queue capping every jump at one backfill interval, so a
//! quiet stretch costs the daemon loop O(1) regardless of its length
//! (`rust/tests/backfill_ondemand.rs` pins the equivalence; the
//! `bf<i>_*` fields in BENCH_hotpath.json track the margin).
//!
//! ### Row gating
//!
//! A row whose inputs are unchanged since an evaluation that settled it
//! (fits / no estimate / policy declined) is skipped. The gate key is
//! the job's **total-ingested checkpoint count** (the delta cursor),
//! *not* the rolling-history length: once the history saturates the H
//! window, `len` freezes at cap, and a `len`-keyed gate goes blind to
//! new checkpoints — the seed's latent bug where a job with more than
//! `history_window` fitting checkpoints was never re-evaluated and
//! hence never cancelled. The old key survives only behind
//! [`DaemonConfig::legacy_row_gate`], honored exclusively by the legacy
//! reference driver (regression-pinned in `rust/tests/policy_layer.rs`).
//!
//! ## Known hazards (executable in `rust/tests/`)
//!
//! - **Completion hazard**: the daemon cannot observe true durations. A
//!   *reporting* job that would COMPLETE before its limit, but whose
//!   next checkpoint does not fit, is early-cancelled at its last
//!   checkpoint — destroying the (unsaveable-by-checkpoint but real)
//!   final segment. The paper's workload avoids this by construction:
//!   every checkpointing job there times out at the 24 h cap. Sites
//!   with completing checkpointers should prefer Extend/Hybrid, a
//!   tail-aware threshold, or have apps stop reporting near completion.
//! - **OverTimeLimit interaction**: predictions are made against the
//!   job's *limit*; checkpoints that would land inside a blanket grace
//!   window are treated as not fitting.
//! - **Margin/jitter trade-off**: a non-zero margin (or interval
//!   jitter) can sacrifice a boundary checkpoint that would just have
//!   fit — the paper's Limitations §6.

pub mod appdb;
pub mod supervise;

use std::sync::Arc;

use crate::analytics::{DecisionBatch, DecisionEngine, DecisionOutputs, NativeEngine};
use crate::ckpt::ReportBook;
use crate::jobtable::JobTable;
use crate::policy::{Action, DecisionPolicy, EngineRow, PolicySpec, RowCtx};
use crate::simtime::Time;
use crate::slurm::{Adjustment, DaemonHook, JobId, QueueSnapshot, SlurmControl};
use crate::{error_log, warn_log};

pub use appdb::AppDb;
pub use supervise::{KillKind, Supervised, SupervisorStats};

/// The legacy closed policy enum (paper §3). Kept as the retained
/// reference the [`crate::policy`] pipeline is pinned bit-identical
/// against; new policies exist only as [`PolicySpec`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// No adjustments (the paper's comparison baseline).
    Baseline,
    /// Cancel after the last checkpoint that fits the initial limit.
    EarlyCancel,
    /// Always extend to accommodate one more checkpoint.
    Extend,
    /// Extend iff no queued job would be delayed; else cancel early.
    Hybrid,
}

impl Policy {
    pub const ALL: [Policy; 4] = [Policy::Baseline, Policy::EarlyCancel, Policy::Extend, Policy::Hybrid];

    pub fn name(self) -> &'static str {
        match self {
            Policy::Baseline => "Baseline",
            Policy::EarlyCancel => "Early Cancellation",
            Policy::Extend => "Time Limit Extension",
            Policy::Hybrid => "Hybrid Approach",
        }
    }
    // Parsing lives in `crate::policy` (the REGISTRY is the single
    // name/alias authority); convert with `PolicySpec::from(policy)`.
}

/// Daemon tuning knobs.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// Poll period, seconds (paper: 20 — chosen to avoid overloading
    /// Slurm).
    pub poll_period: Time,
    /// Safety margin added to the predicted next checkpoint when
    /// deciding fit and when setting an extended limit, seconds.
    pub margin: Time,
    /// Extra margin in units of the interval's std (jitter tolerance).
    pub safety: f64,
    /// Rolling checkpoint-history window (must be <= the largest
    /// compiled H variant).
    pub history_window: usize,
    /// Queued jobs whose predicted start lies further than this beyond
    /// the latest candidate's current end cannot be delayed by any
    /// plausible one-checkpoint extension and are filtered out of the
    /// conflict batch (keeps Q small on deep queues).
    pub conflict_horizon: Time,
    /// Threshold-Hybrid: extend when the engine's worst-case delay cost
    /// (node-seconds of queued-job push-back) is at or below this. The
    /// paper's strict Hybrid is 0 — extend only when *no* job would be
    /// delayed.
    pub max_delay_cost: f64,
    /// Learn per-application interval priors across jobs ([`AppDb`],
    /// the paper's future-work item): a returning application becomes
    /// estimable after its *first* checkpoint.
    pub use_priors: bool,
    /// Row / queue chunk sizes per engine call. Defaults match the
    /// largest shipped artifact variant (R=64, Q=256); larger batches
    /// are split — `fits`/`pred` come from the first queue chunk and
    /// the conflict flag ORs across chunks (it is OR-decomposable).
    pub chunk_r: usize,
    pub chunk_q: usize,
    /// Reference-only: key the row gate on the saturating history
    /// length instead of the total-ingested cursor, reproducing the
    /// seed's latent blind spot (a job with more than `history_window`
    /// fitting checkpoints is never re-evaluated). Honored **only** by
    /// [`Autonomy::legacy_reference`]; the pipeline driver always uses
    /// the fixed gate.
    pub legacy_row_gate: bool,
    /// Windowed token-bucket budget for **retries** of rejected control
    /// actions, per action class (`scontrol` / `scancel`): at most this
    /// many retries per [`retry_window`](Self::retry_window). First
    /// attempts are never budgeted, so a clean control surface is
    /// bit-identical to an unbudgeted daemon. When a class is
    /// exhausted the daemon degrades that row to a no-op for the tick
    /// (recorded as [`DaemonStats::budget_exhausted`]) and retries once
    /// the window refills. 0 = unlimited (the pre-budget behavior).
    pub retry_budget: u32,
    /// Refill window for [`retry_budget`](Self::retry_budget), sim
    /// seconds — deterministic: refill depends only on the poll's sim
    /// time, never on the wall clock.
    pub retry_window: Time,
    /// Collect every limit update of a tick and flush them through the
    /// batched [`SlurmControl::scontrol_update_limits`] call instead of
    /// one RPC per job, with an AIMD controller sizing the in-flight
    /// window from the observed rejection rate (pipeline driver only;
    /// the legacy reference always issues singles).
    pub batch_actions: bool,
    /// AIMD ceiling for the in-flight batch window.
    pub batch_window: usize,
    /// Append an event-sourced journal of every tick here (see
    /// [`crate::journal`]); a crashed daemon is rebuilt from it via
    /// [`Autonomy::replay`]. `None` = no journal.
    pub journal_path: Option<String>,
    /// Rotate the active journal segment at the next snapshot once it
    /// exceeds this many bytes; rotated segments beyond
    /// [`journal_keep_segments`](Self::journal_keep_segments) are
    /// pruned, bounding disk over unbounded uptime. 0 = never rotate
    /// (one unbounded file, the pre-rotation behavior).
    pub journal_rotate_bytes: u64,
    /// Rotated journal segments retained before pruning (the active
    /// segment is always kept on top of these).
    pub journal_keep_segments: u32,
    /// AIMD ceiling for concurrent in-flight `scontrol` RPCs when
    /// `batch_actions` is on: the second AIMD controller sizes
    /// *parallelism* across a worker pool (additive increase on clean
    /// completions, halve on any rejection/timeout) while
    /// [`batch_window`](Self::batch_window) sizes batch *width*.
    /// 1 = serial (the default; the clean surface is bit-identical to
    /// serial by construction — only transports that override
    /// [`SlurmControl::scontrol_update_limits_concurrent`] actually
    /// parallelize).
    pub rpc_concurrency: u32,
    /// Node-failure MTBF the cluster is configured with (`[failures]`
    /// mtbf, threaded through by [`crate::config`]). 0 = no failures.
    /// The `tail-aware` policy turns it into a hazard rate so the
    /// value of a completed checkpoint rises as MTBF drops; 0 keeps
    /// every policy bit-identical to the pre-failure daemon.
    pub failure_mtbf: Time,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            poll_period: 20,
            margin: 30,
            safety: 0.0,
            history_window: 32,
            conflict_horizon: 3600,
            max_delay_cost: 0.0,
            use_priors: false,
            chunk_r: 64,
            chunk_q: 256,
            legacy_row_gate: false,
            retry_budget: 8,
            retry_window: 600,
            batch_actions: false,
            batch_window: 16,
            journal_path: None,
            journal_rotate_bytes: 0,
            journal_keep_segments: 2,
            rpc_concurrency: 1,
            failure_mtbf: 0,
        }
    }
}

/// Deterministic windowed token bucket: refill is driven purely by the
/// poll's *sim* time (whole elapsed windows restore full capacity), so
/// two replays of the same schedule spend identically — the budget
/// layer stays inside the bit-identity doctrine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct TokenBucket {
    capacity: u32,
    window: Time,
    tokens: u32,
    last_refill: Time,
}

impl TokenBucket {
    fn new(capacity: u32, window: Time) -> Self {
        Self { capacity, window, tokens: capacity, last_refill: 0 }
    }

    /// Take one token at sim time `now`. Zero capacity means
    /// "unlimited" and always succeeds.
    fn try_take(&mut self, now: Time) -> bool {
        if self.capacity == 0 {
            return true;
        }
        if self.window > 0 && now >= self.last_refill + self.window {
            let periods = (now - self.last_refill) / self.window;
            self.last_refill += periods * self.window;
            self.tokens = self.capacity;
        }
        if self.tokens > 0 {
            self.tokens -= 1;
            true
        } else {
            false
        }
    }
}

/// Observability counters for the loop itself.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct DaemonStats {
    pub polls: u64,
    pub engine_calls: u64,
    pub engine_nanos: u64,
    pub batch_rows: u64,
    pub cancels: u64,
    pub extensions: u64,
    /// scancel of an extended job after its bonus checkpoint.
    pub post_extension_cancels: u64,
    pub scontrol_errors: u64,
    /// Rows whose estimate came from an application prior (cold start).
    pub prior_seeded_rows: u64,
    /// Extension seconds granted (budget accounting, all policies).
    pub budget_spent: u64,
    /// `Leave` verdicts issued (tail-aware): counts decline *events*,
    /// not jobs — a declined row is re-presented whenever its inputs
    /// change (a new checkpoint, a limit move), so one job can decline
    /// several times over its life.
    pub policy_declines: u64,
    /// Retries suppressed because the action class's token bucket was
    /// empty ([`DaemonConfig::retry_budget`]): the row degraded to a
    /// no-op for that tick and is retried once the window refills.
    pub budget_exhausted: u64,
    /// Batched `scontrol_update_limits` RPCs issued
    /// ([`DaemonConfig::batch_actions`]).
    pub batch_calls: u64,
    /// Limit updates carried by those batched RPCs (the RPC saving is
    /// `batched_updates - batch_calls`).
    pub batched_updates: u64,
}

impl DaemonStats {
    /// Copy with the wall-clock `engine_nanos` zeroed — everything
    /// left is deterministic, so two runs of the same replay compare
    /// bit-identically (the golden-equivalence suites and the
    /// elided-vs-blind bench race compare these).
    pub fn deterministic(&self) -> DaemonStats {
        DaemonStats { engine_nanos: 0, ..self.clone() }
    }

    /// Fold another daemon's counters into this one — the federation
    /// recombination sums per-shard autonomy stats into one record
    /// ([`crate::slurm::fed`]). Field-exhaustive by construction: the
    /// struct literal below fails to compile if a counter is added
    /// without deciding how it merges.
    pub fn absorb(&mut self, o: &DaemonStats) {
        *self = DaemonStats {
            polls: self.polls + o.polls,
            engine_calls: self.engine_calls + o.engine_calls,
            engine_nanos: self.engine_nanos + o.engine_nanos,
            batch_rows: self.batch_rows + o.batch_rows,
            cancels: self.cancels + o.cancels,
            extensions: self.extensions + o.extensions,
            post_extension_cancels: self.post_extension_cancels + o.post_extension_cancels,
            scontrol_errors: self.scontrol_errors + o.scontrol_errors,
            prior_seeded_rows: self.prior_seeded_rows + o.prior_seeded_rows,
            budget_spent: self.budget_spent + o.budget_spent,
            policy_declines: self.policy_declines + o.policy_declines,
            budget_exhausted: self.budget_exhausted + o.budget_exhausted,
            batch_calls: self.batch_calls + o.batch_calls,
            batched_updates: self.batched_updates + o.batched_updates,
        };
    }
}

/// Which decision driver an [`Autonomy`] instance runs.
enum Driver {
    /// The retained legacy enum dispatch — the reference the pipeline
    /// is golden-tested against. Not constructible from config.
    Legacy(Policy),
    /// The [`crate::policy`] staged pipeline (the default).
    Pipeline(Box<dyn DecisionPolicy>),
}

/// The time-limit adjustment daemon.
///
/// All per-job bookkeeping is held in dense [`JobTable`]s indexed by
/// the dense [`JobId`] — an index and a branch instead of hashing on
/// every poll row (§Perf; the reference core keeps its maps by
/// design). Running membership is tick-stamped so "clearing" the set
/// is O(1). At federation scale the control plane retires the leading
/// terminal id prefix ([`DaemonHook::retire_to`]), so resident table
/// memory is O(live id window), not O(ids ever submitted).
pub struct Autonomy {
    /// The parsed policy this daemon runs (reporting key:
    /// [`PolicySpec::name`]).
    pub spec: PolicySpec,
    pub cfg: DaemonConfig,
    driver: Driver,
    /// `cfg.legacy_row_gate` resolved against the driver: only the
    /// legacy reference may reproduce the saturating-length gate.
    legacy_gate: bool,
    engine: Box<dyn DecisionEngine>,
    book: ReportBook,
    /// Dense by job id: extensions granted so far (legacy policies cap
    /// at one; `extend-budget` keeps going while the budget lasts).
    ext_count: JobTable<u32>,
    /// Dense by job id: extension seconds granted so far (stage-4
    /// budget accounting, fed back to policies via [`RowCtx`]).
    ext_secs: JobTable<Time>,
    /// Dense by job id: control actions rejected so far (feeds the
    /// backoff policy's widening fit margin).
    rejected: JobTable<u32>,
    /// Dense by job id: jobs we are done with (cancelled).
    acted: JobTable<bool>,
    /// Dense by job id: reports consumed so far — the delta-read cursor
    /// handed to [`SlurmControl::read_new_ckpt_reports_into`], so each
    /// checkpoint is ingested exactly once over a job's life (§Perf).
    /// Doubles as the row-gate key (total-ingested count; see module
    /// docs "Row gating").
    report_cursor: JobTable<usize>,
    /// Cross-job application priors (future-work feature; fed and used
    /// only when `cfg.use_priors`).
    pub db: AppDb,
    /// Dense by job id: names of currently tracked reporting jobs (set
    /// only under `cfg.use_priors`, for the appdb); interned, so
    /// tracking a job never copies its name.
    names: JobTable<Option<Arc<str>>>,
    /// Reporting jobs with live [`ReportBook`] state — the harvest
    /// sweep's iteration order; entries leave when the job leaves the
    /// running set (so book memory is reclaimed for *every* finished
    /// reporting job, not just cancelled or prior-tracked ones).
    tracked: Vec<JobId>,
    /// Dense by job id: membership flag for `tracked` (O(1) dedup).
    in_tracked: JobTable<bool>,
    /// Dense by job id: (gate key, cur_end) → verdict cache.
    /// A row whose inputs are unchanged and whose verdict was stable
    /// (fits / no estimate / policy declined) cannot newly need action,
    /// so it is skipped — this collapses the steady-state poll tick to
    /// zero engine calls (§Perf).
    row_cache: JobTable<Option<(usize, Time, f32)>>,
    /// Dense by job id: tick stamp marking current running membership
    /// (`== tick_no` means "seen running this tick"; O(1) clear).
    running_mark: JobTable<u64>,
    /// Highest retirement watermark received via
    /// [`DaemonHook::retire_to`]. Applied clamped by the lowest still
    /// tracked id (the book keeps reporting state until the job leaves
    /// the running set), so retirement is purely base-advancing and
    /// never reorders any policy-visible observation — the retired and
    /// grow-only runs stay bit-identical.
    retire_watermark: u32,
    tick_no: u64,
    /// Rows whose ¬fits action did not terminate the job this tick —
    /// they are re-evaluated every poll, so while any are pending the
    /// control plane must not elide polls ([`DaemonHook::poll_elidable`]).
    pending_retries: usize,
    /// Latched on an engine failure: stop claiming polls elidable (the
    /// blind reference would keep retrying the failing evaluation).
    engine_errored: bool,
    /// Retry budget for the `scontrol` action class (extensions).
    scontrol_budget: TokenBucket,
    /// Retry budget for the `scancel` action class.
    scancel_budget: TokenBucket,
    /// AIMD in-flight window for batched limit updates: +1 per clean
    /// window, halved on any rejection, clamped to
    /// `[1, cfg.batch_window]`.
    aimd_window: usize,
    /// The second AIMD controller: RPC *parallelism* requested from the
    /// transport for each batched flush (+1 per fully clean flush
    /// window, halved on any rejection/timeout, clamped to
    /// `[1, cfg.rpc_concurrency]`). Advisory for transports without a
    /// worker pool — the default trait method runs serially, so the
    /// clean in-sim surface stays bit-identical.
    aimd_rpc: usize,
    /// Event-sourced journal ([`crate::journal`]); every tick's inputs
    /// and action results are appended so [`Autonomy::replay`] can
    /// rebuild this exact state. Dropped (with an error log) on the
    /// first write failure — journaling must never wedge the loop.
    journal: Option<crate::journal::JournalWriter>,
    /// Pooled per-tick buffers: the poll path allocates nothing in the
    /// steady state (§Perf).
    scratch: TickScratch,
    pub stats: DaemonStats,
}

/// What a [`Autonomy::replay_info`] recovery cost: journaled work
/// re-run past the restored snapshot, and the shape of the journal
/// chain it read.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplayInfo {
    /// Tick blocks re-executed after the last complete snapshot.
    pub ticks_replayed: u64,
    /// Elided/inactive polls re-counted after the last snapshot.
    pub polls_recovered: u64,
    /// Segment files the chain parse walked (1 for unrotated journals).
    pub segments: usize,
}

/// One deferred limit update awaiting the batched end-of-tick flush.
/// `new_limit`/`granted_end` are computed from the tick-start snapshot
/// row — sim time is frozen for the tick and the daemon is the only
/// limit writer, so they equal what the per-row `extend_to` fresh
/// `squeue` would have produced (the batched-vs-single golden test
/// pins this).
#[derive(Debug, Clone, Copy)]
struct PendingUpdate {
    idx: usize,
    id: JobId,
    cur_end: Time,
    new_limit: Time,
    granted_end: Time,
}

/// Reused buffers for [`Autonomy::tick`] (swapped out during the tick
/// so the borrow checker sees them as independent of `self`).
#[derive(Default)]
struct TickScratch {
    snap: QueueSnapshot,
    reports: Vec<Time>,
    /// Candidate rows: (id, cur_end, nodes, start).
    rows: Vec<(JobId, Time, u32, Time)>,
    /// Conflict-relevant queued jobs: (pred start, nodes, free at start).
    q_rows: Vec<(Time, u32, u32)>,
    /// Pooled engine-call arenas: the per-chunk input batch, the
    /// per-call outputs, and the combined whole-tick outputs (§Perf).
    batch: DecisionBatch,
    chunk_out: DecisionOutputs,
    out: DecisionOutputs,
    /// Deferred batched limit updates (`DaemonConfig::batch_actions`).
    updates: Vec<PendingUpdate>,
    /// Pooled `(id, limit)` argument buffer for the batched RPC.
    update_call: Vec<(JobId, Time)>,
}

/// Row-cache verdict for a not-fitting row the policy deliberately left
/// alone: stable (skippable) until the row's inputs change, but
/// distinguishable from a real "fits" in debugging.
const VERDICT_DECLINED: f32 = 2.0;

impl Autonomy {
    /// Daemon running `spec` on the staged [`crate::policy`] pipeline
    /// (the production path; accepts a legacy [`Policy`] too).
    pub fn new(
        spec: impl Into<PolicySpec>,
        cfg: DaemonConfig,
        engine: Box<dyn DecisionEngine>,
    ) -> Self {
        let spec = spec.into();
        let driver = Driver::Pipeline(spec.compile(&cfg));
        Self::build(spec, cfg, driver, engine)
    }

    /// The retained legacy enum driver — the golden reference for the
    /// pipeline re-expression of the paper's three policies, and the
    /// only constructor honoring [`DaemonConfig::legacy_row_gate`].
    pub fn legacy_reference(policy: Policy, cfg: DaemonConfig) -> Self {
        Self::build(policy.into(), cfg, Driver::Legacy(policy), Box::new(NativeEngine::new()))
    }

    fn build(
        spec: PolicySpec,
        cfg: DaemonConfig,
        driver: Driver,
        engine: Box<dyn DecisionEngine>,
    ) -> Self {
        let window = cfg.history_window;
        let legacy_gate = cfg.legacy_row_gate && matches!(driver, Driver::Legacy(_));
        let budget = TokenBucket::new(cfg.retry_budget, cfg.retry_window);
        let journal_path = cfg.journal_path.clone();
        let mut d = Self {
            spec,
            cfg,
            driver,
            legacy_gate,
            engine,
            book: ReportBook::new(window),
            ext_count: JobTable::new(),
            ext_secs: JobTable::new(),
            rejected: JobTable::new(),
            acted: JobTable::new(),
            report_cursor: JobTable::new(),
            db: AppDb::new(),
            names: JobTable::new(),
            tracked: Vec::new(),
            in_tracked: JobTable::new(),
            row_cache: JobTable::new(),
            running_mark: JobTable::new(),
            retire_watermark: 0,
            tick_no: 0,
            pending_retries: 0,
            engine_errored: false,
            scontrol_budget: budget,
            scancel_budget: budget,
            aimd_window: 1,
            aimd_rpc: 1,
            journal: None,
            scratch: TickScratch::default(),
            stats: DaemonStats::default(),
        };
        if let Some(path) = journal_path {
            if let Err(e) = d.enable_journal(&path) {
                error_log!("journal {path}: {e}; continuing without durability");
            }
        }
        d
    }

    /// Grow every dense per-job table to cover `id`.
    fn ensure_slot(&mut self, id: JobId) {
        let need = id.0 as usize + 1;
        self.ext_count.ensure(need);
        self.ext_secs.ensure(need);
        self.rejected.ensure(need);
        self.acted.ensure(need);
        self.report_cursor.ensure(need);
        self.names.ensure(need);
        self.in_tracked.ensure(need);
        self.row_cache.ensure(need);
        self.running_mark.ensure(need);
    }

    /// Apply the latest control-plane retirement watermark, clamped by
    /// the lowest still-tracked reporting job: tracked ids keep their
    /// book/name/cursor state until [`harvest_finished`] drops them, so
    /// the clamp guarantees every live access stays at or above the
    /// table base. Purely base-advancing — no priors are banked, no
    /// observation is made or reordered — so policy behavior (and the
    /// AppDb f64 accumulation order under `use_priors`) is untouched
    /// and retired runs stay bit-identical to grow-only runs.
    fn apply_retirement(&mut self) {
        let mut w = self.retire_watermark as usize;
        if let Some(min) = self.tracked.iter().map(|id| id.0 as usize).min() {
            w = w.min(min);
        }
        if w > self.ext_count.base() {
            self.ext_count.retire_to(w);
            self.ext_secs.retire_to(w);
            self.rejected.retire_to(w);
            self.acted.retire_to(w);
            self.report_cursor.retire_to(w);
            self.names.retire_to(w);
            self.in_tracked.retire_to(w);
            self.row_cache.retire_to(w);
            self.running_mark.retire_to(w);
            self.book.retire_to(w);
        }
    }

    /// High-water resident bytes across the daemon's dense per-job
    /// tables and the report book (the federation BENCH metric's
    /// daemon share).
    pub fn peak_table_bytes(&self) -> usize {
        self.ext_count.peak_bytes()
            + self.ext_secs.peak_bytes()
            + self.rejected.peak_bytes()
            + self.acted.peak_bytes()
            + self.report_cursor.peak_bytes()
            + self.names.peak_bytes()
            + self.in_tracked.peak_bytes()
            + self.row_cache.peak_bytes()
            + self.running_mark.peak_bytes()
            + self.book.peak_bytes()
    }

    /// Ids whose daemon-side slots have been reclaimed (table base).
    pub fn jobs_retired(&self) -> u64 {
        self.ext_count.base() as u64
    }

    /// Convenience: native-engine daemon (tests, fallback).
    pub fn native(spec: impl Into<PolicySpec>, cfg: DaemonConfig) -> Self {
        Self::new(spec, cfg, Box::new(NativeEngine::new()))
    }

    pub fn engine_name(&self) -> &str {
        self.engine.name()
    }

    /// Whether the daemon adjusts anything (false: Baseline).
    fn active(&self) -> bool {
        match &self.driver {
            Driver::Legacy(p) => *p != Policy::Baseline,
            Driver::Pipeline(p) => p.active(),
        }
    }

    /// Row-gate key: the total-ingested checkpoint count (fixed), or
    /// the saturating history length (reference-only legacy mode).
    fn gate_key(&self, idx: usize, id: JobId) -> usize {
        if self.legacy_gate {
            self.book.history(id).map_or(0, |h| h.len())
        } else {
            self.report_cursor[idx]
        }
    }

    /// One autonomy-loop iteration. Public so live mode and benches can
    /// drive it without the simulator's event loop.
    pub fn tick(&mut self, now: Time, ctl: &mut dyn SlurmControl) {
        self.stats.polls += 1;
        if !self.active() {
            // An inactive (Baseline) poll still counts: journal it so
            // a replayed daemon's poll counter stays bit-identical.
            if let Some(j) = self.journal.as_mut() {
                if let Err(e) = j.note_polls(1) {
                    error_log!("journal write failed, disabling: {e}");
                    self.journal = None;
                }
            }
            return;
        }
        // Swap the pooled buffers, the driver, and the journal out so
        // the tick body can borrow them alongside `self`; swapped back
        // intact.
        let mut scratch = std::mem::take(&mut self.scratch);
        let driver = std::mem::replace(&mut self.driver, Driver::Legacy(Policy::Baseline));
        match self.journal.take() {
            None => self.tick_inner(now, ctl, &mut scratch, &driver),
            Some(mut j) => {
                // Record every control-surface interaction of this tick
                // as one atomic journal block (torn tails are discarded
                // on replay).
                j.begin_tick(now);
                let mut rec = crate::journal::RecordingCtl::new(ctl, &mut j);
                self.tick_inner(now, &mut rec, &mut scratch, &driver);
                match j.end_tick() {
                    Err(e) => error_log!("journal write failed, disabling: {e}"),
                    Ok(()) => self.journal = Some(j),
                }
            }
        }
        self.driver = driver;
        self.scratch = scratch;
        // The tick may have dropped tracked jobs (harvest), unblocking
        // a deferred control-plane retirement watermark.
        self.apply_retirement();
        // Periodic full-state snapshot: bounds replay to the tail of
        // the journal (taken outside the swap so it sees whole `self`).
        if self.journal.as_ref().is_some_and(|j| j.snapshot_due()) {
            let state = self.snapshot_state();
            let mut j = self.journal.take().expect("checked above");
            match j.snapshot(&state) {
                Err(e) => error_log!("journal snapshot failed, disabling: {e}"),
                Ok(()) => self.journal = Some(j),
            }
        }
    }

    fn tick_inner(
        &mut self,
        now: Time,
        ctl: &mut dyn SlurmControl,
        scratch: &mut TickScratch,
        driver: &Driver,
    ) {
        ctl.squeue_into(&mut scratch.snap);
        self.tick_no += 1;

        // Ingest new reports (delta cursors); collect candidate rows.
        scratch.rows.clear();
        for r in &scratch.snap.running {
            self.ensure_slot(r.id);
            let idx = r.id.0 as usize;
            self.running_mark[idx] = self.tick_no;
            if self.acted[idx] {
                continue;
            }
            // Delta read: only reports past this job's cursor cross the
            // control surface; each checkpoint is ingested exactly once
            // over the job's life instead of the full O(C) prefix being
            // re-read every 20 s (§Perf).
            let mut cursor = self.report_cursor[idx];
            ctl.read_new_ckpt_reports_into(r.id, &mut cursor, &mut scratch.reports);
            self.report_cursor[idx] = cursor;
            self.book.ingest(r.id, &scratch.reports);
            if cursor == 0 {
                continue; // non-reporting job: out of scope by contract
            }
            if !self.in_tracked[idx] {
                self.in_tracked[idx] = true;
                self.tracked.push(r.id);
                if self.cfg.use_priors {
                    self.names[idx] = Some(r.name.clone());
                }
            }
            // Change gating: skip rows whose (ingested count, limit)
            // are unchanged since an evaluation that settled them —
            // nothing about them can have flipped. Rows with a retry
            // verdict (0.0, a rejected action) are always re-included.
            let gate = self.gate_key(idx, r.id);
            if let Some((cgate, cend, verdict)) = self.row_cache[idx] {
                // verdict: 1.0 = fits, -1.0 = no estimate yet, 2.0 =
                // policy declined; all stable until the inputs change.
                // 0.0 = a rejected or pending action: always retry.
                if cgate == gate && cend == r.expected_end && verdict != 0.0 {
                    continue;
                }
            }
            scratch.rows.push((r.id, r.expected_end, r.nodes, r.start));
        }
        self.harvest_finished();
        if scratch.rows.is_empty() {
            // Every previously retrying row either terminated or left
            // the running set: nothing is pending.
            self.pending_retries = 0;
            return;
        }

        // Queued jobs that could plausibly be delayed by an extension:
        // predicted to start before the conflict horizon past the
        // latest candidate end.
        let rows = &scratch.rows;
        let max_cur_end = rows.iter().map(|&(_, e, _, _)| e).max().unwrap();
        let horizon = max_cur_end + self.cfg.conflict_horizon;
        scratch.q_rows.clear();
        scratch.q_rows.extend(
            scratch
                .snap
                .pending
                .iter()
                .filter_map(|p| p.prediction.map(|pr| (pr.start, p.nodes, pr.free_at_start)))
                .filter(|&(start, _, _)| start <= horizon),
        );

        if let Err(e) = self.evaluate_chunked(
            &scratch.rows,
            &scratch.q_rows,
            &mut scratch.batch,
            &mut scratch.chunk_out,
            &mut scratch.out,
        ) {
            error_log!("decision engine failed, skipping tick: {e}");
            // The blind reference would retry (and re-fail) every tick;
            // stop claiming polls elidable so elision does the same.
            self.engine_errored = true;
            return;
        }

        // Apply the policy per row. `pending_retries` counts ¬fits rows
        // whose action left the job running (rejected actions, plus
        // fresh extensions pending their re-evaluation): while any
        // exist the next tick re-evaluates them, so polls must not be
        // elided.
        self.pending_retries = match driver {
            Driver::Legacy(policy) => {
                self.apply_legacy(*policy, now, ctl, &scratch.rows, &scratch.out)
            }
            Driver::Pipeline(policy) => self.apply_pipeline(
                policy.as_ref(),
                now,
                ctl,
                &scratch.rows,
                &scratch.out,
                &mut scratch.updates,
                &mut scratch.update_call,
            ),
        };
    }

    /// The retained legacy action loop — the seed's inline enum match,
    /// preserved as the golden reference for the pipeline driver below
    /// (`rust/tests/properties.rs` pins the two bit-identical).
    fn apply_legacy(
        &mut self,
        policy: Policy,
        now: Time,
        ctl: &mut dyn SlurmControl,
        rows: &[(JobId, Time, u32, Time)],
        out: &DecisionOutputs,
    ) -> usize {
        let mut retries = 0usize;
        for (i, &(id, cur_end, _nodes, _start)) in rows.iter().enumerate() {
            let idx = id.0 as usize;
            let gate = self.gate_key(idx, id);
            let verdict = if out.count[i] < 2.0 { -1.0 } else { out.fits[i] };
            self.row_cache[idx] = Some((gate, cur_end, verdict));
            if out.count[i] < 2.0 || out.fits[i] == 1.0 {
                continue; // no estimate yet, or the next checkpoint fits
            }
            let already_extended = self.ext_count[idx] > 0;
            let extend_now = !already_extended
                && match policy {
                    Policy::EarlyCancel => false,
                    Policy::Extend => true,
                    // Strict hybrid at threshold 0 (conflict flag);
                    // threshold-Hybrid tolerates a bounded delay cost.
                    Policy::Hybrid => {
                        out.conflict[i] == 0.0
                            || (out.delay_cost[i] as f64) <= self.cfg.max_delay_cost
                    }
                    Policy::Baseline => unreachable!(),
                };
            if extend_now {
                if !self.budget_blocked(idx, now, false) {
                    // New limit: predicted next checkpoint + margin,
                    // relative to the job's start (cur_end - old limit).
                    let ext_end = out.ext_end[i].ceil() as Time;
                    match self.extend_to(ctl, id, ext_end, now) {
                        Ok(granted_end) => {
                            self.record_extension(idx, granted_end, cur_end);
                            ctl.mark_adjustment(id, Adjustment::Extended);
                        }
                        Err(e) => {
                            self.record_rejection(idx);
                            warn_log!("extend {id} failed: {e}");
                        }
                    }
                }
                // Either way the job is still running with a 0.0
                // verdict: the next tick re-evaluates it.
                retries += 1;
            } else if self.budget_blocked(idx, now, true) {
                retries += 1;
            } else {
                // Cancel now: the last completed checkpoint is the last
                // that fits (or the bonus one, for extended jobs).
                match ctl.scancel(id) {
                    Ok(()) => self.record_cancel(ctl, id, idx),
                    Err(e) => {
                        self.record_rejection(idx);
                        warn_log!("scancel {id} failed: {e}");
                        retries += 1;
                    }
                }
            }
        }
        retries
    }

    /// The staged pipeline driver (see [`crate::policy`]): eligibility
    /// gate → fit prediction → action selection → budget accounting.
    /// With [`DaemonConfig::batch_actions`] the per-row extends are
    /// deferred into `updates` and flushed through the batched RPC at
    /// the end of the tick ([`flush_batched`](Self::flush_batched)).
    #[allow(clippy::too_many_arguments)]
    fn apply_pipeline(
        &mut self,
        policy: &dyn DecisionPolicy,
        now: Time,
        ctl: &mut dyn SlurmControl,
        rows: &[(JobId, Time, u32, Time)],
        out: &DecisionOutputs,
        updates: &mut Vec<PendingUpdate>,
        update_call: &mut Vec<(JobId, Time)>,
    ) -> usize {
        let margin = self.cfg.margin as f32;
        let batching = self.cfg.batch_actions;
        updates.clear();
        let mut retries = 0usize;
        for (i, &(id, cur_end, nodes, start)) in rows.iter().enumerate() {
            let idx = id.0 as usize;
            let gate = self.gate_key(idx, id);
            if out.count[i] < 2.0 {
                self.row_cache[idx] = Some((gate, cur_end, -1.0));
                continue; // no interval estimate yet
            }
            let row = RowCtx {
                id,
                start,
                cur_end,
                nodes,
                last_ckpt: self.book.history(id).and_then(|h| h.last()).unwrap_or(start),
                extensions: self.ext_count[idx],
                ext_secs: self.ext_secs[idx],
                rejections: self.rejected[idx],
            };

            // Stage 2 — fit prediction. Zero extra margin reproduces
            // the engine's fit bit verbatim; a widened margin re-runs
            // the engine's own f32 comparison with the extra slack.
            let extra = policy.extra_margin(&row);
            let fits = if extra == 0.0 {
                out.fits[i] == 1.0
            } else {
                out.pred_next[i] + margin + extra <= cur_end as f32
            };
            if fits {
                self.row_cache[idx] = Some((gate, cur_end, 1.0));
                continue;
            }
            let ext_end_f =
                if extra == 0.0 { out.ext_end[i] } else { out.pred_next[i] + margin + extra };
            let engine_row = EngineRow {
                pred_next: out.pred_next[i],
                ext_end: ext_end_f,
                conflict: out.conflict[i] != 0.0,
                delay_cost: out.delay_cost[i] as f64,
            };

            // Stages 1 + 3 — eligibility gate, then action selection.
            let may_extend = policy.may_extend(&row);
            match policy.select(&row, &engine_row, may_extend) {
                Action::Leave => {
                    // Deliberate no-op: stable until the inputs change,
                    // so the verdict is skippable (and polls elidable).
                    self.row_cache[idx] = Some((gate, cur_end, VERDICT_DECLINED));
                    self.stats.policy_declines += 1;
                }
                Action::Extend => {
                    self.row_cache[idx] = Some((gate, cur_end, 0.0));
                    if !self.budget_blocked(idx, now, false) {
                        let ext_end = ext_end_f.ceil() as Time;
                        if batching {
                            // Defer to the end-of-tick batched flush.
                            // Same limit math as `extend_to`, from the
                            // tick-start row (start, cur_end): sim time
                            // is frozen for the tick and nothing else
                            // moves limits, so the fresh-squeue value
                            // would be identical.
                            let new_limit =
                                (ext_end - start).max(cur_end - start + 1).max(now - start + 1);
                            updates.push(PendingUpdate {
                                idx,
                                id,
                                cur_end,
                                new_limit,
                                granted_end: start + new_limit,
                            });
                        } else {
                            match self.extend_to(ctl, id, ext_end, now) {
                                Ok(granted_end) => {
                                    self.record_extension(idx, granted_end, cur_end);
                                    ctl.mark_adjustment(id, Adjustment::Extended);
                                }
                                Err(e) => {
                                    self.record_rejection(idx);
                                    warn_log!("extend {id} failed: {e}");
                                }
                            }
                        }
                    }
                    // Still running with a retry verdict either way:
                    // the next tick re-evaluates it.
                    retries += 1;
                }
                Action::Cancel => {
                    self.row_cache[idx] = Some((gate, cur_end, 0.0));
                    if self.budget_blocked(idx, now, true) {
                        retries += 1;
                    } else {
                        match ctl.scancel(id) {
                            Ok(()) => self.record_cancel(ctl, id, idx),
                            Err(e) => {
                                self.record_rejection(idx);
                                warn_log!("scancel {id} failed: {e}");
                                retries += 1;
                            }
                        }
                    }
                }
            }
        }
        if !updates.is_empty() {
            self.flush_batched(ctl, updates, update_call);
        }
        retries
    }

    /// Budget gate for a control action on row `idx`: first attempts
    /// are free (clean surfaces stay bit-identical); a retry of a
    /// previously rejected action draws one token from its class
    /// bucket. `true` means the action is suppressed this tick — the
    /// row keeps its retry verdict and is re-presented once the window
    /// refills (polls stay non-elidable meanwhile).
    fn budget_blocked(&mut self, idx: usize, now: Time, cancel: bool) -> bool {
        if self.rejected[idx] == 0 {
            return false;
        }
        let bucket = if cancel { &mut self.scancel_budget } else { &mut self.scontrol_budget };
        if bucket.try_take(now) {
            false
        } else {
            self.stats.budget_exhausted += 1;
            true
        }
    }

    /// Flush the tick's deferred limit updates through the batched RPC
    /// in AIMD-sized windows: the in-flight window grows by one after
    /// every clean window and halves on any rejection, so a flaky
    /// control plane automatically degrades toward safe singles while
    /// a healthy one converges to `cfg.batch_window` updates per RPC.
    ///
    /// A second AIMD controller sizes RPC *parallelism*: with
    /// `cfg.rpc_concurrency > 1` each flush goes through
    /// [`SlurmControl::scontrol_update_limits_concurrent`] with the
    /// current `aimd_rpc` worker-pool width, which grows by one after
    /// a fully clean flush window and halves on any rejection or
    /// timeout. The default trait method ignores the width and runs
    /// serially (results in submission order either way), so the clean
    /// surface is bit-identical to serial by construction; only real
    /// transports (e.g. [`crate::slurm::ExternalSlurm`]) actually fan
    /// out.
    fn flush_batched(
        &mut self,
        ctl: &mut dyn SlurmControl,
        updates: &[PendingUpdate],
        call: &mut Vec<(JobId, Time)>,
    ) {
        let ceiling = self.cfg.batch_window.max(1);
        let rpc_ceiling = (self.cfg.rpc_concurrency as usize).max(1);
        let concurrent = rpc_ceiling > 1;
        let mut i = 0;
        while i < updates.len() {
            let w = self.aimd_window.clamp(1, ceiling).min(updates.len() - i);
            let window = &updates[i..i + w];
            call.clear();
            call.extend(window.iter().map(|u| (u.id, u.new_limit)));
            let results = if concurrent {
                let par = self.aimd_rpc.clamp(1, rpc_ceiling);
                ctl.scontrol_update_limits_concurrent(call, par)
            } else {
                ctl.scontrol_update_limits(call)
            };
            self.stats.batch_calls += 1;
            self.stats.batched_updates += window.len() as u64;
            let mut rejected = false;
            for (u, res) in window.iter().zip(&results) {
                match res {
                    Ok(()) => {
                        self.record_extension(u.idx, u.granted_end, u.cur_end);
                        ctl.mark_adjustment(u.id, Adjustment::Extended);
                    }
                    Err(e) => {
                        rejected = true;
                        self.record_rejection(u.idx);
                        warn_log!("extend {} failed: {e}", u.id);
                    }
                }
            }
            self.aimd_window =
                if rejected { (w / 2).max(1) } else { (self.aimd_window + 1).min(ceiling) };
            if concurrent {
                self.aimd_rpc = if rejected {
                    (self.aimd_rpc / 2).max(1)
                } else {
                    (self.aimd_rpc + 1).min(rpc_ceiling)
                };
            }
            i += w;
        }
    }

    /// Stage 4 — budget accounting for a granted extension (shared by
    /// both drivers so their `DaemonStats` stay comparable).
    /// `granted_end` is the end the control plane *actually* granted —
    /// [`extend_to`](Self::extend_to) may clamp the requested target up
    /// (monotone limits, past-`now` requests), and booking the request
    /// instead of the grant would let a budget policy overdraw.
    fn record_extension(&mut self, idx: usize, granted_end: Time, cur_end: Time) {
        self.ext_count[idx] += 1;
        let granted = (granted_end - cur_end).max(0);
        self.ext_secs[idx] += granted;
        self.stats.budget_spent += granted as u64;
        self.stats.extensions += 1;
    }

    /// A rejected control action: counted for observability and fed to
    /// the backoff policy via the dense rejection table.
    fn record_rejection(&mut self, idx: usize) {
        self.stats.scontrol_errors += 1;
        self.rejected[idx] += 1;
    }

    /// A landed cancel: accounting + tracking teardown (shared by both
    /// drivers).
    fn record_cancel(&mut self, ctl: &mut dyn SlurmControl, id: JobId, idx: usize) {
        if self.ext_count[idx] > 0 {
            self.stats.post_extension_cancels += 1;
            // The accounting tag stays `Extended`.
        } else {
            self.stats.cancels += 1;
            ctl.mark_adjustment(id, Adjustment::EarlyCancelled);
        }
        self.acted[idx] = true;
        self.row_cache[idx] = None;
        // Bank the interval knowledge before dropping. The id stays in
        // `tracked` until the next harvest sweep drops it (O(1) here
        // instead of an O(T) retain); the taken name marks it as
        // already banked.
        if self.cfg.use_priors {
            if let Some(name) = self.names[idx].take() {
                self.bank_prior(id, &name);
            }
        }
        self.book.forget(id);
    }

    /// Bank a finished (or about-to-be-cancelled) job's observed mean
    /// checkpoint interval into the appdb; shared by the cancel path
    /// and [`harvest_finished`](Self::harvest_finished).
    fn bank_prior(&mut self, id: JobId, name: &Arc<str>) {
        if let Some(h) = self.book.history(id) {
            let ts = h.timestamps();
            if ts.len() >= 2 {
                let mean = (ts[ts.len() - 1] - ts[0]) as f64 / (ts.len() - 1) as f64;
                self.db.observe(name, mean);
            }
        }
    }

    /// Drop tracking state for reporting jobs that stopped running
    /// since the last poll (tick-stamp mismatch): reclaim their
    /// [`ReportBook`] history in every mode, and — when priors are on
    /// and the name was not already banked by the cancel path — feed
    /// the observed mean interval into the appdb first.
    fn harvest_finished(&mut self) {
        let mut i = 0;
        while i < self.tracked.len() {
            let id = self.tracked[i];
            let idx = id.0 as usize;
            if self.running_mark[idx] == self.tick_no {
                i += 1;
                continue;
            }
            self.tracked.swap_remove(i);
            self.in_tracked[idx] = false;
            if let Some(name) = self.names[idx].take() {
                self.bank_prior(id, &name);
            }
            self.book.forget(id);
        }
    }

    /// Evaluate a batch that may exceed the engine's compiled shapes by
    /// chunking rows (independent) and queue columns (the conflict flag
    /// ORs and the delay cost sums across queue chunks; everything else
    /// is queue-independent and taken from the first chunk). All
    /// buffers — the chunk batch, the per-call outputs, and the
    /// combined `out` — are caller-owned pooled arenas: the steady
    /// state allocates nothing (§Perf).
    fn evaluate_chunked(
        &mut self,
        rows: &[(JobId, Time, u32, Time)],
        q_rows: &[(Time, u32, u32)],
        batch: &mut DecisionBatch,
        chunk_out: &mut DecisionOutputs,
        out: &mut DecisionOutputs,
    ) -> crate::errors::Result<()> {
        let (chunk_r, chunk_q) = (self.cfg.chunk_r, self.cfg.chunk_q);
        let t0 = std::time::Instant::now();
        out.reset(rows.len());

        for (ci, rchunk) in rows.chunks(chunk_r).enumerate() {
            let off = ci * chunk_r;
            let mut first_q = true;
            let mut q_iter = q_rows.chunks(chunk_q);
            // Always at least one (possibly empty) queue chunk.
            let first: &[(Time, u32, u32)] = q_iter.next().unwrap_or(&[]);
            let mut qchunk = first;
            loop {
                batch.reset(
                    rchunk.len(),
                    qchunk.len().max(1),
                    self.cfg.history_window,
                    self.cfg.margin as f32,
                    self.cfg.safety as f32,
                );
                for (i, &(id, cur_end, nodes, _start)) in rchunk.iter().enumerate() {
                    let hist = self.book.history(id).expect("ingested above");
                    // Cold start: a returning application with a single
                    // checkpoint gets a prior-seeded two-point history.
                    let seeded = if self.cfg.use_priors && hist.len() == 1 {
                        self.names[id.0 as usize]
                            .as_ref()
                            .and_then(|n| self.db.seed_history(n, hist.timestamps()))
                    } else {
                        None
                    };
                    match seeded {
                        Some(ts) => {
                            self.stats.prior_seeded_rows += 1;
                            batch.set_row(i, id, &ts, cur_end, nodes);
                        }
                        None => batch.set_row(i, id, hist.timestamps(), cur_end, nodes),
                    }
                }
                for (k, &(start, nodes, free)) in qchunk.iter().enumerate() {
                    batch.set_queue(k, start, nodes, free);
                }
                self.engine.evaluate_into(batch, chunk_out)?;
                self.stats.engine_calls += 1;
                let n = rchunk.len();
                if first_q {
                    first_q = false;
                    // Every output field, via the shared field list so
                    // a future field cannot miss this copy site.
                    for (dst, src) in out.fields_mut().into_iter().zip(chunk_out.fields()) {
                        dst[off..off + n].copy_from_slice(&src[..n]);
                    }
                } else {
                    // conflict ORs and delay_cost sums across queue
                    // chunks; the other outputs are queue-independent.
                    for (c, &v) in out.conflict[off..off + n].iter_mut().zip(&chunk_out.conflict[..n]) {
                        *c = c.max(v);
                    }
                    for (c, &v) in
                        out.delay_cost[off..off + n].iter_mut().zip(&chunk_out.delay_cost[..n])
                    {
                        *c += v;
                    }
                }
                match q_iter.next() {
                    Some(next) => qchunk = next,
                    None => break,
                }
            }
        }
        self.stats.engine_nanos += t0.elapsed().as_nanos() as u64;
        self.stats.batch_rows += rows.len() as u64;
        Ok(())
    }

    /// Returns the absolute end actually granted (`start + new_limit`),
    /// which can exceed the requested `ext_end` when the clamps fire.
    fn extend_to(
        &self,
        ctl: &mut dyn SlurmControl,
        id: JobId,
        ext_end: Time,
        now: Time,
    ) -> Result<Time, String> {
        // Translate the absolute extension end into a limit: we only
        // know start via expected_end - cur_limit from the snapshot;
        // fetch fresh to avoid staleness.
        let snap = ctl.squeue();
        let info = snap
            .running
            .iter()
            .find(|r| r.id == id)
            .ok_or_else(|| format!("{id}: vanished between snapshot and action"))?;
        let start = info.start;
        let new_limit = (ext_end - start).max(info.cur_limit + 1).max(now - start + 1);
        ctl.scontrol_update_limit(id, new_limit)?;
        Ok(start + new_limit)
    }

    /// Mean engine latency per call, nanoseconds.
    pub fn mean_engine_nanos(&self) -> f64 {
        if self.stats.engine_calls == 0 {
            0.0
        } else {
            self.stats.engine_nanos as f64 / self.stats.engine_calls as f64
        }
    }

    /// Start (or restart) event-sourced journaling to `path`:
    /// truncates any existing file, writes the header and a genesis
    /// snapshot of the *current* state, then appends every subsequent
    /// tick (see [`crate::journal`]). Safe to call on a freshly
    /// [`replay`](Self::replay)ed daemon to resume durability.
    pub fn enable_journal(&mut self, path: impl AsRef<std::path::Path>) -> crate::errors::Result<()> {
        let mut j =
            crate::journal::JournalWriter::create(path.as_ref(), &self.spec.name(), &self.cfg)?;
        j.snapshot(&self.snapshot_state())?;
        self.journal = Some(j);
        Ok(())
    }

    /// Whether this daemon is currently journaling.
    pub fn journaling(&self) -> bool {
        self.journal.is_some()
    }

    /// Journal rotation counters so far: `(segments_rotated,
    /// segments_pruned, disk_peak_bytes)`. `None` when not journaling.
    pub fn journal_rotation_stats(&self) -> Option<(u64, u64, u64)> {
        self.journal.as_ref().map(|j| j.rotation_stats())
    }

    /// Test hook: kill the journal writer exactly inside the rotation
    /// crash window (the active segment renamed away, the fresh base
    /// not yet created). The supervised-kill harness uses this to pin
    /// recovery from a kill -9 that lands mid-rotation; a daemon so
    /// killed must be dropped and rebuilt via [`replay`](Self::replay).
    pub fn debug_kill_mid_rotation(&mut self) -> crate::errors::Result<()> {
        match self.journal.as_mut() {
            Some(j) => j.kill_mid_rotation(),
            None => crate::bail!("not journaling"),
        }
    }

    /// Tighten (or relax) the periodic-snapshot cadence — ticks
    /// between full-state snapshots. Testing hook: short runs use 1–4
    /// to exercise multi-snapshot journals; no-op when not journaling.
    pub fn set_journal_snapshot_every(&mut self, n: u64) {
        if let Some(j) = self.journal.as_mut() {
            j.set_snapshot_every(n);
        }
    }

    /// Rebuild a daemon from its journal (native engine): restore the
    /// last complete snapshot, then re-run every journaled tick after
    /// it against the recorded control-surface interactions. The result
    /// is bit-identical (deterministic stats, decision trajectory) to
    /// the daemon that wrote the journal — a torn tail (crash mid-
    /// write) is discarded, losing at most the unfinished tick.
    pub fn replay(path: impl AsRef<std::path::Path>) -> crate::errors::Result<Autonomy> {
        Self::replay_info(path).map(|(d, _)| d)
    }

    /// [`replay`](Self::replay), also returning what the recovery cost:
    /// how many journaled ticks were re-run past the restored snapshot,
    /// how many elided/inactive polls were re-counted, and how many
    /// segment files the chain parse walked.
    pub fn replay_info(
        path: impl AsRef<std::path::Path>,
    ) -> crate::errors::Result<(Autonomy, ReplayInfo)> {
        Self::replay_with(path, None)
    }

    /// [`replay_info`](Self::replay_info) with an explicit decision
    /// engine.
    pub fn replay_with(
        path: impl AsRef<std::path::Path>,
        engine: Option<Box<dyn DecisionEngine>>,
    ) -> crate::errors::Result<(Autonomy, ReplayInfo)> {
        use crate::errors::Context;
        let journal = crate::journal::parse(path.as_ref())?;
        let spec = PolicySpec::parse(&journal.policy)
            .with_context(|| format!("journal policy {:?}", journal.policy))?;
        let mut cfg = journal.cfg;
        cfg.journal_path = None; // never clobber the file being replayed
        let mut d = match engine {
            Some(e) => Autonomy::new(spec, cfg, e),
            None => Autonomy::native(spec, cfg),
        };
        let snap_i = journal
            .blocks
            .iter()
            .rposition(|b| matches!(b, crate::journal::Block::Snapshot(_)))
            .ok_or_else(|| crate::errors::Error::msg("journal has no complete snapshot"))?;
        if let crate::journal::Block::Snapshot(state) = &journal.blocks[snap_i] {
            d.restore_state(state).context("journal snapshot")?;
        }
        let mut info = ReplayInfo { ticks_replayed: 0, polls_recovered: 0, segments: journal.segments };
        for b in &journal.blocks[snap_i + 1..] {
            match b {
                crate::journal::Block::Polls(n) => {
                    d.stats.polls += n;
                    info.polls_recovered += n;
                }
                crate::journal::Block::Tick { now, ops } => {
                    let mut rc = crate::journal::ReplayCtl::new(*now, ops.clone());
                    d.tick(*now, &mut rc);
                    info.ticks_replayed += 1;
                    if let Some(msg) = rc.take_diverged() {
                        crate::bail!("replay diverged at t={now}: {msg}");
                    }
                    if rc.remaining() != 0 {
                        crate::bail!(
                            "replay diverged at t={now}: {} recorded ops unconsumed",
                            rc.remaining()
                        );
                    }
                }
                crate::journal::Block::Snapshot(_) => unreachable!("after last snapshot"),
            }
        }
        Ok((d, info))
    }

    /// Encode the full mutable daemon state as snapshot lines (the
    /// payload of a journal `S..E` block). Everything a decision can
    /// depend on is here — dense per-job tables, rolling histories,
    /// priors, budgets, the AIMD window, stats — while the immutable
    /// parts (spec, config, compiled policy) travel in the journal
    /// header and are rebuilt by [`replay`](Self::replay).
    fn snapshot_state(&self) -> String {
        use std::fmt::Write as _;
        let enc = crate::journal::encode_str;
        let mut s = String::new();
        let len = self.ext_count.len();
        let _ = writeln!(
            s,
            "meta {} {} {} {} {} {}",
            self.tick_no,
            self.pending_retries,
            u8::from(self.engine_errored),
            self.aimd_window,
            self.aimd_rpc,
            len
        );
        let st = &self.stats;
        let _ = writeln!(
            s,
            "stats {} {} {} {} {} {} {} {} {} {} {} {} {} {}",
            st.polls,
            st.engine_calls,
            st.engine_nanos,
            st.batch_rows,
            st.cancels,
            st.extensions,
            st.post_extension_cancels,
            st.scontrol_errors,
            st.prior_seeded_rows,
            st.budget_spent,
            st.policy_declines,
            st.budget_exhausted,
            st.batch_calls,
            st.batched_updates
        );
        let (b1, b2) = (&self.scontrol_budget, &self.scancel_budget);
        let _ = writeln!(
            s,
            "buckets {} {} {} {}",
            b1.tokens, b1.last_refill, b2.tokens, b2.last_refill
        );
        // Retired slots are unobservable: only running/tracked ids are
        // ever read, and every one of those is at or above the table
        // base (the retirement clamp). Omitting them keeps the snapshot
        // O(live window); restore rebuilds them as defaults at base 0,
        // equally unobservable. Meta format is unchanged.
        for idx in self.ext_count.base()..len {
            let (e, x, r, a, c, m) = (
                self.ext_count[idx],
                self.ext_secs[idx],
                self.rejected[idx],
                self.acted[idx],
                self.report_cursor[idx],
                self.running_mark[idx],
            );
            if e != 0 || x != 0 || r != 0 || a || c != 0 || m != 0 {
                let _ = writeln!(s, "job {idx} {e} {x} {r} {} {c} {m}", u8::from(a));
            }
            if let Some(n) = &self.names[idx] {
                let _ = writeln!(s, "name {idx} {}", enc(n));
            }
            if let Some((gate, cend, v)) = self.row_cache[idx] {
                let _ = writeln!(s, "cache {idx} {gate} {cend} {}", v.to_bits());
            }
        }
        // `tracked` order matters: the harvest sweep (and so the order
        // of prior observations) iterates it.
        let mut line = String::from("tracked");
        for id in &self.tracked {
            let _ = write!(line, " {}", id.0);
        }
        let _ = writeln!(s, "{line}");
        for id in &self.tracked {
            if let Some(h) = self.book.history(*id) {
                let mut hl = format!("hist {}", id.0);
                for t in h.timestamps() {
                    let _ = write!(hl, " {t}");
                }
                let _ = writeln!(s, "{hl}");
            }
        }
        let _ = writeln!(s, "book {}", self.book.ingested);
        let _ = writeln!(s, "appdb {}", self.db.observations);
        for l in self.db.to_text().lines() {
            let _ = writeln!(s, "prof {l}");
        }
        s
    }

    /// Inverse of [`snapshot_state`](Self::snapshot_state); only ever
    /// called on a freshly built daemon.
    fn restore_state(&mut self, state: &str) -> crate::errors::Result<()> {
        use crate::errors::Context;
        let dec = crate::journal::decode_str;
        fn nums<T: std::str::FromStr>(it: &mut std::str::SplitWhitespace<'_>, n: usize) -> crate::errors::Result<Vec<T>> {
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                let tok = it.next().ok_or_else(|| crate::errors::Error::msg("truncated snapshot line"))?;
                out.push(tok.parse::<T>().map_err(|_| crate::errors::Error::msg(format!("bad number {tok:?}")))?);
            }
            Ok(out)
        }
        let mut profiles = String::new();
        for line in state.lines() {
            let mut it = line.split_whitespace();
            let Some(kind) = it.next() else { continue };
            match kind {
                "meta" => {
                    // 6 fields since the RPC-concurrency controller; a
                    // 5-field line (no aimd_rpc) restores it to 1.
                    let v: Vec<u64> = it
                        .map(|t| {
                            t.parse::<u64>()
                                .map_err(|_| crate::errors::Error::msg(format!("bad number {t:?}")))
                        })
                        .collect::<crate::errors::Result<_>>()
                        .context("meta")?;
                    if v.len() != 5 && v.len() != 6 {
                        crate::bail!("meta wants 5 or 6 fields, got {}", v.len());
                    }
                    self.tick_no = v[0];
                    self.pending_retries = v[1] as usize;
                    self.engine_errored = v[2] != 0;
                    self.aimd_window = (v[3] as usize).max(1);
                    let (rpc, len) = if v.len() == 6 { (v[4], v[5]) } else { (1, v[4]) };
                    self.aimd_rpc = (rpc as usize).max(1);
                    if len > 0 {
                        self.ensure_slot(JobId(len as u32 - 1));
                    }
                }
                "stats" => {
                    let v: Vec<u64> = nums(&mut it, 14).context("stats")?;
                    self.stats = DaemonStats {
                        polls: v[0],
                        engine_calls: v[1],
                        engine_nanos: v[2],
                        batch_rows: v[3],
                        cancels: v[4],
                        extensions: v[5],
                        post_extension_cancels: v[6],
                        scontrol_errors: v[7],
                        prior_seeded_rows: v[8],
                        budget_spent: v[9],
                        policy_declines: v[10],
                        budget_exhausted: v[11],
                        batch_calls: v[12],
                        batched_updates: v[13],
                    };
                }
                "buckets" => {
                    let v: Vec<i64> = nums(&mut it, 4).context("buckets")?;
                    self.scontrol_budget.tokens = v[0] as u32;
                    self.scontrol_budget.last_refill = v[1];
                    self.scancel_budget.tokens = v[2] as u32;
                    self.scancel_budget.last_refill = v[3];
                }
                "job" => {
                    let v: Vec<i64> = nums(&mut it, 7).context("job")?;
                    let idx = v[0] as usize;
                    self.ensure_slot(JobId(idx as u32));
                    self.ext_count[idx] = v[1] as u32;
                    self.ext_secs[idx] = v[2];
                    self.rejected[idx] = v[3] as u32;
                    self.acted[idx] = v[4] != 0;
                    self.report_cursor[idx] = v[5] as usize;
                    self.running_mark[idx] = v[6] as u64;
                }
                "name" => {
                    let idx: usize =
                        nums::<usize>(&mut it, 1).context("name")?[0];
                    self.ensure_slot(JobId(idx as u32));
                    let raw = it.next().ok_or_else(|| crate::errors::Error::msg("name missing"))?;
                    self.names[idx] = Some(Arc::from(dec(raw).as_str()));
                }
                "cache" => {
                    let v: Vec<i64> = nums(&mut it, 4).context("cache")?;
                    let idx = v[0] as usize;
                    self.ensure_slot(JobId(idx as u32));
                    self.row_cache[idx] =
                        Some((v[1] as usize, v[2], f32::from_bits(v[3] as u32)));
                }
                "tracked" => {
                    for tok in it {
                        let id = JobId(tok.parse().context("tracked id")?);
                        self.ensure_slot(id);
                        self.in_tracked[id.0 as usize] = true;
                        self.tracked.push(id);
                    }
                }
                "hist" => {
                    let id: u32 = nums::<u32>(&mut it, 1).context("hist")?[0];
                    let ts: Vec<Time> =
                        it.map(|t| t.parse::<Time>()).collect::<Result<_, _>>().context("hist ts")?;
                    self.book.ingest(JobId(id), &ts);
                }
                "book" => {
                    self.book.ingested = nums::<u64>(&mut it, 1).context("book")?[0];
                }
                "appdb" => {
                    self.db.observations = nums::<u64>(&mut it, 1).context("appdb")?[0];
                }
                "prof" => {
                    // AppDb's own text format, verbatim (tab-separated
                    // within the line).
                    if let Some(rest) = line.strip_prefix("prof ") {
                        profiles.push_str(rest);
                        profiles.push('\n');
                    }
                }
                other => crate::bail!("unknown snapshot line kind {other:?}"),
            }
        }
        let obs = self.db.observations;
        self.db = AppDb::from_text(&profiles).context("appdb profiles")?;
        self.db.observations = obs;
        Ok(())
    }
}

impl DaemonHook for Autonomy {
    fn poll_period(&self) -> Option<Time> {
        self.active().then_some(self.cfg.poll_period)
    }

    fn on_poll(&mut self, t: Time, ctl: &mut dyn SlurmControl) {
        self.tick(t, ctl);
    }

    fn poll_elidable(&self) -> bool {
        // With unchanged inputs a tick only re-evaluates rows whose
        // last verdict was a retry — rows left by a rejected (or not
        // yet re-checked) action. While any are pending, or after an
        // engine failure, the blind reference would keep doing real
        // work every tick, so polls must execute.
        self.pending_retries == 0 && !self.engine_errored
    }

    fn note_elided_polls(&mut self, n: u64) {
        self.stats.polls += n;
        // Elided polls are daemon-observable state (the poll counter),
        // so they are journaled too — a replayed daemon's stats stay
        // bit-identical under poll elision.
        if let Some(j) = self.journal.as_mut() {
            if let Err(e) = j.note_polls(n) {
                error_log!("journal write failed, disabling: {e}");
                self.journal = None;
            }
        }
    }

    fn retire_to(&mut self, watermark: JobId) {
        // Watermarks only advance; application is clamped by the
        // lowest still-tracked id (see [`Autonomy::apply_retirement`])
        // and re-attempted at the end of every tick.
        self.retire_watermark = self.retire_watermark.max(watermark.0);
        self.apply_retirement();
    }
}

/// Run one scenario end to end: submit `specs`, run with `policy` (a
/// [`PolicySpec`] or a legacy [`Policy`]), return (jobs, slurm stats,
/// daemon stats).
pub fn run_scenario(
    specs: &[crate::slurm::JobSpec],
    slurm_cfg: crate::slurm::SlurmConfig,
    policy: impl Into<PolicySpec>,
    daemon_cfg: DaemonConfig,
    mut engine: Option<Box<dyn DecisionEngine>>,
) -> (Vec<crate::slurm::Job>, crate::slurm::SlurmStats, DaemonStats) {
    let mut sim = crate::slurm::Slurmd::new(slurm_cfg);
    for s in specs {
        sim.submit(s.clone());
    }
    let spec = policy.into();
    let mut daemon = match engine.take() {
        Some(e) => Autonomy::new(spec, daemon_cfg, e),
        None => Autonomy::native(spec, daemon_cfg),
    };
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    (sim.into_jobs(), stats, daemon.stats)
}

/// [`run_scenario`] plus the federation perf observability pair:
/// returns `(jobs, slurm stats, daemon stats, peak_table_bytes,
/// jobs_retired)` where the peak sums the control plane's and the
/// daemon's dense-table high-water bytes.
pub fn run_scenario_metered(
    specs: &[crate::slurm::JobSpec],
    slurm_cfg: crate::slurm::SlurmConfig,
    policy: impl Into<PolicySpec>,
    daemon_cfg: DaemonConfig,
    mut engine: Option<Box<dyn DecisionEngine>>,
) -> (Vec<crate::slurm::Job>, crate::slurm::SlurmStats, DaemonStats, usize, u64) {
    let mut sim = crate::slurm::Slurmd::new(slurm_cfg);
    for s in specs {
        sim.submit(s.clone());
    }
    let spec = policy.into();
    let mut daemon = match engine.take() {
        Some(e) => Autonomy::new(spec, daemon_cfg, e),
        None => Autonomy::native(spec, daemon_cfg),
    };
    sim.run(&mut daemon);
    let stats = sim.stats.clone();
    let peak = sim.peak_table_bytes() + daemon.peak_table_bytes();
    let retired = sim.jobs_retired();
    (sim.into_jobs(), stats, daemon.stats, peak, retired)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{job_checkpoints, job_tail_waste, summarize};
    use crate::slurm::{JobSpec, JobState, SlurmConfig};

    /// The paper's canonical misaligned job on an otherwise empty
    /// cluster: limit 1440 s, checkpoints every 420 s.
    fn canonical() -> JobSpec {
        JobSpec::new("ck", 1440, 2880, 1).with_ckpt(420)
    }

    fn run_one(policy: Policy) -> (Vec<crate::slurm::Job>, DaemonStats) {
        let (jobs, _, dstats) = run_scenario(
            &[canonical()],
            SlurmConfig { nodes: 4, ..Default::default() },
            policy,
            DaemonConfig::default(),
            None,
        );
        (jobs, dstats)
    }

    #[test]
    fn token_bucket_is_deterministic_and_windowed() {
        // capacity 2, window 100: spends are granted until the window's
        // tokens run out, and a refill lands exactly on the next window
        // boundary *multiple* — never "window after last spend".
        let mut b = TokenBucket::new(2, 100);
        assert!(b.try_take(10));
        assert!(b.try_take(20));
        assert!(!b.try_take(90), "window 0 exhausted");
        assert!(b.try_take(100), "refill at the boundary");
        assert!(b.try_take(130));
        assert!(!b.try_take(199), "window 1 exhausted");
        assert!(b.try_take(200));
        // A long quiet gap refills once, not cumulatively: capacity is
        // the ceiling no matter how many windows elapsed.
        let mut b = TokenBucket::new(1, 100);
        assert!(b.try_take(0));
        assert!(b.try_take(1000));
        assert!(!b.try_take(1001), "no banked tokens across idle windows");
        // Capacity 0 is "unlimited": always grants, state untouched.
        let mut b = TokenBucket::new(0, 100);
        for t in [0, 1, 2, 50, 51] {
            assert!(b.try_take(t));
        }
    }

    #[test]
    fn token_bucket_boundary_and_degenerate_configs_are_pinned() {
        // Refill lands exactly AT the window edge (`now >=
        // last_refill + window`), never one tick early.
        let mut b = TokenBucket::new(1, 50);
        assert!(b.try_take(0));
        assert!(!b.try_take(49), "one tick before the edge: still dry");
        assert!(b.try_take(50), "exactly at the edge: refilled");
        // Multi-window catch-up anchors on the window *grid*: the spend
        // at 250 refills from the t=200 grid point, so the next refill
        // is at 300, not 350.
        let mut b = TokenBucket::new(1, 100);
        assert!(b.try_take(0));
        assert!(b.try_take(250), "two whole windows elapsed: refill");
        assert!(!b.try_take(299), "anchored at 200, not at the 250 spend");
        assert!(b.try_take(300), "next grid point");
        // retry_window = 0 with a finite budget: a *lifetime* budget.
        // Spends never refill no matter how far sim time advances.
        let mut b = TokenBucket::new(2, 0);
        assert!(b.try_take(0));
        assert!(b.try_take(1_000_000));
        assert!(!b.try_take(100_000_000), "window 0 never refills");
        assert!(!b.try_take(Time::MAX / 2), "not even at the end of time");
        // retry_budget = 0: unlimited, with or without a window.
        let mut b = TokenBucket::new(0, 0);
        for t in [0, 7, Time::MAX / 2] {
            assert!(b.try_take(t), "capacity 0 is unlimited");
        }
    }

    #[test]
    fn rpc_concurrency_clean_surface_is_bit_identical() {
        // The RPC-width AIMD controller only changes how many scontrol
        // children a *real* transport runs at once; on an in-sim
        // surface (trait default = serial delegation) a wide config
        // must be bit-identical to rpc_concurrency = 1.
        let specs = [
            JobSpec::new("a", 1440, 2880, 1).with_ckpt(420),
            JobSpec::new("b", 1440, 2880, 1).with_ckpt(300),
            JobSpec::new("c", 900, 1500, 2).with_ckpt(200),
            JobSpec::new("plain", 600, 1200, 1),
        ];
        for policy in [Policy::EarlyCancel, Policy::Extend, Policy::Hybrid] {
            let base = DaemonConfig { batch_actions: true, ..DaemonConfig::default() };
            let wide_cfg = DaemonConfig { rpc_concurrency: 8, ..base.clone() };
            let (j1, s1, d1) = run_scenario(
                &specs,
                SlurmConfig { nodes: 4, ..Default::default() },
                policy,
                base,
                None,
            );
            let (j2, s2, d2) = run_scenario(
                &specs,
                SlurmConfig { nodes: 4, ..Default::default() },
                policy,
                wide_cfg,
                None,
            );
            assert_eq!(j1, j2, "{policy:?}: job records diverged under rpc_concurrency");
            assert_eq!(s1, s2, "{policy:?}: SlurmStats diverged under rpc_concurrency");
            assert_eq!(
                d1.deterministic(),
                d2.deterministic(),
                "{policy:?}: DaemonStats diverged under rpc_concurrency"
            );
        }
    }

    #[test]
    fn concurrent_trait_default_is_serial_and_ordered() {
        struct RecordingMock {
            calls: Vec<(u32, Time)>,
        }
        impl SlurmControl for RecordingMock {
            fn control_now(&self) -> Time {
                0
            }
            fn squeue(&self) -> QueueSnapshot {
                QueueSnapshot::default()
            }
            fn read_ckpt_reports(&self, _id: JobId) -> Vec<Time> {
                Vec::new()
            }
            fn scontrol_update_limit(&mut self, id: JobId, l: Time) -> Result<(), String> {
                self.calls.push((id.0, l));
                if id.0 == 2 { Err("nope".into()) } else { Ok(()) }
            }
            fn scancel(&mut self, _id: JobId) -> Result<(), String> {
                Ok(())
            }
            fn mark_adjustment(&mut self, _id: JobId, _adj: Adjustment) {}
        }
        let mut m = RecordingMock { calls: Vec::new() };
        let updates = [(JobId(1), 100), (JobId(2), 200), (JobId(3), 300)];
        let rs = m.scontrol_update_limits_concurrent(&updates, 7);
        assert_eq!(
            m.calls,
            vec![(1, 100), (2, 200), (3, 300)],
            "the default ignores the advisory width: serial, in submission order"
        );
        assert_eq!(rs.len(), 3, "one result per update");
        assert!(rs[0].is_ok() && rs[1].is_err() && rs[2].is_ok());
    }

    #[test]
    fn aimd_rpc_width_snapshot_roundtrips_and_tolerates_legacy_meta() {
        let cfg = DaemonConfig { rpc_concurrency: 8, ..Default::default() };
        let mut d = Autonomy::native(PolicySpec::Hybrid, cfg.clone());
        d.aimd_rpc = 5;
        let snap = d.snapshot_state();
        let mut r = Autonomy::native(PolicySpec::Hybrid, cfg.clone());
        r.restore_state(&snap).expect("restore");
        assert_eq!(r.aimd_rpc, 5, "learned RPC width survives snapshot/restore");
        // Pre-width journals wrote a 5-field meta line (no aimd_rpc);
        // replaying one must not fail — the width defaults to 1.
        let legacy: String = snap
            .lines()
            .map(|l| {
                if let Some(rest) = l.strip_prefix("meta ") {
                    let t: Vec<&str> = rest.split_whitespace().collect();
                    assert_eq!(t.len(), 6, "current meta has 6 fields");
                    format!("meta {} {} {} {} {}\n", t[0], t[1], t[2], t[3], t[5])
                } else {
                    format!("{l}\n")
                }
            })
            .collect();
        let mut r2 = Autonomy::native(PolicySpec::Hybrid, cfg);
        r2.restore_state(&legacy).expect("legacy 5-field meta restores");
        assert_eq!(r2.aimd_rpc, 1, "legacy journals default the width to serial");
    }

    #[test]
    fn baseline_leaves_tail_waste() {
        let (jobs, _) = run_one(Policy::Baseline);
        let j = &jobs[0];
        assert_eq!(j.state, JobState::Timeout);
        assert_eq!(j.end, Some(1440));
        assert_eq!(job_checkpoints(j), 3);
        assert_eq!(job_tail_waste(j), 180 * 48);
    }

    #[test]
    fn early_cancel_cuts_tail_to_poll_residue() {
        let (jobs, stats) = run_one(Policy::EarlyCancel);
        let j = &jobs[0];
        assert_eq!(j.state, JobState::Cancelled);
        assert_eq!(j.adjustment, Some(crate::slurm::Adjustment::EarlyCancelled));
        // Cancelled at the first poll after the 1260 checkpoint.
        let end = j.end.unwrap();
        assert!(end >= 1260 && end <= 1260 + 20, "end={end}");
        assert_eq!(job_checkpoints(j), 3, "same checkpoints as baseline");
        assert!(job_tail_waste(j) <= 20 * 48);
        assert_eq!(stats.cancels, 1);
        assert_eq!(stats.extensions, 0);
    }

    #[test]
    fn extend_gains_exactly_one_checkpoint() {
        let (jobs, stats) = run_one(Policy::Extend);
        let j = &jobs[0];
        assert_eq!(j.adjustment, Some(crate::slurm::Adjustment::Extended));
        assert_eq!(job_checkpoints(j), 4, "one bonus checkpoint");
        // Gracefully cancelled shortly after the bonus checkpoint at 1680.
        let end = j.end.unwrap();
        assert!(end >= 1680 && end <= 1680 + 20, "end={end}");
        assert!(job_tail_waste(j) <= 20 * 48);
        assert_eq!(stats.extensions, 1);
        assert_eq!(stats.post_extension_cancels, 1);
        assert_eq!(stats.cancels, 0);
        assert!(stats.budget_spent > 0, "extension seconds are accounted");
    }

    #[test]
    fn hybrid_extends_on_empty_cluster() {
        // No queued jobs -> no conflict -> hybrid behaves like Extend.
        let (jobs, stats) = run_one(Policy::Hybrid);
        assert_eq!(job_checkpoints(&jobs[0]), 4);
        assert_eq!(stats.extensions, 1);
    }

    #[test]
    fn hybrid_cancels_when_extension_would_delay() {
        // 4 nodes. ck holds 1; filler holds 3 until 1500; a queued job
        // needs 4 nodes and is predicted to start at ck's current end
        // (1440 < 1500 is false... make filler end at 1440 too).
        // Setup: filler(3 nodes) limit 1440; queued needs 4 nodes ->
        // predicted start 1440 (when both release); extending ck to
        // 1710 would delay it -> hybrid must cancel early.
        let specs = vec![
            canonical(),                                  // 1 node, ends 1440
            JobSpec::new("filler", 1440, 1440, 3),        // 3 nodes, ends 1440
            JobSpec::new("big", 600, 600, 4),             // queued: needs all 4
        ];
        let (jobs, _, dstats) = run_scenario(
            &specs,
            SlurmConfig { nodes: 4, ..Default::default() },
            Policy::Hybrid,
            DaemonConfig::default(),
            None,
        );
        assert_eq!(jobs[0].adjustment, Some(crate::slurm::Adjustment::EarlyCancelled));
        assert_eq!(dstats.cancels, 1);
        assert_eq!(dstats.extensions, 0);
        // And the big job starts as soon as the filler ends.
        assert_eq!(jobs[2].start, Some(1440));
    }

    #[test]
    fn threshold_hybrid_tolerates_bounded_delay() {
        // Same conflict topology as hybrid_cancels_when_extension_would_delay,
        // but with a generous max_delay_cost the hybrid extends anyway.
        let specs = vec![
            canonical(),
            JobSpec::new("filler", 1440, 1440, 3),
            JobSpec::new("big", 600, 600, 4),
        ];
        let strict = DaemonConfig::default();
        let tolerant = DaemonConfig { max_delay_cost: 1.0e6, ..Default::default() };
        let (jobs_s, _, ds) = run_scenario(
            &specs,
            SlurmConfig { nodes: 4, ..Default::default() },
            Policy::Hybrid,
            strict,
            None,
        );
        let (jobs_t, _, dt) = run_scenario(
            &specs,
            SlurmConfig { nodes: 4, ..Default::default() },
            Policy::Hybrid,
            tolerant,
            None,
        );
        assert_eq!(jobs_s[0].adjustment, Some(crate::slurm::Adjustment::EarlyCancelled));
        assert_eq!(ds.extensions, 0);
        assert_eq!(jobs_t[0].adjustment, Some(crate::slurm::Adjustment::Extended));
        assert_eq!(dt.extensions, 1);
        assert!(jobs_t[2].start.unwrap() > jobs_s[2].start.unwrap(), "the tolerated delay is real");
    }

    #[test]
    fn extend_policy_delays_queued_job() {
        // Same topology, Extend policy: the big job IS delayed.
        let specs = vec![
            canonical(),
            JobSpec::new("filler", 1440, 1440, 3),
            JobSpec::new("big", 600, 600, 4),
        ];
        let (jobs, _, _) = run_scenario(
            &specs,
            SlurmConfig { nodes: 4, ..Default::default() },
            Policy::Extend,
            DaemonConfig::default(),
            None,
        );
        assert_eq!(jobs[0].adjustment, Some(crate::slurm::Adjustment::Extended));
        assert!(jobs[2].start.unwrap() > 1440, "extension delays the 4-node job");
    }

    #[test]
    fn non_reporting_jobs_untouched() {
        let specs = vec![JobSpec::new("opaque", 600, 1200, 1)];
        let (jobs, _, dstats) = run_scenario(
            &specs,
            SlurmConfig { nodes: 4, ..Default::default() },
            Policy::EarlyCancel,
            DaemonConfig::default(),
            None,
        );
        assert_eq!(jobs[0].state, JobState::Timeout);
        assert_eq!(jobs[0].end, Some(600));
        assert_eq!(dstats.cancels, 0);
    }

    #[test]
    fn completed_checkpointer_untouched() {
        // A checkpointing job that finishes before its limit: the next
        // checkpoint always fits until it completes.
        let specs = vec![JobSpec::new("ok", 2000, 900, 1).with_ckpt(420)];
        let (jobs, _, dstats) = run_scenario(
            &specs,
            SlurmConfig { nodes: 4, ..Default::default() },
            Policy::EarlyCancel,
            DaemonConfig::default(),
            None,
        );
        assert_eq!(jobs[0].state, JobState::Completed);
        assert_eq!(dstats.cancels, 0);
    }

    #[test]
    fn jittered_intervals_still_handled() {
        let mut spec = canonical();
        spec.ckpt = Some(crate::slurm::CkptSpec { interval: 420, jitter_frac: 0.15, seed: 3 });
        let cfg = DaemonConfig { safety: 1.0, ..Default::default() };
        let (jobs, _, dstats) = run_scenario(
            &[spec],
            SlurmConfig { nodes: 4, ..Default::default() },
            Policy::EarlyCancel,
            cfg,
            None,
        );
        // The daemon must still terminate the job via cancel, and tail
        // waste must beat the baseline's ~180 s x 48.
        assert_eq!(dstats.cancels, 1);
        assert!(job_tail_waste(&jobs[0]) < 180 * 48);
    }

    #[test]
    fn extend_budget_grants_multiple_checkpoints() {
        // Budget for ~3 extensions of ~450 s each: the job earns
        // several bonus checkpoints before the budget runs dry and the
        // daemon cancels gracefully.
        let (jobs, _, dstats) = run_scenario(
            &[canonical()],
            SlurmConfig { nodes: 4, ..Default::default() },
            PolicySpec::ExtendBudget { budget: 1_400 },
            DaemonConfig::default(),
            None,
        );
        let j = &jobs[0];
        assert_eq!(j.adjustment, Some(crate::slurm::Adjustment::Extended));
        assert!(dstats.extensions >= 2, "budget allows repeats: {dstats:?}");
        // No grant clamp fires on this replay (every request precedes
        // the acting poll), so the spend stays strictly within budget.
        assert!(
            dstats.budget_spent <= 1_400,
            "spend within budget on this replay: spent {}",
            dstats.budget_spent
        );
        assert_eq!(dstats.post_extension_cancels, 1);
        assert!(
            job_checkpoints(j) > 4,
            "more than Extend's single bonus checkpoint: {}",
            job_checkpoints(j)
        );
    }

    #[test]
    fn tail_aware_threshold_splits_cancel_and_leave() {
        // Canonical job: tail 180 s vs 1260 s of checkpointed work
        // (ratio ~0.143). A strict threshold cancels, a lax one leaves
        // the job to its natural timeout (and the verdict is stable:
        // no per-tick retry churn).
        let run = |frac: f64| {
            run_scenario(
                &[canonical()],
                SlurmConfig { nodes: 4, ..Default::default() },
                PolicySpec::TailAware { frac },
                DaemonConfig::default(),
                None,
            )
        };
        let (strict_jobs, _, strict) = run(0.1);
        assert_eq!(strict_jobs[0].state, JobState::Cancelled);
        assert_eq!(strict.cancels, 1);
        assert_eq!(strict.policy_declines, 0);
        let (lax_jobs, _, lax) = run(0.5);
        assert_eq!(lax_jobs[0].state, JobState::Timeout, "tail is cheap: left alone");
        assert_eq!(lax.cancels, 0);
        assert!(lax.policy_declines >= 1);
        assert_eq!(job_tail_waste(&lax_jobs[0]), 180 * 48, "baseline tail accepted");
    }

    #[test]
    fn hybrid_backoff_matches_hybrid_without_rejections() {
        // No control failures -> zero extra margin -> decision-for-
        // decision identical to strict Hybrid.
        let specs = vec![
            canonical(),
            JobSpec::new("filler", 1440, 1440, 3),
            JobSpec::new("big", 600, 600, 4),
        ];
        let run = |spec: PolicySpec| {
            run_scenario(
                &specs,
                SlurmConfig { nodes: 4, ..Default::default() },
                spec,
                DaemonConfig::default(),
                None,
            )
        };
        let (hj, hs, hd) = run(PolicySpec::Hybrid);
        let (bj, bs, bd) = run(PolicySpec::HybridBackoff { step: 60 });
        assert_eq!(hj, bj);
        assert_eq!(hs, bs);
        assert_eq!(hd.deterministic(), bd.deterministic());
    }

    #[test]
    fn priors_enable_cold_start_decisions() {
        // Application "wrf": interval 600 s, limit 1000 s. Only ONE
        // checkpoint (600) ever fits, so without a prior the daemon can
        // never estimate (count < 2) and the job times out with 400 s
        // of tail. After a first run teaches the db, the SECOND run is
        // cancelled right after its single checkpoint.
        let cluster = SlurmConfig { nodes: 2, ..Default::default() };
        let cfg = DaemonConfig { use_priors: true, ..Default::default() };
        let mk = |i: u32| JobSpec::new(&format!("wrf-{i:03}"), 1000, 3000, 1).with_ckpt(600);

        // Without priors: both runs time out (control).
        let (jobs, _, d0) = run_scenario(
            &[mk(1)],
            cluster.clone(),
            Policy::EarlyCancel,
            DaemonConfig::default(),
            None,
        );
        assert_eq!(jobs[0].state, JobState::Timeout);
        assert_eq!(d0.cancels, 0);

        // With priors: one daemon across two sequential runs.
        let mut sim = Slurmd::new(cluster.clone());
        sim.submit(mk(1));
        sim.submit(mk(2)); // 1-node jobs on 2 nodes: run concurrently...
        let mut daemon = Autonomy::native(Policy::EarlyCancel, cfg.clone());
        sim.run(&mut daemon);
        // Teacher run(s) finish with >= 2 observed... they can't (only
        // one ckpt fits). So seed the db explicitly, as a persisted
        // profile from another system would be:
        let mut daemon2 = Autonomy::native(Policy::EarlyCancel, cfg);
        daemon2.db.observe("wrf-teach", 600.0); // "wrf-teach" -> key "wrf-teach"
        daemon2.db.observe("wrf-0", 600.0); // key "wrf"
        let mut sim2 = Slurmd::new(cluster);
        let id = sim2.submit(mk(3));
        sim2.run(&mut daemon2);
        let j = sim2.job(id);
        assert_eq!(j.state, JobState::Cancelled, "prior-seeded cold start must act");
        assert!(j.end.unwrap() <= 600 + 21, "cancel right after the only checkpoint");
        assert!(daemon2.stats.prior_seeded_rows > 0);
        assert_eq!(daemon2.stats.cancels, 1);
    }

    use crate::slurm::Slurmd;

    #[test]
    fn priors_are_learned_across_jobs_in_one_run() {
        // Two sequential runs of the same app with 2 fitting ckpts:
        // the first run teaches the db (harvested at termination).
        let cfg = DaemonConfig { use_priors: true, ..Default::default() };
        let mut sim = Slurmd::new(SlurmConfig { nodes: 1, ..Default::default() });
        sim.submit(JobSpec::new("lmp-001", 1440, 3000, 1).with_ckpt(420));
        sim.submit(JobSpec::new("lmp-002", 1440, 3000, 1).with_ckpt(420));
        let mut daemon = Autonomy::native(Policy::EarlyCancel, cfg);
        sim.run(&mut daemon);
        let (mean, _) = daemon.db.prior("lmp-003").expect("first run must teach the db");
        assert!((mean - 420.0).abs() < 1.0, "learned mean {mean}");
        assert!(daemon.db.observations >= 2);
    }

    #[test]
    fn summarize_full_micro_workload() {
        let specs = vec![
            canonical(),
            JobSpec::new("short", 600, 300, 2),
            JobSpec::new("opaque-to", 600, 1200, 1),
        ];
        let (jobs, sstats, _) = run_scenario(
            &specs,
            SlurmConfig { nodes: 4, ..Default::default() },
            Policy::EarlyCancel,
            DaemonConfig::default(),
            None,
        );
        let s = summarize("EC", &jobs, &sstats);
        assert_eq!(s.total_jobs, 3);
        assert_eq!(s.completed, 1);
        assert_eq!(s.timeout, 1);
        assert_eq!(s.early_cancelled, 1);
        assert_eq!(s.total_checkpoints, 3);
    }
}
