//! Tiny benchmark harness (criterion is not in the offline vendor set).
//!
//! Provides warmup + repeated timed runs with median/min/mean reporting,
//! used by every target in `rust/benches/`, plus a minimal JSON emitter
//! ([`BenchJson`] / [`save_bench_json`]) so CI can track the perf
//! trajectory machine-readably (`BENCH_hotpath.json`).

use std::path::Path;
use std::time::{Duration, Instant};

/// Result of a timed measurement.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub runs: Vec<Duration>,
}

impl Timing {
    pub fn median(&self) -> Duration {
        let mut v = self.runs.clone();
        v.sort();
        v[v.len() / 2]
    }

    pub fn min(&self) -> Duration {
        self.runs.iter().copied().min().unwrap()
    }

    pub fn mean(&self) -> Duration {
        self.runs.iter().sum::<Duration>() / self.runs.len() as u32
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>10.3?}  min {:>10.3?}  mean {:>10.3?}  (n={})",
            self.name,
            self.median(),
            self.min(),
            self.mean(),
            self.runs.len()
        )
    }
}

/// Run `f` once as warmup, then `n` timed iterations.
pub fn bench<T>(name: &str, n: usize, mut f: impl FnMut() -> T) -> Timing {
    std::hint::black_box(f());
    let mut runs = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        std::hint::black_box(f());
        runs.push(t0.elapsed());
    }
    let t = Timing { name: name.to_string(), runs };
    println!("{}", t.report());
    t
}

/// `--quick` flag passed through `cargo bench -- --quick`.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
}

/// One named section of a bench-results JSON file: a flat object of
/// numeric/string fields. No serde offline, so the writer is in-tree;
/// the format is one section per line inside one top-level object:
///
/// ```json
/// {
/// "sim_scale": {"jobs": 20000, "speedup": 7.3},
/// "engine_hotpath": {"native_median_us": 41.2}
/// }
/// ```
#[derive(Debug, Clone)]
pub struct BenchJson {
    section: String,
    fields: Vec<(String, String)>,
}

impl BenchJson {
    pub fn new(section: &str) -> Self {
        Self { section: section.to_string(), fields: Vec::new() }
    }

    pub fn int(mut self, key: &str, v: i64) -> Self {
        self.fields.push((key.to_string(), v.to_string()));
        self
    }

    pub fn num(mut self, key: &str, v: f64) -> Self {
        // JSON has no NaN/inf literals.
        let v = if v.is_finite() { v } else { 0.0 };
        self.fields.push((key.to_string(), format!("{v:.6}")));
        self
    }

    pub fn text(mut self, key: &str, v: &str) -> Self {
        // Keys/values here are bench names: keep them quote-free.
        let clean: String = v.chars().filter(|&c| c != '"' && c != '\\' && c != '\n').collect();
        self.fields.push((key.to_string(), format!("\"{clean}\"")));
        self
    }

    /// Record a `usize` counter under `key` — e.g. the per-regime peak
    /// breakpoint counts the sim_scale bench emits, so the perf
    /// trajectory tracks B (the placement-cost driver), not just wall
    /// time.
    pub fn count(self, key: &str, v: usize) -> Self {
        self.int(key, v as i64)
    }

    /// Record a [`Timing`]'s median in microseconds under `key`.
    pub fn timing(self, key: &str, t: &Timing) -> Self {
        self.num(key, t.median().as_secs_f64() * 1e6)
    }

    fn render_line(&self) -> String {
        let body: Vec<String> =
            self.fields.iter().map(|(k, v)| format!("\"{k}\": {v}")).collect();
        format!("\"{}\": {{{}}}", self.section, body.join(", "))
    }
}

/// Write (or update) a bench-results file: sections already present in
/// the file but not in `sections` are kept, so independent bench
/// targets can contribute to one `BENCH_hotpath.json`.
pub fn save_bench_json(path: &Path, sections: &[BenchJson]) -> std::io::Result<()> {
    let mut kept: Vec<String> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        for line in text.lines() {
            let l = line.trim().trim_end_matches(',');
            if l.is_empty() || l == "{" || l == "}" {
                continue;
            }
            if let Some(name) = l.strip_prefix('"').and_then(|r| r.split_once('"')).map(|(n, _)| n)
            {
                if !sections.iter().any(|s| s.section == name) {
                    kept.push(l.to_string());
                }
            }
        }
    }
    kept.extend(sections.iter().map(|s| s.render_line()));
    std::fs::write(path, format!("{{\n{}\n}}\n", kept.join(",\n")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_n_runs() {
        let t = bench("noop", 5, || 1 + 1);
        assert_eq!(t.runs.len(), 5);
        assert!(t.median() <= t.runs.iter().copied().max().unwrap());
        assert!(t.min() <= t.mean());
    }

    #[test]
    fn bench_json_renders_and_merges() {
        let path = std::env::temp_dir().join(format!("tt_bench_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let a = BenchJson::new("sim_scale").int("jobs", 20000).num("speedup", 7.25);
        save_bench_json(&path, &[a]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("{\n"), "{text}");
        assert!(text.contains("\"sim_scale\": {\"jobs\": 20000, \"speedup\": 7.250000}"));

        // A second target contributes its own section; the first stays.
        let b = BenchJson::new("engine_hotpath").text("host", "ci").num("median_us", 41.0);
        save_bench_json(&path, &[b]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"sim_scale\""));
        assert!(text.contains("\"engine_hotpath\""));
        assert!(text.contains("\"host\": \"ci\""));
        assert_eq!(text.matches(',').count() >= 1, true);

        // Re-writing a section replaces it instead of duplicating.
        let c = BenchJson::new("sim_scale").int("jobs", 99);
        save_bench_json(&path, &[c]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.matches("sim_scale").count(), 1);
        assert!(text.contains("\"jobs\": 99"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bench_json_sanitizes_non_finite() {
        let j = BenchJson::new("x").num("bad", f64::NAN);
        assert!(j.render_line().contains("\"bad\": 0.000000"));
    }

    #[test]
    fn bench_json_counts_render_as_integers() {
        let j = BenchJson::new("x").count("bp0_peak_breakpoints", 5_321);
        assert!(j.render_line().contains("\"bp0_peak_breakpoints\": 5321"));
    }
}
