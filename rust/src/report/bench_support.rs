//! Tiny benchmark harness (criterion is not in the offline vendor set).
//!
//! Provides warmup + repeated timed runs with median/min/mean reporting,
//! used by every target in `rust/benches/`.

use std::time::{Duration, Instant};

/// Result of a timed measurement.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub runs: Vec<Duration>,
}

impl Timing {
    pub fn median(&self) -> Duration {
        let mut v = self.runs.clone();
        v.sort();
        v[v.len() / 2]
    }

    pub fn min(&self) -> Duration {
        self.runs.iter().copied().min().unwrap()
    }

    pub fn mean(&self) -> Duration {
        self.runs.iter().sum::<Duration>() / self.runs.len() as u32
    }

    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>10.3?}  min {:>10.3?}  mean {:>10.3?}  (n={})",
            self.name,
            self.median(),
            self.min(),
            self.mean(),
            self.runs.len()
        )
    }
}

/// Run `f` once as warmup, then `n` timed iterations.
pub fn bench<T>(name: &str, n: usize, mut f: impl FnMut() -> T) -> Timing {
    std::hint::black_box(f());
    let mut runs = Vec::with_capacity(n);
    for _ in 0..n {
        let t0 = Instant::now();
        std::hint::black_box(f());
        runs.push(t0.elapsed());
    }
    let t = Timing { name: name.to_string(), runs };
    println!("{}", t.report());
    t
}

/// `--quick` flag passed through `cargo bench -- --quick`.
pub fn quick_mode() -> bool {
    std::env::args().any(|a| a == "--quick")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_n_runs() {
        let t = bench("noop", 5, || 1 + 1);
        assert_eq!(t.runs.len(), 5);
        assert!(t.median() <= t.runs.iter().copied().max().unwrap());
        assert!(t.min() <= t.mean());
    }
}
