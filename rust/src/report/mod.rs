//! Report rendering: Table 1, Fig. 4's normalized comparison, and CSV
//! exports for downstream plotting. [`bench_support`] holds the tiny
//! timing harness used by `rust/benches/`.

pub mod bench_support;

use std::fmt::Write as _;

use crate::metrics::Summary;

fn fmt_thousands(v: i64) -> String {
    let neg = v < 0;
    let digits = v.abs().to_string();
    let mut out = String::new();
    for (i, c) in digits.chars().enumerate() {
        if i > 0 && (digits.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    if neg { format!("-{out}") } else { out }
}

/// Render the paper's Table 1 ("Comparison of scheduling scenarios under
/// different daemon policies") from one summary per policy. The first
/// summary is the baseline.
pub fn render_table1(summaries: &[Summary]) -> String {
    let mut s = String::new();
    let w_metric = 40;
    let w_col = 16;
    let dash = |c: usize| "-".repeat(c);

    let _ = writeln!(s, "{:<w_metric$} {}", "Metric (unit of measure)",
        summaries.iter().map(|x| format!("{:>w_col$}", x.policy)).collect::<Vec<_>>().join(" "));
    let _ = writeln!(s, "{} {}", dash(w_metric),
        summaries.iter().map(|_| dash(w_col)).collect::<Vec<_>>().join(" "));

    macro_rules! row {
        ($label:expr, $f:expr) => {{
            let cells: Vec<String> = summaries.iter().map(|x| format!("{:>w_col$}", $f(x))).collect();
            let _ = writeln!(s, "{:<w_metric$} {}", $label, cells.join(" "));
        }};
    }
    let dashes = |v: usize| if v == 0 { "-".to_string() } else { fmt_thousands(v as i64) };

    row!("TIMEOUT (jobs)", |x: &Summary| fmt_thousands(x.timeout as i64));
    row!("Early canceled (jobs)", |x: &Summary| dashes(x.early_cancelled));
    row!("Extended time limit (jobs)", |x: &Summary| dashes(x.extended));
    row!("NODE_FAILED (jobs)", |x: &Summary| dashes(x.node_failed));
    row!("COMPLETED (jobs)", |x: &Summary| fmt_thousands(x.completed as i64));
    row!("Total Jobs (jobs)", |x: &Summary| fmt_thousands(x.total_jobs as i64));
    row!("Slurm SchedMain (operations)", |x: &Summary| fmt_thousands(x.sched_main as i64));
    row!("Slurm SchedBackfill (operations)", |x: &Summary| fmt_thousands(x.sched_backfill as i64));
    row!("Total Checkpoints (count)", |x: &Summary| fmt_thousands(x.total_checkpoints as i64));
    row!("Average Wait Time (sec)", |x: &Summary| fmt_thousands(x.avg_wait.round() as i64));
    row!("Weighted Avg Wait Time (nodes x sec)", |x: &Summary| fmt_thousands(x.weighted_avg_wait.round() as i64));
    row!("Tail Waste CPU Time (cores x sec)", |x: &Summary| fmt_thousands(x.tail_waste));
    row!("Failed Tail Waste (cores x sec)", |x: &Summary| dashes(x.failed_tail_waste as usize));
    row!("Total CPU Time (cores x sec)", |x: &Summary| fmt_thousands(x.total_cpu_time));
    row!("Workload Makespan (sec)", |x: &Summary| fmt_thousands(x.makespan));
    s
}

/// Render Fig. 4: percent deltas of each policy vs the baseline, plus
/// the headline tail-waste reduction.
pub fn render_fig4(summaries: &[Summary]) -> String {
    assert!(!summaries.is_empty());
    let base = &summaries[0];
    let mut s = String::new();
    let _ = writeln!(s, "{:<28} {}", "Metric (% vs Baseline)",
        summaries[1..].iter().map(|x| format!("{:>18}", x.policy)).collect::<Vec<_>>().join(" "));
    macro_rules! row {
        ($label:expr, $get:expr) => {{
            let get = $get;
            let cells: Vec<String> = summaries[1..]
                .iter()
                .map(|x| format!("{:>+17.2}%", Summary::pct_delta(get(x), get(base))))
                .collect();
            let _ = writeln!(s, "{:<28} {}", $label, cells.join(" "));
        }};
    }
    row!("Tail Waste", |x: &Summary| x.tail_waste as f64);
    row!("Total CPU Time", |x: &Summary| x.total_cpu_time as f64);
    row!("Makespan", |x: &Summary| x.makespan as f64);
    row!("Average Wait", |x: &Summary| x.avg_wait);
    row!("Weighted Avg Wait", |x: &Summary| x.weighted_avg_wait);
    row!("Total Checkpoints", |x: &Summary| x.total_checkpoints as f64);
    let _ = writeln!(s);
    for x in &summaries[1..] {
        let _ = writeln!(
            s,
            "{:<24} tail-waste reduction: {:5.1}%  (paper: ~95%)",
            x.policy,
            x.tail_waste_reduction(base)
        );
    }
    s
}

/// Render the policy matrix: one row per policy **keyed by the
/// canonical spec string** ([`crate::policy::PolicySpec::name`]), with
/// the two metrics the policy family trades off — tail waste (and its
/// reduction vs the first row, the baseline) and weighted average wait
/// (and its delta vs baseline) — plus checkpoints, adjustment counts,
/// and the cell's perf meters: jobs simulated per wall second and peak
/// resident dense-table bytes (both render `-` when unmetered, e.g.
/// rows built from bare summaries). This is the table EXPERIMENTS.md's
/// policy-matrix section and the sweep CLI print for parameterized
/// policy grids.
///
/// Row tuple: `(name, summary, jobs_per_sec, peak_table_bytes)`.
pub fn render_policy_matrix(rows: &[(String, Summary, f64, usize)]) -> String {
    assert!(!rows.is_empty());
    let mut s = String::new();
    let base = &rows[0].1;
    let _ = writeln!(
        s,
        "{:<24} {:>14} {:>10} {:>14} {:>10} {:>8} {:>8} {:>8} {:>10} {:>12}",
        "policy",
        "tail waste",
        "reduction",
        "w.avg wait",
        "vs base",
        "ckpts",
        "cancel",
        "extend",
        "jobs/s",
        "peak tbl B"
    );
    let _ = writeln!(s, "{}", "-".repeat(24 + 14 + 10 + 14 + 10 + 8 * 3 + 10 + 12 + 9));
    for (name, x, jps, peak) in rows {
        let jps_s = if *jps > 0.0 { format!("{jps:.0}") } else { "-".to_string() };
        let peak_s = if *peak > 0 { fmt_thousands(*peak as i64) } else { "-".to_string() };
        let _ = writeln!(
            s,
            "{:<24} {:>14} {:>9.1}% {:>14.0} {:>+9.2}% {:>8} {:>8} {:>8} {:>10} {:>12}",
            name,
            fmt_thousands(x.tail_waste),
            x.tail_waste_reduction(base),
            x.weighted_avg_wait,
            Summary::pct_delta(x.weighted_avg_wait, base.weighted_avg_wait),
            x.total_checkpoints,
            x.early_cancelled,
            x.extended,
            jps_s,
            peak_s,
        );
    }
    s
}

/// CSV export (one row per policy) for plotting.
pub fn summaries_csv(summaries: &[Summary]) -> String {
    let mut s = String::from(
        "policy,total_jobs,completed,timeout,early_cancelled,extended,sched_main,sched_backfill,\
         total_checkpoints,avg_wait,weighted_avg_wait,tail_waste,node_failed,failed_tail_waste,\
         total_cpu_time,makespan\n",
    );
    for x in summaries {
        let _ = writeln!(
            s,
            "{},{},{},{},{},{},{},{},{},{:.2},{:.2},{},{},{},{},{}",
            x.policy,
            x.total_jobs,
            x.completed,
            x.timeout,
            x.early_cancelled,
            x.extended,
            x.sched_main,
            x.sched_backfill,
            x.total_checkpoints,
            x.avg_wait,
            x.weighted_avg_wait,
            x.tail_waste,
            x.node_failed,
            x.failed_tail_waste,
            x.total_cpu_time,
            x.makespan
        );
    }
    s
}

/// A fixed-width ASCII histogram (Fig. 3's panels).
pub fn render_histogram(title: &str, buckets: &[(String, u64)], width: usize) -> String {
    let max = buckets.iter().map(|&(_, c)| c).max().unwrap_or(1).max(1);
    let mut s = format!("{title}\n");
    for (label, count) in buckets {
        let bar = "#".repeat(((count * width as u64) / max) as usize);
        let _ = writeln!(s, "  {label:>16} | {bar} {count}");
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::slurm::SlurmStats;

    fn dummy(policy: &str, tail: i64) -> Summary {
        let mut s = crate::metrics::summarize(policy, &[], &SlurmStats::default());
        s.tail_waste = tail;
        s.total_cpu_time = 1000;
        s.makespan = 500;
        s
    }

    #[test]
    fn thousands_formatting() {
        assert_eq!(fmt_thousands(0), "0");
        assert_eq!(fmt_thousands(999), "999");
        assert_eq!(fmt_thousands(1000), "1,000");
        assert_eq!(fmt_thousands(875520), "875,520");
        assert_eq!(fmt_thousands(-45020), "-45,020");
    }

    #[test]
    fn table_contains_all_policies_and_rows() {
        let t = render_table1(&[dummy("Baseline", 875520), dummy("Early Cancellation", 43120)]);
        assert!(t.contains("Baseline"));
        assert!(t.contains("Early Cancellation"));
        assert!(t.contains("875,520"));
        assert!(t.contains("Tail Waste CPU Time"));
        assert!(t.contains("NODE_FAILED"));
        assert!(t.contains("Failed Tail Waste"));
        assert_eq!(t.lines().count(), 17);
    }

    #[test]
    fn fig4_reports_reduction() {
        let f = render_fig4(&[dummy("Baseline", 875520), dummy("EC", 43120)]);
        assert!(f.contains("tail-waste reduction:  95.1%"), "{f}");
    }

    #[test]
    fn policy_matrix_keys_rows_by_spec_name() {
        let rows = vec![
            ("baseline".to_string(), dummy("Baseline", 875520), 12500.0, 4_096_000),
            ("tail-aware:0.25".to_string(), dummy("Tail-Aware Cancel (0.25)", 400000), 0.0, 0),
            ("extend-budget:1200".to_string(), dummy("Extension Budget (1200 s)", 43120), 0.0, 0),
        ];
        let m = render_policy_matrix(&rows);
        assert!(m.contains("tail-aware:0.25"), "{m}");
        assert!(m.contains("extend-budget:1200"), "{m}");
        assert!(m.contains("875,520"));
        assert!(m.contains("95.1%"), "reduction vs the baseline row: {m}");
        assert!(m.contains("w.avg wait"));
        assert!(m.contains("jobs/s") && m.contains("peak tbl B"), "perf columns: {m}");
        assert!(m.contains("12500") && m.contains("4,096,000"), "metered row: {m}");
        // Unmetered rows render dashes, not zeros.
        let ta_row = m.lines().find(|l| l.starts_with("tail-aware:0.25")).unwrap();
        assert!(ta_row.trim_end().ends_with('-'), "{ta_row}");
    }

    #[test]
    fn csv_roundtrips_fields() {
        let c = summaries_csv(&[dummy("Baseline", 1)]);
        assert_eq!(c.lines().count(), 2);
        assert!(c.lines().nth(1).unwrap().starts_with("Baseline,"));
        let header_cols = c.lines().next().unwrap().split(',').count();
        let row_cols = c.lines().nth(1).unwrap().split(',').count();
        assert_eq!(header_cols, row_cols);
        assert!(c.contains(",node_failed,failed_tail_waste,"));
    }

    #[test]
    fn failure_rows_render_counts_not_dashes_when_nonzero() {
        let mut s = dummy("Baseline", 100);
        s.node_failed = 7;
        s.failed_tail_waste = 1234;
        let t = render_table1(&[s.clone()]);
        let nf = t.lines().find(|l| l.starts_with("NODE_FAILED")).unwrap();
        assert!(nf.contains('7'), "{nf}");
        let fw = t.lines().find(|l| l.starts_with("Failed Tail Waste")).unwrap();
        assert!(fw.contains("1,234"), "{fw}");
        let c = summaries_csv(&[s]);
        assert!(c.lines().nth(1).unwrap().contains(",7,1234,"), "{c}");
    }

    #[test]
    fn histogram_scales_bars() {
        let h = render_histogram("nodes", &[("1".into(), 10), ("2".into(), 5)], 20);
        assert!(h.contains("####################"));
        assert!(h.contains("##########"));
    }
}
