//! Discrete-event simulation core.
//!
//! A minimal, fast virtual-time substrate: an integer-second clock and a
//! binary-heap event queue with deterministic FIFO tie-breaking and lazy
//! invalidation (events carry a generation stamp; stale events are
//! skipped on pop). Everything above (the Slurm simulator, the daemon
//! poll loop, the workload replayer) is built on this module.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulated time in seconds. The paper's workload spans ~25 h scaled;
/// i64 gives headroom for unscaled month-long traces.
pub type Time = i64;

/// A monotonically increasing sequence number used to make the event
/// order fully deterministic: ties in time are processed in push order.
type Seq = u64;

/// An entry in the event queue.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Entry<E> {
    time: Time,
    seq: Seq,
    event: E,
}

impl<E: Eq> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.time, self.seq).cmp(&(other.time, other.seq))
    }
}

impl<E: Eq> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic time-ordered event queue.
///
/// `E` is the simulation's event payload. Cancellation is handled by the
/// caller via lazy invalidation (see [`crate::slurm`]): rather than
/// removing entries, the consumer checks on pop whether the event is
/// still authoritative.
#[derive(Debug)]
pub struct EventQueue<E: Eq> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    next_seq: Seq,
    now: Time,
    processed: u64,
}

impl<E: Eq> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E: Eq> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), next_seq: 0, now: 0, processed: 0 }
    }

    /// Current simulation time: the timestamp of the last popped event.
    pub fn now(&self) -> Time {
        self.now
    }

    /// Number of events popped so far (perf counter).
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Number of events currently queued (including stale ones).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `event` at absolute time `time`.
    ///
    /// Panics if `time` is in the past — the simulation must never
    /// schedule backwards; this catches logic errors early.
    pub fn push(&mut self, time: Time, event: E) {
        assert!(
            time >= self.now,
            "event scheduled in the past: t={time} < now={}",
            self.now
        );
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(Reverse(Entry { time, seq, event }));
    }

    /// Timestamp of the next event, if any.
    pub fn peek_time(&self) -> Option<Time> {
        self.heap.peek().map(|Reverse(e)| e.time)
    }

    /// Timestamp **and sequence number** of the next event, if any.
    ///
    /// The sequence number is the queue's FIFO tie-break key: an event
    /// pushed later carries a strictly larger seq, and equal-time
    /// events pop in seq order. Exposing it lets a caller interleave
    /// *virtual* events (ones never materialized in the heap) at an
    /// exact position inside a same-instant batch — the on-demand
    /// backfill tick chain ([`crate::slurm::ctld`]) orders its grid
    /// slots against the queue this way, reproducing the pop order the
    /// perpetual reference's physical tick events would have had.
    pub fn peek(&self) -> Option<(Time, u64)> {
        self.heap.peek().map(|Reverse(e)| (e.time, e.seq))
    }

    /// The seq the *next* push will receive. A caller that snapshots
    /// this value can later test whether a queued event was pushed
    /// before (`seq < snapshot`) or after (`seq >= snapshot`) the
    /// snapshot point — the ordering watermark of a virtual event.
    pub fn next_seq(&self) -> u64 {
        self.next_seq
    }

    /// Advance the clock to `time` without popping anything, so work
    /// performed for a virtual event (one that never entered the heap)
    /// sees — and schedules against — the correct `now`. Must not move
    /// backwards or jump past a queued event.
    pub fn advance_to(&mut self, time: Time) {
        assert!(
            time >= self.now,
            "clock advanced backwards: t={time} < now={}",
            self.now
        );
        debug_assert!(
            self.peek_time().map_or(true, |t| time <= t),
            "clock advanced past a queued event"
        );
        self.now = time;
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(Time, E)> {
        let Reverse(entry) = self.heap.pop()?;
        debug_assert!(entry.time >= self.now);
        self.now = entry.time;
        self.processed += 1;
        Some((entry.time, entry.event))
    }
}

/// Formats a simulated duration as `H:MM:SS` (Slurm-style).
///
/// Uses `unsigned_abs`: `Time::MIN.abs()` would overflow and panic.
pub fn fmt_hms(t: Time) -> String {
    let sign = if t < 0 { "-" } else { "" };
    let t = t.unsigned_abs();
    format!("{sign}{}:{:02}:{:02}", t / 3600, (t % 3600) / 60, t % 60)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, "c");
        q.push(10, "a");
        q.push(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_in_push_order() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(42, i);
        }
        for i in 0..100 {
            assert_eq!(q.pop(), Some((42, i)));
        }
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.push(5, ());
        q.push(5, ());
        q.push(7, ());
        let mut last = 0;
        while let Some((t, _)) = q.pop() {
            assert!(t >= last);
            last = t;
        }
        assert_eq!(q.now(), 7);
        assert_eq!(q.processed(), 3);
    }

    #[test]
    #[should_panic(expected = "scheduled in the past")]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push(10, ());
        q.pop();
        q.push(5, ());
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(1, 1u32);
        assert_eq!(q.pop(), Some((1, 1)));
        q.push(3, 3);
        q.push(2, 2);
        assert_eq!(q.pop(), Some((2, 2)));
        q.push(4, 4);
        assert_eq!(q.pop(), Some((3, 3)));
        assert_eq!(q.pop(), Some((4, 4)));
        assert!(q.is_empty());
    }

    #[test]
    fn peek_exposes_the_fifo_tiebreak() {
        let mut q = EventQueue::new();
        let w0 = q.next_seq();
        q.push(5, "a");
        q.push(5, "b");
        // Both events were pushed at or after the watermark; a virtual
        // event holding seq w0 would order before either of them.
        let (t, seq) = q.peek().unwrap();
        assert_eq!(t, 5);
        assert!(seq >= w0);
        assert_eq!(q.pop(), Some((5, "a")));
        let (_, seq_b) = q.peek().unwrap();
        assert!(seq_b > seq, "later push, larger seq");
        // A watermark taken now orders after everything already queued.
        assert!(q.next_seq() > seq_b);
    }

    #[test]
    fn advance_to_moves_the_clock_without_popping() {
        let mut q = EventQueue::new();
        q.push(100, ());
        q.advance_to(40);
        assert_eq!(q.now(), 40);
        assert_eq!(q.len(), 1);
        assert_eq!(q.processed(), 0);
        q.push(60, ()); // now legal relative to the advanced clock
        assert_eq!(q.pop(), Some((60, ())));
    }

    #[test]
    #[should_panic(expected = "advanced backwards")]
    fn advance_to_rejects_going_backwards() {
        let mut q = EventQueue::new();
        q.push(10, ());
        q.pop();
        q.advance_to(5);
    }

    #[test]
    fn same_instant_entries_straddling_a_shard_boundary_merge_deterministically() {
        // Two independent queues (one per federation shard) both hold
        // entries at t=50. The merged order the federation driver must
        // reproduce is (time, shard, seq): all of shard 0's t=50 batch
        // before any of shard 1's, each batch in FIFO seq order.
        let mut shard0 = EventQueue::new();
        let mut shard1 = EventQueue::new();
        shard0.push(50, "s0-a");
        shard1.push(50, "s1-a");
        shard0.push(50, "s0-b");
        shard1.push(50, "s1-b");
        let mut merged = Vec::new();
        loop {
            // Strictly-less pick keeps the earliest shard on ties.
            let pick = match (shard0.peek(), shard1.peek()) {
                (Some((t0, _)), Some((t1, _))) if t1 < t0 => 1,
                (Some(_), _) => 0,
                (None, Some(_)) => 1,
                (None, None) => break,
            };
            let q = if pick == 0 { &mut shard0 } else { &mut shard1 };
            merged.push(q.pop().unwrap().1);
        }
        assert_eq!(merged, ["s0-a", "s0-b", "s1-a", "s1-b"]);
    }

    #[test]
    fn watermark_resnapshot_after_an_empty_shard_drains() {
        // A shard that drains and later refills must hand out strictly
        // larger seqs: a watermark snapshotted while it sat empty still
        // orders before everything pushed afterwards.
        let mut q = EventQueue::new();
        q.push(10, "first");
        assert_eq!(q.pop(), Some((10, "first")));
        assert!(q.is_empty());
        let w = q.next_seq();
        assert!(q.peek().is_none(), "drained shard peeks nothing");
        q.push(20, "late");
        let (t, seq) = q.peek().unwrap();
        assert_eq!(t, 20);
        assert!(seq >= w, "re-snapshot orders before the refill");
        // Seqs never reset across the empty episode.
        assert_eq!(q.next_seq(), w + 1);
    }

    #[test]
    fn advance_to_past_end_on_a_drained_queue() {
        // With nothing queued the "don't jump past a queued event"
        // guard is vacuous: the driver may advance a drained shard's
        // clock arbitrarily far (to the federation's merge horizon) and
        // still push there afterwards.
        let mut q = EventQueue::new();
        q.push(5, ());
        q.pop();
        q.advance_to(1_000_000);
        assert_eq!(q.now(), 1_000_000);
        assert_eq!(q.processed(), 1);
        q.push(1_000_000, ());
        assert_eq!(q.pop(), Some((1_000_000, ())));
        // Idempotent at the same instant.
        q.advance_to(1_000_000);
        assert_eq!(q.now(), 1_000_000);
    }

    #[test]
    fn fmt_hms_works() {
        assert_eq!(fmt_hms(0), "0:00:00");
        assert_eq!(fmt_hms(1440), "0:24:00");
        assert_eq!(fmt_hms(86400 + 61), "24:01:01");
        assert_eq!(fmt_hms(-90), "-0:01:30");
    }

    #[test]
    fn fmt_hms_handles_extremes() {
        // Regression: `Time::MIN.abs()` overflows; unsigned_abs doesn't.
        assert_eq!(fmt_hms(Time::MIN), "-2562047788015215:30:08");
        assert_eq!(fmt_hms(Time::MAX), "2562047788015215:30:07");
    }
}
