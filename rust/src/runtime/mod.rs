//! PJRT runtime: load and execute the AOT-compiled decision model.
//!
//! `make artifacts` (build time, Python) lowers the Layer-2 JAX model —
//! which embeds the Layer-1 Pallas kernels — to HLO *text*, one module
//! per (R, Q, H) shape variant, named `decision_r{R}_q{Q}_h{H}.hlo.txt`.
//! This module loads every variant once at daemon startup
//! (`HloModuleProto::from_text_file` → `PjRtClient::compile`) and then
//! serves [`DecisionEngine::evaluate`] calls from the daemon's poll
//! loop: pick the smallest variant that fits the live batch, pad into
//! a pooled scratch batch (zero per-call allocation once warmed —
//! `DecisionBatch::padded_into`), build literals, execute, unpack the
//! 6-tuple. Python is never involved at
//! runtime — the compiled executables are pure XLA:CPU programs.
//!
//! HLO text (not serialized protos) is the interchange format: jax
//! >= 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see `python/compile/aot.py`).
//!
//! ## Feature gating
//!
//! The XLA bindings are not on crates.io and the crate is otherwise
//! dependency-free, so the real engine is compiled only with
//! `--features pjrt` (which expects a vendored `xla` crate added as a
//! path dependency by the artifact pipeline). The default build ships
//! a stub [`PjrtEngine`] whose `load` always errors — every caller
//! already handles load failure by falling back to the native oracle,
//! so `cargo build`/`test`/`bench` work out of the box.

use std::path::PathBuf;

#[cfg(feature = "pjrt")]
pub use enabled::PjrtEngine;
#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtEngine;

/// Parse `(r, q, h)` out of `decision_r{R}_q{Q}_h{H}.hlo.txt`.
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))] // stub builds use it only in tests
fn parse_variant_name(name: &str) -> Option<(usize, usize, usize)> {
    let rest = name.strip_prefix("decision_r")?.strip_suffix(".hlo.txt")?;
    let (r, rest) = rest.split_once("_q")?;
    let (q, h) = rest.split_once("_h")?;
    Some((r.parse().ok()?, q.parse().ok()?, h.parse().ok()?))
}

/// Resolve the default artifacts directory: `$TAILTAMER_ARTIFACTS`, or
/// `artifacts/` relative to the current directory, or relative to the
/// crate root (for `cargo test` / `cargo bench` from anywhere).
pub fn default_artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("TAILTAMER_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let cwd = PathBuf::from("artifacts");
    if cwd.exists() {
        return cwd;
    }
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::path::Path;

    use crate::analytics::{DecisionBatch, DecisionEngine, DecisionOutputs};
    use crate::errors::Result;

    /// Stub for the default (dependency-free) build: loading always
    /// fails with an actionable message, so callers fall back to
    /// [`crate::analytics::NativeEngine`].
    pub struct PjrtEngine {
        _private: (),
    }

    impl PjrtEngine {
        pub fn load(_dir: &Path) -> Result<Self> {
            Err(crate::err!(
                "built without the `pjrt` feature (no vendored xla crate); \
                 use --engine native, or rebuild with --features pjrt"
            ))
        }

        /// Shape variants available, smallest first.
        pub fn shapes(&self) -> Vec<(usize, usize, usize)> {
            Vec::new()
        }
    }

    impl DecisionEngine for PjrtEngine {
        fn name(&self) -> &str {
            "pjrt-stub"
        }

        fn evaluate(&mut self, _batch: &DecisionBatch) -> Result<DecisionOutputs> {
            Err(crate::err!("pjrt stub cannot evaluate (built without the `pjrt` feature)"))
        }
    }
}

#[cfg(feature = "pjrt")]
mod enabled {
    use std::path::{Path, PathBuf};

    use crate::analytics::{DecisionBatch, DecisionEngine, DecisionOutputs};
    use crate::err;
    use crate::errors::{Context, Result};

    use super::parse_variant_name;

    /// One compiled shape variant.
    struct Variant {
        r: usize,
        q: usize,
        h: usize,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The production engine: PJRT-compiled JAX/Pallas decision model.
    pub struct PjrtEngine {
        variants: Vec<Variant>,
        /// Pooled padding target: batches smaller than the selected
        /// variant are padded into this reusable arena instead of
        /// allocating a fresh `DecisionBatch` per call (§Perf — the
        /// literal-building path is the per-poll hot loop). Warms up
        /// to the largest variant shape ever used and stays there.
        pad_scratch: DecisionBatch,
        /// Executions so far (observability).
        pub calls: u64,
    }

    impl PjrtEngine {
        /// Load and compile every variant in `dir` on the PJRT CPU client.
        pub fn load(dir: &Path) -> Result<Self> {
            let client = xla::PjRtClient::cpu().map_err(|e| err!("PJRT CPU client: {e}"))?;
            let mut found: Vec<(usize, usize, usize, PathBuf)> = std::fs::read_dir(dir)
                .with_context(|| format!("artifact dir {} (run `make artifacts`)", dir.display()))?
                .filter_map(|e| e.ok())
                .filter_map(|e| {
                    let name = e.file_name().into_string().ok()?;
                    let (r, q, h) = parse_variant_name(&name)?;
                    Some((r, q, h, e.path()))
                })
                .collect();
            if found.is_empty() {
                crate::bail!(
                    "no decision_r*_q*_h*.hlo.txt artifacts in {} (run `make artifacts`)",
                    dir.display()
                );
            }
            // Smallest first: selection picks the first that fits.
            found.sort_by_key(|&(r, q, h, _)| (r * q * h, r, q, h));

            let mut variants = Vec::with_capacity(found.len());
            for (r, q, h, path) in found {
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| err!("parse {}: {e}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe =
                    client.compile(&comp).map_err(|e| err!("compile {}: {e}", path.display()))?;
                variants.push(Variant { r, q, h, exe });
            }
            Ok(Self { variants, pad_scratch: DecisionBatch::default(), calls: 0 })
        }

        /// Shape variants available, smallest first.
        pub fn shapes(&self) -> Vec<(usize, usize, usize)> {
            self.variants.iter().map(|v| (v.r, v.q, v.h)).collect()
        }

        /// Index of the smallest variant that fits — an index, not a
        /// reference, so `evaluate` can borrow the variant and the pad
        /// scratch disjointly.
        fn pick(&self, r: usize, q: usize, h: usize) -> Result<usize> {
            self.variants
                .iter()
                .position(|v| v.r >= r && v.q >= q && v.h >= h)
                .ok_or_else(|| {
                    err!(
                        "batch (R={r}, Q={q}, H={h}) exceeds the largest compiled variant {:?}; \
                         add a variant in python/compile/model.py::VARIANTS",
                        self.variants.last().map(|v| (v.r, v.q, v.h))
                    )
                })
        }
    }

    fn lit2(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
        debug_assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| err!("reshape [{rows},{cols}]: {e}"))
    }

    impl DecisionEngine for PjrtEngine {
        fn name(&self) -> &str {
            "pjrt"
        }

        fn evaluate(&mut self, batch: &DecisionBatch) -> Result<DecisionOutputs> {
            let vi = self.pick(batch.r, batch.q, batch.h)?;
            let v = &self.variants[vi];
            let b = if (batch.r, batch.q, batch.h) == (v.r, v.q, v.h) {
                batch
            } else {
                // Pad into the pooled scratch: zero allocation per
                // call once the pool has warmed to this variant shape.
                batch.padded_into(v.r, v.q, v.h, &mut self.pad_scratch);
                &self.pad_scratch
            };

            // Input order per artifacts/manifest.json.
            let inputs = [
                lit2(&b.ts, v.r, v.h)?,
                lit2(&b.mask, v.r, v.h)?,
                xla::Literal::vec1(&b.cur_end),
                xla::Literal::vec1(&b.nodes_r),
                xla::Literal::vec1(&b.rmask),
                xla::Literal::vec1(&b.pred_start),
                xla::Literal::vec1(&b.nodes_q),
                xla::Literal::vec1(&b.free_at),
                xla::Literal::vec1(&b.qmask),
                xla::Literal::vec1(&b.params),
            ];
            let result =
                v.exe.execute::<xla::Literal>(&inputs).map_err(|e| err!("execute: {e}"))?;
            self.calls += 1;
            let tuple = result[0][0]
                .to_literal_sync()
                .map_err(|e| err!("fetch result: {e}"))?
                .to_tuple()
                .map_err(|e| err!("untuple: {e}"))?;
            if tuple.len() != 7 {
                crate::bail!(
                    "expected 7 outputs, got {} (stale artifacts? re-run `make artifacts`)",
                    tuple.len()
                );
            }
            let mut vecs = tuple
                .into_iter()
                .map(|l| l.to_vec::<f32>().map_err(|e| err!("output to_vec: {e}")));
            let mut next = || vecs.next().unwrap();
            let out = DecisionOutputs {
                pred_next: next()?,
                ext_end: next()?,
                fits: next()?,
                conflict: next()?,
                count: next()?,
                mean_int: next()?,
                delay_cost: next()?,
            };
            Ok(out.truncated(batch.r))
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        /// The pad pool must warm up once and then serve every
        /// subsequent undersized batch without reallocating. Variants
        /// stay empty — the pool is engine state, not executable
        /// state, so this typechecks and runs without artifacts.
        #[test]
        fn pad_scratch_is_pooled_across_calls() {
            let mut engine =
                PjrtEngine { variants: Vec::new(), pad_scratch: DecisionBatch::default(), calls: 0 };
            assert!(engine.pick(1, 1, 1).is_err(), "no variants compiled");

            let mut batch = DecisionBatch::empty(2, 3, 2, 30.0, 0.0);
            batch.set_row(0, crate::slurm::JobId(1), &[420, 840], 1440, 1);
            batch.padded_into(16, 64, 16, &mut engine.pad_scratch);
            let ptr = engine.pad_scratch.ts.as_ptr();
            let cap = engine.pad_scratch.ts.capacity();
            for _ in 0..3 {
                batch.padded_into(16, 64, 16, &mut engine.pad_scratch);
                assert_eq!(engine.pad_scratch.ts.as_ptr(), ptr, "pool reused");
                assert_eq!(engine.pad_scratch.ts.capacity(), cap, "pool not regrown");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_name_parsing() {
        assert_eq!(parse_variant_name("decision_r16_q64_h16.hlo.txt"), Some((16, 64, 16)));
        assert_eq!(parse_variant_name("decision_r64_q256_h32.hlo.txt"), Some((64, 256, 32)));
        assert_eq!(parse_variant_name("decision_r64.hlo.txt"), None);
        assert_eq!(parse_variant_name("manifest.json"), None);
        assert_eq!(parse_variant_name("decision_rX_qY_hZ.hlo.txt"), None);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_load_fails_with_actionable_message() {
        let err = PjrtEngine::load(&default_artifacts_dir()).unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }

    // Execution tests against the NativeEngine oracle live in
    // rust/tests/pjrt_runtime.rs (they need built artifacts).
}
