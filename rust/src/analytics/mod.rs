//! Decision analytics: batch/output types, the engine abstraction, and
//! the pure-Rust oracle engine.
//!
//! The autonomy daemon batches all running checkpointing jobs (R rows)
//! and all queued jobs (Q rows) into a [`DecisionBatch`] once per poll
//! tick and hands it to a [`DecisionEngine`]:
//!
//! - [`crate::runtime::PjrtEngine`] executes the AOT-compiled JAX/Pallas
//!   decision model (the production hot path);
//! - [`NativeEngine`] (here) re-implements the same f32 math in Rust —
//!   the correctness oracle the PJRT path is tested against, and a
//!   fallback when artifacts are absent.
//!
//! Keep the formulas in lockstep with `python/compile/kernels/ref.py`.

use crate::errors::Result;
use crate::simtime::Time;
use crate::slurm::JobId;

/// Sentinel for "no interval estimate" (fewer than 2 checkpoints).
/// Mirrors `ref.py::NO_ESTIMATE`.
pub const NO_ESTIMATE: f32 = -1.0;

/// Fixed-shape, padded, f32 batch — the decision model's input tuple.
/// Field order mirrors the artifact manifest (`artifacts/manifest.json`).
#[derive(Debug, Clone)]
pub struct DecisionBatch {
    pub r: usize,
    pub q: usize,
    pub h: usize,
    /// f32[R,H] row-major checkpoint timestamps (0-padded).
    pub ts: Vec<f32>,
    /// f32[R,H] validity mask.
    pub mask: Vec<f32>,
    /// f32[R] expected end under the current limit.
    pub cur_end: Vec<f32>,
    /// f32[R] nodes held.
    pub nodes_r: Vec<f32>,
    /// f32[R] row validity.
    pub rmask: Vec<f32>,
    /// f32[Q] backfill-predicted starts.
    pub pred_start: Vec<f32>,
    /// f32[Q] nodes requested.
    pub nodes_q: Vec<f32>,
    /// f32[Q] free nodes at the predicted start.
    pub free_at: Vec<f32>,
    /// f32[Q] row validity.
    pub qmask: Vec<f32>,
    /// [margin, safety].
    pub params: [f32; 2],
    /// Which job each R row refers to (not an engine input).
    pub row_jobs: Vec<Option<JobId>>,
}

impl Default for DecisionBatch {
    /// A zero-shape placeholder (pooled-arena slot before first use).
    fn default() -> Self {
        Self::empty(0, 0, 0, 0.0, 0.0)
    }
}

impl DecisionBatch {
    /// An all-masked empty batch of shape (r, q, h). Delegates to
    /// [`reset`](Self::reset), the single shape-building authority.
    pub fn empty(r: usize, q: usize, h: usize, margin: f32, safety: f32) -> Self {
        let mut b = Self {
            r: 0,
            q: 0,
            h: 0,
            ts: Vec::new(),
            mask: Vec::new(),
            cur_end: Vec::new(),
            nodes_r: Vec::new(),
            rmask: Vec::new(),
            pred_start: Vec::new(),
            nodes_q: Vec::new(),
            free_at: Vec::new(),
            qmask: Vec::new(),
            params: [0.0, 0.0],
            row_jobs: Vec::new(),
        };
        b.reset(r, q, h, margin, safety);
        b
    }

    /// Re-shape in place to an all-masked empty batch, reusing the
    /// backing buffers: the daemon's pooled chunk arena (§Perf) —
    /// equivalent to [`empty`](Self::empty) with zero steady-state
    /// allocation once the buffers have warmed up.
    pub fn reset(&mut self, r: usize, q: usize, h: usize, margin: f32, safety: f32) {
        self.r = r;
        self.q = q;
        self.h = h;
        self.params = [margin, safety];
        for v in [&mut self.ts, &mut self.mask] {
            v.clear();
            v.resize(r * h, 0.0);
        }
        for v in [&mut self.cur_end, &mut self.nodes_r, &mut self.rmask] {
            v.clear();
            v.resize(r, 0.0);
        }
        for v in [&mut self.pred_start, &mut self.nodes_q, &mut self.free_at, &mut self.qmask] {
            v.clear();
            v.resize(q, 0.0);
        }
        self.row_jobs.clear();
        self.row_jobs.resize(r, None);
    }

    /// Fill running-job row `i`. `history` is the rolling checkpoint
    /// window (ascending); only the newest `h` entries are used.
    pub fn set_row(&mut self, i: usize, job: JobId, history: &[Time], cur_end: Time, nodes: u32) {
        assert!(i < self.r);
        let tail = &history[history.len().saturating_sub(self.h)..];
        for (k, &t) in tail.iter().enumerate() {
            self.ts[i * self.h + k] = t as f32;
            self.mask[i * self.h + k] = 1.0;
        }
        self.cur_end[i] = cur_end as f32;
        self.nodes_r[i] = nodes as f32;
        self.rmask[i] = 1.0;
        self.row_jobs[i] = Some(job);
    }

    /// Fill queued-job column `k`.
    pub fn set_queue(&mut self, k: usize, pred_start: Time, nodes: u32, free_at: u32) {
        assert!(k < self.q);
        self.pred_start[k] = pred_start as f32;
        self.nodes_q[k] = nodes as f32;
        self.free_at[k] = free_at as f32;
        self.qmask[k] = 1.0;
    }

    /// Grow into a (possibly larger) target shape, preserving content.
    /// Allocating convenience wrapper over
    /// [`padded_into`](Self::padded_into); hot paths (the PJRT
    /// engine's per-call padding) keep a pooled target batch and call
    /// `padded_into` directly instead.
    pub fn padded_to(&self, r: usize, q: usize, h: usize) -> DecisionBatch {
        let mut out = DecisionBatch::default();
        self.padded_into(r, q, h, &mut out);
        out
    }

    /// Grow into `out` at a (possibly larger) target shape, preserving
    /// content and reusing `out`'s backing buffers — zero steady-state
    /// allocation once the pool has warmed up to the largest variant
    /// shape (the same arena idiom as [`reset`](Self::reset), which
    /// does the reshaping).
    pub fn padded_into(&self, r: usize, q: usize, h: usize, out: &mut DecisionBatch) {
        assert!(r >= self.r && q >= self.q && h >= self.h);
        out.reset(r, q, h, self.params[0], self.params[1]);
        for i in 0..self.r {
            for k in 0..self.h {
                out.ts[i * h + k] = self.ts[i * self.h + k];
                out.mask[i * h + k] = self.mask[i * self.h + k];
            }
            out.cur_end[i] = self.cur_end[i];
            out.nodes_r[i] = self.nodes_r[i];
            out.rmask[i] = self.rmask[i];
            out.row_jobs[i] = self.row_jobs[i];
        }
        out.pred_start[..self.q].copy_from_slice(&self.pred_start);
        out.nodes_q[..self.q].copy_from_slice(&self.nodes_q);
        out.free_at[..self.q].copy_from_slice(&self.free_at);
        out.qmask[..self.q].copy_from_slice(&self.qmask);
    }
}

/// Per-running-job outputs of the decision model (all length R).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DecisionOutputs {
    pub pred_next: Vec<f32>,
    pub ext_end: Vec<f32>,
    pub fits: Vec<f32>,
    pub conflict: Vec<f32>,
    pub count: Vec<f32>,
    pub mean_int: Vec<f32>,
    /// Worst-case extension delay cost, node-seconds (threshold-Hybrid).
    pub delay_cost: Vec<f32>,
}

impl DecisionOutputs {
    pub fn truncated(mut self, r: usize) -> Self {
        self.pred_next.truncate(r);
        self.ext_end.truncate(r);
        self.fits.truncate(r);
        self.conflict.truncate(r);
        self.count.truncate(r);
        self.mean_int.truncate(r);
        self.delay_cost.truncate(r);
        self
    }

    /// All seven per-row output vectors in manifest order — the single
    /// field list that [`reset`](Self::reset) and the daemon's chunk
    /// merge iterate, so adding a field cannot silently miss a site.
    pub fn fields(&self) -> [&Vec<f32>; 7] {
        [
            &self.pred_next,
            &self.ext_end,
            &self.fits,
            &self.conflict,
            &self.count,
            &self.mean_int,
            &self.delay_cost,
        ]
    }

    /// Mutable view of [`fields`](Self::fields), same order.
    pub fn fields_mut(&mut self) -> [&mut Vec<f32>; 7] {
        [
            &mut self.pred_next,
            &mut self.ext_end,
            &mut self.fits,
            &mut self.conflict,
            &mut self.count,
            &mut self.mean_int,
            &mut self.delay_cost,
        ]
    }

    /// Re-shape in place to `r` zeroed rows, reusing the backing
    /// buffers (the daemon's pooled output arena, §Perf).
    pub fn reset(&mut self, r: usize) {
        for v in self.fields_mut() {
            v.clear();
            v.resize(r, 0.0);
        }
    }
}

/// The daemon's pluggable analytics backend.
///
/// Not `Send`: the PJRT client is single-threaded by design; the daemon
/// owns its engine and always calls it from one thread.
pub trait DecisionEngine {
    fn name(&self) -> &str;
    fn evaluate(&mut self, batch: &DecisionBatch) -> Result<DecisionOutputs>;
    /// Allocation-free variant: write the outputs into a caller-owned
    /// pooled buffer (re-shaped to `batch.r` rows first). The daemon's
    /// poll loop uses this so the steady state allocates nothing
    /// (§Perf); the default delegates to [`evaluate`](Self::evaluate)
    /// for simple implementations.
    fn evaluate_into(&mut self, batch: &DecisionBatch, out: &mut DecisionOutputs) -> Result<()> {
        *out = self.evaluate(batch)?;
        Ok(())
    }
}

/// Share one engine across several sequential scenario runs (e.g. the
/// four policies of a comparison): loading + compiling the PJRT
/// executables once instead of per policy (§Perf: saves ~0.6 s per
/// avoided load on this testbed).
#[derive(Clone)]
pub struct SharedEngine(pub std::rc::Rc<std::cell::RefCell<dyn DecisionEngine>>);

impl SharedEngine {
    pub fn new(engine: impl DecisionEngine + 'static) -> Self {
        Self(std::rc::Rc::new(std::cell::RefCell::new(engine)))
    }
}

impl DecisionEngine for SharedEngine {
    fn name(&self) -> &str {
        "shared"
    }

    fn evaluate(&mut self, batch: &DecisionBatch) -> Result<DecisionOutputs> {
        self.0.borrow_mut().evaluate(batch)
    }

    fn evaluate_into(&mut self, batch: &DecisionBatch, out: &mut DecisionOutputs) -> Result<()> {
        self.0.borrow_mut().evaluate_into(batch, out)
    }
}

/// Pure-Rust oracle implementing the L2 model's math in f32, mirroring
/// `ref.py` operation for operation.
///
/// The conflict/delay-cost scan comes in two flavours:
///
/// - **windowed** (default): queue columns are sorted by `pred_start`
///   once per batch, and each row's conflict window
///   `[cur_end, ext_end)` becomes a `partition_point` range over that
///   order — O(log Q + matches) per row instead of the naive O(Q)
///   sweep, O(R·log Q + R·matches) per batch instead of O(R·Q).
///   Matches are re-sorted into original column order before the f32
///   cost accumulation, so every sum adds the same terms in the same
///   order as the naive loop — outputs are **bit-identical**.
/// - **naive** ([`NativeEngine::naive`]): the retained full O(R·Q)
///   loop, kept as the second oracle the windowed scan is
///   differentially fuzzed against (`rust/tests/engine_fuzz.rs`) and
///   raced against in `benches/engine_hotpath.rs`.
#[derive(Debug)]
pub struct NativeEngine {
    windowed: bool,
    /// Scratch: unmasked queue columns sorted by `pred_start` (pooled).
    order: Vec<u32>,
    /// Scratch: one row's conflicting columns, original order (pooled).
    hits: Vec<u32>,
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeEngine {
    /// The default engine: windowed conflict scan.
    pub fn new() -> Self {
        Self { windowed: true, order: Vec::new(), hits: Vec::new() }
    }

    /// The retained naive O(R·Q) conflict loop (second oracle).
    pub fn naive() -> Self {
        Self { windowed: false, order: Vec::new(), hits: Vec::new() }
    }
}

impl DecisionEngine for NativeEngine {
    fn name(&self) -> &str {
        if self.windowed { "native" } else { "native-naive" }
    }

    fn evaluate(&mut self, b: &DecisionBatch) -> Result<DecisionOutputs> {
        let mut out = DecisionOutputs::default();
        self.evaluate_into(b, &mut out)?;
        Ok(out)
    }

    fn evaluate_into(&mut self, b: &DecisionBatch, out: &mut DecisionOutputs) -> Result<()> {
        let (r, q, h) = (b.r, b.q, b.h);
        out.reset(r);
        let (margin, safety) = (b.params[0], b.params[1]);

        if self.windowed {
            // Sort the unmasked queue columns by predicted start once
            // per batch; every row's window scan below narrows to a
            // contiguous range of this order. In-place unstable sort:
            // ties in pred_start don't matter because matches are
            // re-sorted into column order before accumulation.
            self.order.clear();
            self.order.extend((0..q as u32).filter(|&k| b.qmask[k as usize] > 0.0));
            self.order
                .sort_unstable_by(|&a, &c| b.pred_start[a as usize].total_cmp(&b.pred_start[c as usize]));
        }

        for i in 0..r {
            let ts = &b.ts[i * h..(i + 1) * h];
            let mask = &b.mask[i * h..(i + 1) * h];

            // ckpt_stats (see kernels/ckpt_stats.py)
            let mut count = 0.0f32;
            let mut last = 0.0f32;
            for k in 0..h {
                count += mask[k];
                last = last.max(ts[k] * mask[k]);
            }
            let mut nd = 0.0f32;
            let mut sum_d = 0.0f32;
            for k in 0..h.saturating_sub(1) {
                let dm = mask[k + 1] * mask[k];
                nd += dm;
                sum_d += (ts[k + 1] - ts[k]) * dm;
            }
            let nd_safe = nd.max(1.0);
            let mean = sum_d / nd_safe;
            let mut var = 0.0f32;
            for k in 0..h.saturating_sub(1) {
                let dm = mask[k + 1] * mask[k];
                let d = ts[k + 1] - ts[k] - mean;
                var += dm * d * d;
            }
            var /= nd_safe;
            let std = var.sqrt();
            let have = count >= 2.0;
            let mean = if have { mean } else { NO_ESTIMATE };
            let std = if have { std } else { 0.0 };

            // prediction (see model.py)
            let pred_next = if have { last + mean + safety * std } else { -1.0 };
            let ext_end = if have { pred_next + margin } else { -1.0 };
            let fits = if have && pred_next + margin <= b.cur_end[i] { 1.0 } else { 0.0 };

            // conflict + delay_cost (see kernels/conflict.py,
            // kernels/delay_cost.py)
            let rmask_eff = b.rmask[i] * if have { 1.0 } else { 0.0 };
            let mut conflict = 0.0f32;
            let mut cost = 0.0f32;
            if rmask_eff > 0.0 {
                if self.windowed {
                    // The window predicate `cur_end <= pred_start <
                    // ext_end` is a contiguous slice of the sorted
                    // order; only those columns are examined. Matches
                    // are gathered, restored to original column order,
                    // and accumulated — the identical f32 additions in
                    // the identical order as the naive loop below.
                    let lo = b.cur_end[i];
                    let s = self.order.partition_point(|&k| b.pred_start[k as usize] < lo);
                    // Searched within the suffix so an inverted window
                    // (ext_end < cur_end, the fits-comfortably case)
                    // yields an empty range instead of s > e.
                    let e = s + self.order[s..].partition_point(|&k| b.pred_start[k as usize] < ext_end);
                    self.hits.clear();
                    for &k in &self.order[s..e] {
                        if b.nodes_q[k as usize] > b.free_at[k as usize] - b.nodes_r[i] {
                            self.hits.push(k);
                        }
                    }
                    self.hits.sort_unstable();
                    for &k in &self.hits {
                        conflict = 1.0;
                        let push = (ext_end - b.pred_start[k as usize]).max(0.0);
                        cost += push * b.nodes_q[k as usize];
                    }
                } else {
                    for k in 0..q {
                        let in_window =
                            b.pred_start[k] >= b.cur_end[i] && b.pred_start[k] < ext_end;
                        let needs_r = b.nodes_q[k] > b.free_at[k] - b.nodes_r[i];
                        if in_window && needs_r && b.qmask[k] > 0.0 {
                            conflict = 1.0;
                            let push = (ext_end - b.pred_start[k]).max(0.0);
                            cost += push * b.nodes_q[k];
                        }
                    }
                }
            }

            out.pred_next[i] = pred_next;
            out.ext_end[i] = ext_end;
            out.fits[i] = fits;
            out.conflict[i] = conflict;
            out.count[i] = count;
            out.mean_int[i] = mean;
            out.delay_cost[i] = cost;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn canonical_batch() -> DecisionBatch {
        // The paper's canonical job: ckpts at 420/840/1260, limit 1440.
        let mut b = DecisionBatch::empty(16, 64, 16, 30.0, 0.0);
        b.set_row(0, JobId(7), &[420, 840, 1260], 1440, 1);
        b
    }

    #[test]
    fn canonical_prediction() {
        let out = NativeEngine::new().evaluate(&canonical_batch()).unwrap();
        assert_eq!(out.count[0], 3.0);
        assert_eq!(out.mean_int[0], 420.0);
        assert_eq!(out.pred_next[0], 1680.0);
        assert_eq!(out.ext_end[0], 1710.0);
        assert_eq!(out.fits[0], 0.0, "1680+30 > 1440");
        assert_eq!(out.conflict[0], 0.0, "empty queue");
        // Masked rows stay sentineled.
        assert_eq!(out.pred_next[5], -1.0);
        assert_eq!(out.count[5], 0.0);
    }

    #[test]
    fn two_checkpoints_fit() {
        let mut b = DecisionBatch::empty(16, 64, 16, 30.0, 0.0);
        b.set_row(0, JobId(0), &[420, 840], 1440, 1);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.pred_next[0], 1260.0);
        assert_eq!(out.fits[0], 1.0, "1260+30 <= 1440");
    }

    #[test]
    fn one_checkpoint_no_estimate() {
        let mut b = DecisionBatch::empty(16, 64, 16, 30.0, 0.0);
        b.set_row(0, JobId(0), &[420], 1440, 1);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.count[0], 1.0);
        assert_eq!(out.mean_int[0], NO_ESTIMATE);
        assert_eq!(out.fits[0], 0.0);
        assert_eq!(out.conflict[0], 0.0, "no estimate -> never extend, so no conflict");
    }

    #[test]
    fn conflict_detection() {
        let mut b = canonical_batch();
        // Queued job planned at 1500 (inside [1440, 1710)), needs 10
        // nodes, 9 free at 1500 without our 1 node -> wait: free_at=10
        // includes our release; 10 - 1 = 9 < 10 -> conflict.
        b.set_queue(0, 1500, 10, 10);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.conflict[0], 1.0);

        // Plenty free -> no conflict.
        let mut b2 = canonical_batch();
        b2.set_queue(0, 1500, 10, 20);
        assert_eq!(NativeEngine::new().evaluate(&b2).unwrap().conflict[0], 0.0);

        // Outside the window -> no conflict.
        let mut b3 = canonical_batch();
        b3.set_queue(0, 1710, 10, 10);
        assert_eq!(NativeEngine::new().evaluate(&b3).unwrap().conflict[0], 0.0);
    }

    #[test]
    fn delay_cost_arithmetic() {
        let mut b = canonical_batch(); // cur_end 1440, ext_end 1710
        // Two conflicting queued jobs: pushed from 1500 (4 nodes) and
        // 1700 (2 nodes) to 1710: cost = 210*4 + 10*2 = 860.
        b.set_queue(0, 1500, 4, 4);
        b.set_queue(1, 1700, 2, 2);
        b.set_queue(2, 1800, 9, 0); // outside window: free
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.conflict[0], 1.0);
        assert_eq!(out.delay_cost[0], 210.0 * 4.0 + 10.0 * 2.0);
        // No conflict -> zero cost.
        let out2 = NativeEngine::new().evaluate(&canonical_batch()).unwrap();
        assert_eq!(out2.delay_cost[0], 0.0);
    }

    #[test]
    fn safety_factor_widens_prediction() {
        let mut b = DecisionBatch::empty(16, 64, 16, 0.0, 1.0);
        // Intervals 400 and 440: mean 420, std 20.
        b.set_row(0, JobId(0), &[400, 800, 1240], 2000, 1);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.mean_int[0], 420.0);
        assert_eq!(out.pred_next[0], 1240.0 + 420.0 + 20.0);
    }

    #[test]
    fn history_window_uses_newest() {
        let mut b = DecisionBatch::empty(16, 64, 4, 30.0, 0.0);
        let hist: Vec<Time> = (1..=10).map(|k| k * 100).collect();
        b.set_row(0, JobId(0), &hist, 5000, 1);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.count[0], 4.0);
        assert_eq!(out.pred_next[0], 1000.0 + 100.0);
    }

    fn assert_batches_equal(a: &DecisionBatch, b: &DecisionBatch, what: &str) {
        // DecisionBatch deliberately has no PartialEq (it's a pooled
        // arena, not a value); compare field by field.
        assert_eq!((a.r, a.q, a.h), (b.r, b.q, b.h), "{what}: shape");
        assert_eq!(a.params, b.params, "{what}: params");
        assert_eq!(a.ts, b.ts, "{what}: ts");
        assert_eq!(a.mask, b.mask, "{what}: mask");
        assert_eq!(a.cur_end, b.cur_end, "{what}: cur_end");
        assert_eq!(a.nodes_r, b.nodes_r, "{what}: nodes_r");
        assert_eq!(a.rmask, b.rmask, "{what}: rmask");
        assert_eq!(a.pred_start, b.pred_start, "{what}: pred_start");
        assert_eq!(a.nodes_q, b.nodes_q, "{what}: nodes_q");
        assert_eq!(a.free_at, b.free_at, "{what}: free_at");
        assert_eq!(a.qmask, b.qmask, "{what}: qmask");
        assert_eq!(a.row_jobs, b.row_jobs, "{what}: row_jobs");
    }

    #[test]
    fn padded_into_matches_padded_to_and_reuses_buffers() {
        let mut b = DecisionBatch::empty(2, 3, 2, 30.0, 0.5);
        b.set_row(0, JobId(7), &[420, 840], 1440, 1);
        b.set_row(1, JobId(9), &[100], 900, 2);
        b.set_queue(0, 1500, 4, 4);
        b.set_queue(2, 1700, 2, 8);

        let alloc = b.padded_to(16, 64, 16);
        let mut pooled = DecisionBatch::default();
        b.padded_into(16, 64, 16, &mut pooled);
        assert_batches_equal(&alloc, &pooled, "first pad");

        // Pool reuse: once warmed to the variant shape, repeated pads
        // must not reallocate any backing buffer (the PJRT engine
        // calls this once per poll tick).
        let ptrs = (pooled.ts.as_ptr(), pooled.qmask.as_ptr(), pooled.row_jobs.as_ptr());
        let caps = (pooled.ts.capacity(), pooled.qmask.capacity(), pooled.row_jobs.capacity());
        for _ in 0..3 {
            b.padded_into(16, 64, 16, &mut pooled);
            assert_eq!(
                ptrs,
                (pooled.ts.as_ptr(), pooled.qmask.as_ptr(), pooled.row_jobs.as_ptr()),
                "warm pad must reuse the pooled buffers"
            );
            assert_eq!(
                caps,
                (pooled.ts.capacity(), pooled.qmask.capacity(), pooled.row_jobs.capacity()),
                "warm pad must not regrow the pooled buffers"
            );
        }
        assert_batches_equal(&alloc, &pooled, "warm pad");

        // Identity pad (same shape) preserves content too.
        let same = b.padded_to(2, 3, 2);
        assert_batches_equal(&b, &same, "identity pad");
    }

    #[test]
    fn padding_preserves_outputs() {
        let small = canonical_batch();
        let big = small.padded_to(64, 256, 32);
        let mut e = NativeEngine::new();
        let a = e.evaluate(&small).unwrap();
        let b = e.evaluate(&big).unwrap().truncated(16);
        assert_eq!(a, b);
    }

    #[test]
    fn windowed_and_naive_scans_agree_bitwise() {
        // Unsorted, duplicated, boundary-straddling queue columns: the
        // windowed scan must reproduce the naive loop exactly,
        // including the f32 cost-accumulation order.
        let mut b = canonical_batch(); // cur_end 1440, ext_end 1710
        b.set_queue(0, 1700, 2, 2);
        b.set_queue(1, 1440, 4, 4); // exactly at the lower boundary
        b.set_queue(2, 1710, 9, 0); // exactly at the upper boundary: out
        b.set_queue(3, 1500, 4, 4);
        b.set_queue(4, 1500, 1, 50); // in window but plenty free
        b.set_queue(5, 100, 8, 0); // before the window
        let a = NativeEngine::new().evaluate(&b).unwrap();
        let n = NativeEngine::naive().evaluate(&b).unwrap();
        assert_eq!(a, n);
        assert_eq!(a.conflict[0], 1.0);
        // 270*2 + 270*4 + 210*4 accumulated in column order 0,1,3.
        assert_eq!(a.delay_cost[0], (1710.0 - 1700.0) * 2.0 + 270.0 * 4.0 + 210.0 * 4.0);
    }

    #[test]
    fn inverted_window_fitting_row_with_queue_between() {
        // Regression: a row whose next checkpoint fits comfortably has
        // ext_end < cur_end; queue columns with pred_start inside
        // [ext_end, cur_end) made the windowed scan's partition_point
        // range invert (s > e) and panic. The scan must yield the
        // naive loop's empty match set instead.
        let mut b = DecisionBatch::empty(4, 8, 8, 30.0, 0.0);
        // ckpts 420/840: pred_next 1260, ext_end 1290, cur_end 4000.
        b.set_row(0, JobId(0), &[420, 840], 4000, 1);
        b.set_queue(0, 2000, 4, 1); // in [ext_end, cur_end): must not match
        b.set_queue(1, 1300, 4, 1);
        b.set_queue(2, 5000, 4, 1);
        let a = NativeEngine::new().evaluate(&b).unwrap();
        let n = NativeEngine::naive().evaluate(&b).unwrap();
        assert_eq!(a, n);
        assert_eq!(a.fits[0], 1.0);
        assert_eq!(a.conflict[0], 0.0);
        assert_eq!(a.delay_cost[0], 0.0);
    }

    #[test]
    fn evaluate_into_reuses_buffers() {
        let b = canonical_batch();
        let mut e = NativeEngine::new();
        let fresh = e.evaluate(&b).unwrap();
        let mut pooled = DecisionOutputs::default();
        e.evaluate_into(&b, &mut pooled).unwrap();
        assert_eq!(pooled, fresh);
        // Re-fill after a dirty intermediate state: identical again.
        pooled.reset(3);
        e.evaluate_into(&b, &mut pooled).unwrap();
        assert_eq!(pooled, fresh);
    }

    #[test]
    fn batch_reset_matches_empty() {
        let mut pooled = DecisionBatch::empty(8, 16, 4, 1.0, 2.0);
        pooled.set_row(0, JobId(1), &[10, 20], 100, 3);
        pooled.set_queue(5, 50, 2, 1);
        pooled.reset(16, 64, 16, 30.0, 0.0);
        let fresh = DecisionBatch::empty(16, 64, 16, 30.0, 0.0);
        assert_eq!(pooled.ts, fresh.ts);
        assert_eq!(pooled.mask, fresh.mask);
        assert_eq!(pooled.cur_end, fresh.cur_end);
        assert_eq!(pooled.pred_start, fresh.pred_start);
        assert_eq!(pooled.qmask, fresh.qmask);
        assert_eq!(pooled.params, fresh.params);
        assert_eq!(pooled.row_jobs, fresh.row_jobs);
    }
}
