//! Decision analytics: batch/output types, the engine abstraction, and
//! the pure-Rust oracle engine.
//!
//! The autonomy daemon batches all running checkpointing jobs (R rows)
//! and all queued jobs (Q rows) into a [`DecisionBatch`] once per poll
//! tick and hands it to a [`DecisionEngine`]:
//!
//! - [`crate::runtime::PjrtEngine`] executes the AOT-compiled JAX/Pallas
//!   decision model (the production hot path);
//! - [`NativeEngine`] (here) re-implements the same f32 math in Rust —
//!   the correctness oracle the PJRT path is tested against, and a
//!   fallback when artifacts are absent.
//!
//! Keep the formulas in lockstep with `python/compile/kernels/ref.py`.

use crate::errors::Result;
use crate::simtime::Time;
use crate::slurm::JobId;

/// Sentinel for "no interval estimate" (fewer than 2 checkpoints).
/// Mirrors `ref.py::NO_ESTIMATE`.
pub const NO_ESTIMATE: f32 = -1.0;

/// Fixed-shape, padded, f32 batch — the decision model's input tuple.
/// Field order mirrors the artifact manifest (`artifacts/manifest.json`).
#[derive(Debug, Clone)]
pub struct DecisionBatch {
    pub r: usize,
    pub q: usize,
    pub h: usize,
    /// f32[R,H] row-major checkpoint timestamps (0-padded).
    pub ts: Vec<f32>,
    /// f32[R,H] validity mask.
    pub mask: Vec<f32>,
    /// f32[R] expected end under the current limit.
    pub cur_end: Vec<f32>,
    /// f32[R] nodes held.
    pub nodes_r: Vec<f32>,
    /// f32[R] row validity.
    pub rmask: Vec<f32>,
    /// f32[Q] backfill-predicted starts.
    pub pred_start: Vec<f32>,
    /// f32[Q] nodes requested.
    pub nodes_q: Vec<f32>,
    /// f32[Q] free nodes at the predicted start.
    pub free_at: Vec<f32>,
    /// f32[Q] row validity.
    pub qmask: Vec<f32>,
    /// [margin, safety].
    pub params: [f32; 2],
    /// Which job each R row refers to (not an engine input).
    pub row_jobs: Vec<Option<JobId>>,
}

impl DecisionBatch {
    /// An all-masked empty batch of shape (r, q, h).
    pub fn empty(r: usize, q: usize, h: usize, margin: f32, safety: f32) -> Self {
        Self {
            r,
            q,
            h,
            ts: vec![0.0; r * h],
            mask: vec![0.0; r * h],
            cur_end: vec![0.0; r],
            nodes_r: vec![0.0; r],
            rmask: vec![0.0; r],
            pred_start: vec![0.0; q],
            nodes_q: vec![0.0; q],
            free_at: vec![0.0; q],
            qmask: vec![0.0; q],
            params: [margin, safety],
            row_jobs: vec![None; r],
        }
    }

    /// Fill running-job row `i`. `history` is the rolling checkpoint
    /// window (ascending); only the newest `h` entries are used.
    pub fn set_row(&mut self, i: usize, job: JobId, history: &[Time], cur_end: Time, nodes: u32) {
        assert!(i < self.r);
        let tail = &history[history.len().saturating_sub(self.h)..];
        for (k, &t) in tail.iter().enumerate() {
            self.ts[i * self.h + k] = t as f32;
            self.mask[i * self.h + k] = 1.0;
        }
        self.cur_end[i] = cur_end as f32;
        self.nodes_r[i] = nodes as f32;
        self.rmask[i] = 1.0;
        self.row_jobs[i] = Some(job);
    }

    /// Fill queued-job column `k`.
    pub fn set_queue(&mut self, k: usize, pred_start: Time, nodes: u32, free_at: u32) {
        assert!(k < self.q);
        self.pred_start[k] = pred_start as f32;
        self.nodes_q[k] = nodes as f32;
        self.free_at[k] = free_at as f32;
        self.qmask[k] = 1.0;
    }

    /// Grow into a (possibly larger) target shape, preserving content.
    pub fn padded_to(&self, r: usize, q: usize, h: usize) -> DecisionBatch {
        assert!(r >= self.r && q >= self.q && h >= self.h);
        let mut out = DecisionBatch::empty(r, q, h, self.params[0], self.params[1]);
        for i in 0..self.r {
            for k in 0..self.h {
                out.ts[i * h + k] = self.ts[i * self.h + k];
                out.mask[i * h + k] = self.mask[i * self.h + k];
            }
            out.cur_end[i] = self.cur_end[i];
            out.nodes_r[i] = self.nodes_r[i];
            out.rmask[i] = self.rmask[i];
            out.row_jobs[i] = self.row_jobs[i];
        }
        out.pred_start[..self.q].copy_from_slice(&self.pred_start);
        out.nodes_q[..self.q].copy_from_slice(&self.nodes_q);
        out.free_at[..self.q].copy_from_slice(&self.free_at);
        out.qmask[..self.q].copy_from_slice(&self.qmask);
        out
    }
}

/// Per-running-job outputs of the decision model (all length R).
#[derive(Debug, Clone, PartialEq)]
pub struct DecisionOutputs {
    pub pred_next: Vec<f32>,
    pub ext_end: Vec<f32>,
    pub fits: Vec<f32>,
    pub conflict: Vec<f32>,
    pub count: Vec<f32>,
    pub mean_int: Vec<f32>,
    /// Worst-case extension delay cost, node-seconds (threshold-Hybrid).
    pub delay_cost: Vec<f32>,
}

impl DecisionOutputs {
    pub fn truncated(mut self, r: usize) -> Self {
        self.pred_next.truncate(r);
        self.ext_end.truncate(r);
        self.fits.truncate(r);
        self.conflict.truncate(r);
        self.count.truncate(r);
        self.mean_int.truncate(r);
        self.delay_cost.truncate(r);
        self
    }
}

/// The daemon's pluggable analytics backend.
///
/// Not `Send`: the PJRT client is single-threaded by design; the daemon
/// owns its engine and always calls it from one thread.
pub trait DecisionEngine {
    fn name(&self) -> &str;
    fn evaluate(&mut self, batch: &DecisionBatch) -> Result<DecisionOutputs>;
}

/// Share one engine across several sequential scenario runs (e.g. the
/// four policies of a comparison): loading + compiling the PJRT
/// executables once instead of per policy (§Perf: saves ~0.6 s per
/// avoided load on this testbed).
#[derive(Clone)]
pub struct SharedEngine(pub std::rc::Rc<std::cell::RefCell<dyn DecisionEngine>>);

impl SharedEngine {
    pub fn new(engine: impl DecisionEngine + 'static) -> Self {
        Self(std::rc::Rc::new(std::cell::RefCell::new(engine)))
    }
}

impl DecisionEngine for SharedEngine {
    fn name(&self) -> &str {
        "shared"
    }

    fn evaluate(&mut self, batch: &DecisionBatch) -> Result<DecisionOutputs> {
        self.0.borrow_mut().evaluate(batch)
    }
}

/// Pure-Rust oracle implementing the L2 model's math in f32, mirroring
/// `ref.py` operation for operation.
#[derive(Debug, Default)]
pub struct NativeEngine;

impl NativeEngine {
    pub fn new() -> Self {
        Self
    }
}

impl DecisionEngine for NativeEngine {
    fn name(&self) -> &str {
        "native"
    }

    fn evaluate(&mut self, b: &DecisionBatch) -> Result<DecisionOutputs> {
        let (r, q, h) = (b.r, b.q, b.h);
        let mut out = DecisionOutputs {
            pred_next: vec![0.0; r],
            ext_end: vec![0.0; r],
            fits: vec![0.0; r],
            conflict: vec![0.0; r],
            count: vec![0.0; r],
            mean_int: vec![0.0; r],
            delay_cost: vec![0.0; r],
        };
        let (margin, safety) = (b.params[0], b.params[1]);

        for i in 0..r {
            let ts = &b.ts[i * h..(i + 1) * h];
            let mask = &b.mask[i * h..(i + 1) * h];

            // ckpt_stats (see kernels/ckpt_stats.py)
            let mut count = 0.0f32;
            let mut last = 0.0f32;
            for k in 0..h {
                count += mask[k];
                last = last.max(ts[k] * mask[k]);
            }
            let mut nd = 0.0f32;
            let mut sum_d = 0.0f32;
            for k in 0..h - 1 {
                let dm = mask[k + 1] * mask[k];
                nd += dm;
                sum_d += (ts[k + 1] - ts[k]) * dm;
            }
            let nd_safe = nd.max(1.0);
            let mean = sum_d / nd_safe;
            let mut var = 0.0f32;
            for k in 0..h - 1 {
                let dm = mask[k + 1] * mask[k];
                let d = ts[k + 1] - ts[k] - mean;
                var += dm * d * d;
            }
            var /= nd_safe;
            let std = var.sqrt();
            let have = count >= 2.0;
            let mean = if have { mean } else { NO_ESTIMATE };
            let std = if have { std } else { 0.0 };

            // prediction (see model.py)
            let pred_next = if have { last + mean + safety * std } else { -1.0 };
            let ext_end = if have { pred_next + margin } else { -1.0 };
            let fits = if have && pred_next + margin <= b.cur_end[i] { 1.0 } else { 0.0 };

            // conflict + delay_cost (see kernels/conflict.py,
            // kernels/delay_cost.py)
            let rmask_eff = b.rmask[i] * if have { 1.0 } else { 0.0 };
            let mut conflict = 0.0f32;
            let mut cost = 0.0f32;
            if rmask_eff > 0.0 {
                for k in 0..q {
                    let in_window =
                        b.pred_start[k] >= b.cur_end[i] && b.pred_start[k] < ext_end;
                    let needs_r = b.nodes_q[k] > b.free_at[k] - b.nodes_r[i];
                    if in_window && needs_r && b.qmask[k] > 0.0 {
                        conflict = 1.0;
                        let push = (ext_end - b.pred_start[k]).max(0.0);
                        cost += push * b.nodes_q[k];
                    }
                }
            }

            out.pred_next[i] = pred_next;
            out.ext_end[i] = ext_end;
            out.fits[i] = fits;
            out.conflict[i] = conflict;
            out.count[i] = count;
            out.mean_int[i] = mean;
            out.delay_cost[i] = cost;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn canonical_batch() -> DecisionBatch {
        // The paper's canonical job: ckpts at 420/840/1260, limit 1440.
        let mut b = DecisionBatch::empty(16, 64, 16, 30.0, 0.0);
        b.set_row(0, JobId(7), &[420, 840, 1260], 1440, 1);
        b
    }

    #[test]
    fn canonical_prediction() {
        let out = NativeEngine::new().evaluate(&canonical_batch()).unwrap();
        assert_eq!(out.count[0], 3.0);
        assert_eq!(out.mean_int[0], 420.0);
        assert_eq!(out.pred_next[0], 1680.0);
        assert_eq!(out.ext_end[0], 1710.0);
        assert_eq!(out.fits[0], 0.0, "1680+30 > 1440");
        assert_eq!(out.conflict[0], 0.0, "empty queue");
        // Masked rows stay sentineled.
        assert_eq!(out.pred_next[5], -1.0);
        assert_eq!(out.count[5], 0.0);
    }

    #[test]
    fn two_checkpoints_fit() {
        let mut b = DecisionBatch::empty(16, 64, 16, 30.0, 0.0);
        b.set_row(0, JobId(0), &[420, 840], 1440, 1);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.pred_next[0], 1260.0);
        assert_eq!(out.fits[0], 1.0, "1260+30 <= 1440");
    }

    #[test]
    fn one_checkpoint_no_estimate() {
        let mut b = DecisionBatch::empty(16, 64, 16, 30.0, 0.0);
        b.set_row(0, JobId(0), &[420], 1440, 1);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.count[0], 1.0);
        assert_eq!(out.mean_int[0], NO_ESTIMATE);
        assert_eq!(out.fits[0], 0.0);
        assert_eq!(out.conflict[0], 0.0, "no estimate -> never extend, so no conflict");
    }

    #[test]
    fn conflict_detection() {
        let mut b = canonical_batch();
        // Queued job planned at 1500 (inside [1440, 1710)), needs 10
        // nodes, 9 free at 1500 without our 1 node -> wait: free_at=10
        // includes our release; 10 - 1 = 9 < 10 -> conflict.
        b.set_queue(0, 1500, 10, 10);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.conflict[0], 1.0);

        // Plenty free -> no conflict.
        let mut b2 = canonical_batch();
        b2.set_queue(0, 1500, 10, 20);
        assert_eq!(NativeEngine::new().evaluate(&b2).unwrap().conflict[0], 0.0);

        // Outside the window -> no conflict.
        let mut b3 = canonical_batch();
        b3.set_queue(0, 1710, 10, 10);
        assert_eq!(NativeEngine::new().evaluate(&b3).unwrap().conflict[0], 0.0);
    }

    #[test]
    fn delay_cost_arithmetic() {
        let mut b = canonical_batch(); // cur_end 1440, ext_end 1710
        // Two conflicting queued jobs: pushed from 1500 (4 nodes) and
        // 1700 (2 nodes) to 1710: cost = 210*4 + 10*2 = 860.
        b.set_queue(0, 1500, 4, 4);
        b.set_queue(1, 1700, 2, 2);
        b.set_queue(2, 1800, 9, 0); // outside window: free
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.conflict[0], 1.0);
        assert_eq!(out.delay_cost[0], 210.0 * 4.0 + 10.0 * 2.0);
        // No conflict -> zero cost.
        let out2 = NativeEngine::new().evaluate(&canonical_batch()).unwrap();
        assert_eq!(out2.delay_cost[0], 0.0);
    }

    #[test]
    fn safety_factor_widens_prediction() {
        let mut b = DecisionBatch::empty(16, 64, 16, 0.0, 1.0);
        // Intervals 400 and 440: mean 420, std 20.
        b.set_row(0, JobId(0), &[400, 800, 1240], 2000, 1);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.mean_int[0], 420.0);
        assert_eq!(out.pred_next[0], 1240.0 + 420.0 + 20.0);
    }

    #[test]
    fn history_window_uses_newest() {
        let mut b = DecisionBatch::empty(16, 64, 4, 30.0, 0.0);
        let hist: Vec<Time> = (1..=10).map(|k| k * 100).collect();
        b.set_row(0, JobId(0), &hist, 5000, 1);
        let out = NativeEngine::new().evaluate(&b).unwrap();
        assert_eq!(out.count[0], 4.0);
        assert_eq!(out.pred_next[0], 1000.0 + 100.0);
    }

    #[test]
    fn padding_preserves_outputs() {
        let small = canonical_batch();
        let big = small.padded_to(64, 256, 32);
        let mut e = NativeEngine::new();
        let a = e.evaluate(&small).unwrap();
        let b = e.evaluate(&big).unwrap().truncated(16);
        assert_eq!(a, b);
    }
}
